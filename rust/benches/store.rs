//! Disk-tier TTFT bench: cold prefill vs disk-warm (blocks promoted
//! from the persistent store) vs RAM-warm (blocks resident in the
//! in-memory cache).
//!
//! ```sh
//! cargo bench --bench store                       # 8 passages x 128 tokens
//! cargo bench --bench store -- --passages 6 --passage-len 64
//! cargo bench --bench store -- --kv-quant int4    # packed low-bit tier
//! ```
//!
//! Writes `BENCH_store.json` (`--json-out PATH` overrides) with
//! `ttft_cold_ms` / `ttft_disk_warm_ms` / `ttft_ram_warm_ms` for the
//! `bench_guard` gate. The bench itself fails if the disk-warm path is
//! not faster than cold, or if the disk-warm generation diverges from
//! the cold one (promotion must be bitwise invisible — see
//! `docs/kvstore-format.md`).

use block_attn::config::{KvPrecision, KvStoreConfig};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::runtime::backend_from_args;
use block_attn::tokenizer::{QRY, SEP};
use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use block_attn::util::rng::Rng;
use block_attn::util::timer::{bench, BenchOpts};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let threads = block_attn::kernels::init_threads_from_args(&args);
    let n_passages = args.usize_or("passages", 8);
    let passage_len = args.usize_or("passage-len", 128);
    let kv_precision = KvPrecision::resolve(&args)?;

    let store_dir =
        std::env::temp_dir().join(format!("block-attn-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store_cfg = KvStoreConfig { dir: store_dir.clone(), budget_bytes: 0 };

    // Two coordinators over identically-seeded backends: `cold` never
    // sees the store; `warm` owns it.
    let mut cold = Coordinator::with_kv_precision(
        backend_from_args(&args, "tiny")?,
        256 << 20,
        kv_precision,
    );
    let mut warm = Coordinator::with_kv_precision(
        backend_from_args(&args, "tiny")?,
        256 << 20,
        kv_precision,
    );
    warm.attach_kv_store(&store_cfg)?;

    let cfg = cold.engine().config().clone();
    let max_block = cold.engine().max_block_tokens()?;
    anyhow::ensure!(
        passage_len + 1 <= max_block,
        "--passage-len {passage_len} exceeds the model's block capacity {max_block}"
    );
    let mut rng = Rng::new(11);
    let mut passage = |len: usize| -> Vec<i32> {
        let mut ids: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
        ids.push(SEP);
        ids
    };
    let blocks: Vec<Vec<i32>> = (0..n_passages).map(|_| passage(passage_len)).collect();
    let mut query = vec![QRY];
    query.extend((0..16).map(|_| rng.below(256) as i32));
    let req = Request {
        id: 1,
        blocks,
        query,
        max_new_tokens: 1,
        mode: AttentionMode::Block,
    };

    // Correctness first, untimed: cold reference generation, then the
    // disk round trip must reproduce it token for token.
    let r_cold = cold.process(&req)?;
    warm.process(&req)?;
    let spilled = warm.flush_kv_store();
    anyhow::ensure!(spilled == n_passages, "expected {n_passages} spills, got {spilled}");
    let dropped = warm.drop_resident_blocks();
    anyhow::ensure!(dropped == n_passages, "expected {n_passages} drops, got {dropped}");
    let r_disk = warm.process(&req)?;
    anyhow::ensure!(
        r_disk.tokens == r_cold.tokens,
        "disk-promoted generation diverged from cold ({:?} vs {:?})",
        r_disk.tokens,
        r_cold.tokens
    );
    anyhow::ensure!(
        r_disk.cached_blocks == n_passages,
        "disk-warm request should hit every block (hit {}/{})",
        r_disk.cached_blocks,
        n_passages
    );

    let opts = BenchOpts { warmup_iters: 1, iters: 5, max_seconds: 300.0 };
    let r_c = bench("cold", &opts, || {
        cold.clear_cache();
        cold.process(&req).expect("cold process");
    });
    let r_d = bench("disk-warm", &opts, || {
        warm.drop_resident_blocks();
        warm.process(&req).expect("disk-warm process");
    });
    let r_r = bench("ram-warm", &opts, || {
        warm.process(&req).expect("ram-warm process");
    });

    let stats = warm.cache_stats();
    anyhow::ensure!(stats.disk_hits > 0, "no disk promotions were recorded");
    anyhow::ensure!(stats.disk_errors == 0, "{} disk errors during bench", stats.disk_errors);
    anyhow::ensure!(
        r_d.p50_ms() < r_c.p50_ms(),
        "disk-warm TTFT ({:.1} ms) did not beat cold ({:.1} ms)",
        r_d.p50_ms(),
        r_c.p50_ms()
    );

    println!(
        "# store TTFT — config '{}', {} passages x {} tokens, kv {}",
        cfg.name,
        n_passages,
        passage_len,
        kv_precision.as_str()
    );
    println!("{:>12} {:>12} {:>12} {:>10}", "cold", "disk-warm", "ram-warm", "speedup");
    println!(
        "{:>10.1}ms {:>10.1}ms {:>10.1}ms {:>9.2}x",
        r_c.p50_ms(),
        r_d.p50_ms(),
        r_r.p50_ms(),
        r_c.p50_ms() / r_d.p50_ms()
    );

    let report = Json::obj(vec![
        ("bench", Json::str("store")),
        ("model", Json::str(cfg.name.clone())),
        ("backend", Json::str(block_attn::runtime::backend_choice(&args))),
        ("kv_precision", Json::str(kv_precision.as_str())),
        ("threads", Json::num(threads as f64)),
        ("passages", Json::num(n_passages as f64)),
        ("passage_len", Json::num(passage_len as f64)),
        ("ttft_cold_ms", Json::num(r_c.p50_ms())),
        ("ttft_disk_warm_ms", Json::num(r_d.p50_ms())),
        ("ttft_ram_warm_ms", Json::num(r_r.p50_ms())),
        ("disk_speedup", Json::num(r_c.p50_ms() / r_d.p50_ms())),
        ("store_entries", Json::num(stats.disk_entries as f64)),
        ("store_bytes", Json::num(stats.disk_bytes as f64)),
    ]);
    let out_path = args.str_or("json-out", "BENCH_store.json");
    std::fs::write(&out_path, format!("{report}\n"))?;
    eprintln!("# wrote {out_path}");
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    let _ = std::fs::remove_dir_all(&store_dir);
    Ok(())
}
