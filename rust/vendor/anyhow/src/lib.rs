//! Offline shim of the [`anyhow`](https://docs.rs/anyhow) error type.
//!
//! The build environment for this repository has no network access, so
//! crates.io dependencies are vendored. This crate reimplements the
//! subset of anyhow's surface that `block-attn` uses:
//!
//! * [`Error`] — an opaque error value carrying a context chain.
//! * [`Result`] — `Result<T, Error>` with a default error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on results.
//! * A blanket `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Semantics match the real crate where it matters: `{}` displays the
//! outermost message, `{:#}` displays the whole chain separated by
//! `": "`, and `Error` deliberately does **not** implement
//! `std::error::Error` (which is what makes the blanket `From` legal).

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of messages, outermost first.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) context.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { chain: vec![msg.into()] }
    }

    /// Attach an outer context message.
    pub fn context(mut self, msg: impl Into<String>) -> Error {
        self.chain.insert(0, msg.into());
        self
    }

    /// The outermost message.
    pub fn to_msg(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for (i, c) in self.chain.iter().enumerate().skip(1) {
            write!(f, "\n\nCaused by ({i}):\n    {c}")?;
        }
        Ok(())
    }
}

// The blanket conversion that makes `?` work on std errors. Legal only
// because `Error` itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve source chains as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error with an outer message.
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    /// Wrap the error with a lazily-built outer message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| e.into().context(msg.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_msg(), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_msg(), "x must be positive, got -1");
        assert_eq!(f(11).unwrap_err().to_msg(), "x too large: 11");
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_msg(), "missing");
    }
}
