"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything here is written as plainly as possible (materialized score
matrices, explicit masks) so it can serve as the ground truth the kernels
are validated against in ``python/tests``.
"""

import jax
import jax.numpy as jnp


def rope_cos_sin(positions, head_dim, theta):
    """cos/sin tables for RoPE at the given integer positions.

    Llama-style half-split pairing: pair ``j`` couples dims ``(j, j+d/2)``
    with angle ``pos * theta ** (-2j/d)``. Must match
    ``rust/src/rope/mod.rs``.

    Returns (cos, sin), each ``(len(positions), head_dim // 2)`` f32.
    """
    half = head_dim // 2
    j = jnp.arange(half, dtype=jnp.float32)
    inv_freq = theta ** (-2.0 * j / head_dim)
    ang = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """Rotate ``x`` of shape (L, H, head_dim) by per-position angles.

    cos/sin are (L, head_dim//2).
    """
    half = x.shape[-1] // 2
    a, b = x[..., :half], x[..., half:]
    c = cos[:, None, :]
    s = sin[:, None, :]
    return jnp.concatenate([a * c - b * s, a * s + b * c], axis=-1)


def reencode_k(k, delta, theta):
    """Reference position re-encoding (paper Eq. 3).

    Rotates cached keys ``k`` of shape (layers, L, kv_heads, head_dim) by
    ``delta`` positions: keys encoded at local positions ``0..L`` become
    keys at absolute positions ``delta..delta+L``.
    """
    layers, L, H, d = k.shape
    pos = jnp.full((1,), delta, dtype=jnp.int32)
    cos, sin = rope_cos_sin(pos, d, theta)  # (1, d/2)
    half = d // 2
    a, b = k[..., :half], k[..., half:]
    c = cos[0][None, None, None, :]
    s = sin[0][None, None, None, :]
    return jnp.concatenate([a * c - b * s, a * s + b * c], axis=-1)


def attention(q, k, v, mask):
    """Masked multi-head attention with materialized scores.

    q: (H, Lq, d); k, v: (H, Lk, d); mask: (Lq, Lk) bool (True = attend).
    Returns (H, Lq, d) f32.
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.einsum("hid,hjd->hij", q.astype(jnp.float32), k.astype(jnp.float32))
    s = jnp.where(mask[None, :, :], s * scale, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hij,hjd->hid", p, v.astype(jnp.float32))


def causal_mask(L, length):
    """(L, L) causal mask further restricted to the first ``length`` keys."""
    rows = jnp.arange(L)[:, None]
    cols = jnp.arange(L)[None, :]
    return (cols <= rows) & (cols < length)


def block_attention(q, k, v, length, kv_repeat=1):
    """Reference for the per-block prefill kernel: causal + length mask.

    q: (Hq, L, d); k, v: (Hkv, L, d) with Hq = Hkv * kv_repeat (GQA).
    """
    if kv_repeat > 1:
        k = jnp.repeat(k, kv_repeat, axis=0)
        v = jnp.repeat(v, kv_repeat, axis=0)
    return attention(q, k, v, causal_mask(q.shape[1], length))


def context_attention(q, kv_k, kv_v, ctx_capacity, ctx_len, kv_repeat=1):
    """Reference for the final-block kernel.

    The key/value sequence is the concatenation of a padded context region
    of static capacity ``ctx_capacity`` (valid prefix ``ctx_len``) and the
    final block itself. Query ``i`` attends to context keys ``< ctx_len``
    and causally to final-block keys ``<= i``.

    q: (Hq, Lq, d); kv_k/kv_v: (Hkv, ctx_capacity + Lq, d).
    """
    if kv_repeat > 1:
        kv_k = jnp.repeat(kv_k, kv_repeat, axis=0)
        kv_v = jnp.repeat(kv_v, kv_repeat, axis=0)
    Lq = q.shape[1]
    Lk = kv_k.shape[1]
    rows = jnp.arange(Lq)[:, None]
    cols = jnp.arange(Lk)[None, :]
    in_ctx = (cols < ctx_len)
    in_self = (cols >= ctx_capacity) & (cols - ctx_capacity <= rows)
    return attention(q, kv_k, kv_v, in_ctx | in_self)
