//! General / ICL benchmarks (the Table-2 substitutes).
//!
//! Zero-shot tasks (the Block-attention model falls back to full
//! attention, paper §3.1) and few-shot ICL tasks where each
//! demonstration is an independent block (a k-shot sample = k+1 blocks).

use super::words::{rand_word, vocabulary, word};
use super::Sample;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GeneralTask {
    /// 0-shot: "copy : w" → w (IFEval-style instruction following).
    Copy,
    /// 0-shot: "reverse : abc" → "cba" (string manipulation).
    Reverse,
    /// k-shot ICL: mapping retrieval — demos define x→y pairs, the test
    /// input repeats one x (BBH/DROP-style context dependence).
    IclMap { shots: usize },
    /// k-shot ICL: single-digit modular addition "3 + 4 = 7" (GSM8K's
    /// role: arithmetic with in-context format).
    IclArith { shots: usize },
    /// k-shot ICL: sort three letters "bca -> abc" (MATH's role:
    /// symbolic manipulation with in-context format).
    IclSort { shots: usize },
}

impl GeneralTask {
    pub fn name(&self) -> String {
        match self {
            GeneralTask::Copy => "gen-copy(0-shot)".into(),
            GeneralTask::Reverse => "gen-reverse(0-shot)".into(),
            GeneralTask::IclMap { shots } => format!("icl-map({shots}-shot)"),
            GeneralTask::IclArith { shots } => format!("icl-arith({shots}-shot)"),
            GeneralTask::IclSort { shots } => format!("icl-sort({shots}-shot)"),
        }
    }

    pub fn is_zero_shot(&self) -> bool {
        matches!(self, GeneralTask::Copy | GeneralTask::Reverse)
    }

    /// The Table-2 task list.
    pub fn table2() -> Vec<GeneralTask> {
        vec![
            GeneralTask::Copy,
            GeneralTask::Reverse,
            GeneralTask::IclMap { shots: 4 },
            GeneralTask::IclArith { shots: 4 },
            GeneralTask::IclSort { shots: 3 },
        ]
    }
}

pub struct GeneralGen {
    pub task: GeneralTask,
    vocab: Vec<String>,
}

impl GeneralGen {
    pub fn new(task: GeneralTask, rng: &mut Rng, world: usize) -> GeneralGen {
        GeneralGen { task, vocab: vocabulary(rng, world, 2) }
    }

    pub fn sample(&self, rng: &mut Rng) -> Sample {
        match self.task {
            GeneralTask::Copy => {
                let w = rand_word(rng, 6);
                Sample::bare(vec![], format!("copy : {w}"), w)
            }
            GeneralTask::Reverse => {
                let w = word(rng, 2);
                let rev: String = w.chars().rev().collect();
                Sample::bare(vec![], format!("reverse : {w}"), rev)
            }
            GeneralTask::IclMap { shots } => {
                let mut xs = Vec::new();
                let mut demos = Vec::new();
                for _ in 0..shots {
                    let x = rng.pick(&self.vocab).clone();
                    let y = rand_word(rng, 4); // high-entropy: must be copied
                    demos.push(format!("{x} -> {y}"));
                    xs.push((x, y));
                }
                let (qx, qy) = xs[rng.below(xs.len())].clone();
                Sample::bare(demos, format!("{qx} ->"), qy)
            }
            GeneralTask::IclArith { shots } => {
                let mut demos = Vec::new();
                for _ in 0..shots {
                    let a = rng.below(10);
                    let b = rng.below(10);
                    demos.push(format!("{a} + {b} = {}", (a + b) % 10));
                }
                let a = rng.below(10);
                let b = rng.below(10);
                Sample::bare(demos, format!("{a} + {b} ="), format!("{}", (a + b) % 10))
            }
            GeneralTask::IclSort { shots } => {
                let mut demos = Vec::new();
                for _ in 0..shots {
                    let (o, s) = sort_pair(rng);
                    demos.push(format!("{o} => {s}"));
                }
                let (o, s) = sort_pair(rng);
                Sample::bare(demos, format!("{o} =>"), s)
            }
        }
    }
}

fn sort_pair(rng: &mut Rng) -> (String, String) {
    let mut cs: Vec<char> = (0..3).map(|_| (b'a' + rng.below(8) as u8) as char).collect();
    let orig: String = cs.iter().collect();
    cs.sort_unstable();
    (orig, cs.into_iter().collect())
}

/// A frozen few-shot exemplar set shared across many samples — the ICL
/// serving scenario: the demonstration blocks are generated once, every
/// request re-serves them from the block cache and only the query (and
/// its answer) is fresh. `GeneralGen::sample` by contrast draws new
/// demos per sample, so nothing would ever hit.
pub struct SharedIcl {
    task: GeneralTask,
    /// The frozen demonstration blocks, identical for every sample.
    pub demos: Vec<String>,
    /// For mapping tasks: the (x, y) pairs the demos define.
    pairs: Vec<(String, String)>,
}

impl SharedIcl {
    pub fn new(task: GeneralTask, rng: &mut Rng, world: usize) -> SharedIcl {
        let mut demos = Vec::new();
        let mut pairs = Vec::new();
        match task {
            GeneralTask::IclMap { shots } => {
                assert!(world >= shots, "need >= {shots} distinct words");
                let vocab = vocabulary(rng, world, 2);
                // Distinct x's so every query has a unique answer.
                let mut xs: Vec<String> = Vec::new();
                while xs.len() < shots {
                    let x = rng.pick(&vocab).clone();
                    if !xs.contains(&x) {
                        xs.push(x);
                    }
                }
                for x in xs {
                    let y = rand_word(rng, 4);
                    demos.push(format!("{x} -> {y}"));
                    pairs.push((x, y));
                }
            }
            GeneralTask::IclArith { shots } => {
                for _ in 0..shots {
                    let a = rng.below(10);
                    let b = rng.below(10);
                    demos.push(format!("{a} + {b} = {}", (a + b) % 10));
                }
            }
            GeneralTask::IclSort { shots } => {
                for _ in 0..shots {
                    let (o, s) = sort_pair(rng);
                    demos.push(format!("{o} => {s}"));
                }
            }
            GeneralTask::Copy | GeneralTask::Reverse => {}
        }
        SharedIcl { task, demos, pairs }
    }

    /// A fresh query over the frozen demo blocks.
    pub fn sample(&self, rng: &mut Rng) -> Sample {
        match self.task {
            GeneralTask::IclMap { .. } => {
                let (qx, qy) = self.pairs[rng.below(self.pairs.len())].clone();
                Sample::bare(self.demos.clone(), format!("{qx} ->"), qy)
            }
            GeneralTask::IclArith { .. } => {
                let a = rng.below(10);
                let b = rng.below(10);
                Sample::bare(
                    self.demos.clone(),
                    format!("{a} + {b} ="),
                    format!("{}", (a + b) % 10),
                )
            }
            GeneralTask::IclSort { .. } => {
                let (o, s) = sort_pair(rng);
                Sample::bare(self.demos.clone(), format!("{o} =>"), s)
            }
            GeneralTask::Copy => {
                let w = rand_word(rng, 6);
                Sample::bare(vec![], format!("copy : {w}"), w)
            }
            GeneralTask::Reverse => {
                let w = word(rng, 2);
                let rev: String = w.chars().rev().collect();
                Sample::bare(vec![], format!("reverse : {w}"), rev)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shot_has_no_blocks() {
        let mut rng = Rng::new(1);
        let g = GeneralGen::new(GeneralTask::Copy, &mut rng, 20);
        let s = g.sample(&mut rng);
        assert!(s.blocks.is_empty());
        assert!(s.query.contains(&s.answer));
    }

    #[test]
    fn icl_map_answer_is_retrievable() {
        let mut rng = Rng::new(2);
        let g = GeneralGen::new(GeneralTask::IclMap { shots: 4 }, &mut rng, 30);
        for _ in 0..20 {
            let s = g.sample(&mut rng);
            assert_eq!(s.blocks.len(), 4);
            let qx = s.query.trim_end_matches(" ->");
            assert!(
                s.blocks.iter().any(|d| d.starts_with(&format!("{qx} ->"))
                    && d.ends_with(&s.answer)),
                "query not answerable from demos: {s:?}"
            );
        }
    }

    #[test]
    fn shared_icl_freezes_demos_across_samples() {
        let mut rng = Rng::new(5);
        let shared = SharedIcl::new(GeneralTask::IclMap { shots: 4 }, &mut rng, 30);
        assert_eq!(shared.demos.len(), 4);
        for _ in 0..20 {
            let s = shared.sample(&mut rng);
            // Demo blocks never change, so a warm cache re-serves them.
            assert_eq!(s.blocks, shared.demos);
            // Every query is answerable from the frozen demos.
            let qx = s.query.trim_end_matches(" ->");
            assert!(
                s.blocks.iter().any(|d| *d == format!("{qx} -> {}", s.answer)),
                "query not answerable from frozen demos: {s:?}"
            );
        }
        let sh = SharedIcl::new(GeneralTask::IclArith { shots: 4 }, &mut rng, 10);
        assert_eq!(sh.sample(&mut rng).blocks, sh.sample(&mut rng).blocks);
    }

    #[test]
    fn arith_is_correct() {
        let mut rng = Rng::new(3);
        let g = GeneralGen::new(GeneralTask::IclArith { shots: 4 }, &mut rng, 10);
        let s = g.sample(&mut rng);
        let parts: Vec<usize> = s
            .query
            .trim_end_matches(" =")
            .split(" + ")
            .map(|x| x.trim().parse().unwrap())
            .collect();
        assert_eq!(s.answer, format!("{}", (parts[0] + parts[1]) % 10));
    }

    #[test]
    fn sort_is_sorted() {
        let mut rng = Rng::new(4);
        let g = GeneralGen::new(GeneralTask::IclSort { shots: 3 }, &mut rng, 10);
        let s = g.sample(&mut rng);
        let mut cs: Vec<char> = s.answer.chars().collect();
        let orig = cs.clone();
        cs.sort_unstable();
        assert_eq!(cs, orig);
    }
}
