//! Table 1 reproduction: accuracy of the eight model/mode variants on
//! the four synthetic RAG benchmarks.
//!
//! Requires trained checkpoints: `make checkpoints` (≈20 min on 1 core).
//!
//! ```sh
//! cargo bench --bench table1_rag -- --samples 50
//! cargo bench --bench table1_rag -- --show-masks   # Figure-1 ASCII masks
//! ```
//!
//! Paper row → ours:
//!   Tulu3-SFT                = base ckpt, full attention
//!   Tulu3-RAG                = rag  ckpt, full attention
//!   Tulu3-RAG-Superposition  = rag  ckpt, parallel-position block mode
//!   Tulu3-RAG-promptCache    = rag  ckpt, block mode w/o re-encoding
//!   Tulu3-block-ft           = block ckpt, block mode
//!   Tulu3-block-ft-full      = block ckpt, full attention
//!   Tulu3-block-ft-w/o-pos   = block ckpt, block mode w/o re-encoding
//!   Tulu3-block-w/o-ft       = rag  ckpt, block mode

use block_attn::coordinator::{AttentionMode, Coordinator};
use block_attn::runtime::backend_from_args;
use block_attn::train::eval::{accuracy, answer_nll, EvalOpts};
use block_attn::train::presets::rag_eval_by_variant;
use block_attn::util::cli::Args;
use block_attn::Backend;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    if args.flag("show-masks") {
        show_masks();
        return Ok(());
    }
    let samples_n = args.usize_or("samples", 25);
    let ck_dir = PathBuf::from(args.str_or("checkpoints", "checkpoints"));
    let model = args.str_or("model", "tiny");

    for tag in ["base", "rag", "block"] {
        let p = ck_dir.join(format!("{model}_{tag}.bin"));
        if !p.exists() {
            eprintln!("missing checkpoint {p:?} — run `make checkpoints` first");
            std::process::exit(0); // not a test failure: artifacts absent
        }
    }

    let engine = backend_from_args(&args, &model)?;
    let mut coord = Coordinator::new(engine, 256 << 20);
    let benches = rag_eval_by_variant(samples_n);

    // (paper row, checkpoint, mode)
    let rows: Vec<(&str, &str, AttentionMode)> = vec![
        ("SFT (base, full)", "base", AttentionMode::Full),
        ("RAG-ft (full)", "rag", AttentionMode::Full),
        ("RAG-ft + superposition", "rag", AttentionMode::BlockParallel),
        ("RAG-ft + promptCache", "rag", AttentionMode::BlockNoReencode),
        ("block-ft (block)", "block", AttentionMode::Block),
        ("block-ft (full)", "block", AttentionMode::Full),
        ("block-ft w/o pos", "block", AttentionMode::BlockNoReencode),
        ("block w/o ft", "rag", AttentionMode::Block),
    ];

    println!("# Table 1 — four synthetic RAG benchmarks ({samples_n} samples each).");
    println!("# cell = exact-match accuracy% (teacher-forced answer NLL, nats/token; lower=better).");
    println!("# NLL is the primary signal at tiny-model scale — see EXPERIMENTS.md.");
    print!("{:<26}", "model / mode");
    for (name, _) in &benches {
        print!(" {name:>21}");
    }
    println!(" {:>17}", "avg");

    let mut loaded = String::new();
    for (label, ckpt, mode) in rows {
        if loaded != ckpt {
            coord
                .engine()
                .load_params_file(&ck_dir.join(format!("{model}_{ckpt}.bin")))?;
            loaded = ckpt.to_string();
        }
        print!("{label:<26}");
        let mut acc_sum = 0.0;
        let mut nll_sum = 0.0;
        for (_, samples) in &benches {
            let o = EvalOpts { mode, max_new_tokens: 48, fresh_cache: true };
            let acc = accuracy(&mut coord, samples, &o)?;
            let nll = answer_nll(&mut coord, samples, &o)?;
            acc_sum += acc;
            nll_sum += nll;
            print!(" {:>12.1}% ({:5.3})", acc * 100.0, nll);
        }
        println!(
            " {:>8.1}% ({:5.3})",
            acc_sum / benches.len() as f64 * 100.0,
            nll_sum / benches.len() as f64
        );
    }
    println!("\n# paper shape: block-ft ≈ RAG-ft; w/o-ft degrades; promptCache/superposition");
    println!("# worse still; w/o-pos degrades; block-ft-full ≥ RAG-ft (mode switch is free).");
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}

/// Figure 1: render the full vs block attention masks for a 3-block
/// prompt (two 4-token passages + 4-token query).
fn show_masks() {
    let seg = [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2];
    let max_seg = 2;
    println!("Figure 1 — left: full attention; right: Block-attention");
    for i in 0..seg.len() {
        let mut l = String::new();
        let mut r = String::new();
        for j in 0..seg.len() {
            let causal = j <= i;
            l.push(if causal { '#' } else { '.' });
            let blk = causal && (seg[i] == seg[j] || seg[i] == max_seg);
            r.push(if blk { '#' } else { '.' });
        }
        println!("  {l}    {r}");
    }
}
