//! Game-AI workload (paper Appendix A): a Texas-hold'em-like gamecore
//! JSON stream where consecutive frames are >99% identical, so per-field
//! block caching eliminates nearly all prefill work.

use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// A simulated poker table whose state serializes to gamecore JSON.
pub struct GamecoreSim {
    players: usize,
    pot: u64,
    round: u64,
    chips: Vec<(u64, u64)>, // (bet, remain) per player
    board: Vec<String>,
    history: Vec<String>,
    rng: Rng,
}

impl GamecoreSim {
    pub fn new(players: usize, seed: u64) -> GamecoreSim {
        let mut rng = Rng::new(seed);
        let board = (0..3).map(|_| card(&mut rng)).collect();
        GamecoreSim {
            players,
            pot: 0,
            round: 0,
            chips: vec![(0, 1000); players],
            board,
            history: Vec::new(),
            rng,
        }
    }

    /// Current frame as gamecore JSON.
    pub fn frame(&self) -> Json {
        let mut chips = BTreeMap::new();
        for (i, (bet, remain)) in self.chips.iter().enumerate() {
            chips.insert(
                format!("p{}", i + 1),
                Json::obj(vec![
                    ("bet", Json::num(*bet as f64)),
                    ("remain", Json::num(*remain as f64)),
                ]),
            );
        }
        let mut o = BTreeMap::new();
        o.insert("chips".into(), Json::Obj(chips));
        o.insert("pot".into(), Json::num(self.pot as f64));
        o.insert("round".into(), Json::num(self.round as f64));
        o.insert(
            "board".into(),
            Json::Arr(self.board.iter().map(|c| Json::str(c.clone())).collect()),
        );
        o.insert(
            "history".into(),
            Json::Arr(self.history.iter().map(|h| Json::str(h.clone())).collect()),
        );
        Json::Obj(o)
    }

    /// Advance one action: exactly one player's chips change (the paper's
    /// example: `state['chips']['p2']` is the only delta between frames).
    pub fn step(&mut self) {
        let p = self.rng.below(self.players);
        let bet = 10 * (1 + self.rng.below(5) as u64);
        let (b, r) = self.chips[p];
        let bet = bet.min(r);
        self.chips[p] = (b + bet, r - bet);
        self.pot += bet;
        self.round += 1;
        if self.history.len() > 8 {
            self.history.remove(0);
        }
        self.history.push(format!("p{} bets {bet}", p + 1));
    }
}

fn card(rng: &mut Rng) -> String {
    let ranks = ["2", "3", "4", "5", "6", "7", "8", "9", "T", "J", "Q", "K", "A"];
    let suits = ["s", "h", "d", "c"];
    format!("{}{}", rng.pick(&ranks), rng.pick(&suits))
}

/// Fraction of identical blocks between two consecutive frames (the
/// paper reports >99.5% repetition on real gamecore data; our simulator
/// is smaller so the per-block fraction is lower but still dominant).
pub fn repetition_ratio(a: &[Vec<i32>], b: &[Vec<i32>]) -> f64 {
    if b.is_empty() {
        return 0.0;
    }
    let set: std::collections::HashSet<&Vec<i32>> = a.iter().collect();
    let same = b.iter().filter(|x| set.contains(*x)).count();
    same as f64 / b.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::segmenter::segment_gamecore;
    use crate::tokenizer::ByteTokenizer;

    #[test]
    fn frames_mostly_repeat() {
        let tok = ByteTokenizer::new();
        let mut sim = GamecoreSim::new(6, 42);
        let f0 = segment_gamecore(&tok, &sim.frame(), "act");
        sim.step();
        let f1 = segment_gamecore(&tok, &sim.frame(), "act");
        let ratio = repetition_ratio(&f0.blocks, &f1.blocks);
        // chips of one player + pot + round + history change; the other
        // 5 players' chips and the board repeat.
        assert!(ratio > 0.5, "repetition {ratio}");
        assert_eq!(f0.blocks.len(), f1.blocks.len());
    }

    #[test]
    fn deterministic_frames() {
        let a = GamecoreSim::new(4, 7).frame().to_string();
        let b = GamecoreSim::new(4, 7).frame().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn step_changes_exactly_one_player() {
        let mut sim = GamecoreSim::new(6, 1);
        let before = sim.chips.clone();
        sim.step();
        let changed = sim
            .chips
            .iter()
            .zip(&before)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(changed, 1);
    }
}
