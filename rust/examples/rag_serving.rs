//! End-to-end RAG serving driver — the repository's headline example.
//!
//! Builds a passage pool ("external database"), pre-computes block KV for
//! it (paper §1: passages "might have been computed"), then replays a
//! Zipf-skewed query stream through the continuous batcher in both
//! attention modes and reports TTFT percentiles, FLOPs-TFT, throughput
//! and cache efficiency — the serving-side counterpart of Table 3.
//!
//! ```sh
//! cargo run --release --example rag_serving -- \
//!     --model tiny --requests 40 --passages-per-query 6 \
//!     --checkpoint checkpoints/tiny_block.bin
//! ```

use block_attn::coordinator::batcher::{run_batch, BatchPolicy};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::runtime::backend_from_args;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::cli::Args;
use block_attn::util::rng::Rng;
use block_attn::util::stats::Summary;
use block_attn::workload::traces::RagTrace;
use block_attn::Backend;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    let n_requests = args.usize_or("requests", 40);
    let k = args.usize_or("passages-per-query", 6);
    let pool_size = args.usize_or("pool", 64);
    let zipf_s = args.f64_or("zipf", 1.1);
    let max_new = args.usize_or("max-new-tokens", 12);

    let engine = backend_from_args(&args, "tiny")?;
    if let Some(ck) = args.get("checkpoint") {
        engine.load_params_file(std::path::Path::new(ck))?;
    }
    engine.warmup()?;
    let mut coord = Coordinator::new(engine, 256 << 20);
    let tok = ByteTokenizer::new();

    // The external database + query trace.
    let mut rng = Rng::new(args.u64_or("seed", 42));
    let trace = RagTrace::build(&mut rng, pool_size);
    let samples: Vec<_> = (0..n_requests)
        .map(|_| trace.request(&mut rng, k, zipf_s))
        .collect();

    // Offline KV pre-computation of the whole passage pool.
    let t = Instant::now();
    for p in &trace.pool {
        let mut ids = tok.encode(p);
        ids.push(block_attn::tokenizer::SEP);
        coord.precompute_block(&ids)?;
    }
    println!(
        "pre-computed KV for {} passages in {:.2} s\n",
        trace.pool.len(),
        t.elapsed().as_secs_f64()
    );

    let reqs = |mode: AttentionMode| -> Vec<Request> {
        samples
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let sp = s.segment(&tok);
                Request {
                    id: i as u64,
                    blocks: sp.blocks,
                    query: sp.query,
                    max_new_tokens: max_new,
                    mode,
                }
            })
            .collect()
    };
    let policy = BatchPolicy {
        max_active: args.usize_or("max-active", 4),
        max_active_tokens: args.usize_or("max-active-tokens", 4096),
        ..BatchPolicy::default()
    };

    println!("── serving {n_requests} requests ({k} passages each, zipf {zipf_s}) ──");
    for mode in [AttentionMode::Block, AttentionMode::Full] {
        let t0 = Instant::now();
        let out = run_batch(&mut coord, reqs(mode), &policy)?;
        let wall = t0.elapsed().as_secs_f64();
        let mut ttft = Summary::new();
        let mut flops = Summary::new();
        let mut cached = 0usize;
        let mut total = 0usize;
        for r in &out {
            ttft.add(r.ttft * 1e3);
            flops.add(r.flops_tft);
            cached += r.cached_blocks;
            total += r.total_blocks;
        }
        println!(
            "{mode:?}: ttft(incl-queue) p50={:7.2} ms p95={:7.2} ms  flops_tft mean={:9.3e}  \
             hit {}/{} blocks  wall={:6.2} s  ({:.2} req/s)",
            ttft.p50(),
            ttft.p95(),
            flops.mean(),
            cached,
            total,
            wall,
            out.len() as f64 / wall,
        );
    }
    println!("\ncache: {:?}", coord.cache_stats());
    Ok(())
}
