"""L1 Pallas kernel: RoPE position re-encoding (paper §2.3, Eq. 3).

Rotates every cached key of a block by ``delta`` positions so that keys
encoded at local positions ``0..L`` become keys at absolute positions
``delta..delta+L``. Because RoPE rotations compose additively this is
exactly equivalent to recomputing the keys at the shifted positions —
the invariant pinned by ``python/tests/test_rope.py`` and mirrored by the
native Rust implementation in ``rust/src/rope/``.

TPU shape: one grid step per layer; the (L, kv_heads, d) key block of
that layer is staged into VMEM, rotated with a single broadcasted
cos/sin pair (the angle depends only on ``delta``, not on the token), and
written back. The rotation is element-wise → VPU work, no MXU needed.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reencode_kernel(k_ref, delta_ref, o_ref, *, theta):
    k = k_ref[...].astype(jnp.float32)  # (L, H, d)
    d = k.shape[-1]
    half = d // 2
    j = jax.lax.iota(jnp.float32, half)
    inv_freq = jnp.exp(j * (-2.0 / d) * jnp.log(jnp.float32(theta)))
    ang = delta_ref[0].astype(jnp.float32) * inv_freq  # (d/2,)
    cos = jnp.cos(ang)[None, None, :]
    sin = jnp.sin(ang)[None, None, :]
    a, b = k[..., :half], k[..., half:]
    o_ref[...] = jnp.concatenate(
        [a * cos - b * sin, a * sin + b * cos], axis=-1
    ).astype(o_ref.dtype)


def reencode_k(k, delta, *, theta, interpret=True):
    """Rotate cached keys by ``delta`` positions.

    k: (layers, L, kv_heads, head_dim); delta: (1,) i32.
    Returns the re-encoded keys, same shape/dtype.
    """
    N, L, H, d = k.shape
    import functools

    kern = functools.partial(_reencode_kernel, theta=theta)
    return pl.pallas_call(
        kern,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((None, L, H, d), lambda n: (n, 0, 0, 0)),
            pl.BlockSpec((1,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((None, L, H, d), lambda n: (n, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, L, H, d), k.dtype),
        interpret=interpret,
    )(k, delta)
