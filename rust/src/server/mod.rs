//! TCP JSON-line serving front-end.
//!
//! **Wire protocol: see `docs/serving.md`** — the normative spec of the
//! request line, per-token streaming frames, the final response line,
//! error lines, and every field of the `stats` reply. In one sentence:
//! one JSON object per line in each direction, and a client reads until
//! it sees a line carrying a `text` or `error` field.
//!
//! Architecture: the engine is `!Send`, so a dedicated **engine thread**
//! owns the [`Coordinator`] and runs the **continuous-batching loop**:
//! requests land in a bounded admission queue (bound =
//! `BatchPolicy::queue_depth`; a full queue blocks `submit`, which is
//! the client-facing backpressure), the loop admits at most one prefill
//! per decode round under the [`BatchPolicy`] slot + token budgets, and
//! every decode round advances *all* active sessions one token through
//! a single `Backend::decode_batch` dispatch per layer. Connection
//! handlers (on the [`ThreadPool`]) parse requests, submit jobs and
//! stream frames back — the vLLM-router shape at miniature scale.
//! Python is nowhere in this path.
//!
//! Determinism contract: a batched decode round is **bitwise identical**
//! to decoding each session serially (see `Backend::decode_batch`), at
//! every thread count and KV tier — so continuous batching changes
//! throughput and latency, never output text.

use crate::config::SegmentPolicy;
use crate::coordinator::batcher::{BatchEvent, BatchPolicy, BatchRunner, Pending};
use crate::coordinator::segmenter::{policy_block_texts, RawPrompt};
use crate::coordinator::{AttentionMode, Coordinator, DecodeState, Request, Response};
use crate::runtime::Backend;
use crate::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

/// A parsed wire request under the default pre-segmented policy
/// ([`SegmentPolicy::Passages`]): the historical protocol surface,
/// kept for callers that never carry raw prompt fields.
pub fn parse_request(line: &str, tok: &ByteTokenizer) -> Result<Request> {
    parse_request_with_policy(line, tok, SegmentPolicy::Passages)
}

/// An optional string field, loud on a non-string value.
fn opt_str(j: &Json, key: &str) -> Result<Option<String>> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => Ok(Some(
            v.as_str()
                .ok_or_else(|| anyhow!("'{key}' must be a string (got {v})"))?
                .to_string(),
        )),
    }
}

/// An optional array-of-strings field, loud on anything else.
fn opt_str_arr(j: &Json, key: &str) -> Result<Option<Vec<String>>> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("'{key}' must be an array of strings"))?;
            let mut out = Vec::with_capacity(arr.len());
            for (i, p) in arr.iter().enumerate() {
                out.push(
                    p.as_str()
                        .ok_or_else(|| anyhow!("{key}[{i}] is not a string (got {p})"))?
                        .to_string(),
                );
            }
            Ok(Some(out))
        }
    }
}

/// A parsed wire request under an explicit segmentation policy.
///
/// Context blocks come from either a pre-segmented `passages` array
/// (served identically under every policy) or — per `policy` — raw
/// `prompt`/`demos`/`system`+`turns`/`state` fields that
/// [`policy_block_texts`] cuts into block texts. Both shapes then take
/// the same tokenize step (byte-encode + `SEP` per block; `QRY` +
/// byte-encode for the query), so a raw request is bitwise
/// interchangeable with its pre-segmented equivalent.
pub fn parse_request_with_policy(
    line: &str,
    tok: &ByteTokenizer,
    policy: SegmentPolicy,
) -> Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let id = j.get("id").as_usize().unwrap_or(0) as u64;
    let mode = AttentionMode::parse(j.get("mode").as_str().unwrap_or("block"))?;
    let raw = RawPrompt {
        prompt: opt_str(&j, "prompt")?,
        system: opt_str(&j, "system")?,
        demos: opt_str_arr(&j, "demos")?,
        turns: opt_str_arr(&j, "turns")?,
        state: match j.get("state") {
            Json::Null => None,
            v => Some(v.clone()),
        },
    };
    let segmented = policy_block_texts(policy, &raw)?;
    let passages_j = j.get("passages");
    if segmented.is_some() && !matches!(passages_j, Json::Null) {
        bail!(
            "a request may carry either raw prompt fields or a \
             pre-segmented 'passages' array, not both"
        );
    }
    let block_texts: Vec<String> = match segmented {
        Some(texts) => texts,
        None => match passages_j {
            Json::Null => Vec::new(),
            _ => passages_j
                .as_arr()
                .ok_or_else(|| anyhow!("'passages' must be an array of strings"))?
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    Ok(p.as_str()
                        .ok_or_else(|| anyhow!("passages[{i}] is not a string (got {p})"))?
                        .to_string())
                })
                .collect::<Result<_>>()?,
        },
    };
    let blocks = block_texts
        .iter()
        .map(|text| {
            let mut ids = tok.encode(text);
            ids.push(crate::tokenizer::SEP);
            ids
        })
        .collect();
    let query_text = j.req_str("query")?;
    let mut query = vec![crate::tokenizer::QRY];
    query.extend(tok.encode(query_text));
    Ok(Request {
        id,
        blocks,
        query,
        max_new_tokens: j.get("max_new_tokens").as_usize().unwrap_or(16),
        mode,
    })
}

/// Serialize the final response line.
pub fn format_response(resp: &Response, tok: &ByteTokenizer) -> String {
    Json::obj(vec![
        ("id", Json::num(resp.id as f64)),
        ("text", Json::str(tok.decode_until_eos(&resp.tokens))),
        ("ttft_ms", Json::num(resp.ttft * 1e3)),
        ("block_prefill_ms", Json::num(resp.block_prefill_s * 1e3)),
        ("flops_tft", Json::num(resp.flops_tft)),
        ("cached_blocks", Json::num(resp.cached_blocks as f64)),
        ("total_blocks", Json::num(resp.total_blocks as f64)),
        ("prompt_tokens", Json::num(resp.prompt_tokens as f64)),
    ])
    .to_string()
}

/// Serialize one streamed token frame.
pub fn format_token_frame(id: u64, token: i32) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("token", Json::num(token as f64)),
    ])
    .to_string()
}

fn format_error(id: u64, err: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("error", Json::str(err)),
    ])
    .to_string()
}

/// Best-effort request id of a line that failed [`parse_request`]: when
/// the line is still parsable JSON carrying a numeric `id` (e.g. a
/// request with a malformed `passages` field), error lines echo it so
/// the client can correlate; otherwise 0.
pub fn request_id_hint(line: &str) -> u64 {
    Json::parse(line)
        .map(|j| j.get("id").as_usize().unwrap_or(0) as u64)
        .unwrap_or(0)
}

/// One line of a streamed reply: intermediate token frames, then
/// exactly one `Final` (full response or error).
#[derive(Debug)]
pub enum Frame {
    Token(String),
    Final(String),
}

enum Job {
    /// A generation request, its arrival time (TTFT is charged from
    /// here, including any time spent blocked on the full admission
    /// queue) and the per-request reply channel.
    Generate(Request, Instant, mpsc::Sender<Frame>),
    Stats(mpsc::Sender<String>),
}

/// Handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::SyncSender<Job>,
}

impl EngineHandle {
    /// Spawn the engine thread around a coordinator factory, with the
    /// batching policy resolved from the environment. The factory runs
    /// *on* the engine thread: backends need not be `Send` (the PJRT
    /// engine wraps raw C pointers), so the coordinator is built where
    /// it lives.
    pub fn spawn<B: Backend + 'static>(
        make: impl FnOnce() -> Result<Coordinator<B>> + Send + 'static,
    ) -> Result<EngineHandle> {
        Self::spawn_with_policy(make, BatchPolicy::from_env())
    }

    /// [`Self::spawn`] with an explicit batching policy (the `serve`
    /// CLI resolves flags > env > defaults via `BatchPolicy::resolve`).
    pub fn spawn_with_policy<B: Backend + 'static>(
        make: impl FnOnce() -> Result<Coordinator<B>> + Send + 'static,
        policy: BatchPolicy,
    ) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::sync_channel::<Job>(policy.queue_depth);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("block-attn-engine".into())
            .spawn(move || {
                let coord = match make() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(coord, rx, policy);
            })?;
        ready_rx.recv().map_err(|_| anyhow!("engine thread died"))??;
        Ok(EngineHandle { tx })
    }

    /// Submit a request; returns the receiver of its streamed
    /// [`Frame`]s. Blocks while the engine's admission queue is full
    /// (backpressure). The stream ends with a `Final` frame; a receiver
    /// that disconnects without one means the engine thread died.
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<Frame>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Job::Generate(req, Instant::now(), tx))
            .map_err(|_| anyhow!("engine gone"))?;
        Ok(rx)
    }

    /// Synchronous generate: submit, discard intermediate token frames
    /// and return the final line (used by tests and non-streaming
    /// tools).
    pub fn generate(&self, req: Request) -> Result<String> {
        let rx = self.submit(req)?;
        for frame in rx {
            if let Frame::Final(line) = frame {
                return Ok(line);
            }
        }
        Err(anyhow!("engine thread died mid-request"))
    }

    pub fn stats(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Job::Stats(tx))
            .map_err(|_| anyhow!("engine gone"))?;
        rx.recv().map_err(|_| anyhow!("engine gone"))
    }
}

/// The continuous-batching engine loop. Owns the coordinator for the
/// thread's lifetime: ingest jobs (blocking only when idle), admit at
/// most one prefill per round, then advance every active session one
/// token through a single batched decode dispatch. Exits when every
/// handle is dropped and the remaining work has drained.
fn engine_loop<B: Backend>(
    mut coord: Coordinator<B>,
    rx: mpsc::Receiver<Job>,
    policy: BatchPolicy,
) {
    let tok = ByteTokenizer::new();
    let mut runner: BatchRunner<DecodeState, mpsc::Sender<Frame>> = BatchRunner::new(policy);
    let mut queue: VecDeque<Pending<mpsc::Sender<Frame>>> = VecDeque::new();
    let mut disconnected = false;

    loop {
        // Ingest. Park on the channel only when there is nothing to
        // decode; under load, just drain whatever arrived while the
        // last round ran.
        let mut jobs: Vec<Job> = Vec::new();
        if queue.is_empty() && !runner.has_active() && !disconnected {
            match rx.recv() {
                Ok(j) => jobs.push(j),
                Err(_) => disconnected = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(j) => jobs.push(j),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        for job in jobs {
            match job {
                Job::Generate(req, arrived, out) => {
                    queue.push_back(Pending { req, arrived, tag: out });
                }
                Job::Stats(out) => {
                    let _ = out.send(stats_line(
                        &coord,
                        runner.policy(),
                        runner.active_len(),
                        queue.len(),
                    ));
                }
            }
        }

        // Schedule: one admission, then a decode round for everyone.
        // A dropped client receiver just discards that request's
        // remaining frames; its session still decodes to completion.
        let mut sink = |ev: BatchEvent<mpsc::Sender<Frame>>| match ev {
            BatchEvent::Token { tag, id, token } => {
                let _ = tag.send(Frame::Token(format_token_frame(id, token)));
            }
            BatchEvent::Done { tag, resp } => {
                let _ = tag.send(Frame::Final(format_response(&resp, &tok)));
            }
            BatchEvent::Failed { tag, id, error } => {
                let _ = tag.send(Frame::Final(format_error(id, &error)));
            }
        };
        if queue.front().map(|p| runner.can_admit(&p.req)).unwrap_or(false) {
            let p = queue.pop_front().unwrap();
            runner.admit(&mut coord, p, &mut sink);
        }
        runner.decode_round(&mut coord, &mut sink);

        if disconnected && queue.is_empty() && !runner.has_active() {
            return;
        }
    }
}

/// The one-line JSON `stats` reply: serving metrics, cache state,
/// batching state and kernel-pool counters.
fn stats_line<B: Backend>(
    coord: &Coordinator<B>,
    policy: &BatchPolicy,
    active: usize,
    queued: usize,
) -> String {
    let s = coord.cache_stats();
    let ps = crate::kernels::pool_stats();
    let m = &coord.metrics;
    Json::obj(vec![
        ("metrics", Json::str(m.report())),
        ("block_prefill_p50_ms", Json::num(m.block_prefill_p50_ms())),
        ("cache_entries", Json::num(s.entries as f64)),
        ("cache_bytes", Json::num(s.bytes as f64)),
        ("cache_bytes_saved", Json::num(s.bytes_saved as f64)),
        ("cache_bytes_saved_int8", Json::num(s.bytes_saved_int8 as f64)),
        ("cache_bytes_saved_int4", Json::num(s.bytes_saved_int4 as f64)),
        ("cache_hits", Json::num(s.hits as f64)),
        ("cache_misses", Json::num(s.misses as f64)),
        ("cache_evictions", Json::num(s.evictions as f64)),
        ("cache_hit_rate", Json::num(s.hit_rate())),
        ("cache_quant_rel_err", Json::num(s.quant_rel_err())),
        (
            "kv_store_dir",
            Json::str(
                coord
                    .kv_store_dir()
                    .map(|d| d.display().to_string())
                    .unwrap_or_default(),
            ),
        ),
        ("disk_hits", Json::num(s.disk_hits as f64)),
        ("disk_misses", Json::num(s.disk_misses as f64)),
        ("disk_spills", Json::num(s.disk_spills as f64)),
        ("disk_errors", Json::num(s.disk_errors as f64)),
        ("disk_entries", Json::num(s.disk_entries as f64)),
        ("disk_bytes", Json::num(s.disk_bytes as f64)),
        ("memo_hits", Json::num(s.memo_hits as f64)),
        ("memo_misses", Json::num(s.memo_misses as f64)),
        ("memo_evictions", Json::num(s.memo_evictions as f64)),
        ("memo_entries", Json::num(s.memo_entries as f64)),
        ("memo_bytes", Json::num(s.memo_bytes as f64)),
        ("delta_rotations", Json::num(s.delta_rotations as f64)),
        ("kv_precision", Json::str(coord.kv_precision().as_str())),
        ("reencode_mode", Json::str(coord.reencode_mode().as_str())),
        ("segment_policy", Json::str(coord.segment_policy().as_str())),
        ("blocks_seen", Json::num(m.blocks_seen as f64)),
        ("blocks_cached", Json::num(m.blocks_cached as f64)),
        ("block_hit_rate", Json::num(m.block_hit_rate())),
        ("simd_isa", Json::str(crate::kernels::isa_name())),
        ("threads", Json::num(crate::kernels::num_threads() as f64)),
        ("pool_workers", Json::num(ps.workers as f64)),
        ("pool_jobs_executed", Json::num(ps.jobs_executed as f64)),
        ("pool_jobs_panicked", Json::num(ps.jobs_panicked as f64)),
        ("pool_queue_peak", Json::num(ps.queue_peak as f64)),
        ("batch_max_active", Json::num(policy.max_active as f64)),
        ("batch_max_active_tokens", Json::num(policy.max_active_tokens as f64)),
        ("batch_queue_depth", Json::num(policy.queue_depth as f64)),
        ("active_requests", Json::num(active as f64)),
        ("queued_requests", Json::num(queued as f64)),
        ("decode_rounds", Json::num(m.decode_rounds as f64)),
        ("batch_occupancy", Json::num(m.batch_occupancy())),
    ])
    .to_string()
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7841"), segmenting raw
/// requests under `policy` (the `serve` CLI resolves `--segment` >
/// `$BLOCK_ATTN_SEGMENT` > passages-only).
pub fn serve(
    addr: &str,
    handle: EngineHandle,
    workers: usize,
    policy: SegmentPolicy,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("[server] listening on {addr} (segment policy: {})", policy.as_str());
    let pool = ThreadPool::new(workers);
    for stream in listener.incoming() {
        let stream = stream?;
        let handle = handle.clone();
        pool.spawn(move || {
            if let Err(e) = handle_conn(stream, handle, policy) {
                eprintln!("[server] connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn write_line(w: &mut impl Write, line: &str) -> Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    Ok(())
}

fn handle_conn(stream: TcpStream, handle: EngineHandle, policy: SegmentPolicy) -> Result<()> {
    let tok = ByteTokenizer::new();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "stats" {
            let out = handle
                .stats()
                .unwrap_or_else(|e| format_error(0, &format!("{e:#}")));
            write_line(&mut writer, &out)?;
            continue;
        }
        let req = match parse_request_with_policy(&line, &tok, policy) {
            Ok(req) => req,
            Err(e) => {
                // Echo the client's id when the line is recoverable
                // JSON, so errors can be correlated with requests.
                write_line(
                    &mut writer,
                    &format_error(request_id_hint(&line), &format!("{e:#}")),
                )?;
                continue;
            }
        };
        let id = req.id;
        match handle.submit(req) {
            Err(e) => write_line(&mut writer, &format_error(id, &format!("{e:#}")))?,
            Ok(rx) => {
                // Stream frames until the final line. If the engine
                // thread dies mid-request the frame stream ends without
                // a `Final`; the client still gets a clean JSON error
                // line instead of an aborted socket.
                let mut finished = false;
                for frame in rx {
                    match frame {
                        Frame::Token(l) => write_line(&mut writer, &l)?,
                        Frame::Final(l) => {
                            write_line(&mut writer, &l)?;
                            finished = true;
                            break;
                        }
                    }
                }
                if !finished {
                    write_line(
                        &mut writer,
                        &format_error(id, "engine thread died mid-request"),
                    )?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{KvPrecision, ModelConfig, ParamSpec};
    use crate::runtime::{DecodeOut, NativeBackend, PrefillFinalOut, PrefillFullOut, TrainOut};
    use crate::tensor::{TensorF, TensorI};

    #[test]
    fn parse_request_roundtrip() {
        let tok = ByteTokenizer::new();
        let req = parse_request(
            r#"{"id": 3, "passages": ["doc a"], "query": "q?", "mode": "full", "max_new_tokens": 5}"#,
            &tok,
        )
        .unwrap();
        assert_eq!(req.id, 3);
        assert_eq!(req.blocks.len(), 1);
        assert_eq!(req.mode, AttentionMode::Full);
        assert_eq!(req.max_new_tokens, 5);
        assert_eq!(req.query[0], crate::tokenizer::QRY);
    }

    #[test]
    fn parse_rejects_missing_query() {
        let tok = ByteTokenizer::new();
        assert!(parse_request(r#"{"id": 1}"#, &tok).is_err());
        assert!(parse_request("not json", &tok).is_err());
    }

    #[test]
    fn parse_rejects_non_string_passage_entries() {
        // Pre-fix, `filter_map` silently dropped non-string entries and
        // served the request with part of its context missing.
        let tok = ByteTokenizer::new();
        let err =
            parse_request(r#"{"id": 7, "passages": ["ok", 42], "query": "q"}"#, &tok).unwrap_err();
        assert!(
            format!("{err}").contains("passages[1]"),
            "error must name the offending entry: {err}"
        );
        let err = parse_request(r#"{"id": 7, "passages": "nope", "query": "q"}"#, &tok)
            .unwrap_err();
        assert!(format!("{err}").contains("passages"));
        // Absent passages stay legal (query-only request).
        assert!(parse_request(r#"{"id": 7, "query": "q"}"#, &tok).is_ok());
    }

    /// Raw-field parsing under each policy: the segmented request must
    /// be token-for-token identical to its hand-pre-segmented twin
    /// (the bitwise-equivalence contract starts here), and the loud
    /// failure modes must name what went wrong.
    #[test]
    fn parse_raw_fields_under_policies() {
        let tok = ByteTokenizer::new();
        let raw = parse_request_with_policy(
            r#"{"id": 1, "prompt": "part a---part b---tail", "query": "q?"}"#,
            &tok,
            SegmentPolicy::Text,
        )
        .unwrap();
        let pre = parse_request(
            r#"{"id": 1, "passages": ["part a---", "part b---", "tail"], "query": "q?"}"#,
            &tok,
        )
        .unwrap();
        assert_eq!(raw.blocks, pre.blocks, "text segmentation diverged from passages");
        assert_eq!(raw.query, pre.query);

        // `auto` dispatches on the field the request carries.
        let icl = parse_request_with_policy(
            r#"{"demos": ["in a out b", "in c out d"], "query": "in e out"}"#,
            &tok,
            SegmentPolicy::Auto,
        )
        .unwrap();
        assert_eq!(icl.blocks.len(), 2);
        let chat = parse_request_with_policy(
            r#"{"system": "be brief", "turns": ["t1", "t2"], "query": "next"}"#,
            &tok,
            SegmentPolicy::Auto,
        )
        .unwrap();
        assert_eq!(chat.blocks.len(), 3);
        let game = parse_request_with_policy(
            r#"{"state": {"pot": 10, "round": 2}, "query": "act"}"#,
            &tok,
            SegmentPolicy::Auto,
        )
        .unwrap();
        assert_eq!(game.blocks.len(), 2);

        // The default passages policy rejects raw fields loudly…
        let err = parse_request(r#"{"prompt": "x", "query": "q"}"#, &tok).unwrap_err();
        assert!(format!("{err}").contains("passages"), "unhelpful: {err}");
        // …field types are validated with the entry named…
        let err = parse_request_with_policy(
            r#"{"demos": ["ok", 3], "query": "q"}"#,
            &tok,
            SegmentPolicy::Icl,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("demos[1]"), "unhelpful: {err}");
        // …and mixing raw fields with pre-cut passages is rejected.
        let err = parse_request_with_policy(
            r#"{"prompt": "x", "passages": ["y"], "query": "q"}"#,
            &tok,
            SegmentPolicy::Text,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("not both"), "unhelpful: {err}");
        // Pre-segmented requests are served under *every* policy.
        assert!(parse_request_with_policy(
            r#"{"passages": ["doc"], "query": "q"}"#,
            &tok,
            SegmentPolicy::Gamecore
        )
        .is_ok());
    }

    #[test]
    fn error_lines_can_echo_the_request_id() {
        // Valid JSON failing request validation: the id is recoverable.
        assert_eq!(request_id_hint(r#"{"id": 7, "passages": [1], "query": "q"}"#), 7);
        // Unparsable input: fall back to 0.
        assert_eq!(request_id_hint("not json"), 0);
        assert_eq!(request_id_hint(r#"{"passages": [], "query": "q"}"#), 0);
    }

    #[test]
    fn response_is_valid_json() {
        let tok = ByteTokenizer::new();
        let resp = Response {
            id: 9,
            tokens: vec![b'h' as i32, b'i' as i32, crate::tokenizer::EOS],
            ttft: 0.0123,
            block_prefill_s: 0.0042,
            flops_tft: 1e9,
            cached_blocks: 2,
            total_blocks: 3,
            prompt_tokens: 100,
        };
        let line = format_response(&resp, &tok);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("text").as_str(), Some("hi"));
        assert_eq!(j.get("cached_blocks").as_i64(), Some(2));
        assert!((j.get("ttft_ms").as_f64().unwrap() - 12.3).abs() < 0.01);
        assert!((j.get("block_prefill_ms").as_f64().unwrap() - 4.2).abs() < 0.01);
    }

    #[test]
    fn token_frame_is_valid_json() {
        let j = Json::parse(&format_token_frame(5, 104)).unwrap();
        assert_eq!(j.get("id").as_i64(), Some(5));
        assert_eq!(j.get("token").as_i64(), Some(104));
    }

    fn tiny_coordinator() -> Result<Coordinator<NativeBackend>> {
        let cfg = ModelConfig::builtin("tiny").unwrap();
        Ok(Coordinator::with_kv_precision(
            NativeBackend::new(cfg, 0xB10C),
            32 << 20,
            KvPrecision::F32,
        ))
    }

    /// The live engine loop (admission queue + batched decode rounds)
    /// must produce exactly the text the serial `Coordinator::process`
    /// path produces — continuous batching is a scheduling decision,
    /// never an output one.
    #[test]
    fn engine_loop_matches_serial_processing() {
        let lines = [
            r#"{"id": 1, "passages": ["alpha doc", "beta doc"], "query": "one?", "max_new_tokens": 6}"#,
            r#"{"id": 2, "passages": ["beta doc", "gamma doc"], "query": "two?", "max_new_tokens": 6}"#,
            r#"{"id": 3, "passages": ["alpha doc"], "query": "three?", "max_new_tokens": 6}"#,
        ];
        let tok = ByteTokenizer::new();

        let mut serial = tiny_coordinator().unwrap();
        let expect: Vec<String> = lines
            .iter()
            .map(|l| {
                let req = parse_request(l, &tok).unwrap();
                let resp = serial.process(&req).unwrap();
                tok.decode_until_eos(&resp.tokens)
            })
            .collect();

        let policy =
            BatchPolicy { max_active: 4, max_active_tokens: 4096, ..BatchPolicy::default() };
        let handle = EngineHandle::spawn_with_policy(tiny_coordinator, policy).unwrap();
        // Submit everything before draining so the sessions really
        // overlap inside the engine loop.
        let rxs: Vec<_> = lines
            .iter()
            .map(|l| handle.submit(parse_request(l, &tok).unwrap()).unwrap())
            .collect();
        for (rx, want) in rxs.into_iter().zip(&expect) {
            let mut text = None;
            let mut streamed = 0usize;
            for frame in rx {
                match frame {
                    Frame::Token(line) => {
                        assert!(
                            Json::parse(&line).unwrap().get("token").as_i64().is_some(),
                            "bad token frame: {line}"
                        );
                        streamed += 1;
                    }
                    Frame::Final(line) => {
                        let j = Json::parse(&line).unwrap();
                        text = Some(j.get("text").as_str().unwrap().to_string());
                        break;
                    }
                }
            }
            assert!(streamed >= 1, "no token frames streamed");
            assert_eq!(text.as_deref(), Some(want.as_str()), "batched decode diverged");
        }
    }

    /// A backend that panics mid-prefill when it sees the byte sequence
    /// "BOOM" — simulates an engine-thread death under a live request.
    struct PanickyBackend(NativeBackend);

    const BOOM: [i32; 4] = [66, 79, 79, 77];

    impl Backend for PanickyBackend {
        fn config(&self) -> &ModelConfig {
            self.0.config()
        }
        fn param_specs(&self) -> &[ParamSpec] {
            self.0.param_specs()
        }
        fn set_params(&self, tensors: Vec<TensorF>) -> Result<()> {
            self.0.set_params(tensors)
        }
        fn params_host(&self) -> Result<Vec<TensorF>> {
            self.0.params_host()
        }
        fn reset_opt_state(&self) {
            self.0.reset_opt_state()
        }
        fn prefill_full(&self, tokens: &[i32]) -> Result<PrefillFullOut> {
            assert!(
                !tokens.windows(4).any(|w| *w == BOOM),
                "poison prompt hit the engine"
            );
            self.0.prefill_full(tokens)
        }
        fn prefill_block(&self, tokens: &[i32]) -> Result<(TensorF, TensorF)> {
            self.0.prefill_block(tokens)
        }
        fn prefill_final_at(
            &self,
            tokens: &[i32],
            past_k: &TensorF,
            past_v: &TensorF,
            past_len: usize,
            q_pos0: usize,
        ) -> Result<PrefillFinalOut> {
            self.0.prefill_final_at(tokens, past_k, past_v, past_len, q_pos0)
        }
        fn decode(
            &self,
            token: i32,
            k_cache: &TensorF,
            v_cache: &TensorF,
            cache_len: usize,
        ) -> Result<DecodeOut> {
            self.0.decode(token, k_cache, v_cache, cache_len)
        }
        fn train_step(
            &self,
            step: usize,
            lr: f32,
            tokens: &TensorI,
            seg: &TensorI,
            loss_mask: &TensorF,
        ) -> Result<TrainOut> {
            self.0.train_step(step, lr, tokens, seg, loss_mask)
        }
        fn final_ctx_capacity(&self, ctx_len: usize) -> Result<usize> {
            self.0.final_ctx_capacity(ctx_len)
        }
        fn final_q_capacity(&self) -> Result<usize> {
            self.0.final_q_capacity()
        }
        fn decode_ctx_capacity(&self) -> Result<usize> {
            self.0.decode_ctx_capacity()
        }
        fn max_block_tokens(&self) -> Result<usize> {
            self.0.max_block_tokens()
        }
        fn train_shape(&self) -> Result<(usize, usize)> {
            self.0.train_shape()
        }
    }

    /// A request in flight when the engine thread dies must still yield
    /// a clean JSON error line over the socket (pre-fix, `handle_conn`
    /// aborted the connection via `?`). Also pins error-line id echoing
    /// end to end.
    #[test]
    fn conn_gets_clean_error_line_when_engine_dies() {
        let handle = EngineHandle::spawn(|| {
            let cfg = ModelConfig::builtin("tiny").unwrap();
            Ok(Coordinator::with_kv_precision(
                PanickyBackend(NativeBackend::new(cfg, 0xB10C)),
                16 << 20,
                KvPrecision::F32,
            ))
        })
        .unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = handle_conn(stream, handle, SegmentPolicy::Passages);
        });

        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();

        // 1. Malformed request (non-string passage): the error line
        //    echoes the client's id instead of 0.
        writeln!(writer, r#"{{"id": 7, "passages": [1], "query": "q"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("id").as_i64(), Some(7), "line: {line}");
        assert!(j.get("error").as_str().unwrap().contains("passages[0]"), "line: {line}");

        // 2. A healthy request streams frames and a final line.
        line.clear();
        writeln!(
            writer,
            r#"{{"id": 8, "passages": [], "query": "hi", "mode": "full", "max_new_tokens": 2}}"#
        )
        .unwrap();
        let mut saw_final = false;
        while reader.read_line(&mut line).unwrap() > 0 {
            let j = Json::parse(line.trim()).unwrap();
            if j.get("text").as_str().is_some() {
                assert_eq!(j.get("id").as_i64(), Some(8));
                saw_final = true;
                break;
            }
            assert!(j.get("token").as_i64().is_some(), "unexpected frame: {line}");
            line.clear();
        }
        assert!(saw_final, "healthy request never finished");

        // 3. Poison request: the engine thread panics mid-prefill. The
        //    client must get a clean JSON error line, not a dead socket.
        line.clear();
        writeln!(
            writer,
            r#"{{"id": 9, "passages": [], "query": "BOOM", "mode": "full", "max_new_tokens": 2}}"#
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("id").as_i64(), Some(9), "line: {line}");
        assert!(j.get("error").as_str().is_some(), "line: {line}");

        // 4. The engine is gone; later requests error cleanly too.
        line.clear();
        writeln!(
            writer,
            r#"{{"id": 10, "passages": [], "query": "hi", "mode": "full", "max_new_tokens": 2}}"#
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("id").as_i64(), Some(10), "line: {line}");
        assert!(j.get("error").as_str().is_some(), "line: {line}");

        drop(writer);
        drop(reader);
        server.join().unwrap();
    }
}
