//! Byte-pair-encoding tokenizer substrate (trained from a corpus).
//!
//! Used by the `bench`-config workload generator so synthetic passages
//! get realistic token counts for a 32000-entry vocabulary. The
//! implementation is the classic BPE loop: start from bytes, repeatedly
//! merge the most frequent adjacent pair, record merge rules; encoding
//! replays the rules greedily (lowest-rank merge first).

use std::collections::HashMap;

/// A trained BPE tokenizer: 256 byte tokens + one token per merge.
#[derive(Debug, Clone)]
pub struct BpeTokenizer {
    /// merge rules: (left, right) -> merged id, in training order.
    merges: Vec<(u32, u32)>,
    ranks: HashMap<(u32, u32), u32>,
}

impl BpeTokenizer {
    /// Train merge rules from a corpus until `vocab` tokens exist (or no
    /// pair repeats). `vocab` must be > 256.
    pub fn train(corpus: &str, vocab: usize) -> BpeTokenizer {
        assert!(vocab > 256);
        let mut words: Vec<Vec<u32>> = corpus
            .split_whitespace()
            .map(|w| w.bytes().map(|b| b as u32).collect())
            .collect();
        let mut merges = Vec::new();
        let mut next_id = 256u32;
        while (next_id as usize) < vocab {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in &words {
                for pair in w.windows(2) {
                    *counts.entry((pair[0], pair[1])).or_default() += 1;
                }
            }
            // Deterministic tie-break: highest count, then smallest pair.
            let best = counts
                .into_iter()
                .filter(|&(_, c)| c >= 2)
                .max_by_key(|&((a, b), c)| (c, std::cmp::Reverse((a, b))));
            let Some((pair, _)) = best else { break };
            merges.push(pair);
            for w in &mut words {
                merge_in_place(w, pair, next_id);
            }
            next_id += 1;
        }
        let ranks = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();
        BpeTokenizer { merges, ranks }
    }

    pub fn vocab(&self) -> usize {
        256 + self.merges.len()
    }

    /// Encode one whitespace-split word (no space handling).
    fn encode_word(&self, word: &str) -> Vec<u32> {
        let mut toks: Vec<u32> = word.bytes().map(|b| b as u32).collect();
        loop {
            // Find the lowest-rank applicable merge.
            let mut best: Option<(u32, usize)> = None;
            for (i, pair) in toks.windows(2).enumerate() {
                if let Some(&r) = self.ranks.get(&(pair[0], pair[1])) {
                    if best.map(|(br, _)| r < br).unwrap_or(true) {
                        best = Some((r, i));
                    }
                }
            }
            match best {
                Some((r, i)) => {
                    let id = 256 + r;
                    toks[i] = id;
                    toks.remove(i + 1);
                }
                None => return toks,
            }
        }
    }

    /// Encode text; words are separated implicitly (the id stream does
    /// not retain whitespace — fine for workload length modelling).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for w in text.split_whitespace() {
            out.extend(self.encode_word(w));
        }
        out
    }
}

fn merge_in_place(w: &mut Vec<u32>, pair: (u32, u32), id: u32) {
    let mut i = 0;
    while i + 1 < w.len() {
        if w[i] == pair.0 && w[i + 1] == pair.1 {
            w[i] = id;
            w.remove(i + 1);
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_frequent_pairs() {
        let bpe = BpeTokenizer::train("aaab aaab aaab xyz", 300);
        assert!(bpe.vocab() > 256);
        // "aaab" should compress well below its byte length.
        let enc = bpe.encode("aaab");
        assert!(enc.len() < 4, "{enc:?}");
    }

    #[test]
    fn encoding_is_deterministic() {
        let bpe = BpeTokenizer::train("the quick brown fox the quick fox", 280);
        assert_eq!(bpe.encode("the quick fox"), bpe.encode("the quick fox"));
    }

    #[test]
    fn unseen_bytes_fall_back() {
        let bpe = BpeTokenizer::train("hello hello", 270);
        let enc = bpe.encode("Zq");
        assert_eq!(enc, vec![b'Z' as u32, b'q' as u32]);
    }

    #[test]
    fn compression_improves_with_vocab() {
        let corpus = "block attention makes prefilling efficient ".repeat(20);
        let small = BpeTokenizer::train(&corpus, 260);
        let large = BpeTokenizer::train(&corpus, 400);
        let text = "block attention makes prefilling efficient";
        assert!(large.encode(text).len() <= small.encode(text).len());
    }
}
