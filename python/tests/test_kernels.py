"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles,
hypothesis-swept over shapes, lengths, GQA ratios and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_attention as ba
from compile.kernels import ref
from compile.kernels import rope as rope_kernel


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# --------------------------------------------------------------------------
# flash_block_attention
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    hq=st.sampled_from([1, 2, 4]),
    ratio=st.sampled_from([1, 2]),
    n_tiles=st.integers(1, 4),
    tile=st.sampled_from([8, 16]),
    d=st.sampled_from([8, 32]),
    frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**16),
)
def test_block_attention_matches_ref(hq, ratio, n_tiles, tile, d, frac, seed):
    if hq % ratio:
        ratio = 1
    hkv = hq // ratio
    L = n_tiles * tile
    length = max(1, int(frac * L))
    q = rand(seed, (hq, L, d), jnp.float32)
    k = rand(seed + 1, (hkv, L, d), jnp.float32)
    v = rand(seed + 2, (hkv, L, d), jnp.float32)
    n = jnp.array([length], jnp.int32)
    out = ba.flash_block_attention(q, k, v, n, tile_q=tile, tile_k=tile)
    expect = ref.block_attention(q, k, v, length, kv_repeat=ratio)
    np.testing.assert_allclose(
        np.asarray(out)[:, :length], np.asarray(expect)[:, :length], atol=2e-4
    )


def test_block_attention_is_causal():
    # Changing a future token must not change earlier outputs.
    q = rand(0, (2, 64, 16), jnp.float32)
    k = rand(1, (2, 64, 16), jnp.float32)
    v = rand(2, (2, 64, 16), jnp.float32)
    n = jnp.array([64], jnp.int32)
    out1 = ba.flash_block_attention(q, k, v, n)
    k2 = k.at[:, 50:].set(99.0)
    v2 = v.at[:, 50:].set(-99.0)
    out2 = ba.flash_block_attention(q, k2, v2, n)
    np.testing.assert_allclose(np.asarray(out1[:, :50]), np.asarray(out2[:, :50]), atol=1e-6)
    assert not np.allclose(np.asarray(out1[:, 51:]), np.asarray(out2[:, 51:]))


def test_block_attention_length_mask():
    # Tokens past `length` must not influence valid positions.
    q = rand(3, (1, 32, 8), jnp.float32)
    k = rand(4, (1, 32, 8), jnp.float32)
    v = rand(5, (1, 32, 8), jnp.float32)
    out1 = ba.flash_block_attention(q, k, v, jnp.array([20], jnp.int32), tile_q=8, tile_k=8)
    k2 = k.at[:, 20:].set(7.0)
    out2 = ba.flash_block_attention(q, k2, v, jnp.array([20], jnp.int32), tile_q=8, tile_k=8)
    np.testing.assert_allclose(np.asarray(out1[:, :20]), np.asarray(out2[:, :20]), atol=1e-6)


def test_block_attention_bf16():
    q = rand(6, (2, 64, 32), jnp.bfloat16)
    k = rand(7, (1, 64, 32), jnp.bfloat16)
    v = rand(8, (1, 64, 32), jnp.bfloat16)
    n = jnp.array([64], jnp.int32)
    out = ba.flash_block_attention(q, k, v, n)
    expect = ref.block_attention(q, k, v, 64, kv_repeat=2)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=3e-2
    )


# --------------------------------------------------------------------------
# flash_context_attention
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    hq=st.sampled_from([1, 2, 4]),
    ratio=st.sampled_from([1, 2]),
    ctx_tiles=st.integers(1, 4),
    lq=st.sampled_from([8, 16]),
    d=st.sampled_from([8, 16]),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_context_attention_matches_ref(hq, ratio, ctx_tiles, lq, d, frac, seed):
    if hq % ratio:
        ratio = 1
    hkv = hq // ratio
    tile = lq  # keep C+Lq divisible by tile
    C = ctx_tiles * tile
    ctx_len = int(frac * C)
    q = rand(seed, (hq, lq, d), jnp.float32)
    kv_k = rand(seed + 1, (hkv, C + lq, d), jnp.float32)
    kv_v = rand(seed + 2, (hkv, C + lq, d), jnp.float32)
    n = jnp.array([ctx_len], jnp.int32)
    out = ba.flash_context_attention(q, kv_k, kv_v, n, ctx_capacity=C, tile_k=tile)
    expect = ref.context_attention(q, kv_k, kv_v, C, ctx_len, kv_repeat=ratio)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4)


def test_context_attention_ignores_ctx_padding():
    # Garbage in the padded context region (>= ctx_len) must not matter.
    q = rand(9, (2, 16, 8), jnp.float32)
    kv_k = rand(10, (2, 48, 8), jnp.float32)
    kv_v = rand(11, (2, 48, 8), jnp.float32)
    n = jnp.array([12], jnp.int32)
    out1 = ba.flash_context_attention(q, kv_k, kv_v, n, ctx_capacity=32, tile_k=16)
    kv_k2 = kv_k.at[:, 12:32].set(55.0)
    kv_v2 = kv_v.at[:, 12:32].set(-55.0)
    out2 = ba.flash_context_attention(q, kv_k2, kv_v2, n, ctx_capacity=32, tile_k=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_context_attention_zero_ctx_equals_causal():
    # With ctx_len = 0 the kernel degenerates to causal self-attention.
    q = rand(12, (2, 16, 8), jnp.float32)
    self_k = rand(13, (2, 16, 8), jnp.float32)
    self_v = rand(14, (2, 16, 8), jnp.float32)
    pad = jnp.zeros((2, 16, 8), jnp.float32)
    kv_k = jnp.concatenate([pad, self_k], axis=1)
    kv_v = jnp.concatenate([pad, self_v], axis=1)
    out = ba.flash_context_attention(
        q, kv_k, kv_v, jnp.array([0], jnp.int32), ctx_capacity=16, tile_k=16
    )
    expect = ref.block_attention(q, self_k, self_v, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4)


# --------------------------------------------------------------------------
# RoPE re-encode kernel
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    layers=st.integers(1, 3),
    L=st.sampled_from([4, 16]),
    heads=st.integers(1, 3),
    d=st.sampled_from([8, 32]),
    delta=st.integers(0, 5000),
    seed=st.integers(0, 2**16),
)
def test_reencode_matches_ref(layers, L, heads, d, delta, seed):
    k = rand(seed, (layers, L, heads, d), jnp.float32)
    out = rope_kernel.reencode_k(k, jnp.array([delta], jnp.int32), theta=10000.0)
    expect = ref.reencode_k(k, delta, 10000.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)


def test_reencode_equals_recompute():
    """Paper Eq. 3: encode at local positions then rotate by delta ==
    encode at absolute positions delta.. directly."""
    d, L, H = 16, 8, 2
    raw = rand(20, (1, L, H, d), jnp.float32)
    delta = 37
    pos_local = jnp.arange(L, dtype=jnp.int32)
    cos_l, sin_l = ref.rope_cos_sin(pos_local, d, 10000.0)
    local = ref.apply_rope(raw[0], cos_l, sin_l)[None]
    re = rope_kernel.reencode_k(local, jnp.array([delta], jnp.int32), theta=10000.0)
    cos_a, sin_a = ref.rope_cos_sin(pos_local + delta, d, 10000.0)
    absolute = ref.apply_rope(raw[0], cos_a, sin_a)[None]
    np.testing.assert_allclose(np.asarray(re), np.asarray(absolute), atol=1e-4)


def test_reencode_zero_delta_identity():
    k = rand(21, (2, 4, 2, 8), jnp.float32)
    out = rope_kernel.reencode_k(k, jnp.array([0], jnp.int32), theta=10000.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(k), atol=1e-6)


# --------------------------------------------------------------------------
# VMEM / MXU estimators (perf-pass bookkeeping)
# --------------------------------------------------------------------------

def test_vmem_estimate_monotone():
    a = ba.vmem_bytes(64, 64, 32, 512)
    b = ba.vmem_bytes(128, 64, 32, 512)
    c = ba.vmem_bytes(64, 64, 32, 2048)
    assert b > a and c > a


def test_mxu_utilization_bounds():
    u = ba.mxu_utilization(128, 128, 128)
    assert abs(u - 1.0) < 1e-9
    assert 0 < ba.mxu_utilization(64, 64, 32) < 1.0
