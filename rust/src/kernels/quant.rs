//! Symmetric low-bit quantization of block KV states (int8 and int4).
//!
//! The cache's **int8** storage tier (see [`crate::kvcache`]) stores
//! each block's K and V tensors as int8 codes plus f32 scales, one
//! scale per **(layer, kv_head, channel)** — the reduction runs over
//! the token axis, so a block of any length carries a fixed
//! `layers·kv_heads·head_dim` scale table and the payload shrinks to
//! ~¼ of f32.
//!
//! The **int4** tier ([`QuantizedKv4`]) packs two 4-bit codes per byte
//! along the channel axis (head rows have even length, so pairs never
//! straddle a head) and refines the scale granularity to **per
//! (layer, kv_head, channel, token-group)** with groups of
//! [`I4_GROUP`] = 32 tokens — the coarser 15-level code range needs the
//! finer amax. Payload: ½ byte per element plus a scale table of
//! `groups·layers·kv_heads·head_dim` f32 — ~⅛ of f32 for block-sized
//! inputs (≤ 16% including scales once groups are mostly full).
//!
//! Determinism contract: quantization and dequantization are
//! **per-element and order-free** — `q = round(x/s)` and `x̂ = q·s`
//! touch one element at a time with no cross-element reduction — so
//! both tiers inherit the kernels layer's bitwise-identical-at-every-
//! thread-count guarantee unchanged. The fused dequantizing re-encodes
//! live in [`crate::rope::RopeTable::reencode_block_dequant`] /
//! [`crate::rope::RopeTable::reencode_block_dequant_i4`]; the mixed
//! low-bit×f32 GEMM micro-kernels live in [`super::gemm`].

use crate::tensor::{Tensor, TensorF};
use anyhow::{ensure, Result};

/// Tokens per int4 scale group (the "group-wise" in group-wise scales).
pub const I4_GROUP: usize = 32;

/// Quantize one value against its channel scale (round half away from
/// zero, saturating at ±127 so the code range is symmetric).
#[inline]
pub fn quantize_one(x: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        0
    } else {
        (x / scale).round().clamp(-127.0, 127.0) as i8
    }
}

/// Quantize one value to a 4-bit code in `[-7, 7]` (symmetric,
/// zero-point-free — the −8 code is unused so the range mirrors).
#[inline]
pub fn quantize_one_i4(x: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        0
    } else {
        (x / scale).round().clamp(-7.0, 7.0) as i8
    }
}

/// Dequantize one code.
#[inline]
pub fn dequant_one(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Pack two 4-bit codes into one byte: `lo` in the low nibble (even
/// channel), `hi` in the high nibble (odd channel).
#[inline]
pub fn pack_nibbles(lo: i8, hi: i8) -> u8 {
    ((lo as u8) & 0x0F) | (((hi as u8) & 0x0F) << 4)
}

/// Sign-extended low nibble of a packed byte (the even channel).
#[inline]
pub fn nibble_lo(b: u8) -> i8 {
    ((b as i8) << 4) >> 4
}

/// Sign-extended high nibble of a packed byte (the odd channel).
#[inline]
pub fn nibble_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// Dequantize one int8 row against a per-channel scale row:
/// `out[c] = q[c]·scale[c]`. Elementwise and order-free, dispatched on
/// [`super::simd::active_isa`] — the row primitive behind
/// [`QuantizedKv::dequantize`] and the fused Eq.-3 re-encode's unpack
/// step.
#[inline]
pub fn dequant_i8_row(q: &[i8], scale: &[f32], out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    debug_assert_eq!(q.len(), scale.len());
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_isa() == super::simd::Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { super::simd::x86::dequant_i8_row_avx2(q, scale, out) };
    }
    for ((o, &qv), &sv) in out.iter_mut().zip(q).zip(scale) {
        *o = dequant_one(qv, sv);
    }
}

/// Dequantize one packed-int4 row against a per-channel scale row:
/// byte `i` yields channels `2i` (low nibble) and `2i+1` (high nibble).
/// Elementwise and order-free, dispatched on
/// [`super::simd::active_isa`].
#[inline]
pub fn dequant_i4_row(packed: &[u8], scale: &[f32], out: &mut [f32]) {
    debug_assert_eq!(packed.len() * 2, out.len());
    debug_assert_eq!(out.len(), scale.len());
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_isa() == super::simd::Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { super::simd::x86::dequant_i4_row_avx2(packed, scale, out) };
    }
    for (cp, &b) in packed.iter().enumerate() {
        out[2 * cp] = dequant_one(nibble_lo(b), scale[2 * cp]);
        out[2 * cp + 1] = dequant_one(nibble_hi(b), scale[2 * cp + 1]);
    }
}

/// Per-channel symmetric scales for a row-major `rows × n` operand with
/// an arbitrary code range: `scales[c] = amax over rows of |b[r][c]| /
/// qmax`. The single owner of the scale formula for both tiers
/// (`qmax = 127` for int8, `7` for int4).
pub fn channel_scales_for(b: &[f32], rows: usize, n: usize, qmax: f32) -> Vec<f32> {
    debug_assert_eq!(b.len(), rows * n);
    let mut scales = vec![0.0f32; n];
    for row in b.chunks(n) {
        for (s, &v) in scales.iter_mut().zip(row) {
            *s = s.max(v.abs());
        }
    }
    for s in scales.iter_mut() {
        *s /= qmax;
    }
    scales
}

/// Per-channel int8 scales (`amax / 127`): [`QuantizedKv::quantize`]
/// applies this per layer over the token axis, and the mixed int8×f32
/// GEMMs ([`super::gemm::gemm_nt_i8_acc`] /
/// [`super::gemm::gemm_nn_i8_acc`]) take their `b_scale` in exactly
/// this layout.
pub fn channel_scales(b: &[f32], rows: usize, n: usize) -> Vec<f32> {
    channel_scales_for(b, rows, n, 127.0)
}

/// Quantize a row-major `rows × n` operand to packed int4 with one
/// `amax / 7` scale per column (`n` must be even): exactly the
/// `(b_q4, b_scale)` operand pair the mixed int4 GEMMs
/// ([`super::gemm::gemm_nt_i4_acc`] / [`super::gemm::gemm_nn_i4_acc`])
/// take — the single owner of the 2-D int4 recipe, so benches and
/// parity tests exercise the shipped formula.
pub fn quantize_cols_i4(b: &[f32], rows: usize, n: usize) -> (Vec<u8>, Vec<f32>) {
    assert!(n % 2 == 0, "int4 packing needs an even column count, got {n}");
    debug_assert_eq!(b.len(), rows * n);
    let scales = channel_scales_for(b, rows, n, 7.0);
    let mut packed = Vec::with_capacity(rows * n / 2);
    for row in b.chunks(n) {
        for cp in 0..n / 2 {
            packed.push(pack_nibbles(
                quantize_one_i4(row[2 * cp], scales[2 * cp]),
                quantize_one_i4(row[2 * cp + 1], scales[2 * cp + 1]),
            ));
        }
    }
    (packed, scales)
}

/// Unpack + dequantize a [`quantize_cols_i4`] operand back to row-major
/// f32 (byte `i` holds channels `2i` and `2i+1`; scale per column) —
/// the reconstruction rule's single owner, used as the oracle by the
/// GEMM parity tests and benches.
pub fn dequantize_cols_i4(packed: &[u8], scales: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(n % 2, 0);
    let mut out = Vec::with_capacity(packed.len() * 2);
    for (i, &b) in packed.iter().enumerate() {
        let c = (2 * i) % n;
        out.push(dequant_one(nibble_lo(b), scales[c]));
        out.push(dequant_one(nibble_hi(b), scales[c + 1]));
    }
    out
}

/// A `(layers, len, kv_heads, head_dim)` KV tensor stored as int8 codes
/// with per-(layer, head, channel) f32 scales.
#[derive(Debug, Clone)]
pub struct QuantizedKv {
    /// Row-major codes, same element order as the source tensor.
    pub q: Vec<i8>,
    /// `scales[(l·kv_heads + h)·head_dim + c] = amax over tokens / 127`.
    pub scales: Vec<f32>,
    /// `[layers, len, kv_heads, head_dim]` of the source tensor.
    pub dims: [usize; 4],
    /// `Σ(x − x̂)²` accumulated while quantizing (ascending element
    /// order) — the reconstruction-error stat comes for free, with no
    /// extra dequant pass on the cache-insert path.
    pub sq_err: f64,
    /// `Σx²` of the source, same accumulation.
    pub sq_ref: f64,
}

impl QuantizedKv {
    /// Quantize a `(layers, len, kv_heads, head_dim)` tensor. The scale
    /// of each (layer, head, channel) is the absolute max over the token
    /// axis divided by 127 (symmetric, zero-point-free): per layer, the
    /// `(len, kv_heads·head_dim)` slice is exactly the row-major layout
    /// [`channel_scales`] reduces over.
    pub fn quantize(x: &TensorF) -> QuantizedKv {
        let d = x.dims();
        assert_eq!(d.len(), 4, "expected (layers, len, kv_heads, head_dim), got {d:?}");
        let (layers, len, heads, hd) = (d[0], d[1], d[2], d[3]);
        let row = heads * hd;
        let mut scales = Vec::with_capacity(layers * row);
        for l in 0..layers {
            scales.extend(channel_scales(x.axis0(l), len, row));
        }
        let mut q = vec![0i8; x.len()];
        let (mut sq_err, mut sq_ref) = (0.0f64, 0.0f64);
        for (l, layer) in x.data().chunks(len * row).enumerate() {
            let srow = &scales[l * row..(l + 1) * row];
            let qlayer = &mut q[l * len * row..(l + 1) * len * row];
            for (i, (&v, code)) in layer.iter().zip(qlayer.iter_mut()).enumerate() {
                let s = srow[i % row];
                *code = quantize_one(v, s);
                let e = (v - dequant_one(*code, s)) as f64;
                sq_err += e * e;
                sq_ref += (v as f64) * (v as f64);
            }
        }
        QuantizedKv { q, scales, dims: [layers, len, heads, hd], sq_err, sq_ref }
    }

    /// Reconstruct the f32 tensor (`q·s` per element).
    pub fn dequantize(&self) -> TensorF {
        let [layers, len, heads, hd] = self.dims;
        let mut out = Tensor::zeros(&self.dims);
        let od = out.data_mut();
        for l in 0..layers {
            for t in 0..len {
                for h in 0..heads {
                    let off = ((l * len + t) * heads + h) * hd;
                    let s0 = (l * heads + h) * hd;
                    dequant_i8_row(
                        &self.q[off..off + hd],
                        &self.scales[s0..s0 + hd],
                        &mut od[off..off + hd],
                    );
                }
            }
        }
        out
    }

    /// Stored bytes: one byte per code plus four per scale.
    pub fn size_bytes(&self) -> usize {
        self.q.len() + self.scales.len() * 4
    }

    /// `(sum of squared reconstruction error, sum of squared reference)`
    /// recomputed against the f32 source — a test-side cross-check of
    /// the [`Self::sq_err`]/[`Self::sq_ref`] sums `quantize` accumulates
    /// inline (the cache reads the fields, not this).
    pub fn sq_err_vs(&self, x: &TensorF) -> (f64, f64) {
        assert_eq!(x.dims(), &self.dims[..], "error reference shape mismatch");
        sq_err_between(x, &self.dequantize())
    }

    /// Reassemble a tensor from stored codes + scales — the
    /// deserialization half of the persistent KV store's stable layout
    /// (`docs/kvstore-format.md`). The codes are taken **verbatim**:
    /// restoring is not a quantization event, so a disk round-trip is
    /// bitwise invisible to every later dequantizing fetch. The error
    /// sums are zeroed (they were accounted once, at the original
    /// [`Self::quantize`]). Fails when the section lengths do not match
    /// the dims.
    pub fn from_parts(q: Vec<i8>, scales: Vec<f32>, dims: [usize; 4]) -> Result<QuantizedKv> {
        let [layers, _, heads, hd] = dims;
        let n: usize = dims.iter().product();
        ensure!(q.len() == n, "int8 code section: {} codes for dims {dims:?}", q.len());
        ensure!(
            scales.len() == layers * heads * hd,
            "int8 scale section: {} scales for dims {dims:?}",
            scales.len()
        );
        Ok(QuantizedKv { q, scales, dims, sq_err: 0.0, sq_ref: 0.0 })
    }
}

/// `(Σ(x − x̂)², Σx²)` between a source tensor and its reconstruction
/// (ascending element order — shared by both tiers' test cross-checks).
fn sq_err_between(x: &TensorF, deq: &TensorF) -> (f64, f64) {
    let mut err = 0.0f64;
    let mut refsq = 0.0f64;
    for (&a, &b) in x.data().iter().zip(deq.data()) {
        let e = (a - b) as f64;
        err += e * e;
        refsq += (a as f64) * (a as f64);
    }
    (err, refsq)
}

/// A `(layers, len, kv_heads, head_dim)` KV tensor stored as packed
/// int4 codes (two per byte along the channel axis) with f32 scales per
/// **(layer, token-group, kv_head, channel)**, groups of [`I4_GROUP`]
/// tokens.
#[derive(Debug, Clone)]
pub struct QuantizedKv4 {
    /// Packed codes, same element order as the source tensor: byte `i`
    /// holds channels `2i` (low nibble) and `2i+1` (high nibble) of the
    /// row-major element stream. Head rows have even length
    /// (`head_dim` is even), so a byte never straddles a head.
    pub packed: Vec<u8>,
    /// `scales[((l·groups + g)·kv_heads + h)·head_dim + c]` =
    /// amax over the tokens of group `g` / 7. The per-token scale row
    /// of a (layer, token, head) is the contiguous `head_dim` slice at
    /// `g = token / I4_GROUP`.
    pub scales: Vec<f32>,
    /// `[layers, len, kv_heads, head_dim]` of the source tensor.
    pub dims: [usize; 4],
    /// `Σ(x − x̂)²` accumulated while quantizing (ascending element
    /// order), as in [`QuantizedKv`].
    pub sq_err: f64,
    /// `Σx²` of the source, same accumulation.
    pub sq_ref: f64,
}

impl QuantizedKv4 {
    /// Token groups along the length axis (`ceil(len / I4_GROUP)`).
    pub fn groups(&self) -> usize {
        self.dims[1].div_ceil(I4_GROUP)
    }

    /// Quantize a `(layers, len, kv_heads, head_dim)` tensor. Each
    /// (layer, head, channel) takes one scale **per group of
    /// [`I4_GROUP`] tokens** (amax over the group / 7) — finer than the
    /// int8 tier's whole-token-axis reduction, which the 15-level code
    /// range needs. `head_dim` must be even (nibble pairing).
    pub fn quantize(x: &TensorF) -> QuantizedKv4 {
        let d = x.dims();
        assert_eq!(d.len(), 4, "expected (layers, len, kv_heads, head_dim), got {d:?}");
        let (layers, len, heads, hd) = (d[0], d[1], d[2], d[3]);
        assert!(hd % 2 == 0, "int4 packing needs an even head_dim, got {hd}");
        let groups = len.div_ceil(I4_GROUP);
        let row = heads * hd;

        let mut scales = vec![0.0f32; layers * groups * row];
        for l in 0..layers {
            let layer = x.axis0(l);
            for g in 0..groups {
                let srow = &mut scales[(l * groups + g) * row..(l * groups + g + 1) * row];
                for t in g * I4_GROUP..((g + 1) * I4_GROUP).min(len) {
                    for (s, &v) in srow.iter_mut().zip(&layer[t * row..(t + 1) * row]) {
                        *s = s.max(v.abs());
                    }
                }
                for s in srow.iter_mut() {
                    *s /= 7.0;
                }
            }
        }

        let mut packed = Vec::with_capacity(layers * len * row / 2);
        let (mut sq_err, mut sq_ref) = (0.0f64, 0.0f64);
        for l in 0..layers {
            let layer = x.axis0(l);
            for t in 0..len {
                let srow = &scales[(l * groups + t / I4_GROUP) * row..][..row];
                let trow = &layer[t * row..(t + 1) * row];
                for cp in 0..row / 2 {
                    let (c0, c1) = (2 * cp, 2 * cp + 1);
                    let q0 = quantize_one_i4(trow[c0], srow[c0]);
                    let e0 = (trow[c0] - dequant_one(q0, srow[c0])) as f64;
                    sq_err += e0 * e0;
                    sq_ref += (trow[c0] as f64) * (trow[c0] as f64);
                    let q1 = quantize_one_i4(trow[c1], srow[c1]);
                    let e1 = (trow[c1] - dequant_one(q1, srow[c1])) as f64;
                    sq_err += e1 * e1;
                    sq_ref += (trow[c1] as f64) * (trow[c1] as f64);
                    packed.push(pack_nibbles(q0, q1));
                }
            }
        }
        QuantizedKv4 { packed, scales, dims: [layers, len, heads, hd], sq_err, sq_ref }
    }

    /// Reconstruct the f32 tensor (`q·s` per element).
    pub fn dequantize(&self) -> TensorF {
        let [layers, len, heads, hd] = self.dims;
        let groups = self.groups();
        let row = heads * hd;
        let mut out = Tensor::zeros(&self.dims);
        let od = out.data_mut();
        for l in 0..layers {
            for t in 0..len {
                let srow = &self.scales[(l * groups + t / I4_GROUP) * row..][..row];
                let orow = &mut od[(l * len + t) * row..(l * len + t + 1) * row];
                let brow = &self.packed[(l * len + t) * row / 2..][..row / 2];
                dequant_i4_row(brow, srow, orow);
            }
        }
        out
    }

    /// Stored bytes: half a byte per code plus four per scale.
    pub fn size_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }

    /// Test-side recomputation of the inline error sums (see
    /// [`QuantizedKv::sq_err_vs`]).
    pub fn sq_err_vs(&self, x: &TensorF) -> (f64, f64) {
        assert_eq!(x.dims(), &self.dims[..], "error reference shape mismatch");
        sq_err_between(x, &self.dequantize())
    }

    /// Reassemble from stored packed codes + group-wise scales — the
    /// int4 half of the persistent store's stable layout (see
    /// [`QuantizedKv::from_parts`] for the contract: verbatim codes,
    /// zeroed error sums, loud failure on section/shape mismatch).
    pub fn from_parts(packed: Vec<u8>, scales: Vec<f32>, dims: [usize; 4]) -> Result<QuantizedKv4> {
        let [layers, len, heads, hd] = dims;
        ensure!(hd % 2 == 0, "int4 packing needs an even head_dim, got {hd}");
        let n: usize = dims.iter().product();
        let groups = len.div_ceil(I4_GROUP);
        ensure!(
            packed.len() == n / 2,
            "int4 code section: {} bytes for dims {dims:?}",
            packed.len()
        );
        ensure!(
            scales.len() == layers * groups * heads * hd,
            "int4 scale section: {} scales for dims {dims:?}",
            scales.len()
        );
        Ok(QuantizedKv4 { packed, scales, dims, sq_err: 0.0, sq_ref: 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_kv(rng: &mut Rng, dims: &[usize; 4]) -> TensorF {
        let n: usize = dims.iter().product();
        Tensor::from_vec(dims, (0..n).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn roundtrip_error_is_bounded_by_channel_amax() {
        let mut rng = Rng::new(0x8B17);
        let dims = [2usize, 9, 2, 8];
        let x = random_kv(&mut rng, &dims);
        let q = QuantizedKv::quantize(&x);
        let deq = q.dequantize();
        // Per element, |x - x̂| ≤ scale/2 (+1 ulp slack); scale = amax/127.
        let (layers, len, heads, hd) = (dims[0], dims[1], dims[2], dims[3]);
        for l in 0..layers {
            for t in 0..len {
                for h in 0..heads {
                    for c in 0..hd {
                        let i = ((l * len + t) * heads + h) * hd + c;
                        let s = q.scales[(l * heads + h) * hd + c];
                        let e = (x.data()[i] - deq.data()[i]).abs();
                        assert!(e <= 0.5001 * s, "elem {i}: err {e} > scale/2 {s}");
                    }
                }
            }
        }
        let (err, refsq) = q.sq_err_vs(&x);
        assert!(err > 0.0 && refsq > 0.0);
        assert!((err / refsq).sqrt() < 0.01, "relative error too large");
        // The inline sums quantize() accumulates walk the elements in
        // the same ascending order as the recomputation — bitwise equal.
        assert_eq!(q.sq_err, err, "inline error sum drifted from recomputation");
        assert_eq!(q.sq_ref, refsq);
    }

    #[test]
    fn quantize_is_deterministic_and_quarter_size() {
        let mut rng = Rng::new(7);
        let dims = [2usize, 64, 1, 8];
        let x = random_kv(&mut rng, &dims);
        let a = QuantizedKv::quantize(&x);
        let b = QuantizedKv::quantize(&x);
        assert_eq!(a.q, b.q);
        assert_eq!(a.scales, b.scales);
        // 64 tokens: codes dominate the fixed scale table.
        let f32_bytes = x.size_bytes();
        assert!(
            a.size_bytes() * 10 <= f32_bytes * 3,
            "int8 {} vs f32 {f32_bytes}: over 30%",
            a.size_bytes()
        );
    }

    /// `from_parts` must reproduce the quantizer's output bitwise for
    /// both tiers (verbatim codes — the disk round-trip contract) and
    /// reject sections that do not match the dims.
    #[test]
    fn from_parts_is_verbatim_and_validates() {
        let mut rng = Rng::new(0x5E1A);
        let dims = [2usize, 37, 2, 8]; // partial trailing int4 group
        let x = random_kv(&mut rng, &dims);

        let q8 = QuantizedKv::quantize(&x);
        let r8 = QuantizedKv::from_parts(q8.q.clone(), q8.scales.clone(), dims).unwrap();
        assert_eq!(r8.q, q8.q);
        assert_eq!(r8.scales, q8.scales);
        assert_eq!(r8.dequantize(), q8.dequantize(), "reassembled int8 must dequantize bitwise");
        assert_eq!((r8.sq_err, r8.sq_ref), (0.0, 0.0), "restore is not a quantization event");
        assert!(QuantizedKv::from_parts(q8.q[1..].to_vec(), q8.scales.clone(), dims).is_err());
        assert!(QuantizedKv::from_parts(q8.q.clone(), q8.scales[1..].to_vec(), dims).is_err());

        let q4 = QuantizedKv4::quantize(&x);
        let r4 =
            QuantizedKv4::from_parts(q4.packed.clone(), q4.scales.clone(), dims).unwrap();
        assert_eq!(r4.packed, q4.packed);
        assert_eq!(r4.scales, q4.scales);
        assert_eq!(r4.dequantize(), q4.dequantize(), "reassembled int4 must dequantize bitwise");
        assert!(
            QuantizedKv4::from_parts(q4.packed[1..].to_vec(), q4.scales.clone(), dims).is_err()
        );
        assert!(QuantizedKv4::from_parts(q4.packed.clone(), q4.scales.clone(), [2, 37, 2, 7])
            .is_err());
    }

    #[test]
    fn constant_channels_roundtrip_exactly() {
        // A constant channel has amax = |v|, so v quantizes to ±127 and
        // dequantizes back to exactly v.
        let dims = [1usize, 4, 1, 4];
        let x = Tensor::from_vec(&dims, vec![2.5f32; 16]);
        let q = QuantizedKv::quantize(&x);
        assert!(q.q.iter().all(|&c| c == 127));
        assert_eq!(q.dequantize(), x);
        assert_eq!(q.sq_err, 0.0);
    }

    #[test]
    fn zero_tensor_has_zero_scales_and_codes() {
        let dims = [1usize, 3, 2, 4];
        let x = Tensor::zeros(&dims);
        let q = QuantizedKv::quantize(&x);
        assert!(q.scales.iter().all(|&s| s == 0.0));
        assert!(q.q.iter().all(|&c| c == 0));
        assert_eq!(q.dequantize(), x);
    }

    #[test]
    fn channel_scales_take_column_amax() {
        // 2×3 operand: column amax are (4, 2, 0).
        let b = [1.0f32, -2.0, 0.0, -4.0, 1.5, 0.0];
        let s = channel_scales(&b, 2, 3);
        assert_eq!(s, vec![4.0 / 127.0, 2.0 / 127.0, 0.0]);
    }

    #[test]
    fn quantize_one_saturates_and_rounds() {
        assert_eq!(quantize_one(1.0, 0.0), 0, "zero scale must not divide");
        assert_eq!(quantize_one(f32::MAX, 1e-30), 127);
        assert_eq!(quantize_one(-f32::MAX, 1e-30), -127);
        assert_eq!(quantize_one(0.5, 1.0), 1, "round half away from zero");
        assert_eq!(quantize_one(-0.5, 1.0), -1);
        assert_eq!(dequant_one(3, 0.5), 1.5);
    }

    #[test]
    fn nibble_pack_roundtrips_all_codes() {
        for lo in -8i8..8 {
            for hi in -8i8..8 {
                let b = pack_nibbles(lo, hi);
                assert_eq!(nibble_lo(b), lo, "lo nibble of ({lo}, {hi})");
                assert_eq!(nibble_hi(b), hi, "hi nibble of ({lo}, {hi})");
            }
        }
    }

    #[test]
    fn quantize_one_i4_saturates_and_rounds() {
        assert_eq!(quantize_one_i4(1.0, 0.0), 0, "zero scale must not divide");
        assert_eq!(quantize_one_i4(f32::MAX, 1e-30), 7);
        assert_eq!(quantize_one_i4(-f32::MAX, 1e-30), -7);
        assert_eq!(quantize_one_i4(0.5, 1.0), 1, "round half away from zero");
        assert_eq!(quantize_one_i4(-0.5, 1.0), -1);
    }

    #[test]
    fn int4_roundtrip_error_is_bounded_by_group_amax() {
        let mut rng = Rng::new(0x4B17);
        // 67 tokens: three groups, the last partial.
        let dims = [2usize, 67, 2, 8];
        let x = random_kv(&mut rng, &dims);
        let q = QuantizedKv4::quantize(&x);
        assert_eq!(q.groups(), 3);
        let deq = q.dequantize();
        let (layers, len, heads, hd) = (dims[0], dims[1], dims[2], dims[3]);
        let row = heads * hd;
        for l in 0..layers {
            for t in 0..len {
                let srow = &q.scales[(l * q.groups() + t / I4_GROUP) * row..][..row];
                for c in 0..row {
                    let i = (l * len + t) * row + c;
                    let e = (x.data()[i] - deq.data()[i]).abs();
                    assert!(
                        e <= 0.5001 * srow[c],
                        "elem {i}: err {e} > scale/2 {}",
                        srow[c]
                    );
                }
            }
        }
        let (err, refsq) = q.sq_err_vs(&x);
        assert!(err > 0.0 && refsq > 0.0);
        // ~15-level codes with per-group amax: coarse but bounded.
        assert!((err / refsq).sqrt() < 0.15, "relative error too large");
        assert_eq!(q.sq_err, err, "inline error sum drifted from recomputation");
        assert_eq!(q.sq_ref, refsq);
    }

    #[test]
    fn int4_is_deterministic_and_under_one_eighth_plus_scales() {
        let mut rng = Rng::new(0x44);
        let dims = [2usize, 64, 1, 8];
        let x = random_kv(&mut rng, &dims);
        let a = QuantizedKv4::quantize(&x);
        let b = QuantizedKv4::quantize(&x);
        assert_eq!(a.packed, b.packed);
        assert_eq!(a.scales, b.scales);
        assert_eq!(a.packed.len() * 2, x.len(), "two codes per byte");
        // 64 tokens = two full groups: ≤ 16% of the f32 bytes.
        let f32_bytes = x.size_bytes();
        assert!(
            a.size_bytes() * 100 <= f32_bytes * 16,
            "int4 {} vs f32 {f32_bytes}: over 16%",
            a.size_bytes()
        );
    }

    #[test]
    fn int4_constant_channels_roundtrip_exactly() {
        // A constant channel has group amax = |v|, so v quantizes to ±7
        // and dequantizes back to exactly v.
        let dims = [1usize, 4, 1, 4];
        let x = Tensor::from_vec(&dims, vec![2.5f32; 16]);
        let q = QuantizedKv4::quantize(&x);
        assert!(q.packed.iter().all(|&b| nibble_lo(b) == 7 && nibble_hi(b) == 7));
        assert_eq!(q.dequantize(), x);
        assert_eq!(q.sq_err, 0.0);
    }

    #[test]
    fn int4_group_scales_are_per_token_group() {
        // One channel, two groups: tokens 0..32 hold amax 1, tokens
        // 32..40 hold amax 10 — the second group's scale must not bleed
        // into the first.
        let len = 40usize;
        let mut data = vec![0.0f32; len * 2];
        for t in 0..len {
            let v = if t < I4_GROUP { 1.0 } else { 10.0 };
            data[t * 2] = v;
            data[t * 2 + 1] = -v;
        }
        let x = Tensor::from_vec(&[1usize, len, 1, 2], data);
        let q = QuantizedKv4::quantize(&x);
        assert_eq!(q.groups(), 2);
        assert_eq!(&q.scales[..2], &[1.0 / 7.0, 1.0 / 7.0]);
        assert_eq!(&q.scales[2..], &[10.0 / 7.0, 10.0 / 7.0]);
        // Both magnitudes are exact at their group's amax.
        assert_eq!(q.dequantize(), x);
    }

    #[test]
    fn channel_scales_for_generalizes_qmax() {
        let b = [7.0f32, -14.0];
        assert_eq!(channel_scales_for(&b, 1, 2, 7.0), vec![1.0, 2.0]);
        assert_eq!(channel_scales(&b, 1, 2), vec![7.0 / 127.0, 14.0 / 127.0]);
    }
}
