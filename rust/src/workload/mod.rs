//! Synthetic workloads.
//!
//! The paper evaluates on NQ/TQA/HQA/2Wiki (RAG), MMLU/BBH/... (general
//! and ICL) and an internal Game-AI task. None of those are available
//! offline, and an 8B LLM does not fit this box — so, per the
//! substitution rule (DESIGN.md), we build synthetic equivalents that
//! exercise the *same mechanism*: answers that can only be produced by
//! attending into retrieved context blocks.
//!
//! * [`rag`] — fact-retrieval passages with distractors; 1-hop/2-hop/
//!   distractor variants play the roles of NQ/TQA/HQA/2Wiki.
//! * [`general`] — zero-shot (copy/reverse) and few-shot ICL tasks
//!   (mapping retrieval, modular arithmetic, sorting) for Table 2.
//! * [`gamecore`] — a Texas-hold'em-like JSON frame stream with >99%
//!   inter-frame repetition (Appendix A).
//! * [`traces`] — Zipf-skewed passage-reuse query streams for the
//!   serving benchmarks.

pub mod gamecore;
pub mod general;
pub mod rag;
pub mod traces;
pub mod words;

use crate::coordinator::segmenter::SegmentedPrompt;
use crate::tokenizer::{ByteTokenizer, QRY, SEP};

/// One supervised sample: context blocks, a query, and the gold answer.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Context blocks (raw text; one per passage/demo). May be empty for
    /// zero-shot tasks.
    pub blocks: Vec<String>,
    pub query: String,
    /// The gold *value* — evaluation checks that it appears in the
    /// generated output (the paper's containment metric, §3.1).
    pub answer: String,
    /// The training target text. For RAG this is a full restatement
    /// sentence ("the key of kato is mi .") rather than the bare value —
    /// the restatement makes the copy behaviour a clean suffix-match
    /// induction, which a from-scratch tiny model learns readily.
    pub response: String,
}

impl Sample {
    /// Sample whose training target equals the bare answer.
    pub fn bare(blocks: Vec<String>, query: String, answer: String) -> Sample {
        let response = answer.clone();
        Sample { blocks, query, answer, response }
    }
}

impl Sample {
    /// Tokenize into a segmented prompt: each block ends with SEP (so
    /// identical passages are identical token blocks anywhere they
    /// appear) and the query block starts with QRY.
    pub fn segment(&self, tok: &ByteTokenizer) -> SegmentedPrompt {
        let blocks = self
            .blocks
            .iter()
            .map(|b| {
                let mut ids = tok.encode(b);
                ids.push(SEP);
                ids
            })
            .collect();
        let mut query = vec![QRY];
        query.extend(tok.encode(&self.query));
        SegmentedPrompt { blocks, query }
    }

    /// Total prompt tokens after segmentation.
    pub fn prompt_tokens(&self, tok: &ByteTokenizer) -> usize {
        let sp = self.segment(tok);
        sp.context_tokens() + sp.query.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segmentation_appends_sep_and_qry() {
        let tok = ByteTokenizer::new();
        let s = Sample::bare(vec!["abc".into(), "de".into()], "q".into(), "a".into());
        let sp = s.segment(&tok);
        assert_eq!(sp.blocks.len(), 2);
        assert_eq!(*sp.blocks[0].last().unwrap(), SEP);
        assert_eq!(sp.blocks[0].len(), 4);
        assert_eq!(sp.query[0], QRY);
        assert_eq!(s.prompt_tokens(&tok), 4 + 3 + 2);
    }

    #[test]
    fn identical_blocks_tokenize_identically() {
        let tok = ByteTokenizer::new();
        let a = Sample::bare(vec!["same doc".into()], "x".into(), "".into());
        let b = Sample::bare(vec!["same doc".into()], "y".into(), "".into());
        assert_eq!(a.segment(&tok).blocks[0], b.segment(&tok).blocks[0]);
    }
}
