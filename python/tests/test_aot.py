"""AOT pipeline sanity: entry enumeration, HLO text lowering, init
params, and manifest consistency with the model's parameter layout."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import CONFIGS, TINY


def test_entries_cover_all_kinds():
    kinds = {kind for _, kind, _, _, _ in aot.entries_for(TINY)}
    assert kinds == {
        "prefill_full",
        "prefill_block",
        "prefill_final",
        "decode_step",
        "reencode_k",
        "train_step",
    }


def test_entry_names_are_unique():
    for cfg in CONFIGS.values():
        names = [name for name, *_ in aot.entries_for(cfg)]
        assert len(names) == len(set(names)), cfg.name


def test_lower_one_entry_to_hlo_text():
    # The smallest tiny entry: reencode (no params).
    entries = {name: (fn, specs) for name, _, _, fn, specs in aot.entries_for(TINY)}
    fn, specs = entries["tiny_reencode_L64"]
    text = aot.to_hlo_text(fn, specs)
    assert "HloModule" in text
    assert len(text) > 1000


def test_init_params_deterministic_and_correct_layout():
    a = model.init_params(TINY, seed=5)
    b = model.init_params(TINY, seed=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    specs = model.param_specs(TINY)
    assert len(a) == len(specs)
    for arr, (name, shape) in zip(a, specs):
        assert arr.shape == tuple(shape), name
        assert arr.dtype == np.float32
    # Norm weights start at one, matrices near zero-mean.
    names = [n for n, _ in specs]
    assert np.all(a[names.index("final_norm")] == 1.0)
    assert abs(float(a[0].mean())) < 1e-2


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_model_layout():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        man = json.load(f)
    for name, cfg in CONFIGS.items():
        mc = man["configs"][name]
        assert mc["d_model"] == cfg.d_model
        assert mc["head_dim"] == cfg.head_dim
        specs = model.param_specs(cfg)
        assert [p["name"] for p in mc["params"]] == [n for n, _ in specs]
        assert [tuple(p["shape"]) for p in mc["params"]] == [tuple(s) for _, s in specs]
        # Every listed artifact file exists.
        adir = os.path.dirname(path)
        for e in mc["entries"]:
            assert os.path.exists(os.path.join(adir, e["file"])), e["file"]
        # Init file length matches the layout.
        n_params = sum(int(np.prod(s)) for _, s in specs)
        init = os.path.join(adir, mc["init_file"])
        assert os.path.getsize(init) == 4 * n_params
