//! L3 runtime: pluggable inference/training backends.
//!
//! The serving stack above this module ([`crate::coordinator`],
//! [`crate::server`], [`crate::train`], the benches) is generic over the
//! [`Backend`] trait, which captures the engine contract of the paper's
//! serving pipeline: full-prompt prefill (baseline), independent
//! per-block prefill at local positions (§2.1), final-block prefill over
//! a re-encoded cached context (§2.5), single-token decode, and the
//! block fine-tune step (§2.4).
//!
//! Two implementations:
//!
//! * [`NativeBackend`] — a pure-Rust Llama-style forward pass over
//!   [`crate::tensor::TensorF`] with deterministic seeded weights. No
//!   artifacts, no C dependencies: the hermetic reference that the test
//!   suite runs against, and the executable specification the
//!   accelerated paths are checked against.
//! * `ModelEngine` (cargo feature `xla`) — loads AOT HLO artifacts
//!   produced by `python/compile/aot.py` and executes them on the PJRT
//!   CPU client. Compiled only with `--features xla`.
//!
//! Select at runtime with `--backend native|xla` (see
//! [`backend_from_args`]).

pub mod ctx;
pub mod native;
mod native_train;
mod params;

#[cfg(feature = "xla")]
mod engine;
#[cfg(feature = "xla")]
mod literal;

#[cfg(feature = "xla")]
pub use engine::ModelEngine;
#[cfg(feature = "xla")]
pub use literal::{literal_to_f32, literal_to_i32, tensor_f, tensor_i};
pub use ctx::{CtxKv, DecodeCtx};
pub use native::NativeBackend;
pub use params::{read_flat_params, write_flat_params};

use crate::config::{ModelConfig, ParamSpec};
use crate::tensor::{argmax, Tensor, TensorF, TensorI};
use crate::util::cli::Args;
use anyhow::{bail, ensure, Result};

/// Output of a vanilla full prefill.
pub struct PrefillFullOut {
    /// Logits of the last valid position (vocab,).
    pub last_logits: Vec<f32>,
    /// Per-layer keys `(layers, len, kv_heads, head_dim)`, trimmed.
    pub k: TensorF,
    pub v: TensorF,
}

/// Output of a final-block prefill.
pub struct PrefillFinalOut {
    pub last_logits: Vec<f32>,
    /// Final-block KV at absolute positions, trimmed to the query length.
    pub k: TensorF,
    pub v: TensorF,
}

/// Output of a decode step.
pub struct DecodeOut {
    pub logits: Vec<f32>,
    pub k_cache: TensorF,
    pub v_cache: TensorF,
}

/// Output of a train step.
pub struct TrainOut {
    pub loss: f32,
}

/// The engine contract the serving stack is generic over.
///
/// All methods take `&self`: backends use interior mutability for
/// parameters and optimizer state, mirroring the device-resident state
/// of the PJRT engine. Implementations need not be `Sync`; the server
/// owns its backend on a dedicated engine thread.
pub trait Backend {
    /// Transformer dimensions of this backend's model.
    fn config(&self) -> &ModelConfig;

    /// The flattened parameter layout (checkpoint order).
    fn param_specs(&self) -> &[ParamSpec];

    /// Replace the parameters (checked against [`Self::param_specs`]).
    fn set_params(&self, tensors: Vec<TensorF>) -> Result<()>;

    /// Download the current parameters to host tensors (checkpointing).
    fn params_host(&self) -> Result<Vec<TensorF>>;

    /// Reset optimizer state (call when fine-tuning from a freshly
    /// loaded checkpoint).
    fn reset_opt_state(&self);

    /// Vanilla full-attention prefill (the baseline path). Returns KV
    /// trimmed to `tokens.len()`.
    fn prefill_full(&self, tokens: &[i32]) -> Result<PrefillFullOut>;

    /// Independent block prefill at local positions (paper §2.1).
    /// Returns KV trimmed to the block length; keys are at positions
    /// `0..len` and must be re-encoded before use at a non-zero offset.
    fn prefill_block(&self, tokens: &[i32]) -> Result<(TensorF, TensorF)>;

    /// Prefill several independent blocks, returning KV pairs in input
    /// order. Blocks never attend to each other (the paper's
    /// independence property), so backends may compute them
    /// concurrently — the coordinator routes every batch of cache
    /// misses through this. The default is the serial loop; results
    /// must be identical to per-block [`Self::prefill_block`] calls.
    fn prefill_blocks(&self, blocks: &[&[i32]]) -> Result<Vec<(TensorF, TensorF)>> {
        blocks.iter().map(|b| self.prefill_block(b)).collect()
    }

    /// Final-block prefill with an explicit query position origin
    /// (`q_pos0`): superposition-style baselines place the query after
    /// the longest *parallel* document path instead of after the
    /// concatenated context. `past_k`/`past_v` are
    /// `(layers, C, kv_heads, head_dim)` with valid prefix `past_len`,
    /// already rotated to absolute positions.
    fn prefill_final_at(
        &self,
        tokens: &[i32],
        past_k: &TensorF,
        past_v: &TensorF,
        past_len: usize,
        q_pos0: usize,
    ) -> Result<PrefillFinalOut>;

    /// Final-block prefill over an assembled, re-encoded context; the
    /// query sits at RoPE positions `past_len..`.
    fn prefill_final(
        &self,
        tokens: &[i32],
        past_k: &TensorF,
        past_v: &TensorF,
        past_len: usize,
    ) -> Result<PrefillFinalOut> {
        self.prefill_final_at(tokens, past_k, past_v, past_len, past_len)
    }

    /// One decode step: append `token` at `cache_len` and return logits
    /// plus the updated dense cache.
    fn decode(
        &self,
        token: i32,
        k_cache: &TensorF,
        v_cache: &TensorF,
        cache_len: usize,
    ) -> Result<DecodeOut>;

    /// One decode step over a [`DecodeCtx`] — the serving decode path.
    /// Appends the token's KV to the context's f32 tail and returns the
    /// logits; on the quantized tiers attention must read the prefix
    /// codes (see [`NativeBackend`]'s fused implementation).
    ///
    /// The default bridges to [`Self::decode`] by materializing a dense
    /// f32 cache at [`Self::decode_ctx_capacity`] — correct for any
    /// backend (bitwise identical to the fused path, because
    /// dequantization is per-element), but it re-dequantizes the prefix
    /// every step; backends with a native quantized path should
    /// override.
    fn decode_ctx(&self, token: i32, ctx: &mut DecodeCtx) -> Result<Vec<f32>> {
        let cap = self.decode_ctx_capacity()?;
        let (kc, vc) = ctx.to_dense(cap)?;
        let out = self.decode(token, &kc, &vc, ctx.len())?;
        ctx.push_row_from_dense(&out.k_cache, &out.v_cache)?;
        Ok(out.logits)
    }

    /// One decode step for a **batch** of independent in-flight
    /// sessions (continuous batching): append each session's token to
    /// its own context and return the greedy next token per session, in
    /// input order. Sessions may sit at different lengths and different
    /// KV tiers.
    ///
    /// Contract: the result — tokens *and* every context's KV tail —
    /// must be bitwise identical to calling [`Self::decode_ctx`] on
    /// each session one at a time, at every thread count. This is what
    /// lets the serving loop batch sessions freely: batching is a pure
    /// performance decision, never an accuracy one. The default is that
    /// serial loop; `NativeBackend` overrides it to fuse all sessions'
    /// per-token GEMV rows into one GEMM dispatch per projection
    /// (memory-bound GEMV → compute-dense GEMM), which preserves the
    /// contract because the GEMM kernels guarantee row independence
    /// (see `kernels::gemm`).
    fn decode_batch(&self, ctxs: &mut [&mut DecodeCtx], last: &[i32]) -> Result<Vec<i32>> {
        ensure!(
            ctxs.len() == last.len(),
            "decode_batch: {} contexts vs {} tokens",
            ctxs.len(),
            last.len()
        );
        ctxs.iter_mut()
            .zip(last)
            .map(|(ctx, &t)| Ok(argmax(&self.decode_ctx(t, ctx)?) as i32))
            .collect()
    }

    /// One block-fine-tune step (paper §2.4). `seg` carries the
    /// Figure-1 segment ids (uniform ids = full-attention mode),
    /// `loss_mask` marks target tokens. Updates the backend's
    /// parameters in place.
    fn train_step(
        &self,
        step: usize,
        lr: f32,
        tokens: &TensorI,
        seg: &TensorI,
        loss_mask: &TensorF,
    ) -> Result<TrainOut>;

    /// Context capacity (C) a final-prefill over `ctx_len` past tokens
    /// must allocate. Bucketed backends round up; exact backends return
    /// `ctx_len`.
    fn final_ctx_capacity(&self, ctx_len: usize) -> Result<usize>;

    /// Max query-block length supported by the final prefill.
    fn final_q_capacity(&self) -> Result<usize>;

    /// Dense-cache capacity of the decode path.
    fn decode_ctx_capacity(&self) -> Result<usize>;

    /// Longest single block `prefill_block` accepts.
    fn max_block_tokens(&self) -> Result<usize>;

    /// `(batch, seq_len)` shape of one training step's packed batch.
    fn train_shape(&self) -> Result<(usize, usize)>;

    /// Prepare the serving entry points (e.g. pre-compile AOT
    /// executables). No-op for backends without a compile step.
    fn warmup(&self) -> Result<()> {
        Ok(())
    }

    /// Zero-filled KV context tensor `(layers, c, kv_heads, head_dim)`.
    fn kv_zeros(&self, c: usize) -> TensorF {
        let cfg = self.config();
        Tensor::zeros(&[cfg.layers, c, cfg.kv_heads, cfg.head_dim])
    }

    /// Load parameters from a flat little-endian f32 checkpoint file.
    fn load_params_file(&self, path: &std::path::Path) -> Result<()> {
        let tensors = read_flat_params(path, self.param_specs())?;
        self.set_params(tensors)
    }

    /// Save the current parameters as a flat f32 checkpoint.
    fn save_params_file(&self, path: &std::path::Path) -> Result<()> {
        let tensors = self.params_host()?;
        write_flat_params(path, &tensors)
    }
}

/// `Box<dyn Backend>` is itself a backend, so runtime-selected backends
/// (`--backend native|xla`) drive the same generic stack.
impl Backend for Box<dyn Backend> {
    fn config(&self) -> &ModelConfig {
        (**self).config()
    }

    fn param_specs(&self) -> &[ParamSpec] {
        (**self).param_specs()
    }

    fn set_params(&self, tensors: Vec<TensorF>) -> Result<()> {
        (**self).set_params(tensors)
    }

    fn params_host(&self) -> Result<Vec<TensorF>> {
        (**self).params_host()
    }

    fn reset_opt_state(&self) {
        (**self).reset_opt_state()
    }

    fn prefill_full(&self, tokens: &[i32]) -> Result<PrefillFullOut> {
        (**self).prefill_full(tokens)
    }

    fn prefill_block(&self, tokens: &[i32]) -> Result<(TensorF, TensorF)> {
        (**self).prefill_block(tokens)
    }

    fn prefill_blocks(&self, blocks: &[&[i32]]) -> Result<Vec<(TensorF, TensorF)>> {
        (**self).prefill_blocks(blocks)
    }

    fn prefill_final_at(
        &self,
        tokens: &[i32],
        past_k: &TensorF,
        past_v: &TensorF,
        past_len: usize,
        q_pos0: usize,
    ) -> Result<PrefillFinalOut> {
        (**self).prefill_final_at(tokens, past_k, past_v, past_len, q_pos0)
    }

    fn decode(
        &self,
        token: i32,
        k_cache: &TensorF,
        v_cache: &TensorF,
        cache_len: usize,
    ) -> Result<DecodeOut> {
        (**self).decode(token, k_cache, v_cache, cache_len)
    }

    fn decode_ctx(&self, token: i32, ctx: &mut DecodeCtx) -> Result<Vec<f32>> {
        (**self).decode_ctx(token, ctx)
    }

    fn decode_batch(&self, ctxs: &mut [&mut DecodeCtx], last: &[i32]) -> Result<Vec<i32>> {
        (**self).decode_batch(ctxs, last)
    }

    fn train_step(
        &self,
        step: usize,
        lr: f32,
        tokens: &TensorI,
        seg: &TensorI,
        loss_mask: &TensorF,
    ) -> Result<TrainOut> {
        (**self).train_step(step, lr, tokens, seg, loss_mask)
    }

    fn final_ctx_capacity(&self, ctx_len: usize) -> Result<usize> {
        (**self).final_ctx_capacity(ctx_len)
    }

    fn final_q_capacity(&self) -> Result<usize> {
        (**self).final_q_capacity()
    }

    fn decode_ctx_capacity(&self) -> Result<usize> {
        (**self).decode_ctx_capacity()
    }

    fn max_block_tokens(&self) -> Result<usize> {
        (**self).max_block_tokens()
    }

    fn train_shape(&self) -> Result<(usize, usize)> {
        (**self).train_shape()
    }

    fn warmup(&self) -> Result<()> {
        (**self).warmup()
    }
}

/// Default weight seed for hermetically-initialized native models.
pub const DEFAULT_WEIGHT_SEED: u64 = 0xB10C;

/// The backend name selected by CLI options: `--backend` wins, then
/// `$BLOCK_ATTN_BACKEND`, then `"native"`. Every site that branches on
/// the backend choice (defaults, artifact listings) must use this so
/// the env override behaves exactly like the flag.
pub fn backend_choice(args: &Args) -> String {
    args.str_or(
        "backend",
        &std::env::var("BLOCK_ATTN_BACKEND").unwrap_or_else(|_| "native".into()),
    )
}

/// Build a backend from CLI-style options:
///
/// * `--backend native|xla` (default: `$BLOCK_ATTN_BACKEND` or `native`)
/// * `--model NAME` (default: `default_model`; for the native backend a
///   built-in config name, for xla a manifest config name)
/// * `--seed-weights N` (native: deterministic init seed)
/// * `--artifacts DIR` (xla: the AOT artifact directory)
///
/// Checkpoint loading is left to callers (`--checkpoint` handling
/// differs per tool); checkpoints are interchangeable between backends
/// because both use the same flat-f32 parameter layout.
pub fn backend_from_args(args: &Args, default_model: &str) -> Result<Box<dyn Backend>> {
    let choice = backend_choice(args);
    let model = args.str_or("model", default_model);
    match choice.as_str() {
        "native" => {
            let cfg = ModelConfig::builtin(&model)
                .ok_or_else(|| anyhow::anyhow!("no built-in native config '{model}'"))?;
            let seed = args.u64_or("seed-weights", DEFAULT_WEIGHT_SEED);
            Ok(Box::new(NativeBackend::new(cfg, seed)))
        }
        "xla" => xla_backend(args, &model),
        other => bail!("unknown backend '{other}' (expected 'native' or 'xla')"),
    }
}

#[cfg(feature = "xla")]
fn xla_backend(args: &Args, model: &str) -> Result<Box<dyn Backend>> {
    let dir = args.str_or(
        "artifacts",
        crate::config::default_artifacts_dir().to_str().unwrap_or("artifacts"),
    );
    let manifest = crate::config::Manifest::load(&dir)?;
    Ok(Box::new(ModelEngine::new(&manifest, model)?))
}

#[cfg(not(feature = "xla"))]
fn xla_backend(_args: &Args, _model: &str) -> Result<Box<dyn Backend>> {
    bail!(
        "this binary was built without the `xla` feature; rebuild with \
         `cargo build --features xla` (and a real xla crate, see \
         rust/vendor/xla-stub/README.md) or use `--backend native`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Explicit `--backend native` so the test stays hermetic even when
    /// the ambient environment exports `BLOCK_ATTN_BACKEND`.
    fn native_args(extra: &[&str]) -> Args {
        let mut v = vec!["--backend".to_string(), "native".to_string()];
        v.extend(extra.iter().map(|s| s.to_string()));
        Args::parse_from(v)
    }

    #[test]
    fn backend_from_args_selects_native() {
        let b = backend_from_args(&native_args(&[]), "tiny").unwrap();
        assert_eq!(b.config().name, "tiny");
        assert_eq!(b.param_specs().len(), 11);
    }

    #[test]
    fn backend_from_args_rejects_unknown() {
        let args = Args::parse_from(vec!["--backend".to_string(), "tpu".to_string()]);
        assert!(backend_from_args(&args, "tiny").is_err());
        assert!(backend_from_args(&native_args(&["--model", "nope"]), "tiny").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_backend_requires_feature() {
        let args = Args::parse_from(vec!["--backend".to_string(), "xla".to_string()]);
        let err = backend_from_args(&args, "tiny").unwrap_err();
        assert!(format!("{err}").contains("xla"));
    }

    #[test]
    fn boxed_backend_is_a_backend() {
        fn takes_backend<B: Backend>(b: &B) -> usize {
            b.config().layers
        }
        let b = backend_from_args(&native_args(&[]), "tiny").unwrap();
        assert_eq!(takes_backend(&b), 4);
    }

    #[test]
    fn flag_overrides_env_choice() {
        // The flag always wins regardless of ambient environment.
        assert_eq!(backend_choice(&native_args(&[])), "native");
    }
}
