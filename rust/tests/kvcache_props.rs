//! Property tests for [`BlockKvCache`] (the paper's enabling data
//! structure) driven by `util::prop` against a shadow model:
//!
//! 1. pinned entries are never evicted;
//! 2. byte accounting equals the sum of live entries' KV bytes;
//! 3. LRU evicts strictly in `last_used` order among unpinned entries;
//! 4. `CacheStats` hit/miss/insert/evict counters are consistent with
//!    the operation stream.

use block_attn::kvcache::{block_key, BlockKvCache};
use block_attn::rope::RopeTable;
use block_attn::tensor::{Tensor, TensorF};
use block_attn::util::prop;
use block_attn::util::rng::Rng;
use block_attn::{prop_assert, prop_assert_eq};
use std::collections::HashMap;

fn rope() -> RopeTable {
    RopeTable::new(8, 10000.0)
}

/// KV pair for a block of `len` tokens: 2 layers × len × 1 head × 8 dim.
fn kv(len: usize, fill: f32) -> (TensorF, TensorF) {
    let mut k = Tensor::<f32>::zeros(&[2, len, 1, 8]);
    k.data_mut().iter_mut().for_each(|x| *x = fill);
    (k.clone(), k)
}

fn kv_bytes(len: usize) -> usize {
    2 * (2 * len * 8 * 4) // K and V tensors
}

/// Shadow model entry.
struct ModelEntry {
    bytes: usize,
    pins: usize,
    last_used: u64,
}

/// Replays a random op stream against both the cache and a shadow
/// model, checking all four invariants after every step.
#[test]
fn prop_cache_agrees_with_shadow_model() {
    prop::check("kvcache-shadow-model", 0x5EED_CAFE, 120, |rng: &mut Rng| {
        let budget = kv_bytes(4) * rng.range(1, 5); // 1..4 four-token blocks
        let mut cache = BlockKvCache::new(rope(), budget);
        let mut model: HashMap<u128, ModelEntry> = HashMap::new();
        let mut clock = 0u64;
        let (mut hits, mut misses, mut insertions) = (0u64, 0u64, 0u64);

        for _ in 0..rng.range(10, 80) {
            let id = rng.below(10) as i32;
            let key = block_key(&[id]);
            clock += 1;
            match rng.below(4) {
                0 | 1 => {
                    // lookup_pin; insert on miss (the serving pattern).
                    if cache.lookup_pin(key) {
                        hits += 1;
                        let e = model.get_mut(&key).expect("hit not in model");
                        e.pins += 1;
                        e.last_used = clock;
                    } else {
                        misses += 1;
                        prop_assert!(!model.contains_key(&key), "cache missed a live entry");
                        let len = 4;
                        let (k, v) = kv(len, id as f32);
                        cache.insert_pinned(key, k, v);
                        insertions += 1;
                        model.insert(
                            key,
                            ModelEntry { bytes: kv_bytes(len), pins: 1, last_used: clock },
                        );
                        evict_in_model(&mut model, budget);
                    }
                }
                2 => {
                    // unpin (only when the model says we hold a pin).
                    if model.get(&key).map(|e| e.pins > 0).unwrap_or(false) {
                        cache.unpin(key);
                        model.get_mut(&key).unwrap().pins -= 1;
                        evict_in_model(&mut model, budget);
                    }
                }
                _ => {
                    // get_reencoded must not disturb accounting.
                    let _ = cache.get_reencoded(key, rng.below(50));
                }
            }

            // Invariant 1+3: the live set matches the shadow LRU model
            // exactly (pinned entries present, LRU victims gone).
            for (k, e) in &model {
                prop_assert!(
                    cache.contains(*k),
                    "model entry missing from cache (pins={})",
                    e.pins
                );
            }
            let s = cache.stats();
            prop_assert_eq!(s.entries, model.len());
            // Invariant 2: byte accounting = sum of live entries.
            let want_bytes: usize = model.values().map(|e| e.bytes).sum();
            prop_assert_eq!(s.bytes, want_bytes);
            // Invariant 4: counter consistency.
            prop_assert_eq!(s.hits, hits);
            prop_assert_eq!(s.misses, misses);
            prop_assert_eq!(s.insertions, insertions);
            prop_assert_eq!(s.evictions, insertions - model.len() as u64);
        }
        Ok(())
    });
}

/// Mirror of the cache's eviction rule: drop least-recently-used
/// unpinned entries until the byte budget holds (or only pins remain).
fn evict_in_model(model: &mut HashMap<u128, ModelEntry>, budget: usize) {
    loop {
        let total: usize = model.values().map(|e| e.bytes).sum();
        if total <= budget {
            return;
        }
        let victim = model
            .iter()
            .filter(|(_, e)| e.pins == 0)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k);
        match victim {
            Some(k) => {
                model.remove(&k);
            }
            None => return, // everything pinned; over budget transiently
        }
    }
}

/// Pinned entries survive arbitrarily heavy insert pressure.
#[test]
fn prop_pinned_entries_never_evicted() {
    prop::check("kvcache-pins-survive", 0x9177_BEEF, 60, |rng: &mut Rng| {
        let budget = kv_bytes(4) * 2; // room for two blocks only
        let mut cache = BlockKvCache::new(rope(), budget);
        let pinned_key = block_key(&[1000]);
        let (k, v) = kv(4, 1.0);
        cache.insert_pinned(pinned_key, k, v);
        // Hammer the cache with unpinned inserts way past the budget.
        for i in 0..rng.range(5, 40) as i32 {
            let key = block_key(&[i]);
            if !cache.lookup_pin(key) {
                let (k, v) = kv(4, i as f32);
                cache.insert_pinned(key, k, v);
            }
            cache.unpin(key);
            prop_assert!(cache.contains(pinned_key), "pinned entry evicted");
        }
        let s = cache.stats();
        prop_assert!(s.bytes <= budget, "budget violated with one pin held");
        prop_assert!(s.evictions > 0, "pressure never evicted anything");
        Ok(())
    });
}

/// Unpinned entries leave in exactly `last_used` order.
#[test]
fn lru_eviction_follows_last_used_order() {
    // Budget for 3 blocks; insert 3, touch them in a shuffled order,
    // then push new blocks one at a time: evictions must follow the
    // touch order.
    prop::check("kvcache-lru-order", 0x10BE, 80, |rng: &mut Rng| {
        let budget = kv_bytes(4) * 3;
        let mut cache = BlockKvCache::new(rope(), budget);
        let mut ids: Vec<i32> = (0..3).collect();
        for &i in &ids {
            let (k, v) = kv(4, i as f32);
            cache.insert_pinned(block_key(&[i]), k, v);
            cache.unpin(block_key(&[i]));
        }
        // Touch in random order: that order becomes the eviction order.
        rng.shuffle(&mut ids);
        for &i in &ids {
            prop_assert!(cache.lookup_pin(block_key(&[i])), "warm entry missed");
            cache.unpin(block_key(&[i]));
        }
        for (n, &expect_evicted) in ids.iter().enumerate() {
            let newcomer = 100 + n as i32;
            let (k, v) = kv(4, 0.0);
            cache.insert_pinned(block_key(&[newcomer]), k, v);
            cache.unpin(block_key(&[newcomer]));
            prop_assert!(
                !cache.contains(block_key(&[expect_evicted])),
                "expected {expect_evicted} to be the LRU victim"
            );
            // Later-touched survivors are still present.
            for &still in &ids[n + 1..] {
                prop_assert!(cache.contains(block_key(&[still])), "evicted out of order");
            }
        }
        Ok(())
    });
}

/// hit_rate is hits / (hits + misses).
#[test]
fn hit_rate_matches_counters() {
    let mut cache = BlockKvCache::new(rope(), 0);
    assert_eq!(cache.stats().hit_rate(), 0.0);
    let key = block_key(&[7]);
    assert!(!cache.lookup_pin(key)); // miss
    let (k, v) = kv(2, 1.0);
    cache.insert_pinned(key, k, v);
    assert!(cache.lookup_pin(key)); // hit
    assert!(cache.lookup_pin(key)); // hit
    let s = cache.stats();
    assert_eq!(s.hits, 2);
    assert_eq!(s.misses, 1);
    assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
}
