//! Block fine-tuning from scratch — a compact version of the paper's
//! §2.4 recipe and the driver behind Figure 4.
//!
//! Trains the tiny model with dual-mode (full + block) batches for a few
//! hundred steps, printing the loss curve and, at each checkpoint, the
//! RAG accuracy in *both* attention modes. Early in training the block
//! mode lags badly (the paper's w/o-ft observation); by the end the two
//! curves meet.
//!
//! ```sh
//! cargo run --release --example block_finetune -- --steps 200 --eval-every 40
//! ```

use block_attn::coordinator::{AttentionMode, Coordinator};
use block_attn::runtime::backend_from_args;
use block_attn::train::eval::{accuracy, EvalOpts};
use block_attn::train::presets::{rag_eval_samples, rag_mix, TRAIN_WORLD_SEED};
use block_attn::train::{train, TrainConfig, TrainMode};
use block_attn::util::cli::Args;
use block_attn::Backend;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    let steps = args.usize_or("steps", 200);
    let eval_every = args.usize_or("eval-every", 40);
    let eval_n = args.usize_or("eval-samples", 24);

    let engine = backend_from_args(&args, "tiny")?;
    if let Some(ck) = args.get("checkpoint") {
        engine.load_params_file(std::path::Path::new(ck))?;
        println!("warm-starting from {ck}");
    }
    let mut coord = Coordinator::new(engine, 128 << 20);

    let eval_samples = rag_eval_samples(eval_n);
    println!("step   loss    block-acc  full-acc");
    let cfg = TrainConfig {
        steps,
        lr: args.f64_or("lr", 1e-3),
        mode: TrainMode::Dual,
        eval_every,
        seed: args.u64_or("seed", 3),
        ..Default::default()
    };
    let mut losses_at: Vec<f32> = Vec::new();
    let losses = train(&mut coord, &cfg, &rag_mix(TRAIN_WORLD_SEED), |c, step| {
        let block = accuracy(
            c,
            &eval_samples,
            &EvalOpts { mode: AttentionMode::Block, ..Default::default() },
        )
        .unwrap_or(f64::NAN);
        let full = accuracy(
            c,
            &eval_samples,
            &EvalOpts { mode: AttentionMode::Full, ..Default::default() },
        )
        .unwrap_or(f64::NAN);
        println!(
            "{step:>5}  {:.3}   {block:8.3}   {full:8.3}",
            losses_at.last().copied().unwrap_or(f32::NAN)
        );
        let _ = c;
    })?;
    losses_at.extend(&losses);

    // Loss-curve summary (the e2e training deliverable: a few hundred
    // steps with a monotone-ish trend).
    let k = losses.len() / 5;
    println!("\nloss curve (mean per fifth of training):");
    for (i, chunk) in losses.chunks(k.max(1)).enumerate() {
        let m: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  {:>3}%: {m:.4}", i * 20);
    }
    if let Some(out) = args.get("save") {
        coord.engine().save_params_file(std::path::Path::new(out))?;
        println!("saved checkpoint to {out}");
    }
    Ok(())
}
