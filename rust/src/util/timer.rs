//! Wall-clock timing helpers and the bench harness (criterion
//! replacement): warmup + timed iterations + summary statistics.

use super::stats::Summary;
use std::time::Instant;

/// Measure one closure invocation in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Bench configuration.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Stop early once this much wall time (seconds) has been spent in
    /// timed iterations — keeps very slow cases (32K prefill) bounded.
    pub max_seconds: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 60.0 }
    }
}

/// Result of a bench run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean() * 1e3
    }

    pub fn p50_ms(&self) -> f64 {
        self.summary.p50() * 1e3
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<40} n={:<3} mean={:>10.3} ms  p50={:>10.3} ms  min={:>10.3} ms  max={:>10.3} ms",
            self.name,
            self.summary.count(),
            self.mean_ms(),
            self.p50_ms(),
            self.summary.min() * 1e3,
            self.summary.max() * 1e3,
        )
    }
}

/// Run a micro/macro benchmark: warmup, then timed iterations with an
/// early-exit time budget. The closure should perform one full operation.
pub fn bench(name: &str, opts: &BenchOpts, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut summary = Summary::new();
    let start = Instant::now();
    for _ in 0..opts.iters {
        let t0 = Instant::now();
        f();
        summary.add(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > opts.max_seconds {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let r = bench(
            "noop",
            &BenchOpts { warmup_iters: 2, iters: 5, max_seconds: 10.0 },
            || n += 1,
        );
        assert_eq!(n, 7); // 2 warmup + 5 timed
        assert_eq!(r.summary.count(), 5);
    }

    #[test]
    fn bench_respects_time_budget() {
        let r = bench(
            "sleepy",
            &BenchOpts { warmup_iters: 0, iters: 1000, max_seconds: 0.05 },
            || std::thread::sleep(std::time::Duration::from_millis(10)),
        );
        assert!(r.summary.count() < 1000);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
