//! `block-attn` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `serve`   — run the TCP JSON-line serving loop (`docs/serving.md`).
//! * `train`   — block fine-tuning driver (Tables 1-2, Figure 4 models).
//! * `eval`    — synthetic RAG accuracy benchmarks.
//! * `info`    — print the artifact manifest summary.
//!
//! Benches live under `cargo bench`; the offline corpus-to-store
//! encoder is the separate `precompute` binary.

use block_attn::util::cli::Args;

fn main() {
    let args = Args::parse();
    let code = match block_attn::run_cli(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
