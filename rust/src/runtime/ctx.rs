//! [`DecodeCtx`] — the decode-path KV context at tier precision.
//!
//! Before this type existed, the coordinator materialized a **dense
//! f32 cache at full decode capacity** right after prefill: every
//! cached block was dequantized into it, every decode step cloned it,
//! and attention always ran over f32 — so on the quantized tiers the
//! bytes saved in the block cache were spent right back on the decode
//! path, and attention never actually read quantized data.
//!
//! [`DecodeCtx`] splits the decode-time KV into two parts:
//!
//! * **prefix** — the assembled prompt context (re-encoded cached
//!   blocks + the final query block), *static* for the life of the
//!   request. It is stored at the serving tier ([`CtxKv`]): f32
//!   verbatim, or re-quantized int8 / packed int4 straight from the
//!   assembly pass. Decode attention reads the codes directly through
//!   the mixed-precision row kernels ([`crate::kernels::dot_i8`] /
//!   [`crate::kernels::dot_i4`] / the `axpy` twins — the same inner
//!   loops as the `gemm_*_i8/i4` micro-kernels), so the capacity win
//!   holds end to end.
//! * **tail** — the tokens generated so far, appended one per decode
//!   step, kept in f32 (they are written once and read every step;
//!   quantizing a growing tensor would re-scale history every token
//!   and break step-to-step determinism). The tail grows geometrically
//!   up to `capacity − prefix_len`, so a request never allocates the
//!   full decode capacity it does not use.
//!
//! Because quantization is per-element and order-free and the fused
//! kernels accumulate in the exact ascending order of their f32
//! counterparts, a [`crate::runtime::Backend::decode_ctx`] step over a
//! quantized prefix is bitwise identical to dequantizing the prefix and
//! decoding over f32 — at every thread count. That is what keeps the
//! quantized decode path inside the stack's determinism contract
//! (pinned by `tests/kv_quant.rs` and the fused-vs-dense tests in
//! `runtime::native`).

use crate::config::KvPrecision;
use crate::kernels::quant::{QuantizedKv, QuantizedKv4};
use crate::tensor::{Tensor, TensorF};
use anyhow::{ensure, Result};

/// Initial tail capacity (tokens); grows by doubling.
const TAIL_INITIAL: usize = 32;

/// The static prompt prefix of a [`DecodeCtx`], at tier precision.
pub enum CtxKv {
    /// Full-precision prefix (the f32 tier; bit-lossless).
    F32 { k: TensorF, v: TensorF },
    /// Int8 codes with per-(layer, head, channel) scales.
    Int8 { k: QuantizedKv, v: QuantizedKv },
    /// Packed int4 codes with per-(layer, head, channel, token-group)
    /// scales.
    Int4 { k: QuantizedKv4, v: QuantizedKv4 },
}

/// In-flight decode KV of one request: a tier-precision static prefix
/// plus a growing f32 tail (see the module docs).
pub struct DecodeCtx {
    pub(crate) prefix: CtxKv,
    prefix_len: usize,
    /// `(layers, tail_capacity, kv_heads, head_dim)`; rows
    /// `0..tail_len` are valid.
    pub(crate) k_tail: TensorF,
    pub(crate) v_tail: TensorF,
    tail_len: usize,
    /// Max total tokens (prefix + tail) this context may hold.
    capacity: usize,
    layers: usize,
    kv_heads: usize,
    head_dim: usize,
}

impl DecodeCtx {
    /// Build a decode context from the assembled prompt KV
    /// (`(layers, prefix_len, kv_heads, head_dim)`, exact length, keys
    /// already at absolute positions), storing the prefix at
    /// `precision`. `capacity` bounds the total tokens (prefix plus
    /// generated tail); the prefix must leave room for at least one
    /// generated token.
    pub fn new(
        k: TensorF,
        v: TensorF,
        precision: KvPrecision,
        capacity: usize,
    ) -> Result<DecodeCtx> {
        let d = k.dims().to_vec();
        ensure!(
            d.len() == 4 && v.dims() == &d[..],
            "decode context KV dims {:?}/{:?} must match (layers, len, kv_heads, head_dim)",
            k.dims(),
            v.dims()
        );
        let (layers, prefix_len, kv_heads, head_dim) = (d[0], d[1], d[2], d[3]);
        ensure!(
            prefix_len < capacity,
            "prompt of {prefix_len} tokens exceeds decode capacity {capacity}"
        );
        let prefix = match precision {
            KvPrecision::F32 => CtxKv::F32 { k, v },
            KvPrecision::Int8 => CtxKv::Int8 {
                k: QuantizedKv::quantize(&k),
                v: QuantizedKv::quantize(&v),
            },
            KvPrecision::Int4 => CtxKv::Int4 {
                k: QuantizedKv4::quantize(&k),
                v: QuantizedKv4::quantize(&v),
            },
        };
        let tail_cap = TAIL_INITIAL.min(capacity - prefix_len);
        Ok(DecodeCtx {
            prefix,
            prefix_len,
            k_tail: Tensor::zeros(&[layers, tail_cap, kv_heads, head_dim]),
            v_tail: Tensor::zeros(&[layers, tail_cap, kv_heads, head_dim]),
            tail_len: 0,
            capacity,
            layers,
            kv_heads,
            head_dim,
        })
    }

    /// Storage tier of the prefix.
    pub fn precision(&self) -> KvPrecision {
        match self.prefix {
            CtxKv::F32 { .. } => KvPrecision::F32,
            CtxKv::Int8 { .. } => KvPrecision::Int8,
            CtxKv::Int4 { .. } => KvPrecision::Int4,
        }
    }

    /// Total valid tokens (prefix + generated tail).
    pub fn len(&self) -> usize {
        self.prefix_len + self.tail_len
    }

    /// A decode context always holds at least the prompt prefix.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn prefix_len(&self) -> usize {
        self.prefix_len
    }

    pub fn tail_len(&self) -> usize {
        self.tail_len
    }

    /// Max total tokens this context may hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// `(layers, kv_heads, head_dim)` of the KV states.
    pub fn kv_dims(&self) -> (usize, usize, usize) {
        (self.layers, self.kv_heads, self.head_dim)
    }

    /// Bytes held by the prefix (codes + scales on the quantized
    /// tiers) — the decode-path counterpart of the cache's per-block
    /// accounting.
    pub fn prefix_bytes(&self) -> usize {
        match &self.prefix {
            CtxKv::F32 { k, v } => k.size_bytes() + v.size_bytes(),
            CtxKv::Int8 { k, v } => k.size_bytes() + v.size_bytes(),
            CtxKv::Int4 { k, v } => k.size_bytes() + v.size_bytes(),
        }
    }

    /// Ensure the tail can absorb one more token, growing geometrically
    /// up to `capacity − prefix_len`. Errors when the context is full —
    /// the decode-capacity guard every backend relies on.
    pub(crate) fn reserve_one(&mut self) -> Result<()> {
        ensure!(
            self.len() < self.capacity,
            "decode context full: {} tokens at capacity {}",
            self.len(),
            self.capacity
        );
        let tail_cap = self.k_tail.dims()[1];
        if self.tail_len < tail_cap {
            return Ok(());
        }
        let new_cap = (tail_cap * 2).max(TAIL_INITIAL).min(self.capacity - self.prefix_len);
        let mut k = Tensor::zeros(&[self.layers, new_cap, self.kv_heads, self.head_dim]);
        let mut v = Tensor::zeros(&[self.layers, new_cap, self.kv_heads, self.head_dim]);
        let row = self.kv_heads * self.head_dim;
        for l in 0..self.layers {
            k.axis0_mut(l)[..self.tail_len * row]
                .copy_from_slice(&self.k_tail.axis0(l)[..self.tail_len * row]);
            v.axis0_mut(l)[..self.tail_len * row]
                .copy_from_slice(&self.v_tail.axis0(l)[..self.tail_len * row]);
        }
        self.k_tail = k;
        self.v_tail = v;
        Ok(())
    }

    /// Write the in-flight token's KV row for layer `n` at the current
    /// tail position (call [`Self::reserve_one`] first; the row only
    /// becomes visible to [`Self::len`] once [`Self::advance_tail`]
    /// commits the step). Shared by the fused serial decode and the
    /// batched decode so both paths write the tail identically.
    pub(crate) fn write_tail_row(&mut self, n: usize, kb: &[f32], vb: &[f32]) {
        let row = self.kv_heads * self.head_dim;
        let at = self.tail_len * row..(self.tail_len + 1) * row;
        self.k_tail.axis0_mut(n)[at.clone()].copy_from_slice(kb);
        self.v_tail.axis0_mut(n)[at].copy_from_slice(vb);
    }

    /// Commit the tail row written at `tail_len` (backends call this
    /// after filling the row for every layer).
    pub(crate) fn advance_tail(&mut self) {
        debug_assert!(self.tail_len < self.k_tail.dims()[1], "advance past tail capacity");
        self.tail_len += 1;
    }

    /// Materialize a dense f32 cache of token capacity `cap`
    /// (dequantized prefix + tail, zero-padded) — the compatibility
    /// bridge for backends without a fused quantized decode path (the
    /// default [`crate::runtime::Backend::decode_ctx`] and the bucketed
    /// AOT engine).
    pub fn to_dense(&self, cap: usize) -> Result<(TensorF, TensorF)> {
        ensure!(
            self.len() <= cap,
            "decode context of {} tokens exceeds dense capacity {cap}",
            self.len()
        );
        let mut kc: TensorF = Tensor::zeros(&[self.layers, cap, self.kv_heads, self.head_dim]);
        let mut vc: TensorF = Tensor::zeros(&[self.layers, cap, self.kv_heads, self.head_dim]);
        let row = self.kv_heads * self.head_dim;
        let (pk, pv) = match &self.prefix {
            CtxKv::F32 { k, v } => (k.clone(), v.clone()),
            CtxKv::Int8 { k, v } => (k.dequantize(), v.dequantize()),
            CtxKv::Int4 { k, v } => (k.dequantize(), v.dequantize()),
        };
        for l in 0..self.layers {
            let kd = kc.axis0_mut(l);
            kd[..self.prefix_len * row].copy_from_slice(pk.axis0(l));
            kd[self.prefix_len * row..self.len() * row]
                .copy_from_slice(&self.k_tail.axis0(l)[..self.tail_len * row]);
            let vd = vc.axis0_mut(l);
            vd[..self.prefix_len * row].copy_from_slice(pv.axis0(l));
            vd[self.prefix_len * row..self.len() * row]
                .copy_from_slice(&self.v_tail.axis0(l)[..self.tail_len * row]);
        }
        Ok((kc, vc))
    }

    /// Append the token row at index `at` of a dense `(layers, C,
    /// kv_heads, head_dim)` cache pair to the tail — how the default
    /// dense-decode bridge feeds a step's new KV back into the context.
    pub fn push_row_from_dense(&mut self, k_cache: &TensorF, v_cache: &TensorF) -> Result<()> {
        let at = self.len();
        self.reserve_one()?;
        for cache in [k_cache, v_cache] {
            ensure!(
                cache.dims().len() == 4 && cache.dims()[1] > at,
                "dense cache of {:?} has no row {at}",
                cache.dims()
            );
        }
        let row = self.kv_heads * self.head_dim;
        for l in 0..self.layers {
            let dst = self.tail_len * row..(self.tail_len + 1) * row;
            self.k_tail.axis0_mut(l)[dst.clone()]
                .copy_from_slice(&k_cache.axis0(l)[at * row..(at + 1) * row]);
            self.v_tail.axis0_mut(l)[dst]
                .copy_from_slice(&v_cache.axis0(l)[at * row..(at + 1) * row]);
        }
        self.advance_tail();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_kv(rng: &mut Rng, len: usize) -> (TensorF, TensorF) {
        let dims = [2usize, len, 1, 8];
        let n: usize = dims.iter().product();
        let mk = |rng: &mut Rng| {
            Tensor::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect())
        };
        (mk(rng), mk(rng))
    }

    #[test]
    fn f32_prefix_is_lossless_and_tail_grows() {
        let mut rng = Rng::new(1);
        let (k, v) = rand_kv(&mut rng, 5);
        let mut ctx = DecodeCtx::new(k.clone(), v.clone(), KvPrecision::F32, 200).unwrap();
        assert_eq!(ctx.precision(), KvPrecision::F32);
        assert_eq!((ctx.len(), ctx.prefix_len(), ctx.tail_len()), (5, 5, 0));
        assert!(!ctx.is_empty());
        assert_eq!(ctx.kv_dims(), (2, 1, 8));
        // Push rows beyond the initial tail capacity to force growth.
        let (kc, vc) = ctx.to_dense(200).unwrap();
        assert_eq!(kc.dims(), &[2, 200, 1, 8]);
        for i in 0..40 {
            let (kstep, vstep) = rand_kv(&mut rng, ctx.len() + 1);
            ctx.push_row_from_dense(&kstep, &vstep).unwrap();
            assert_eq!(ctx.len(), 6 + i);
        }
        // The f32 prefix round-trips bitwise through to_dense.
        let (kd, _) = ctx.to_dense(200).unwrap();
        let row = 8;
        for l in 0..2 {
            assert_eq!(&kd.axis0(l)[..5 * row], &k.axis0(l)[..]);
        }
    }

    #[test]
    fn quantized_prefix_to_dense_equals_dequantize() {
        let mut rng = Rng::new(2);
        let (k, v) = rand_kv(&mut rng, 37);
        for prec in [KvPrecision::Int8, KvPrecision::Int4] {
            let ctx = DecodeCtx::new(k.clone(), v.clone(), prec, 64).unwrap();
            assert_eq!(ctx.precision(), prec);
            assert!(
                ctx.prefix_bytes() * 10 < (k.size_bytes() + v.size_bytes()) * 4,
                "{prec:?} prefix must be well under 40% of f32"
            );
            let (kd, vd) = ctx.to_dense(40).unwrap();
            let (want_k, want_v) = match &ctx.prefix {
                CtxKv::Int8 { k, v } => (k.dequantize(), v.dequantize()),
                CtxKv::Int4 { k, v } => (k.dequantize(), v.dequantize()),
                CtxKv::F32 { .. } => unreachable!(),
            };
            let row = 8;
            for l in 0..2 {
                assert_eq!(&kd.axis0(l)[..37 * row], want_k.axis0(l));
                assert_eq!(&vd.axis0(l)[..37 * row], want_v.axis0(l));
            }
        }
    }

    #[test]
    fn capacity_guards_fail_loudly() {
        let mut rng = Rng::new(3);
        let (k, v) = rand_kv(&mut rng, 8);
        // Prefix must leave decode room.
        assert!(DecodeCtx::new(k.clone(), v.clone(), KvPrecision::F32, 8).is_err());
        let mut ctx = DecodeCtx::new(k.clone(), v.clone(), KvPrecision::F32, 10).unwrap();
        let (kstep, vstep) = rand_kv(&mut rng, 10);
        ctx.push_row_from_dense(&kstep, &vstep).unwrap();
        ctx.push_row_from_dense(&kstep, &vstep).unwrap();
        assert_eq!(ctx.len(), 10);
        let err = ctx.push_row_from_dense(&kstep, &vstep);
        assert!(err.is_err(), "pushing past capacity must error");
        assert!(ctx.to_dense(9).is_err(), "dense cap below len must error");
    }
}
