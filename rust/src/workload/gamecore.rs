//! Game-AI workload (paper Appendix A): a Texas-hold'em-like gamecore
//! JSON stream where consecutive frames are >99% identical, so per-field
//! block caching eliminates nearly all prefill work.
//!
//! The serving scenario (`benches/scenarios.rs`) runs hundreds of these
//! tables concurrently: every session's frame carries the same static
//! `rules` field (one shared cached block across the whole fleet), and
//! between consecutive frames of one table only the acting player's
//! chips, the pot and one new history entry change — every other field
//! (seats, board, blinds, rules, the older history entries) re-serves
//! from cache.

use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// The static rule block every table shares (the paper's "rule block"
/// — identical across sessions, so the whole fleet caches it once).
pub const RULES_TEXT: &str = "holdem: bet or check in turn; raise <= 50; showdown after river";

/// A simulated poker table whose state serializes to gamecore JSON.
pub struct GamecoreSim {
    players: usize,
    pot: u64,
    round: u64,
    chips: Vec<(u64, u64)>, // (bet, remain) per player
    board: Vec<String>,
    /// Rolling action log, newest last, capped — paired with the
    /// absolute id of the oldest retained action so every entry keeps a
    /// stable key across frames (`history.a0013=…`): a step adds one
    /// new block instead of rewriting the whole log block.
    history: Vec<String>,
    rng: Rng,
}

impl GamecoreSim {
    pub fn new(players: usize, seed: u64) -> GamecoreSim {
        let mut rng = Rng::new(seed);
        let board = (0..3).map(|_| card(&mut rng)).collect();
        GamecoreSim {
            players,
            pot: 0,
            round: 0,
            chips: vec![(0, 1000); players],
            board,
            history: Vec::new(),
            rng,
        }
    }

    /// Current frame as gamecore JSON. Field shapes are chosen so
    /// `segmenter::gamecore_field_texts` cuts cache-friendly blocks:
    /// `chips`/`seats`/`history` are one-level objects (one block per
    /// player / per retained action, keyed stably), scalars stay single
    /// blocks, and the static `rules` text rides in every frame.
    pub fn frame(&self) -> Json {
        let mut chips = BTreeMap::new();
        let mut seats = BTreeMap::new();
        for (i, (_bet, remain)) in self.chips.iter().enumerate() {
            chips.insert(format!("p{}", i + 1), Json::num(*remain as f64));
            seats.insert(format!("p{}", i + 1), Json::str(format!("s{}", i + 1)));
        }
        let mut history = BTreeMap::new();
        // Entry j's absolute action id: `round` actions happened, the
        // newest is a<round>, the oldest retained is a<round-len+1>.
        let base = self.round - self.history.len() as u64;
        for (j, h) in self.history.iter().enumerate() {
            history.insert(format!("a{:04}", base + 1 + j as u64), Json::str(h.clone()));
        }
        let mut o = BTreeMap::new();
        o.insert("rules".into(), Json::str(RULES_TEXT));
        o.insert("chips".into(), Json::Obj(chips));
        o.insert("seats".into(), Json::Obj(seats));
        o.insert("pot".into(), Json::num(self.pot as f64));
        o.insert("blinds".into(), Json::str("5/10"));
        o.insert(
            "board".into(),
            Json::Arr(self.board.iter().map(|c| Json::str(c.clone())).collect()),
        );
        o.insert("history".into(), Json::Obj(history));
        Json::Obj(o)
    }

    /// Advance one action: exactly one player's chips change (the paper's
    /// example: `state['chips']['p2']` is the only delta between frames),
    /// plus the pot and one appended history entry.
    pub fn step(&mut self) {
        let p = self.rng.below(self.players);
        let bet = 10 * (1 + self.rng.below(5) as u64);
        let (b, r) = self.chips[p];
        let bet = bet.min(r);
        self.chips[p] = (b + bet, r - bet);
        self.pot += bet;
        self.round += 1;
        if self.history.len() > 8 {
            self.history.remove(0);
        }
        self.history.push(format!("p{} bets {bet}", p + 1));
    }

    /// Number of steps taken so far.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// The frame as a serving wire-request line (`--segment gamecore`
    /// or `auto`): the state rides raw and the server cuts it into
    /// per-field blocks — used by the scenarios bench and tests.
    pub fn request_line(&self, id: u64, max_new_tokens: usize) -> String {
        Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("state", self.frame()),
            ("query", Json::str("act")),
            ("max_new_tokens", Json::num(max_new_tokens as f64)),
        ])
        .to_string()
    }
}

fn card(rng: &mut Rng) -> String {
    let ranks = ["2", "3", "4", "5", "6", "7", "8", "9", "T", "J", "Q", "K", "A"];
    let suits = ["s", "h", "d", "c"];
    format!("{}{}", rng.pick(&ranks), rng.pick(&suits))
}

/// Fraction of identical blocks between two consecutive frames (the
/// paper reports >99.5% repetition on real gamecore data; our simulator
/// is smaller so the per-block fraction is lower but still dominant).
pub fn repetition_ratio(a: &[Vec<i32>], b: &[Vec<i32>]) -> f64 {
    if b.is_empty() {
        return 0.0;
    }
    let set: std::collections::HashSet<&Vec<i32>> = a.iter().collect();
    let same = b.iter().filter(|x| set.contains(*x)).count();
    same as f64 / b.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::segmenter::segment_gamecore;
    use crate::tokenizer::ByteTokenizer;

    #[test]
    fn frames_mostly_repeat() {
        let tok = ByteTokenizer::new();
        let mut sim = GamecoreSim::new(6, 42);
        let f0 = segment_gamecore(&tok, &sim.frame(), "act");
        sim.step();
        let f1 = segment_gamecore(&tok, &sim.frame(), "act");
        let ratio = repetition_ratio(&f0.blocks, &f1.blocks);
        // One player's chips + pot + one history block change; rules,
        // seats, blinds, the board and the other players' chips repeat.
        assert!(ratio > 0.5, "repetition {ratio}");
        assert_eq!(f0.blocks.len(), f1.blocks.len());
    }

    #[test]
    fn steady_state_frames_share_all_but_three_blocks() {
        let tok = ByteTokenizer::new();
        let mut sim = GamecoreSim::new(10, 3);
        for _ in 0..13 {
            sim.step(); // fill the rolling history to its cap
        }
        let f0 = segment_gamecore(&tok, &sim.frame(), "act");
        sim.step();
        let f1 = segment_gamecore(&tok, &sim.frame(), "act");
        // rules + 10 chips + 10 seats + pot + blinds + board + 9 history.
        assert_eq!(f0.blocks.len(), 33);
        assert_eq!(f1.blocks.len(), 33);
        // A step touches exactly the actor's chips, the pot and one new
        // history entry; the other 30 blocks must be byte-identical so
        // a warm cache re-serves >= 90% of each steady-state frame.
        let set: std::collections::HashSet<&Vec<i32>> = f0.blocks.iter().collect();
        let missed = f1.blocks.iter().filter(|b| !set.contains(*b)).count();
        assert!(missed <= 3, "steady-state frame re-cut {missed}/33 blocks");
        assert!(repetition_ratio(&f0.blocks, &f1.blocks) >= 0.90);
        // The whole prompt must fit the tiny model's 704-token context.
        let total: usize =
            f1.blocks.iter().map(|b| b.len()).sum::<usize>() + f1.query.len();
        assert!(total <= 700, "frame uses {total} tokens");
    }

    #[test]
    fn deterministic_frames() {
        let a = GamecoreSim::new(4, 7).frame().to_string();
        let b = GamecoreSim::new(4, 7).frame().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn step_changes_exactly_one_player() {
        let mut sim = GamecoreSim::new(6, 1);
        let before = sim.chips.clone();
        sim.step();
        let changed = sim
            .chips
            .iter()
            .zip(&before)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(changed, 1);
    }
}
