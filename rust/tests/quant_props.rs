//! Property-style round-trip battery for `kernels::quant` — the
//! per-element contracts both quantized KV tiers rest on, hammered
//! across randomized shapes and the degenerate corners:
//!
//! * **Error bound** — per element, `|x − x̂| ≤ scale/2` (the scale
//!   being per-channel amax/127 for int8, per-(channel, 32-token
//!   group) amax/7 for int4).
//! * **Determinism / order-freedom** — quantize is a pure per-element
//!   function of (value, scale): repeat calls are identical, negating
//!   the input negates the codes, and the inline `sq_err`/`sq_ref`
//!   sums match a from-scratch recomputation **bitwise** (same
//!   ascending element order).
//! * **Corners** — single-token blocks, single-channel heads,
//!   odd channel counts (int8), token counts on and off the int4
//!   group boundary, all-zero rows, max-abs ties (±v in one channel),
//!   and ±extreme magnitudes.
//! * **Byte accounting** — `size_bytes` is exactly codes + 4·scales on
//!   both tiers.

use block_attn::kernels::quant::{
    channel_scales_for, QuantizedKv, QuantizedKv4, I4_GROUP,
};
use block_attn::tensor::{Tensor, TensorF};
use block_attn::util::prop;
use block_attn::util::rng::Rng;
use block_attn::{prop_assert, prop_assert_eq};

/// Random KV tensor with a magnitude profile chosen per case: plain
/// N(0,1), scaled by an extreme power of ten, with whole-token zero
/// rows, or with exact ±v tie pairs inside a channel.
fn random_kv(rng: &mut Rng, dims: &[usize; 4]) -> TensorF {
    let n: usize = dims.iter().product();
    let magnitude = match rng.below(4) {
        0 => 1.0,
        1 => 1e-20,
        2 => 1e20,
        _ => 1e30,
    };
    let mut data: Vec<f32> = (0..n)
        .map(|_| (rng.normal() * magnitude) as f32)
        .collect();
    let row = dims[2] * dims[3];
    let tokens_total = dims[0] * dims[1];
    if rng.chance(0.3) {
        // Zero out a whole token row.
        let t = rng.below(tokens_total);
        data[t * row..(t + 1) * row].fill(0.0);
    }
    if rng.chance(0.3) && dims[1] >= 2 {
        // Max-abs tie: plant ±v in the same channel of two tokens of
        // one layer (both candidates for the amax).
        let l = rng.below(dims[0]);
        let c = rng.below(row);
        let t0 = l * dims[1];
        let v = (rng.normal() * magnitude) as f32;
        data[(t0) * row + c] = v;
        data[(t0 + 1) * row + c] = -v;
    }
    Tensor::from_vec(dims, data)
}

fn flip_sign(x: &TensorF) -> TensorF {
    Tensor::from_vec(x.dims(), x.data().iter().map(|&v| -v).collect())
}

#[test]
fn prop_int8_roundtrip_bounded_deterministic() {
    prop::check("int8-roundtrip", 0x18A7, 150, |rng: &mut Rng| {
        // Shapes include single-row (len 1), single-channel and odd
        // channel counts — int8 has no packing constraint.
        let dims = [rng.range(1, 4), rng.range(1, 41), rng.range(1, 4), rng.range(1, 13)];
        let x = random_kv(rng, &dims);
        let q = QuantizedKv::quantize(&x);
        let (layers, len, heads, hd) = (dims[0], dims[1], dims[2], dims[3]);
        let row = heads * hd;
        prop_assert_eq!(q.q.len(), x.len());
        prop_assert_eq!(q.scales.len(), layers * row);
        prop_assert_eq!(q.size_bytes(), q.q.len() + q.scales.len() * 4);
        // Per-element error bound against the per-channel scale.
        let deq = q.dequantize();
        for l in 0..layers {
            for t in 0..len {
                for c in 0..row {
                    let i = (l * len + t) * row + c;
                    let s = q.scales[l * row + c];
                    let e = (x.data()[i] - deq.data()[i]).abs();
                    prop_assert!(
                        e <= 0.5001 * s,
                        "elem {i}: err {e} > scale/2 ({s})"
                    );
                }
            }
        }
        // Determinism: identical codes and scales on a second pass.
        let q2 = QuantizedKv::quantize(&x);
        prop_assert_eq!(q.q, q2.q);
        prop_assert_eq!(q.scales, q2.scales);
        // Inline error sums equal the recomputation bitwise.
        let (err, refsq) = q.sq_err_vs(&x);
        prop_assert!(q.sq_err == err, "inline sq_err {} != recomputed {err}", q.sq_err);
        prop_assert!(q.sq_ref == refsq, "inline sq_ref {} != recomputed {refsq}", q.sq_ref);
        // Symmetry (order-free per-element map): q(-x) == -q(x),
        // identical scales.
        let qn = QuantizedKv::quantize(&flip_sign(&x));
        prop_assert_eq!(qn.scales, q.scales);
        for (a, b) in q.q.iter().zip(&qn.q) {
            prop_assert_eq!(*a, -*b);
        }
        Ok(())
    });
}

#[test]
fn prop_int4_roundtrip_bounded_deterministic() {
    prop::check("int4-roundtrip", 0x4A47, 150, |rng: &mut Rng| {
        // Even head_dim (nibble packing); lengths sweep the group
        // boundary: 1, 31, 32, 33, 63, 64, 65 all reachable.
        let len = *rng.pick(&[1usize, 2, 7, 31, 32, 33, 63, 64, 65]);
        let dims = [rng.range(1, 4), len, rng.range(1, 4), 2 * rng.range(1, 7)];
        let x = random_kv(rng, &dims);
        let q = QuantizedKv4::quantize(&x);
        let (layers, _, heads, hd) = (dims[0], dims[1], dims[2], dims[3]);
        let row = heads * hd;
        let groups = len.div_ceil(I4_GROUP);
        prop_assert_eq!(q.groups(), groups);
        prop_assert_eq!(q.packed.len() * 2, x.len());
        prop_assert_eq!(q.scales.len(), layers * groups * row);
        prop_assert_eq!(q.size_bytes(), q.packed.len() + q.scales.len() * 4);
        // Per-element error bound against the per-group scale.
        let deq = q.dequantize();
        for l in 0..layers {
            for t in 0..len {
                let srow = &q.scales[(l * groups + t / I4_GROUP) * row..][..row];
                for c in 0..row {
                    let i = (l * len + t) * row + c;
                    let e = (x.data()[i] - deq.data()[i]).abs();
                    prop_assert!(
                        e <= 0.5001 * srow[c],
                        "elem {i}: err {e} > scale/2 ({})",
                        srow[c]
                    );
                }
            }
        }
        // Determinism + bitwise-exact inline sums.
        let q2 = QuantizedKv4::quantize(&x);
        prop_assert_eq!(q.packed, q2.packed);
        prop_assert_eq!(q.scales, q2.scales);
        let (err, refsq) = q.sq_err_vs(&x);
        prop_assert!(q.sq_err == err, "inline sq_err {} != recomputed {err}", q.sq_err);
        prop_assert!(q.sq_ref == refsq, "inline sq_ref {} != recomputed {refsq}", q.sq_ref);
        // Symmetry: negating the input negates every reconstructed
        // element (codes are clamped symmetrically to ±7).
        let qn = QuantizedKv4::quantize(&flip_sign(&x));
        prop_assert_eq!(qn.scales, q.scales);
        let dn = qn.dequantize();
        for (a, b) in deq.data().iter().zip(dn.data()) {
            prop_assert_eq!(*a, -*b);
        }
        Ok(())
    });
}

/// All-zero tensors are exact on both tiers: zero scales, zero codes,
/// zero inline error.
#[test]
fn all_zero_tensors_roundtrip_exactly() {
    let dims = [2usize, 33, 2, 4];
    let x: TensorF = Tensor::zeros(&dims);
    let q8 = QuantizedKv::quantize(&x);
    assert!(q8.scales.iter().all(|&s| s == 0.0));
    assert!(q8.q.iter().all(|&c| c == 0));
    assert_eq!(q8.dequantize(), x);
    assert_eq!(q8.sq_err, 0.0);
    let q4 = QuantizedKv4::quantize(&x);
    assert!(q4.scales.iter().all(|&s| s == 0.0));
    assert!(q4.packed.iter().all(|&b| b == 0));
    assert_eq!(q4.dequantize(), x);
    assert_eq!(q4.sq_err, 0.0);
}

/// Group isolation: bumping a token in group 1 must not change group
/// 0's scales or codes (the whole point of group-wise scales).
#[test]
fn int4_groups_are_isolated() {
    let mut rng = Rng::new(0x150);
    let dims = [1usize, I4_GROUP + 5, 1, 4];
    let n: usize = dims.iter().product();
    let base: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let a = QuantizedKv4::quantize(&Tensor::from_vec(&dims, base.clone()));
    let mut bumped = base;
    // Token I4_GROUP + 1 lives in group 1; make it the dominant amax.
    let row = 4;
    bumped[(I4_GROUP + 1) * row..(I4_GROUP + 2) * row].fill(1000.0);
    let b = QuantizedKv4::quantize(&Tensor::from_vec(&dims, bumped));
    assert_eq!(
        &a.scales[..row],
        &b.scales[..row],
        "group-0 scales moved when group 1 changed"
    );
    assert_eq!(
        &a.packed[..I4_GROUP * row / 2],
        &b.packed[..I4_GROUP * row / 2],
        "group-0 codes moved when group 1 changed"
    );
    assert_ne!(&a.scales[row..], &b.scales[row..], "group-1 scales must move");
}

/// ±extremes survive: scales stay finite, codes saturate at the rail,
/// and reconstruction is finite on both tiers.
#[test]
fn extreme_magnitudes_stay_finite() {
    let dims = [1usize, 2, 1, 4];
    let x = Tensor::from_vec(
        &dims,
        vec![1e37f32, -1e37, 1e-30, -1e-30, 5e36, -2e36, 0.0, 1e-37],
    );
    let q8 = QuantizedKv::quantize(&x);
    assert!(q8.scales.iter().all(|s| s.is_finite()));
    assert!(q8.dequantize().data().iter().all(|v| v.is_finite()));
    assert_eq!(q8.q[0], 127, "amax element must sit on the +rail");
    assert_eq!(q8.q[1], -127, "amax element must sit on the -rail");
    let q4 = QuantizedKv4::quantize(&x);
    assert!(q4.scales.iter().all(|s| s.is_finite()));
    assert!(q4.dequantize().data().iter().all(|v| v.is_finite()));
    assert!(q4.sq_err.is_finite() && q4.sq_ref.is_finite());
}

/// The shared scale formula: `channel_scales_for` is the single owner
/// for both qmax values, including zero columns.
#[test]
fn channel_scales_for_handles_zero_columns() {
    let b = [0.0f32, 3.0, 0.0, -6.0];
    let s8 = channel_scales_for(&b, 2, 2, 127.0);
    assert_eq!(s8, vec![0.0, 6.0 / 127.0]);
    let s4 = channel_scales_for(&b, 2, 2, 7.0);
    assert_eq!(s4, vec![0.0, 6.0 / 7.0]);
}
