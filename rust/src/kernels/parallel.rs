//! Fork/join parallelism for the compute kernels, dispatched to one
//! process-global **persistent worker pool**.
//!
//! Everything here partitions work into **contiguous, disjoint output
//! ranges**. Each range used to run on a freshly scoped thread
//! (`std::thread::scope`); it now runs as a task on
//! [`crate::util::pool::ThreadPool`] workers that are spawned once from
//! the `--threads`/`$BLOCK_ATTN_THREADS` budget and live for the
//! process. A parallel region costs a queue push + condvar wake instead
//! of an OS thread spawn/join — the difference that makes decode-sized
//! ops (one dispatch per layer per generated token) worth splitting at
//! all. The calling thread always executes the first chunk itself and
//! then runs its region's still-queued chunks while it waits
//! ([`ThreadPool::run_scoped`]), so regions complete at any worker
//! count and nested regions cannot deadlock.
//!
//! **Determinism is untouched by the pool.** Chunk layout is a pure
//! function of the thread *budget* ([`effective_threads`]) — never of
//! pool state, queue order, or which thread ends up running a chunk —
//! and every output element is produced by exactly one task performing
//! the same floating-point sequence it would under a single thread.
//! Results are therefore **bitwise identical** for any thread count —
//! the guarantee the coordinator's `--threads 1` vs `--threads 8`
//! parity tests pin down.
//!
//! Nested parallelism is *budgeted*, not forbidden: a worker inherits a
//! share of the global budget (its parent's budget divided by the
//! number of sibling workers), so a 2-item [`par_map`] on 8 threads
//! leaves each item 4 threads for its inner kernels instead of idling
//! six cores. Leaf row-splits ([`par_rows`]) hand their workers a
//! budget of 1 — re-splitting a leaf chunk is never useful.

use crate::util::pool::{PoolStats, ScopedJob, ThreadPool};
use std::cell::Cell;
use std::sync::OnceLock;

thread_local! {
    /// Thread budget assigned to this worker thread; `None` outside any
    /// parallel region (= use the global budget).
    static WORKER_BUDGET: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The process-global kernel worker pool, created on first parallel
/// region with one worker per budgeted thread. [`super::set_threads`]
/// grows it (via [`grow_pool`]) when the budget is raised later; it is
/// never shut down — workers idle on a condvar between regions.
static POOL: OnceLock<ThreadPool> = OnceLock::new();

pub(crate) fn global_pool() -> &'static ThreadPool {
    POOL.get_or_init(|| ThreadPool::new(super::num_threads()))
}

/// Grow the global pool to at least `n` workers if it already exists
/// (if it does not, first use will size it from the current budget).
pub(crate) fn grow_pool(n: usize) {
    if let Some(pool) = POOL.get() {
        pool.ensure_workers(n);
    }
}

/// Counters of the global pool: worker count, jobs executed, queue
/// depth high-water. All zero before the first parallel region (the
/// query never forces the pool into existence).
pub fn pool_stats() -> PoolStats {
    POOL.get().map(|p| p.stats()).unwrap_or_default()
}

/// Run `f` with this thread's budget set to `budget` (≥ 1); nested
/// parallel regions see that many [`effective_threads`].
///
/// The previous budget is restored by a drop guard, so it survives a
/// panic in `f`. That matters now that threads are persistent: the
/// pool contains a panicking job and reuses the thread, and a
/// help-while-wait caller outlives any panicking task it steals — a
/// leaked `Some(1)` would silently pin that thread serial forever.
pub(crate) fn with_budget<R>(budget: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            WORKER_BUDGET.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(WORKER_BUDGET.with(|c| c.replace(Some(budget.max(1)))));
    f()
}

/// Run `f` as a leaf worker (no nested parallelism).
pub(crate) fn enter_worker<R>(f: impl FnOnce() -> R) -> R {
    with_budget(1, f)
}

/// The thread budget visible at this call site: the configured width
/// ([`super::num_threads`]) at top level, or this worker's assigned
/// share inside a parallel region.
pub fn effective_threads() -> usize {
    WORKER_BUDGET.with(|c| c.get()).unwrap_or_else(super::num_threads)
}

/// Parallel-for over the rows of a flat row-major buffer.
///
/// `out` is split into contiguous chunks of whole rows (`row_len`
/// elements each); `f(row0, chunk)` receives the index of its first row
/// and a mutable view of its rows. Chunks smaller than `min_rows` are
/// not worth a dispatch and are merged; with one chunk (or inside a
/// worker) `f` runs inline on the caller's thread. With more, the first
/// chunk runs on the calling thread and the rest dispatch to the pool.
///
/// `f` must compute each row independently of which chunk it lands in —
/// that is what makes the split invisible to the results.
pub fn par_rows<T: Send>(
    out: &mut [T],
    row_len: usize,
    min_rows: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(row_len > 0, "row_len must be positive");
    debug_assert_eq!(out.len() % row_len, 0, "buffer is not whole rows");
    let rows = out.len() / row_len;
    if rows == 0 {
        return;
    }
    let chunks = effective_threads()
        .min(rows / min_rows.max(1))
        .max(1)
        .min(rows);
    if chunks <= 1 {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(chunks);
    let f = &f;
    let (head, mut rest) = out.split_at_mut(per * row_len);
    let mut tasks: Vec<ScopedJob<'_>> = Vec::with_capacity(chunks - 1);
    let mut row0 = per;
    while !rest.is_empty() {
        let take = per.min(rows - row0);
        let (chunk, tail) = rest.split_at_mut(take * row_len);
        rest = tail;
        let r0 = row0;
        row0 += take;
        tasks.push(Box::new(move || enter_worker(|| f(r0, chunk))));
    }
    global_pool().run_scoped(|| enter_worker(|| f(0, head)), tasks);
}

/// Parallel map over a slice, preserving order. Each worker handles a
/// contiguous range of items and inherits an even share of the thread
/// budget for its own nested kernels (8 threads over 2 items → 2
/// workers × 4 inner threads). With one effective thread (or a single
/// item) it degenerates to a plain serial map with the full budget
/// still available to inner parallelism. The first range runs on the
/// calling thread; the rest dispatch to the pool.
pub fn par_map<I: Sync, T: Send>(items: &[I], f: impl Fn(usize, &I) -> T + Sync) -> Vec<T> {
    let threads = effective_threads();
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let mut out: Vec<Option<T>> = (0..items.len()).map(|_| None).collect();
    let workers = threads.min(items.len());
    let per = items.len().div_ceil(workers);
    let inner_budget = threads / workers;
    let f = &f;
    let mut chunks = out.chunks_mut(per);
    let head = chunks.next().expect("at least one chunk");
    let tasks: Vec<ScopedJob<'_>> = chunks
        .enumerate()
        .map(|(ci, slots)| {
            let base = (ci + 1) * per;
            Box::new(move || {
                with_budget(inner_budget, || {
                    for (j, slot) in slots.iter_mut().enumerate() {
                        *slot = Some(f(base + j, &items[base + j]));
                    }
                })
            }) as ScopedJob<'_>
        })
        .collect();
    global_pool().run_scoped(
        || {
            with_budget(inner_budget, || {
                for (j, slot) in head.iter_mut().enumerate() {
                    *slot = Some(f(j, &items[j]));
                }
            })
        },
        tasks,
    );
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_rows_touches_every_row_once() {
        let rows = 37;
        let row_len = 5;
        let mut buf = vec![0u32; rows * row_len];
        par_rows(&mut buf, row_len, 1, |row0, chunk| {
            for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v += (row0 + i) as u32 + 1;
                }
            }
        });
        for (i, row) in buf.chunks(row_len).enumerate() {
            assert!(row.iter().all(|&v| v == i as u32 + 1), "row {i} wrong: {row:?}");
        }
    }

    #[test]
    fn par_rows_min_rows_merges_small_work() {
        // 4 rows with min_rows=4 must run as one inline chunk.
        let mut buf = vec![0u8; 4 * 3];
        let calls = AtomicUsize::new(0);
        par_rows(&mut buf, 3, 4, |_, chunk| {
            calls.fetch_add(1, Ordering::SeqCst);
            assert_eq!(chunk.len(), 12);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn nested_budgets_are_scoped() {
        // Leaf workers see a budget of 1; budgeted workers see their
        // share; both restore the previous budget on exit.
        assert!(effective_threads() >= 1);
        assert_eq!(enter_worker(effective_threads), 1);
        assert_eq!(with_budget(3, effective_threads), 3);
        let nested = with_budget(4, || (effective_threads(), enter_worker(effective_threads)));
        assert_eq!(nested, (4, 1));
        assert!(effective_threads() >= 1, "budget leaked out of the region");
    }

    #[test]
    fn par_map_preserves_order_and_indices() {
        let items: Vec<usize> = (0..23).collect();
        let out = par_map(&items, |i, &it| {
            assert_eq!(i, it);
            it * 3
        });
        assert_eq!(out, (0..23).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        let e: Vec<u8> = vec![];
        assert!(par_map(&e, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u8], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn regions_reuse_the_persistent_pool() {
        let _g = crate::kernels::TEST_THREADS_LOCK.lock().unwrap();
        let prev = crate::kernels::num_threads();
        crate::kernels::set_threads(4);
        let before = pool_stats().jobs_executed;
        let mut buf = vec![0u64; 64];
        for _ in 0..10 {
            par_rows(&mut buf, 1, 1, |r0, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v += (r0 + i) as u64;
                }
            });
        }
        crate::kernels::set_threads(prev);
        let after = pool_stats();
        assert!(
            after.jobs_executed > before,
            "parallel regions did not dispatch to the pool"
        );
        assert!(after.workers >= 4, "set_threads(4) did not grow the pool");
    }
}
