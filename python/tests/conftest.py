import os
import sys

# Make the `compile` package importable regardless of pytest rootdir.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

jax.config.update("jax_platform_name", "cpu")
