"""L2 model invariants — the correctness core of Block-Attention:

1. Block prefill at local positions + RoPE re-encode reproduces exactly
   the KV a block-masked *global* forward would produce (the paper's
   §2.3 equivalence — makes cross-prompt cache reuse lossless).
2. Single-block degenerate case: block path == full-attention path.
3. Decode after prefill == prefill of the extended sequence.
4. train_step reduces loss and keeps both attention modes trainable.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig
from compile.kernels import ref
from compile.kernels import rope as rope_kernel

MICRO = ModelConfig(
    name="micro",
    vocab=61,
    d_model=32,
    layers=2,
    heads=2,
    kv_heads=1,
    d_ff=48,
    max_len=256,
    attn_impl="pallas",
    full_lengths=(128,),
    block_lengths=(64,),
    final_ctx=(128,),
    final_q=64,
    decode_ctx=(192,),
    train_batch=2,
    train_len=64,
)

MICRO_JNP = dataclasses.replace(MICRO, name="micro_jnp", attn_impl="jnp")


@pytest.fixture(scope="module")
def params():
    return [jnp.asarray(a) for a in model.init_params(MICRO, seed=7)]


def tokens_of(rng, n):
    return jnp.asarray(rng.integers(0, MICRO.vocab, n), jnp.int32)


def test_param_specs_cover_init(params):
    specs = model.param_specs(MICRO)
    assert len(specs) == len(params) == 11
    for (name, shape), p in zip(specs, params):
        assert tuple(shape) == p.shape, name


def test_prefill_full_shapes(params):
    rng = np.random.default_rng(0)
    toks = tokens_of(rng, 128)
    logits, ks, vs = model.prefill_full(MICRO, toks, jnp.int32(100), *params)
    assert logits.shape == (MICRO.vocab,)
    assert ks.shape == (2, 128, 1, 16)
    assert vs.shape == (2, 128, 1, 16)


def test_pallas_and_jnp_impls_agree(params):
    rng = np.random.default_rng(1)
    toks = tokens_of(rng, 128)
    la, ka, va = model.prefill_full(MICRO, toks, jnp.int32(128), *params)
    lb, kb, vb = model.prefill_full(MICRO_JNP, toks, jnp.int32(128), *params)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-3)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(kb), atol=2e-4)


def test_prefill_full_length_mask(params):
    # Padding beyond `length` must not change the answer.
    rng = np.random.default_rng(2)
    toks = tokens_of(rng, 128)
    l1, _, _ = model.prefill_full(MICRO, toks, jnp.int32(80), *params)
    toks2 = toks.at[80:].set(3)
    l2, _, _ = model.prefill_full(MICRO, toks2, jnp.int32(80), *params)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def _block_path_logits(cfg, params, blocks, query, C):
    """Run the full Block-attention inference pipeline in python:
    per-block prefill at local positions → re-encode to global offsets →
    final-block prefill. Returns (last_logits, ctx_len)."""
    N, K, hd = cfg.layers, cfg.kv_heads, cfg.head_dim
    past_k = jnp.zeros((N, C, K, hd), jnp.float32)
    past_v = jnp.zeros((N, C, K, hd), jnp.float32)
    off = 0
    for b in blocks:
        Lb = b.shape[0]
        ks, vs = model.prefill_block(cfg, b, jnp.int32(Lb), *params)
        ks = rope_kernel.reencode_k(
            ks, jnp.array([off], jnp.int32), theta=cfg.rope_theta
        )
        past_k = past_k.at[:, off : off + Lb].set(ks)
        past_v = past_v.at[:, off : off + Lb].set(vs)
        off += Lb
    logits, _, _ = model.prefill_final(
        cfg,
        query,
        jnp.int32(query.shape[0]),
        past_k,
        past_v,
        jnp.int32(off),
        jnp.int32(off),
        *params,
    )
    return logits, off


def test_single_block_path_equals_full(params):
    """One context block + query via the block path == vanilla prefill.

    With a single block there is no cross-block independence, so the two
    attention modes define the identical function (no fine-tuning needed)
    — this pins the plumbing: local-position prefill, re-encode at
    delta=0..L, context assembly and final-block positions."""
    rng = np.random.default_rng(3)
    block = tokens_of(rng, 64)
    query = tokens_of(rng, 64)
    logits_block, off = _block_path_logits(MICRO, params, [block], query, C=128)
    full = jnp.concatenate([block, query])
    logits_full, _, _ = model.prefill_full(MICRO, full, jnp.int32(128), *params)
    np.testing.assert_allclose(
        np.asarray(logits_block), np.asarray(logits_full), atol=3e-3
    )


def test_multi_block_path_equals_segment_masked_forward(params):
    """Two blocks + query via the serving pipeline == the *training-time*
    segment-masked forward (Figure 1 right). This is the train/infer
    consistency the paper's block fine-tune relies on."""
    rng = np.random.default_rng(4)
    b1 = tokens_of(rng, 64)
    b2 = tokens_of(rng, 64)
    q = tokens_of(rng, 64)
    logits_block, _ = _block_path_logits(MICRO, params, [b1, b2], q, C=128)

    toks = jnp.concatenate([b1, b2, q])[None]  # (1, 192)
    seg = jnp.concatenate(
        [jnp.zeros(64, jnp.int32), jnp.ones(64, jnp.int32), jnp.full(64, 2, jnp.int32)]
    )[None]
    logits_all = model._train_forward(MICRO, tuple(params), toks, seg)
    np.testing.assert_allclose(
        np.asarray(logits_block), np.asarray(logits_all[0, -1]), atol=3e-3
    )


def test_decode_consistency_with_prefill(params):
    """Greedy decode step after a full prefill must equal prefilling the
    extended sequence."""
    rng = np.random.default_rng(5)
    toks = tokens_of(rng, 128)
    L = 100
    logits, ks, vs = model.prefill_full(MICRO, toks, jnp.int32(L), *params)
    nxt = jnp.argmax(logits).astype(jnp.int32)

    C = 192
    kc = jnp.zeros((2, C, 1, 16), jnp.float32).at[:, :128].set(ks)
    vc = jnp.zeros((2, C, 1, 16), jnp.float32).at[:, :128].set(vs)
    # Note the cache holds only the first L valid tokens.
    kc = kc.at[:, L:].set(0.0)
    vc = vc.at[:, L:].set(0.0)
    dl, _, _ = model.decode_step(MICRO, nxt, jnp.int32(L), kc, vc, *params)

    ext = toks.at[L].set(nxt)
    el, _, _ = model.prefill_full(MICRO, ext, jnp.int32(L + 1), *params)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(el), atol=3e-3)


def test_segment_mask_rules():
    seg = jnp.asarray([[0, 0, 1, 1, 2, 2]], jnp.int32)
    m = np.asarray(model.segment_attention_mask(seg))[0]
    # Causal.
    assert not m[0, 1]
    # Within-block attends.
    assert m[1, 0] and m[3, 2]
    # Cross-block (non-final) blocked.
    assert not m[2, 0] and not m[3, 1]
    # Final segment attends everything before it.
    assert m[4, 0] and m[4, 2] and m[5, 1] and m[5, 4]
    # Uniform ids degenerate to plain causal.
    m2 = np.asarray(model.segment_attention_mask(jnp.zeros((1, 4), jnp.int32)))[0]
    assert m2[3, 0] and m2[2, 1] and not m2[0, 3]


def test_train_step_reduces_loss(params):
    rng = np.random.default_rng(6)
    B, L = 2, 64
    toks = jnp.asarray(rng.integers(0, 8, (B, L)), jnp.int32)  # low-entropy data
    seg = jnp.concatenate(
        [jnp.zeros((B, L // 2), jnp.int32), jnp.ones((B, L // 2), jnp.int32)], axis=1
    )
    mask = jnp.ones((B, L), jnp.float32)
    n = len(params)
    state = tuple(params) + tuple(jnp.zeros_like(p) for p in params) * 2
    step_fn = jax.jit(lambda s, st: model.train_step(MICRO_JNP, s, jnp.float32(3e-3), toks, seg, mask, *st))
    losses = []
    for i in range(8):
        out = step_fn(jnp.int32(i), state)
        losses.append(float(out[0]))
        state = out[1:]
    assert losses[-1] < losses[0] - 0.2, losses


def test_train_loss_respects_mask(params):
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, MICRO.vocab, (1, 64)), jnp.int32)
    seg = jnp.zeros((1, 64), jnp.int32)
    full = jnp.ones((1, 64), jnp.float32)
    half = full.at[:, :32].set(0.0)
    l_full = model.train_loss(MICRO_JNP, tuple(params), toks, seg, full)
    l_half = model.train_loss(MICRO_JNP, tuple(params), toks, seg, half)
    assert not np.isnan(float(l_full)) and not np.isnan(float(l_half))
    assert abs(float(l_full) - float(l_half)) > 1e-6
