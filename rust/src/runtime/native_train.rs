//! Native block fine-tune step (paper §2.4): segment-masked forward,
//! manual reverse-mode backprop, Adam with global-norm clipping.
//!
//! Semantics mirror `python/compile/model.py::train_step` exactly:
//!
//! * The attention mask is derived from per-token segment ids
//!   (Figure 1 right): `mask[t, j] = causal && (seg[j] == seg[t] ||
//!   seg[t] == max(seg))`. A row whose ids are all equal degenerates to
//!   plain causal attention, so one code path serves both halves of the
//!   paper's dual-mode training.
//! * Positions are global `0..L` (cached local-position keys are
//!   rotated at serving time — the equivalence Eq. 3 rests on).
//! * Loss is next-token cross-entropy over tokens whose `loss_mask` is
//!   set, normalized by the total masked weight of the batch.
//! * The optimizer is Adam(0.9, 0.999, 1e-8) with global-norm clip 1.0
//!   and bias correction, matching the AOT `train_step` artifact.
//!
//! All contractions run on the [`crate::kernels`] layer: tiled GEMMs for
//! the projection/weight gradients (row-parallel), and the attention
//! forward/backward split into head-parallel and row-parallel passes
//! whose per-element reduction order matches the single-threaded loops
//! exactly. The step is additionally **batch-parallel**: rows fan out
//! over the persistent worker pool via [`crate::kernels::par_map`]
//! (each row computing a private gradient set), and the per-row grads
//! are reduced in ascending row order on the calling thread — a fixed
//! reduction sequence at every thread budget, so gradients stay
//! bitwise identical at every `--threads` setting.
//!
//! Gradients are derived by hand; the correctness anchor is the
//! directional-derivative check against finite differences in the tests
//! below.

use super::native::{
    Weights, N_PARAMS, P_EMBED, P_FINAL_NORM, P_LN1, P_LN2, P_WD, P_WG, P_WK, P_WO, P_WQ, P_WU,
    P_WV,
};
use crate::config::ModelConfig;
use crate::kernels::{
    axpy, dot, gemm_nn, gemm_nn_acc, gemm_nt_acc, gemm_tn_acc, par_rows, rms_norm_rows, sigmoid,
    silu, softmax_inplace,
};
use crate::rope::RopeTable;
use crate::tensor::{Tensor, TensorF, TensorI};
use anyhow::{ensure, Result};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const CLIP_NORM: f64 = 1.0;

/// Everything the backward pass needs from one row's forward pass.
struct LayerCache {
    rstd1: Vec<f32>,
    h1: Vec<f32>,
    /// Post-RoPE projections.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention probabilities, `(heads, L, L)`; masked entries are 0.
    probs: Vec<f32>,
    o: Vec<f32>,
    xmid: Vec<f32>,
    rstd2: Vec<f32>,
    h2: Vec<f32>,
    gpre: Vec<f32>,
    u: Vec<f32>,
    m: Vec<f32>,
}

struct RowCache {
    /// `xs[n]` is the input to layer n; `xs[layers]` the final stream.
    xs: Vec<Vec<f32>>,
    layers: Vec<LayerCache>,
    rstdf: Vec<f32>,
    hf: Vec<f32>,
    logits: Vec<f32>,
}

/// Segment-mask predicate (python `segment_attention_mask`).
#[inline]
fn attends(seg: &[i32], max_seg: i32, t: usize, j: usize) -> bool {
    j <= t && (seg[j] == seg[t] || seg[t] == max_seg)
}

/// Serial-below chunk floors for the attention passes, shared by the
/// forward and backward so the chunking heuristics cannot drift apart:
/// `(head_min_rows, row_min_rows)` for head-parallel passes (~½·L²·hd
/// mul-adds per head) and query-row-parallel passes respectively.
fn attn_pass_floors(l: usize, nh: usize, hd: usize) -> (usize, usize) {
    let head = ((1 << 15) / (l * l * hd).max(1)).max(1);
    let row = ((1 << 14) / (nh * l * hd / 2).max(1)).max(1);
    (head, row)
}

fn row_forward(
    cfg: &ModelConfig,
    rope: &RopeTable,
    w: &Weights<'_>,
    tokens: &[i32],
    seg: &[i32],
) -> RowCache {
    let (dm, nh, kvh, hd, ff) = (cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.d_ff);
    let l = tokens.len();
    let rep = nh / kvh;
    let scale = 1.0 / (hd as f32).sqrt();
    let max_seg = seg.iter().copied().max().unwrap_or(0);

    let mut x = vec![0.0f32; l * dm];
    for (t, &tok) in tokens.iter().enumerate() {
        x[t * dm..(t + 1) * dm]
            .copy_from_slice(&w.embed[tok as usize * dm..(tok as usize + 1) * dm]);
    }
    let mut xs = vec![x];
    let mut layers = Vec::with_capacity(cfg.layers);

    let (head_min_rows, row_min_rows) = attn_pass_floors(l, nh, hd);

    for n in 0..cfg.layers {
        let lw = w.layer(n);
        let x_in = xs[n].clone();

        let mut h1 = vec![0.0f32; l * dm];
        let mut rstd1 = vec![0.0f32; l];
        rms_norm_rows(&x_in, lw.ln1, cfg.norm_eps, l, dm, &mut h1, &mut rstd1);
        let mut q = vec![0.0f32; l * nh * hd];
        let mut k = vec![0.0f32; l * kvh * hd];
        let mut v = vec![0.0f32; l * kvh * hd];
        gemm_nn(&h1, lw.wq, l, dm, nh * hd, &mut q);
        gemm_nn(&h1, lw.wk, l, dm, kvh * hd, &mut k);
        gemm_nn(&h1, lw.wv, l, dm, kvh * hd, &mut v);
        for t in 0..l {
            let pos = t as i64;
            for h in 0..nh {
                rope.rotate_head(&mut q[(t * nh + h) * hd..(t * nh + h + 1) * hd], pos);
            }
            for h in 0..kvh {
                rope.rotate_head(&mut k[(t * kvh + h) * hd..(t * kvh + h + 1) * hd], pos);
            }
        }

        // Attention probabilities, parallel over heads (each head's
        // `(L, L)` prob block is contiguous).
        let mut probs = vec![0.0f32; nh * l * l];
        {
            let (q_r, k_r) = (&q, &k);
            par_rows(&mut probs, l * l, head_min_rows, |h0, chunk| {
                let mut scores = vec![0.0f32; l];
                let mut idx = vec![0usize; l];
                for (hi, p_h) in chunk.chunks_mut(l * l).enumerate() {
                    let h = h0 + hi;
                    let kh = h / rep;
                    for t in 0..l {
                        let qv = &q_r[(t * nh + h) * hd..(t * nh + h + 1) * hd];
                        let mut cnt = 0;
                        for j in 0..=t {
                            if attends(seg, max_seg, t, j) {
                                let kr = &k_r[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd];
                                scores[cnt] = dot(qv, kr) * scale;
                                idx[cnt] = j;
                                cnt += 1;
                            }
                        }
                        softmax_inplace(&mut scores[..cnt]);
                        let p_row = &mut p_h[t * l..(t + 1) * l];
                        for c in 0..cnt {
                            p_row[idx[c]] = scores[c];
                        }
                    }
                }
            });
        }
        // Attention output, parallel over query rows; the unmasked-j
        // iteration order matches the fused loop it replaced.
        let mut o = vec![0.0f32; l * nh * hd];
        {
            let (probs_r, v_r) = (&probs, &v);
            par_rows(&mut o, nh * hd, row_min_rows, |t0, chunk| {
                for (ti, orow) in chunk.chunks_mut(nh * hd).enumerate() {
                    let t = t0 + ti;
                    for h in 0..nh {
                        let kh = h / rep;
                        let p_row = &probs_r[(h * l + t) * l..(h * l + t + 1) * l];
                        let ov = &mut orow[h * hd..(h + 1) * hd];
                        for j in 0..=t {
                            if attends(seg, max_seg, t, j) {
                                let vr = &v_r[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd];
                                axpy(p_row[j], vr, ov);
                            }
                        }
                    }
                }
            });
        }

        let mut xmid = x_in.clone();
        gemm_nn_acc(&o, lw.wo, l, nh * hd, dm, &mut xmid);

        let mut h2 = vec![0.0f32; l * dm];
        let mut rstd2 = vec![0.0f32; l];
        rms_norm_rows(&xmid, lw.ln2, cfg.norm_eps, l, dm, &mut h2, &mut rstd2);
        let mut gpre = vec![0.0f32; l * ff];
        let mut u = vec![0.0f32; l * ff];
        gemm_nn(&h2, lw.wg, l, dm, ff, &mut gpre);
        gemm_nn(&h2, lw.wu, l, dm, ff, &mut u);
        let mut m = vec![0.0f32; l * ff];
        for i in 0..l * ff {
            m[i] = silu(gpre[i]) * u[i];
        }
        let mut x_out = xmid.clone();
        gemm_nn_acc(&m, lw.wd, l, ff, dm, &mut x_out);

        layers.push(LayerCache {
            rstd1,
            h1,
            q,
            k,
            v,
            probs,
            o,
            xmid,
            rstd2,
            h2,
            gpre,
            u,
            m,
        });
        xs.push(x_out);
    }

    let mut hf = vec![0.0f32; l * dm];
    let mut rstdf = vec![0.0f32; l];
    rms_norm_rows(&xs[cfg.layers], w.final_norm, cfg.norm_eps, l, dm, &mut hf, &mut rstdf);
    let mut logits = vec![0.0f32; l * cfg.vocab];
    gemm_nt_acc(&hf, w.embed, l, dm, cfg.vocab, &mut logits);

    RowCache { xs, layers, rstdf, hf, logits }
}

/// RMSNorm backward: accumulates into `dx_acc` and `gw`.
fn rms_backward(
    x: &[f32],
    w: &[f32],
    rstd: &[f32],
    dy: &[f32],
    l: usize,
    d: usize,
    dx_acc: &mut [f32],
    gw: &mut [f32],
) {
    for t in 0..l {
        let xr = &x[t * d..(t + 1) * d];
        let dyr = &dy[t * d..(t + 1) * d];
        let r = rstd[t];
        let mut proj = 0.0f64;
        for i in 0..d {
            proj += (dyr[i] * w[i]) as f64 * xr[i] as f64;
            gw[i] += dyr[i] * xr[i] * r;
        }
        let c = (proj as f32) * r * r / d as f32;
        let dxr = &mut dx_acc[t * d..(t + 1) * d];
        for i in 0..d {
            dxr[i] += r * (dyr[i] * w[i] - xr[i] * c);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn row_backward(
    cfg: &ModelConfig,
    rope: &RopeTable,
    w: &Weights<'_>,
    tokens: &[i32],
    cache: &RowCache,
    dlogits: &[f32],
    grads: &mut [TensorF],
) {
    let (dm, nh, kvh, hd, ff) = (cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.d_ff);
    let l = tokens.len();
    let rep = nh / kvh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (head_min_rows, row_min_rows) = attn_pass_floors(l, nh, hd);

    // Tied head: logits = hf @ embedᵀ.
    let mut dhf = vec![0.0f32; l * dm];
    gemm_nn_acc(dlogits, w.embed, l, cfg.vocab, dm, &mut dhf);
    gemm_tn_acc(dlogits, &cache.hf, l, cfg.vocab, dm, grads[P_EMBED].data_mut());

    let mut dx = vec![0.0f32; l * dm];
    rms_backward(
        &cache.xs[cfg.layers],
        w.final_norm,
        &cache.rstdf,
        &dhf,
        l,
        dm,
        &mut dx,
        grads[P_FINAL_NORM].data_mut(),
    );

    for n in (0..cfg.layers).rev() {
        let lw = w.layer(n);
        let c = &cache.layers[n];

        // MLP: x_out = x_mid + (silu(h2@wg) ⊙ (h2@wu)) @ wd.
        let mut dmvec = vec![0.0f32; l * ff];
        gemm_nt_acc(&dx, lw.wd, l, dm, ff, &mut dmvec);
        gemm_tn_acc(&c.m, &dx, l, ff, dm, grads[P_WD].axis0_mut(n));
        let mut dg = vec![0.0f32; l * ff];
        let mut du = vec![0.0f32; l * ff];
        for i in 0..l * ff {
            let g = c.gpre[i];
            let s = sigmoid(g);
            du[i] = dmvec[i] * g * s;
            dg[i] = dmvec[i] * c.u[i] * s * (1.0 + g * (1.0 - s));
        }
        let mut dh2 = vec![0.0f32; l * dm];
        gemm_nt_acc(&dg, lw.wg, l, ff, dm, &mut dh2);
        gemm_nt_acc(&du, lw.wu, l, ff, dm, &mut dh2);
        gemm_tn_acc(&c.h2, &dg, l, dm, ff, grads[P_WG].axis0_mut(n));
        gemm_tn_acc(&c.h2, &du, l, dm, ff, grads[P_WU].axis0_mut(n));
        // Residual: dx (= dL/dx_out) flows to x_mid directly plus
        // through the norm.
        rms_backward(&c.xmid, lw.ln2, &c.rstd2, &dh2, l, dm, &mut dx, grads[P_LN2].axis0_mut(n));

        // Attention: x_mid = x_in + o @ wo.
        let mut do_ = vec![0.0f32; l * nh * hd];
        gemm_nt_acc(&dx, lw.wo, l, dm, nh * hd, &mut do_);
        gemm_tn_acc(&c.o, &dx, l, nh * hd, dm, grads[P_WO].axis0_mut(n));

        // Softmax/score backward in three deterministic passes.
        //
        // Pass A (parallel over heads): dp[h,t,j] = ⟨do_t, v_j⟩ for
        // unmasked entries, and psum[h,t] = Σ_j p·dp. Buffer row per
        // head = [dp (L·L) | psum (L)].
        let dp_row = l * l + l;
        let mut dp_all = vec![0.0f32; nh * dp_row];
        {
            let (probs_r, do_r, v_r) = (&c.probs, &do_, &c.v);
            par_rows(&mut dp_all, dp_row, head_min_rows, |h0, chunk| {
                for (hi, row) in chunk.chunks_mut(dp_row).enumerate() {
                    let h = h0 + hi;
                    let kh = h / rep;
                    let (dp_h, psum_h) = row.split_at_mut(l * l);
                    for t in 0..l {
                        let p_row = &probs_r[(h * l + t) * l..(h * l + t + 1) * l];
                        let do_t = &do_r[(t * nh + h) * hd..(t * nh + h + 1) * hd];
                        let mut psum = 0.0f32;
                        for j in 0..=t {
                            let p = p_row[j];
                            if p != 0.0 {
                                let vr = &v_r[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd];
                                let d = dot(do_t, vr);
                                dp_h[t * l + j] = d;
                                psum += p * d;
                            }
                        }
                        psum_h[t] = psum;
                    }
                }
            });
        }

        // Pass B (parallel over query rows): dq[t,h] = Σ_j ds·k_j.
        let mut dq = vec![0.0f32; l * nh * hd];
        {
            let (probs_r, k_r, dp_r) = (&c.probs, &c.k, &dp_all);
            par_rows(&mut dq, nh * hd, row_min_rows, |t0, chunk| {
                for (ti, dqrow) in chunk.chunks_mut(nh * hd).enumerate() {
                    let t = t0 + ti;
                    for h in 0..nh {
                        let kh = h / rep;
                        let p_row = &probs_r[(h * l + t) * l..(h * l + t + 1) * l];
                        let dp_h = &dp_r[h * dp_row..h * dp_row + l * l];
                        let psum = dp_r[h * dp_row + l * l + t];
                        let dq_t = &mut dqrow[h * hd..(h + 1) * hd];
                        for j in 0..=t {
                            let p = p_row[j];
                            if p != 0.0 {
                                let ds = p * (dp_h[t * l + j] - psum) * scale;
                                let kr = &k_r[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd];
                                axpy(ds, kr, dq_t);
                            }
                        }
                    }
                }
            });
        }

        // Pass C (parallel over kv-head groups): dk/dv accumulate over
        // every (h, t) in the group — reduction order (h asc, t asc)
        // matches the fused loop. Written head-major per group, then
        // scattered to the token-major layout the projections expect.
        let mut dkv = vec![0.0f32; kvh * 2 * l * hd];
        {
            let (probs_r, do_r, q_r, dp_r) = (&c.probs, &do_, &c.q, &dp_all);
            par_rows(&mut dkv, 2 * l * hd, head_min_rows, |kh0, chunk| {
                for (ki, row) in chunk.chunks_mut(2 * l * hd).enumerate() {
                    let kh = kh0 + ki;
                    let (dk_h, dv_h) = row.split_at_mut(l * hd);
                    for h in kh * rep..(kh + 1) * rep {
                        let dp_base = h * dp_row;
                        for t in 0..l {
                            let p_row = &probs_r[(h * l + t) * l..(h * l + t + 1) * l];
                            let do_t = &do_r[(t * nh + h) * hd..(t * nh + h + 1) * hd];
                            let q_t = &q_r[(t * nh + h) * hd..(t * nh + h + 1) * hd];
                            let psum = dp_r[dp_base + l * l + t];
                            for j in 0..=t {
                                let p = p_row[j];
                                if p != 0.0 {
                                    let ds = p * (dp_r[dp_base + t * l + j] - psum) * scale;
                                    axpy(p, do_t, &mut dv_h[j * hd..(j + 1) * hd]);
                                    axpy(ds, q_t, &mut dk_h[j * hd..(j + 1) * hd]);
                                }
                            }
                        }
                    }
                }
            });
        }
        let mut dk = vec![0.0f32; l * kvh * hd];
        let mut dv = vec![0.0f32; l * kvh * hd];
        for kh in 0..kvh {
            let base = kh * 2 * l * hd;
            for j in 0..l {
                let dst = (j * kvh + kh) * hd;
                dk[dst..dst + hd].copy_from_slice(&dkv[base + j * hd..base + (j + 1) * hd]);
                dv[dst..dst + hd]
                    .copy_from_slice(&dkv[base + (l + j) * hd..base + (l + j + 1) * hd]);
            }
        }

        // RoPE is an orthogonal rotation: its adjoint is rotation by -pos.
        for t in 0..l {
            let pos = t as i64;
            for h in 0..nh {
                rope.rotate_head(&mut dq[(t * nh + h) * hd..(t * nh + h + 1) * hd], -pos);
            }
            for h in 0..kvh {
                rope.rotate_head(&mut dk[(t * kvh + h) * hd..(t * kvh + h + 1) * hd], -pos);
            }
        }

        let mut dh1 = vec![0.0f32; l * dm];
        gemm_nt_acc(&dq, lw.wq, l, nh * hd, dm, &mut dh1);
        gemm_nt_acc(&dk, lw.wk, l, kvh * hd, dm, &mut dh1);
        gemm_nt_acc(&dv, lw.wv, l, kvh * hd, dm, &mut dh1);
        gemm_tn_acc(&c.h1, &dq, l, dm, nh * hd, grads[P_WQ].axis0_mut(n));
        gemm_tn_acc(&c.h1, &dk, l, dm, kvh * hd, grads[P_WK].axis0_mut(n));
        gemm_tn_acc(&c.h1, &dv, l, dm, kvh * hd, grads[P_WV].axis0_mut(n));
        rms_backward(
            &cache.xs[n],
            lw.ln1,
            &c.rstd1,
            &dh1,
            l,
            dm,
            &mut dx,
            grads[P_LN1].axis0_mut(n),
        );
    }

    // Input embedding lookup.
    let gembed = grads[P_EMBED].data_mut();
    for (t, &tok) in tokens.iter().enumerate() {
        axpy(1.0, &dx[t * dm..(t + 1) * dm], &mut gembed[tok as usize * dm..(tok as usize + 1) * dm]);
    }
}

/// Mean masked next-token CE loss and parameter gradients for one
/// packed `(B, L)` batch.
pub(crate) fn loss_and_grads(
    cfg: &ModelConfig,
    rope: &RopeTable,
    params: &[TensorF],
    tokens: &TensorI,
    seg: &TensorI,
    loss_mask: &TensorF,
) -> Result<(f32, Vec<TensorF>)> {
    ensure!(tokens.rank() == 2, "tokens must be (B, L), got {:?}", tokens.dims());
    ensure!(
        seg.dims() == tokens.dims() && loss_mask.dims() == tokens.dims(),
        "tokens/seg/loss_mask shape mismatch: {:?} {:?} {:?}",
        tokens.dims(),
        seg.dims(),
        loss_mask.dims()
    );
    let (b, l) = (tokens.dims()[0], tokens.dims()[1]);
    ensure!(l >= 2, "sequence length {l} too short for next-token loss");
    for &t in tokens.data() {
        ensure!(
            t >= 0 && (t as usize) < cfg.vocab,
            "token id {t} out of vocab range 0..{}",
            cfg.vocab
        );
    }
    ensure!(params.len() == N_PARAMS, "expected {N_PARAMS} parameter tensors");
    let w = Weights::split(params);
    let vocab = cfg.vocab;

    // Total masked weight of the batch (targets are positions 1..L).
    let mut w_total = 0.0f64;
    for r in 0..b {
        for t in 1..l {
            w_total += loss_mask.data()[r * l + t] as f64;
        }
    }
    if w_total <= 0.0 {
        let grads = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
        return Ok((0.0, grads));
    }

    // Batch rows are independent given `w_total`, so forward + backward
    // run **batch-parallel** on the kernel worker pool: each row
    // produces its loss contribution and a private gradient set (the
    // `par_map` budget split leaves rows inner-kernel parallelism when
    // the batch is narrower than the thread budget). Rows are processed
    // in windows of the thread budget so peak memory stays
    // O(threads × params) instead of O(batch × params), and each
    // window's results fold into the running total on the calling
    // thread in **ascending row order**, element-wise. The fold
    // sequence is strictly row 0, 1, …, B−1 regardless of the window
    // size, so it is a fixed floating-point sequence independent of the
    // thread count — which is what keeps gradients bitwise identical at
    // every `--threads` setting (pinned by the parity test below).
    let per_row = |r: usize| -> (f64, Vec<TensorF>) {
        let toks = &tokens.data()[r * l..(r + 1) * l];
        let segs = &seg.data()[r * l..(r + 1) * l];
        let mask = &loss_mask.data()[r * l..(r + 1) * l];
        let cache = row_forward(cfg, rope, &w, toks, segs);

        let mut row_loss = 0.0f64;
        let mut dlogits = vec![0.0f32; l * vocab];
        for t in 0..l - 1 {
            let wgt = mask[t + 1];
            if wgt <= 0.0 {
                continue;
            }
            let row = &cache.logits[t * vocab..(t + 1) * vocab];
            let mut mx = f32::NEG_INFINITY;
            for &v in row {
                mx = mx.max(v);
            }
            let mut se = 0.0f64;
            for &v in row {
                se += ((v - mx) as f64).exp();
            }
            let tgt = toks[t + 1] as usize;
            let lse = se.ln() + mx as f64;
            row_loss += wgt as f64 * (lse - row[tgt] as f64);
            let scale_w = (wgt as f64 / w_total) as f32;
            let drow = &mut dlogits[t * vocab..(t + 1) * vocab];
            for (dv, &v) in drow.iter_mut().zip(row) {
                *dv = (((v - mx) as f64).exp() / se) as f32 * scale_w;
            }
            drow[tgt] -= scale_w;
        }
        let mut row_grads: Vec<TensorF> =
            params.iter().map(|p| Tensor::zeros(p.dims())).collect();
        row_backward(cfg, rope, &w, toks, &cache, &dlogits, &mut row_grads);
        (row_loss, row_grads)
    };

    let window = crate::kernels::effective_threads().max(1);
    let mut loss_sum = 0.0f64;
    // Every row folds into zero-initialized buffers in ascending row
    // order — a fixed element-wise sequence (row 0, 1, …, B−1 onto
    // zeros), so the result is bitwise identical at every window size
    // and thread budget.
    let mut grads: Vec<TensorF> = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
    let mut r0 = 0;
    while r0 < b {
        let rows: Vec<usize> = (r0..(r0 + window).min(b)).collect();
        r0 += rows.len();
        for (row_loss, row_grads) in crate::kernels::par_map(&rows, |_, &r| per_row(r)) {
            loss_sum += row_loss;
            for (gv, rgv) in grads.iter_mut().zip(&row_grads) {
                for (a, &v) in gv.data_mut().iter_mut().zip(rgv.data()) {
                    *a += v;
                }
            }
        }
    }
    Ok(((loss_sum / w_total) as f32, grads))
}

/// One Adam step with global-norm clipping (matches the AOT artifact).
pub(crate) fn adam_update(
    params: &mut [TensorF],
    grads: Vec<TensorF>,
    m_state: &mut [TensorF],
    v_state: &mut [TensorF],
    step: usize,
    lr: f32,
) {
    let mut gsq = 0.0f64;
    for g in &grads {
        for &x in g.data() {
            gsq += x as f64 * x as f64;
        }
    }
    let clip = (CLIP_NORM / gsq.sqrt().max(1e-12)).min(1.0) as f32;
    let t = (step + 1) as i32;
    let bc1 = 1.0 - ADAM_B1.powi(t);
    let bc2 = 1.0 - ADAM_B2.powi(t);
    for (i, g) in grads.iter().enumerate() {
        let pd = params[i].data_mut();
        let gd = g.data();
        let md = m_state[i].data_mut();
        let vd = v_state[i].data_mut();
        for j in 0..pd.len() {
            let gc = gd[j] * clip;
            md[j] = ADAM_B1 * md[j] + (1.0 - ADAM_B1) * gc;
            vd[j] = ADAM_B2 * vd[j] + (1.0 - ADAM_B2) * gc * gc;
            let upd = (md[j] / bc1) / ((vd[j] / bc2).sqrt() + ADAM_EPS);
            pd[j] -= lr * upd;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::native::test_util::micro_config;
    use super::super::native::{init_params, native_param_specs};
    use super::*;
    use crate::util::rng::Rng;

    fn batch(
        cfg: &ModelConfig,
        b: usize,
        l: usize,
        seed: u64,
    ) -> (TensorI, TensorI, TensorF) {
        let mut rng = Rng::new(seed);
        let mut toks = Vec::with_capacity(b * l);
        let mut segs = Vec::with_capacity(b * l);
        let mut mask = Vec::with_capacity(b * l);
        for _ in 0..b {
            // Two context segments plus a final (query) segment.
            let s1 = l / 3;
            let s2 = 2 * l / 3;
            for t in 0..l {
                toks.push(rng.below(cfg.vocab) as i32);
                segs.push(if t < s1 {
                    0
                } else if t < s2 {
                    1
                } else {
                    2
                });
                mask.push(if t > 0 && rng.chance(0.7) { 1.0 } else { 0.0 });
            }
        }
        (
            Tensor::from_vec(&[b, l], toks),
            Tensor::from_vec(&[b, l], segs),
            Tensor::from_vec(&[b, l], mask),
        )
    }

    #[test]
    fn loss_is_near_uniform_at_init() {
        // With tiny random weights the predictive distribution is close
        // to uniform, so the CE loss is ≈ ln(vocab).
        let cfg = micro_config();
        let specs = native_param_specs(&cfg);
        let params = init_params(&cfg, &specs, 3);
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        let (toks, segs, mask) = batch(&cfg, 2, 12, 5);
        let (loss, grads) = loss_and_grads(&cfg, &rope, &params, &toks, &segs, &mask).unwrap();
        let uniform = (cfg.vocab as f64).ln() as f32;
        assert!((loss - uniform).abs() < 0.2, "loss {loss} vs ln(V) {uniform}");
        assert_eq!(grads.len(), N_PARAMS);
        assert!(grads.iter().all(|g| g.data().iter().all(|x| x.is_finite())));
        // Some gradient must be nonzero.
        assert!(grads[P_EMBED].data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn empty_mask_gives_zero_loss_and_grads() {
        let cfg = micro_config();
        let specs = native_param_specs(&cfg);
        let params = init_params(&cfg, &specs, 3);
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        let (toks, segs, _) = batch(&cfg, 1, 8, 5);
        let mask = Tensor::zeros(&[1, 8]);
        let (loss, grads) = loss_and_grads(&cfg, &rope, &params, &toks, &segs, &mask).unwrap();
        assert_eq!(loss, 0.0);
        assert!(grads.iter().all(|g| g.data().iter().all(|&x| x == 0.0)));
    }

    /// The correctness anchor for the whole backward pass: the analytic
    /// directional derivative along the gradient direction must match
    /// central finite differences of the loss.
    #[test]
    fn gradient_matches_finite_differences() {
        let cfg = micro_config();
        let specs = native_param_specs(&cfg);
        let params = init_params(&cfg, &specs, 11);
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        let (toks, segs, mask) = batch(&cfg, 2, 10, 17);

        let (_, grads) = loss_and_grads(&cfg, &rope, &params, &toks, &segs, &mask).unwrap();
        // Direction = normalized gradient (guarantees a well-sized
        // directional derivative).
        let mut norm = 0.0f64;
        for g in &grads {
            for &x in g.data() {
                norm += x as f64 * x as f64;
            }
        }
        let norm = norm.sqrt() as f32;
        assert!(norm > 1e-6, "degenerate gradient");
        let dir: Vec<TensorF> = grads
            .iter()
            .map(|g| {
                Tensor::from_vec(g.dims(), g.data().iter().map(|&x| x / norm).collect())
            })
            .collect();
        // Analytic directional derivative = ⟨g, d⟩ = ‖g‖.
        let analytic = norm as f64;

        let eps = 1e-3f32;
        let shift = |sign: f32| -> Vec<TensorF> {
            params
                .iter()
                .zip(&dir)
                .map(|(p, d)| {
                    Tensor::from_vec(
                        p.dims(),
                        p.data()
                            .iter()
                            .zip(d.data())
                            .map(|(&pv, &dv)| pv + sign * eps * dv)
                            .collect(),
                    )
                })
                .collect()
        };
        let (lp, _) =
            loss_and_grads(&cfg, &rope, &shift(1.0), &toks, &segs, &mask).unwrap();
        let (lm, _) =
            loss_and_grads(&cfg, &rope, &shift(-1.0), &toks, &segs, &mask).unwrap();
        let numeric = (lp as f64 - lm as f64) / (2.0 * eps as f64);
        let rel = (numeric - analytic).abs() / analytic.abs().max(1e-12);
        assert!(
            rel < 3e-2,
            "directional derivative mismatch: analytic {analytic:.6} vs numeric {numeric:.6} (rel {rel:.4})"
        );
    }

    /// Gradients must be bitwise identical at every thread budget (the
    /// kernels' determinism contract, exercised end to end through the
    /// batch-parallel step). B = 3 rows over a 1/3/8 sweep covers the
    /// serial path, one-row-per-worker, and rows-with-inner-splits plus
    /// the non-divisible 8-over-3 budget split.
    #[test]
    fn gradients_identical_across_thread_counts() {
        let _g = crate::kernels::TEST_THREADS_LOCK.lock().unwrap();
        let cfg = micro_config();
        let specs = native_param_specs(&cfg);
        let params = init_params(&cfg, &specs, 29);
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        // L = 64 crosses the attention passes' serial-below thresholds,
        // so the inner parallel splits actually engage at threads = 8.
        let (toks, segs, mask) = batch(&cfg, 3, 64, 41);
        let prev = crate::kernels::num_threads();
        crate::kernels::set_threads(1);
        let (l1, g1) = loss_and_grads(&cfg, &rope, &params, &toks, &segs, &mask).unwrap();
        for t in [3usize, 8] {
            crate::kernels::set_threads(t);
            let (lt, gt) = loss_and_grads(&cfg, &rope, &params, &toks, &segs, &mask).unwrap();
            assert_eq!(l1, lt, "loss differs between 1 and {t} threads");
            for (a, b) in g1.iter().zip(&gt) {
                assert_eq!(a, b, "gradient tensor differs between 1 and {t} threads");
            }
        }
        crate::kernels::set_threads(prev);
    }

    #[test]
    fn full_and_block_masks_differ_only_with_segments() {
        // With uniform segment ids the mask degenerates to causal; the
        // loss must be identical to an explicitly-uniform run, and a
        // genuinely segmented run must differ.
        let cfg = micro_config();
        let specs = native_param_specs(&cfg);
        let params = init_params(&cfg, &specs, 23);
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        let (toks, segs, mask) = batch(&cfg, 1, 12, 31);
        let zeros = Tensor::zeros(&[1, 12]);
        let (full_a, _) = loss_and_grads(&cfg, &rope, &params, &toks, &zeros, &mask).unwrap();
        let ones = Tensor::from_vec(&[1, 12], vec![5i32; 12]);
        let (full_b, _) = loss_and_grads(&cfg, &rope, &params, &toks, &ones, &mask).unwrap();
        assert_eq!(full_a, full_b, "uniform segment ids must be causal");
        let (block, _) = loss_and_grads(&cfg, &rope, &params, &toks, &segs, &mask).unwrap();
        assert!((block - full_a).abs() > 1e-6, "segment mask had no effect");
    }

    #[test]
    fn adam_descends_on_a_quadratic() {
        // Minimize f(p) = ½‖p‖² with the real update rule: gradients
        // are p itself.
        let mut params = vec![Tensor::from_vec(&[3], vec![1.0f32, -2.0, 3.0])];
        let mut m = vec![Tensor::zeros(&[3])];
        let mut v = vec![Tensor::zeros(&[3])];
        for step in 0..300 {
            let grads = vec![params[0].clone()];
            adam_update(&mut params, grads, &mut m, &mut v, step, 0.02);
        }
        let norm: f32 = params[0].data().iter().map(|x| x * x).sum();
        assert!(norm < 1e-2, "Adam failed to descend: {:?}", params[0].data());
    }

    #[test]
    fn shape_validation() {
        let cfg = micro_config();
        let specs = native_param_specs(&cfg);
        let params = init_params(&cfg, &specs, 3);
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        let toks = Tensor::from_vec(&[4], vec![1, 2, 3, 4]);
        let seg = Tensor::from_vec(&[4], vec![0; 4]);
        let mask = Tensor::from_vec(&[4], vec![1.0; 4]);
        assert!(loss_and_grads(&cfg, &rope, &params, &toks, &seg, &mask).is_err());
    }
}
