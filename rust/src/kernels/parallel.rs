//! Scoped fork/join parallelism for the compute kernels.
//!
//! Everything here partitions work into **contiguous, disjoint output
//! ranges** and runs each range on its own thread via
//! [`std::thread::scope`]. Because every output element is produced by
//! exactly one task, and each task performs the same sequence of
//! floating-point operations it would under a single thread, results
//! are **bitwise identical** for any thread count — the determinism
//! guarantee the coordinator's `--threads 1` vs `--threads 8` parity
//! tests pin down.
//!
//! Nested parallelism is *budgeted*, not forbidden: a worker inherits a
//! share of the global budget (its parent's budget divided by the
//! number of sibling workers), so a 2-item [`par_map`] on 8 threads
//! leaves each item 4 threads for its inner kernels instead of idling
//! six cores. Leaf row-splits ([`par_rows`]) hand their workers a
//! budget of 1 — re-splitting a leaf chunk is never useful.

use std::cell::Cell;

thread_local! {
    /// Thread budget assigned to this worker thread; `None` outside any
    /// parallel region (= use the global budget).
    static WORKER_BUDGET: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Run `f` with this thread's budget set to `budget` (≥ 1); nested
/// parallel regions see that many [`effective_threads`].
pub(crate) fn with_budget<R>(budget: usize, f: impl FnOnce() -> R) -> R {
    WORKER_BUDGET.with(|c| {
        let prev = c.replace(Some(budget.max(1)));
        let r = f();
        c.set(prev);
        r
    })
}

/// Run `f` as a leaf worker (no nested parallelism).
pub(crate) fn enter_worker<R>(f: impl FnOnce() -> R) -> R {
    with_budget(1, f)
}

/// The thread budget visible at this call site: the configured width
/// ([`super::num_threads`]) at top level, or this worker's assigned
/// share inside a parallel region.
pub fn effective_threads() -> usize {
    WORKER_BUDGET.with(|c| c.get()).unwrap_or_else(super::num_threads)
}

/// Parallel-for over the rows of a flat row-major buffer.
///
/// `out` is split into contiguous chunks of whole rows (`row_len`
/// elements each); `f(row0, chunk)` receives the index of its first row
/// and a mutable view of its rows. Chunks smaller than `min_rows` are
/// not worth a thread and are merged; with one chunk (or inside a
/// worker) `f` runs inline on the caller's thread.
///
/// `f` must compute each row independently of which chunk it lands in —
/// that is what makes the split invisible to the results.
pub fn par_rows<T: Send>(
    out: &mut [T],
    row_len: usize,
    min_rows: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(row_len > 0, "row_len must be positive");
    debug_assert_eq!(out.len() % row_len, 0, "buffer is not whole rows");
    let rows = out.len() / row_len;
    if rows == 0 {
        return;
    }
    let chunks = effective_threads()
        .min(rows / min_rows.max(1))
        .max(1)
        .min(rows);
    if chunks <= 1 {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(chunks);
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = out;
        let mut row0 = 0;
        while !rest.is_empty() {
            let take = per.min(rows - row0);
            let (head, tail) = rest.split_at_mut(take * row_len);
            rest = tail;
            let r0 = row0;
            row0 += take;
            s.spawn(move || enter_worker(|| f(r0, head)));
        }
    });
}

/// Parallel map over a slice, preserving order. Each worker handles a
/// contiguous range of items and inherits an even share of the thread
/// budget for its own nested kernels (8 threads over 2 items → 2
/// workers × 4 inner threads). With one effective thread (or a single
/// item) it degenerates to a plain serial map with the full budget
/// still available to inner parallelism.
pub fn par_map<I: Sync, T: Send>(items: &[I], f: impl Fn(usize, &I) -> T + Sync) -> Vec<T> {
    let threads = effective_threads();
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let mut out: Vec<Option<T>> = (0..items.len()).map(|_| None).collect();
    let workers = threads.min(items.len());
    let per = items.len().div_ceil(workers);
    let inner_budget = threads / workers;
    std::thread::scope(|s| {
        let f = &f;
        for (ci, slots) in out.chunks_mut(per).enumerate() {
            let base = ci * per;
            s.spawn(move || {
                with_budget(inner_budget, || {
                    for (j, slot) in slots.iter_mut().enumerate() {
                        *slot = Some(f(base + j, &items[base + j]));
                    }
                })
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_rows_touches_every_row_once() {
        let rows = 37;
        let row_len = 5;
        let mut buf = vec![0u32; rows * row_len];
        par_rows(&mut buf, row_len, 1, |row0, chunk| {
            for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v += (row0 + i) as u32 + 1;
                }
            }
        });
        for (i, row) in buf.chunks(row_len).enumerate() {
            assert!(row.iter().all(|&v| v == i as u32 + 1), "row {i} wrong: {row:?}");
        }
    }

    #[test]
    fn par_rows_min_rows_merges_small_work() {
        // 4 rows with min_rows=4 must run as one inline chunk.
        let mut buf = vec![0u8; 4 * 3];
        let calls = AtomicUsize::new(0);
        par_rows(&mut buf, 3, 4, |_, chunk| {
            calls.fetch_add(1, Ordering::SeqCst);
            assert_eq!(chunk.len(), 12);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn nested_budgets_are_scoped() {
        // Leaf workers see a budget of 1; budgeted workers see their
        // share; both restore the previous budget on exit.
        assert!(effective_threads() >= 1);
        assert_eq!(enter_worker(effective_threads), 1);
        assert_eq!(with_budget(3, effective_threads), 3);
        let nested = with_budget(4, || (effective_threads(), enter_worker(effective_threads)));
        assert_eq!(nested, (4, 1));
        assert!(effective_threads() >= 1, "budget leaked out of the region");
    }

    #[test]
    fn par_map_preserves_order_and_indices() {
        let items: Vec<usize> = (0..23).collect();
        let out = par_map(&items, |i, &it| {
            assert_eq!(i, it);
            it * 3
        });
        assert_eq!(out, (0..23).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        let e: Vec<u8> = vec![];
        assert!(par_map(&e, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u8], |_, &x| x + 1), vec![8]);
    }
}
