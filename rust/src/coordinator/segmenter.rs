//! Block segmentation (paper §2.2 and §3.1).
//!
//! "Segment semantically independent parts of the prompt into separate
//! blocks": retrieved passages in RAG, demonstrations in ICL, turns in
//! dialogue, fields in gamecore JSON, and the paper's newline heuristics
//! (`\n\n`, `---`, `===`, `\n\t\t`) for free-form text. The final block —
//! the user query — is the only one allowed to attend across blocks.

use crate::config::SegmentPolicy;
use crate::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use anyhow::{bail, ensure, Result};

/// A segmented prompt: context blocks + the final (query) block.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentedPrompt {
    pub blocks: Vec<Vec<i32>>,
    pub query: Vec<i32>,
}

impl SegmentedPrompt {
    pub fn context_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum()
    }
}

/// The paper's newline block-division labels (§3.1, rule 3).
pub const DIVISION_LABELS: [&str; 4] = ["\n\n", "---", "===", "\n\t\t"];

/// Segment a RAG prompt: one block per retrieved passage (plus an
/// optional leading system block); the query is the final block.
pub fn segment_rag(
    tok: &ByteTokenizer,
    system: Option<&str>,
    passages: &[String],
    query: &str,
) -> SegmentedPrompt {
    let mut blocks = Vec::new();
    if let Some(s) = system {
        blocks.push(tok.encode(s));
    }
    for p in passages {
        blocks.push(tok.encode(p));
    }
    SegmentedPrompt { blocks, query: tok.encode(query) }
}

/// Segment an ICL prompt: one block per demonstration; the test input is
/// the final block (a k-shot sample becomes k+1 blocks, paper Table 2).
pub fn segment_icl(tok: &ByteTokenizer, demos: &[String], test_input: &str) -> SegmentedPrompt {
    SegmentedPrompt {
        blocks: demos.iter().map(|d| tok.encode(d)).collect(),
        query: tok.encode(test_input),
    }
}

/// Split free-form text on the paper's division labels, each label kept
/// with the part it terminates — so concatenating the parts reproduces
/// the input byte-for-byte. Empty parts (adjacent labels, label at EOF)
/// are dropped; an empty input yields no parts.
pub fn split_text_parts(text: &str) -> Vec<String> {
    let mut parts: Vec<String> = vec![String::new()];
    let bytes = text.as_bytes();
    let mut i = 0;
    'outer: while i < bytes.len() {
        for label in DIVISION_LABELS {
            let lb = label.as_bytes();
            if bytes[i..].starts_with(lb) {
                // The label terminates the current part (and is kept with
                // it so decode round-trips).
                parts.last_mut().unwrap().push_str(label);
                parts.push(String::new());
                i += lb.len();
                continue 'outer;
            }
        }
        // Advance one UTF-8 character.
        let ch_len = utf8_len(bytes[i]);
        parts
            .last_mut()
            .unwrap()
            .push_str(std::str::from_utf8(&bytes[i..i + ch_len]).unwrap_or("?"));
        i += ch_len;
    }
    parts.retain(|p| !p.is_empty());
    parts
}

/// Segment free-form text on the paper's division labels. The text after
/// the last division becomes the query block.
pub fn segment_text(tok: &ByteTokenizer, text: &str) -> SegmentedPrompt {
    let mut parts = split_text_parts(text);
    let query = parts.pop().unwrap_or_default();
    SegmentedPrompt {
        blocks: parts.iter().map(|p| tok.encode(p)).collect(),
        query: tok.encode(&query),
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// The per-field block texts of a gamecore JSON state (paper
/// Appendix A): one text per top-level field, with non-empty object
/// fields expanded one level (`"chips.p1={…}"`), serialized
/// deterministically so identical sub-states hash to identical blocks
/// across frames. A non-object state collapses to a single text.
pub fn gamecore_field_texts(state: &Json) -> Vec<String> {
    let mut texts = Vec::new();
    if let Some(obj) = state.as_obj() {
        for (key, val) in obj {
            match val {
                Json::Obj(inner) if !inner.is_empty() => {
                    for (k2, v2) in inner {
                        texts.push(format!("{key}.{k2}={v2}"));
                    }
                }
                other => texts.push(format!("{key}={other}")),
            }
        }
    } else {
        texts.push(state.to_string());
    }
    texts
}

/// Segment a gamecore JSON state (paper Appendix A): each top-level (or
/// second-level, for objects) field becomes one block, serialized
/// deterministically so identical sub-states hash to identical blocks
/// across frames. `task` is the instruction/query block.
pub fn segment_gamecore(tok: &ByteTokenizer, state: &Json, task: &str) -> SegmentedPrompt {
    SegmentedPrompt {
        blocks: gamecore_field_texts(state).iter().map(|t| tok.encode(t)).collect(),
        query: tok.encode(task),
    }
}

/// Raw (unsegmented) prompt material a wire request may carry instead
/// of a pre-cut `passages` array: which fields are present decides —
/// together with the serving [`SegmentPolicy`] — how context blocks are
/// drawn. Built by `server::parse_request` from the request JSON.
#[derive(Debug, Default, Clone)]
pub struct RawPrompt {
    /// Free-form text, split on [`DIVISION_LABELS`] (`text` policy).
    pub prompt: Option<String>,
    /// System prompt, the leading block of a chat prompt (`chat`).
    pub system: Option<String>,
    /// Few-shot demonstrations, one exemplar block each (`icl`).
    pub demos: Option<Vec<String>>,
    /// Completed dialogue exchanges, one history block each (`chat`).
    pub turns: Option<Vec<String>>,
    /// Game state object, segmented per field (`gamecore`).
    pub state: Option<Json>,
}

impl RawPrompt {
    /// True when no raw prompt material is present (the request is
    /// pre-segmented or query-only).
    pub fn is_empty(&self) -> bool {
        self.prompt.is_none()
            && self.system.is_none()
            && self.demos.is_none()
            && self.turns.is_none()
            && self.state.is_none()
    }
}

/// Apply a [`SegmentPolicy`] to raw prompt material, yielding the
/// context-block **texts** in prompt order — `Ok(None)` when the
/// request carries no raw fields (it is pre-segmented / query-only and
/// every policy serves it unchanged). The texts then go through the
/// same tokenize step as a `passages` array (encode + `SEP` per block),
/// so a raw request and its equivalent pre-segmented request produce
/// byte-identical token streams — and therefore bitwise-identical
/// output.
///
/// Failures are loud: raw fields under the `passages` policy, a field
/// that does not match the policy, or conflicting raw fields all name
/// the offending field and the policy that rejected it.
pub fn policy_block_texts(policy: SegmentPolicy, raw: &RawPrompt) -> Result<Option<Vec<String>>> {
    // Which segmentation the present fields select. `system` and
    // `turns` are one group: a chat prompt may carry either or both.
    let mut groups: Vec<(&str, SegmentPolicy)> = Vec::new();
    if raw.prompt.is_some() {
        groups.push(("prompt", SegmentPolicy::Text));
    }
    if raw.demos.is_some() {
        groups.push(("demos", SegmentPolicy::Icl));
    }
    if raw.turns.is_some() || raw.system.is_some() {
        let name = if raw.turns.is_some() { "turns" } else { "system" };
        groups.push((name, SegmentPolicy::Chat));
    }
    if raw.state.is_some() {
        groups.push(("state", SegmentPolicy::Gamecore));
    }
    let (field, implied) = match groups.as_slice() {
        [] => return Ok(None),
        [one] => *one,
        many => {
            let names: Vec<&str> = many.iter().map(|(n, _)| *n).collect();
            bail!(
                "conflicting raw prompt fields {:?}: a request may carry \
                 at most one of 'prompt', 'demos', 'turns'/'system', 'state'",
                names
            );
        }
    };
    let effective = if policy == SegmentPolicy::Auto { implied } else { policy };
    ensure!(
        effective == implied,
        "segment policy '{}' cannot serve raw field '{field}' \
         (use --segment {} or auto)",
        policy.as_str(),
        implied.as_str()
    );
    Ok(Some(match effective {
        SegmentPolicy::Text => split_text_parts(raw.prompt.as_deref().unwrap()),
        SegmentPolicy::Icl => raw.demos.clone().unwrap(),
        SegmentPolicy::Chat => {
            let mut texts: Vec<String> = Vec::new();
            if let Some(s) = &raw.system {
                texts.push(s.clone());
            }
            if let Some(turns) = &raw.turns {
                texts.extend(turns.iter().cloned());
            }
            texts
        }
        SegmentPolicy::Gamecore => gamecore_field_texts(raw.state.as_ref().unwrap()),
        // `implied` is never Passages or Auto; `effective == implied`.
        SegmentPolicy::Passages | SegmentPolicy::Auto => unreachable!(),
    }))
}

/// Merge blocks shorter than `min_len` into their predecessor — tiny
/// blocks waste cache entries and bucket padding.
pub fn coalesce_small_blocks(mut sp: SegmentedPrompt, min_len: usize) -> SegmentedPrompt {
    let mut out: Vec<Vec<i32>> = Vec::with_capacity(sp.blocks.len());
    for b in sp.blocks.drain(..) {
        match out.last_mut() {
            Some(prev) if b.len() < min_len || prev.len() < min_len => {
                prev.extend_from_slice(&b)
            }
            _ => out.push(b),
        }
    }
    sp.blocks = out;
    sp
}

/// Split context blocks longer than `max_len` into `max_len`-sized
/// chunks so every block fits the prefill_block bucket capacity. The
/// **query** block cannot be split — its tokens must attend to the
/// whole context in one final prefill, so chunking it would change the
/// attention semantics — and is instead rejected loudly when it
/// exceeds `max_len` (it would otherwise overflow the final-prefill
/// bucket downstream with a much less actionable error).
pub fn split_oversized_blocks(mut sp: SegmentedPrompt, max_len: usize) -> Result<SegmentedPrompt> {
    ensure!(max_len > 0, "split_oversized_blocks needs max_len > 0");
    ensure!(
        sp.query.len() <= max_len,
        "query block of {} tokens exceeds the prefill bucket capacity \
         ({max_len}); the query cannot be split — shorten it",
        sp.query.len()
    );
    let mut out = Vec::with_capacity(sp.blocks.len());
    for b in sp.blocks.drain(..) {
        if b.len() <= max_len {
            out.push(b);
        } else {
            for chunk in b.chunks(max_len) {
                out.push(chunk.to_vec());
            }
        }
    }
    sp.blocks = out;
    Ok(sp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> ByteTokenizer {
        ByteTokenizer::new()
    }

    #[test]
    fn rag_blocks_one_per_passage() {
        let t = tok();
        let sp = segment_rag(
            &t,
            Some("You are helpful."),
            &["Doc one.".into(), "Doc two.".into()],
            "Which doc?",
        );
        assert_eq!(sp.blocks.len(), 3);
        assert_eq!(t.decode(&sp.query), "Which doc?");
        assert_eq!(t.decode(&sp.blocks[1]), "Doc one.");
    }

    #[test]
    fn icl_k_shot_is_k_plus_one_blocks() {
        let t = tok();
        let sp = segment_icl(&t, &["in: a out: b".into(), "in: c out: d".into()], "in: e out:");
        assert_eq!(sp.blocks.len(), 2);
        assert!(!sp.query.is_empty());
    }

    #[test]
    fn text_splits_on_division_labels() {
        let t = tok();
        let sp = segment_text(&t, "part one\n\npart two---part three===tail");
        assert_eq!(sp.blocks.len(), 3);
        assert_eq!(t.decode(&sp.query), "tail");
        // Round-trip: blocks + query reassemble the original text.
        let mut s = String::new();
        for b in &sp.blocks {
            s.push_str(&t.decode(b));
        }
        s.push_str(&t.decode(&sp.query));
        assert_eq!(s, "part one\n\npart two---part three===tail");
    }

    #[test]
    fn text_without_labels_is_single_query() {
        let t = tok();
        let sp = segment_text(&t, "just a sentence");
        assert!(sp.blocks.is_empty());
        assert_eq!(t.decode(&sp.query), "just a sentence");
    }

    #[test]
    fn gamecore_fields_become_blocks() {
        let t = tok();
        let state = Json::parse(
            r#"{"chips":{"p1":{"bet":10},"p2":{"bet":50}},"round":3}"#,
        )
        .unwrap();
        let sp = segment_gamecore(&t, &state, "act");
        // chips.p1, chips.p2, round
        assert_eq!(sp.blocks.len(), 3);
        // Deterministic serialization → frame-to-frame block identity.
        let sp2 = segment_gamecore(&t, &Json::parse(
            r#"{"round":3,"chips":{"p2":{"bet":50},"p1":{"bet":10}}}"#,
        ).unwrap(), "act");
        assert_eq!(sp.blocks, sp2.blocks);
    }

    #[test]
    fn coalesce_merges_small() {
        let sp = SegmentedPrompt {
            blocks: vec![vec![1; 2], vec![2; 50], vec![3; 2], vec![4; 50]],
            query: vec![9],
        };
        let out = coalesce_small_blocks(sp, 8);
        // [2] merges into [50] (prev too small), trailing [2] merges
        // backward, final [50] stands alone: [54, 50].
        assert_eq!(out.blocks.len(), 2);
        assert_eq!(out.blocks[0].len(), 54);
        assert_eq!(out.blocks[1].len(), 50);
        assert_eq!(out.blocks.iter().map(|b| b.len()).sum::<usize>(), 104);
    }

    #[test]
    fn split_caps_block_len() {
        let sp = SegmentedPrompt { blocks: vec![vec![1; 300]], query: vec![] };
        let out = split_oversized_blocks(sp, 128).unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert!(out.blocks.iter().all(|b| b.len() <= 128));
        assert_eq!(out.blocks.iter().map(|b| b.len()).sum::<usize>(), 300);
    }

    /// Regression: the query block used to pass through unchecked, so
    /// an oversized final block could overflow the prefill bucket
    /// downstream. It cannot be chunked (its tokens attend across the
    /// whole context), so it must be rejected loudly here.
    #[test]
    fn split_rejects_oversized_query() {
        let sp = SegmentedPrompt { blocks: vec![vec![1; 10]], query: vec![2; 200] };
        let err = split_oversized_blocks(sp, 128).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("200") && msg.contains("128"), "unhelpful error: {msg}");
        // At the cap is fine.
        let sp = SegmentedPrompt { blocks: vec![], query: vec![2; 128] };
        assert!(split_oversized_blocks(sp, 128).is_ok());
    }

    #[test]
    fn policy_texts_dispatch_and_reject() {
        let raw_text = RawPrompt { prompt: Some("a---b---c".into()), ..Default::default() };
        let raw_icl = RawPrompt {
            demos: Some(vec!["in a out b".into(), "in c out d".into()]),
            ..Default::default()
        };
        let raw_chat = RawPrompt {
            system: Some("be brief".into()),
            turns: Some(vec!["t1".into(), "t2".into()]),
            ..Default::default()
        };
        let raw_game = RawPrompt {
            state: Some(Json::parse(r#"{"pot":10,"round":2}"#).unwrap()),
            ..Default::default()
        };

        // Each dedicated policy segments its field…
        let texts = policy_block_texts(SegmentPolicy::Text, &raw_text).unwrap().unwrap();
        assert_eq!(texts, vec!["a---", "b---", "c"]);
        let texts = policy_block_texts(SegmentPolicy::Icl, &raw_icl).unwrap().unwrap();
        assert_eq!(texts.len(), 2);
        let texts = policy_block_texts(SegmentPolicy::Chat, &raw_chat).unwrap().unwrap();
        assert_eq!(texts, vec!["be brief", "t1", "t2"]);
        let texts = policy_block_texts(SegmentPolicy::Gamecore, &raw_game).unwrap().unwrap();
        assert_eq!(texts, vec!["pot=10", "round=2"]);

        // …`auto` dispatches on the field…
        for raw in [&raw_text, &raw_icl, &raw_chat, &raw_game] {
            assert!(policy_block_texts(SegmentPolicy::Auto, raw).unwrap().is_some());
        }

        // …no raw fields means pre-segmented under every policy…
        for p in [
            SegmentPolicy::Passages,
            SegmentPolicy::Text,
            SegmentPolicy::Icl,
            SegmentPolicy::Chat,
            SegmentPolicy::Gamecore,
            SegmentPolicy::Auto,
        ] {
            assert!(policy_block_texts(p, &RawPrompt::default()).unwrap().is_none());
        }

        // …and mismatches fail loudly, naming field and policy.
        let err = policy_block_texts(SegmentPolicy::Passages, &raw_text).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("passages") && msg.contains("prompt"), "unhelpful: {msg}");
        let err = policy_block_texts(SegmentPolicy::Icl, &raw_game).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("icl") && msg.contains("state"), "unhelpful: {msg}");

        // Conflicting raw fields are ambiguous even under `auto`.
        let both = RawPrompt {
            prompt: Some("x".into()),
            demos: Some(vec!["d".into()]),
            ..Default::default()
        };
        let err = policy_block_texts(SegmentPolicy::Auto, &both).unwrap_err();
        assert!(format!("{err}").contains("conflicting"));
    }
}
