//! Content-addressed block KV cache — the paper's enabling data
//! structure (§2.1, §2.5).
//!
//! Each retrieved passage / prompt block is keyed by the **hash of its
//! token ids** (content addressing: the same passage retrieved for a
//! different query hits the cache regardless of its position in the new
//! prompt). The cached value is the block's KV states computed by
//! `prefill_block` at *local* positions `0..L`; on reuse at offset `Δ`
//! the keys are RoPE-rotated by `Δ` (paper Eq. 3) via
//! [`crate::rope::RopeTable::reencode_block`].
//!
//! Eviction: LRU over unpinned entries with a byte budget. Entries are
//! pinned (ref-counted) while a scheduler plan holds them so an admitted
//! request can never lose its blocks mid-flight.
//!
//! ## Storage tiers
//!
//! The cache stores blocks at a configurable [`KvPrecision`]:
//!
//! * **f32** (default) — KV bytes as computed; reuse is bit-lossless.
//! * **int8** — K and V are quantized at insert time to symmetric int8
//!   with per-(layer, head, channel) f32 scales
//!   ([`crate::kernels::quant::QuantizedKv`]), cutting the per-block
//!   byte cost to ~¼ — i.e. ~4× the blocks for the same budget. On use,
//!   dequantization is **fused into the Eq.-3 re-encode**
//!   ([`RopeTable::reencode_block_dequant`]): one pass reconstructs and
//!   rotates the keys.
//! * **int4** — packed 4-bit codes (two per byte along the channel
//!   axis) with group-wise scales per (layer, head, channel, 32-token
//!   group) ([`crate::kernels::quant::QuantizedKv4`]): ~⅛ the bytes
//!   (≤ 16% with scales) — ~8× the blocks per budget. Fetch fuses the
//!   nibble unpack into the re-encode
//!   ([`RopeTable::reencode_block_dequant_i4`]).
//!
//! Quantize and dequantize are per-element and order-free on every
//! tier, so the stack's bitwise thread-count determinism is preserved;
//! the accuracy contracts (decode-logit cosine vs f32 ≥ 0.999 for int8,
//! ≥ 0.99 for int4, on the workload traces) are pinned by
//! `tests/kv_quant.rs`. [`CacheStats`] reports the bytes saved (total
//! and per tier) and the running relative quantization error.
//!
//! ## Rotation memo
//!
//! All tiers fetch through one parameterized path
//! ([`RopeTable::reencode_into`] over a [`crate::rope::KvView`]), and
//! every freshly rotated panel is recorded in a byte-budgeted **memo**
//! keyed by `(key, Δ)`: a repeat fetch at the same offset — the common
//! case for a shared system block at offset 0 or a popular passage in
//! a stable plan — replays the stored panel verbatim (a copy, not a
//! rotation; bitwise identical to recomputing it, pinned by
//! `tests/reencode_modes.rs`). Under the opt-in
//! [`ReencodeMode::Delta`] a fetch at a new `Δ₂` delta-rotates the
//! nearest memoized panel by `Δ₂−Δ₁` instead of re-deriving from the
//! stored codes — cheaper for f32-sized rotations than a dequant, but
//! cosine-contracted rather than bitwise (f32 rounding differs per
//! hop). Memo panels die with their entry (eviction, drop, clear) and
//! never outlive the stored codes they were derived from.
//!
//! The tier is a property of the *entry*, not the cache:
//! [`BlockKvCache::set_precision`] switches the precision for future
//! inserts while resident entries keep serving at the tier they were
//! stored at, so mixed-tier populations (precision changed between
//! requests) coexist with exact per-tier byte accounting.
//!
//! ## Disk tier
//!
//! An attached [`disk::DiskStore`] ([`BlockKvCache::attach_store`])
//! extends the cache below RAM: LRU eviction **spills** the victim's
//! codes + scales to a content-addressed block file (write-behind),
//! and a RAM miss **promotes** the block file back to a resident entry
//! (read-through), fused into the same [`Self::lookup_pin`] the
//! scheduler already calls — a promoted block pins and re-encodes
//! exactly like one that was never evicted. Because quantization
//! happens once at insert and the file stores the codes verbatim
//! (format: [`store`], spec: `docs/kvstore-format.md`), a disk
//! round-trip is **bitwise invisible** to every later fetch, at every
//! tier and thread count. Corrupt or mismatched files are rejected
//! loudly (stderr + [`CacheStats::disk_errors`]) and fall back to a
//! recompute miss; they never wedge a request.

use crate::config::{KvPrecision, ReencodeMode};
use crate::kernels::quant::{QuantizedKv, QuantizedKv4};
use crate::rope::{AngleCache, KvView, RopeTable};
use crate::tensor::{Tensor, TensorF};
use disk::DiskStore;
use std::collections::HashMap;

pub mod disk;
pub mod store;

/// 128-bit FNV-1a over token ids — content key of a block.
pub fn block_key(tokens: &[i32]) -> u128 {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013b;
    let mut h = OFFSET;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u128;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// The stored KV payload of one block, at the precision the cache had
/// when the block was inserted.
enum KvData {
    /// `(layers, len, kv_heads, head_dim)` keys at positions `0..len`.
    F32 { k_local: TensorF, v: TensorF },
    /// Int8 codes + per-(layer, head, channel) scales for K and V.
    Int8 { k: QuantizedKv, v: QuantizedKv },
    /// Packed int4 codes + per-(layer, head, channel, token-group)
    /// scales for K and V.
    Int4 { k: QuantizedKv4, v: QuantizedKv4 },
}

impl KvData {
    fn tier(&self) -> KvPrecision {
        match self {
            KvData::F32 { .. } => KvPrecision::F32,
            KvData::Int8 { .. } => KvPrecision::Int8,
            KvData::Int4 { .. } => KvPrecision::Int4,
        }
    }
}

/// One cached block: KV states at local positions.
struct Entry {
    data: KvData,
    len: usize,
    /// Bytes actually held (codes + scales for the quantized tiers).
    bytes: usize,
    /// What the same block would cost at f32 (for bytes-saved stats).
    bytes_f32: usize,
    pins: usize,
    last_used: u64,
    hits: u64,
}

/// Memoized rotated panels of one resident entry: the dequantized V
/// (position-independent — V is never rotated, so one copy serves every
/// offset) plus K panels keyed by the `Δ` they were rotated to.
/// Derived data only: invalidated whenever the base entry leaves the
/// RAM map, and always re-derivable from the stored codes.
struct MemoEntry {
    v: TensorF,
    /// `(delta, rotated K panel)` in insertion order.
    panels: Vec<(usize, TensorF)>,
    last_used: u64,
}

impl MemoEntry {
    fn bytes(&self) -> usize {
        self.v.size_bytes() + self.panels.iter().map(|(_, k)| k.size_bytes()).sum::<usize>()
    }
}

/// Cache statistics (exported via coordinator metrics).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub entries: usize,
    pub bytes: usize,
    /// Bytes the quantized tiers save for the *currently resident*
    /// entries vs storing them at f32 (0 when everything resident is
    /// f32); always `bytes_saved_int8 + bytes_saved_int4`.
    pub bytes_saved: usize,
    /// Bytes saved by the resident int8 entries alone.
    pub bytes_saved_int8: usize,
    /// Bytes saved by the resident int4 entries alone.
    pub bytes_saved_int4: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// RAM misses served by promoting a block file from the attached
    /// disk store (each also counts as a [`Self::hits`] — the two-tier
    /// cache did hold the block). 0 without a store.
    pub disk_hits: u64,
    /// Lookups that missed RAM *and* the attached store (a subset of
    /// [`Self::misses`]). 0 without a store.
    pub disk_misses: u64,
    /// Blocks newly written to the store (eviction write-behind plus
    /// explicit [`BlockKvCache::spill_all`] flushes). Idempotent
    /// re-spills of an already-published block are not counted.
    pub disk_spills: u64,
    /// Store failures: spill write errors and rejected (corrupt,
    /// truncated, version- or fingerprint-mismatched) block files.
    /// Every one is also reported on stderr; the lookup falls back to
    /// a recompute miss.
    pub disk_errors: u64,
    /// Block files currently published in the attached store.
    pub disk_entries: usize,
    /// Summed size of those files in bytes.
    pub disk_bytes: usize,
    /// Fetches served by replaying a memoized `(key, Δ)` panel — a
    /// copy, not a rotation; bitwise identical to re-deriving it.
    pub memo_hits: u64,
    /// Fetches that found no memoized panel at their exact `(key, Δ)`
    /// (the panel was then derived — or, in delta mode, delta-rotated —
    /// and memoized).
    pub memo_misses: u64,
    /// Memo panels dropped: LRU trims to the memo byte budget plus
    /// invalidations when the base entry left RAM.
    pub memo_evictions: u64,
    /// Fetches served by delta-rotating a memoized panel from a nearby
    /// `Δ` instead of re-deriving from the stored codes. Only the
    /// opt-in [`ReencodeMode::Delta`] does this; always 0 under the
    /// bitwise default.
    pub delta_rotations: u64,
    /// Entries currently holding memoized panels (derived in `stats()`).
    pub memo_entries: usize,
    /// Summed bytes of the memoized panels (derived in `stats()`).
    pub memo_bytes: usize,
    /// Running sums over every quantized (int8 or int4) insertion:
    /// squared reconstruction error and squared reference magnitude
    /// (see [`Self::quant_rel_err`]).
    pub quant_err_sq: f64,
    pub quant_ref_sq: f64,
}

impl CacheStats {
    /// Hits as a fraction of all lookups. A cache that has never been
    /// looked up reports 0.0 (not NaN): the zero-lookup edge must stay
    /// finite because the value is serialized straight into the server's
    /// stats JSON, where NaN is not representable.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits.saturating_add(self.misses);
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Relative quantization error of the quantized tiers,
    /// `sqrt(Σ‖x − x̂‖² / Σ‖x‖²)` over all int8 and int4 insertions.
    /// 0.0 when nothing was quantized (f32 tier, or an empty cache) —
    /// like [`Self::hit_rate`], this must stay finite for the stats
    /// JSON.
    pub fn quant_rel_err(&self) -> f64 {
        if self.quant_ref_sq <= 0.0 {
            0.0
        } else {
            (self.quant_err_sq / self.quant_ref_sq).sqrt()
        }
    }
}

/// A block fetched from the cache, with keys re-encoded to an offset.
pub struct ReencodedBlock {
    pub k: TensorF,
    pub v: TensorF,
    pub len: usize,
}

/// Content-addressed block KV cache with LRU eviction, pinning, and an
/// optional persistent disk tier (spill on evict, promote on miss).
pub struct BlockKvCache {
    map: HashMap<u128, Entry>,
    rope: RopeTable,
    byte_budget: usize,
    precision: KvPrecision,
    clock: u64,
    stats: CacheStats,
    store: Option<DiskStore>,
    /// Rotated-panel memo (see the module docs): derived data keyed by
    /// entry, LRU-trimmed to `memo_budget`, invalidated with its entry.
    memo: HashMap<u128, MemoEntry>,
    /// Byte budget of the memo alone (0 = unbounded). Defaults to the
    /// cache's own byte budget — the memo holds f32 panels, so it can
    /// cost more RAM than the (possibly quantized) entries it derives
    /// from, and deserves its own bound.
    memo_budget: usize,
    reencode_mode: ReencodeMode,
    /// Δ-keyed cos/sin memo shared across fetches (consecutive blocks
    /// of one plan frequently land at few distinct offsets).
    angles: AngleCache,
}

impl BlockKvCache {
    /// `byte_budget` bounds the summed KV bytes (0 = unbounded).
    /// Stores at f32; use [`Self::with_precision`] for the int8 tier.
    pub fn new(rope: RopeTable, byte_budget: usize) -> Self {
        Self::with_precision(rope, byte_budget, KvPrecision::F32)
    }

    /// A cache that stores blocks at `precision` (see [`KvPrecision`]).
    pub fn with_precision(rope: RopeTable, byte_budget: usize, precision: KvPrecision) -> Self {
        BlockKvCache {
            map: HashMap::new(),
            rope,
            byte_budget,
            precision,
            clock: 0,
            stats: CacheStats::default(),
            store: None,
            memo: HashMap::new(),
            memo_budget: byte_budget,
            reencode_mode: ReencodeMode::default(),
            angles: AngleCache::new(),
        }
    }

    /// The active re-encode mode (see [`ReencodeMode`]; the bitwise
    /// `Eager` by default).
    pub fn reencode_mode(&self) -> ReencodeMode {
        self.reencode_mode
    }

    /// Switch between eager re-derivation and delta-rotation of
    /// memoized panels. Takes effect for future fetches; existing memo
    /// panels stay valid (both modes produce and consume the same
    /// memo — only the miss path differs).
    pub fn set_reencode_mode(&mut self, mode: ReencodeMode) {
        self.reencode_mode = mode;
    }

    /// Bound the rotation memo to `bytes` (0 = unbounded), trimming
    /// immediately. The memo starts at the cache's own byte budget.
    pub fn set_memo_budget(&mut self, bytes: usize) {
        self.memo_budget = bytes;
        self.enforce_memo_budget();
    }

    /// Drop every memoized rotated panel. A measurement aid (benches
    /// time the memo-cold fetch path with it) — correctness never needs
    /// it, since the memo is derived data. Not counted as evictions.
    pub fn clear_memo(&mut self) {
        self.memo.clear();
    }

    /// Attach a persistent disk tier: from now on LRU eviction spills
    /// the victim's stored codes to the directory (write-behind) and a
    /// RAM miss reads through to it, promoting the block file back to
    /// a resident entry. Replaces any previously attached store.
    pub fn attach_store(&mut self, store: DiskStore) {
        self.store = Some(store);
    }

    /// Detach and return the disk tier (resident entries are kept).
    pub fn detach_store(&mut self) -> Option<DiskStore> {
        self.store.take()
    }

    /// The attached disk tier, if any.
    pub fn store(&self) -> Option<&DiskStore> {
        self.store.as_ref()
    }

    pub fn precision(&self) -> KvPrecision {
        self.precision
    }

    /// Change the storage precision for **future** inserts. Resident
    /// entries keep the tier they were stored at (their codes cannot be
    /// retroactively re-quantized without the source f32 states), so a
    /// precision change mid-run yields a mixed-tier population — which
    /// the per-entry byte accounting and [`CacheStats`] per-tier fields
    /// handle exactly.
    pub fn set_precision(&mut self, precision: KvPrecision) {
        self.precision = precision;
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = self.stats.clone();
        s.entries = self.map.len();
        // Byte totals are derived from the resident entries, not the
        // running counters.
        (s.bytes, s.bytes_saved_int8, s.bytes_saved_int4) = (0, 0, 0);
        for e in self.map.values() {
            s.bytes += e.bytes;
            let saved = e.bytes_f32.saturating_sub(e.bytes);
            match e.data.tier() {
                KvPrecision::F32 => {}
                KvPrecision::Int8 => s.bytes_saved_int8 += saved,
                KvPrecision::Int4 => s.bytes_saved_int4 += saved,
            }
        }
        s.bytes_saved = s.bytes_saved_int8 + s.bytes_saved_int4;
        s.memo_entries = self.memo.len();
        s.memo_bytes = self.memo.values().map(|m| m.bytes()).sum();
        (s.disk_entries, s.disk_bytes) = match &self.store {
            Some(st) => (st.entries(), st.bytes() as usize),
            None => (0, 0),
        };
        s
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Does the cache hold this block **in RAM**? (Does not count as a
    /// hit/miss, and does not consult the disk tier.)
    pub fn contains(&self, key: u128) -> bool {
        self.map.contains_key(&key)
    }

    /// Is the block resident in RAM *or* published in the attached
    /// store? Counts nothing — the offline precompute path uses this to
    /// skip blocks that are already durable.
    pub fn contains_anywhere(&self, key: u128) -> bool {
        self.map.contains_key(&key) || self.store.as_ref().is_some_and(|s| s.contains(key))
    }

    /// Add a pin to an already-present entry **without** touching the
    /// hit/miss statistics (used when a request holds several references
    /// to a block it just computed — that is not a cache hit). Returns
    /// false if the key is absent.
    pub fn pin(&mut self, key: u128) -> bool {
        let t = self.tick();
        match self.map.get_mut(&key) {
            Some(e) => {
                e.pins += 1;
                e.last_used = t;
                true
            }
            None => false,
        }
    }

    /// Record a lookup; pins the entry if present (must be released with
    /// [`Self::unpin`]). A RAM miss reads through to the attached disk
    /// store first: a valid block file is promoted back to a resident
    /// entry — already pinned, indistinguishable to the caller from a
    /// block that was never evicted — before the miss would be counted.
    pub fn lookup_pin(&mut self, key: u128) -> bool {
        let t = self.tick();
        if let Some(e) = self.map.get_mut(&key) {
            e.pins += 1;
            e.last_used = t;
            e.hits += 1;
            self.stats.hits += 1;
            return true;
        }
        if self.promote_from_store(key) {
            self.stats.hits += 1;
            self.stats.disk_hits += 1;
            return true;
        }
        self.stats.misses += 1;
        false
    }

    /// Try to promote `key` from the attached store into a resident
    /// pinned entry. The stored codes/scales are inserted **verbatim**
    /// (a promotion is not a quantization event), so a disk round-trip
    /// is bitwise invisible to every later fetch. A rejected file —
    /// corrupt, truncated, wrong version, foreign fingerprint — is
    /// reported on stderr, counted in [`CacheStats::disk_errors`],
    /// deleted by the store, and treated as a recompute miss.
    fn promote_from_store(&mut self, key: u128) -> bool {
        let Some(st) = self.store.as_mut() else { return false };
        match st.get(key) {
            Ok(Some(block)) => {
                let (bytes, bytes_f32) = block_sizes(&block.data);
                let t = self.tick();
                self.map.insert(
                    key,
                    Entry {
                        data: block.data,
                        len: block.len,
                        bytes,
                        bytes_f32,
                        pins: 1,
                        last_used: t,
                        hits: 0,
                    },
                );
                self.enforce_budget();
                true
            }
            Ok(None) => {
                self.stats.disk_misses += 1;
                false
            }
            Err(e) => {
                eprintln!("kv-store: {e:#}");
                self.stats.disk_errors += 1;
                false
            }
        }
    }

    /// Insert a block computed by `prefill_block` (keys at local
    /// positions). The entry starts pinned (the inserting request is
    /// about to use it). On the quantized tiers the block is quantized
    /// here — every later use (including by the inserting request
    /// itself) reads the quantized states, so cold and warm servings of
    /// a block are identical by construction. Evicts LRU unpinned
    /// entries to honor the budget.
    pub fn insert_pinned(&mut self, key: u128, k_local: TensorF, v: TensorF) {
        let len = k_local.dims()[1];
        let bytes_f32 = k_local.size_bytes() + v.size_bytes();
        let data = match self.precision {
            KvPrecision::F32 => KvData::F32 { k_local, v },
            KvPrecision::Int8 => {
                let kq = QuantizedKv::quantize(&k_local);
                let vq = QuantizedKv::quantize(&v);
                // Error sums were accumulated inline by quantize() — no
                // extra dequant pass on the miss-prefill hot path.
                self.stats.quant_err_sq += kq.sq_err + vq.sq_err;
                self.stats.quant_ref_sq += kq.sq_ref + vq.sq_ref;
                KvData::Int8 { k: kq, v: vq }
            }
            KvPrecision::Int4 => {
                let kq = QuantizedKv4::quantize(&k_local);
                let vq = QuantizedKv4::quantize(&v);
                self.stats.quant_err_sq += kq.sq_err + vq.sq_err;
                self.stats.quant_ref_sq += kq.sq_ref + vq.sq_ref;
                KvData::Int4 { k: kq, v: vq }
            }
        };
        let bytes = match &data {
            KvData::F32 { .. } => bytes_f32,
            KvData::Int8 { k, v } => k.size_bytes() + v.size_bytes(),
            KvData::Int4 { k, v } => k.size_bytes() + v.size_bytes(),
        };
        let t = self.tick();
        // Defensive: replacing a resident entry invalidates any panels
        // derived from the old payload.
        self.invalidate_memo(key);
        self.map.insert(
            key,
            Entry { data, len, bytes, bytes_f32, pins: 1, last_used: t, hits: 0 },
        );
        self.stats.insertions += 1;
        self.enforce_budget();
    }

    /// Release one pin.
    pub fn unpin(&mut self, key: u128) {
        if let Some(e) = self.map.get_mut(&key) {
            debug_assert!(e.pins > 0, "unbalanced unpin");
            e.pins = e.pins.saturating_sub(1);
        }
        self.enforce_budget();
    }

    /// Fetch a pinned block with its keys re-encoded to absolute offset
    /// `delta` (paper Eq. 3). `delta = 0` returns the cached keys as-is.
    ///
    /// Fetch order (per tier, all through the one unified
    /// [`RopeTable::reencode_into`] path):
    ///
    /// 1. **Memo hit** — a panel already rotated to this exact `Δ` is
    ///    replayed verbatim (a copy; bitwise identical to recomputing).
    /// 2. **Delta rotation** (opt-in [`ReencodeMode::Delta`] only) —
    ///    the nearest memoized panel is rotated by the offset
    ///    difference; cosine-contracted, not bitwise.
    /// 3. **Memo-cold derivation** — the panel is materialized from the
    ///    stored codes (verbatim copy / fused dequant) and rotated;
    ///    bitwise identical to the pre-memo fetch paths.
    ///
    /// Whatever path produced the panel, it is memoized for the next
    /// fetch, then the memo is trimmed to its byte budget.
    pub fn get_reencoded(&mut self, key: u128, delta: usize) -> Option<ReencodedBlock> {
        if !self.map.contains_key(&key) {
            return None;
        }
        self.clock += 1;
        let now = self.clock;

        // 1. Exact (key, Δ) memo hit: replay the stored panel.
        if let Some(m) = self.memo.get_mut(&key) {
            if let Some((_, k)) = m.panels.iter().find(|(d, _)| *d == delta) {
                let blk = ReencodedBlock { k: k.clone(), v: m.v.clone(), len: self.map[&key].len };
                m.last_used = now;
                self.stats.memo_hits += 1;
                return Some(blk);
            }
        }
        self.stats.memo_misses += 1;

        // 2. Delta mode: rotate the nearest memoized panel by Δ₂−Δ₁
        //    instead of re-deriving from the stored codes. Ties break
        //    toward the smaller Δ so the hop is deterministic.
        if self.reencode_mode == ReencodeMode::Delta {
            let base = self.memo.get(&key).and_then(|m| {
                m.panels
                    .iter()
                    .min_by_key(|(d, _)| ((*d as i64 - delta as i64).abs(), *d))
                    .map(|(d, k)| (*d, k.clone()))
            });
            if let Some((d1, mut k)) = base {
                let dims = k.dims().to_vec();
                let hop = delta as i64 - d1 as i64;
                self.rope.rotate_panel(
                    k.data_mut(),
                    dims[0],
                    dims[1],
                    dims[2],
                    hop,
                    &mut self.angles,
                );
                let v = self.memo[&key].v.clone();
                let len = self.map[&key].len;
                self.stats.delta_rotations += 1;
                self.memoize(key, delta, &k, &v, now);
                return Some(ReencodedBlock { k, v, len });
            }
        }

        // 3. Memo-cold: derive from the stored codes through the
        //    unified path (also delta mode's first fetch of a block).
        let e = &self.map[&key];
        let (dims, view) = match &e.data {
            KvData::F32 { k_local, .. } => {
                let d = k_local.dims();
                ([d[0], d[1], d[2], d[3]], KvView::F32(k_local.data()))
            }
            KvData::Int8 { k, .. } => (k.dims, KvView::Int8 { q: &k.q, scales: &k.scales }),
            KvData::Int4 { k, .. } => {
                (k.dims, KvView::Int4 { packed: &k.packed, scales: &k.scales })
            }
        };
        let mut kf: TensorF = Tensor::zeros(&dims);
        self.rope.reencode_into(
            view,
            dims[0],
            dims[1],
            dims[2],
            delta as i64,
            &mut self.angles,
            kf.data_mut(),
        );
        let v = match &e.data {
            KvData::F32 { v, .. } => v.clone(),
            KvData::Int8 { v, .. } => v.dequantize(),
            KvData::Int4 { v, .. } => v.dequantize(),
        };
        let len = e.len;
        self.memoize(key, delta, &kf, &v, now);
        Some(ReencodedBlock { k: kf, v, len })
    }

    /// Record a freshly rotated K panel (and the shared V) in the
    /// rotation memo, then trim the memo to its byte budget.
    fn memoize(&mut self, key: u128, delta: usize, k: &TensorF, v: &TensorF, now: u64) {
        match self.memo.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut o) => {
                let m = o.get_mut();
                m.last_used = now;
                if !m.panels.iter().any(|(d, _)| *d == delta) {
                    m.panels.push((delta, k.clone()));
                }
            }
            std::collections::hash_map::Entry::Vacant(slot) => {
                slot.insert(MemoEntry {
                    v: v.clone(),
                    panels: vec![(delta, k.clone())],
                    last_used: now,
                });
            }
        }
        self.enforce_memo_budget();
    }

    /// Trim the memo to its byte budget, dropping least-recently-used
    /// whole entries first (an entry's panels share its V and die
    /// together). Unlike cache entries, memo panels are pure
    /// accelerators — always re-derivable — so even the entry that was
    /// just memoized may be dropped when it alone exceeds the budget.
    fn enforce_memo_budget(&mut self) {
        if self.memo_budget == 0 {
            return;
        }
        let mut total: usize = self.memo.values().map(|m| m.bytes()).sum();
        while total > self.memo_budget {
            let victim = self
                .memo
                .iter()
                .min_by_key(|(k, m)| (m.last_used, **k))
                .map(|(k, _)| *k);
            let Some(k) = victim else { break };
            let dropped = self.memo.remove(&k).expect("victim vanished");
            total -= dropped.bytes();
            self.stats.memo_evictions += 1;
        }
    }

    /// Drop `key`'s memoized panels: the base entry left RAM (or was
    /// replaced), so derived panels must not outlive it.
    fn invalidate_memo(&mut self, key: u128) {
        if self.memo.remove(&key).is_some() {
            self.stats.memo_evictions += 1;
        }
    }

    /// Length (tokens) of a cached block.
    pub fn block_len(&self, key: u128) -> Option<usize> {
        self.map.get(&key).map(|e| e.len)
    }

    /// Drop every entry (required whenever model parameters change —
    /// cached KV states are functions of the weights). Panics if any
    /// entry is still pinned: clearing mid-request is a logic error.
    /// The attached disk store (if any) is detached too: its
    /// fingerprint binds it to the old weights, so keeping the handle
    /// would be a stale-reuse hazard — re-attach with a fresh
    /// fingerprint after the update. Nothing is spilled on the way out.
    pub fn clear(&mut self) {
        assert!(
            self.map.values().all(|e| e.pins == 0),
            "clear() with pinned entries"
        );
        self.map.clear();
        self.memo.clear();
        self.store = None;
    }

    /// Drop every **unpinned** resident entry *without* spilling,
    /// keeping the attached store untouched — the disk-warm measurement
    /// aid (benches and restart tests: after a flush, the next lookups
    /// must come back through promotion). Unlike [`Self::clear`] this
    /// is not tied to a weights change. Returns the number dropped.
    pub fn drop_resident(&mut self) -> usize {
        let before = self.map.len();
        let dropped: Vec<u128> =
            self.map.iter().filter(|(_, e)| e.pins == 0).map(|(k, _)| *k).collect();
        self.map.retain(|_, e| e.pins > 0);
        for k in dropped {
            self.invalidate_memo(k);
        }
        before - self.map.len()
    }

    /// Write-behind one evicted block to the attached store. A no-op
    /// without a store or when the file already exists (content
    /// addressing makes re-spills idempotent); a write failure is loud
    /// but non-fatal — the block is simply lost to recompute.
    fn spill(&mut self, key: u128, data: &KvData, len: usize) {
        let Some(st) = self.store.as_mut() else { return };
        match st.put(key, data, len) {
            Ok(true) => self.stats.disk_spills += 1,
            Ok(false) => {}
            Err(e) => {
                eprintln!("kv-store: spill failed: {e:#}");
                self.stats.disk_errors += 1;
            }
        }
    }

    /// Persist every resident block to the attached store without
    /// evicting anything — the explicit flush behind
    /// [`crate::coordinator::Coordinator::flush_kv_store`] (offline
    /// precompute, graceful shutdown, tests). Returns the number of
    /// blocks newly written; a no-op without a store.
    pub fn spill_all(&mut self) -> usize {
        let Some(mut st) = self.store.take() else { return 0 };
        let mut keys: Vec<u128> = self.map.keys().copied().collect();
        keys.sort_unstable(); // deterministic write order
        let mut written = 0;
        for k in keys {
            let e = &self.map[&k];
            match st.put(k, &e.data, e.len) {
                Ok(true) => {
                    written += 1;
                    self.stats.disk_spills += 1;
                }
                Ok(false) => {}
                Err(err) => {
                    eprintln!("kv-store: flush failed: {err:#}");
                    self.stats.disk_errors += 1;
                }
            }
        }
        self.store = Some(st);
        written
    }

    fn enforce_budget(&mut self) {
        if self.byte_budget == 0 {
            return;
        }
        let mut total: usize = self.map.values().map(|e| e.bytes).sum();
        while total > self.byte_budget {
            // Evict the least-recently-used unpinned entry.
            let victim = self
                .map
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    let e = self.map.remove(&k).unwrap();
                    total -= e.bytes;
                    self.stats.evictions += 1;
                    self.invalidate_memo(k);
                    self.spill(k, &e.data, e.len);
                }
                None => break, // everything pinned; over-budget transiently
            }
        }
    }
}

/// `(stored bytes, f32-equivalent bytes)` of a block payload — the
/// accounting pair a promoted entry needs (mirrors what
/// [`BlockKvCache::insert_pinned`] computes on the insert path).
fn block_sizes(data: &KvData) -> (usize, usize) {
    match data {
        KvData::F32 { k_local, v } => {
            let b = k_local.size_bytes() + v.size_bytes();
            (b, b)
        }
        KvData::Int8 { k, v } => {
            let n: usize = k.dims.iter().product();
            (k.size_bytes() + v.size_bytes(), 2 * n * 4)
        }
        KvData::Int4 { k, v } => {
            let n: usize = k.dims.iter().product();
            (k.size_bytes() + v.size_bytes(), 2 * n * 4)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::{prop_assert, prop_assert_eq};

    fn rope() -> RopeTable {
        RopeTable::new(8, 10000.0)
    }

    fn kv(len: usize, fill: f32) -> (TensorF, TensorF) {
        let mut k = Tensor::zeros(&[2, len, 1, 8]);
        k.data_mut().iter_mut().for_each(|x| *x = fill);
        (k.clone(), k)
    }

    #[test]
    fn key_is_content_addressed() {
        assert_eq!(block_key(&[1, 2, 3]), block_key(&[1, 2, 3]));
        assert_ne!(block_key(&[1, 2, 3]), block_key(&[1, 2, 4]));
        assert_ne!(block_key(&[1, 2]), block_key(&[1, 2, 0]));
        assert_ne!(block_key(&[]), block_key(&[0]));
    }

    #[test]
    fn hit_rate_is_finite_with_no_lookups() {
        let c = BlockKvCache::new(rope(), 0);
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 0);
        assert_eq!(s.hit_rate(), 0.0, "0/0 lookups must report 0.0, not NaN");
        // And saturates rather than overflowing at the extremes.
        let extreme = CacheStats { hits: u64::MAX, misses: u64::MAX, ..Default::default() };
        assert!(extreme.hit_rate().is_finite());
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = BlockKvCache::new(rope(), 0);
        let key = block_key(&[5, 6]);
        assert!(!c.lookup_pin(key));
        let (k, v) = kv(2, 1.0);
        c.insert_pinned(key, k, v);
        assert!(c.lookup_pin(key));
        let s = c.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.insertions, 1);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn pin_does_not_count_as_lookup() {
        let mut c = BlockKvCache::new(rope(), 0);
        let key = block_key(&[1, 2]);
        assert!(!c.pin(key), "pin of an absent key must fail");
        let (k, v) = kv(2, 1.0);
        c.insert_pinned(key, k, v);
        assert!(c.pin(key));
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "pin must not touch stats");
        // Both pins must be released before the entry can be evicted.
        c.unpin(key);
        c.unpin(key);
    }

    #[test]
    fn reencode_delta_zero_returns_cached() {
        let mut c = BlockKvCache::new(rope(), 0);
        let key = block_key(&[1]);
        let (k, v) = kv(3, 2.5);
        c.insert_pinned(key, k.clone(), v);
        let b = c.get_reencoded(key, 0).unwrap();
        assert_eq!(b.k, k);
        assert_eq!(b.len, 3);
    }

    #[test]
    fn reencode_rotates_keys() {
        let mut c = BlockKvCache::new(rope(), 0);
        let key = block_key(&[1]);
        let (k, v) = kv(3, 1.0);
        c.insert_pinned(key, k.clone(), v);
        let b = c.get_reencoded(key, 10).unwrap();
        assert!(b.k.max_abs_diff(&k) > 1e-3);
        // Norm preserved per head row.
        let n1: f32 = k.data().iter().map(|x| x * x).sum();
        let n2: f32 = b.k.data().iter().map(|x| x * x).sum();
        assert!((n1 - n2).abs() / n1 < 1e-4);
    }

    #[test]
    fn lru_eviction_respects_pins_and_budget() {
        // Each block: 2 layers * 4 tokens * 1 head * 8 dim * 4B * 2 (K+V)
        // = 512 bytes. Budget of 1024 holds two blocks.
        let mut c = BlockKvCache::new(rope(), 1024);
        let k1 = block_key(&[1]);
        let k2 = block_key(&[2]);
        let k3 = block_key(&[3]);
        let (k, v) = kv(4, 1.0);
        c.insert_pinned(k1, k.clone(), v.clone());
        c.insert_pinned(k2, k.clone(), v.clone());
        // Everything pinned: inserting a third exceeds the budget but
        // nothing can be evicted.
        c.insert_pinned(k3, k.clone(), v.clone());
        assert_eq!(c.stats().entries, 3);
        assert_eq!(c.stats().evictions, 0);
        // Unpin k1 (oldest) → it becomes the victim.
        c.unpin(k1);
        assert_eq!(c.stats().entries, 2);
        assert!(!c.contains(k1));
        assert!(c.contains(k2) && c.contains(k3));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn lru_order_follows_use() {
        let mut c = BlockKvCache::new(rope(), 1024);
        let k1 = block_key(&[1]);
        let k2 = block_key(&[2]);
        let (k, v) = kv(4, 1.0);
        c.insert_pinned(k1, k.clone(), v.clone());
        c.insert_pinned(k2, k.clone(), v.clone());
        c.unpin(k1);
        c.unpin(k2);
        // Touch k1 so k2 becomes LRU.
        assert!(c.lookup_pin(k1));
        c.unpin(k1);
        let k3 = block_key(&[3]);
        c.insert_pinned(k3, k.clone(), v.clone());
        c.unpin(k3);
        assert!(c.contains(k1), "recently used survives");
        assert!(!c.contains(k2), "LRU evicted");
    }

    /// The LRU victim scan must *skip* pinned entries: with the oldest
    /// entry pinned, eviction takes the next-oldest unpinned one and the
    /// pinned entry survives.
    #[test]
    fn lru_eviction_skips_pinned_oldest() {
        // Blocks are 512 bytes (see above); budget holds two.
        let mut c = BlockKvCache::new(rope(), 1024);
        let (k, v) = kv(4, 1.0);
        let k1 = block_key(&[1]);
        let k2 = block_key(&[2]);
        let k3 = block_key(&[3]);
        c.insert_pinned(k1, k.clone(), v.clone()); // oldest, stays pinned
        c.insert_pinned(k2, k.clone(), v.clone());
        c.unpin(k2);
        c.insert_pinned(k3, k.clone(), v.clone());
        // k1 is LRU but pinned: the victim must be k2.
        assert!(c.contains(k1), "pinned LRU entry was evicted");
        assert!(!c.contains(k2), "unpinned next-LRU entry survived");
        assert!(c.contains(k3));
        assert_eq!(c.stats().evictions, 1);
        c.unpin(k1);
        c.unpin(k3);
    }

    /// An insert larger than the entire byte budget must not wedge the
    /// cache: the entry lives while pinned (transiently over budget),
    /// is evicted at unpin, and the cache keeps serving afterwards.
    #[test]
    fn oversized_insert_does_not_wedge() {
        let mut c = BlockKvCache::new(rope(), 512);
        let big = block_key(&[9]);
        let (k, v) = kv(8, 1.0); // 1024 bytes — twice the whole budget
        c.insert_pinned(big, k, v);
        assert!(c.contains(big), "pinned oversize entry must be usable");
        assert!(c.get_reencoded(big, 3).is_some());
        assert!(c.stats().bytes > 512, "transiently over budget while pinned");
        c.unpin(big);
        assert!(!c.contains(big), "oversize entry must go at unpin");
        assert_eq!(c.stats().bytes, 0);
        assert_eq!(c.stats().evictions, 1);
        // The cache still admits and serves normal blocks.
        let small = block_key(&[10]);
        let (k, v) = kv(4, 2.0); // 512 bytes — exactly the budget
        c.insert_pinned(small, k, v);
        c.unpin(small);
        assert!(c.contains(small));
        assert!(c.lookup_pin(small));
        c.unpin(small);
        assert!(c.stats().bytes <= 512);
    }

    fn kv_rand(rng: &mut Rng, len: usize) -> (TensorF, TensorF) {
        let dims = [2usize, len, 1, 8];
        let n: usize = dims.iter().product();
        let mk = |rng: &mut Rng| {
            Tensor::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect())
        };
        (mk(rng), mk(rng))
    }

    /// The int8 tier: ≤ 30% of the f32 bytes per block, a small and
    /// finite relative error, and a fetch path that is bitwise identical
    /// to dequantize-then-f32-re-encode.
    #[test]
    fn int8_tier_shrinks_bytes_and_reencodes_bitwise() {
        let mut rng = Rng::new(0x18);
        let mut c8 = BlockKvCache::with_precision(rope(), 0, crate::config::KvPrecision::Int8);
        assert_eq!(c8.precision(), crate::config::KvPrecision::Int8);
        let key = block_key(&[42]);
        let (k, v) = kv_rand(&mut rng, 64);
        let f32_bytes = k.size_bytes() + v.size_bytes();
        c8.insert_pinned(key, k.clone(), v.clone());
        let s = c8.stats();
        assert!(
            s.bytes * 10 <= f32_bytes * 3,
            "int8 block {} bytes > 30% of f32 {f32_bytes}",
            s.bytes
        );
        assert_eq!(s.bytes_saved, f32_bytes - s.bytes);
        let rel = s.quant_rel_err();
        assert!(rel > 0.0 && rel < 0.01, "relative error {rel} out of range");

        // Reconstruction error is bounded per element.
        let b0 = c8.get_reencoded(key, 0).unwrap();
        assert!(b0.k.max_abs_diff(&k) < 0.05);
        assert!(b0.v.max_abs_diff(&v) < 0.05);

        // Fused dequant+re-encode == storing the dequantized states in
        // an f32 cache and re-encoding there, bit for bit.
        let mut cf = BlockKvCache::new(rope(), 0);
        cf.insert_pinned(key, b0.k.clone(), b0.v.clone());
        for delta in [0usize, 7, 1000] {
            let a = c8.get_reencoded(key, delta).unwrap();
            let b = cf.get_reencoded(key, delta).unwrap();
            assert_eq!(a.k, b.k, "fused re-encode differs at delta={delta}");
            assert_eq!(a.v, b.v);
            assert_eq!(a.len, 64);
        }
        c8.unpin(key);
        cf.unpin(key);
    }

    /// The int4 tier: ≤ 16% of the f32 bytes per block (codes are ⅛,
    /// plus the group-wise scale table), finite error, and a fetch path
    /// bitwise identical to dequantize-then-f32-re-encode.
    #[test]
    fn int4_tier_shrinks_bytes_and_reencodes_bitwise() {
        let mut rng = Rng::new(0x14);
        let mut c4 = BlockKvCache::with_precision(rope(), 0, crate::config::KvPrecision::Int4);
        assert_eq!(c4.precision(), crate::config::KvPrecision::Int4);
        let key = block_key(&[43]);
        let (k, v) = kv_rand(&mut rng, 64);
        let f32_bytes = k.size_bytes() + v.size_bytes();
        c4.insert_pinned(key, k.clone(), v.clone());
        let s = c4.stats();
        assert!(
            s.bytes * 100 <= f32_bytes * 16,
            "int4 block {} bytes > 16% of f32 {f32_bytes}",
            s.bytes
        );
        assert_eq!(s.bytes_saved, f32_bytes - s.bytes);
        assert_eq!(s.bytes_saved_int4, s.bytes_saved, "saving must be attributed to int4");
        assert_eq!(s.bytes_saved_int8, 0);
        let rel = s.quant_rel_err();
        assert!(rel > 0.0 && rel < 0.15, "relative error {rel} out of range");

        // Reconstruction error is bounded per element (scale/2 with
        // per-group amax over ~2.5σ of N(0,1) data).
        let b0 = c4.get_reencoded(key, 0).unwrap();
        assert!(b0.k.max_abs_diff(&k) < 0.35);
        assert!(b0.v.max_abs_diff(&v) < 0.35);

        // Fused unpack+dequant+re-encode == storing the dequantized
        // states in an f32 cache and re-encoding there, bit for bit.
        let mut cf = BlockKvCache::new(rope(), 0);
        cf.insert_pinned(key, b0.k.clone(), b0.v.clone());
        for delta in [0usize, 7, 1000] {
            let a = c4.get_reencoded(key, delta).unwrap();
            let b = cf.get_reencoded(key, delta).unwrap();
            assert_eq!(a.k, b.k, "fused int4 re-encode differs at delta={delta}");
            assert_eq!(a.v, b.v);
            assert_eq!(a.len, 64);
        }
        c4.unpin(key);
        cf.unpin(key);
    }

    /// Mixed-tier coexistence: precision changed between inserts leaves
    /// earlier entries at their original tier, with exact per-tier byte
    /// accounting and LRU eviction order that ignores tiers.
    #[test]
    fn mixed_tier_population_accounts_and_evicts_correctly() {
        let mut rng = Rng::new(0x3711);
        let mut c = BlockKvCache::new(rope(), 0);
        let (kf, vf) = kv_rand(&mut rng, 32);
        let f32_bytes = kf.size_bytes() + vf.size_bytes();
        let (key_f, key_8, key_4) = (block_key(&[1]), block_key(&[2]), block_key(&[3]));

        c.insert_pinned(key_f, kf.clone(), vf.clone());
        assert_eq!(c.stats().quant_rel_err(), 0.0, "f32 insert must not record error");
        c.set_precision(crate::config::KvPrecision::Int8);
        assert_eq!(c.precision(), crate::config::KvPrecision::Int8);
        let (k8, v8) = kv_rand(&mut rng, 32);
        c.insert_pinned(key_8, k8, v8);
        c.set_precision(crate::config::KvPrecision::Int4);
        let (k4, v4) = kv_rand(&mut rng, 32);
        c.insert_pinned(key_4, k4, v4);

        let s = c.stats();
        assert_eq!(s.entries, 3);
        // Per-tier savings: the f32 entry saves nothing, the int8 entry
        // ~75%, the int4 entry ~85% — and the totals must reconcile.
        assert!(s.bytes_saved_int8 * 10 >= f32_bytes * 7, "int8 saving too small");
        assert!(s.bytes_saved_int4 > s.bytes_saved_int8, "int4 must save more than int8");
        assert_eq!(s.bytes_saved, s.bytes_saved_int8 + s.bytes_saved_int4);
        assert_eq!(s.bytes + s.bytes_saved, 3 * f32_bytes, "bytes + saved == f32 total");
        let rel = s.quant_rel_err();
        assert!(rel > 0.0 && rel < 0.15, "mixed-tier relative error {rel}");

        // Every tier still serves (the f32 entry stayed f32: lossless).
        let bf = c.get_reencoded(key_f, 5).unwrap();
        let mut kf_want = kf.clone();
        {
            let d = kf_want.dims().to_vec();
            rope().reencode_block(kf_want.data_mut(), d[0], d[1], d[2], 5);
        }
        assert_eq!(bf.k, kf_want, "resident f32 entry must stay bit-lossless");
        assert!(c.get_reencoded(key_8, 5).is_some());
        assert!(c.get_reencoded(key_4, 5).is_some());

        // Eviction order is LRU across tiers, not per tier: unpin all,
        // touch the f32 entry, then shrink the budget so only the two
        // most-recent survive — the *int8* entry (oldest untouched) goes.
        c.unpin(key_f);
        c.unpin(key_8);
        c.unpin(key_4);
        assert!(c.lookup_pin(key_f));
        c.unpin(key_f);
        c.byte_budget = c.stats().bytes - 1; // force exactly one eviction
        c.enforce_budget();
        assert!(!c.contains(key_8), "LRU (int8) entry must evict first");
        assert!(c.contains(key_f) && c.contains(key_4));
        let s2 = c.stats();
        assert_eq!(s2.evictions, 1);
        // Per-tier stats track the eviction: no int8 savings remain.
        assert_eq!(s2.bytes_saved_int8, 0);
        assert!(s2.bytes_saved_int4 > 0);
    }

    /// The oversized-insert and pinned-LRU edges hold on the quantized
    /// tiers exactly as on f32 (sizes just shrink).
    #[test]
    fn quantized_tiers_keep_eviction_edges() {
        for prec in [crate::config::KvPrecision::Int8, crate::config::KvPrecision::Int4] {
            let mut rng = Rng::new(0xE3);
            // Budget below one quantized block: the pinned insert must
            // stay usable and go at unpin.
            let (k, v) = kv_rand(&mut rng, 32);
            let mut c = BlockKvCache::with_precision(rope(), 64, prec);
            let big = block_key(&[9]);
            c.insert_pinned(big, k.clone(), v.clone());
            assert!(c.contains(big), "{prec:?}: pinned oversize entry must be usable");
            assert!(c.get_reencoded(big, 3).is_some());
            c.unpin(big);
            assert!(!c.contains(big), "{prec:?}: oversize entry must go at unpin");
            assert_eq!(c.stats().evictions, 1);

            // Pinned-LRU skip: oldest pinned survives, next-oldest goes.
            let one_block = {
                let mut probe = BlockKvCache::with_precision(rope(), 0, prec);
                probe.insert_pinned(big, k.clone(), v.clone());
                probe.stats().bytes
            };
            let mut c = BlockKvCache::with_precision(rope(), 2 * one_block, prec);
            let (k1, k2, k3) = (block_key(&[1]), block_key(&[2]), block_key(&[3]));
            c.insert_pinned(k1, k.clone(), v.clone()); // oldest, stays pinned
            c.insert_pinned(k2, k.clone(), v.clone());
            c.unpin(k2);
            c.insert_pinned(k3, k.clone(), v.clone());
            assert!(c.contains(k1), "{prec:?}: pinned LRU entry was evicted");
            assert!(!c.contains(k2), "{prec:?}: unpinned next-LRU entry survived");
            assert!(c.contains(k3));
            c.unpin(k1);
            c.unpin(k3);
        }
    }

    #[test]
    fn f32_tier_reports_zero_quant_stats() {
        let mut c = BlockKvCache::new(rope(), 0);
        assert_eq!(c.precision(), crate::config::KvPrecision::F32);
        let key = block_key(&[1]);
        let (k, v) = kv(4, 1.5);
        c.insert_pinned(key, k, v);
        let s = c.stats();
        assert_eq!(s.bytes_saved, 0);
        assert_eq!(s.quant_rel_err(), 0.0);
        c.unpin(key);
    }

    fn store_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("block-attn-kvcache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Spill → drop → promote must be bitwise invisible at every tier:
    /// the re-encoded fetch after a disk round-trip equals the fetch
    /// from the never-evicted entry, and a fresh cache on the same dir
    /// (the restart path) promotes to the same bytes.
    #[test]
    fn disk_roundtrip_is_bitwise_per_tier() {
        use crate::config::KvPrecision;
        for prec in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
            let dir = store_dir(&format!("tier-{prec:?}"));
            let mut rng = Rng::new(0xD00D);
            let key = block_key(&[7, 8, 9]);
            let (k, v) = kv_rand(&mut rng, 40);

            let mut c = BlockKvCache::with_precision(rope(), 0, prec);
            c.attach_store(disk::DiskStore::open(&dir, 0xF1, 0).unwrap());
            c.insert_pinned(key, k.clone(), v.clone());
            let want = c.get_reencoded(key, 13).unwrap();
            c.unpin(key);
            assert_eq!(c.spill_all(), 1);
            assert_eq!(c.drop_resident(), 1);
            assert!(!c.contains(key) && c.contains_anywhere(key));

            // Promotion through the normal lookup path...
            assert!(c.lookup_pin(key), "promotion must serve the lookup");
            let got = c.get_reencoded(key, 13).unwrap();
            assert_eq!(got.k, want.k, "{prec:?}: promoted keys differ");
            assert_eq!(got.v, want.v, "{prec:?}: promoted values differ");
            assert_eq!(got.len, want.len);
            c.unpin(key);
            let s = c.stats();
            assert_eq!((s.disk_hits, s.disk_spills, s.disk_errors), (1, 1, 0));
            assert!(s.disk_entries == 1 && s.disk_bytes > 0);

            // ...and from a fresh cache on the same directory (the
            // restart path).
            let mut c2 = BlockKvCache::with_precision(rope(), 0, prec);
            c2.attach_store(disk::DiskStore::open(&dir, 0xF1, 0).unwrap());
            assert!(c2.lookup_pin(key));
            let got2 = c2.get_reencoded(key, 13).unwrap();
            assert_eq!(got2.k, want.k, "{prec:?}: restart promotion differs");
            assert_eq!(got2.v, want.v);
            c2.unpin(key);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// Eviction write-behind: the LRU victim lands on disk and comes
    /// back through promotion instead of recompute.
    #[test]
    fn eviction_spills_and_lookup_promotes() {
        let dir = store_dir("evict");
        // 512-byte blocks (see the LRU tests); budget holds one.
        let mut c = BlockKvCache::new(rope(), 512);
        c.attach_store(disk::DiskStore::open(&dir, 1, 0).unwrap());
        let k1 = block_key(&[1]);
        let k2 = block_key(&[2]);
        let (k, v) = kv(4, 1.0);
        c.insert_pinned(k1, k.clone(), v.clone());
        c.unpin(k1);
        c.insert_pinned(k2, k.clone(), v.clone());
        c.unpin(k2); // k1 was evicted + spilled during the k2 insert
        assert!(!c.contains(k1));
        assert_eq!(c.stats().disk_spills, 1);
        assert!(c.lookup_pin(k1), "spilled block must promote");
        assert_eq!(c.stats().disk_hits, 1);
        c.unpin(k1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `clear()` is the weights-changed hook: it must drop the
    /// weights-bound store handle along with the entries, and spill
    /// nothing on the way out.
    #[test]
    fn clear_detaches_the_store() {
        let dir = store_dir("clear");
        let mut c = BlockKvCache::new(rope(), 0);
        c.attach_store(disk::DiskStore::open(&dir, 1, 0).unwrap());
        assert!(c.store().is_some());
        let key = block_key(&[1]);
        let (k, v) = kv(2, 1.0);
        c.insert_pinned(key, k, v);
        c.unpin(key);
        c.clear();
        assert!(c.store().is_none(), "clear() must drop the weights-bound store");
        assert!(!c.contains_anywhere(key), "nothing may be spilled by clear()");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prop_pins_balance_and_budget_holds() {
        prop::check("kvcache-invariants", 0xCAFE, 200, |rng: &mut Rng| {
            let budget = 512 * (1 + rng.below(4));
            let mut c = BlockKvCache::new(rope(), budget);
            let mut pins: std::collections::HashMap<u128, usize> = Default::default();
            for _ in 0..rng.range(5, 60) {
                let id = rng.below(8) as i32;
                let key = block_key(&[id]);
                match rng.below(3) {
                    0 => {
                        if c.lookup_pin(key) {
                            *pins.entry(key).or_default() += 1;
                        } else {
                            let (k, v) = kv(4, id as f32);
                            c.insert_pinned(key, k, v);
                            *pins.entry(key).or_default() += 1;
                        }
                    }
                    1 => {
                        if pins.get(&key).copied().unwrap_or(0) > 0 {
                            c.unpin(key);
                            *pins.get_mut(&key).unwrap() -= 1;
                        }
                    }
                    _ => {
                        let _ = c.get_reencoded(key, rng.below(100));
                    }
                }
                // Pinned entries must always be present.
                for (k, &p) in &pins {
                    if p > 0 {
                        prop_assert!(c.contains(*k), "pinned block evicted");
                    }
                }
            }
            // Release all pins: budget must then hold.
            for (k, p) in pins {
                for _ in 0..p {
                    c.unpin(k);
                }
            }
            let s = c.stats();
            prop_assert!(
                s.bytes <= budget,
                "bytes {} exceed budget {budget} with no pins",
                s.bytes
            );
            prop_assert_eq!(s.hits + s.misses >= 1, true);
            Ok(())
        });
    }
}
