//! Continuous batching.
//!
//! vLLM-style scheduling adapted to this runtime: requests are admitted
//! FIFO under a slot + token budget; each admitted request runs its
//! prefill (which defines its TTFT), then all active requests advance
//! one decode token per round (round-robin). When a request finishes its
//! slot is immediately refilled — prefills interleave with ongoing
//! decodes exactly as in continuous batching.
//!
//! The batcher is generic over a [`BatchExec`] so its scheduling
//! invariants are property-tested with a mock executor, independent of
//! the XLA engine.

use super::{Coordinator, DecodeState, Request, Response};
use crate::runtime::Backend;
use crate::tokenizer::EOS;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

/// Execution interface the batcher drives.
pub trait BatchExec {
    type State;
    /// Run prefill; returns decode state + the response skeleton holding
    /// the first token and final TTFT/FLOPs numbers.
    fn do_prefill(&mut self, req: &Request, t0: Instant) -> Result<(Self::State, Response)>;
    /// Advance one decode step.
    fn do_decode(&mut self, state: &mut Self::State, last: i32) -> Result<i32>;
}

impl<B: Backend> BatchExec for Coordinator<B> {
    type State = DecodeState;

    fn do_prefill(&mut self, req: &Request, t0: Instant) -> Result<(DecodeState, Response)> {
        self.prefill(req, t0)
    }

    fn do_decode(&mut self, state: &mut DecodeState, last: i32) -> Result<i32> {
        self.decode_one(state, last)
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max concurrently-decoding requests.
    pub max_active: usize,
    /// Max summed prompt tokens across active requests (backpressure).
    pub max_active_tokens: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_active: 4, max_active_tokens: 16 * 1024 }
    }
}

struct Active<S> {
    req: Request,
    state: S,
    resp: Response,
    done: bool,
}

/// Run a closed set of requests to completion with continuous batching.
/// Responses are returned in completion order.
pub fn run_batch<E: BatchExec>(
    exec: &mut E,
    requests: Vec<Request>,
    policy: &BatchPolicy,
) -> Result<Vec<Response>> {
    let mut queue: VecDeque<Request> = requests.into();
    let mut active: Vec<Active<E::State>> = Vec::new();
    let mut done: Vec<Response> = Vec::new();
    let t_admit = Instant::now();

    loop {
        // Admission: fill free slots FIFO under the token budget.
        while active.len() < policy.max_active {
            let fits = match queue.front() {
                None => false,
                Some(next) => {
                    let in_flight: usize =
                        active.iter().map(|a| a.req.prompt_tokens()).sum();
                    active.is_empty()
                        || in_flight + next.prompt_tokens() <= policy.max_active_tokens
                }
            };
            if !fits {
                break;
            }
            let req = queue.pop_front().unwrap();
            // TTFT includes queueing time from batch start — the latency a
            // client actually observes.
            let (state, resp) = exec.do_prefill(&req, t_admit)?;
            let finished = resp.tokens.len() >= req.max_new_tokens
                || resp.tokens.last() == Some(&EOS);
            active.push(Active { req, state, resp, done: finished });
        }

        if active.is_empty() {
            break;
        }

        // One decode round across all active requests.
        for a in active.iter_mut() {
            if a.done {
                continue;
            }
            let last = *a.resp.tokens.last().unwrap();
            if last == EOS || a.resp.tokens.len() >= a.req.max_new_tokens {
                a.done = true;
                continue;
            }
            let next = exec.do_decode(&mut a.state, last)?;
            a.resp.tokens.push(next);
            if next == EOS || a.resp.tokens.len() >= a.req.max_new_tokens {
                a.done = true;
            }
        }

        // Retire finished requests (their slots free immediately).
        let mut i = 0;
        while i < active.len() {
            if active[i].done {
                let a = active.remove(i);
                done.push(a.resp);
            } else {
                i += 1;
            }
        }
    }
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AttentionMode;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::{prop_assert, prop_assert_eq};

    /// Mock executor: generates `id`-derived tokens, records order.
    struct Mock {
        prefill_order: Vec<u64>,
        decode_calls: usize,
    }

    impl BatchExec for Mock {
        type State = u64;

        fn do_prefill(&mut self, req: &Request, t0: Instant) -> Result<(u64, Response)> {
            self.prefill_order.push(req.id);
            Ok((
                req.id,
                Response {
                    id: req.id,
                    tokens: vec![1],
                    ttft: t0.elapsed().as_secs_f64(),
                    block_prefill_s: 0.0,
                    flops_tft: 0.0,
                    cached_blocks: 0,
                    total_blocks: req.blocks.len(),
                    prompt_tokens: req.prompt_tokens(),
                },
            ))
        }

        fn do_decode(&mut self, state: &mut u64, last: i32) -> Result<i32> {
            self.decode_calls += 1;
            // Request `id` emits EOS after id%5 + 1 decode steps.
            let _ = last;
            *state += 1 << 32;
            let steps = (*state >> 32) as i32;
            if steps > (*state as u32 % 5) as i32 {
                Ok(EOS)
            } else {
                Ok(2)
            }
        }
    }

    fn req(id: u64, ntoks: usize, max_new: usize) -> Request {
        Request {
            id,
            blocks: vec![vec![0; ntoks]],
            query: vec![1, 2],
            max_new_tokens: max_new,
            mode: AttentionMode::Block,
        }
    }

    #[test]
    fn all_requests_complete_in_fifo_prefill_order() {
        let mut mock = Mock { prefill_order: vec![], decode_calls: 0 };
        let reqs: Vec<Request> = (0..10).map(|i| req(i, 8, 4)).collect();
        let out = run_batch(&mut mock, reqs, &BatchPolicy { max_active: 3, max_active_tokens: 1000 }).unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(mock.prefill_order, (0..10).collect::<Vec<_>>());
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn token_budget_limits_admission() {
        let mut mock = Mock { prefill_order: vec![], decode_calls: 0 };
        // Each request has 100 prompt tokens; budget 150 → one at a time
        // (the first always admits).
        let reqs: Vec<Request> = (0..3).map(|i| req(i, 98, 3)).collect();
        let out = run_batch(
            &mut mock,
            reqs,
            &BatchPolicy { max_active: 8, max_active_tokens: 150 },
        )
        .unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn max_new_tokens_respected() {
        let mut mock = Mock { prefill_order: vec![], decode_calls: 0 };
        let out = run_batch(
            &mut mock,
            vec![req(7, 4, 2)],
            &BatchPolicy::default(),
        )
        .unwrap();
        assert!(out[0].tokens.len() <= 2);
    }

    #[test]
    fn prop_batcher_invariants() {
        prop::check("batcher-invariants", 0xFEED, 150, |rng: &mut Rng| {
            let n = rng.range(1, 20);
            let reqs: Vec<Request> = (0..n as u64)
                .map(|i| req(i, rng.range(1, 50), rng.range(1, 8)))
                .collect();
            let policy = BatchPolicy {
                max_active: rng.range(1, 6),
                max_active_tokens: rng.range(60, 400),
            };
            let mut mock = Mock { prefill_order: vec![], decode_calls: 0 };
            let out = run_batch(&mut mock, reqs, &policy).unwrap();
            prop_assert_eq!(out.len(), n);
            // No request starved: every id appears exactly once.
            let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            prop_assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
            // FIFO prefill admission.
            prop_assert_eq!(mock.prefill_order, (0..n as u64).collect::<Vec<_>>());
            // Token limits respected.
            for r in &out {
                prop_assert!(r.tokens.len() <= 8, "too many tokens");
                prop_assert!(!r.tokens.is_empty(), "no first token");
            }
            Ok(())
        });
    }
}
