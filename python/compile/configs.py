"""Model configurations and AOT bucket tables.

Single source of truth for the shapes the AOT pipeline emits; the Rust
side reads everything back from ``artifacts/manifest.json`` and never
hardcodes a dimension.

Configs:
  tiny  -- the trainable model for the accuracy experiments (Tables 1-2,
           Figure 4). Byte-level vocab, ~1M params, trains in minutes on
           the 1-core CI box.
  small -- a larger untrained config exercising GQA and longer contexts in
           the serving examples and integration tests.
  bench -- the Table-3 efficiency config: realistic vocab, 32K context.
           Never trained; used only for TTFT / FLOPs measurements.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    layers: int
    heads: int
    kv_heads: int
    d_ff: int
    max_len: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # "pallas" routes prefill attention through the L1 kernels;
    # "jnp" uses the chunked flash-style jnp path (CPU-fast, used for the
    # very long bench-config sequences — see DESIGN.md §Hardware-Adaptation).
    attn_impl: str = "pallas"
    # AOT buckets ----------------------------------------------------------
    full_lengths: tuple = ()          # prefill_full L buckets
    block_lengths: tuple = ()         # prefill_block Lb buckets
    final_ctx: tuple = ()             # prefill_final C buckets
    final_q: int = 64                 # prefill_final Lq capacity
    decode_ctx: tuple = ()            # decode_step cache capacity buckets
    train_batch: int = 0              # 0 = no train_step artifact
    train_len: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads


# Byte-level tokenizer: 256 byte values + specials (must match
# rust/src/tokenizer). PAD=256, BOS=257, EOS=258, SEP=259, QRY=260.
BYTE_VOCAB = 261
PAD, BOS, EOS, SEP, QRY = 256, 257, 258, 259, 260

TINY = ModelConfig(
    name="tiny",
    vocab=BYTE_VOCAB,
    d_model=128,
    layers=4,
    heads=4,
    kv_heads=2,
    d_ff=344,
    max_len=704,
    attn_impl="pallas",
    full_lengths=(128, 320, 640),
    block_lengths=(64, 128),
    final_ctx=(320, 640),
    final_q=64,
    decode_ctx=(704,),
    # B=8 x L=256: RAG samples are authored to fit 256 tokens, so each
    # step sees 8 full samples — sample-efficiency matters far more than
    # sequence length for the retrieval-copy circuit to form.
    train_batch=8,
    train_len=256,
)

SMALL = ModelConfig(
    name="small",
    vocab=BYTE_VOCAB,
    d_model=256,
    layers=6,
    heads=8,
    kv_heads=4,
    d_ff=688,
    max_len=2176,
    attn_impl="pallas",
    full_lengths=(512, 1024, 2048),
    block_lengths=(128, 256),
    final_ctx=(1024, 2048),
    final_q=128,
    decode_ctx=(2176,),
)

BENCH = ModelConfig(
    name="bench",
    vocab=32000,
    d_model=256,
    layers=4,
    heads=8,
    kv_heads=4,
    d_ff=688,
    max_len=32768,
    rope_theta=500000.0,
    attn_impl="jnp",
    full_lengths=(64, 512, 1024, 2048, 4096, 8192, 16384, 32768),
    block_lengths=(512,),
    final_ctx=(512, 1024, 2048, 4096, 8192, 16384, 32768),
    final_q=64,
    decode_ctx=(1024,),
)

CONFIGS = {c.name: c for c in (TINY, SMALL, BENCH)}
