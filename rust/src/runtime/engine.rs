//! [`ModelEngine`]: the request-path executor over AOT artifacts.
//!
//! One engine owns one model config: its PJRT client, lazily-compiled
//! executables (one per manifest entry), the current parameters (as
//! device-ready literals plus cached conversions) and — when training —
//! the Adam optimizer state.
//!
//! The engine is deliberately `!Send`: the `xla` crate wraps raw C
//! pointers. The coordinator runs it on a dedicated engine thread and
//! communicates over channels (see `coordinator::router`).

use super::literal::{buf_f, buf_i, buf_scalar_f, buf_scalar_i, literal_to_f32};
use super::params::read_flat_params;
use super::{Backend, DecodeOut, PrefillFinalOut, PrefillFullOut, TrainOut};
use crate::config::{ArtifactEntry, EntryKind, Manifest, ModelArtifacts};
use crate::tensor::{Tensor, TensorF, TensorI};
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

pub struct ModelEngine {
    client: xla::PjRtClient,
    arts: ModelArtifacts,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Current parameters, **device-resident**, in manifest order.
    /// Uploaded once per `set_params`; every entry-point execution
    /// borrows them (no per-call conversion or transfer).
    params: RefCell<Vec<xla::PjRtBuffer>>,
    /// Adam state (m, v), device-resident — allocated on first train step.
    opt_state: RefCell<Option<(Vec<xla::PjRtBuffer>, Vec<xla::PjRtBuffer>)>>,
}

impl ModelEngine {
    /// Create an engine for `model_name`, loading initial parameters from
    /// the manifest's `init_file` if present (zeros otherwise).
    pub fn new(manifest: &Manifest, model_name: &str) -> Result<ModelEngine> {
        let arts = manifest.model(model_name)?.clone();
        let client = xla::PjRtClient::cpu()?;
        let engine = ModelEngine {
            client,
            arts,
            exes: RefCell::new(HashMap::new()),
            params: RefCell::new(Vec::new()),
            opt_state: RefCell::new(None),
        };
        let init = engine.arts.init_file.clone();
        match init {
            Some(path) if path.exists() => engine.load_params_file(&path)?,
            _ => engine.set_params(
                engine
                    .arts
                    .params
                    .iter()
                    .map(|p| Tensor::zeros(&p.shape))
                    .collect(),
            )?,
        }
        Ok(engine)
    }

    pub fn artifacts(&self) -> &ModelArtifacts {
        &self.arts
    }

    pub fn config(&self) -> &crate::config::ModelConfig {
        &self.arts.config
    }

    // -- parameters --------------------------------------------------------

    /// Replace the parameters (checked against the manifest layout).
    pub fn set_params(&self, tensors: Vec<TensorF>) -> Result<()> {
        if tensors.len() != self.arts.params.len() {
            bail!(
                "expected {} parameter tensors, got {}",
                self.arts.params.len(),
                tensors.len()
            );
        }
        let mut bufs = Vec::with_capacity(tensors.len());
        for (spec, t) in self.arts.params.iter().zip(&tensors) {
            if spec.shape != t.dims() {
                bail!("param '{}' shape {:?} != {:?}", spec.name, t.dims(), spec.shape);
            }
            bufs.push(buf_f(&self.client, t)?);
        }
        *self.params.borrow_mut() = bufs;
        Ok(())
    }

    /// Load parameters from a flat little-endian f32 checkpoint file.
    pub fn load_params_file(&self, path: &std::path::Path) -> Result<()> {
        let tensors = read_flat_params(path, &self.arts.params)?;
        self.set_params(tensors)
    }

    /// Download the current parameters to host tensors (checkpointing).
    pub fn params_host(&self) -> Result<Vec<TensorF>> {
        self.params
            .borrow()
            .iter()
            .map(|b| literal_to_f32(&b.to_literal_sync()?))
            .collect()
    }

    /// Save the current parameters as a flat f32 checkpoint.
    pub fn save_params_file(&self, path: &std::path::Path) -> Result<()> {
        let tensors = self.params_host()?;
        super::params::write_flat_params(path, &tensors)
    }

    // -- executables ---------------------------------------------------

    fn exe(&self, entry: &ArtifactEntry) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&entry.name) {
            return Ok(e.clone());
        }
        let path = entry
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?,
        );
        self.exes
            .borrow_mut()
            .insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every artifact a serving process will need (avoids
    /// first-request latency spikes).
    pub fn warmup(&self, kinds: &[EntryKind]) -> Result<()> {
        for e in &self.arts.entries {
            if kinds.contains(&e.kind) {
                self.exe(e)?;
            }
        }
        Ok(())
    }

    /// Execute an entry with `extra` data inputs followed by the
    /// device-resident model parameters, returning the decomposed output
    /// tuple. Uses `execute_b` (buffer args) — see `literal.rs` for why
    /// the literal-argument path is off-limits.
    fn run_with_params(
        &self,
        entry: &ArtifactEntry,
        extra: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(entry)?;
        let params = self.params.borrow();
        if params.is_empty() {
            bail!("engine has no parameters loaded");
        }
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(extra.len() + params.len());
        args.extend(extra.iter());
        args.extend(params.iter());
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    // -- entry points ----------------------------------------------------

    /// Vanilla full-attention prefill (the baseline path). Picks the
    /// smallest length bucket that fits, pads, and trims the returned KV
    /// to `tokens.len()`.
    pub fn prefill_full(&self, tokens: &[i32]) -> Result<PrefillFullOut> {
        let need = tokens.len();
        let entry = self.arts.pick_bucket(EntryKind::PrefillFull, "L", need)?.clone();
        let l = entry.size("L")?;
        let toks = pad_tokens(tokens, l);
        let outs = self.run_with_params(
            &entry,
            &[
                buf_i(&self.client, &toks)?,
                buf_scalar_i(&self.client, need as i32)?,
            ],
        )?;
        let [logits, k, v] = take3(outs)?;
        Ok(PrefillFullOut {
            last_logits: logits.to_vec::<f32>()?,
            k: trim_kv(literal_to_f32(&k)?, need),
            v: trim_kv(literal_to_f32(&v)?, need),
        })
    }

    /// Independent block prefill at local positions (paper §2.1). Returns
    /// KV trimmed to the block length; keys are at positions `0..len` and
    /// must be re-encoded before use at a non-zero offset.
    pub fn prefill_block(&self, tokens: &[i32]) -> Result<(TensorF, TensorF)> {
        let need = tokens.len();
        let entry = self.arts.pick_bucket(EntryKind::PrefillBlock, "L", need)?.clone();
        let l = entry.size("L")?;
        let toks = pad_tokens(tokens, l);
        let outs = self.run_with_params(
            &entry,
            &[
                buf_i(&self.client, &toks)?,
                buf_scalar_i(&self.client, need as i32)?,
            ],
        )?;
        let [k, v] = take2(outs)?;
        Ok((
            trim_kv(literal_to_f32(&k)?, need),
            trim_kv(literal_to_f32(&v)?, need),
        ))
    }

    /// Capacity (C) the final-prefill bucket would use for `ctx_len`.
    pub fn final_ctx_capacity(&self, ctx_len: usize) -> Result<usize> {
        self.arts
            .pick_bucket(EntryKind::PrefillFinal, "C", ctx_len)?
            .size("C")
    }

    /// Max query-block length supported by the final-prefill artifacts.
    pub fn final_q_capacity(&self) -> Result<usize> {
        self.arts
            .entries_of(EntryKind::PrefillFinal, "C")
            .first()
            .ok_or_else(|| anyhow!("no prefill_final artifacts"))?
            .size("Lq")
    }

    /// Final-block prefill over an assembled, re-encoded context.
    ///
    /// `past_k`/`past_v` must be `(layers, C, kv_heads, head_dim)` where C
    /// is exactly [`Self::final_ctx_capacity`]`(past_len)`. The query
    /// sits at RoPE positions `past_len..` (see
    /// [`Self::prefill_final_at`] for baselines that decouple position
    /// from context length).
    pub fn prefill_final(
        &self,
        tokens: &[i32],
        past_k: &TensorF,
        past_v: &TensorF,
        past_len: usize,
    ) -> Result<PrefillFinalOut> {
        self.prefill_final_at(tokens, past_k, past_v, past_len, past_len)
    }

    /// [`Self::prefill_final`] with an explicit query position origin
    /// (`q_pos0`): superposition-style baselines place the query after
    /// the longest *parallel* document path instead of after the
    /// concatenated context.
    pub fn prefill_final_at(
        &self,
        tokens: &[i32],
        past_k: &TensorF,
        past_v: &TensorF,
        past_len: usize,
        q_pos0: usize,
    ) -> Result<PrefillFinalOut> {
        let c = past_k.dims()[1];
        let entry = self.arts.pick_bucket(EntryKind::PrefillFinal, "C", c)?.clone();
        if entry.size("C")? != c {
            bail!("context tensor capacity {c} does not match bucket");
        }
        let lq = entry.size("Lq")?;
        let need = tokens.len();
        if need > lq {
            bail!("final block of {need} tokens exceeds capacity {lq}");
        }
        let toks = pad_tokens(tokens, lq);
        let outs = self.run_with_params(
            &entry,
            &[
                buf_i(&self.client, &toks)?,
                buf_scalar_i(&self.client, need as i32)?,
                buf_f(&self.client, past_k)?,
                buf_f(&self.client, past_v)?,
                buf_scalar_i(&self.client, past_len as i32)?,
                buf_scalar_i(&self.client, q_pos0 as i32)?,
            ],
        )?;
        let [logits, k, v] = take3(outs)?;
        Ok(PrefillFinalOut {
            last_logits: logits.to_vec::<f32>()?,
            k: trim_kv(literal_to_f32(&k)?, need),
            v: trim_kv(literal_to_f32(&v)?, need),
        })
    }

    /// Dense-cache capacity of the decode artifact.
    pub fn decode_ctx_capacity(&self) -> Result<usize> {
        self.arts
            .entries_of(EntryKind::DecodeStep, "C")
            .first()
            .ok_or_else(|| anyhow!("no decode artifacts"))?
            .size("C")
    }

    /// One decode step: append `token` at `cache_len` and return logits
    /// plus the updated cache.
    pub fn decode(
        &self,
        token: i32,
        k_cache: &TensorF,
        v_cache: &TensorF,
        cache_len: usize,
    ) -> Result<DecodeOut> {
        let c = k_cache.dims()[1];
        let entry = self.arts.pick_bucket(EntryKind::DecodeStep, "C", c)?.clone();
        if entry.size("C")? != c {
            bail!("decode cache capacity {c} does not match bucket");
        }
        let outs = self.run_with_params(
            &entry,
            &[
                buf_scalar_i(&self.client, token)?,
                buf_scalar_i(&self.client, cache_len as i32)?,
                buf_f(&self.client, k_cache)?,
                buf_f(&self.client, v_cache)?,
            ],
        )?;
        let [logits, k, v] = take3(outs)?;
        Ok(DecodeOut {
            logits: logits.to_vec::<f32>()?,
            k_cache: literal_to_f32(&k)?,
            v_cache: literal_to_f32(&v)?,
        })
    }

    /// RoPE re-encode via the AOT Pallas kernel (parity target for the
    /// native implementation in `crate::rope`).
    pub fn reencode_k_artifact(&self, k: &TensorF, delta: i32) -> Result<TensorF> {
        let l = k.dims()[1];
        let entry = self.arts.pick_bucket(EntryKind::ReencodeK, "L", l)?.clone();
        if entry.size("L")? != l {
            bail!("reencode artifact bucket mismatch");
        }
        let exe = self.exe(&entry)?;
        let delta_t = Tensor::from_vec(&[1], vec![delta]);
        let args = [buf_f(&self.client, k)?, buf_i(&self.client, &delta_t)?];
        let refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        let out = exe.execute_b::<&xla::PjRtBuffer>(&refs)?[0][0].to_literal_sync()?;
        let mut parts = out.to_tuple()?;
        literal_to_f32(&parts.remove(0))
    }

    // -- training --------------------------------------------------------

    /// One block-fine-tune step (paper §2.4). `seg` carries the Figure-1
    /// segment ids (uniform ids = full-attention mode), `loss_mask` marks
    /// target tokens. Updates the engine's parameters in place.
    pub fn train_step(
        &self,
        step: usize,
        lr: f32,
        tokens: &TensorI,
        seg: &TensorI,
        loss_mask: &TensorF,
    ) -> Result<TrainOut> {
        let entry = self
            .arts
            .entries
            .iter()
            .find(|e| e.kind == EntryKind::TrainStep)
            .ok_or_else(|| anyhow!("config '{}' has no train artifact", self.arts.config.name))?
            .clone();
        let exe = self.exe(&entry)?;

        // Lazily allocate Adam state (device-resident zeros).
        if self.opt_state.borrow().is_none() {
            let zeros = || -> Result<Vec<xla::PjRtBuffer>> {
                self.arts
                    .params
                    .iter()
                    .map(|p| buf_f(&self.client, &Tensor::zeros(&p.shape)))
                    .collect()
            };
            *self.opt_state.borrow_mut() = Some((zeros()?, zeros()?));
        }

        let extra = [
            buf_scalar_i(&self.client, step as i32)?,
            buf_scalar_f(&self.client, lr)?,
            buf_i(&self.client, tokens)?,
            buf_i(&self.client, seg)?,
            buf_f(&self.client, loss_mask)?,
        ];
        let params = self.params.borrow();
        let opt = self.opt_state.borrow();
        let (m, v) = opt.as_ref().unwrap();
        let mut args: Vec<&xla::PjRtBuffer> = extra.iter().collect();
        args.extend(params.iter());
        args.extend(m.iter());
        args.extend(v.iter());
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        drop(params);
        drop(opt);

        // The output is one tuple buffer (return_tuple=True lowering);
        // split on host and re-upload the new state. ~50 MB of memcpy per
        // step at tiny scale — negligible next to the step compute.
        let lit = result[0][0].to_literal_sync()?;
        let mut outs = lit.to_tuple()?;
        let n = self.arts.params.len();
        if outs.len() != 1 + 3 * n {
            bail!("train_step returned {} outputs, expected {}", outs.len(), 1 + 3 * n);
        }
        let loss = outs.remove(0).to_vec::<f32>()?[0];
        // NOTE: not `buffer_from_host_literal` — its C shim starts an
        // async transfer without awaiting it, so dropping the literal
        // races the copy (SIGSEGV). `buf_f` copies synchronously
        // (kImmutableOnlyDuringCall semantics).
        let upload = |lits: &[xla::Literal]| -> Result<Vec<xla::PjRtBuffer>> {
            lits.iter()
                .map(|l| buf_f(&self.client, &literal_to_f32(l)?))
                .collect()
        };
        let new_v = upload(&outs.split_off(2 * n))?;
        let new_m = upload(&outs.split_off(n))?;
        let new_p = upload(&outs)?;
        *self.params.borrow_mut() = new_p;
        *self.opt_state.borrow_mut() = Some((new_m, new_v));
        Ok(TrainOut { loss })
    }

    /// Reset the Adam state (call when starting a new fine-tune from a
    /// freshly loaded checkpoint).
    pub fn reset_opt_state(&self) {
        *self.opt_state.borrow_mut() = None;
    }

    /// Zero-filled KV context tensor `(layers, c, kv_heads, head_dim)`.
    pub fn kv_zeros(&self, c: usize) -> TensorF {
        let cfg = &self.arts.config;
        Tensor::zeros(&[cfg.layers, c, cfg.kv_heads, cfg.head_dim])
    }
}

// -- helpers ---------------------------------------------------------------

fn pad_tokens(tokens: &[i32], to: usize) -> TensorI {
    let mut v = tokens.to_vec();
    v.resize(to, 0);
    Tensor::from_vec(&[to], v)
}

/// Trim a `(layers, L, kv_heads, head_dim)` KV tensor to `len` tokens.
fn trim_kv(kv: TensorF, len: usize) -> TensorF {
    let dims = kv.dims().to_vec();
    if dims[1] == len {
        return kv;
    }
    let (layers, l, heads, hd) = (dims[0], dims[1], dims[2], dims[3]);
    let mut out = Tensor::zeros(&[layers, len, heads, hd]);
    let row = heads * hd;
    for n in 0..layers {
        let src = kv.axis0(n);
        out.axis0_mut(n).copy_from_slice(&src[..len * row]);
        let _ = l;
    }
    out
}

fn take2(mut v: Vec<xla::Literal>) -> Result<[xla::Literal; 2]> {
    if v.len() != 2 {
        bail!("expected 2 outputs, got {}", v.len());
    }
    let b = v.pop().unwrap();
    let a = v.pop().unwrap();
    Ok([a, b])
}

fn take3(mut v: Vec<xla::Literal>) -> Result<[xla::Literal; 3]> {
    if v.len() != 3 {
        bail!("expected 3 outputs, got {}", v.len());
    }
    let c = v.pop().unwrap();
    let b = v.pop().unwrap();
    let a = v.pop().unwrap();
    Ok([a, b, c])
}

/// The [`Backend`] contract, delegating to the inherent artifact-backed
/// methods. Capacities come from the manifest's bucket tables.
impl Backend for ModelEngine {
    fn config(&self) -> &crate::config::ModelConfig {
        &self.arts.config
    }

    fn param_specs(&self) -> &[crate::config::ParamSpec] {
        &self.arts.params
    }

    fn set_params(&self, tensors: Vec<TensorF>) -> Result<()> {
        ModelEngine::set_params(self, tensors)
    }

    fn params_host(&self) -> Result<Vec<TensorF>> {
        ModelEngine::params_host(self)
    }

    fn reset_opt_state(&self) {
        ModelEngine::reset_opt_state(self)
    }

    fn prefill_full(&self, tokens: &[i32]) -> Result<PrefillFullOut> {
        ModelEngine::prefill_full(self, tokens)
    }

    fn prefill_block(&self, tokens: &[i32]) -> Result<(TensorF, TensorF)> {
        ModelEngine::prefill_block(self, tokens)
    }

    fn prefill_final_at(
        &self,
        tokens: &[i32],
        past_k: &TensorF,
        past_v: &TensorF,
        past_len: usize,
        q_pos0: usize,
    ) -> Result<PrefillFinalOut> {
        ModelEngine::prefill_final_at(self, tokens, past_k, past_v, past_len, q_pos0)
    }

    fn decode(
        &self,
        token: i32,
        k_cache: &TensorF,
        v_cache: &TensorF,
        cache_len: usize,
    ) -> Result<DecodeOut> {
        ModelEngine::decode(self, token, k_cache, v_cache, cache_len)
    }

    fn train_step(
        &self,
        step: usize,
        lr: f32,
        tokens: &TensorI,
        seg: &TensorI,
        loss_mask: &TensorF,
    ) -> Result<TrainOut> {
        ModelEngine::train_step(self, step, lr, tokens, seg, loss_mask)
    }

    fn final_ctx_capacity(&self, ctx_len: usize) -> Result<usize> {
        ModelEngine::final_ctx_capacity(self, ctx_len)
    }

    fn final_q_capacity(&self) -> Result<usize> {
        ModelEngine::final_q_capacity(self)
    }

    fn decode_ctx_capacity(&self) -> Result<usize> {
        ModelEngine::decode_ctx_capacity(self)
    }

    fn max_block_tokens(&self) -> Result<usize> {
        self.arts
            .entries_of(EntryKind::PrefillBlock, "L")
            .last()
            .ok_or_else(|| anyhow!("no prefill_block artifacts"))?
            .size("L")
    }

    fn train_shape(&self) -> Result<(usize, usize)> {
        let entry = self
            .arts
            .entries
            .iter()
            .find(|e| e.kind == EntryKind::TrainStep)
            .ok_or_else(|| anyhow!("config '{}' has no train artifact", self.arts.config.name))?;
        Ok((entry.size("B")?, entry.size("L")?))
    }

    fn warmup(&self) -> Result<()> {
        ModelEngine::warmup(
            self,
            &[
                EntryKind::PrefillFull,
                EntryKind::PrefillBlock,
                EntryKind::PrefillFinal,
                EntryKind::DecodeStep,
            ],
        )
    }

    fn kv_zeros(&self, c: usize) -> TensorF {
        ModelEngine::kv_zeros(self, c)
    }

    fn load_params_file(&self, path: &std::path::Path) -> Result<()> {
        ModelEngine::load_params_file(self, path)
    }

    fn save_params_file(&self, path: &std::path::Path) -> Result<()> {
        ModelEngine::save_params_file(self, path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_trim() {
        let t = pad_tokens(&[1, 2, 3], 5);
        assert_eq!(t.data(), &[1, 2, 3, 0, 0]);
        let kv = Tensor::from_vec(&[1, 3, 1, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let trimmed = trim_kv(kv, 2);
        assert_eq!(trimmed.dims(), &[1, 2, 1, 2]);
        assert_eq!(trimmed.data(), &[1.0, 2.0, 3.0, 4.0]);
    }
}
