//! Sample → training-batch packing.
//!
//! Layout of one row (matches inference exactly):
//!
//! ```text
//! [block0 .. SEP][block1 .. SEP] ... [QRY query][answer EOS][PAD ...]
//!  seg=0          seg=1               seg=K      seg=K        seg=K
//!  mask=0         mask=0              mask=0     mask=1       mask=0
//! ```
//!
//! With `block_mask = false` all segment ids collapse to 0 — the same
//! row trains in full-attention mode (the dual-mode trick needs no
//! second artifact).

use crate::tensor::{Tensor, TensorF, TensorI};
use crate::tokenizer::{ByteTokenizer, EOS, PAD};
use crate::workload::Sample;

/// Encode one sample. Returns (tokens, segment ids, loss mask); rows are
/// truncated to `max_len` if necessary (the response is kept by trimming
/// context blocks from the front first).
///
/// The loss mask covers **every non-pad token** (full-LM loss): for a
/// from-scratch model the context/passage tokens carry most of the
/// learning signal, and the paper's SFT-style answer-only masking
/// starves a tiny model of it. The response tokens are what evaluation
/// measures; the context tokens teach the representations.
pub fn encode_sample(
    tok: &ByteTokenizer,
    sample: &Sample,
    max_len: usize,
    block_mask: bool,
) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let sp = sample.segment(tok);
    let answer = tok.encode(&sample.response);
    let tail_len = sp.query.len() + answer.len() + 1;

    // Drop leading blocks until everything fits.
    let mut blocks: &[Vec<i32>] = &sp.blocks;
    let mut ctx_len: usize = blocks.iter().map(|b| b.len()).sum();
    while ctx_len + tail_len > max_len && !blocks.is_empty() {
        ctx_len -= blocks[0].len();
        blocks = &blocks[1..];
    }

    let mut tokens = Vec::with_capacity(max_len);
    let mut seg = Vec::with_capacity(max_len);
    let mut mask = Vec::with_capacity(max_len);
    for (i, b) in blocks.iter().enumerate() {
        let id = if block_mask { i as i32 } else { 0 };
        for &t in b {
            tokens.push(t);
            seg.push(id);
            mask.push(1.0);
        }
    }
    let final_id = if block_mask { blocks.len() as i32 } else { 0 };
    for &t in &sp.query {
        tokens.push(t);
        seg.push(final_id);
        mask.push(1.0);
    }
    for &t in &answer {
        tokens.push(t);
        seg.push(final_id);
        mask.push(1.0);
    }
    tokens.push(EOS);
    seg.push(final_id);
    mask.push(1.0);
    // Position 0 is never a prediction target.
    if let Some(m) = mask.first_mut() {
        *m = 0.0;
    }

    tokens.truncate(max_len);
    seg.truncate(max_len);
    mask.truncate(max_len);
    while tokens.len() < max_len {
        tokens.push(PAD);
        seg.push(final_id);
        mask.push(0.0);
    }
    (tokens, seg, mask)
}

/// Pack samples into `(B, L)` batch tensors.
pub fn pack_batch(
    tok: &ByteTokenizer,
    samples: &[Sample],
    max_len: usize,
    block_mask: bool,
) -> (TensorI, TensorI, TensorF) {
    let b = samples.len();
    let mut tokens = Vec::with_capacity(b * max_len);
    let mut seg = Vec::with_capacity(b * max_len);
    let mut mask = Vec::with_capacity(b * max_len);
    for s in samples {
        let (t, g, m) = encode_sample(tok, s, max_len, block_mask);
        tokens.extend(t);
        seg.extend(g);
        mask.extend(m);
    }
    (
        Tensor::from_vec(&[b, max_len], tokens),
        Tensor::from_vec(&[b, max_len], seg),
        Tensor::from_vec(&[b, max_len], mask),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::{QRY, SEP};

    fn sample() -> Sample {
        Sample::bare(vec!["ab".into(), "cd".into()], "q".into(), "xy".into())
    }

    #[test]
    fn layout_matches_inference() {
        let tok = ByteTokenizer::new();
        let (t, g, m) = encode_sample(&tok, &sample(), 16, true);
        // ab SEP cd SEP QRY q x y EOS PAD...
        assert_eq!(t[2], SEP);
        assert_eq!(t[5], SEP);
        assert_eq!(t[6], QRY);
        assert_eq!(t[10], EOS);
        assert_eq!(t[11], PAD);
        assert_eq!(&g[..6], &[0, 0, 0, 1, 1, 1]);
        assert_eq!(&g[6..11], &[2, 2, 2, 2, 2]);
        // Full-LM loss: every non-pad token except position 0.
        assert_eq!(m[0], 0.0);
        assert_eq!(m.iter().filter(|&&x| x > 0.0).count(), 10);
        assert!(m[11..].iter().all(|&x| x == 0.0), "pad must be unmasked");
    }

    #[test]
    fn response_differs_from_answer_when_set() {
        let tok = ByteTokenizer::new();
        let s = Sample {
            blocks: vec![],
            query: "q".into(),
            answer: "v".into(),
            response: "the x is v .".into(),
        };
        let (t, _, _) = encode_sample(&tok, &s, 32, false);
        let text = tok.decode(&t);
        assert!(text.contains("the x is v ."));
    }

    #[test]
    fn full_mode_collapses_segments() {
        let tok = ByteTokenizer::new();
        let (_, g, _) = encode_sample(&tok, &sample(), 16, false);
        assert!(g.iter().all(|&x| x == 0));
    }

    #[test]
    fn truncation_keeps_answer() {
        let tok = ByteTokenizer::new();
        let long = Sample::bare(
            vec!["a".repeat(30), "b".repeat(30)],
            "q".into(),
            "zz".into(),
        );
        let (t, _, _) = encode_sample(&tok, &long, 40, true);
        assert_eq!(t.len(), 40);
        // The answer tokens survive (block "a"*30 dropped).
        let txt = tok.decode(&t);
        assert!(txt.contains("zz"));
        assert!(!txt.contains("aaa"));
    }

    #[test]
    fn batch_shapes() {
        let tok = ByteTokenizer::new();
        let (t, g, m) = pack_batch(&tok, &[sample(), sample(), sample()], 32, true);
        assert_eq!(t.dims(), &[3, 32]);
        assert_eq!(g.dims(), &[3, 32]);
        assert_eq!(m.dims(), &[3, 32]);
    }
}
