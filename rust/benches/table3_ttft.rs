//! Table 3 reproduction: TTFT and FLOPs to first token, user input of 50
//! tokens, total sequence length swept 50 → 32K; vanilla full-attention
//! prefill vs Block-attention with all passage KV cached.
//!
//! ```sh
//! cargo bench --bench table3_ttft                  # lengths ≤ 8K
//! cargo bench --bench table3_ttft -- --full        # adds 16K and 32K
//! cargo bench --bench table3_ttft -- --lengths 512,2048
//! cargo bench --bench table3_ttft -- --kv-quant int8   # quantized KV tier
//! cargo bench --bench table3_ttft -- --kv-quant int4   # packed low-bit tier
//! ```
//!
//! The block path is timed end to end as served: cache fetch + RoPE
//! re-encode + context assembly + final-block prefill. The vanilla path
//! is one full prefill. FLOPs are reported in both the paper's
//! convention (weight FLOPs, 2·params·tokens — see flops/mod.rs) and
//! exact (attention contractions included).
//!
//! Besides the table, results are written machine-readable to
//! `BENCH_ttft.json` (`--json-out PATH` overrides) so the perf
//! trajectory is tracked across PRs.

use block_attn::coordinator::write_ctx;
use block_attn::flops::FlopsModel;
use block_attn::kvcache::{block_key, BlockKvCache};
use block_attn::rope::RopeTable;
use block_attn::runtime::backend_from_args;
use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use block_attn::util::rng::Rng;
use block_attn::util::timer::{bench, BenchOpts};
use block_attn::Backend;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let threads = block_attn::kernels::init_threads_from_args(&args);
    let q_len = args.usize_or("user-input", 50);
    // The native backend is an interpretive CPU loop — default to the
    // short end of the sweep there; `--backend xla` (or --lengths) runs
    // the paper's full range.
    let default_lengths: &[usize] = if block_attn::runtime::backend_choice(&args) == "native" {
        &[50, 256, 512, 1024]
    } else {
        &[50, 512, 1024, 2048, 4096, 8192]
    };
    let mut lengths = args.usize_list_or("lengths", default_lengths);
    if args.flag("full") {
        lengths.extend([16384, 32768]);
    }

    let engine = backend_from_args(&args, "bench")?;
    let model = engine.config().name.clone();
    let cfg = engine.config().clone();
    let flops = FlopsModel::from_config(&cfg);
    let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
    // KV cache tier for the block path (`--kv-quant int8` times the
    // fused dequant + re-encode fetch instead of the f32 fetch).
    let kv_precision = block_attn::config::KvPrecision::resolve(&args)?;
    let block_bucket = engine.max_block_tokens()?.min(512);
    let mut rng = Rng::new(7);

    println!("# Table 3 — TTFT (ms) and FLOPs-TFT, user input {q_len} tokens, config '{model}'");
    println!("# paper: TTFT reduction 48% @512 → 98.7% @32K; FLOPs reduction 90.1% @512 → 99.8% @32K");
    println!(
        "{:>8} {:>14} {:>14} {:>8} {:>13} {:>13} {:>8} {:>13} {:>13}",
        "length",
        "ttft-vanilla",
        "ttft-block",
        "red%",
        "flops-van(p)",
        "flops-blk(p)",
        "red%",
        "flops-van(x)",
        "flops-blk(x)"
    );

    let mut rows: Vec<Json> = Vec::new();
    for &n in &lengths {
        let ctx_len = n.saturating_sub(q_len);
        let tokens: Vec<i32> = (0..n).map(|_| rng.below(cfg.vocab) as i32).collect();
        let query = &tokens[ctx_len..];

        // Vanilla: one full prefill. Fewer iterations at longer lengths.
        let iters = if n > 8192 { 1 } else if n > 2048 { 2 } else { 5 };
        let opts = BenchOpts { warmup_iters: 1, iters, max_seconds: 600.0 };
        let r_van = bench("vanilla", &opts, || {
            engine.prefill_full(&tokens).expect("prefill_full");
        });

        // Block: pre-populate the cache (not timed — the paper assumes
        // the passage KV "has been pre-computed and cached in memory").
        let mut ttft_block_ms = r_van.p50_ms();
        if ctx_len > 0 {
            let mut cache = BlockKvCache::with_precision(rope.clone(), 0, kv_precision);
            let blocks: Vec<&[i32]> = tokens[..ctx_len].chunks(block_bucket).collect();
            for b in &blocks {
                let (k, v) = engine.prefill_block(b)?;
                let key = block_key(b);
                cache.insert_pinned(key, k, v);
                cache.unpin(key);
            }
            let cap = engine.final_ctx_capacity(ctx_len)?;
            let r_blk = bench("block", &opts, || {
                // Timed: fetch + re-encode + assemble + final prefill.
                let mut past_k = engine.kv_zeros(cap);
                let mut past_v = engine.kv_zeros(cap);
                let mut off = 0;
                for b in &blocks {
                    let blk = cache.get_reencoded(block_key(b), off).unwrap();
                    write_ctx(&mut past_k, &blk.k, off);
                    write_ctx(&mut past_v, &blk.v, off);
                    off += blk.len;
                }
                engine
                    .prefill_final(query, &past_k, &past_v, ctx_len)
                    .expect("prefill_final");
            });
            ttft_block_ms = r_blk.p50_ms();
        }

        let red_t = 100.0 * (1.0 - ttft_block_ms / r_van.p50_ms());
        let fv_p = flops.weights_prefill(n);
        let fb_p = flops.weights_block_tft(q_len.min(n));
        let red_f = 100.0 * (1.0 - fb_p / fv_p);
        let fv_x = flops.prefill_full(n);
        let fb_x = if ctx_len > 0 { flops.block_mode_tft(q_len, ctx_len) } else { fv_x };
        println!(
            "{:>8} {:>14.1} {:>14.1} {:>7.1}% {:>13.2e} {:>13.2e} {:>7.1}% {:>13.2e} {:>13.2e}",
            n, r_van.p50_ms(), ttft_block_ms, red_t, fv_p, fb_p, red_f, fv_x, fb_x
        );
        rows.push(Json::obj(vec![
            ("length", Json::num(n as f64)),
            ("ttft_vanilla_ms", Json::num(r_van.p50_ms())),
            ("ttft_block_ms", Json::num(ttft_block_ms)),
            ("ttft_reduction_pct", Json::num(red_t)),
            ("flops_vanilla_paper", Json::num(fv_p)),
            ("flops_block_paper", Json::num(fb_p)),
            ("flops_vanilla_exact", Json::num(fv_x)),
            ("flops_block_exact", Json::num(fb_x)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::str("table3_ttft")),
        ("model", Json::str(model)),
        ("backend", Json::str(block_attn::runtime::backend_choice(&args))),
        ("kv_precision", Json::str(kv_precision.as_str())),
        ("threads", Json::num(threads as f64)),
        ("user_input_tokens", Json::num(q_len as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let out_path = args.str_or("json-out", "BENCH_ttft.json");
    std::fs::write(&out_path, format!("{report}\n"))?;
    eprintln!("# wrote {out_path}");
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}
