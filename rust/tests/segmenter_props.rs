//! Property battery for the text segmenter and the block-shape
//! normalizers the serving path composes around it:
//!
//! * **Byte-lossless split** — `split_text_parts` keeps each division
//!   label with the part it terminates, so concatenating the parts
//!   reproduces the input byte-for-byte, on adversarial UTF-8:
//!   overlapping/adjacent labels, a label at EOF, multi-byte characters
//!   hugging label boundaries, and empty input.
//! * **Tokenized round-trip** — `segment_text` ∘ `ByteTokenizer::decode`
//!   recovers the original text (blocks ++ query).
//! * **Shape normalization** — `coalesce_small_blocks` ∘
//!   `split_oversized_blocks` preserves the flattened context-token
//!   sequence (hence the total count), caps every block at `max_len`,
//!   never touches the query, and rejects an unsplittable oversized
//!   query loudly.
//! * **Seeded fuzz** — random interleavings of labels, near-labels and
//!   multi-byte characters uphold all of the above.

use block_attn::coordinator::segmenter::{
    coalesce_small_blocks, segment_text, split_oversized_blocks, split_text_parts,
    SegmentedPrompt, DIVISION_LABELS,
};
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::prop;
use block_attn::util::rng::Rng;
use block_attn::{prop_assert, prop_assert_eq};

#[test]
fn split_round_trips_adversarial_texts() {
    let cases = [
        "",                        // empty input: no parts at all
        "plain text, no labels",   // nothing to split on
        "a---b",                   // single label mid-text
        "a---",                    // label at EOF: empty tail dropped
        "---",                     // the whole input is one label
        "------",                  // adjacent labels, empty part between
        "---===---",               // alternating adjacent labels
        "=====",                   // overlap: one label plus a leftover "=="
        "----",                    // overlap: label plus a stray "-"
        "a-- -b==+c",              // near-labels must not split
        "\n\n\t\t",                // "\n\n" wins over "\n\t\t" at offset 0
        "x\n\t\ty\n\nz",           // both newline labels in one text
        "日本---語",               // multi-byte chars hugging a label
        "…---…===…",               // 3-byte ellipsis between labels
        "🎲---🎯",                 // 4-byte chars around a label
        "é=====é",                 // 2-byte char against an overlapping label
        "tail---",                 // trailing label, tail becomes empty
        "---lead",                 // leading label, empty head dropped
    ];
    let tok = ByteTokenizer::new();
    for text in cases {
        let parts = split_text_parts(text);
        assert_eq!(parts.concat(), text, "lossy split of {text:?}");
        assert!(parts.iter().all(|p| !p.is_empty()), "empty part in {text:?}");
        // Tokenized round-trip: blocks ++ query decode to the input.
        let sp = segment_text(&tok, text);
        let mut decoded = String::new();
        for b in &sp.blocks {
            decoded.push_str(&tok.decode(b));
        }
        decoded.push_str(&tok.decode(&sp.query));
        assert_eq!(decoded, text, "segment_text lost bytes of {text:?}");
        // Every context block ends with the label that terminated it.
        for b in &sp.blocks {
            let t = tok.decode(b);
            assert!(
                DIVISION_LABELS.iter().any(|l| t.ends_with(l)),
                "context block {t:?} of {text:?} lacks a terminating label"
            );
        }
    }
}

#[test]
fn fuzz_split_round_trips_random_label_placements() {
    // Pieces chosen to collide: full labels, their prefixes/overlaps,
    // and multi-byte characters whose bytes sit next to label bytes.
    let pieces = [
        "---", "===", "\n\n", "\n\t\t", "--", "==", "-", "=", "\n", "\t\t",
        "a", "bc", " ", "é", "漢", "…", "🎲",
    ];
    prop::check("text-split-round-trip", 0x5E61, 300, |rng: &mut Rng| {
        let n = rng.below(24);
        let mut text = String::new();
        for _ in 0..n {
            text.push_str(rng.pick(&pieces));
        }
        let parts = split_text_parts(&text);
        prop_assert_eq!(parts.concat(), text);
        prop_assert!(
            parts.iter().all(|p| !p.is_empty()),
            "empty part from {text:?}"
        );
        // Labels only ever terminate a part: every label occurrence
        // inside a part ends exactly at the part's end (the scanner
        // checks each character position, so an earlier occurrence
        // would have cut the part there).
        for p in &parts {
            let pb = p.as_bytes();
            for l in DIVISION_LABELS {
                let lb = l.as_bytes();
                for i in 0..pb.len() {
                    if pb[i..].starts_with(lb) {
                        prop_assert!(
                            i + lb.len() == pb.len(),
                            "part {p:?} of {text:?} continues past label {l:?} at byte {i}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fuzz_coalesce_then_split_preserves_tokens() {
    prop::check("coalesce-split-composition", 0xC0A1, 300, |rng: &mut Rng| {
        let nblocks = rng.below(12);
        let blocks: Vec<Vec<i32>> = (0..nblocks)
            .map(|_| {
                let len = rng.below(40);
                (0..len).map(|_| rng.below(256) as i32).collect()
            })
            .collect();
        let min_len = 1 + rng.below(6);
        let max_len = min_len + 1 + rng.below(32);
        // The query must fit the bucket — an oversized query is a loud
        // error by design (covered below), not part of this property.
        let query: Vec<i32> =
            (0..rng.below(max_len + 1)).map(|_| rng.below(256) as i32).collect();

        let sp = SegmentedPrompt { blocks: blocks.clone(), query: query.clone() };
        let sp = coalesce_small_blocks(sp, min_len);
        let sp = match split_oversized_blocks(sp, max_len) {
            Ok(sp) => sp,
            Err(e) => return Err(format!("normalization failed: {e}")),
        };

        // The flattened context-token sequence is invariant (coalesce
        // concatenates neighbors, split re-chunks) — so the total token
        // count is too, and no block exceeds the bucket capacity.
        let flat: Vec<i32> = blocks.iter().flatten().copied().collect();
        let norm: Vec<i32> = sp.blocks.iter().flatten().copied().collect();
        prop_assert_eq!(norm, flat);
        prop_assert!(
            sp.blocks.iter().all(|b| b.len() <= max_len),
            "block over the {max_len}-token bucket"
        );
        // Coalescing folds empty blocks into a neighbor, so empties can
        // only survive when there were no context tokens at all.
        prop_assert!(
            flat.is_empty() || sp.blocks.iter().all(|b| !b.is_empty()),
            "empty block survived normalization"
        );
        prop_assert_eq!(sp.query, query);
        Ok(())
    });
}

#[test]
fn split_rejects_query_it_cannot_cap() {
    let sp = SegmentedPrompt { blocks: vec![vec![1; 8]], query: vec![2; 40] };
    let err = split_oversized_blocks(sp, 16).unwrap_err().to_string();
    assert!(err.contains("40") && err.contains("16"), "unhelpful error: {err}");
    // At exactly the cap the query passes untouched.
    let sp = SegmentedPrompt { blocks: vec![vec![1; 8]], query: vec![2; 16] };
    let sp = split_oversized_blocks(sp, 16).unwrap();
    assert_eq!(sp.query.len(), 16);
}
