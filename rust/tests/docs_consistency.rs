//! Docs ↔ code consistency: the configuration table in
//! `docs/ARCHITECTURE.md` is the canonical list of CLI flags and
//! `BLOCK_ATTN_*` environment variables. This test parses that table
//! and asserts (a) every documented name exists in the sources, and
//! (b) every `BLOCK_ATTN_*` variable referenced by the sources is
//! documented — so a new knob cannot land without its row, and a
//! removed knob cannot leave a stale row behind.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root").to_path_buf()
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Every `.rs` file under the given roots, concatenated.
fn all_sources() -> String {
    fn walk(dir: &Path, out: &mut String) {
        for entry in std::fs::read_dir(dir).expect("read_dir") {
            let path = entry.expect("dir entry").path();
            if path.is_dir() {
                walk(&path, out);
            } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push_str(&read(&path));
                out.push('\n');
            }
        }
    }
    let root = repo_root();
    let mut out = String::new();
    for sub in ["rust/src", "rust/benches", "rust/examples", "rust/tests"] {
        walk(&root.join(sub), &mut out);
    }
    out
}

/// All `BLOCK_ATTN_<NAME>` identifiers in `text` (full names only; a
/// bare `BLOCK_ATTN_*` wildcard in prose is ignored).
fn env_names(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut rest = text;
    while let Some(i) = rest.find("BLOCK_ATTN_") {
        let tail = &rest[i..];
        let name: String = tail
            .chars()
            .take_while(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || *c == '_')
            .collect();
        if name.len() > "BLOCK_ATTN_".len() && !name.ends_with('_') {
            out.insert(name);
        }
        rest = &rest[i + "BLOCK_ATTN_".len()..];
    }
    out
}

/// The configuration-table lines of ARCHITECTURE.md (markdown rows).
fn table_lines(doc: &str) -> Vec<&str> {
    doc.lines().filter(|l| l.trim_start().starts_with('|')).collect()
}

/// Backticked `--flag` names in the table rows.
fn table_flags(doc: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in table_lines(doc) {
        let mut rest = line;
        while let Some(i) = rest.find("`--") {
            let tail = &rest[i + 3..];
            let name: String = tail
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
                .collect();
            if !name.is_empty() {
                out.insert(name);
            }
            rest = tail;
        }
    }
    out
}

#[test]
fn the_four_docs_exist() {
    let root = repo_root();
    for doc in [
        "README.md",
        "docs/ARCHITECTURE.md",
        "docs/serving.md",
        "docs/kvstore-format.md",
    ] {
        let path = root.join(doc);
        assert!(path.is_file(), "{doc} is missing");
        assert!(read(&path).len() > 500, "{doc} is a stub");
    }
}

#[test]
fn every_documented_flag_and_env_var_exists_in_the_sources() {
    let doc = read(&repo_root().join("docs/ARCHITECTURE.md"));
    let sources = all_sources();

    let flags = table_flags(&doc);
    assert!(
        flags.len() >= 20,
        "configuration table parse broke: only {} flags found",
        flags.len()
    );
    for flag in &flags {
        assert!(
            sources.contains(&format!("\"{flag}\"")),
            "documented flag --{flag} is not parsed anywhere in the sources"
        );
    }

    let documented = env_names(&doc);
    assert!(
        documented.len() >= 10,
        "configuration table parse broke: only {} env vars found",
        documented.len()
    );
    for var in &documented {
        assert!(
            sources.contains(var.as_str()),
            "documented env var {var} is not read anywhere in the sources"
        );
    }
}

#[test]
fn every_env_var_in_the_sources_is_documented() {
    let doc = read(&repo_root().join("docs/ARCHITECTURE.md"));
    let documented = env_names(&doc);
    let in_sources = env_names(&all_sources());
    let undocumented: Vec<&String> =
        in_sources.iter().filter(|v| !documented.contains(*v)).collect();
    assert!(
        undocumented.is_empty(),
        "env vars read by the sources but missing from the docs/ARCHITECTURE.md table: \
         {undocumented:?}"
    );
}

#[test]
fn format_constants_match_the_format_doc() {
    // The normative spec and the code must move together; pin the
    // values the corrupt-file tests rely on.
    use block_attn::kvcache::store::{CHECKSUM_OFFSET, HEADER_LEN, MAGIC, VERSION, VERSION_OFFSET};
    let doc = read(&repo_root().join("docs/kvstore-format.md"));
    assert_eq!(&MAGIC, b"BAKV");
    assert!(doc.contains("\"BAKV\""), "format doc lost the magic");
    assert_eq!(VERSION, 1);
    assert_eq!(VERSION_OFFSET, 4);
    assert_eq!(HEADER_LEN, 64);
    assert_eq!(CHECKSUM_OFFSET, 56);
    assert!(doc.contains("64 bytes"), "format doc lost the header length");
    assert!(doc.contains("version 1"), "format doc lost the version");
}
