//! # block-attn — Block-Attention for Efficient Prefilling (ICLR 2025)
//!
//! A three-layer Rust + JAX + Pallas serving stack reproducing
//! *Block-Attention for Efficient Prefilling* (Ma, Wang & Lan, ICLR 2025).
//!
//! The paper's idea: in RAG serving, split the prompt into semantically
//! independent blocks (one per retrieved passage), let every block compute
//! its KV states *independently* (block-diagonal attention), cache those KV
//! states keyed by block content, and at request time only compute the
//! final (query) block — which attends to all cached blocks after their
//! RoPE positions are *re-encoded* to the block's position in this prompt.
//! TTFT and prefill FLOPs become (nearly) independent of context length.
//!
//! Layering (python never on the request path):
//! - **L1** `python/compile/kernels/` — Pallas attention + RoPE kernels.
//! - **L2** `python/compile/model.py` — Llama-style model, AOT-lowered to
//!   HLO text artifacts (`make artifacts`).
//! - **L3** this crate — PJRT runtime, block-KV cache with position
//!   re-encoding, segmentation, scheduling/batching, serving, training
//!   driver, benchmarks.
//!
//! Entry points:
//! - [`runtime::ModelEngine`] — load + execute the AOT artifacts.
//! - [`kvcache::BlockKvCache`] — content-addressed block KV store.
//! - [`coordinator::Coordinator`] — the serving stack (segment → plan →
//!   prefill → decode) with metrics.
//! - [`train::train`] — block fine-tuning driver over the AOT
//!   `train_step` (presets in [`train::presets`]).

pub mod config;
pub mod coordinator;
pub mod flops;
pub mod kvcache;
pub mod rope;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod tokenizer;
pub mod train;
pub mod util;
pub mod workload;

pub use config::ModelConfig;
pub use coordinator::Coordinator;
pub use runtime::ModelEngine;

/// CLI dispatcher used by the `block-attn` binary.
pub fn run_cli(args: &util::cli::Args) -> anyhow::Result<()> {
    match args.subcommand() {
        Some("info") => cli_info(args),
        Some("train") => cli_train(args),
        Some("serve") => cli_serve(args),
        Some("eval") => cli_eval(args),
        Some(other) => anyhow::bail!("unknown subcommand '{other}'"),
        None => {
            eprintln!("usage: block-attn <info|train|serve> [--options]");
            eprintln!("  info   --artifacts DIR");
            eprintln!("  train  --preset table1 --out DIR [--scale 1.0] [--model tiny]");
            eprintln!("  serve  --addr 127.0.0.1:7841 --model tiny [--checkpoint FILE]");
            Ok(())
        }
    }
}

/// Evaluate a checkpoint on the synthetic RAG benchmarks, optionally
/// dumping generations (debugging aid for the accuracy experiments).
fn cli_eval(args: &util::cli::Args) -> anyhow::Result<()> {
    use coordinator::{AttentionMode, Request};
    use tokenizer::ByteTokenizer;

    let dir = args.str_or("artifacts", "artifacts");
    let model = args.str_or("model", "tiny");
    let n = args.usize_or("samples", 10);
    let mode = AttentionMode::parse(&args.str_or("mode", "full"))?;
    let manifest = config::Manifest::load(&dir)?;
    let engine = ModelEngine::new(&manifest, &model)?;
    if let Some(ck) = args.get("checkpoint") {
        engine.load_params_file(std::path::Path::new(ck))?;
    }
    let mut coord = Coordinator::new(engine, 128 << 20);
    let tok = ByteTokenizer::new();
    for (bench_name, samples) in train::presets::rag_eval_by_variant(n) {
        let mut correct = 0;
        for (i, s) in samples.iter().enumerate() {
            let sp = s.segment(&tok);
            let req = Request {
                id: i as u64,
                blocks: sp.blocks,
                query: sp.query,
                max_new_tokens: 48,
                mode,
            };
            let resp = coord.process(&req)?;
            let text = tok.decode_until_eos(&resp.tokens);
            let ok = text.contains(&s.answer);
            correct += ok as usize;
            if args.flag("show") && i < 5 {
                println!("  [{}] q={:?} gold={:?} got={:?}", ok as u8, s.query, s.answer, text);
            }
        }
        println!("{bench_name}: {}/{}", correct, samples.len());
        if args.flag("show") {
            break;
        }
    }
    Ok(())
}

fn cli_serve(args: &util::cli::Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let model = args.str_or("model", "tiny");
    let addr = args.str_or("addr", "127.0.0.1:7841");
    let checkpoint = args.get("checkpoint").map(std::path::PathBuf::from);
    let workers = args.usize_or("workers", 4);
    let cache_mb = args.usize_or("cache-mb", 256);
    let handle = server::EngineHandle::spawn(move || {
        let manifest = config::Manifest::load(&dir)?;
        let engine = ModelEngine::new(&manifest, &model)?;
        if let Some(ck) = checkpoint {
            engine.load_params_file(&ck)?;
        }
        engine.warmup(&[
            config::EntryKind::PrefillBlock,
            config::EntryKind::PrefillFinal,
            config::EntryKind::DecodeStep,
        ])?;
        Ok(Coordinator::new(engine, cache_mb << 20))
    })?;
    server::serve(&addr, handle, workers)
}

fn cli_train(args: &util::cli::Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let model = args.str_or("model", "tiny");
    let out = std::path::PathBuf::from(args.str_or("out", "checkpoints"));
    let scale = args.f64_or("scale", 1.0);
    let manifest = config::Manifest::load(&dir)?;
    let engine = ModelEngine::new(&manifest, &model)?;
    let mut coord = Coordinator::new(engine, 256 << 20);
    let mut opts = train::presets::PresetOpts::scaled(scale);
    opts.only_block = args.flag("only-block");
    match args.str_or("preset", "table1").as_str() {
        "table1" => train::presets::run_table1_training(&mut coord, &out, &opts),
        other => anyhow::bail!("unknown preset '{other}'"),
    }
}

fn cli_info(args: &util::cli::Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = config::Manifest::load(&dir)?;
    for (name, m) in &manifest.models {
        println!(
            "{name}: {} layers, d_model {}, {} heads ({} kv), vocab {}, {} entries",
            m.config.layers,
            m.config.d_model,
            m.config.heads,
            m.config.kv_heads,
            m.config.vocab,
            m.entries.len()
        );
        for e in &m.entries {
            println!("  {:<40} {:?} {:?}", e.name, e.kind, e.sizes);
        }
    }
    Ok(())
}
