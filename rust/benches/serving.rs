//! Continuous-batching serving throughput: aggregate decode tokens/s
//! at a sweep of concurrent session counts over a warm passage pool.
//!
//! ```sh
//! cargo bench --bench serving                         # 1, 8, 64 sessions
//! cargo bench --bench serving -- --sessions 1,16
//! cargo bench --bench serving -- --kv-quant int8      # quantized KV tier
//! ```
//!
//! Each sweep point serves `S` concurrent requests through `run_batch`
//! with `max_active = S`: FIFO admission, at most one prefill per
//! decode round, and every round's decode fused into one GEMM dispatch
//! per projection by `Backend::decode_batch`. The passage pool KV is
//! pre-computed (not timed), so the sweep isolates what batching is
//! for: turning S memory-bound decode GEMVs into one compute-dense
//! GEMM. The bench fails if the widest batch does not out-throughput
//! serial serving — the acceptance bar for the batched decode path.
//!
//! Results are written machine-readable to `BENCH_serving.json`
//! (`--json-out PATH` overrides); per-token `tok_ms` and `ttft_p50_ms`
//! are gated by `bench_guard` in CI (see ci/baselines/README.md).

use anyhow::ensure;
use block_attn::coordinator::batcher::{run_batch, BatchPolicy};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::runtime::backend_from_args;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use block_attn::util::rng::Rng;
use block_attn::util::stats::Summary;
use block_attn::workload::traces::RagTrace;
use block_attn::Backend;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let threads = block_attn::kernels::init_threads_from_args(&args);
    let sessions = args.usize_list_or("sessions", &[1, 8, 64]);
    let max_new = args.usize_or("max-new-tokens", 16);
    let k = args.usize_or("passages-per-query", 4);
    let pool_size = args.usize_or("pool", 32);
    let zipf_s = args.f64_or("zipf", 1.1);

    let engine = backend_from_args(&args, "tiny")?;
    engine.warmup()?;
    let model = engine.config().name.clone();
    let kv_precision = block_attn::config::KvPrecision::resolve(&args)?;
    let mut coord = Coordinator::with_kv_precision(engine, 256 << 20, kv_precision);
    let tok = ByteTokenizer::new();

    // The external database + one query sample per concurrent session.
    let mut rng = Rng::new(args.u64_or("seed", 42));
    let trace = RagTrace::build(&mut rng, pool_size);
    let max_s = sessions.iter().copied().max().unwrap_or(1);
    let samples: Vec<_> = (0..max_s)
        .map(|_| trace.request(&mut rng, k, zipf_s))
        .collect();

    // Offline KV pre-computation of the pool (paper §1: passage KV
    // "might have been computed"); not timed.
    for p in &trace.pool {
        let mut ids = tok.encode(p);
        ids.push(block_attn::tokenizer::SEP);
        coord.precompute_block(&ids)?;
    }

    let build = |n: usize| -> Vec<Request> {
        samples[..n]
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let sp = s.segment(&tok);
                Request {
                    id: i as u64,
                    blocks: sp.blocks,
                    query: sp.query,
                    max_new_tokens: max_new,
                    mode: AttentionMode::Block,
                }
            })
            .collect()
    };
    // Warm the serving path (final-prefill buffers, worker pool) before
    // the timed sweep.
    run_batch(&mut coord, build(1), &BatchPolicy::default())?;

    println!(
        "# serving throughput — config '{model}', {kv_precision:?} KV, {max_new} new tokens/request"
    );
    println!(
        "{:>10} {:>12} {:>14} {:>10} {:>12}",
        "sessions", "tokens", "tokens/s", "tok-ms", "ttft-p50-ms"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut tput: Vec<(usize, f64)> = Vec::new();
    for &s in &sessions {
        let policy = BatchPolicy {
            max_active: s.max(1),
            max_active_tokens: 1 << 20,
            ..BatchPolicy::default()
        };
        let reqs = build(s);
        let t0 = Instant::now();
        let out = run_batch(&mut coord, reqs, &policy)?;
        let wall = t0.elapsed().as_secs_f64();
        let generated: usize = out.iter().map(|r| r.tokens.len()).sum();
        ensure!(generated > 0, "no tokens generated at {s} sessions");
        let tokens_per_s = generated as f64 / wall;
        let tok_ms = wall * 1e3 / generated as f64;
        let mut ttft = Summary::new();
        for r in &out {
            ttft.add(r.ttft * 1e3);
        }
        println!(
            "{:>10} {:>12} {:>14.1} {:>10.3} {:>12.2}",
            s, generated, tokens_per_s, tok_ms, ttft.p50()
        );
        rows.push(Json::obj(vec![
            ("sessions", Json::num(s as f64)),
            ("generated_tokens", Json::num(generated as f64)),
            ("tokens_per_s", Json::num(tokens_per_s)),
            ("tok_ms", Json::num(tok_ms)),
            ("ttft_p50_ms", Json::num(ttft.p50())),
        ]));
        tput.push((s, tokens_per_s));
    }

    // The point of batching: the widest batch must beat serial serving
    // on aggregate throughput.
    let mut speedup = 1.0;
    let lo = tput.iter().min_by_key(|(s, _)| *s).copied();
    let hi = tput.iter().max_by_key(|(s, _)| *s).copied();
    if let (Some((s_lo, t_lo)), Some((s_hi, t_hi))) = (lo, hi) {
        if s_hi > s_lo {
            speedup = t_hi / t_lo;
            println!("# throughput {s_hi} vs {s_lo} sessions: {speedup:.2}x");
            ensure!(
                speedup > 1.0,
                "batched serving at {s_hi} sessions must out-throughput {s_lo} session(s), got {speedup:.2}x"
            );
        }
    }

    let report = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("model", Json::str(model)),
        ("backend", Json::str(block_attn::runtime::backend_choice(&args))),
        ("kv_precision", Json::str(kv_precision.as_str())),
        ("threads", Json::num(threads as f64)),
        ("max_new_tokens", Json::num(max_new as f64)),
        ("passages_per_query", Json::num(k as f64)),
        ("throughput_speedup", Json::num(speedup)),
        ("rows", Json::Arr(rows)),
    ]);
    let out_path = args.str_or("json-out", "BENCH_serving.json");
    std::fs::write(&out_path, format!("{report}\n"))?;
    eprintln!("# wrote {out_path}");
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}
