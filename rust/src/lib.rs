//! # block-attn — Block-Attention for Efficient Prefilling (ICLR 2025)
//!
//! A three-layer Rust + JAX + Pallas serving stack reproducing
//! *Block-Attention for Efficient Prefilling* (Ma, Wang & Lan, ICLR 2025).
//!
//! The paper's idea: in RAG serving, split the prompt into semantically
//! independent blocks (one per retrieved passage), let every block compute
//! its KV states *independently* (block-diagonal attention), cache those KV
//! states keyed by block content, and at request time only compute the
//! final (query) block — which attends to all cached blocks after their
//! RoPE positions are *re-encoded* to the block's position in this prompt.
//! TTFT and prefill FLOPs become (nearly) independent of context length.
//!
//! ## Backends
//!
//! The serving stack ([`coordinator`], [`server`], [`train`], the benches)
//! is generic over the [`runtime::Backend`] trait. Two implementations:
//!
//! * **native** (default) — [`runtime::NativeBackend`], a pure-Rust
//!   Llama-style forward pass (embedding, RMSNorm, GQA attention with
//!   block-diagonal masking, RoPE, SwiGLU) plus a hand-derived backward
//!   pass for block fine-tuning. Deterministic seeded weights, no
//!   artifacts, no C dependencies. This is what the hermetic test suite
//!   runs against: `cargo test -q` exercises coordinator → cache →
//!   re-encode → decode end to end with nothing installed.
//! * **xla** (cargo feature `xla`) — [`runtime::ModelEngine`]: loads the
//!   AOT HLO artifacts produced by `python/compile/aot.py` and executes
//!   them on the PJRT CPU client. Requires a real `xla` crate (see
//!   `rust/vendor/xla-stub/README.md`) and `make artifacts`.
//!
//! Every binary and bench selects with `--backend native|xla`
//! (`$BLOCK_ATTN_BACKEND` overrides the default); checkpoints are
//! interchangeable because both backends share the flat-f32 parameter
//! layout.
//!
//! ## Kernels & threading
//!
//! All native dense math runs on the [`kernels`] layer: cache-blocked
//! (tiled) GEMMs in the three layouts the forward/backward passes need,
//! fused row kernels (RMSNorm, softmax, SwiGLU), and a fork/join
//! parallel-for dispatched to a **persistent worker pool**
//! ([`util::pool::ThreadPool`]). Workers are spawned once from the
//! process-global thread budget (`--threads N` >
//! `$BLOCK_ATTN_THREADS` > available parallelism) and live for the
//! process, so a parallel region costs a queue push + condvar wake
//! instead of a per-region thread spawn/join — cheap enough that even
//! decode-sized ops (one dispatch per layer per generated token)
//! parallelize. The budget drives attention row/head parallelism, GEMM
//! row splits, the **batch-parallel train step** (per-row gradients
//! reduced in ascending row order), and the coordinator's **concurrent
//! block prefill**: cache-miss blocks are independent (block-diagonal
//! attention), so [`runtime::Backend::prefill_blocks`] fans them out
//! one per budgeted worker.
//!
//! Budget inheritance: nested regions split their parent's budget
//! evenly instead of oversubscribing (2 blocks on 8 threads → 2
//! workers × 4 inner threads); leaf row-splits hand their chunks a
//! budget of 1. The submitting thread always runs the first chunk and
//! then executes its own region's still-queued tasks while it waits,
//! so regions complete at any worker count and nested regions cannot
//! deadlock. To add a new
//! parallel consumer, express the work as disjoint output rows and
//! call [`kernels::par_rows`] / [`kernels::par_map`] — never spawn
//! threads directly (see the [`kernels`] module docs).
//!
//! Determinism: every kernel accumulates each output element in a fixed
//! reduction order and every parallel split is row-disjoint
//! and a pure function of the *budget* (never of pool state), so
//! serving output is **bitwise identical at every thread count** — CI
//! runs the suite at `BLOCK_ATTN_THREADS=1`, `=3` (odd, non-divisible
//! splits) and `=4` to pin it. Pool counters (workers, jobs executed,
//! queue-depth high-water) surface in the server stats endpoint and
//! the bench reports via [`kernels::pool_stats`].
//!
//! **SIMD dispatch** ([`kernels::simd`]): the hot inner loops
//! (f32/int8/int4 dot + axpy, dequant rows, the GEMM serial tiles, the
//! RMSNorm reduction, the RoPE rotation) have runtime-dispatched vector
//! bodies — AVX2 on x86_64, NEON on aarch64, detected at startup with
//! the scalar reference as the universal fallback. Selection:
//! `--simd auto|off` > `$BLOCK_ATTN_SIMD` > auto-detect (invalid values
//! fail loudly). The scalar references are restructured to the same
//! **lane-striped reduction order** the vector units use (8 fixed f32
//! partial sums folded ascending; 4 for the f64 RMSNorm sum), and the
//! vector bodies use separate mul+add (never FMA), so every SIMD
//! variant is **bitwise identical** to scalar at every shape, tier,
//! and thread count — `--simd` is a pure wall-clock knob, pinned by
//! `tests/simd_parity.rs` and a `BLOCK_ATTN_SIMD=off` CI leg. The
//! active ISA is reported as `simd_isa` in server stats and in bench
//! footers. To add a vector kernel, see the [`kernels::simd`] module
//! docs (stripe the scalar body first, mirror the lane assignment,
//! dispatch on [`kernels::active_isa`], pin parity).
//!
//! ## Quantized KV tiers
//!
//! The block-KV cache **and the decode-path context** store at a
//! configurable precision ([`config::KvPrecision`],
//! `--kv-quant f32|int8|int4` / `$BLOCK_ATTN_KV_QUANT`):
//!
//! | tier | codes | scales | bytes/block | blocks per budget | accuracy contract |
//! |------|-------|--------|-------------|-------------------|-------------------|
//! | `f32`  | — | — | 100% | 1× | bit-lossless reuse |
//! | `int8` | 1 B/elem | per (layer, head, channel) | ~27% | ~4× | decode-logit cosine ≥ 0.999 vs f32 |
//! | `int4` | ½ B/elem, packed pairs | per (layer, head, channel, 32-token group) | ≤ 16% | ~8× | decode-logit cosine ≥ 0.99 vs f32 |
//!
//! Pick `f32` when bit-exact reuse matters more than capacity, `int8`
//! as the default capacity tier (TurboRAG-style: more resident passage
//! blocks ⇒ more hits ⇒ lower TTFT), and `int4` when the corpus is far
//! larger than memory and the relaxed 0.99 cosine bound is acceptable.
//!
//! Blocks are quantized once at cache insert ([`kernels::quant`]);
//! fetch fuses dequantization (and the int4 nibble unpack) into the
//! Eq.-3 RoPE re-encode ([`rope::RopeTable::reencode_block_dequant`] /
//! [`rope::RopeTable::reencode_block_dequant_i4`]).
//!
//! ## Re-encode acceleration
//!
//! All three fetch paths flow through one parameterized rotation
//! primitive ([`rope::RopeTable::reencode_into`] over a
//! [`rope::KvView`]), and each cache entry carries a byte-budgeted
//! **rotation memo**: a fetch at a `(key, Δ)` seen before returns the
//! memoized rotated panel — a copy, not a rotation — so warm
//! same-offset fetches are O(1) amortized (LazyAttention-style; memo
//! hit/miss/byte counters ride [`kvcache::CacheStats`] and the server
//! `stats` line). Determinism contract: `eager` mode (the default) and
//! every memo hit are **bitwise identical** to recomputing Eq. 3 from
//! the stored local codes, at every tier and thread count
//! (`tests/reencode_modes.rs`). The opt-in approximate path
//! (`--reencode eager|delta` / `$BLOCK_ATTN_REENCODE`, invalid values
//! fail loudly) instead rotates the *closest already-rotated* memoized
//! panel by `Δ₂−Δ₁`: rotations compose additively
//! (`rope::tests::reencode_composes_additively`), but float rounding
//! differs from the eager product, so `delta` is **cosine-contracted**
//! (decode-logit cosine ≥ 0.999 vs eager on the workload traces, like
//! the quant tiers) rather than bitwise.
//!
//! **Decode-path data flow** (the f32-dense assumption is gone): after
//! the final-block prefill, the assembled context + query KV is stored
//! once at tier precision as the static prefix of a
//! [`runtime::DecodeCtx`]; generated tokens append to a small growing
//! f32 tail. Each decode step's attention reads the prefix **codes**
//! directly through the fused mixed-precision kernels
//! ([`kernels::dot_i8`] / [`kernels::dot_i4`] and their `axpy` twins —
//! the same inner loops as the [`kernels::gemm_nt_i8_acc`] /
//! [`kernels::gemm_nt_i4_acc`] micro-kernel family), so no dense f32
//! copy of the context exists between fetch and attention — and the
//! old capacity-sized cache clone per decode step is gone with it.
//!
//! Because quantize/dequantize are per-element and order-free and the
//! fused kernels keep the ascending accumulation order, every tier
//! keeps serving bitwise identical at every thread count; CI runs
//! tier-1 legs with `BLOCK_ATTN_KV_QUANT=int8` and `=int4` so all
//! precisions stay green. Cache stats report `bytes_saved` (total and
//! per tier) and the running relative quantization error.
//!
//! ## Continuous batching
//!
//! The live server runs a vLLM-shaped **engine loop** on the dedicated
//! engine thread ([`server::EngineHandle`]): requests land in a bounded
//! admission queue (`--queue-depth`; a full queue blocks `submit` — the
//! client-facing backpressure), the scheduler
//! ([`coordinator::batcher::BatchRunner`]) admits at most **one**
//! prefill per decode round under the slot + token budgets
//! (`--max-active`, `--max-active-tokens`), and each round advances
//! every active session one token through
//! [`runtime::Backend::decode_batch`] — the native backend fuses all
//! sessions' per-token GEMV rows into one GEMM dispatch per projection,
//! turning memory-bound single-session decode into compute-dense
//! batched decode. Clients see each token as a streamed
//! `{"id":..,"token":..}` frame followed by one final full-response
//! line (see [`server`] for the wire protocol).
//!
//! TTFT is charged from each request's own arrival time (queueing
//! included), and per-round batch occupancy surfaces in the `stats`
//! endpoint (`decode_rounds`, `batch_occupancy`).
//!
//! ## Persistent KV store
//!
//! The block cache has an optional **disk tier**
//! ([`kvcache::disk::DiskStore`], `--kv-store-dir DIR
//! [--kv-store-budget MB]` / `$BLOCK_ATTN_KV_STORE_DIR` /
//! `$BLOCK_ATTN_KV_STORE_BUDGET`): LRU eviction spills a block's codes
//! + scales to a content-addressed file (write-behind), and a RAM miss
//! promotes the file back to a resident entry (read-through, fused
//! into the scheduler's normal `lookup_pin`). Because quantization
//! happens once at insert and files store the codes verbatim, a disk
//! round-trip is **bitwise invisible** at every tier and thread count
//! (`tests/kv_store.rs`), and warm TTFT survives process restarts —
//! the TurboRAG-style guarantee — instead of resetting with the RAM
//! cache. Block files are keyed by content hash **and a fingerprint of
//! the model weights**, so a store populated under other weights reads
//! as a clean miss, never stale KV. Corrupt/truncated/mismatched files
//! are rejected loudly and fall back to recompute. The `precompute`
//! bin encodes a passage corpus into a store ahead of serving; the
//! on-disk layout is specified in `docs/kvstore-format.md`.
//!
//! Determinism contract: a batched decode round is **bitwise
//! identical** to decoding each session serially, at every thread
//! count and KV tier — GEMM output rows are functions of their input
//! row only (fixed ascending-k reduction), RMSNorm/SwiGLU are
//! row-local, and each session's KV tail is written independently. So
//! batching — like threading and quantization tiering — is a pure
//! performance decision, never an accuracy one
//! (`tests/serving_batch.rs` pins this across threads × tiers).
//!
//! Layering (python never on the request path):
//! - **L1** `python/compile/kernels/` — Pallas attention + RoPE kernels.
//! - **L2** `python/compile/model.py` — Llama-style model, AOT-lowered to
//!   HLO text artifacts (`make artifacts`); the native backend mirrors it
//!   operation for operation.
//! - **L3** this crate — compute kernels, backends, block-KV cache with
//!   position re-encoding, segmentation, scheduling/batching, serving,
//!   training driver, benchmarks.
//!
//! Entry points:
//! - [`kernels`] — tiled/parallel compute kernels and the thread budget.
//! - [`runtime::Backend`] — the engine contract; [`runtime::backend_from_args`]
//!   builds one from CLI options.
//! - [`kvcache::BlockKvCache`] — content-addressed block KV cache;
//!   [`kvcache::disk::DiskStore`] — its persistent tier.
//! - [`coordinator::Coordinator`] — the serving stack (segment → plan →
//!   prefill → decode) with metrics.
//! - [`server`] — the TCP JSON-line front-end with the
//!   continuous-batching engine loop (protocol: `docs/serving.md`).
//! - [`train::train`] — block fine-tuning driver (presets in
//!   [`train::presets`]).
//!
//! Repository-level documentation: `README.md` (quick start),
//! `docs/ARCHITECTURE.md` (layer map, invariants, every CLI flag and
//! `BLOCK_ATTN_*` env var), `docs/serving.md` (wire protocol),
//! `docs/kvstore-format.md` (block file format).

// Dense numeric kernels index heavily; the idiomatic-iterator forms are
// measurably harder to keep allocation-free and fused.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod config;
pub mod coordinator;
pub mod flops;
pub mod kernels;
pub mod kvcache;
pub mod rope;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod tokenizer;
pub mod train;
pub mod util;
pub mod workload;

pub use config::ModelConfig;
pub use coordinator::Coordinator;
pub use runtime::{Backend, NativeBackend};
#[cfg(feature = "xla")]
pub use runtime::ModelEngine;

/// CLI dispatcher used by the `block-attn` binary.
pub fn run_cli(args: &util::cli::Args) -> anyhow::Result<()> {
    kernels::init_threads_from_args(args);
    match args.subcommand() {
        Some("info") => cli_info(args),
        Some("train") => cli_train(args),
        Some("serve") => cli_serve(args),
        Some("eval") => cli_eval(args),
        Some(other) => anyhow::bail!("unknown subcommand '{other}'"),
        None => {
            eprintln!("usage: block-attn <info|train|serve|eval> [--options]");
            eprintln!("  common: --backend native|xla   (default native; xla needs --features xla)");
            eprintln!("          --model tiny|small|bench [--checkpoint FILE]");
            eprintln!("          --threads N            (kernel threads; or $BLOCK_ATTN_THREADS)");
            eprintln!("          --kv-quant f32|int8|int4  (KV cache tier; or $BLOCK_ATTN_KV_QUANT)");
            eprintln!("          --reencode eager|delta (fetch re-encode mode; or $BLOCK_ATTN_REENCODE)");
            eprintln!("          --segment passages|text|icl|chat|gamecore|auto");
            eprintln!("                                 (request segmentation; or $BLOCK_ATTN_SEGMENT)");
            eprintln!("          --simd auto|off        (vector kernels; or $BLOCK_ATTN_SIMD)");
            eprintln!("          --kv-store-dir DIR     (persistent block store; or $BLOCK_ATTN_KV_STORE_DIR)");
            eprintln!("          --kv-store-budget MB   (disk budget, 0=unbounded; or $BLOCK_ATTN_KV_STORE_BUDGET)");
            eprintln!("  info   [--artifacts DIR]");
            eprintln!("  train  --preset table1 --out DIR [--scale 1.0]");
            eprintln!("  serve  --addr 127.0.0.1:7841 [--workers 4] [--cache-mb 256]");
            eprintln!("         [--max-active 4] [--max-active-tokens 16384] [--queue-depth 64]");
            eprintln!("         (continuous batching; or $BLOCK_ATTN_MAX_ACTIVE etc.)");
            eprintln!("  eval   [--mode full|block] [--samples 10] [--show]");
            eprintln!("  (offline corpus -> store encoding lives in the `precompute` bin)");
            Ok(())
        }
    }
}

/// Evaluate a checkpoint on the synthetic RAG benchmarks, optionally
/// dumping generations (debugging aid for the accuracy experiments).
fn cli_eval(args: &util::cli::Args) -> anyhow::Result<()> {
    use coordinator::{AttentionMode, Request};
    use tokenizer::ByteTokenizer;

    let n = args.usize_or("samples", 10);
    let mode = AttentionMode::parse(&args.str_or("mode", "full"))?;
    let backend = runtime::backend_from_args(args, "tiny")?;
    if let Some(ck) = args.get("checkpoint") {
        backend.load_params_file(std::path::Path::new(ck))?;
    }
    let kv_precision = config::KvPrecision::resolve(args)?;
    let mut coord = Coordinator::with_kv_precision(backend, 128 << 20, kv_precision);
    coord.set_reencode_mode(config::ReencodeMode::resolve(args)?);
    coord.set_segment_policy(config::SegmentPolicy::resolve(args)?);
    if let Some(sc) = config::KvStoreConfig::resolve(args)? {
        coord.attach_kv_store(&sc)?;
    }
    let tok = ByteTokenizer::new();
    for (bench_name, samples) in train::presets::rag_eval_by_variant(n) {
        let mut correct = 0;
        for (i, s) in samples.iter().enumerate() {
            let sp = s.segment(&tok);
            let req = Request {
                id: i as u64,
                blocks: sp.blocks,
                query: sp.query,
                max_new_tokens: 48,
                mode,
            };
            let resp = coord.process(&req)?;
            let text = tok.decode_until_eos(&resp.tokens);
            let ok = text.contains(&s.answer);
            correct += ok as usize;
            if args.flag("show") && i < 5 {
                println!("  [{}] q={:?} gold={:?} got={:?}", ok as u8, s.query, s.answer, text);
            }
        }
        println!("{bench_name}: {}/{}", correct, samples.len());
        if args.flag("show") {
            break;
        }
    }
    Ok(())
}

fn cli_serve(args: &util::cli::Args) -> anyhow::Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7841");
    let workers = args.usize_or("workers", 4);
    let cache_mb = args.usize_or("cache-mb", 256);
    let kv_precision = config::KvPrecision::resolve(args)?;
    let reencode = config::ReencodeMode::resolve(args)?;
    let segment = config::SegmentPolicy::resolve(args)?;
    let store_cfg = config::KvStoreConfig::resolve(args)?;
    let policy = coordinator::batcher::BatchPolicy::resolve(args);
    let args2 = args.clone();
    let handle = server::EngineHandle::spawn_with_policy(
        move || {
            let backend = runtime::backend_from_args(&args2, "tiny")?;
            if let Some(ck) = args2.get("checkpoint") {
                backend.load_params_file(std::path::Path::new(ck))?;
            }
            backend.warmup()?;
            let mut coord = Coordinator::with_kv_precision(backend, cache_mb << 20, kv_precision);
            coord.set_reencode_mode(reencode);
            // Connection handlers segment with the same resolved policy
            // (passed to `serve` below); the coordinator carries it so
            // the `stats` line reports what is in force.
            coord.set_segment_policy(segment);
            if let Some(sc) = &store_cfg {
                coord.attach_kv_store(sc)?;
            }
            Ok(coord)
        },
        policy,
    )?;
    server::serve(&addr, handle, workers, segment)
}

fn cli_train(args: &util::cli::Args) -> anyhow::Result<()> {
    let out = std::path::PathBuf::from(args.str_or("out", "checkpoints"));
    let scale = args.f64_or("scale", 1.0);
    let backend = runtime::backend_from_args(args, "tiny")?;
    let kv_precision = config::KvPrecision::resolve(args)?;
    let mut coord = Coordinator::with_kv_precision(backend, 256 << 20, kv_precision);
    let mut opts = train::presets::PresetOpts::scaled(scale);
    opts.only_block = args.flag("only-block");
    match args.str_or("preset", "table1").as_str() {
        "table1" => train::presets::run_table1_training(&mut coord, &out, &opts),
        other => anyhow::bail!("unknown preset '{other}'"),
    }
}

fn cli_info(args: &util::cli::Args) -> anyhow::Result<()> {
    // With the xla backend selected (and compiled in) show the artifact
    // manifest; the native backend reports its built-in config.
    #[cfg(feature = "xla")]
    if runtime::backend_choice(args) == "xla" {
        let dir = args.str_or("artifacts", "artifacts");
        let manifest = config::Manifest::load(&dir)?;
        for (name, m) in &manifest.models {
            println!(
                "{name}: {} layers, d_model {}, {} heads ({} kv), vocab {}, {} entries",
                m.config.layers,
                m.config.d_model,
                m.config.heads,
                m.config.kv_heads,
                m.config.vocab,
                m.entries.len()
            );
            for e in &m.entries {
                println!("  {:<40} {:?} {:?}", e.name, e.kind, e.sizes);
            }
        }
        return Ok(());
    }
    let backend = runtime::backend_from_args(args, "tiny")?;
    let cfg = backend.config();
    let n_params = cfg.param_count(backend.param_specs());
    println!(
        "{}: {} layers, d_model {}, {} heads ({} kv, head_dim {}), d_ff {}, vocab {}, max_len {}",
        cfg.name,
        cfg.layers,
        cfg.d_model,
        cfg.heads,
        cfg.kv_heads,
        cfg.head_dim,
        cfg.d_ff,
        cfg.vocab,
        cfg.max_len
    );
    println!("  {} parameters across {} tensors:", n_params, backend.param_specs().len());
    for p in backend.param_specs() {
        println!("    {:<12} {:?}", p.name, p.shape);
    }
    Ok(())
}
