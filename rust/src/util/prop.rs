//! Property-based testing helpers (proptest replacement).
//!
//! A property is a closure over a [`Rng`]; [`check`] runs it for many
//! random cases and, on failure, reports the failing case seed so the run
//! can be reproduced with `case(seed)`.

use super::rng::Rng;

/// Outcome of a property check on one case.
pub type CaseResult = Result<(), String>;

/// Run `prop` for `cases` random cases derived from `seed`.
///
/// Panics with the failing case's seed on the first failure.
pub fn check(name: &str, seed: u64, cases: usize, prop: impl Fn(&mut Rng) -> CaseResult) {
    let mut meta = Rng::new(seed);
    for i in 0..cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {i} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Run a property on one specific case seed (for reproducing failures).
pub fn case(name: &str, case_seed: u64, mut prop: impl FnMut(&mut Rng) -> CaseResult) {
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {case_seed:#x}): {msg}");
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Assert equality helper for properties.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 1, 200, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 1, 10, |_| Err("nope".into()));
    }

    #[test]
    fn case_reproduces() {
        // The same seed must generate the same values.
        let mut observed = Vec::new();
        case("record", 0xABCD, |rng| {
            observed.push(rng.next_u64());
            Ok(())
        });
        let mut rng = Rng::new(0xABCD);
        assert_eq!(observed[0], rng.next_u64());
    }
}
