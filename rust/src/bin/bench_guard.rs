//! `bench_guard` — the CI perf-regression gate.
//!
//! Diffs two machine-readable bench reports (the `BENCH_*.json` files
//! written by `cargo bench --bench kernels` / `--bench table3_ttft`)
//! and fails when any tracked metric regresses beyond a threshold.
//!
//! ```sh
//! bench_guard --baseline ci/baselines/BENCH_kernels.json \
//!             --current BENCH_kernels.json [--threshold 1.5] [--min-ms 0.05]
//! ```
//!
//! Tracked metrics are every numeric field whose key ends in `_ms`
//! (times), found recursively — nested `rows` arrays are matched by
//! index, which is stable because CI pins the bench shapes. Baselines
//! below `--min-ms` are skipped: sub-tenth-millisecond timings are
//! noise-dominated on shared runners. Exit code is non-zero iff any
//! metric's `current / baseline` exceeds `--threshold` (default 1.5×)
//! — or a baseline metric is missing from the current report, so a
//! bench refactor cannot silently drop its own gate.

use block_attn::util::cli::Args;
use block_attn::util::json::Json;

/// Flatten to `(dotted.path[idx], value)` pairs for every numeric leaf.
fn flatten(prefix: &str, j: &Json, out: &mut Vec<(String, f64)>) {
    match j {
        Json::Num(n) => out.push((prefix.to_string(), *n)),
        Json::Arr(a) => {
            for (i, v) in a.iter().enumerate() {
                flatten(&format!("{prefix}[{i}]"), v, out);
            }
        }
        Json::Obj(o) => {
            for (k, v) in o {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(&p, v, out);
            }
        }
        _ => {}
    }
}

fn load_metrics(path: &str) -> anyhow::Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let mut out = Vec::new();
    flatten("", &json, &mut out);
    out.retain(|(k, _)| k.ends_with("_ms"));
    Ok(out)
}

/// Outcome of one baseline-vs-current comparison: the printable table
/// body, the failure descriptions, and how many metrics were actually
/// gated (after `min_ms` skips).
struct GateReport {
    lines: Vec<String>,
    regressions: Vec<String>,
    compared: usize,
}

/// The pure comparison behind `main` — split out so the gate semantics
/// (including the missing-key failure) are unit-testable without
/// touching the filesystem or process exit codes.
fn gate(
    baseline: &[(String, f64)],
    current: &[(String, f64)],
    threshold: f64,
    min_ms: f64,
) -> GateReport {
    let mut report = GateReport { lines: Vec::new(), regressions: Vec::new(), compared: 0 };
    for (key, base) in baseline {
        let Some((_, cur)) = current.iter().find(|(k, _)| k == key) else {
            // A vanished metric is a gate failure, not a skip: a bench
            // refactor that drops or renames a timed metric must not
            // silently disable its regression coverage.
            report.lines.push(format!("{key:<40} {base:>12.3} {:>12} {:>8}  MISSING", "-", "-"));
            report
                .regressions
                .push(format!("{key}: present in baseline, missing from current run"));
            continue;
        };
        if !base.is_finite() || *base < min_ms {
            report.lines.push(format!(
                "{key:<40} {base:>12.3} {cur:>12.3} {:>8}  below --min-ms (skipped)",
                "-"
            ));
            continue;
        }
        report.compared += 1;
        let ratio = cur / base;
        let status = if ratio > threshold { "REGRESSED" } else { "ok" };
        report.lines.push(format!("{key:<40} {base:>12.3} {cur:>12.3} {ratio:>7.2}x  {status}"));
        if ratio > threshold {
            report.regressions.push(format!("{key}: {base:.3} ms -> {cur:.3} ms ({ratio:.2}x)"));
        }
    }
    report
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow::anyhow!("--baseline PATH is required"))?
        .to_string();
    let current_path = args
        .get("current")
        .ok_or_else(|| anyhow::anyhow!("--current PATH is required"))?
        .to_string();
    let threshold = args.f64_or("threshold", 1.5);
    let min_ms = args.f64_or("min-ms", 0.05);

    let baseline = load_metrics(&baseline_path)?;
    let current = load_metrics(&current_path)?;

    println!("# bench_guard: {current_path} vs {baseline_path} (fail > {threshold:.2}x)");
    println!("{:<40} {:>12} {:>12} {:>8}  status", "metric", "baseline", "current", "ratio");
    let report = gate(&baseline, &current, threshold, min_ms);
    for line in &report.lines {
        println!("{line}");
    }
    if report.compared == 0 {
        anyhow::bail!(
            "no comparable *_ms metrics between {baseline_path} and {current_path} — \
             wrong file, or the bench output format drifted from the baseline"
        );
    }
    if !report.regressions.is_empty() {
        anyhow::bail!(
            "{} perf gate failure(s) (>{threshold:.2}x regression or missing metric):\n  {}",
            report.regressions.len(),
            report.regressions.join("\n  ")
        );
    }
    println!("# {} metrics within {threshold:.2}x of baseline", report.compared);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(pairs: &[(&str, f64)]) -> Vec<(String, f64)> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn missing_baseline_key_fails_loudly() {
        let base = metrics(&[("a_ms", 2.0), ("b_ms", 3.0)]);
        let cur = metrics(&[("a_ms", 2.0)]);
        let r = gate(&base, &cur, 1.5, 0.05);
        assert_eq!(r.regressions.len(), 1);
        assert!(r.regressions[0].contains("b_ms"), "{:?}", r.regressions);
        assert!(r.regressions[0].contains("missing"), "{:?}", r.regressions);
        // The present metric still gates normally alongside the failure.
        assert_eq!(r.compared, 1);
    }

    #[test]
    fn regression_beyond_threshold_fails() {
        let base = metrics(&[("a_ms", 2.0)]);
        let cur = metrics(&[("a_ms", 3.5)]);
        let r = gate(&base, &cur, 1.5, 0.05);
        assert_eq!(r.regressions.len(), 1);
        assert!(r.regressions[0].contains("1.75x"), "{:?}", r.regressions);
    }

    #[test]
    fn within_threshold_passes() {
        let base = metrics(&[("a_ms", 2.0), ("b_ms", 10.0)]);
        let cur = metrics(&[("a_ms", 2.9), ("b_ms", 4.0)]);
        let r = gate(&base, &cur, 1.5, 0.05);
        assert!(r.regressions.is_empty(), "{:?}", r.regressions);
        assert_eq!(r.compared, 2);
    }

    #[test]
    fn sub_min_ms_baselines_are_skipped_not_gated() {
        // 0.01 ms baseline regressing 100x is runner noise, not signal.
        let base = metrics(&[("tiny_ms", 0.01)]);
        let cur = metrics(&[("tiny_ms", 1.0)]);
        let r = gate(&base, &cur, 1.5, 0.05);
        assert!(r.regressions.is_empty());
        assert_eq!(r.compared, 0);
    }

    #[test]
    fn flatten_extracts_nested_ms_keys() {
        let json = Json::parse(r#"{"a_ms": 1.5, "rows": [{"b_ms": 2.0, "n": 7}], "c": "x"}"#)
            .unwrap();
        let mut out = Vec::new();
        flatten("", &json, &mut out);
        out.retain(|(k, _)| k.ends_with("_ms"));
        assert_eq!(
            out,
            vec![("a_ms".to_string(), 1.5), ("rows[0].b_ms".to_string(), 2.0)]
        );
    }
}
