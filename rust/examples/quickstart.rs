//! Quickstart: load the tiny model, serve three RAG queries that share
//! passages, and watch the block KV cache turn repeat passages into
//! near-free prefills.
//!
//! ```sh
//! cargo run --release --example quickstart            # hermetic native backend
//! cargo run --release --example quickstart -- --backend xla   # AOT artifacts
//! # with a trained checkpoint (make checkpoints):
//! cargo run --release --example quickstart -- --checkpoint checkpoints/tiny_block.bin
//! ```

use block_attn::coordinator::segmenter::segment_rag;
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::runtime::backend_from_args;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::cli::Args;
use block_attn::Backend;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    let engine = backend_from_args(&args, "tiny")?;
    if let Some(ck) = args.get("checkpoint") {
        engine.load_params_file(std::path::Path::new(ck))?;
        println!("loaded checkpoint {ck}");
    }
    // Pre-compile the serving executables (xla backend) so TTFTs below
    // measure serving, not first-use compilation; no-op on native.
    engine.warmup()?;
    let mut coord = Coordinator::new(engine, 64 << 20);
    let tok = ByteTokenizer::new();

    let passages = vec![
        "the key of obelisk is marble .".to_string(),
        "the color of lantern is copper .".to_string(),
        "the owner of harbor is silas .".to_string(),
    ];
    let queries = [
        "what is the key of obelisk ?",
        "what is the color of lantern ?",
        "what is the owner of harbor ?",
    ];

    println!("── Block-attention serving (3 queries over the same 3 passages)\n");
    for (i, q) in queries.iter().enumerate() {
        let sp = segment_rag(&tok, None, &passages, q);
        let req = Request {
            id: i as u64,
            blocks: sp.blocks,
            query: sp.query,
            max_new_tokens: 12,
            mode: AttentionMode::Block,
        };
        let resp = coord.process(&req)?;
        println!(
            "q{i}: ttft={:6.2} ms  cache {}/{} blocks  flops_tft={:.2e}  → {:?}",
            resp.ttft * 1e3,
            resp.cached_blocks,
            resp.total_blocks,
            resp.flops_tft,
            tok.decode_until_eos(&resp.tokens),
        );
    }

    // The same prompt through the vanilla full-attention baseline.
    let sp = segment_rag(&tok, None, &passages, queries[0]);
    let req = Request {
        id: 99,
        blocks: sp.blocks,
        query: sp.query,
        max_new_tokens: 12,
        mode: AttentionMode::Full,
    };
    let resp = coord.process(&req)?;
    println!(
        "\nvanilla full-attention: ttft={:6.2} ms  flops_tft={:.2e}",
        resp.ttft * 1e3,
        resp.flops_tft
    );
    println!("\n{}", coord.metrics.report());
    let s = coord.cache_stats();
    println!(
        "cache: {} blocks, {:.1} kB, hit rate {:.0}%",
        s.entries,
        s.bytes as f64 / 1e3,
        s.hit_rate() * 100.0
    );
    Ok(())
}
