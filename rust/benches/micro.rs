//! Micro-benchmarks of the L3 hot paths (criterion-style, in-tree
//! harness): RoPE re-encoding, cache operations, hashing, planning,
//! segmentation, JSON. These are the knobs the §Perf pass turns.
//!
//! ```sh
//! cargo bench --bench micro
//! ```

use block_attn::coordinator::scheduler::Scheduler;
use block_attn::coordinator::segmenter::{segment_gamecore, segment_text};
use block_attn::coordinator::write_ctx;
use block_attn::kvcache::{block_key, BlockKvCache};
use block_attn::rope::RopeTable;
use block_attn::tensor::Tensor;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use block_attn::util::rng::Rng;
use block_attn::util::timer::{bench, BenchOpts};
use block_attn::workload::gamecore::GamecoreSim;

fn main() {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    let opts = BenchOpts { warmup_iters: 3, iters: 30, max_seconds: 10.0 };
    let mut rng = Rng::new(1);

    // RoPE re-encode of one cached block (the per-hit cost of reuse):
    // bench-config block: 4 layers x 512 tokens x 4 kv heads x 32 dim.
    let rope = RopeTable::new(32, 500000.0);
    let dims = [4usize, 512, 4, 32];
    let n: usize = dims.iter().product();
    let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut k = Tensor::from_vec(&dims, data);
    let r = bench("rope_reencode_block(4x512x4x32)", &opts, || {
        rope.reencode_block(k.data_mut(), 4, 512, 4, 1234);
    });
    let mb = (n * 4) as f64 / 1e6;
    println!("{}  ({:.0} MB/s)", r.report_line(), mb / (r.summary.mean()));

    // Content hashing of a 512-token block.
    let toks: Vec<i32> = (0..512).map(|_| rng.below(32000) as i32).collect();
    let r = bench("block_key(512 tokens)", &opts, || {
        std::hint::black_box(block_key(&toks));
    });
    println!("{}", r.report_line());

    // Cache insert + lookup + evict churn.
    let mut cache = BlockKvCache::new(RopeTable::new(32, 10000.0), 8 << 20);
    let mut i = 0u64;
    let r = bench("cache_insert_lookup_evict", &opts, || {
        for _ in 0..100 {
            i += 1;
            let key = block_key(&[i as i32]);
            if !cache.lookup_pin(key) {
                let k = Tensor::zeros(&[4, 64, 4, 32]);
                cache.insert_pinned(key, k.clone(), k);
            }
            cache.unpin(key);
        }
    });
    println!("{}  (100 ops/iter)", r.report_line());

    // Prefill planning over 32 blocks.
    let blocks: Vec<Vec<i32>> = (0..32)
        .map(|b| (0..64).map(|t| (b * 64 + t) as i32).collect())
        .collect();
    let sched = Scheduler::new();
    let mut cache2 = BlockKvCache::new(RopeTable::new(32, 10000.0), 0);
    let r = bench("scheduler_plan(32 blocks)", &opts, || {
        let plan = sched.plan(&blocks, &mut cache2);
        for it in &plan.items {
            if it.cached {
                cache2.unpin(it.key);
            }
        }
        std::hint::black_box(plan.total_tokens);
    });
    println!("{}", r.report_line());

    // Context assembly memcpy: write 32 x 64-token blocks into a 2048 ctx.
    let block_kv = Tensor::zeros(&[4usize, 64, 4, 32]);
    let mut ctx = Tensor::zeros(&[4usize, 2048, 4, 32]);
    let r = bench("assemble_ctx(32x64 into 2048)", &opts, || {
        for b in 0..32 {
            write_ctx(&mut ctx, &block_kv, b * 64);
        }
    });
    println!("{}", r.report_line());

    // Segmentation of gamecore JSON and labeled text.
    let tok = ByteTokenizer::new();
    let sim = GamecoreSim::new(8, 3);
    let frame = sim.frame();
    let r = bench("segment_gamecore(8 players)", &opts, || {
        std::hint::black_box(segment_gamecore(&tok, &frame, "act").blocks.len());
    });
    println!("{}", r.report_line());

    let text = "para one\n\npara two---para three===tail ".repeat(50);
    let r = bench("segment_text(~2kB)", &opts, || {
        std::hint::black_box(segment_text(&tok, &text).blocks.len());
    });
    println!("{}", r.report_line());

    // JSON parse of a gamecore frame.
    let frame_str = frame.to_string();
    let r = bench("json_parse(gamecore frame)", &opts, || {
        std::hint::black_box(Json::parse(&frame_str).unwrap());
    });
    println!(
        "{}  ({:.1} MB/s)",
        r.report_line(),
        frame_str.len() as f64 / 1e6 / r.summary.mean()
    );
    eprintln!("{}", block_attn::kernels::pool_stats_line());
}
