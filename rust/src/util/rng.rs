//! Deterministic pseudo-random number generation (splitmix64 core).
//!
//! Replacement for the `rand` crate (unavailable offline). Used by the
//! workload generators, parameter init cross-checks and property tests.
//! All experiment code takes explicit seeds so every table/figure in
//! EXPERIMENTS.md is exactly reproducible.

/// A small, fast, deterministic RNG (splitmix64).
///
/// Not cryptographically secure; statistical quality is more than enough
/// for workload generation and property testing.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a new RNG from a seed. Two RNGs with the same seed yield the
    /// same sequence forever.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniform element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from a Zipf(s) distribution over `[0, n)`.
    ///
    /// Used to model passage reuse skew in the RAG workload (a few hot
    /// passages are retrieved for many queries — the regime where block KV
    /// caching pays off, cf. paper §3.7).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF over precomputation-free harmonic approximation:
        // acceptable for n <= ~1e6 workload sizes; exactness irrelevant.
        debug_assert!(n > 0);
        let u = self.f64();
        // Normalizing constant H(n, s) approximated by integral.
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let hn = h(n as f64);
        let target = u * hn;
        // Invert.
        let x = if (s - 1.0).abs() < 1e-9 {
            target.exp() - 1.0
        } else {
            ((target * (1.0 - s) + 1.0).powf(1.0 / (1.0 - s))) - 1.0
        };
        (x as usize).min(n - 1)
    }

    /// Derive a new independent RNG (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let n = 1 + (r.next_u64() % 100) as usize;
            assert!(r.below(n) < n);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let (mut s1, mut s2) = (0.0, 0.0);
        let n = 20_000;
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_skew() {
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 1.1)] += 1;
        }
        // Head should dominate tail.
        assert!(counts[0] > counts[50] * 5, "{} vs {}", counts[0], counts[50]);
        assert!(counts[0] > counts[10]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
