//! Coordinator-level integration tests: the serving pipeline
//! (segment → plan → prefill → decode) with cache semantics.
//!
//! Hermetic: they run on the pure-Rust [`NativeBackend`], so
//! `cargo test -q` exercises coordinator → cache → RoPE re-encode →
//! decode end to end with no artifacts directory and no XLA. The same
//! suite runs against real AOT artifacts via the `xla_artifacts` module
//! at the bottom (`--features xla` + `make artifacts`).

use block_attn::config::ModelConfig;
use block_attn::coordinator::batcher::{run_batch, BatchPolicy};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::runtime::NativeBackend;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::rng::Rng;
use block_attn::workload::rag::{RagGen, RagVariant};

fn coordinator() -> Coordinator<NativeBackend> {
    let engine = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C);
    Coordinator::new(engine, 64 << 20)
}

fn rag_request(id: u64, seed: u64, mode: AttentionMode) -> Request {
    let tok = ByteTokenizer::new();
    let mut rng = Rng::new(seed);
    let gen = RagGen::new(RagVariant::OneHopEasy, &mut rng, 30);
    let sp = gen.sample(&mut rng).segment(&tok);
    Request {
        id,
        blocks: sp.blocks,
        query: sp.query,
        max_new_tokens: 8,
        mode,
    }
}

/// Invariant #4 (DESIGN.md): a cache hit must produce bit-identical
/// tokens to a cold-cache run of the same request.
#[test]
fn cache_hits_do_not_change_output() {
    let mut coord = coordinator();
    let req = rag_request(1, 11, AttentionMode::Block);

    let cold = coord.process(&req).expect("cold");
    assert_eq!(cold.cached_blocks, 0);
    let warm = coord.process(&req).expect("warm");
    assert_eq!(warm.cached_blocks, warm.total_blocks, "all blocks cached");
    assert_eq!(cold.tokens, warm.tokens, "cache changed the output");
    assert!(warm.flops_tft < cold.flops_tft * 0.7, "hit did not save FLOPs");
}

#[test]
fn shared_passages_hit_across_requests() {
    let mut coord = coordinator();
    // Two different queries over the same passage set.
    let base = rag_request(1, 22, AttentionMode::Block);
    let mut other = base.clone();
    other.id = 2;
    other.query = {
        let tok = ByteTokenizer::new();
        let mut q = vec![block_attn::tokenizer::QRY];
        q.extend(tok.encode("what is the color of nothing ?"));
        q
    };
    let a = coord.process(&base).unwrap();
    assert_eq!(a.cached_blocks, 0);
    let b = coord.process(&other).unwrap();
    assert_eq!(b.cached_blocks, b.total_blocks, "cross-request reuse failed");
}

#[test]
fn precompute_makes_first_request_hot() {
    let mut coord = coordinator();
    let req = rag_request(5, 33, AttentionMode::Block);
    for blk in &req.blocks {
        coord.precompute_block(blk).unwrap();
    }
    let r = coord.process(&req).unwrap();
    assert_eq!(r.cached_blocks, r.total_blocks);
}

#[test]
fn modes_agree_structurally_but_differ_numerically() {
    // Without fine-tuning the modes produce different logits (that is the
    // paper's w/o-ft gap) but identical bookkeeping.
    let mut coord = coordinator();
    let full = coord.process(&rag_request(1, 44, AttentionMode::Full)).unwrap();
    let block = coord.process(&rag_request(2, 44, AttentionMode::Block)).unwrap();
    assert_eq!(full.prompt_tokens, block.prompt_tokens);
    assert_eq!(full.total_blocks, block.total_blocks);
    // Block mode with cached context does far less prefill compute.
    let block_warm = coord.process(&rag_request(3, 44, AttentionMode::Block)).unwrap();
    assert!(block_warm.flops_tft < full.flops_tft);
}

#[test]
fn no_reencode_and_parallel_modes_run() {
    let mut coord = coordinator();
    for (i, mode) in [AttentionMode::BlockNoReencode, AttentionMode::BlockParallel]
        .into_iter()
        .enumerate()
    {
        let r = coord.process(&rag_request(i as u64, 55, mode)).unwrap();
        assert!(!r.tokens.is_empty());
    }
}

#[test]
fn continuous_batching_serves_a_closed_set() {
    let mut coord = coordinator();
    let reqs: Vec<Request> = (0..6)
        .map(|i| rag_request(i, 100 + i, AttentionMode::Block))
        .collect();
    let out = run_batch(
        &mut coord,
        reqs,
        &BatchPolicy { max_active: 3, max_active_tokens: 2048, ..BatchPolicy::default() },
    )
    .unwrap();
    assert_eq!(out.len(), 6);
    let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..6).collect::<Vec<_>>());
    for r in &out {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 8);
        assert!(r.ttft >= 0.0);
    }
}

/// A rejected request must release every pin it acquired — planning
/// pins cached blocks before the miss prefill runs; leaking them on an
/// error exit leaves entries unevictable and makes `clear_cache` panic.
#[test]
fn failed_request_releases_all_pins() {
    let mut coord = coordinator();
    let warm = rag_request(1, 77, AttentionMode::Block);
    coord.process(&warm).expect("warm-up");
    // Same blocks (now cache hits, pinned at planning) plus one bad
    // block: the concurrent miss prefill rejects the out-of-vocab token
    // and the request errors out with the hit pins still held.
    let mut bad = warm.clone();
    bad.id = 2;
    bad.blocks.push(vec![-5]);
    assert!(coord.process(&bad).is_err(), "invalid block must be rejected");
    // All pins released: clearing the cache must not panic.
    coord.clear_cache();
}

#[test]
fn cache_budget_evicts_but_serving_still_correct() {
    // A tiny budget forces eviction churn; outputs must stay correct.
    // Sized so churn happens on *both* cache tiers: the int8 tier
    // (BLOCK_ATTN_KV_QUANT=int8 CI leg) stores blocks at ~¼ the bytes.
    let engine = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C);
    let mut coord = Coordinator::new(engine, 80_000); // ~few blocks only
    let req = rag_request(1, 66, AttentionMode::Block);
    let cold = coord.process(&req).unwrap();
    // Run unrelated requests to churn the cache.
    for i in 0..3 {
        let _ = coord.process(&rag_request(10 + i, 200 + i, AttentionMode::Block)).unwrap();
    }
    let again = coord.process(&req).unwrap();
    assert_eq!(cold.tokens, again.tokens);
    let stats = coord.cache_stats();
    assert!(stats.evictions > 0, "budget never enforced: {stats:?}");
}

/// Multi-turn sessions: turn N+1 reuses the cached KV of all sealed
/// turns, and two sessions share a common system block.
#[test]
fn sessions_reuse_turn_blocks() {
    use block_attn::coordinator::session::Session;
    let mut coord = coordinator();
    let mut s = Session::new(1).with_system("answer briefly .");
    s.max_new_tokens = 4;
    let (_, r1) = s.turn(&mut coord, "what is the key of obelisk ?").unwrap();
    assert_eq!(r1.cached_blocks, 0, "cold first turn");
    assert_eq!(s.turns(), 2);
    let (_, r2) = s.turn(&mut coord, "and its color ?").unwrap();
    // The system block and the sealed first turn both hit.
    assert_eq!(r2.total_blocks, 2);
    assert_eq!(r2.cached_blocks, 2, "history must be served from cache");

    // A second session with the same system prompt hits it immediately.
    let mut s2 = Session::new(2).with_system("answer briefly .");
    s2.max_new_tokens = 4;
    let (_, r3) = s2.turn(&mut coord, "hello ?").unwrap();
    assert_eq!(r3.cached_blocks, 1, "system block shared across sessions");
}

/// The dry-run planner pins nothing permanently.
#[test]
fn dry_plan_leaves_no_pins() {
    let mut coord = coordinator();
    let req = rag_request(1, 77, AttentionMode::Block);
    let _ = coord.process(&req).unwrap();
    let plan = coord.dry_plan(&req.blocks);
    assert_eq!(plan.cached_count(), plan.items.len());
    // If pins leaked, clear_cache would panic.
    coord.clear_cache();
}

/// The native train driver runs end to end through the coordinator: a
/// few steps on a small shape, loss finite and parameters actually move.
#[test]
fn native_train_steps_run_through_coordinator() {
    use block_attn::train::{train, DataMix, TrainConfig, TrainMode};
    use block_attn::workload::Sample;

    let engine = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C)
        .with_train_shape(2, 64);
    let before = block_attn::Backend::params_host(&engine).unwrap();
    let mut coord = Coordinator::new(engine, 16 << 20);
    let mix = DataMix::new().add(1.0, |r: &mut Rng| {
        let v = (b'a' + r.below(4) as u8) as char;
        Sample::bare(
            vec![format!("the key of door is {v} .")],
            "what is the key of door ?".into(),
            v.to_string(),
        )
    });
    let cfg = TrainConfig {
        steps: 3,
        lr: 1e-3,
        warmup: 2,
        seed: 1,
        mode: TrainMode::Dual,
        eval_every: 0,
    };
    let losses = train(&mut coord, &cfg, &mix, |_, _| {}).unwrap();
    assert_eq!(losses.len(), 3);
    assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0));
    let after = block_attn::Backend::params_host(coord.engine()).unwrap();
    let moved = before
        .iter()
        .zip(&after)
        .any(|(a, b)| a.max_abs_diff(b) > 1e-7);
    assert!(moved, "train_step left the parameters untouched");
}

/// Artifact-backed smoke of the same pipeline (`--features xla`).
#[cfg(feature = "xla")]
mod xla_artifacts {
    use super::*;
    use block_attn::config::{default_artifacts_dir, Manifest};
    use block_attn::ModelEngine;

    fn coordinator() -> Coordinator<ModelEngine> {
        let manifest = Manifest::load(default_artifacts_dir()).expect("run `make artifacts`");
        let engine = ModelEngine::new(&manifest, "tiny").expect("engine");
        Coordinator::new(engine, 64 << 20)
    }

    #[test]
    fn cache_hits_do_not_change_output_on_artifacts() {
        let mut coord = coordinator();
        let req = rag_request(1, 11, AttentionMode::Block);
        let cold = coord.process(&req).expect("cold");
        let warm = coord.process(&req).expect("warm");
        assert_eq!(cold.tokens, warm.tokens, "cache changed the output");
        assert_eq!(warm.cached_blocks, warm.total_blocks);
    }

    #[test]
    fn batching_serves_on_artifacts() {
        let mut coord = coordinator();
        let reqs: Vec<Request> = (0..4)
            .map(|i| rag_request(i, 100 + i, AttentionMode::Block))
            .collect();
        let out = run_batch(
            &mut coord,
            reqs,
            &BatchPolicy { max_active: 2, max_active_tokens: 2048, ..BatchPolicy::default() },
        )
        .unwrap();
        assert_eq!(out.len(), 4);
    }
}
