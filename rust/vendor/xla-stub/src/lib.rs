//! Compile-time stub of the `xla` (xla-rs) PJRT bindings.
//!
//! Mirrors the exact API surface `block_attn::runtime::engine` uses so
//! the `xla` cargo feature type-checks without an XLA installation.
//! Every operation fails at runtime with a clear message; see
//! `README.md` for how to substitute a real xla-rs checkout.

use std::fmt;

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Error carrying the stub notice (implements `std::error::Error`, so
/// `?` converts it into `anyhow::Error` exactly like the real crate's
/// error type would).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub<T>(op: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: '{op}' is unavailable — this binary was built against \
         rust/vendor/xla-stub; link a real xla-rs checkout to execute AOT \
         artifacts (see rust/vendor/xla-stub/README.md)"
    )))
}

/// Marker for element types transferable to/from device buffers.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub("PjRtClient::buffer_from_host_buffer")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute_b")
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }
}

pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
