//! Continuous-batching determinism: batching decode is a **pure
//! performance decision**, never an accuracy one.
//!
//! Two layers of the contract are pinned here, both across the kernel
//! thread-budget sweep and across KV tiers:
//!
//! * [`Backend::decode_batch`] — one batched round over N in-flight
//!   [`DecodeCtx`] sessions must be bitwise identical (tokens *and*
//!   dense KV) to stepping each session serially through
//!   [`Backend::decode_ctx`], including mixed-tier batches where f32,
//!   int8 and int4 sessions share one dispatch.
//! * `run_batch` — the continuous-batching scheduler over a request
//!   stream with cache hits and multi-block prompts must emit exactly
//!   the tokens of a serial `Coordinator::process` loop.
//!
//! The unit-level contract (single thread count) is pinned next to the
//! fused implementation in `runtime::native`; this file owns the
//! end-to-end sweep.

use block_attn::config::KvPrecision;
use block_attn::coordinator::batcher::{run_batch, BatchPolicy};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::kernels::set_threads;
use block_attn::runtime::{DecodeCtx, NativeBackend};
use block_attn::tensor::{argmax, TensorF};
use block_attn::util::rng::Rng;
use block_attn::{Backend, ModelConfig};
use std::sync::Mutex;

/// The budget sweep: serial, an odd non-divisible width, and a wide
/// power of two (mirrors `tests/threads_determinism.rs`).
const THREAD_SWEEP: [usize; 3] = [1, 3, 8];

/// Decode rounds per comparison — enough to cross the sessions' first
/// tail rows and make any drift compound visibly.
const STEPS: usize = 10;

/// Every test here flips the process-global thread budget; serialize so
/// concurrent tests cannot mask a thread-count dependence.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab: 24,
        d_model: 16,
        layers: 2,
        heads: 2,
        kv_heads: 1,
        head_dim: 8,
        d_ff: 32,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        max_len: 256,
    }
}

/// Four prompts of different lengths, so the batch always holds
/// sessions at different context sizes (the ragged case the fused GEMM
/// rows must keep independent).
fn session_prompts() -> Vec<Vec<i32>> {
    let mut rng = Rng::new(0xABE);
    (0..4)
        .map(|i| (0..(4 + i * 3)).map(|_| rng.below(24) as i32).collect())
        .collect()
}

/// Full-prefill each prompt into a [`DecodeCtx`] at its tier; return
/// the contexts plus each session's first greedy token.
fn build_sessions(engine: &NativeBackend, tiers: &[KvPrecision; 4]) -> (Vec<DecodeCtx>, Vec<i32>) {
    let cap = engine.decode_ctx_capacity().expect("decode capacity");
    let mut ctxs = Vec::new();
    let mut first = Vec::new();
    for (toks, &prec) in session_prompts().iter().zip(tiers) {
        let pre = engine.prefill_full(toks).expect("prefill");
        first.push(argmax(&pre.last_logits) as i32);
        ctxs.push(DecodeCtx::new(pre.k, pre.v, prec, cap).expect("ctx"));
    }
    (ctxs, first)
}

type SessionOut = (Vec<Vec<i32>>, Vec<(TensorF, TensorF)>);

/// The reference: each session stepped one at a time through
/// `decode_ctx` at a single kernel thread.
fn serial_reference(engine: &NativeBackend, tiers: &[KvPrecision; 4]) -> SessionOut {
    set_threads(1);
    let cap = engine.decode_ctx_capacity().expect("decode capacity");
    let (mut ctxs, mut last) = build_sessions(engine, tiers);
    let mut tokens = vec![Vec::new(); ctxs.len()];
    for _ in 0..STEPS {
        for (i, ctx) in ctxs.iter_mut().enumerate() {
            let logits = engine.decode_ctx(last[i], ctx).expect("decode_ctx");
            last[i] = argmax(&logits) as i32;
            tokens[i].push(last[i]);
        }
    }
    let kv = ctxs
        .iter()
        .map(|c| c.to_dense(cap).expect("to_dense"))
        .collect();
    (tokens, kv)
}

/// The candidate: all sessions advanced per round through one
/// `decode_batch` dispatch at the given thread budget.
fn batched_run(engine: &NativeBackend, tiers: &[KvPrecision; 4], threads: usize) -> SessionOut {
    set_threads(threads);
    let cap = engine.decode_ctx_capacity().expect("decode capacity");
    let (mut ctxs, mut last) = build_sessions(engine, tiers);
    let mut tokens = vec![Vec::new(); ctxs.len()];
    for _ in 0..STEPS {
        let mut refs: Vec<&mut DecodeCtx> = ctxs.iter_mut().collect();
        let next = engine.decode_batch(&mut refs, &last).expect("decode_batch");
        for (i, &t) in next.iter().enumerate() {
            last[i] = t;
            tokens[i].push(t);
        }
    }
    let kv = ctxs
        .iter()
        .map(|c| c.to_dense(cap).expect("to_dense"))
        .collect();
    (tokens, kv)
}

/// Pin bitwise equality — tokens and dense KV — between the serial
/// reference and the batched run at every budget in the sweep.
fn assert_batched_matches_serial(tiers: &[KvPrecision; 4]) {
    let engine = NativeBackend::new(micro_config(), 0xD15C);
    let (want_tokens, want_kv) = serial_reference(&engine, tiers);
    assert!(want_tokens.iter().all(|t| t.len() == STEPS));
    for &threads in &THREAD_SWEEP {
        let (tokens, kv) = batched_run(&engine, tiers, threads);
        assert_eq!(
            want_tokens, tokens,
            "{tiers:?}: batched tokens differ from serial at {threads} threads"
        );
        for (i, ((ks, vs), (kb, vb))) in want_kv.iter().zip(&kv).enumerate() {
            assert_eq!(ks, kb, "session {i}: batched K differs from serial at {threads} threads");
            assert_eq!(vs, vb, "session {i}: batched V differs from serial at {threads} threads");
        }
    }
}

#[test]
fn decode_batch_bitwise_identical_f32() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    assert_batched_matches_serial(&[KvPrecision::F32; 4]);
    set_threads(prev);
}

#[test]
fn decode_batch_bitwise_identical_int8() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    assert_batched_matches_serial(&[KvPrecision::Int8; 4]);
    set_threads(prev);
}

#[test]
fn decode_batch_bitwise_identical_int4() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    assert_batched_matches_serial(&[KvPrecision::Int4; 4]);
    set_threads(prev);
}

/// A single batch mixing all three tiers: the per-session attention
/// reads different storage formats, but the shared GEMM rows and the
/// per-session kernels must still reproduce the serial stream exactly.
#[test]
fn decode_batch_bitwise_identical_mixed_tiers() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    assert_batched_matches_serial(&[
        KvPrecision::F32,
        KvPrecision::Int8,
        KvPrecision::Int4,
        KvPrecision::Int8,
    ]);
    set_threads(prev);
}

/// A request stream with shared blocks (cache hits on later requests),
/// fresh blocks (concurrent misses) and mixed attention modes — the
/// shapes the scheduler actually serves.
fn request_stream() -> Vec<Request> {
    let mut rng = Rng::new(41);
    let mut block = |len: usize| -> Vec<i32> {
        (0..len).map(|_| rng.below(24) as i32).collect()
    };
    let shared = block(10);
    let mut reqs = Vec::new();
    for (i, mode) in [
        AttentionMode::Block,
        AttentionMode::Full,
        AttentionMode::Block,
        AttentionMode::BlockNoReencode,
        AttentionMode::Block,
    ]
    .into_iter()
    .enumerate()
    {
        let blocks = match i {
            0 => vec![shared.clone(), block(6)],
            1 => vec![block(9)],
            _ => vec![shared.clone(), block(5), block(7)],
        };
        reqs.push(Request {
            id: i as u64,
            blocks,
            query: block(8),
            max_new_tokens: 6,
            mode,
        });
    }
    reqs
}

fn serve_stream_batched(
    threads: usize,
    precision: KvPrecision,
    policy: &BatchPolicy,
) -> Vec<(u64, Vec<i32>)> {
    set_threads(threads);
    let engine = NativeBackend::new(micro_config(), 0xD15C);
    let mut coord = Coordinator::with_kv_precision(engine, 64 << 20, precision);
    let mut out: Vec<(u64, Vec<i32>)> = run_batch(&mut coord, request_stream(), policy)
        .expect("run_batch")
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

/// The scheduler path end to end: `run_batch` (FIFO admission, one
/// prefill per round, batched decode) must emit exactly the tokens of
/// a serial `process` loop over the same stream — per tier, at every
/// thread budget.
#[test]
fn run_batch_matches_serial_process_across_threads_and_tiers() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    for precision in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
        set_threads(1);
        let engine = NativeBackend::new(micro_config(), 0xD15C);
        let mut coord = Coordinator::with_kv_precision(engine, 64 << 20, precision);
        let want: Vec<(u64, Vec<i32>)> = request_stream()
            .iter()
            .map(|r| (r.id, coord.process(r).expect("process").tokens))
            .collect();
        assert!(want.iter().all(|(_, tokens)| !tokens.is_empty()));
        let policy = BatchPolicy {
            max_active: 3,
            max_active_tokens: 4096,
            ..BatchPolicy::default()
        };
        for &threads in &THREAD_SWEEP {
            let got = serve_stream_batched(threads, precision, &policy);
            assert_eq!(
                want, got,
                "{precision:?}: batched serving differs from serial at {threads} threads"
            );
        }
    }
    set_threads(prev);
}
