//! Substrate utilities built in-tree because the build environment is
//! offline (only the `xla` crate closure is available): JSON, CLI parsing,
//! PRNG, statistics, a thread pool, property-test helpers and timing.

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
