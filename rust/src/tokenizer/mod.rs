//! Tokenization substrate.
//!
//! Two tokenizers:
//! * [`ByteTokenizer`] — the production path for the trained models:
//!   raw bytes (ids 0..255) plus special tokens. Deterministic, lossless,
//!   matches `python/compile/configs.py` (PAD/BOS/EOS/SEP/QRY).
//! * [`BpeTokenizer`] — a trained byte-pair-encoding substrate used by
//!   the workload generators to model realistic passage token lengths
//!   for the `bench` config (vocab 32000). Implemented from scratch
//!   (merge-rule training + greedy encoding) since no external tokenizer
//!   crate is available offline.

pub mod bpe;

/// Special token ids shared with the python side.
pub const PAD: i32 = 256;
pub const BOS: i32 = 257;
pub const EOS: i32 = 258;
/// Block separator (between passages).
pub const SEP: i32 = 259;
/// Query marker (starts the final block).
pub const QRY: i32 = 260;

/// Vocabulary size of the byte-level models.
pub const BYTE_VOCAB: usize = 261;

/// Byte-level tokenizer with special tokens.
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    pub fn vocab(&self) -> usize {
        BYTE_VOCAB
    }

    /// Encode raw text (no specials added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    /// Decode ids; specials are rendered as readable markers, bytes are
    /// recovered losslessly.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len());
        for &id in ids {
            match id {
                0..=255 => bytes.push(id as u8),
                PAD => {}
                BOS => bytes.extend_from_slice(b"<s>"),
                EOS => bytes.extend_from_slice(b"</s>"),
                SEP => bytes.extend_from_slice(b"<sep>"),
                QRY => bytes.extend_from_slice(b"<qry>"),
                _ => bytes.extend_from_slice(b"<?>"),
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Decode stopping at the first EOS (generation post-processing).
    pub fn decode_until_eos(&self, ids: &[i32]) -> String {
        let end = ids.iter().position(|&t| t == EOS).unwrap_or(ids.len());
        self.decode(&ids[..end])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer::new();
        let s = "Hello, Block-Attention! 123";
        let ids = t.encode(s);
        assert!(ids.iter().all(|&i| (0..256).contains(&i)));
        assert_eq!(t.decode(&ids), s);
    }

    #[test]
    fn specials_render() {
        let t = ByteTokenizer::new();
        let ids = vec![BOS, b'h' as i32, b'i' as i32, EOS];
        assert_eq!(t.decode(&ids), "<s>hi</s>");
        assert_eq!(t.decode_until_eos(&[b'o' as i32, b'k' as i32, EOS, b'x' as i32]), "ok");
    }

    #[test]
    fn pad_is_silent() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[b'a' as i32, PAD, b'b' as i32]), "ab");
    }
}
