//! Block fine-tuning driver (paper §2.4) — Rust drives the AOT
//! `train_step` artifact; python is only the compiler.
//!
//! The paper's recipe: fine-tune with the Figure-1 segment mask so
//! training matches block-mode inference, and train every sample in
//! *both* attention modes so the model can switch seamlessly
//! ([`TrainMode::Dual`] alternates the segment ids batch-by-batch).

pub mod data;
pub mod eval;
pub mod presets;

use crate::coordinator::Coordinator;
use crate::runtime::Backend;
use crate::tokenizer::ByteTokenizer;
use crate::util::rng::Rng;
use crate::workload::Sample;
use anyhow::Result;
use data::pack_batch;

/// Attention-mode schedule during fine-tuning.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainMode {
    /// Plain causal attention only (the full-attention baselines).
    Full,
    /// Alternate full-attention and block-attention batches (the paper's
    /// dual-mode block fine-tune: every sample is seen both ways).
    Dual,
}

/// Training hyper-parameters (paper §3.4 scaled to the tiny model).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub seed: u64,
    pub mode: TrainMode,
    /// Evaluate every `eval_every` steps (0 = never); the callback gets
    /// `(coordinator, step)` — used to trace Figure 4.
    pub eval_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            lr: 1e-3,
            warmup: 20,
            seed: 0x7A41,
            mode: TrainMode::Full,
            eval_every: 0,
        }
    }
}

/// A weighted mixture of sample generators.
pub struct DataMix {
    gens: Vec<(Box<dyn Fn(&mut Rng) -> Sample>, f64)>,
}

impl DataMix {
    pub fn new() -> DataMix {
        DataMix { gens: Vec::new() }
    }

    pub fn add(mut self, weight: f64, g: impl Fn(&mut Rng) -> Sample + 'static) -> Self {
        self.gens.push((Box::new(g), weight));
        self
    }

    pub fn sample(&self, rng: &mut Rng) -> Sample {
        let total: f64 = self.gens.iter().map(|(_, w)| w).sum();
        let mut x = rng.f64() * total;
        for (g, w) in &self.gens {
            if x < *w {
                return g(rng);
            }
            x -= w;
        }
        (self.gens.last().unwrap().0)(rng)
    }

    pub fn is_empty(&self) -> bool {
        self.gens.is_empty()
    }
}

impl Default for DataMix {
    fn default() -> Self {
        Self::new()
    }
}

/// Linear warmup then constant (the paper uses 20 warmup steps).
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f32 {
    if step < cfg.warmup {
        (cfg.lr * (step + 1) as f64 / cfg.warmup as f64) as f32
    } else {
        cfg.lr as f32
    }
}

/// Run fine-tuning on the coordinator's engine. Returns per-step losses.
///
/// `on_eval` fires every `eval_every` steps *and* after the final step;
/// the KV cache is cleared first (cached states are stale once the
/// parameters move).
pub fn train<B: Backend>(
    coord: &mut Coordinator<B>,
    cfg: &TrainConfig,
    mix: &DataMix,
    mut on_eval: impl FnMut(&mut Coordinator<B>, usize),
) -> Result<Vec<f32>> {
    let tok = ByteTokenizer::new();
    let (b, l) = coord.engine().train_shape()?;
    let mut rng = Rng::new(cfg.seed);
    let mut losses = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        // Dual mode alternates the mask; sample data independently.
        let block_mask = match cfg.mode {
            TrainMode::Full => false,
            TrainMode::Dual => step % 2 == 1,
        };
        let samples: Vec<Sample> = (0..b).map(|_| mix.sample(&mut rng)).collect();
        let (tokens, seg, mask) = pack_batch(&tok, &samples, l, block_mask);
        let out = coord
            .engine()
            .train_step(step, lr_at(cfg, step), &tokens, &seg, &mask)?;
        losses.push(out.loss);
        if (step + 1) % 50 == 0 {
            let recent =
                &losses[losses.len().saturating_sub(50)..];
            let mean: f32 = recent.iter().sum::<f32>() / recent.len() as f32;
            eprintln!("[train] step {}/{}: loss(50-avg) {mean:.3}", step + 1, cfg.steps);
        }
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            coord.clear_cache();
            on_eval(coord, step + 1);
        }
    }
    coord.clear_cache();
    if cfg.eval_every == 0 || cfg.steps % cfg.eval_every != 0 {
        on_eval(coord, cfg.steps);
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_warms_up() {
        let cfg = TrainConfig { lr: 1.0, warmup: 10, ..Default::default() };
        assert!((lr_at(&cfg, 0) - 0.1).abs() < 1e-6);
        assert!((lr_at(&cfg, 9) - 1.0).abs() < 1e-6);
        assert!((lr_at(&cfg, 100) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mix_weights_respected() {
        let mix = DataMix::new()
            .add(9.0, |_r| Sample::bare(vec![], "a".into(), "".into()))
            .add(1.0, |_r| Sample::bare(vec![], "b".into(), "".into()));
        let mut rng = Rng::new(5);
        let mut a = 0;
        for _ in 0..1000 {
            if mix.sample(&mut rng).query == "a" {
                a += 1;
            }
        }
        assert!((850..=950).contains(&a), "{a}");
    }
}
