//! Minimal JSON parser / writer (serde replacement for the offline build).
//!
//! Handles the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for the artifact manifest, server
//! protocol, experiment reports and the Game-AI gamecore states.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in sorted order (BTreeMap) so that
/// serialization is deterministic — important for content-hashing gamecore
/// blocks.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

// Hand-rolled (the offline build has no `thiserror`); the impl is what
// lets `?` convert a JsonError through anyhow's blanket `From`.
impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj[key]`, or `Json::Null` if missing / not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Required-field helpers used by manifest/config loading.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

impl fmt::Display for Json {
    /// Compact, deterministic serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.pos + 1) == Some(&b'\\')
                                    && self.b.get(self.pos + 2) == Some(&b'u')
                                    && self.pos + 6 < self.b.len()
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.pos + 3..self.pos + 7],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\ny"],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_i64(), Some(1));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d").as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\cAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cAé"));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn deterministic_serialization() {
        // Key order is sorted regardless of insertion order.
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap().to_string();
        let b = Json::parse(r#"{"a":2,"z":1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn nested_deep() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo wörld 中文\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld 中文"));
    }
}
