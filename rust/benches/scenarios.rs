//! Scenario-family serving bench: the three block-reuse workloads the
//! auto-segmentation tentpole opens end-to-end.
//!
//! ```sh
//! cargo bench --bench scenarios                   # full shapes
//! cargo bench --bench scenarios -- --sessions 16 --waves 2
//! ```
//!
//! * **gamecore** (paper Appendix A): `--sessions` concurrent poker
//!   tables, every frame arriving as a raw `state` wire request that
//!   the server cuts into per-field blocks. All tables share the rules
//!   / blinds / seats blocks; between a table's consecutive frames only
//!   the actor's chips, the pot and one history entry change — so
//!   steady-state frames must re-serve ≥ 90% of their blocks from
//!   cache (the bench fails otherwise).
//! * **chat**: multi-turn [`Session`]s over one shared system prompt;
//!   every history block is sealed and precomputed when its turn
//!   completes, so warm turns must hit ≥ 99% of their blocks.
//! * **icl**: a frozen [`SharedIcl`] exemplar set served as raw `demos`
//!   requests; after the first query the demo blocks must hit ≥ 90%.
//!
//! Results go to `BENCH_scenarios.json` (`--json-out` overrides); the
//! three `ttft_p50_ms` keys are gated by `bench_guard` in CI, the hit
//! rates are self-gated by the `ensure!`s here.

use anyhow::ensure;
use block_attn::config::SegmentPolicy;
use block_attn::coordinator::batcher::{run_batch, BatchPolicy};
use block_attn::coordinator::session::Session;
use block_attn::coordinator::{Coordinator, Request, Response};
use block_attn::runtime::backend_from_args;
use block_attn::server::parse_request_with_policy;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use block_attn::util::rng::Rng;
use block_attn::util::stats::Summary;
use block_attn::workload::gamecore::GamecoreSim;
use block_attn::workload::general::{GeneralTask, SharedIcl};
use block_attn::Backend;
use std::time::Instant;

struct HitMeter {
    cached: usize,
    total: usize,
    ttft: Summary,
}

impl HitMeter {
    fn new() -> HitMeter {
        HitMeter { cached: 0, total: 0, ttft: Summary::new() }
    }
    fn add(&mut self, r: &Response) {
        self.cached += r.cached_blocks;
        self.total += r.total_blocks;
        self.ttft.add(r.ttft * 1e3);
    }
    fn rate(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.cached as f64 / self.total as f64
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let threads = block_attn::kernels::init_threads_from_args(&args);
    let sessions = args.usize_or("sessions", 200);
    let waves = args.usize_or("waves", 4);
    let players = args.usize_or("players", 10);
    let chat_sessions = args.usize_or("chat-sessions", 12);
    let chat_turns = args.usize_or("chat-turns", 4);
    let icl_queries = args.usize_or("icl-queries", 32);
    let max_new = args.usize_or("max-new-tokens", 8);
    let seed = args.u64_or("seed", 42);

    let engine = backend_from_args(&args, "tiny")?;
    engine.warmup()?;
    let model = engine.config().name.clone();
    let kv_precision = block_attn::config::KvPrecision::resolve(&args)?;
    let mut coord = Coordinator::with_kv_precision(engine, 512 << 20, kv_precision);
    coord.set_segment_policy(SegmentPolicy::Auto);
    let tok = ByteTokenizer::new();
    println!(
        "# scenario serving — config '{model}', {kv_precision:?} KV, \
         {sessions} gamecore tables x {waves} waves, {chat_sessions} chats x {chat_turns} turns, \
         {icl_queries} icl queries"
    );

    // ---- gamecore: hundreds of tables sharing the rules block ----
    let mut sims: Vec<GamecoreSim> = (0..sessions)
        .map(|i| GamecoreSim::new(players, seed.wrapping_add(1000 + i as u64)))
        .collect();
    for sim in &mut sims {
        // Fill the rolling history so steady-state frames have the full
        // block shape before anything is measured.
        for _ in 0..13 {
            sim.step();
        }
    }
    let build = |sims: &[GamecoreSim], tok: &ByteTokenizer| -> anyhow::Result<Vec<Request>> {
        sims.iter()
            .enumerate()
            .map(|(i, s)| {
                parse_request_with_policy(
                    &s.request_line(i as u64, max_new),
                    tok,
                    SegmentPolicy::Auto,
                )
            })
            .collect()
    };

    // Cold wave, served serially: the first table computes every block;
    // each later table must re-serve the fleet-shared rules / blinds /
    // seats blocks (12 of its 33) from the first table's cache entries.
    let mut cold = HitMeter::new();
    for (i, req) in build(&sims, &tok)?.iter().enumerate() {
        let r = coord.process(req)?;
        if i > 0 {
            cold.add(&r);
        }
    }
    ensure!(
        cold.rate() >= 0.3,
        "cross-session block sharing broke: cold tables hit only {:.1}% (want >= 30%)",
        cold.rate() * 100.0
    );

    // Steady waves, batched: every table advances one action, only the
    // delta blocks miss.
    let policy = BatchPolicy {
        max_active: 8,
        max_active_tokens: 1 << 20,
        ..BatchPolicy::default()
    };
    let mut steady = HitMeter::new();
    let t0 = Instant::now();
    for _ in 0..waves {
        for sim in &mut sims {
            sim.step();
        }
        let out = run_batch(&mut coord, build(&sims, &tok)?, &policy)?;
        for r in &out {
            steady.add(r);
        }
    }
    let game_wall = t0.elapsed().as_secs_f64();
    ensure!(
        steady.rate() >= 0.90,
        "gamecore steady-state hit rate {:.2}% is below the 90% acceptance bar",
        steady.rate() * 100.0
    );
    println!(
        "gamecore: cold-share {:.1}%  steady hit {:.2}%  ttft p50 {:.2} ms  ({:.2}s)",
        cold.rate() * 100.0,
        steady.rate() * 100.0,
        steady.ttft.p50(),
        game_wall
    );

    // ---- chat: warm turns over sealed history blocks ----
    let mut warm = HitMeter::new();
    let t0 = Instant::now();
    for c in 0..chat_sessions {
        let mut session =
            Session::new(5000 + c as u64).with_system("shared system prompt: be brief");
        session.max_new_tokens = max_new;
        for t in 0..chat_turns {
            let user = format!("turn {t}: please continue topic {c}");
            let (_reply, resp) = session.turn(&mut coord, &user)?;
            if t > 0 {
                warm.add(&resp);
            }
        }
    }
    let chat_wall = t0.elapsed().as_secs_f64();
    ensure!(
        warm.rate() >= 0.99,
        "chat warm-turn hit rate {:.2}% is below the 99% bar (history re-prefilled?)",
        warm.rate() * 100.0
    );
    println!(
        "chat: warm-turn hit {:.2}%  ttft p50 {:.2} ms  ({:.2}s)",
        warm.rate() * 100.0,
        warm.ttft.p50(),
        chat_wall
    );

    // ---- icl: frozen few-shot exemplars as raw `demos` requests ----
    let mut rng = Rng::new(seed);
    let shared = SharedIcl::new(GeneralTask::IclMap { shots: 6 }, &mut rng, 40);
    let mut icl = HitMeter::new();
    let t0 = Instant::now();
    for q in 0..icl_queries {
        let s = shared.sample(&mut rng);
        let line = Json::obj(vec![
            ("id", Json::num(9000.0 + q as f64)),
            (
                "demos",
                Json::Arr(s.blocks.iter().map(|d| Json::str(d.clone())).collect()),
            ),
            ("query", Json::str(s.query.clone())),
            ("max_new_tokens", Json::num(max_new as f64)),
        ])
        .to_string();
        let req = parse_request_with_policy(&line, &tok, SegmentPolicy::Auto)?;
        let resp = coord.process(&req)?;
        if q > 0 {
            icl.add(&resp);
        }
    }
    let icl_wall = t0.elapsed().as_secs_f64();
    ensure!(
        icl.rate() >= 0.90,
        "icl warm hit rate {:.2}% is below the 90% bar (demo blocks not reused?)",
        icl.rate() * 100.0
    );
    println!(
        "icl: warm hit {:.2}%  ttft p50 {:.2} ms  ({:.2}s)",
        icl.rate() * 100.0,
        icl.ttft.p50(),
        icl_wall
    );

    let report = Json::obj(vec![
        ("bench", Json::str("scenarios")),
        ("model", Json::str(model)),
        ("backend", Json::str(block_attn::runtime::backend_choice(&args))),
        ("kv_precision", Json::str(kv_precision.as_str())),
        ("threads", Json::num(threads as f64)),
        ("max_new_tokens", Json::num(max_new as f64)),
        (
            "gamecore",
            Json::obj(vec![
                ("sessions", Json::num(sessions as f64)),
                ("players", Json::num(players as f64)),
                ("waves", Json::num(waves as f64)),
                ("cold_share_hit_rate", Json::num(cold.rate())),
                ("steady_hit_rate", Json::num(steady.rate())),
                ("ttft_p50_ms", Json::num(steady.ttft.p50())),
            ]),
        ),
        (
            "chat",
            Json::obj(vec![
                ("sessions", Json::num(chat_sessions as f64)),
                ("turns", Json::num(chat_turns as f64)),
                ("warm_hit_rate", Json::num(warm.rate())),
                ("ttft_p50_ms", Json::num(warm.ttft.p50())),
            ]),
        ),
        (
            "icl",
            Json::obj(vec![
                ("queries", Json::num(icl_queries as f64)),
                ("warm_hit_rate", Json::num(icl.rate())),
                ("ttft_p50_ms", Json::num(icl.ttft.p50())),
            ]),
        ),
    ]);
    let out_path = args.str_or("json-out", "BENCH_scenarios.json");
    std::fs::write(&out_path, format!("{report}\n"))?;
    eprintln!("# wrote {out_path}");
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}
