"""AOT pipeline: lower every (config, entry-point, bucket) to HLO text.

Run once at build time (``make artifacts``); the Rust runtime loads the
results through ``artifacts/manifest.json`` and python is never touched
again. HLO *text* (not a serialized ``HloModuleProto``) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the runtime's xla_extension 0.5.1 rejects, while the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS
from .kernels import rope as rope_kernel

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(fn, args):
    # keep_unused: the Rust runtime always feeds the full parameter list,
    # so arguments must not be pruned (e.g. prefill_block never touches
    # final_norm but still receives it).
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_spec_list(cfg):
    return [spec(shape) for _, shape in model.param_specs(cfg)]


def entries_for(cfg):
    """Yield (name, kind, sizes, fn, arg_specs) for every artifact of a
    config."""
    N, K, hd = cfg.layers, cfg.kv_heads, cfg.head_dim
    ps = param_spec_list(cfg)

    for L in cfg.full_lengths:
        yield (
            f"{cfg.name}_prefill_full_L{L}",
            "prefill_full",
            {"L": L},
            model.bind(cfg, "prefill_full"),
            [spec((L,), I32), spec((), I32), *ps],
        )
    for Lb in cfg.block_lengths:
        yield (
            f"{cfg.name}_prefill_block_L{Lb}",
            "prefill_block",
            {"L": Lb},
            model.bind(cfg, "prefill_block"),
            [spec((Lb,), I32), spec((), I32), *ps],
        )
    for C in cfg.final_ctx:
        Lq = cfg.final_q
        yield (
            f"{cfg.name}_prefill_final_C{C}_Q{Lq}",
            "prefill_final",
            {"C": C, "Lq": Lq},
            model.bind(cfg, "prefill_final"),
            [
                spec((Lq,), I32),
                spec((), I32),
                spec((N, C, K, hd)),
                spec((N, C, K, hd)),
                spec((), I32),
                spec((), I32),
                *ps,
            ],
        )
    for C in cfg.decode_ctx:
        yield (
            f"{cfg.name}_decode_C{C}",
            "decode_step",
            {"C": C},
            model.bind(cfg, "decode_step"),
            [
                spec((), I32),
                spec((), I32),
                spec((N, C, K, hd)),
                spec((N, C, K, hd)),
                *ps,
            ],
        )
    # RoPE re-encode artifact: parity check target for the native Rust
    # implementation (one bucket suffices).
    if cfg.block_lengths:
        Lb = cfg.block_lengths[0]
        yield (
            f"{cfg.name}_reencode_L{Lb}",
            "reencode_k",
            {"L": Lb},
            lambda k, delta, _cfg=cfg: (
                rope_kernel.reencode_k(k, delta, theta=_cfg.rope_theta),
            ),
            [spec((N, Lb, K, hd)), spec((1,), I32)],
        )
    if cfg.train_batch:
        B, L = cfg.train_batch, cfg.train_len
        yield (
            f"{cfg.name}_train_B{B}_L{L}",
            "train_step",
            {"B": B, "L": L},
            model.bind(cfg, "train_step"),
            [
                spec((), I32),
                spec((), F32),
                spec((B, L), I32),
                spec((B, L), I32),
                spec((B, L), F32),
                *ps,
                *ps,
                *ps,
            ],
        )


def write_init(cfg, out_dir, seed=1234):
    """Write deterministic initial parameters as flat little-endian f32."""
    import numpy as np

    arrays = model.init_params(cfg, seed)
    path = os.path.join(out_dir, f"{cfg.name}_init.bin")
    flat = np.concatenate([a.ravel() for a in arrays]).astype("<f4")
    flat.tofile(path)
    return f"{cfg.name}_init.bin"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,bench")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "configs": {}}
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        entry_list = []
        for ename, kind, sizes, fn, arg_specs in entries_for(cfg):
            fname = f"{ename}.hlo.txt"
            fpath = os.path.join(out_dir, fname)
            if args.force or not os.path.exists(fpath):
                print(f"[aot] lowering {ename} ...", flush=True)
                text = to_hlo_text(fn, arg_specs)
                with open(fpath, "w") as f:
                    f.write(text)
                print(f"[aot]   wrote {fname} ({len(text)/1e3:.0f} kB)", flush=True)
            entry_list.append({"name": ename, "kind": kind, "file": fname, "sizes": sizes})
        init_file = write_init(cfg, out_dir)
        manifest["configs"][name] = {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps,
            "max_len": cfg.max_len,
            "attn_impl": cfg.attn_impl,
            "init_file": init_file,
            "params": [
                {"name": n, "shape": list(s)} for n, s in model.param_specs(cfg)
            ],
            "entries": entry_list,
        }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written with {sum(len(c['entries']) for c in manifest['configs'].values())} entries")


if __name__ == "__main__":
    main()
