//! Fused row-wise kernels: RMSNorm, softmax, SwiGLU, and the dot/axpy
//! primitives the attention inner loops are built from.
//!
//! All reductions run in a fixed ascending order so that identical
//! inputs produce bitwise-identical outputs at every call site — the
//! property the block-serving equivalence and the `--threads N` parity
//! tests are built on.

/// Ascending-index dot product (single f32 accumulator).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// `y += alpha * x`, elementwise.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// Ascending-index dot product against an int8 row with per-channel
/// scales: `Σ a[c] · (q[c]·scale[c])` — the QKᵀ inner loop of the
/// fused-dequant attention path. Dequantization is per-element and
/// order-free, so the reduction order (single f32 accumulator,
/// ascending index) matches [`dot`] exactly.
#[inline]
pub fn dot_i8(a: &[f32], q: &[i8], scale: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    debug_assert_eq!(a.len(), scale.len());
    let mut s = 0.0f32;
    for ((&av, &qv), &sv) in a.iter().zip(q).zip(scale) {
        s += av * (qv as f32 * sv);
    }
    s
}

/// `y += alpha · (q·scale)`, elementwise (the AV inner loop of the
/// fused-dequant attention path; per-channel scales).
#[inline]
pub fn axpy_i8(alpha: f32, q: &[i8], scale: &[f32], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    debug_assert_eq!(q.len(), scale.len());
    for ((&qv, &sv), yi) in q.iter().zip(scale).zip(y.iter_mut()) {
        *yi += alpha * (qv as f32 * sv);
    }
}

/// Ascending-index dot product against a packed-int4 row (two codes per
/// byte, channel-axis packing) with per-channel scales — the QKᵀ inner
/// loop of the int4 decode-attention path. Each byte contributes its
/// even channel then its odd channel, so the accumulation order is the
/// plain ascending channel order of [`dot`]: the fused unpack+dequant
/// is bitwise invisible.
#[inline]
pub fn dot_i4(a: &[f32], packed: &[u8], scale: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), packed.len() * 2);
    debug_assert_eq!(a.len(), scale.len());
    let mut s = 0.0f32;
    for (i, &b) in packed.iter().enumerate() {
        let c = 2 * i;
        s += a[c] * (super::quant::nibble_lo(b) as f32 * scale[c]);
        s += a[c + 1] * (super::quant::nibble_hi(b) as f32 * scale[c + 1]);
    }
    s
}

/// `y += alpha · (q·scale)` over a packed-int4 row (the AV inner loop
/// of the int4 decode-attention path; per-channel scales, ascending
/// channel order as in [`axpy`]).
#[inline]
pub fn axpy_i4(alpha: f32, packed: &[u8], scale: &[f32], y: &mut [f32]) {
    debug_assert_eq!(y.len(), packed.len() * 2);
    debug_assert_eq!(y.len(), scale.len());
    for (i, &b) in packed.iter().enumerate() {
        let c = 2 * i;
        y[c] += alpha * (super::quant::nibble_lo(b) as f32 * scale[c]);
        y[c + 1] += alpha * (super::quant::nibble_hi(b) as f32 * scale[c + 1]);
    }
}

/// Row-wise RMSNorm: `out[t] = x[t] * rstd[t] * w`; returns the
/// reciprocal RMS per row (needed by the backward pass).
pub fn rms_norm_rows(
    x: &[f32],
    w: &[f32],
    eps: f64,
    l: usize,
    d: usize,
    out: &mut [f32],
    rstd: &mut [f32],
) {
    debug_assert_eq!(x.len(), l * d);
    debug_assert_eq!(w.len(), d);
    debug_assert_eq!(out.len(), l * d);
    debug_assert_eq!(rstd.len(), l);
    for t in 0..l {
        let xr = &x[t * d..(t + 1) * d];
        let mut ms = 0.0f64;
        for &v in xr {
            ms += (v as f64) * (v as f64);
        }
        let r = (1.0 / (ms / d as f64 + eps).sqrt()) as f32;
        rstd[t] = r;
        let orow = &mut out[t * d..(t + 1) * d];
        for ((o, &xv), &wv) in orow.iter_mut().zip(xr).zip(w) {
            *o = xv * r * wv;
        }
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// Fused SwiGLU gate: `g[i] = silu(g[i]) * u[i]` in place.
pub fn swiglu_rows(g: &mut [f32], u: &[f32]) {
    debug_assert_eq!(g.len(), u.len());
    for (gv, &uv) in g.iter_mut().zip(u) {
        *gv = silu(*gv) * uv;
    }
}

/// In-place softmax over `s` (max-subtracted, ascending accumulation so
/// identical inputs give bitwise-identical outputs across call sites).
pub fn softmax_inplace(s: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for &v in s.iter() {
        mx = mx.max(v);
    }
    let mut sum = 0.0f32;
    for v in s.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in s.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut s = vec![1.0f32, 2.0, 3.0];
        softmax_inplace(&mut s);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn rms_norm_unit_rows() {
        // A row of equal values v normalizes to w (eps tiny).
        let x = vec![3.0f32; 8];
        let w = vec![0.5f32; 8];
        let mut out = vec![0.0f32; 8];
        let mut rstd = vec![0.0f32; 1];
        rms_norm_rows(&x, &w, 1e-12, 1, 8, &mut out, &mut rstd);
        for &o in &out {
            assert!((o - 0.5).abs() < 1e-5, "{o}");
        }
    }

    #[test]
    fn swiglu_matches_elementwise() {
        let mut g = vec![-1.0f32, 0.0, 2.0];
        let u = vec![2.0f32, 3.0, 4.0];
        let want: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
        swiglu_rows(&mut g, &u);
        assert_eq!(g, want);
    }

    #[test]
    fn int8_dot_and_axpy_match_dequantized_f32() {
        // Dequantize-then-f32 must be bitwise identical to the fused
        // int8 primitives: same per-element expression, same order.
        let a = [0.5f32, -1.25, 2.0, 0.0];
        let q = [3i8, -127, 64, 1];
        let scale = [0.1f32, 0.02, 0.5, 0.0];
        let deq: Vec<f32> = q.iter().zip(&scale).map(|(&qv, &sv)| qv as f32 * sv).collect();
        assert_eq!(dot_i8(&a, &q, &scale), dot(&a, &deq));
        let mut y1 = [1.0f32, 2.0, 3.0, 4.0];
        let mut y2 = y1;
        axpy_i8(-0.75, &q, &scale, &mut y1);
        axpy(-0.75, &deq, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn int4_dot_and_axpy_match_dequantized_f32() {
        use crate::kernels::quant::{nibble_hi, nibble_lo, pack_nibbles};
        let a = [0.5f32, -1.25, 2.0, 0.0];
        let codes = [7i8, -7, 3, 0];
        let packed = [pack_nibbles(codes[0], codes[1]), pack_nibbles(codes[2], codes[3])];
        let scale = [0.1f32, 0.02, 0.5, 0.0];
        let deq: Vec<f32> = (0..4)
            .map(|c| {
                let b = packed[c / 2];
                let q = if c % 2 == 0 { nibble_lo(b) } else { nibble_hi(b) };
                q as f32 * scale[c]
            })
            .collect();
        assert_eq!(dot_i4(&a, &packed, &scale), dot(&a, &deq));
        let mut y1 = [1.0f32, 2.0, 3.0, 4.0];
        let mut y2 = y1;
        axpy_i4(-0.75, &packed, &scale, &mut y1);
        axpy(-0.75, &deq, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn dot_and_axpy_agree_with_naive() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert_eq!(dot(&a, &b), 1.0 * 4.0 - 2.0 * 5.0 + 3.0 * 6.0);
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }
}
