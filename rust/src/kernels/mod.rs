//! Compute-kernel layer: tiled GEMMs, fused row ops, and the thread
//! budget that drives every parallel region in the native stack.
//!
//! Layering: [`crate::runtime::NativeBackend`] (forward/decode) and the
//! native train step (backward) express all dense math through this
//! module; the coordinator's concurrent block prefill reuses the same
//! fork/join machinery via [`parallel::par_map`]. Nothing above this
//! layer spawns threads for compute directly.
//!
//! ## Threading model
//!
//! One process-global thread budget ([`num_threads`]) controls every
//! kernel:
//!
//! * `--threads N` on any bin/bench/example (via
//!   [`init_threads_from_args`]), else
//! * `BLOCK_ATTN_THREADS` in the environment, else
//! * the machine's available parallelism.
//!
//! Parallel regions dispatch contiguous, disjoint output ranges to a
//! **persistent worker pool** ([`crate::util::pool::ThreadPool`]):
//! workers are spawned once from the budget (and grown by
//! [`set_threads`], never shrunk), so a region costs a queue push +
//! condvar wake instead of a per-region thread spawn/join — the
//! difference that makes decode-sized ops worth splitting. The calling
//! thread runs the first chunk and then executes its own region's
//! still-queued chunks while it waits, so regions complete at any
//! worker count. Nested regions
//! split the *budget* instead of oversubscribing (a GEMM inside a
//! 2-block concurrent prefill on 8 threads gets 4), and leaf
//! row-splits run their workers serially. [`pool_stats`] exposes the
//! pool's counters (workers, jobs executed, queue-depth high-water)
//! for the server stats endpoint and the bench reports.
//!
//! To add a new parallel consumer: express the work as disjoint output
//! rows and call [`par_rows`] (leaf split) or [`par_map`] (coarse items
//! that run nested kernels — each item inherits an even budget share).
//! Never spawn threads directly, and keep each output element's
//! reduction order fixed; the pool, budget inheritance, and the
//! determinism tests then come for free.
//!
//! ## Determinism guarantee
//!
//! Every kernel accumulates each output element in a fixed reduction
//! order, and every parallel split assigns whole output rows to exactly
//! one worker. Elementwise and `nn`/`tn` GEMM paths use a single f32
//! accumulator in ascending index order; dot-style reductions (`dot*`
//! and the `nt` GEMM family) use the **lane-striped order** documented
//! in [`simd`] — eight fixed partial sums folded ascending — which is
//! the same sequence whether a scalar loop or a vector unit executes
//! it. Results are therefore **bitwise identical for any thread count
//! and any SIMD mode** — `--threads 1` and `--threads 8`, `--simd
//! auto` and `--simd off`, all serve byte-for-byte the same responses,
//! which CI pins by running the suite at `BLOCK_ATTN_THREADS=1`, `=3`
//! (odd, so row chunks and nested budget splits are non-divisible) and
//! `=4`, plus a `BLOCK_ATTN_SIMD=off` leg. Chunk layout is a function
//! of the budget alone — never of pool state or which worker runs a
//! chunk — so pool dispatch cannot perturb the contract.
//!
//! The quantized KV tiers ride on the same contract: [`quant`] codes
//! and dequantizes per element (no cross-element reduction), and the
//! mixed low-bit×f32 GEMMs ([`gemm_nt_i8_acc`] / [`gemm_nn_i8_acc`] /
//! [`gemm_nt_i4_acc`] / [`gemm_nn_i4_acc`], plus the [`dot_i8`] /
//! [`dot_i4`] / [`axpy_i8`] / [`axpy_i4`] row primitives the decode
//! attention is built from) fuse `q·s` — and, for int4, the nibble
//! unpack — into the inner loop without changing the accumulation
//! sequence, so quantized serving is exactly as deterministic as f32
//! serving.
//!
//! ## SIMD dispatch
//!
//! The [`simd`] module holds runtime-dispatched vector bodies (AVX2 on
//! x86_64, NEON on aarch64) for the hot inner loops; the scalar
//! kernels here are the always-available reference, restructured to
//! the same lane-striped partial sums so every vector variant is
//! **bitwise equal** to scalar. Mode selection: `--simd auto|off` (via
//! [`init_threads_from_args`]) > `BLOCK_ATTN_SIMD` > auto-detect; the
//! active ISA is reported by [`isa_name`] in server stats and bench
//! footers. See the [`simd`] docs for the striping contract and how to
//! add a vector kernel.

pub mod gemm;
pub mod parallel;
pub mod quant;
pub mod rowops;
pub mod simd;

pub use gemm::{
    gemm_nn, gemm_nn_acc, gemm_nn_i4_acc, gemm_nn_i8_acc, gemm_nt_acc, gemm_nt_i4_acc,
    gemm_nt_i8_acc, gemm_tn_acc,
};
pub use parallel::{effective_threads, par_map, par_rows, pool_stats};
pub use quant::{QuantizedKv, QuantizedKv4};
pub use rowops::{
    axpy, axpy_i4, axpy_i8, dot, dot_i4, dot_i8, rms_norm_rows, sigmoid, silu, softmax_inplace,
    swiglu_rows,
};
pub use simd::{active_isa, isa_name, set_simd_mode, Isa, SimdMode};

use crate::util::cli::Args;
use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = not yet resolved; resolved lazily on first use.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// The process-wide kernel thread budget. Resolution order:
/// [`set_threads`] (or `--threads` via [`init_threads_from_args`]) >
/// `BLOCK_ATTN_THREADS` > available parallelism.
pub fn num_threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let n = std::env::var("BLOCK_ATTN_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    // Benign race: concurrent first callers resolve the same value.
    THREADS.store(n, Ordering::Relaxed);
    n
}

/// Set the thread budget explicitly (clamped to ≥ 1). Results are
/// identical for every setting; only wall-clock changes. Raising the
/// budget grows the persistent worker pool so the extra width is real;
/// lowering it leaves excess workers idle (chunk counts follow the
/// budget, not the worker count).
pub fn set_threads(n: usize) {
    let n = n.max(1);
    THREADS.store(n, Ordering::Relaxed);
    parallel::grow_pool(n);
}

/// Apply `--threads N` and `--simd auto|off` from parsed CLI options
/// (every bin/bench/example calls this right after `Args::parse`) and
/// return the effective thread budget. Panics loudly on an invalid
/// `--simd` / `BLOCK_ATTN_SIMD` value — a silently ignored mode would
/// time the wrong kernels.
pub fn init_threads_from_args(args: &Args) -> usize {
    if let Some(n) = args.threads() {
        set_threads(n);
    }
    let mode = SimdMode::resolve(args).unwrap_or_else(|e| panic!("{e}"));
    set_simd_mode(mode);
    num_threads()
}

/// One-line human-readable worker-pool summary (bench/bin footers all
/// print it, so dispatch volume is visible next to every timing).
pub fn pool_stats_line() -> String {
    let ps = pool_stats();
    format!(
        "# pool: {} workers, {} jobs dispatched, {} panicked, queue peak {} | simd {}",
        ps.workers,
        ps.jobs_executed,
        ps.jobs_panicked,
        ps.queue_peak,
        isa_name()
    )
}

/// Unit tests mutate the process-global budget; they serialize on this
/// lock so the parallel test harness cannot interleave set/assert pairs.
#[cfg(test)]
pub(crate) static TEST_THREADS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_budget_is_positive_and_settable() {
        let _g = TEST_THREADS_LOCK.lock().unwrap();
        let prev = num_threads();
        assert!(prev >= 1);
        set_threads(3);
        assert_eq!(num_threads(), 3);
        set_threads(0); // clamps
        assert_eq!(num_threads(), 1);
        set_threads(prev);
    }

    #[test]
    fn args_override_applies() {
        let _g = TEST_THREADS_LOCK.lock().unwrap();
        let prev = num_threads();
        let args = Args::parse_from(vec!["--threads".to_string(), "5".to_string()]);
        assert_eq!(init_threads_from_args(&args), 5);
        set_threads(prev);
    }
}
