//! On-disk block file format for the persistent KV store.
//!
//! This module is the pure **format layer**: it turns one cached block
//! (the private [`KvData`] payload of [`super::BlockKvCache`], at any
//! storage tier) into a self-describing byte image and back, with no
//! filesystem involvement — [`super::disk::DiskStore`] owns the
//! directory side. The layout is specified normatively in
//! `docs/kvstore-format.md`; the constants here ([`MAGIC`],
//! [`VERSION`], [`HEADER_LEN`], the header offsets) are that spec's
//! source of truth, and the corrupt-file tests in `tests/kv_store.rs`
//! flip bytes at the documented offsets.
//!
//! Design constraints, in order:
//!
//! 1. **Bitwise round-trip.** Quantization happens exactly once, at
//!    cache insert ([`super::BlockKvCache::insert_pinned`]); a block
//!    file stores the resulting codes + scales (or the raw f32 states
//!    on the f32 tier) verbatim, so a spill → promote cycle is
//!    invisible to every later Eq.-3 fetch. No re-quantization, no
//!    accumulation of quantization error, no float formatting.
//! 2. **Loud rejection.** Every decode failure — short file, bad
//!    magic, unknown version, foreign content key or weights
//!    fingerprint, wrong payload size, checksum mismatch — is a typed
//!    `Err` naming the first check that failed. The cache treats any
//!    of them as a miss and recomputes; it never serves bytes it
//!    cannot fully validate.
//! 3. **Mmap-friendly.** A fixed 64-byte little-endian header with
//!    4-byte-aligned f32 sections and sizes derivable from the header
//!    alone, so a future reader can map the payload in place without a
//!    parse pass.

use super::KvData;
use crate::config::ModelConfig;
use crate::kernels::quant::{QuantizedKv, QuantizedKv4};
use crate::tensor::{Tensor, TensorF};
use anyhow::{bail, ensure, Result};

/// File magic, bytes `0..4` of every block file.
pub const MAGIC: [u8; 4] = *b"BAKV";

/// Format version, bytes `4..6` (little-endian u16). Bump on any
/// layout change; readers reject every version they were not built
/// for.
pub const VERSION: u16 = 1;

/// Fixed header length in bytes; the payload starts here.
pub const HEADER_LEN: usize = 64;

/// Header offset of the version field (the corrupt-file tests rewrite
/// this byte; keep in sync with `docs/kvstore-format.md`).
pub const VERSION_OFFSET: usize = 4;

/// Header offset of the payload checksum (FNV-1a 64 over the payload).
pub const CHECKSUM_OFFSET: usize = 56;

/// Storage-tier codes in the header (bytes `6..8`).
const TIER_F32: u16 = 0;
const TIER_INT8: u16 = 1;
const TIER_INT4: u16 = 2;

/// 64-bit FNV-1a — the payload checksum. Chosen over a CRC for
/// symmetry with [`super::block_key`] (the 128-bit variant of the same
/// hash): one hash family for both the content key and the integrity
/// check, no new dependency.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Incremental FNV-1a 64 accumulator for the weights fingerprint.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }
    fn eat(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    fn eat_usize(&mut self, v: usize) {
        self.eat(&(v as u64).to_le_bytes());
    }
}

/// Fingerprint of the (config, weights) pair a store directory is
/// valid for: cached KV states are functions of the model weights, so
/// block files carry this in both their filename and their header.
/// A dir populated under different weights (another seed, another
/// checkpoint, another architecture) reads as a clean miss instead of
/// silently serving stale KV. Hashes every parameter bit, so it is
/// computed once at attach time, not per lookup.
pub fn weights_fingerprint(cfg: &ModelConfig, params: &[TensorF]) -> u64 {
    let mut h = Fnv::new();
    for v in [
        cfg.vocab,
        cfg.d_model,
        cfg.layers,
        cfg.heads,
        cfg.kv_heads,
        cfg.head_dim,
        cfg.d_ff,
        cfg.max_len,
    ] {
        h.eat_usize(v);
    }
    h.eat(&cfg.rope_theta.to_bits().to_le_bytes());
    h.eat(&cfg.norm_eps.to_bits().to_le_bytes());
    h.eat_usize(params.len());
    for p in params {
        h.eat_usize(p.dims().len());
        for &d in p.dims() {
            h.eat_usize(d);
        }
        for &x in p.data() {
            h.eat(&x.to_bits().to_le_bytes());
        }
    }
    h.0
}

/// One block decoded from a validated file image.
pub(crate) struct StoredBlock {
    pub data: KvData,
    pub len: usize,
}

fn tier_code(data: &KvData) -> u16 {
    match data {
        KvData::F32 { .. } => TIER_F32,
        KvData::Int8 { .. } => TIER_INT8,
        KvData::Int4 { .. } => TIER_INT4,
    }
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_i8s(buf: &mut Vec<u8>, xs: &[i8]) {
    buf.extend(xs.iter().map(|&x| x as u8));
}

fn read_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Encode one cached block into a complete file image (header +
/// payload). Infallible: every resident `KvData` is well-formed by
/// construction.
pub(crate) fn encode_block(key: u128, fingerprint: u64, data: &KvData, len: usize) -> Vec<u8> {
    let dims: [usize; 4] = match data {
        KvData::F32 { k_local, .. } => {
            let d = k_local.dims();
            [d[0], d[1], d[2], d[3]]
        }
        KvData::Int8 { k, .. } => k.dims,
        KvData::Int4 { k, .. } => k.dims,
    };
    debug_assert_eq!(dims[1], len, "block len must match the token axis");

    let mut payload = Vec::new();
    match data {
        KvData::F32 { k_local, v } => {
            push_f32s(&mut payload, k_local.data());
            push_f32s(&mut payload, v.data());
        }
        KvData::Int8 { k, v } => {
            push_i8s(&mut payload, &k.q);
            push_f32s(&mut payload, &k.scales);
            push_i8s(&mut payload, &v.q);
            push_f32s(&mut payload, &v.scales);
        }
        KvData::Int4 { k, v } => {
            payload.extend_from_slice(&k.packed);
            push_f32s(&mut payload, &k.scales);
            payload.extend_from_slice(&v.packed);
            push_f32s(&mut payload, &v.scales);
        }
    }

    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&tier_code(data).to_le_bytes());
    buf.extend_from_slice(&key.to_le_bytes());
    buf.extend_from_slice(&fingerprint.to_le_bytes());
    for d in dims {
        buf.extend_from_slice(&(d as u32).to_le_bytes());
    }
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    debug_assert_eq!(buf.len(), HEADER_LEN);
    buf.extend_from_slice(&payload);
    buf
}

/// Decode and fully validate one block file image. `want_key` /
/// `want_fingerprint` come from the caller's addressing (the filename
/// encodes both) — a file whose header disagrees was renamed or
/// corrupted and is rejected like any other damage.
pub(crate) fn decode_block(
    bytes: &[u8],
    want_key: u128,
    want_fingerprint: u64,
) -> Result<StoredBlock> {
    ensure!(
        bytes.len() >= HEADER_LEN,
        "truncated block file: {} bytes < {HEADER_LEN}-byte header",
        bytes.len()
    );
    ensure!(bytes[0..4] == MAGIC, "bad magic {:02x?} (want {MAGIC:02x?})", &bytes[0..4]);
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    ensure!(version == VERSION, "unsupported format version {version} (reader speaks {VERSION})");
    let tier = u16::from_le_bytes([bytes[6], bytes[7]]);
    let key = u128::from_le_bytes(bytes[8..24].try_into().unwrap());
    ensure!(key == want_key, "content key mismatch: file {key:032x}, want {want_key:032x}");
    let fingerprint = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
    ensure!(
        fingerprint == want_fingerprint,
        "weights fingerprint mismatch: file {fingerprint:016x}, want {want_fingerprint:016x}"
    );
    let mut dims = [0usize; 4];
    for (i, d) in dims.iter_mut().enumerate() {
        let off = 32 + 4 * i;
        *d = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        ensure!(*d > 0, "zero dimension at axis {i}");
    }
    let [layers, len, heads, hd] = dims;
    let n = layers * len * heads * hd;
    let payload_len = u64::from_le_bytes(bytes[48..56].try_into().unwrap()) as usize;
    let want_checksum = u64::from_le_bytes(bytes[56..64].try_into().unwrap());
    ensure!(
        bytes.len() == HEADER_LEN + payload_len,
        "payload length mismatch: file holds {} payload bytes, header claims {payload_len}",
        bytes.len() - HEADER_LEN
    );

    // The per-tier payload size is fully determined by the dims, so a
    // size check rejects section-level damage before any parsing.
    let scales8 = layers * heads * hd;
    let groups = len.div_ceil(crate::kernels::quant::I4_GROUP);
    let scales4 = layers * groups * heads * hd;
    let expect = match tier {
        TIER_F32 => 2 * n * 4,
        TIER_INT8 => 2 * (n + scales8 * 4),
        TIER_INT4 => {
            ensure!(hd % 2 == 0, "int4 tier with odd head_dim {hd}");
            2 * (n / 2 + scales4 * 4)
        }
        t => bail!("unknown storage tier code {t}"),
    };
    ensure!(
        payload_len == expect,
        "tier-{tier} payload of dims {dims:?} must be {expect} bytes, header claims {payload_len}"
    );

    let payload = &bytes[HEADER_LEN..];
    let got_checksum = fnv1a64(payload);
    ensure!(
        got_checksum == want_checksum,
        "payload checksum mismatch: computed {got_checksum:016x}, header {want_checksum:016x}"
    );

    let data = match tier {
        TIER_F32 => {
            let k = Tensor::from_vec(&dims, read_f32s(&payload[..n * 4]));
            let v = Tensor::from_vec(&dims, read_f32s(&payload[n * 4..]));
            KvData::F32 { k_local: k, v }
        }
        TIER_INT8 => {
            let half = n + scales8 * 4;
            let section = |s: &[u8]| -> Result<QuantizedKv> {
                let q: Vec<i8> = s[..n].iter().map(|&b| b as i8).collect();
                let scales = read_f32s(&s[n..]);
                QuantizedKv::from_parts(q, scales, dims)
            };
            KvData::Int8 { k: section(&payload[..half])?, v: section(&payload[half..])? }
        }
        _ => {
            let half = n / 2 + scales4 * 4;
            let section = |s: &[u8]| -> Result<QuantizedKv4> {
                let packed = s[..n / 2].to_vec();
                let scales = read_f32s(&s[n / 2..]);
                QuantizedKv4::from_parts(packed, scales, dims)
            };
            KvData::Int4 { k: section(&payload[..half])?, v: section(&payload[half..])? }
        }
    };
    Ok(StoredBlock { data, len })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_kv(rng: &mut Rng, len: usize) -> (TensorF, TensorF) {
        let dims = [2usize, len, 1, 8];
        let n: usize = dims.iter().product();
        let mk =
            |rng: &mut Rng| Tensor::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect());
        (mk(rng), mk(rng))
    }

    fn sample(tier: u16, len: usize) -> KvData {
        let mut rng = Rng::new(0xD15C + tier as u64);
        let (k, v) = rand_kv(&mut rng, len);
        match tier {
            TIER_F32 => KvData::F32 { k_local: k, v },
            TIER_INT8 => {
                KvData::Int8 { k: QuantizedKv::quantize(&k), v: QuantizedKv::quantize(&v) }
            }
            _ => KvData::Int4 { k: QuantizedKv4::quantize(&k), v: QuantizedKv4::quantize(&v) },
        }
    }

    /// Bitwise equality of two payloads, tier-aware.
    fn assert_same(a: &KvData, b: &KvData) {
        match (a, b) {
            (KvData::F32 { k_local: ka, v: va }, KvData::F32 { k_local: kb, v: vb }) => {
                assert_eq!(ka, kb);
                assert_eq!(va, vb);
            }
            (KvData::Int8 { k: ka, v: va }, KvData::Int8 { k: kb, v: vb }) => {
                assert_eq!(ka.q, kb.q);
                assert_eq!(ka.scales, kb.scales);
                assert_eq!(ka.dims, kb.dims);
                assert_eq!(va.q, vb.q);
                assert_eq!(va.scales, vb.scales);
            }
            (KvData::Int4 { k: ka, v: va }, KvData::Int4 { k: kb, v: vb }) => {
                assert_eq!(ka.packed, kb.packed);
                assert_eq!(ka.scales, kb.scales);
                assert_eq!(ka.dims, kb.dims);
                assert_eq!(va.packed, vb.packed);
                assert_eq!(va.scales, vb.scales);
            }
            _ => panic!("tier changed across the round-trip"),
        }
    }

    #[test]
    fn roundtrip_is_bitwise_per_tier() {
        // A non-multiple of I4_GROUP so the int4 tier exercises a
        // partial trailing scale group.
        for tier in [TIER_F32, TIER_INT8, TIER_INT4] {
            let data = sample(tier, 37);
            let img = encode_block(7, 9, &data, 37);
            assert_eq!(&img[..4], &MAGIC);
            let back = decode_block(&img, 7, 9).unwrap();
            assert_eq!(back.len, 37);
            assert_same(&data, &back.data);
        }
    }

    #[test]
    fn every_corruption_is_rejected_with_its_own_message() {
        let data = sample(TIER_INT8, 16);
        let img = encode_block(1, 2, &data, 16);
        let expect_err = |bytes: &[u8], needle: &str| {
            let err = format!("{:#}", decode_block(bytes, 1, 2).unwrap_err());
            assert!(err.contains(needle), "error {err:?} does not mention {needle:?}");
        };
        expect_err(&img[..HEADER_LEN - 1], "truncated");
        expect_err(&img[..img.len() - 1], "length mismatch");
        let mut t = img.clone();
        t.push(0);
        expect_err(&t, "length mismatch");
        let mut t = img.clone();
        t[0] ^= 0xFF;
        expect_err(&t, "bad magic");
        let mut t = img.clone();
        t[VERSION_OFFSET] = (VERSION + 1) as u8;
        expect_err(&t, "unsupported format version");
        let mut t = img.clone();
        t[6] = 9; // unknown tier code
        expect_err(&t, "unknown storage tier");
        let mut t = img.clone();
        t[HEADER_LEN] ^= 0x01; // one payload bit
        expect_err(&t, "checksum mismatch");
        let mut t = img.clone();
        t[CHECKSUM_OFFSET] ^= 0x01;
        expect_err(&t, "checksum mismatch");
        // Addressing mismatches: same bytes, wrong expectations.
        let err = format!("{:#}", decode_block(&img, 99, 2).unwrap_err());
        assert!(err.contains("content key mismatch"), "{err}");
        let err = format!("{:#}", decode_block(&img, 1, 99).unwrap_err());
        assert!(err.contains("fingerprint mismatch"), "{err}");
        // The pristine image still decodes after all that.
        assert!(decode_block(&img, 1, 2).is_ok());
    }

    #[test]
    fn fingerprint_tracks_config_and_weights() {
        let cfg = ModelConfig::builtin("tiny").unwrap();
        let p1: Vec<TensorF> = vec![Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0])];
        let f1 = weights_fingerprint(&cfg, &p1);
        assert_eq!(f1, weights_fingerprint(&cfg, &p1), "must be deterministic");
        // One weight bit flips the fingerprint.
        let p2: Vec<TensorF> = vec![Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0000005])];
        assert_ne!(f1, weights_fingerprint(&cfg, &p2));
        // So does a config change with identical weights.
        let mut cfg2 = cfg.clone();
        cfg2.rope_theta += 1.0;
        assert_ne!(f1, weights_fingerprint(&cfg2, &p1));
        // Shape changes are seen even when the flattened data matches.
        let p3: Vec<TensorF> = vec![Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0])];
        assert_ne!(f1, weights_fingerprint(&cfg, &p3));
    }
}
