//! Multi-turn chat cache reuse, end to end: turn N+1 must re-serve
//! turn N's system/history blocks from the block cache, and the cached
//! serving must be **bitwise identical** to a cold full re-prefill of
//! the same conversation — at every thread count and KV tier, and
//! through a disk spill → promote round trip.
//!
//! This is the chat scenario family of the serving tentpole: a
//! [`Session`] seals each completed exchange as an immutable block, so
//! per-turn prefill cost stays constant instead of growing with the
//! history. The mirror bookkeeping below reconstructs each turn's
//! equivalent pre-segmented request independently of the session to
//! prove the cached path changes nothing.

use block_attn::config::{KvPrecision, KvStoreConfig, ModelConfig};
use block_attn::coordinator::session::Session;
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::kernels::set_threads;
use block_attn::runtime::NativeBackend;
use block_attn::tokenizer::{ByteTokenizer, EOS, QRY, SEP};
use std::path::PathBuf;
use std::sync::Mutex;

/// Tests here flip the process-global kernel thread budget; serialize
/// so concurrent tests can't mask thread-count differences.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

/// Byte-capable vocab (chat turns are real text, unlike the synthetic
/// micro streams) over a deliberately small transformer.
fn chat_config() -> ModelConfig {
    ModelConfig {
        name: "chat-micro".into(),
        vocab: 261,
        d_model: 32,
        layers: 2,
        heads: 2,
        kv_heads: 1,
        head_dim: 16,
        d_ff: 64,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        max_len: 256,
    }
}

fn coordinator(precision: KvPrecision) -> Coordinator<NativeBackend> {
    let engine = NativeBackend::new(chat_config(), 0xC4A7);
    Coordinator::with_kv_precision(engine, 64 << 20, precision)
}

fn store_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("block-attn-test-chat-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

const USERS: [&str; 3] = ["hello there", "tell me more", "summarize it"];

#[test]
fn warm_turns_match_cold_reprefill_across_tiers_threads_and_disk() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    let tok = ByteTokenizer::new();

    for precision in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
        let mut per_thread: Vec<Vec<Vec<i32>>> = Vec::new();
        for &threads in &[1usize, 3, 8] {
            set_threads(threads);

            // --- Session path: warm, cache-reusing serving. ---
            let mut coord = coordinator(precision);
            let mut session = Session::new(1).with_system("you are a terse assistant");
            session.max_new_tokens = 8;

            // Mirror of the session's sealed history, rebuilt from the
            // wire-visible replies only — proves the equivalent
            // pre-segmented request is reconstructible.
            let mut sys = tok.encode("you are a terse assistant");
            sys.push(SEP);
            let mut mirror: Vec<Vec<i32>> = vec![sys];

            let mut outputs: Vec<Vec<i32>> = Vec::new();
            let mut replayed: Vec<Request> = Vec::new();
            for (i, user) in USERS.iter().enumerate() {
                let (_reply, resp) = session.turn(&mut coord, user).expect("turn");
                assert_eq!(
                    resp.total_blocks,
                    mirror.len(),
                    "turn {i}: unexpected history block count"
                );
                if i > 0 {
                    // Every history block was sealed (and precomputed)
                    // by an earlier turn — a warm turn misses nothing.
                    assert_eq!(
                        resp.cached_blocks, resp.total_blocks,
                        "{precision:?}/{threads}t turn {i}: warm turn missed a history block"
                    );
                }

                // --- Cold path: same conversation, fresh coordinator,
                // full re-prefill of every block. ---
                let mut query = vec![QRY];
                query.extend(tok.encode(user));
                let req = Request {
                    id: 100 + i as u64,
                    blocks: mirror.clone(),
                    query,
                    max_new_tokens: 8,
                    mode: AttentionMode::Block,
                };
                let mut cold = coordinator(precision);
                let cold_resp = cold.process(&req).expect("cold process");
                assert_eq!(
                    cold_resp.tokens, resp.tokens,
                    "{precision:?}/{threads}t turn {i}: cached serving diverged from cold"
                );
                assert_eq!(cold_resp.cached_blocks, 0, "cold coordinator had warm blocks");

                // Seal the exchange into the mirror exactly as the
                // session does: query + reply (to EOS) + SEP.
                let mut sealed = req.query.clone();
                sealed.extend(resp.tokens.iter().take_while(|&&t| t != EOS));
                sealed.push(SEP);
                mirror.push(sealed);
                replayed.push(req);
                outputs.push(resp.tokens.clone());
            }

            // The warm session must actually have hit the cache: turn 1
            // re-served 2 blocks, turn 2 re-served 3.
            let s = coord.cache_stats();
            assert!(s.hits >= 5, "{precision:?}/{threads}t: only {} cache hits", s.hits);
            assert!(s.misses >= 1, "system block should miss on the first turn");

            // --- Disk round trip: spill → drop residency → promote. ---
            let dir = store_dir(&format!("{precision:?}-{threads}"));
            let mut disk = coordinator(precision);
            disk.attach_kv_store(&KvStoreConfig { dir: dir.clone(), budget_bytes: 0 })
                .expect("attach");
            for (req, want) in replayed.iter().zip(&outputs) {
                let resp = disk.process(req).expect("disk cold");
                assert_eq!(&resp.tokens, want, "{precision:?}/{threads}t: disk-backed cold pass");
            }
            assert!(disk.flush_kv_store() > 0, "nothing spilled");
            assert!(disk.drop_resident_blocks() > 0, "nothing resident to drop");
            for (req, want) in replayed.iter().zip(&outputs) {
                let resp = disk.process(req).expect("disk warm");
                assert_eq!(
                    &resp.tokens, want,
                    "{precision:?}/{threads}t: disk-promoted turn diverged"
                );
            }
            let ds = disk.cache_stats();
            assert!(ds.disk_hits > 0, "{precision:?}/{threads}t: no disk promotions");
            assert_eq!(ds.disk_errors, 0, "{precision:?}/{threads}t: disk errors");
            let _ = std::fs::remove_dir_all(&dir);

            per_thread.push(outputs);
        }
        assert!(
            per_thread.windows(2).all(|w| w[0] == w[1]),
            "{precision:?}: chat serving depends on the thread count"
        );
    }
    set_threads(prev);
}

/// Two sessions sharing one system prompt: the second session's first
/// turn re-serves the system block the first session already paid for
/// (cross-session prefix sharing, paper §2.2).
#[test]
fn shared_system_block_is_reused_across_sessions() {
    let mut coord = coordinator(KvPrecision::F32);
    let mut a = Session::new(1).with_system("shared preamble text");
    let mut b = Session::new(2).with_system("shared preamble text");
    a.max_new_tokens = 6;
    b.max_new_tokens = 6;

    let (_, ra) = a.turn(&mut coord, "first question").expect("turn a");
    assert_eq!(ra.cached_blocks, 0, "nothing should be warm yet");
    let (_, rb) = b.turn(&mut coord, "different question").expect("turn b");
    assert_eq!(
        rb.cached_blocks, rb.total_blocks,
        "session B's system block should be served from session A's cache entry"
    );
}
