//! Synthetic RAG benchmarks (the Table-1 substitutes).
//!
//! Every sample is a set of fact passages plus a question whose answer
//! appears *only* inside one (or two, for 2-hop) of the passages — the
//! model cannot answer from its weights, exactly the property the
//! paper's RAG datasets have. Four variants mirror the difficulty axes
//! of NQ / TQA / HQA / 2Wiki:
//!
//! * `OneHopEasy`  — 4 passages, distinct subjects (≈ TQA).
//! * `OneHopHard`  — 7 passages, distinct subjects (≈ NQ).
//! * `TwoHop`      — answer requires chaining two passages (≈ HQA/2Wiki).
//! * `Distract`    — passages share the subject and differ only in the
//!   relation (reading-comprehension style confusion, ≈ NQ-hard).

use super::words::{rand_word, vocabulary};
use super::Sample;
use crate::util::rng::Rng;

/// RAG benchmark variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RagVariant {
    OneHopEasy,
    OneHopHard,
    TwoHop,
    Distract,
}

impl RagVariant {
    pub fn name(&self) -> &'static str {
        match self {
            RagVariant::OneHopEasy => "sRAG-1hop-easy",
            RagVariant::OneHopHard => "sRAG-1hop-hard",
            RagVariant::TwoHop => "sRAG-2hop",
            RagVariant::Distract => "sRAG-distract",
        }
    }

    pub const ALL: [RagVariant; 4] = [
        RagVariant::OneHopEasy,
        RagVariant::OneHopHard,
        RagVariant::TwoHop,
        RagVariant::Distract,
    ];
}

const RELATIONS: [&str; 6] = ["key", "color", "owner", "origin", "title", "mark"];

/// Generator for one variant. The passage *pool* is shared across
/// queries (subjects are drawn from a closed world), so a serving run
/// over many samples naturally re-retrieves passages — the cache-reuse
/// regime of the paper.
pub struct RagGen {
    pub variant: RagVariant,
    subjects: Vec<String>,
}

impl RagGen {
    /// `world` controls how many distinct subjects/values exist (larger
    /// world = less passage overlap between samples). Words are kept to
    /// 2 syllables so full samples fit the 256-token training rows.
    pub fn new(variant: RagVariant, rng: &mut Rng, world: usize) -> RagGen {
        RagGen { variant, subjects: vocabulary(rng, world, 2) }
    }

    fn passage(&self, subject: &str, relation: &str, value: &str) -> String {
        format!("the {relation} of {subject} is {value} .")
    }

    /// Generate one sample. The answer-bearing passage position is
    /// uniform (the paper's "lost in the middle" concern).
    pub fn sample(&self, rng: &mut Rng) -> Sample {
        match self.variant {
            RagVariant::OneHopEasy => self.one_hop(rng, 4),
            RagVariant::OneHopHard => self.one_hop(rng, 6),
            RagVariant::TwoHop => self.two_hop(rng, 5),
            RagVariant::Distract => self.distract(rng, 5),
        }
    }

    fn one_hop(&self, rng: &mut Rng, n_passages: usize) -> Sample {
        let gold = rng.below(n_passages);
        let mut blocks = Vec::with_capacity(n_passages);
        let mut q_subj = String::new();
        let mut q_rel = "";
        let mut answer = String::new();
        let mut used = std::collections::HashSet::new();
        for i in 0..n_passages {
            let mut s;
            loop {
                s = rng.pick(&self.subjects).clone();
                if used.insert(s.clone()) {
                    break;
                }
            }
            let rel = *rng.pick(&RELATIONS);
            let val = rand_word(rng, 5);
            blocks.push(self.passage(&s, rel, &val));
            if i == gold {
                q_subj = s;
                q_rel = rel;
                answer = val;
            }
        }
        Sample {
            blocks,
            query: format!("what is the {q_rel} of {q_subj} ?"),
            // Restatement response: answering is then a suffix-match copy
            // of the gold passage — the induction pattern the model must
            // route *through the retrieved block*.
            response: format!("the {q_rel} of {q_subj} is {answer} ."),
            answer,
        }
    }

    fn two_hop(&self, rng: &mut Rng, n_passages: usize) -> Sample {
        // Bridge: subject --link--> mid; mid --rel--> answer.
        let mut s = self.one_hop(rng, n_passages - 1);
        let subj = rng.pick(&self.subjects).clone();
        let mid = rng.pick(&self.subjects).clone();
        let rel = *rng.pick(&RELATIONS);
        let val = rand_word(rng, 5);
        let bridge = format!("the link of {subj} is {mid} .");
        let tail = self.passage(&mid, rel, &val);
        // Insert the two gold passages at random positions.
        let i = rng.below(s.blocks.len() + 1);
        s.blocks.insert(i, bridge);
        let j = rng.below(s.blocks.len() + 1);
        s.blocks.insert(j, tail);
        s.query = format!("what is the {rel} of the link of {subj} ?");
        // Chain-of-thought restatement: hop 1 then hop 2.
        s.response = format!(
            "the link of {subj} is {mid} . the {rel} of {mid} is {val} ."
        );
        s.answer = val;
        s
    }

    fn distract(&self, rng: &mut Rng, n_passages: usize) -> Sample {
        // All passages about the same subject, different relations.
        let subj = rng.pick(&self.subjects).clone();
        let mut rels: Vec<&str> = RELATIONS.to_vec();
        rng.shuffle(&mut rels);
        let rels = &rels[..n_passages.min(rels.len())];
        let gold = rng.below(rels.len());
        let mut blocks = Vec::new();
        let mut answer = String::new();
        for (i, rel) in rels.iter().enumerate() {
            let val = rand_word(rng, 5);
            blocks.push(self.passage(&subj, rel, &val));
            if i == gold {
                answer = val;
            }
        }
        Sample {
            blocks,
            query: format!("what is the {} of {subj} ?", rels[gold]),
            response: format!("the {} of {subj} is {answer} .", rels[gold]),
            answer,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answer_is_in_exactly_one_passage_one_hop() {
        let mut rng = Rng::new(3);
        let g = RagGen::new(RagVariant::OneHopHard, &mut rng, 50);
        for _ in 0..50 {
            let s = g.sample(&mut rng);
            assert_eq!(s.blocks.len(), 6);
            let hits = s
                .blocks
                .iter()
                .filter(|b| b.contains(&format!("is {} .", s.answer)))
                .count();
            assert!(hits >= 1, "answer not in context: {s:?}");
        }
    }

    #[test]
    fn two_hop_requires_bridge() {
        let mut rng = Rng::new(4);
        let g = RagGen::new(RagVariant::TwoHop, &mut rng, 50);
        let s = g.sample(&mut rng);
        assert!(s.query.contains("the link of"));
        assert!(s.blocks.iter().any(|b| b.contains("the link of")));
    }

    #[test]
    fn distract_same_subject() {
        let mut rng = Rng::new(5);
        let g = RagGen::new(RagVariant::Distract, &mut rng, 50);
        let s = g.sample(&mut rng);
        // Every passage mentions the queried subject.
        let subj = s
            .query
            .rsplit(" of ")
            .next()
            .unwrap()
            .trim_end_matches([' ', '?'])
            .to_string();
        for b in &s.blocks {
            assert!(b.contains(&subj), "{b} lacks {subj}");
        }
    }

    #[test]
    fn samples_fit_tiny_buckets_and_train_rows() {
        // Each passage block must fit the 64-token prefill_block bucket,
        // the whole prompt the 320 context bucket, and prompt + answer +
        // EOS the 256-token training row (byte tokenizer: 1 token/byte).
        let mut rng = Rng::new(6);
        for v in RagVariant::ALL {
            let g = RagGen::new(v, &mut rng, 80);
            for _ in 0..30 {
                let s = g.sample(&mut rng);
                for b in &s.blocks {
                    assert!(b.len() + 1 <= 64, "block too long: {}", b.len());
                }
                let total: usize =
                    s.blocks.iter().map(|b| b.len() + 1).sum::<usize>() + s.query.len() + 1;
                assert!(total + s.answer.len() + 1 <= 256, "sample too long: {total}");
                assert!(!s.answer.is_empty());
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let mk = || {
            let mut rng = Rng::new(7);
            let g = RagGen::new(RagVariant::OneHopEasy, &mut rng, 30);
            g.sample(&mut rng).query
        };
        assert_eq!(mk(), mk());
    }
}
