"""The bench config's long-sequence attention path: the chunked
flash-style jnp implementation must match the materialized reference."""

import dataclasses

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.configs import ModelConfig
from compile.kernels import ref

CFG = ModelConfig(
    name="chunk-test",
    vocab=31,
    d_model=32,
    layers=1,
    heads=2,
    kv_heads=1,
    d_ff=48,
    max_len=512,
    attn_impl="jnp",
)


@settings(max_examples=20, deadline=None)
@given(
    l=st.sampled_from([64, 256, 512]),
    frac=st.floats(0.1, 1.0),
    hq=st.sampled_from([1, 2, 4]),
    ratio=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_chunked_matches_materialized(l, frac, hq, ratio, seed):
    if hq % ratio:
        ratio = 1
    hkv = hq // ratio
    import jax

    d = 16
    length = max(1, int(l * frac))
    q = jax.random.normal(jax.random.PRNGKey(seed), (hq, l, d))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (hkv, l, d))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (hkv, l, d))
    out = model._jnp_chunked_causal(q, k, v, jnp.int32(length), CFG, chunk=64)
    expect = ref.block_attention(q, k, v, length, kv_repeat=ratio)
    np.testing.assert_allclose(
        np.asarray(out)[:, :length], np.asarray(expect)[:, :length], atol=2e-4
    )


def test_jnp_config_prefill_matches_pallas_config():
    """A jnp-impl config and a pallas-impl config of identical dimensions
    must produce identical prefill outputs (the Table-3 vanilla baseline
    runs jnp; accuracy models run pallas — they must be the same math)."""
    import numpy as np

    pallas_cfg = dataclasses.replace(CFG, name="p", attn_impl="pallas", heads=2, kv_heads=1)
    params = [jnp.asarray(a) for a in model.init_params(pallas_cfg, seed=3)]
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, 64), jnp.int32)
    la, ka, _ = model.prefill_full(pallas_cfg, toks, jnp.int32(64), *params)
    lb, kb, _ = model.prefill_full(CFG, toks, jnp.int32(64), *params)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-3)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(kb), atol=2e-4)
