//! `precompute` — offline corpus-to-store encoder.
//!
//! Reads a passage corpus (one passage per line), encodes each line the
//! same way the server does (`tokenizer::ByteTokenizer::encode` plus a
//! trailing `SEP`, see `docs/serving.md`), runs the block prefill once
//! per passage, and spills the resulting KV blocks into the persistent
//! disk store (`docs/kvstore-format.md`). A later `block-attn serve`
//! pointed at the same `--kv-store-dir` (with the same weights) then
//! answers RAG requests over those passages with disk hits instead of
//! recomputing the prefill.
//!
//! Usage:
//!   precompute --corpus passages.txt --kv-store-dir DIR \
//!       [--kv-store-budget MB] [--model tiny] [--checkpoint FILE] \
//!       [--kv-quant f32|int8|int4] [--threads N]
//!
//! The store directory is required (flag or `$BLOCK_ATTN_KV_STORE_DIR`);
//! without one there is nowhere to persist the blocks.

use anyhow::{bail, Context, Result};
use block_attn::coordinator::Coordinator;
use block_attn::tokenizer::{ByteTokenizer, SEP};
use block_attn::util::cli::Args;
use block_attn::{config, kernels, runtime};

fn main() {
    let args = Args::parse();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let corpus_path = match args.get("corpus") {
        Some(p) => std::path::PathBuf::from(p),
        None => bail!("--corpus FILE is required (one passage per line)"),
    };
    let store_cfg = match config::KvStoreConfig::resolve(args)? {
        Some(c) => c,
        None => bail!(
            "a store directory is required: pass --kv-store-dir DIR or set $BLOCK_ATTN_KV_STORE_DIR"
        ),
    };
    let threads = kernels::init_threads_from_args(args);

    let corpus = std::fs::read_to_string(&corpus_path)
        .with_context(|| format!("reading corpus {}", corpus_path.display()))?;

    let backend = runtime::backend_from_args(args, "tiny")?;
    if let Some(ck) = args.get("checkpoint") {
        backend.load_params_file(std::path::Path::new(ck))?;
    }
    let kv_precision = config::KvPrecision::resolve(args)?;
    let mut coord = Coordinator::with_kv_precision(backend, 256 << 20, kv_precision);
    coord.attach_kv_store(&store_cfg)?;

    let max_len = coord.engine().max_block_tokens()?;
    let tok = ByteTokenizer::new();
    let (mut computed, mut skipped, mut too_long) = (0usize, 0usize, 0usize);
    for (lineno, line) in corpus.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let mut ids = tok.encode(line);
        ids.push(SEP);
        if ids.len() > max_len {
            eprintln!(
                "warning: line {} is {} tokens (> max block length {}); skipping",
                lineno + 1,
                ids.len(),
                max_len
            );
            too_long += 1;
            continue;
        }
        if coord.precompute_block(&ids)? {
            computed += 1;
        } else {
            skipped += 1;
        }
    }
    let spilled = coord.flush_kv_store();
    let stats = coord.cache_stats();
    println!(
        "precompute: {} blocks encoded, {} already present, {} too long \
         ({} spilled this run; store now holds {} entries / {} bytes) \
         [threads={}]",
        computed, skipped, too_long, spilled, stats.disk_entries, stats.disk_bytes, threads
    );
    if stats.disk_errors > 0 {
        bail!("{} store write errors (see stderr)", stats.disk_errors);
    }
    Ok(())
}
