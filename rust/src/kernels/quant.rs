//! Symmetric int8 quantization of block KV states.
//!
//! The cache's int8 storage tier (see [`crate::kvcache`]) stores each
//! block's K and V tensors as int8 codes plus f32 scales, one scale per
//! **(layer, kv_head, channel)** — the reduction runs over the token
//! axis, so a block of any length carries a fixed `layers·kv_heads·
//! head_dim` scale table and the payload shrinks to ~¼ of f32.
//!
//! Determinism contract: quantization and dequantization are
//! **per-element and order-free** — `q = round(x/s)` and `x̂ = q·s`
//! touch one element at a time with no cross-element reduction — so the
//! int8 tier inherits the kernels layer's bitwise-identical-at-every-
//! thread-count guarantee unchanged. The fused dequantizing re-encode
//! lives in [`crate::rope::RopeTable::reencode_block_dequant`]; the
//! mixed int8×f32 GEMM micro-kernels live in [`super::gemm`].

use crate::tensor::{Tensor, TensorF};

/// Quantize one value against its channel scale (round half away from
/// zero, saturating at ±127 so the code range is symmetric).
#[inline]
pub fn quantize_one(x: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        0
    } else {
        (x / scale).round().clamp(-127.0, 127.0) as i8
    }
}

/// Dequantize one code.
#[inline]
pub fn dequant_one(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Per-channel symmetric scales for a row-major `rows × n` operand:
/// `scales[c] = amax over rows of |b[r][c]| / 127`. This is the single
/// owner of the scale formula — [`QuantizedKv::quantize`] applies it
/// per layer over the token axis, and the mixed int8×f32 GEMMs
/// ([`super::gemm::gemm_nt_i8_acc`] / [`super::gemm::gemm_nn_i8_acc`])
/// take their `b_scale` in exactly this layout.
pub fn channel_scales(b: &[f32], rows: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(b.len(), rows * n);
    let mut scales = vec![0.0f32; n];
    for row in b.chunks(n) {
        for (s, &v) in scales.iter_mut().zip(row) {
            *s = s.max(v.abs());
        }
    }
    for s in scales.iter_mut() {
        *s /= 127.0;
    }
    scales
}

/// A `(layers, len, kv_heads, head_dim)` KV tensor stored as int8 codes
/// with per-(layer, head, channel) f32 scales.
#[derive(Debug, Clone)]
pub struct QuantizedKv {
    /// Row-major codes, same element order as the source tensor.
    pub q: Vec<i8>,
    /// `scales[(l·kv_heads + h)·head_dim + c] = amax over tokens / 127`.
    pub scales: Vec<f32>,
    /// `[layers, len, kv_heads, head_dim]` of the source tensor.
    pub dims: [usize; 4],
    /// `Σ(x − x̂)²` accumulated while quantizing (ascending element
    /// order) — the reconstruction-error stat comes for free, with no
    /// extra dequant pass on the cache-insert path.
    pub sq_err: f64,
    /// `Σx²` of the source, same accumulation.
    pub sq_ref: f64,
}

impl QuantizedKv {
    /// Quantize a `(layers, len, kv_heads, head_dim)` tensor. The scale
    /// of each (layer, head, channel) is the absolute max over the token
    /// axis divided by 127 (symmetric, zero-point-free): per layer, the
    /// `(len, kv_heads·head_dim)` slice is exactly the row-major layout
    /// [`channel_scales`] reduces over.
    pub fn quantize(x: &TensorF) -> QuantizedKv {
        let d = x.dims();
        assert_eq!(d.len(), 4, "expected (layers, len, kv_heads, head_dim), got {d:?}");
        let (layers, len, heads, hd) = (d[0], d[1], d[2], d[3]);
        let row = heads * hd;
        let mut scales = Vec::with_capacity(layers * row);
        for l in 0..layers {
            scales.extend(channel_scales(x.axis0(l), len, row));
        }
        let mut q = vec![0i8; x.len()];
        let (mut sq_err, mut sq_ref) = (0.0f64, 0.0f64);
        for (l, layer) in x.data().chunks(len * row).enumerate() {
            let srow = &scales[l * row..(l + 1) * row];
            let qlayer = &mut q[l * len * row..(l + 1) * len * row];
            for (i, (&v, code)) in layer.iter().zip(qlayer.iter_mut()).enumerate() {
                let s = srow[i % row];
                *code = quantize_one(v, s);
                let e = (v - dequant_one(*code, s)) as f64;
                sq_err += e * e;
                sq_ref += (v as f64) * (v as f64);
            }
        }
        QuantizedKv { q, scales, dims: [layers, len, heads, hd], sq_err, sq_ref }
    }

    /// Reconstruct the f32 tensor (`q·s` per element).
    pub fn dequantize(&self) -> TensorF {
        let [layers, len, heads, hd] = self.dims;
        let mut out = Tensor::zeros(&self.dims);
        let od = out.data_mut();
        for l in 0..layers {
            for t in 0..len {
                for h in 0..heads {
                    let off = ((l * len + t) * heads + h) * hd;
                    let s0 = (l * heads + h) * hd;
                    for c in 0..hd {
                        od[off + c] = dequant_one(self.q[off + c], self.scales[s0 + c]);
                    }
                }
            }
        }
        out
    }

    /// Stored bytes: one byte per code plus four per scale.
    pub fn size_bytes(&self) -> usize {
        self.q.len() + self.scales.len() * 4
    }

    /// `(sum of squared reconstruction error, sum of squared reference)`
    /// recomputed against the f32 source — a test-side cross-check of
    /// the [`Self::sq_err`]/[`Self::sq_ref`] sums `quantize` accumulates
    /// inline (the cache reads the fields, not this).
    pub fn sq_err_vs(&self, x: &TensorF) -> (f64, f64) {
        assert_eq!(x.dims(), &self.dims[..], "error reference shape mismatch");
        let deq = self.dequantize();
        let mut err = 0.0f64;
        let mut refsq = 0.0f64;
        for (&a, &b) in x.data().iter().zip(deq.data()) {
            let e = (a - b) as f64;
            err += e * e;
            refsq += (a as f64) * (a as f64);
        }
        (err, refsq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_kv(rng: &mut Rng, dims: &[usize; 4]) -> TensorF {
        let n: usize = dims.iter().product();
        Tensor::from_vec(dims, (0..n).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn roundtrip_error_is_bounded_by_channel_amax() {
        let mut rng = Rng::new(0x8B17);
        let dims = [2usize, 9, 2, 8];
        let x = random_kv(&mut rng, &dims);
        let q = QuantizedKv::quantize(&x);
        let deq = q.dequantize();
        // Per element, |x - x̂| ≤ scale/2 (+1 ulp slack); scale = amax/127.
        let (layers, len, heads, hd) = (dims[0], dims[1], dims[2], dims[3]);
        for l in 0..layers {
            for t in 0..len {
                for h in 0..heads {
                    for c in 0..hd {
                        let i = ((l * len + t) * heads + h) * hd + c;
                        let s = q.scales[(l * heads + h) * hd + c];
                        let e = (x.data()[i] - deq.data()[i]).abs();
                        assert!(e <= 0.5001 * s, "elem {i}: err {e} > scale/2 {s}");
                    }
                }
            }
        }
        let (err, refsq) = q.sq_err_vs(&x);
        assert!(err > 0.0 && refsq > 0.0);
        assert!((err / refsq).sqrt() < 0.01, "relative error too large");
        // The inline sums quantize() accumulates walk the elements in
        // the same ascending order as the recomputation — bitwise equal.
        assert_eq!(q.sq_err, err, "inline error sum drifted from recomputation");
        assert_eq!(q.sq_ref, refsq);
    }

    #[test]
    fn quantize_is_deterministic_and_quarter_size() {
        let mut rng = Rng::new(7);
        let dims = [2usize, 64, 1, 8];
        let x = random_kv(&mut rng, &dims);
        let a = QuantizedKv::quantize(&x);
        let b = QuantizedKv::quantize(&x);
        assert_eq!(a.q, b.q);
        assert_eq!(a.scales, b.scales);
        // 64 tokens: codes dominate the fixed scale table.
        let f32_bytes = x.size_bytes();
        assert!(
            a.size_bytes() * 10 <= f32_bytes * 3,
            "int8 {} vs f32 {f32_bytes}: over 30%",
            a.size_bytes()
        );
    }

    #[test]
    fn constant_channels_roundtrip_exactly() {
        // A constant channel has amax = |v|, so v quantizes to ±127 and
        // dequantizes back to exactly v.
        let dims = [1usize, 4, 1, 4];
        let x = Tensor::from_vec(&dims, vec![2.5f32; 16]);
        let q = QuantizedKv::quantize(&x);
        assert!(q.q.iter().all(|&c| c == 127));
        assert_eq!(q.dequantize(), x);
        assert_eq!(q.sq_err, 0.0);
    }

    #[test]
    fn zero_tensor_has_zero_scales_and_codes() {
        let dims = [1usize, 3, 2, 4];
        let x = Tensor::zeros(&dims);
        let q = QuantizedKv::quantize(&x);
        assert!(q.scales.iter().all(|&s| s == 0.0));
        assert!(q.q.iter().all(|&c| c == 0));
        assert_eq!(q.dequantize(), x);
    }

    #[test]
    fn channel_scales_take_column_amax() {
        // 2×3 operand: column amax are (4, 2, 0).
        let b = [1.0f32, -2.0, 0.0, -4.0, 1.5, 0.0];
        let s = channel_scales(&b, 2, 3);
        assert_eq!(s, vec![4.0 / 127.0, 2.0 / 127.0, 0.0]);
    }

    #[test]
    fn quantize_one_saturates_and_rounds() {
        assert_eq!(quantize_one(1.0, 0.0), 0, "zero scale must not divide");
        assert_eq!(quantize_one(f32::MAX, 1e-30), 127);
        assert_eq!(quantize_one(-f32::MAX, 1e-30), -127);
        assert_eq!(quantize_one(0.5, 1.0), 1, "round half away from zero");
        assert_eq!(quantize_one(-0.5, 1.0), -1);
        assert_eq!(dequant_one(3, 0.5), 1.5);
    }
}
