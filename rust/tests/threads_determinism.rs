//! Thread-count determinism: the serving stack must produce
//! byte-identical output at every `--threads` setting.
//!
//! The kernels layer guarantees a fixed per-element reduction order and
//! row-disjoint parallel splits, dispatched to the persistent worker
//! pool; this test pins the end-to-end consequence: a coordinator
//! serving the same request stream at a sweep of kernel thread budgets
//! (1, 3, 8 — including 3, whose non-divisible splits exercise the
//! uneven chunk and budget-inheritance paths) emits identical tokens,
//! TTFT-independent fields, and identical cache behavior — including
//! the concurrent cache-miss block prefill path and the int8 and int4
//! KV tiers (whose decode path attends directly over quantized
//! context codes).

use block_attn::config::KvPrecision;
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::kernels::set_threads;
use block_attn::runtime::NativeBackend;
use block_attn::util::rng::Rng;
use block_attn::{Backend, ModelConfig};
use std::sync::Mutex;

/// The budget sweep: serial, an odd non-divisible width, and a wide
/// power of two.
const THREAD_SWEEP: [usize; 3] = [1, 3, 8];

/// Every test here flips the process-global thread budget; without
/// serialization the harness could interleave them and run both sides
/// of a comparison at the same effective thread count — which would
/// mask exactly the nondeterminism this file exists to catch.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab: 24,
        d_model: 16,
        layers: 2,
        heads: 2,
        kv_heads: 1,
        head_dim: 8,
        d_ff: 32,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        max_len: 256,
    }
}

/// A request stream with shared blocks (cache hits on later requests),
/// fresh blocks (concurrent misses), and a duplicate block inside one
/// request.
fn request_stream(vocab: usize) -> Vec<Request> {
    let mut rng = Rng::new(99);
    let mut block = |len: usize| -> Vec<i32> {
        (0..len).map(|_| rng.below(vocab) as i32).collect()
    };
    let shared_a = block(10);
    let shared_b = block(7);
    let dup = block(5);
    let mut reqs = Vec::new();
    for (i, mode) in [
        AttentionMode::Block,
        AttentionMode::Block,
        AttentionMode::BlockNoReencode,
        AttentionMode::Full,
    ]
    .iter()
    .enumerate()
    {
        let blocks = match i {
            0 => vec![shared_a.clone(), block(9), dup.clone(), dup.clone()],
            1 => vec![shared_a.clone(), shared_b.clone(), block(12)],
            _ => vec![shared_b.clone(), block(6)],
        };
        reqs.push(Request {
            id: i as u64,
            blocks,
            query: block(8),
            max_new_tokens: 6,
            mode: *mode,
        });
    }
    reqs
}

/// Serve the stream on a fresh coordinator at the given budget and KV
/// tier; return everything deterministic about the responses.
fn serve(threads: usize, precision: KvPrecision) -> Vec<(Vec<i32>, usize, usize, usize)> {
    set_threads(threads);
    let engine = NativeBackend::new(micro_config(), 0xD15C);
    let mut coord = Coordinator::with_kv_precision(engine, 64 << 20, precision);
    request_stream(24)
        .iter()
        .map(|req| {
            let resp = coord.process(req).expect("process");
            (resp.tokens.clone(), resp.cached_blocks, resp.total_blocks, resp.prompt_tokens)
        })
        .collect()
}

#[test]
fn coordinator_output_identical_across_thread_counts() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    let baseline = serve(THREAD_SWEEP[0], KvPrecision::F32);
    for &t in &THREAD_SWEEP[1..] {
        let run = serve(t, KvPrecision::F32);
        assert_eq!(
            baseline, run,
            "serving output differs between {} and {t} threads",
            THREAD_SWEEP[0]
        );
    }
    set_threads(prev);
    // Sanity: the stream exercised cache hits and multi-block requests.
    assert!(baseline.iter().any(|(_, cached, _, _)| *cached > 0), "no cache hits exercised");
    assert!(baseline.iter().all(|(tokens, ..)| !tokens.is_empty()));
}

/// The quantized tiers code per element (order-free) and their decode
/// path reads the context codes through fused kernels that keep the
/// ascending accumulation order, so quantized serving must be exactly
/// as thread-count deterministic as f32 — including at the odd budget
/// where splits are uneven.
#[test]
fn coordinator_int8_tier_identical_across_thread_counts() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    let baseline = serve(THREAD_SWEEP[0], KvPrecision::Int8);
    for &t in &THREAD_SWEEP[1..] {
        let run = serve(t, KvPrecision::Int8);
        assert_eq!(
            baseline, run,
            "int8 serving output differs between {} and {t} threads",
            THREAD_SWEEP[0]
        );
    }
    set_threads(prev);
    assert!(baseline.iter().all(|(tokens, ..)| !tokens.is_empty()));
}

/// Same sweep on the int4 tier: packed nibbles + group-wise scales are
/// still per-element maps, and the int4 decode attention (dot_i4 /
/// axpy_i4 over the packed prefix) splits by whole head rows — the
/// stream must be bitwise identical at 1/3/8 threads.
#[test]
fn coordinator_int4_tier_identical_across_thread_counts() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    let baseline = serve(THREAD_SWEEP[0], KvPrecision::Int4);
    for &t in &THREAD_SWEEP[1..] {
        let run = serve(t, KvPrecision::Int4);
        assert_eq!(
            baseline, run,
            "int4 serving output differs between {} and {t} threads",
            THREAD_SWEEP[0]
        );
    }
    set_threads(prev);
    assert!(baseline.iter().all(|(tokens, ..)| !tokens.is_empty()));
}

#[test]
fn prefill_blocks_identical_across_thread_counts() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    let engine = NativeBackend::new(micro_config(), 0xBEE);
    let mut rng = Rng::new(7);
    let blocks: Vec<Vec<i32>> = (0..5)
        .map(|i| (0..(3 + i * 2)).map(|_| rng.below(24) as i32).collect())
        .collect();
    let refs: Vec<&[i32]> = blocks.iter().map(|b| b.as_slice()).collect();
    set_threads(1);
    let serial = engine.prefill_blocks(&refs).unwrap();
    for &t in &THREAD_SWEEP[1..] {
        set_threads(t);
        let parallel = engine.prefill_blocks(&refs).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for ((k1, v1), (kt, vt)) in serial.iter().zip(&parallel) {
            assert_eq!(k1, kt, "block K differs between 1 and {t} threads");
            assert_eq!(v1, vt, "block V differs between 1 and {t} threads");
        }
    }
    set_threads(prev);
}
