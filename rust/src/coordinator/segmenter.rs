//! Block segmentation (paper §2.2 and §3.1).
//!
//! "Segment semantically independent parts of the prompt into separate
//! blocks": retrieved passages in RAG, demonstrations in ICL, turns in
//! dialogue, fields in gamecore JSON, and the paper's newline heuristics
//! (`\n\n`, `---`, `===`, `\n\t\t`) for free-form text. The final block —
//! the user query — is the only one allowed to attend across blocks.

use crate::tokenizer::ByteTokenizer;
use crate::util::json::Json;

/// A segmented prompt: context blocks + the final (query) block.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentedPrompt {
    pub blocks: Vec<Vec<i32>>,
    pub query: Vec<i32>,
}

impl SegmentedPrompt {
    pub fn context_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum()
    }
}

/// The paper's newline block-division labels (§3.1, rule 3).
pub const DIVISION_LABELS: [&str; 4] = ["\n\n", "---", "===", "\n\t\t"];

/// Segment a RAG prompt: one block per retrieved passage (plus an
/// optional leading system block); the query is the final block.
pub fn segment_rag(
    tok: &ByteTokenizer,
    system: Option<&str>,
    passages: &[String],
    query: &str,
) -> SegmentedPrompt {
    let mut blocks = Vec::new();
    if let Some(s) = system {
        blocks.push(tok.encode(s));
    }
    for p in passages {
        blocks.push(tok.encode(p));
    }
    SegmentedPrompt { blocks, query: tok.encode(query) }
}

/// Segment an ICL prompt: one block per demonstration; the test input is
/// the final block (a k-shot sample becomes k+1 blocks, paper Table 2).
pub fn segment_icl(tok: &ByteTokenizer, demos: &[String], test_input: &str) -> SegmentedPrompt {
    SegmentedPrompt {
        blocks: demos.iter().map(|d| tok.encode(d)).collect(),
        query: tok.encode(test_input),
    }
}

/// Segment free-form text on the paper's division labels. The text after
/// the last division becomes the query block.
pub fn segment_text(tok: &ByteTokenizer, text: &str) -> SegmentedPrompt {
    let mut parts: Vec<String> = vec![String::new()];
    let bytes = text.as_bytes();
    let mut i = 0;
    'outer: while i < bytes.len() {
        for label in DIVISION_LABELS {
            let lb = label.as_bytes();
            if bytes[i..].starts_with(lb) {
                // The label terminates the current part (and is kept with
                // it so decode round-trips).
                parts.last_mut().unwrap().push_str(label);
                parts.push(String::new());
                i += lb.len();
                continue 'outer;
            }
        }
        // Advance one UTF-8 character.
        let ch_len = utf8_len(bytes[i]);
        parts
            .last_mut()
            .unwrap()
            .push_str(std::str::from_utf8(&bytes[i..i + ch_len]).unwrap_or("?"));
        i += ch_len;
    }
    parts.retain(|p| !p.is_empty());
    let query = parts.pop().unwrap_or_default();
    SegmentedPrompt {
        blocks: parts.iter().map(|p| tok.encode(p)).collect(),
        query: tok.encode(&query),
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Segment a gamecore JSON state (paper Appendix A): each top-level (or
/// second-level, for objects) field becomes one block, serialized
/// deterministically so identical sub-states hash to identical blocks
/// across frames. `task` is the instruction/query block.
pub fn segment_gamecore(tok: &ByteTokenizer, state: &Json, task: &str) -> SegmentedPrompt {
    let mut blocks = Vec::new();
    if let Some(obj) = state.as_obj() {
        for (key, val) in obj {
            match val {
                Json::Obj(inner) if !inner.is_empty() => {
                    for (k2, v2) in inner {
                        blocks.push(tok.encode(&format!("{key}.{k2}={v2}")));
                    }
                }
                other => blocks.push(tok.encode(&format!("{key}={other}"))),
            }
        }
    } else {
        blocks.push(tok.encode(&state.to_string()));
    }
    SegmentedPrompt { blocks, query: tok.encode(task) }
}

/// Merge blocks shorter than `min_len` into their predecessor — tiny
/// blocks waste cache entries and bucket padding.
pub fn coalesce_small_blocks(mut sp: SegmentedPrompt, min_len: usize) -> SegmentedPrompt {
    let mut out: Vec<Vec<i32>> = Vec::with_capacity(sp.blocks.len());
    for b in sp.blocks.drain(..) {
        match out.last_mut() {
            Some(prev) if b.len() < min_len || prev.len() < min_len => {
                prev.extend_from_slice(&b)
            }
            _ => out.push(b),
        }
    }
    sp.blocks = out;
    sp
}

/// Split blocks longer than `max_len` into `max_len`-sized chunks so
/// every block fits the prefill_block bucket capacity.
pub fn split_oversized_blocks(mut sp: SegmentedPrompt, max_len: usize) -> SegmentedPrompt {
    let mut out = Vec::with_capacity(sp.blocks.len());
    for b in sp.blocks.drain(..) {
        if b.len() <= max_len {
            out.push(b);
        } else {
            for chunk in b.chunks(max_len) {
                out.push(chunk.to_vec());
            }
        }
    }
    sp.blocks = out;
    sp
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> ByteTokenizer {
        ByteTokenizer::new()
    }

    #[test]
    fn rag_blocks_one_per_passage() {
        let t = tok();
        let sp = segment_rag(
            &t,
            Some("You are helpful."),
            &["Doc one.".into(), "Doc two.".into()],
            "Which doc?",
        );
        assert_eq!(sp.blocks.len(), 3);
        assert_eq!(t.decode(&sp.query), "Which doc?");
        assert_eq!(t.decode(&sp.blocks[1]), "Doc one.");
    }

    #[test]
    fn icl_k_shot_is_k_plus_one_blocks() {
        let t = tok();
        let sp = segment_icl(&t, &["in: a out: b".into(), "in: c out: d".into()], "in: e out:");
        assert_eq!(sp.blocks.len(), 2);
        assert!(!sp.query.is_empty());
    }

    #[test]
    fn text_splits_on_division_labels() {
        let t = tok();
        let sp = segment_text(&t, "part one\n\npart two---part three===tail");
        assert_eq!(sp.blocks.len(), 3);
        assert_eq!(t.decode(&sp.query), "tail");
        // Round-trip: blocks + query reassemble the original text.
        let mut s = String::new();
        for b in &sp.blocks {
            s.push_str(&t.decode(b));
        }
        s.push_str(&t.decode(&sp.query));
        assert_eq!(s, "part one\n\npart two---part three===tail");
    }

    #[test]
    fn text_without_labels_is_single_query() {
        let t = tok();
        let sp = segment_text(&t, "just a sentence");
        assert!(sp.blocks.is_empty());
        assert_eq!(t.decode(&sp.query), "just a sentence");
    }

    #[test]
    fn gamecore_fields_become_blocks() {
        let t = tok();
        let state = Json::parse(
            r#"{"chips":{"p1":{"bet":10},"p2":{"bet":50}},"round":3}"#,
        )
        .unwrap();
        let sp = segment_gamecore(&t, &state, "act");
        // chips.p1, chips.p2, round
        assert_eq!(sp.blocks.len(), 3);
        // Deterministic serialization → frame-to-frame block identity.
        let sp2 = segment_gamecore(&t, &Json::parse(
            r#"{"round":3,"chips":{"p2":{"bet":50},"p1":{"bet":10}}}"#,
        ).unwrap(), "act");
        assert_eq!(sp.blocks, sp2.blocks);
    }

    #[test]
    fn coalesce_merges_small() {
        let sp = SegmentedPrompt {
            blocks: vec![vec![1; 2], vec![2; 50], vec![3; 2], vec![4; 50]],
            query: vec![9],
        };
        let out = coalesce_small_blocks(sp, 8);
        // [2] merges into [50] (prev too small), trailing [2] merges
        // backward, final [50] stands alone: [54, 50].
        assert_eq!(out.blocks.len(), 2);
        assert_eq!(out.blocks[0].len(), 54);
        assert_eq!(out.blocks[1].len(), 50);
        assert_eq!(out.blocks.iter().map(|b| b.len()).sum::<usize>(), 104);
    }

    #[test]
    fn split_caps_block_len() {
        let sp = SegmentedPrompt { blocks: vec![vec![1; 300]], query: vec![] };
        let out = split_oversized_blocks(sp, 128);
        assert_eq!(out.blocks.len(), 3);
        assert!(out.blocks.iter().all(|b| b.len() <= 128));
        assert_eq!(out.blocks.iter().map(|b| b.len()).sum::<usize>(), 300);
    }
}
