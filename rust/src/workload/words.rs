//! Deterministic pseudo-word generation for the synthetic corpora.

use crate::util::rng::Rng;

const CONSONANTS: &[u8] = b"bcdfgklmnprstvz";
const VOWELS: &[u8] = b"aeiou";

/// A uniformly random lowercase word of `len` letters.
///
/// High-entropy by construction (~4.7 bits/char): used for answer
/// *values* so that predicting them is impossible without copying from
/// the context — the loss signal that makes the retrieval circuit form.
/// (With low-entropy CV words the model can reach near-minimal loss from
/// marginal statistics alone and retrieval never emerges — measured the
/// hard way; see DESIGN.md training-recipe notes.)
pub fn rand_word(rng: &mut Rng, len: usize) -> String {
    (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

/// A pronounceable pseudo-word of `syllables` CV pairs ("kato", "meruzi").
pub fn word(rng: &mut Rng, syllables: usize) -> String {
    let mut s = String::with_capacity(syllables * 2);
    for _ in 0..syllables {
        s.push(*rng.pick(CONSONANTS) as char);
        s.push(*rng.pick(VOWELS) as char);
    }
    s
}

/// A vocabulary of `n` distinct pseudo-words. Note: at 2 syllables there
/// are only 75 combinations, so larger vocabularies get numeric suffixes.
pub fn vocabulary(rng: &mut Rng, n: usize, syllables: usize) -> Vec<String> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let mut w = word(rng, syllables);
        // Disambiguate collisions with a numeric suffix.
        if seen.contains(&w) {
            w.push_str(&rng.below(100).to_string());
        }
        if seen.insert(w.clone()) {
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_are_deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        assert_eq!(word(&mut a, 3), word(&mut b, 3));
    }

    #[test]
    fn vocabulary_distinct() {
        let mut rng = Rng::new(2);
        let v = vocabulary(&mut rng, 200, 2);
        let set: std::collections::HashSet<_> = v.iter().collect();
        assert_eq!(set.len(), 200);
    }
}
