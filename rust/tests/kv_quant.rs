//! The quantized KV tiers' three contracts, end to end on the hermetic
//! [`NativeBackend`] — now with decode-path attention running **over
//! the quantized assembled context** (the decode prefix is stored at
//! tier precision and read through the fused mixed-precision kernels,
//! not dequantized into a dense f32 cache):
//!
//! 1. **Accuracy** — teacher-forced decode logits on the workload
//!    traces (the paper's passage-reuse streams) stay within cosine
//!    similarity of the f32 tier: ≥ 0.999 under `--kv-quant int8`,
//!    ≥ 0.99 under `--kv-quant int4`.
//! 2. **Capacity** — a cached block costs ≤ 30% (int8) / ≤ 16% (int4)
//!    of its f32 bytes, and the saving is visible in
//!    `CacheStats::bytes_saved` (attributed per tier).
//! 3. **Determinism** — quantization is per-element and order-free, so
//!    quantized serving stays bitwise identical across thread counts,
//!    just like f32 serving — including the quantized decode path.

use block_attn::config::{KvPrecision, ModelConfig};
use block_attn::coordinator::{AttentionMode, Coordinator};
use block_attn::kernels::set_threads;
use block_attn::runtime::NativeBackend;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::rng::Rng;
use block_attn::workload::traces::RagTrace;
use std::sync::Mutex;

/// The determinism test flips the process-global thread budget;
/// serialize against any future sibling doing the same.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn coordinator(precision: KvPrecision) -> Coordinator<NativeBackend> {
    let engine = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C);
    Coordinator::with_kv_precision(engine, 64 << 20, precision)
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut ab, mut aa, mut bb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        ab += x as f64 * y as f64;
        aa += x as f64 * x as f64;
        bb += y as f64 * y as f64;
    }
    if aa == 0.0 || bb == 0.0 {
        return 1.0;
    }
    ab / (aa.sqrt() * bb.sqrt())
}

/// Contract 1: decode-logit cosine similarity f32-vs-int8 ≥ 0.999 on
/// Zipf-skewed passage-reuse traces served through the full pipeline
/// (segment → plan → quantized cache → fused dequant re-encode →
/// final prefill → teacher-forced decode).
#[test]
fn int8_decode_logits_cosine_against_f32() {
    let tok = ByteTokenizer::new();
    let mut rng = Rng::new(0xACC);
    let trace = RagTrace::build(&mut rng, 24);
    let mut f32_coord = coordinator(KvPrecision::F32);
    let mut int8_coord = coordinator(KvPrecision::Int8);
    assert_eq!(int8_coord.kv_precision(), KvPrecision::Int8);

    let mut worst = 1.0f64;
    for _ in 0..5 {
        let sample = trace.request(&mut rng, 4, 1.1);
        let sp = sample.segment(&tok);
        // Teacher-force the gold response so both tiers decode over the
        // exact same token stream.
        let mut forced = tok.encode(&sample.response);
        forced.truncate(6);
        let a = f32_coord
            .logits_trace(&sp.blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("f32 trace");
        let b = int8_coord
            .logits_trace(&sp.blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("int8 trace");
        assert_eq!(a.len(), b.len());
        for (step, (la, lb)) in a.iter().zip(&b).enumerate() {
            let c = cosine(la, lb);
            worst = worst.min(c);
            assert!(
                c >= 0.999,
                "step {step}: cosine {c} < 0.999 (int8 tier too lossy)"
            );
        }
    }
    // The tiers must actually differ (int8 is lossy) — a fake pass-through
    // would report cosine exactly 1.0 everywhere with zero error stats.
    let s = int8_coord.cache_stats();
    assert!(s.quant_rel_err() > 0.0, "int8 tier recorded no quantization error");
    assert!(s.quant_rel_err() < 0.01, "relative error too large: {}", s.quant_rel_err());
    assert!(worst >= 0.999);
}

/// Contract 1 for int4: the coarser 15-level codes with group-wise
/// scales hold decode-logit cosine ≥ 0.99 vs f32 on the same traces —
/// with decode attention reading the packed codes directly.
#[test]
fn int4_decode_logits_cosine_against_f32() {
    let tok = ByteTokenizer::new();
    let mut rng = Rng::new(0xACC);
    let trace = RagTrace::build(&mut rng, 24);
    let mut f32_coord = coordinator(KvPrecision::F32);
    let mut int4_coord = coordinator(KvPrecision::Int4);
    assert_eq!(int4_coord.kv_precision(), KvPrecision::Int4);

    let mut worst = 1.0f64;
    for _ in 0..5 {
        let sample = trace.request(&mut rng, 4, 1.1);
        let sp = sample.segment(&tok);
        let mut forced = tok.encode(&sample.response);
        forced.truncate(6);
        let a = f32_coord
            .logits_trace(&sp.blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("f32 trace");
        let b = int4_coord
            .logits_trace(&sp.blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("int4 trace");
        assert_eq!(a.len(), b.len());
        for (step, (la, lb)) in a.iter().zip(&b).enumerate() {
            let c = cosine(la, lb);
            worst = worst.min(c);
            assert!(
                c >= 0.99,
                "step {step}: cosine {c} < 0.99 (int4 tier too lossy)"
            );
        }
    }
    // The tier must actually be lossy — and lossier than int8's bound.
    let s = int4_coord.cache_stats();
    assert!(s.quant_rel_err() > 0.0, "int4 tier recorded no quantization error");
    assert!(s.quant_rel_err() < 0.15, "relative error too large: {}", s.quant_rel_err());
    assert!(worst >= 0.99);
}

/// Contract 2: the quantized tier stores a block at ≤ 30% of its f32
/// bytes, and reports the saving.
#[test]
fn int8_cache_bytes_at_most_30_percent_of_f32() {
    let mut rng = Rng::new(0xB17E);
    let vocab = ModelConfig::builtin("tiny").unwrap().vocab;
    let blocks: Vec<Vec<i32>> = (0..3)
        .map(|_| (0..64).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let mut f32_coord = coordinator(KvPrecision::F32);
    let mut int8_coord = coordinator(KvPrecision::Int8);
    for b in &blocks {
        f32_coord.precompute_block(b).expect("f32 precompute");
        int8_coord.precompute_block(b).expect("int8 precompute");
    }
    let sf = f32_coord.cache_stats();
    let s8 = int8_coord.cache_stats();
    assert_eq!(sf.entries, 3);
    assert_eq!(s8.entries, 3);
    assert_eq!(sf.bytes_saved, 0, "f32 tier must not claim savings");
    assert!(
        s8.bytes * 10 <= sf.bytes * 3,
        "int8 cache {} bytes > 30% of f32 {}",
        s8.bytes,
        sf.bytes
    );
    assert_eq!(
        s8.bytes + s8.bytes_saved,
        sf.bytes,
        "bytes_saved must account exactly for the f32 difference"
    );
}

/// Contract 2 for int4: ≤ 16% of the f32 bytes per cached block (the
/// packed codes are ⅛; the group-wise scale table rides on top), with
/// the saving attributed to the int4 tier.
#[test]
fn int4_cache_bytes_at_most_16_percent_of_f32() {
    let mut rng = Rng::new(0xB17E);
    let vocab = ModelConfig::builtin("tiny").unwrap().vocab;
    let blocks: Vec<Vec<i32>> = (0..3)
        .map(|_| (0..64).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let mut f32_coord = coordinator(KvPrecision::F32);
    let mut int4_coord = coordinator(KvPrecision::Int4);
    for b in &blocks {
        f32_coord.precompute_block(b).expect("f32 precompute");
        int4_coord.precompute_block(b).expect("int4 precompute");
    }
    let sf = f32_coord.cache_stats();
    let s4 = int4_coord.cache_stats();
    assert_eq!(sf.entries, 3);
    assert_eq!(s4.entries, 3);
    assert!(
        s4.bytes * 100 <= sf.bytes * 16,
        "int4 cache {} bytes > 16% of f32 {}",
        s4.bytes,
        sf.bytes
    );
    assert_eq!(
        s4.bytes + s4.bytes_saved,
        sf.bytes,
        "bytes_saved must account exactly for the f32 difference"
    );
    assert_eq!(s4.bytes_saved_int4, s4.bytes_saved, "saving must be attributed to int4");
    assert_eq!(s4.bytes_saved_int8, 0);
}

/// Contract 3: with a quantized tier active, serving output — tokens
/// *and* raw logits, through the quantized decode path — is bitwise
/// identical at 1 and 4 kernel threads.
#[test]
fn quantized_serving_is_bitwise_identical_across_thread_counts() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();

    let serve = |threads: usize, precision: KvPrecision| -> Vec<Vec<Vec<f32>>> {
        set_threads(threads);
        let tok = ByteTokenizer::new();
        let mut rng = Rng::new(0xDE7);
        let trace = RagTrace::build(&mut rng, 12);
        let mut coord = coordinator(precision);
        (0..3)
            .map(|_| {
                let sample = trace.request(&mut rng, 3, 1.1);
                let sp = sample.segment(&tok);
                let mut forced = tok.encode(&sample.response);
                forced.truncate(4);
                coord
                    .logits_trace(&sp.blocks, &sp.query, &forced, AttentionMode::Block)
                    .expect("trace")
            })
            .collect()
    };
    for precision in [KvPrecision::Int8, KvPrecision::Int4] {
        let one = serve(1, precision);
        let four = serve(4, precision);
        assert_eq!(
            one, four,
            "{precision:?} serving depends on the thread count (determinism contract broken)"
        );
    }
    set_threads(prev);
}
