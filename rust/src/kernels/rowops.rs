//! Fused row-wise kernels: RMSNorm, softmax, SwiGLU, and the dot/axpy
//! primitives the attention inner loops are built from.
//!
//! Every reduction runs in the fixed **lane-striped** order defined by
//! [`super::simd`] (element `i` accumulates into partial sum `i % 8`,
//! lanes folded ascending at the end; the RMSNorm f64 sum of squares
//! stripes over 4 lanes), and every elementwise op keeps plain
//! ascending order — so identical inputs produce bitwise-identical
//! outputs at every call site, every thread count, and every `--simd`
//! setting. Each public function dispatches on [`super::simd::active_isa`]
//! between the scalar reference body below and a vector body in
//! `simd::x86` / `simd::neon` that is bitwise identical by
//! construction (pinned by `tests/simd_parity.rs`).

use super::simd::{self, Isa, F64_LANES, LANES};

/// Lane-striped dot product (see module docs for the reduction order).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { simd::x86::dot_avx2(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == Isa::Neon {
        // SAFETY: `Isa::Neon` is only stored after runtime detection.
        return unsafe { simd::neon::dot_neon(a, b) };
    }
    dot_scalar(a, b)
}

pub(crate) fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0.0f32; LANES];
    let mut c = 0;
    while c < main {
        for j in 0..LANES {
            lanes[j] += a[c + j] * b[c + j];
        }
        c += LANES;
    }
    for i in main..n {
        lanes[i - main] += a[i] * b[i];
    }
    simd::fold_lanes(&lanes)
}

/// `y += alpha * x`, elementwise.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { simd::x86::axpy_avx2(alpha, x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == Isa::Neon {
        // SAFETY: `Isa::Neon` is only stored after runtime detection.
        return unsafe { simd::neon::axpy_neon(alpha, x, y) };
    }
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// Lane-striped dot product against an int8 row with per-channel
/// scales: `Σ a[c] · (q[c]·scale[c])` — the QKᵀ inner loop of the
/// fused-dequant attention path. Dequantization is per-element and
/// order-free, so the striping matches [`dot`] exactly and
/// dequantize-then-[`dot`] stays bitwise identical.
#[inline]
pub fn dot_i8(a: &[f32], q: &[i8], scale: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    debug_assert_eq!(a.len(), scale.len());
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { simd::x86::dot_i8_avx2(a, q, scale) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == Isa::Neon {
        // SAFETY: `Isa::Neon` is only stored after runtime detection.
        return unsafe { simd::neon::dot_i8_neon(a, q, scale) };
    }
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0.0f32; LANES];
    let mut c = 0;
    while c < main {
        for j in 0..LANES {
            lanes[j] += a[c + j] * (q[c + j] as f32 * scale[c + j]);
        }
        c += LANES;
    }
    for i in main..n {
        lanes[i - main] += a[i] * (q[i] as f32 * scale[i]);
    }
    simd::fold_lanes(&lanes)
}

/// `y += alpha · (q·scale)`, elementwise (the AV inner loop of the
/// fused-dequant attention path; per-channel scales).
#[inline]
pub fn axpy_i8(alpha: f32, q: &[i8], scale: &[f32], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    debug_assert_eq!(q.len(), scale.len());
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { simd::x86::axpy_i8_avx2(alpha, q, scale, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd::active_isa() == Isa::Neon {
        // SAFETY: `Isa::Neon` is only stored after runtime detection.
        return unsafe { simd::neon::axpy_i8_neon(alpha, q, scale, y) };
    }
    for ((&qv, &sv), yi) in q.iter().zip(scale).zip(y.iter_mut()) {
        *yi += alpha * (qv as f32 * sv);
    }
}

/// Lane-striped dot product against a packed-int4 row (two codes per
/// byte, channel-axis packing) with per-channel scales — the QKᵀ inner
/// loop of the int4 decode-attention path. Channel `c` lands in lane
/// `c % 8` exactly as in [`dot`], so the fused unpack+dequant is
/// bitwise invisible next to dequantize-then-[`dot`].
#[inline]
pub fn dot_i4(a: &[f32], packed: &[u8], scale: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), packed.len() * 2);
    debug_assert_eq!(a.len(), scale.len());
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { simd::x86::dot_i4_avx2(a, packed, scale) };
    }
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0.0f32; LANES];
    // 4 bytes = 8 channels per step, so the byte tail continues the
    // channel-lane cycle (`main` is a multiple of 8 channels).
    let mut i = 0;
    while i < main / 2 {
        for jb in 0..LANES / 2 {
            let b = packed[i + jb];
            let c = 2 * (i + jb);
            lanes[2 * jb] += a[c] * (super::quant::nibble_lo(b) as f32 * scale[c]);
            lanes[2 * jb + 1] += a[c + 1] * (super::quant::nibble_hi(b) as f32 * scale[c + 1]);
        }
        i += LANES / 2;
    }
    for i in main / 2..packed.len() {
        let b = packed[i];
        let c0 = 2 * i;
        lanes[c0 - main] += a[c0] * (super::quant::nibble_lo(b) as f32 * scale[c0]);
        lanes[c0 - main + 1] += a[c0 + 1] * (super::quant::nibble_hi(b) as f32 * scale[c0 + 1]);
    }
    simd::fold_lanes(&lanes)
}

/// `y += alpha · (q·scale)` over a packed-int4 row (the AV inner loop
/// of the int4 decode-attention path; per-channel scales, ascending
/// channel order as in [`axpy`]).
#[inline]
pub fn axpy_i4(alpha: f32, packed: &[u8], scale: &[f32], y: &mut [f32]) {
    debug_assert_eq!(y.len(), packed.len() * 2);
    debug_assert_eq!(y.len(), scale.len());
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { simd::x86::axpy_i4_avx2(alpha, packed, scale, y) };
    }
    for (i, &b) in packed.iter().enumerate() {
        let c = 2 * i;
        y[c] += alpha * (super::quant::nibble_lo(b) as f32 * scale[c]);
        y[c + 1] += alpha * (super::quant::nibble_hi(b) as f32 * scale[c + 1]);
    }
}

/// Row-wise RMSNorm: `out[t] = x[t] * rstd[t] * w`; returns the
/// reciprocal RMS per row (needed by the backward pass). The f64 sum
/// of squares stripes over [`F64_LANES`] partial sums (see module
/// docs); the normalize apply is elementwise.
pub fn rms_norm_rows(
    x: &[f32],
    w: &[f32],
    eps: f64,
    l: usize,
    d: usize,
    out: &mut [f32],
    rstd: &mut [f32],
) {
    debug_assert_eq!(x.len(), l * d);
    debug_assert_eq!(w.len(), d);
    debug_assert_eq!(out.len(), l * d);
    debug_assert_eq!(rstd.len(), l);
    let isa = simd::active_isa();
    for t in 0..l {
        let xr = &x[t * d..(t + 1) * d];
        let ms = sumsq_f64(xr, isa);
        let r = (1.0 / (ms / d as f64 + eps).sqrt()) as f32;
        rstd[t] = r;
        let orow = &mut out[t * d..(t + 1) * d];
        #[cfg(target_arch = "x86_64")]
        if isa == Isa::Avx2 {
            // SAFETY: `Isa::Avx2` is only stored after runtime detection.
            unsafe { simd::x86::norm_mul_avx2(xr, r, w, orow) };
            continue;
        }
        for ((o, &xv), &wv) in orow.iter_mut().zip(xr).zip(w) {
            *o = xv * r * wv;
        }
    }
}

#[inline]
fn sumsq_f64(xr: &[f32], isa: Isa) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        return unsafe { simd::x86::sumsq_f64_avx2(xr) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = isa;
    let n = xr.len();
    let main = n - n % F64_LANES;
    let mut lanes = [0.0f64; F64_LANES];
    let mut c = 0;
    while c < main {
        for j in 0..F64_LANES {
            let v = xr[c + j] as f64;
            lanes[j] += v * v;
        }
        c += F64_LANES;
    }
    for i in main..n {
        let v = xr[i] as f64;
        lanes[i - main] += v * v;
    }
    simd::fold_lanes_f64(&lanes)
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// Fused SwiGLU gate: `g[i] = silu(g[i]) * u[i]` in place.
pub fn swiglu_rows(g: &mut [f32], u: &[f32]) {
    debug_assert_eq!(g.len(), u.len());
    for (gv, &uv) in g.iter_mut().zip(u) {
        *gv = silu(*gv) * uv;
    }
}

/// In-place softmax over `s` (max-subtracted, ascending accumulation so
/// identical inputs give bitwise-identical outputs across call sites).
///
/// The max scan and the exp/sum chain stay scalar on every ISA: the
/// sum's addends come out of serial `exp` calls, so lane-striping it
/// buys nothing without a vector `exp` (whose rounding would break
/// parity anyway), and `_mm256_max_ps` NaN semantics differ from
/// `f32::max`. Only the final elementwise `*= inv` scale dispatches.
pub fn softmax_inplace(s: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for &v in s.iter() {
        mx = mx.max(v);
    }
    let mut sum = 0.0f32;
    for v in s.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    #[cfg(target_arch = "x86_64")]
    if simd::active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only stored after runtime detection.
        unsafe { simd::x86::scale_avx2(s, inv) };
        return;
    }
    for v in s.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut s = vec![1.0f32, 2.0, 3.0];
        softmax_inplace(&mut s);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn rms_norm_unit_rows() {
        // A row of equal values v normalizes to w (eps tiny).
        let x = vec![3.0f32; 8];
        let w = vec![0.5f32; 8];
        let mut out = vec![0.0f32; 8];
        let mut rstd = vec![0.0f32; 1];
        rms_norm_rows(&x, &w, 1e-12, 1, 8, &mut out, &mut rstd);
        for &o in &out {
            assert!((o - 0.5).abs() < 1e-5, "{o}");
        }
    }

    #[test]
    fn swiglu_matches_elementwise() {
        let mut g = vec![-1.0f32, 0.0, 2.0];
        let u = vec![2.0f32, 3.0, 4.0];
        let want: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
        swiglu_rows(&mut g, &u);
        assert_eq!(g, want);
    }

    #[test]
    fn int8_dot_and_axpy_match_dequantized_f32() {
        // Dequantize-then-f32 must be bitwise identical to the fused
        // int8 primitives: same per-element expression, same striping.
        let a = [0.5f32, -1.25, 2.0, 0.0];
        let q = [3i8, -127, 64, 1];
        let scale = [0.1f32, 0.02, 0.5, 0.0];
        let deq: Vec<f32> = q.iter().zip(&scale).map(|(&qv, &sv)| qv as f32 * sv).collect();
        assert_eq!(dot_i8(&a, &q, &scale), dot(&a, &deq));
        let mut y1 = [1.0f32, 2.0, 3.0, 4.0];
        let mut y2 = y1;
        axpy_i8(-0.75, &q, &scale, &mut y1);
        axpy(-0.75, &deq, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn int4_dot_and_axpy_match_dequantized_f32() {
        use crate::kernels::quant::{nibble_hi, nibble_lo, pack_nibbles};
        let a = [0.5f32, -1.25, 2.0, 0.0];
        let codes = [7i8, -7, 3, 0];
        let packed = [pack_nibbles(codes[0], codes[1]), pack_nibbles(codes[2], codes[3])];
        let scale = [0.1f32, 0.02, 0.5, 0.0];
        let deq: Vec<f32> = (0..4)
            .map(|c| {
                let b = packed[c / 2];
                let q = if c % 2 == 0 { nibble_lo(b) } else { nibble_hi(b) };
                q as f32 * scale[c]
            })
            .collect();
        assert_eq!(dot_i4(&a, &packed, &scale), dot(&a, &deq));
        let mut y1 = [1.0f32, 2.0, 3.0, 4.0];
        let mut y2 = y1;
        axpy_i4(-0.75, &packed, &scale, &mut y1);
        axpy(-0.75, &deq, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn dot_and_axpy_agree_with_naive() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert_eq!(dot(&a, &b), 1.0 * 4.0 - 2.0 * 5.0 + 3.0 * 6.0);
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn striped_dot_matches_independent_formulation() {
        // Independent i%8 formulation of the lane-striping contract
        // (the chunked scalar body and both vector bodies must all
        // reduce in exactly this order).
        fn striped(a: &[f32], b: &[f32]) -> f32 {
            let mut lanes = [0.0f32; LANES];
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                lanes[i % LANES] += x * y;
            }
            let mut s = lanes[0];
            for &l in &lanes[1..] {
                s += l;
            }
            s
        }
        let mut state = 0x1234_5678u64;
        let mut rnd = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as i32 % 1000) as f32 / 997.0
        };
        for n in (0..40).chain([64, 65, 127, 130]) {
            let a: Vec<f32> = (0..n).map(|_| rnd()).collect();
            let b: Vec<f32> = (0..n).map(|_| rnd()).collect();
            assert_eq!(dot_scalar(&a, &b).to_bits(), striped(&a, &b).to_bits(), "n={n}");
        }
    }
}
