//! Runtime-dispatched SIMD inner loops for the GEMM and rowop families.
//!
//! ## Dispatch rules
//!
//! Every public kernel in [`super::rowops`] / [`super::gemm`] /
//! [`super::quant`] is a thin wrapper that reads [`active_isa`] (one
//! relaxed atomic load) and branches to either the scalar reference
//! body or a vector body in [`x86`] / [`neon`]. The active ISA is
//! resolved once, from:
//!
//! * `--simd auto|off` on any bin/bench/example (via
//!   `kernels::init_threads_from_args`), else
//! * `$BLOCK_ATTN_SIMD` in the environment (invalid values **panic** —
//!   same loud-misconfiguration policy as `KvPrecision::from_env`), else
//! * `auto`: AVX2 on x86_64, NEON on aarch64, scalar anywhere else —
//!   all behind runtime feature detection
//!   (`is_x86_feature_detected!` / `is_aarch64_feature_detected!`),
//!   so a binary built on a new machine still runs on an old one.
//!
//! Vector bodies not implemented for the active ISA (e.g. the i4
//! family on NEON) silently fall back to the scalar reference — which
//! is safe precisely because of the parity contract below.
//!
//! ## The lane-striped reduction contract
//!
//! Vector ISAs cannot cheaply reproduce a single-accumulator ascending
//! dot product, so this module pins a different — but equally fixed —
//! reduction order, and the *scalar reference kernels are restructured
//! to use the same one*:
//!
//! * f32 dot-style reductions accumulate into **[`LANES`] = 8 fixed
//!   partial sums**: element `i` lands in lane `i % 8`, the tail
//!   included, and the lanes are folded left-to-right at the end
//!   (`((l0+l1)+l2)+…`). 8 is one AVX2 `ymm` register; NEON uses two
//!   4-lane accumulators side by side so lane assignment is identical.
//! * The RMSNorm sum-of-squares stripes its f64 accumulation over
//!   **[`F64_LANES`] = 4 lanes** (one AVX2 `ymm` of doubles) the same
//!   way.
//! * Everything elementwise (axpy, dequantize, normalize/scale, SwiGLU,
//!   the RoPE rotation) keeps its per-element expression tree and
//!   ascending order unchanged — vectorizing over independent output
//!   elements is bitwise invisible.
//!
//! Vector kernels use **separate multiply and add instructions, never
//! FMA**: the scalar reference rounds `a*b` and then the add, and a
//! fused `mul_add` (one rounding) would break bitwise parity. This
//! costs a little peak throughput and buys the property everything
//! rests on: **every SIMD variant is bitwise identical to its scalar
//! reference at every shape, tier, and thread count** (pinned by
//! `tests/simd_parity.rs`), so `--simd` joins `--threads` and
//! `--kv-quant` in the set of knobs that cannot change served bytes.
//!
//! ## Adding a new vector kernel
//!
//! 1. Write the scalar body first. If it reduces across elements,
//!    stripe it over [`LANES`] partial sums folded ascending; if it is
//!    elementwise, keep plain ascending order.
//! 2. Add the vector body under [`x86`]/[`neon`] as an
//!    `unsafe fn …` with `#[target_feature(enable = "avx2")]` (or
//!    `"neon"`), using mul+add (no FMA) and the exact same lane
//!    assignment; scalar-process the tail into `lanes[i - main]`.
//! 3. Dispatch on [`active_isa`] in the public wrapper, with the
//!    scalar body as the `_ =>` arm.
//! 4. Pin it in `tests/simd_parity.rs` on shapes that exercise the
//!    vector main loop, the scalar tail (`len % 8 != 0`), and both
//!    together.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};

/// Striping width of every f32 dot-style reduction (one AVX2 register).
pub const LANES: usize = 8;
/// Striping width of the f64 RMSNorm sum-of-squares (one AVX2 register).
pub const F64_LANES: usize = 4;

/// SIMD selection knob. Resolution order: `--simd` > `$BLOCK_ATTN_SIMD`
/// > `Auto`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Use the widest ISA the CPU supports (runtime-detected).
    #[default]
    Auto,
    /// Force the scalar reference kernels (bitwise identical output).
    Off,
}

impl SimdMode {
    pub fn parse(s: &str) -> Result<SimdMode> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "on" => SimdMode::Auto,
            "off" | "scalar" => SimdMode::Off,
            other => bail!("unknown SIMD mode '{other}' (expected 'auto' or 'off')"),
        })
    }

    /// `$BLOCK_ATTN_SIMD`, defaulting to `Auto` when unset or empty.
    /// An unparsable value **panics** — silently serving scalar kernels
    /// when the operator typo'd `off` (or vice versa) would hide either
    /// a multi-× perf misconfiguration or an unwanted vector path, so
    /// bins fail loudly at startup instead (the `KvPrecision::from_env`
    /// policy).
    pub fn from_env() -> SimdMode {
        match Self::parse_env_value(std::env::var("BLOCK_ATTN_SIMD").ok().as_deref()) {
            Ok(m) => m,
            Err(e) => panic!("invalid $BLOCK_ATTN_SIMD: {e}"),
        }
    }

    /// The pure resolution behind [`Self::from_env`]: `None` or an
    /// empty/whitespace value defaults to `Auto`, anything else must
    /// parse. Split out so both paths are unit-testable without
    /// touching the process environment.
    pub fn parse_env_value(v: Option<&str>) -> Result<SimdMode> {
        match v {
            Some(s) if !s.trim().is_empty() => SimdMode::parse(s),
            _ => Ok(SimdMode::Auto),
        }
    }

    /// `--simd` from parsed CLI options, falling back to the
    /// environment then `Auto`. Errors on an unparsable flag value.
    pub fn resolve(args: &crate::util::cli::Args) -> Result<SimdMode> {
        match args.simd() {
            Some(v) => SimdMode::parse(v),
            None => Self::parse_env_value(std::env::var("BLOCK_ATTN_SIMD").ok().as_deref()),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Off => "off",
        }
    }
}

/// The instruction set the dispatch wrappers are currently routing to.
/// Discriminants are the [`ACTIVE`] atomic's encoding (0 is reserved
/// for "unresolved").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Isa {
    Scalar = 1,
    Avx2 = 2,
    Neon = 3,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

const ISA_UNSET: u8 = 0;
const ISA_SCALAR: u8 = 1;
const ISA_AVX2: u8 = 2;
const ISA_NEON: u8 = 3;

/// 0 = not yet resolved; resolved lazily on first use (same pattern as
/// the `kernels::THREADS` budget).
static ACTIVE: AtomicU8 = AtomicU8::new(ISA_UNSET);

fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Scalar
}

/// Apply a SIMD mode process-wide and return the ISA it resolved to.
/// `Off` forces the scalar reference kernels; `Auto` runtime-detects.
/// Output is bitwise identical either way, so flipping this mid-flight
/// (as the parity tests and benches do) is always safe — it only moves
/// wall-clock.
pub fn set_simd_mode(mode: SimdMode) -> Isa {
    let isa = match mode {
        SimdMode::Off => Isa::Scalar,
        SimdMode::Auto => detect(),
    };
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    isa
}

/// The ISA the kernel wrappers dispatch to right now. First call
/// resolves `$BLOCK_ATTN_SIMD` (benign race: concurrent first callers
/// resolve the same value).
#[inline]
pub fn active_isa() -> Isa {
    match ACTIVE.load(Ordering::Relaxed) {
        ISA_SCALAR => Isa::Scalar,
        ISA_AVX2 => Isa::Avx2,
        ISA_NEON => Isa::Neon,
        _ => set_simd_mode(SimdMode::from_env()),
    }
}

/// `active_isa().name()` — the string reported by server `stats`
/// (`simd_isa`), bench footers, and the bench JSON context field.
pub fn isa_name() -> &'static str {
    active_isa().name()
}

/// Fold the 8 striped partial sums left-to-right. Shared by the scalar
/// references and every vector body so the final reduction order is a
/// single definition.
#[inline]
pub(crate) fn fold_lanes(lanes: &[f32; LANES]) -> f32 {
    let mut s = lanes[0];
    for &l in &lanes[1..] {
        s += l;
    }
    s
}

/// [`fold_lanes`] for the 4 striped f64 partial sums of the RMSNorm
/// sum-of-squares.
#[inline]
pub(crate) fn fold_lanes_f64(lanes: &[f64; F64_LANES]) -> f64 {
    let mut s = lanes[0];
    for &l in &lanes[1..] {
        s += l;
    }
    s
}

/// In-place RoPE pair rotation (the Eq.-3 inner loop): given the low
/// and high halves of one head's channels,
/// `lo[j], hi[j] ← lo[j]·cos[j] − hi[j]·sin[j], lo[j]·sin[j] + hi[j]·cos[j]`.
/// Elementwise over `j` (each pair reads only its own two channels), so
/// the vector body is bitwise identical to the scalar one.
pub fn rotate_pairs(lo: &mut [f32], hi: &mut [f32], cos: &[f32], sin: &[f32]) {
    debug_assert_eq!(lo.len(), hi.len());
    debug_assert_eq!(lo.len(), cos.len());
    debug_assert_eq!(lo.len(), sin.len());
    #[cfg(target_arch = "x86_64")]
    if active_isa() == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only ever stored after runtime detection.
        unsafe { x86::rotate_pairs_avx2(lo, hi, cos, sin) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if active_isa() == Isa::Neon {
        // SAFETY: `Isa::Neon` is only ever stored after runtime detection.
        unsafe { neon::rotate_pairs_neon(lo, hi, cos, sin) };
        return;
    }
    rotate_pairs_scalar(lo, hi, cos, sin);
}

pub(crate) fn rotate_pairs_scalar(lo: &mut [f32], hi: &mut [f32], cos: &[f32], sin: &[f32]) {
    for j in 0..lo.len() {
        let a = lo[j];
        let b = hi[j];
        lo[j] = a * cos[j] - b * sin[j];
        hi[j] = a * sin[j] + b * cos[j];
    }
}

/// AVX2 bodies. Callable only through the [`active_isa`] dispatch in
/// the public wrappers, which guarantees the CPU supports AVX2.
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use super::{fold_lanes, F64_LANES, LANES};
    use crate::kernels::quant::{nibble_hi, nibble_lo};
    use core::arch::x86_64::*;

    /// Striped f32 dot product (lane `i % 8`, mul+add, scalar tail).
    ///
    /// # Safety
    /// The CPU must support AVX2 (guaranteed by the [`super::active_isa`]
    /// dispatch). Slices must satisfy `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut c = 0;
        while c < main {
            let va = _mm256_loadu_ps(a.as_ptr().add(c));
            let vb = _mm256_loadu_ps(b.as_ptr().add(c));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            c += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for i in main..n {
            lanes[i - main] += a[i] * b[i];
        }
        fold_lanes(&lanes)
    }

    /// Striped int8 dot product: lane `i % 8` accumulates
    /// `a[i] · (q[i]·scale[i])` with the dequant multiply rounded before
    /// the outer multiply, exactly like the scalar reference.
    ///
    /// # Safety
    /// AVX2 required; `a.len() == q.len() == scale.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8_avx2(a: &[f32], q: &[i8], scale: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut c = 0;
        while c < main {
            let va = _mm256_loadu_ps(a.as_ptr().add(c));
            let vs = _mm256_loadu_ps(scale.as_ptr().add(c));
            let qb = _mm_loadl_epi64(q.as_ptr().add(c) as *const __m128i);
            let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qb));
            let deq = _mm256_mul_ps(qf, vs);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, deq));
            c += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for i in main..n {
            lanes[i - main] += a[i] * (q[i] as f32 * scale[i]);
        }
        fold_lanes(&lanes)
    }

    /// Unpack 4 packed-int4 bytes (8 channels) into 8 sign-extended i32
    /// lanes in channel order `lo0,hi0,lo1,hi1,…` — the shift pair
    /// `(x << 28) >> 28` / `(x << 24) >> 28` on a zero-extended byte is
    /// exactly `nibble_lo` / `nibble_hi`.
    ///
    /// # Safety
    /// AVX2 required; `p` must be readable for 4 bytes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn unpack8_i4(p: *const u8) -> __m256i {
        let word = (p as *const u32).read_unaligned();
        let bi = _mm_cvtepu8_epi32(_mm_cvtsi32_si128(word as i32));
        let lo = _mm_srai_epi32(_mm_slli_epi32(bi, 28), 28);
        let hi = _mm_srai_epi32(_mm_slli_epi32(bi, 24), 28);
        let il = _mm_unpacklo_epi32(lo, hi);
        let ih = _mm_unpackhi_epi32(lo, hi);
        _mm256_inserti128_si256(_mm256_castsi128_si256(il), ih, 1)
    }

    /// Striped packed-int4 dot product: channel `c` lands in lane
    /// `c % 8` (4 bytes = 8 channels per vector step, so the scalar
    /// byte tail continues the lane cycle exactly).
    ///
    /// # Safety
    /// AVX2 required; `a.len() == packed.len() * 2 == scale.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i4_avx2(a: &[f32], packed: &[u8], scale: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut c = 0;
        while c < main {
            let va = _mm256_loadu_ps(a.as_ptr().add(c));
            let vs = _mm256_loadu_ps(scale.as_ptr().add(c));
            let qf = _mm256_cvtepi32_ps(unpack8_i4(packed.as_ptr().add(c / 2)));
            let deq = _mm256_mul_ps(qf, vs);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, deq));
            c += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for i in main / 2..packed.len() {
            let b = packed[i];
            let c0 = 2 * i;
            lanes[c0 - main] += a[c0] * (nibble_lo(b) as f32 * scale[c0]);
            lanes[c0 - main + 1] += a[c0 + 1] * (nibble_hi(b) as f32 * scale[c0 + 1]);
        }
        fold_lanes(&lanes)
    }

    /// Elementwise `y[i] += alpha · x[i]` (per-element mul+add, same
    /// rounding sequence as the scalar body).
    ///
    /// # Safety
    /// AVX2 required; `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let main = n - n % LANES;
        let va = _mm256_set1_ps(alpha);
        let mut c = 0;
        while c < main {
            let vx = _mm256_loadu_ps(x.as_ptr().add(c));
            let vy = _mm256_loadu_ps(y.as_ptr().add(c));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(c),
                _mm256_add_ps(vy, _mm256_mul_ps(va, vx)),
            );
            c += LANES;
        }
        for i in main..n {
            y[i] += alpha * x[i];
        }
    }

    /// Elementwise `y[i] += alpha · (q[i]·scale[i])`.
    ///
    /// # Safety
    /// AVX2 required; `q.len() == scale.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_i8_avx2(alpha: f32, q: &[i8], scale: &[f32], y: &mut [f32]) {
        let n = y.len();
        let main = n - n % LANES;
        let va = _mm256_set1_ps(alpha);
        let mut c = 0;
        while c < main {
            let vs = _mm256_loadu_ps(scale.as_ptr().add(c));
            let qb = _mm_loadl_epi64(q.as_ptr().add(c) as *const __m128i);
            let deq = _mm256_mul_ps(_mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qb)), vs);
            let vy = _mm256_loadu_ps(y.as_ptr().add(c));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(c),
                _mm256_add_ps(vy, _mm256_mul_ps(va, deq)),
            );
            c += LANES;
        }
        for i in main..n {
            y[i] += alpha * (q[i] as f32 * scale[i]);
        }
    }

    /// Elementwise `y[c] += alpha · (q4[c]·scale[c])` over a packed row.
    ///
    /// # Safety
    /// AVX2 required; `y.len() == packed.len() * 2 == scale.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_i4_avx2(alpha: f32, packed: &[u8], scale: &[f32], y: &mut [f32]) {
        let n = y.len();
        let main = n - n % LANES;
        let va = _mm256_set1_ps(alpha);
        let mut c = 0;
        while c < main {
            let vs = _mm256_loadu_ps(scale.as_ptr().add(c));
            let deq = _mm256_mul_ps(_mm256_cvtepi32_ps(unpack8_i4(packed.as_ptr().add(c / 2))), vs);
            let vy = _mm256_loadu_ps(y.as_ptr().add(c));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(c),
                _mm256_add_ps(vy, _mm256_mul_ps(va, deq)),
            );
            c += LANES;
        }
        for i in main / 2..packed.len() {
            let b = packed[i];
            let c0 = 2 * i;
            y[c0] += alpha * (nibble_lo(b) as f32 * scale[c0]);
            y[c0 + 1] += alpha * (nibble_hi(b) as f32 * scale[c0 + 1]);
        }
    }

    /// Elementwise int8 row dequantize: `out[i] = q[i]·scale[i]`.
    ///
    /// # Safety
    /// AVX2 required; `q.len() == scale.len() == out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_i8_row_avx2(q: &[i8], scale: &[f32], out: &mut [f32]) {
        let n = out.len();
        let main = n - n % LANES;
        let mut c = 0;
        while c < main {
            let vs = _mm256_loadu_ps(scale.as_ptr().add(c));
            let qb = _mm_loadl_epi64(q.as_ptr().add(c) as *const __m128i);
            let deq = _mm256_mul_ps(_mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qb)), vs);
            _mm256_storeu_ps(out.as_mut_ptr().add(c), deq);
            c += LANES;
        }
        for i in main..n {
            out[i] = q[i] as f32 * scale[i];
        }
    }

    /// Elementwise packed-int4 row dequantize: `out[c] = q4[c]·scale[c]`.
    ///
    /// # Safety
    /// AVX2 required; `out.len() == packed.len() * 2 == scale.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_i4_row_avx2(packed: &[u8], scale: &[f32], out: &mut [f32]) {
        let n = out.len();
        let main = n - n % LANES;
        let mut c = 0;
        while c < main {
            let vs = _mm256_loadu_ps(scale.as_ptr().add(c));
            let deq = _mm256_mul_ps(_mm256_cvtepi32_ps(unpack8_i4(packed.as_ptr().add(c / 2))), vs);
            _mm256_storeu_ps(out.as_mut_ptr().add(c), deq);
            c += LANES;
        }
        for i in main / 2..packed.len() {
            let b = packed[i];
            let c0 = 2 * i;
            out[c0] = nibble_lo(b) as f32 * scale[c0];
            out[c0 + 1] = nibble_hi(b) as f32 * scale[c0 + 1];
        }
    }

    /// Striped f64 sum of squares over an f32 row (lane `i % 4`; the
    /// f32→f64 widen is exact, so only the striping is contractual).
    ///
    /// # Safety
    /// AVX2 required.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sumsq_f64_avx2(x: &[f32]) -> f64 {
        let n = x.len();
        let main = n - n % F64_LANES;
        let mut acc = _mm256_setzero_pd();
        let mut c = 0;
        while c < main {
            let v = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(c)));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
            c += F64_LANES;
        }
        let mut lanes = [0.0f64; F64_LANES];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        for i in main..n {
            let v = x[i] as f64;
            lanes[i - main] += v * v;
        }
        super::fold_lanes_f64(&lanes)
    }

    /// Elementwise RMSNorm apply: `out[i] = (x[i]·r)·w[i]` (same
    /// left-associated rounding as the scalar `xv * r * wv`).
    ///
    /// # Safety
    /// AVX2 required; `x.len() == w.len() == out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn norm_mul_avx2(x: &[f32], r: f32, w: &[f32], out: &mut [f32]) {
        let n = x.len();
        let main = n - n % LANES;
        let vr = _mm256_set1_ps(r);
        let mut c = 0;
        while c < main {
            let vx = _mm256_loadu_ps(x.as_ptr().add(c));
            let vw = _mm256_loadu_ps(w.as_ptr().add(c));
            _mm256_storeu_ps(
                out.as_mut_ptr().add(c),
                _mm256_mul_ps(_mm256_mul_ps(vx, vr), vw),
            );
            c += LANES;
        }
        for i in main..n {
            out[i] = x[i] * r * w[i];
        }
    }

    /// Elementwise in-place scale: `s[i] *= inv` (the softmax
    /// normalization loop; the exp/sum chain stays scalar).
    ///
    /// # Safety
    /// AVX2 required.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_avx2(s: &mut [f32], inv: f32) {
        let n = s.len();
        let main = n - n % LANES;
        let vi = _mm256_set1_ps(inv);
        let mut c = 0;
        while c < main {
            let v = _mm256_loadu_ps(s.as_ptr().add(c));
            _mm256_storeu_ps(s.as_mut_ptr().add(c), _mm256_mul_ps(v, vi));
            c += LANES;
        }
        for v in &mut s[main..] {
            *v *= inv;
        }
    }

    /// Elementwise RoPE pair rotation (see [`super::rotate_pairs`]).
    ///
    /// # Safety
    /// AVX2 required; all four slices must have equal length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn rotate_pairs_avx2(lo: &mut [f32], hi: &mut [f32], cos: &[f32], sin: &[f32]) {
        let n = lo.len();
        let main = n - n % LANES;
        let mut c = 0;
        while c < main {
            let a = _mm256_loadu_ps(lo.as_ptr().add(c));
            let b = _mm256_loadu_ps(hi.as_ptr().add(c));
            let vc = _mm256_loadu_ps(cos.as_ptr().add(c));
            let vs = _mm256_loadu_ps(sin.as_ptr().add(c));
            let nl = _mm256_sub_ps(_mm256_mul_ps(a, vc), _mm256_mul_ps(b, vs));
            let nh = _mm256_add_ps(_mm256_mul_ps(a, vs), _mm256_mul_ps(b, vc));
            _mm256_storeu_ps(lo.as_mut_ptr().add(c), nl);
            _mm256_storeu_ps(hi.as_mut_ptr().add(c), nh);
            c += LANES;
        }
        if main < n {
            super::rotate_pairs_scalar(&mut lo[main..], &mut hi[main..], &cos[main..], &sin[main..]);
        }
    }

    /// Serial n×n GEMM tile: `out[m×n] += a[m×k] · b[k×n]`, the vector
    /// twin of `gemm::nn_serial_scalar` — 4×16 register tile (two ymm
    /// columns per row), broadcast `a`, ascending `k`, mul+add. Every
    /// output element sees the identical per-element rounding sequence
    /// as the scalar micro tile, so tiling differences are invisible.
    ///
    /// # Safety
    /// AVX2 required; `a.len() == m·k`, `b.len() == k·n`,
    /// `out.len() == m·n`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn nn_serial_avx2(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        const MR: usize = 4;
        const NR: usize = 16;
        let mut i = 0;
        while i + MR <= m {
            let mut j = 0;
            while j + NR <= n {
                let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let o = (i + r) * n + j;
                    accr[0] = _mm256_loadu_ps(out.as_ptr().add(o));
                    accr[1] = _mm256_loadu_ps(out.as_ptr().add(o + LANES));
                }
                for p in 0..k {
                    let vb0 = _mm256_loadu_ps(b.as_ptr().add(p * n + j));
                    let vb1 = _mm256_loadu_ps(b.as_ptr().add(p * n + j + LANES));
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let av = _mm256_set1_ps(*a.get_unchecked((i + r) * k + p));
                        accr[0] = _mm256_add_ps(accr[0], _mm256_mul_ps(av, vb0));
                        accr[1] = _mm256_add_ps(accr[1], _mm256_mul_ps(av, vb1));
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    let o = (i + r) * n + j;
                    _mm256_storeu_ps(out.as_mut_ptr().add(o), accr[0]);
                    _mm256_storeu_ps(out.as_mut_ptr().add(o + LANES), accr[1]);
                }
                j += NR;
            }
            if j < n {
                for r in 0..MR {
                    let row = i + r;
                    nn_row_edge_avx2(&a[row * k..(row + 1) * k], b, n, j, &mut out[row * n..(row + 1) * n]);
                }
            }
            i += MR;
        }
        for row in i..m {
            nn_row_edge_avx2(&a[row * k..(row + 1) * k], b, n, 0, &mut out[row * n..(row + 1) * n]);
        }
    }

    /// Column/row edge of [`nn_serial_avx2`]: saxpy over `b` rows for
    /// columns `j0..n`, ascending `p` (the scalar `nn_row_edge` order).
    ///
    /// # Safety
    /// AVX2 required; same layout preconditions as [`nn_serial_avx2`].
    #[target_feature(enable = "avx2")]
    unsafe fn nn_row_edge_avx2(arow: &[f32], b: &[f32], n: usize, j0: usize, orow: &mut [f32]) {
        for (p, &av) in arow.iter().enumerate() {
            axpy_avx2(av, &b[p * n + j0..(p + 1) * n], &mut orow[j0..]);
        }
    }

    /// Serial tᵀ×n GEMM tile: `out[rows×n] += aᵀ[rows×m] · b[m×n]` for
    /// the row strip `p0..p0+rows` of `aᵀ` — saxpy formulation
    /// (ascending `i` per output element, the scalar `tn` order).
    ///
    /// # Safety
    /// AVX2 required; `a.len() == m·k`, `b.len() == m·n`,
    /// `out.len() == rows·n`, `p0 + rows <= k`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tn_serial_avx2(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        p0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        for i in 0..m {
            let brow = &b[i * n..(i + 1) * n];
            for rr in 0..rows {
                let av = a[i * k + p0 + rr];
                axpy_avx2(av, brow, &mut out[rr * n..(rr + 1) * n]);
            }
        }
    }
}

/// NEON bodies (aarch64). Coverage is the f32/int8 decode-path
/// primitives plus the RoPE rotation; the i4 family and the GEMM tiles
/// fall back to the (striped) scalar references — same bits, narrower
/// speedup. Lane assignment uses two 4-lane accumulators side by side
/// so the 8-wide striping contract is preserved exactly.
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use super::{fold_lanes, LANES};
    use core::arch::aarch64::*;

    /// Striped f32 dot product (lane `i % 8` across two q-registers).
    ///
    /// # Safety
    /// NEON required (guaranteed by the [`super::active_isa`] dispatch);
    /// `a.len() == b.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut c = 0;
        while c < main {
            let a0 = vld1q_f32(a.as_ptr().add(c));
            let a1 = vld1q_f32(a.as_ptr().add(c + 4));
            let b0 = vld1q_f32(b.as_ptr().add(c));
            let b1 = vld1q_f32(b.as_ptr().add(c + 4));
            acc0 = vaddq_f32(acc0, vmulq_f32(a0, b0));
            acc1 = vaddq_f32(acc1, vmulq_f32(a1, b1));
            c += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        for i in main..n {
            lanes[i - main] += a[i] * b[i];
        }
        fold_lanes(&lanes)
    }

    /// Striped int8 dot product (widen i8→i32→f32, then the same
    /// mul-rounding sequence as the scalar reference).
    ///
    /// # Safety
    /// NEON required; `a.len() == q.len() == scale.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8_neon(a: &[f32], q: &[i8], scale: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut c = 0;
        while c < main {
            let q8 = vld1_s8(q.as_ptr().add(c));
            let q16 = vmovl_s8(q8);
            let qf0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16)));
            let qf1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16)));
            let deq0 = vmulq_f32(qf0, vld1q_f32(scale.as_ptr().add(c)));
            let deq1 = vmulq_f32(qf1, vld1q_f32(scale.as_ptr().add(c + 4)));
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(a.as_ptr().add(c)), deq0));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(a.as_ptr().add(c + 4)), deq1));
            c += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        for i in main..n {
            lanes[i - main] += a[i] * (q[i] as f32 * scale[i]);
        }
        fold_lanes(&lanes)
    }

    /// Elementwise `y[i] += alpha · x[i]`.
    ///
    /// # Safety
    /// NEON required; `x.len() == y.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let main = n - n % 4;
        let va = vdupq_n_f32(alpha);
        let mut c = 0;
        while c < main {
            let vx = vld1q_f32(x.as_ptr().add(c));
            let vy = vld1q_f32(y.as_ptr().add(c));
            vst1q_f32(y.as_mut_ptr().add(c), vaddq_f32(vy, vmulq_f32(va, vx)));
            c += 4;
        }
        for i in main..n {
            y[i] += alpha * x[i];
        }
    }

    /// Elementwise `y[i] += alpha · (q[i]·scale[i])`.
    ///
    /// # Safety
    /// NEON required; `q.len() == scale.len() == y.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_i8_neon(alpha: f32, q: &[i8], scale: &[f32], y: &mut [f32]) {
        let n = y.len();
        let main = n - n % LANES;
        let va = vdupq_n_f32(alpha);
        let mut c = 0;
        while c < main {
            let q8 = vld1_s8(q.as_ptr().add(c));
            let q16 = vmovl_s8(q8);
            let qf0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16)));
            let qf1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16)));
            let deq0 = vmulq_f32(qf0, vld1q_f32(scale.as_ptr().add(c)));
            let deq1 = vmulq_f32(qf1, vld1q_f32(scale.as_ptr().add(c + 4)));
            let y0 = vld1q_f32(y.as_ptr().add(c));
            let y1 = vld1q_f32(y.as_ptr().add(c + 4));
            vst1q_f32(y.as_mut_ptr().add(c), vaddq_f32(y0, vmulq_f32(va, deq0)));
            vst1q_f32(y.as_mut_ptr().add(c + 4), vaddq_f32(y1, vmulq_f32(va, deq1)));
            c += LANES;
        }
        for i in main..n {
            y[i] += alpha * (q[i] as f32 * scale[i]);
        }
    }

    /// Elementwise RoPE pair rotation (see [`super::rotate_pairs`]).
    ///
    /// # Safety
    /// NEON required; all four slices must have equal length.
    #[target_feature(enable = "neon")]
    pub unsafe fn rotate_pairs_neon(lo: &mut [f32], hi: &mut [f32], cos: &[f32], sin: &[f32]) {
        let n = lo.len();
        let main = n - n % 4;
        let mut c = 0;
        while c < main {
            let a = vld1q_f32(lo.as_ptr().add(c));
            let b = vld1q_f32(hi.as_ptr().add(c));
            let vc = vld1q_f32(cos.as_ptr().add(c));
            let vs = vld1q_f32(sin.as_ptr().add(c));
            let nl = vsubq_f32(vmulq_f32(a, vc), vmulq_f32(b, vs));
            let nh = vaddq_f32(vmulq_f32(a, vs), vmulq_f32(b, vc));
            vst1q_f32(lo.as_mut_ptr().add(c), nl);
            vst1q_f32(hi.as_mut_ptr().add(c), nh);
            c += 4;
        }
        if main < n {
            super::rotate_pairs_scalar(&mut lo[main..], &mut hi[main..], &cos[main..], &sin[main..]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_accepts_and_rejects() {
        assert_eq!(SimdMode::parse("auto").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse(" ON ").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse("off").unwrap(), SimdMode::Off);
        assert_eq!(SimdMode::parse("Scalar").unwrap(), SimdMode::Off);
        assert!(SimdMode::parse("avx512").is_err());
        assert!(SimdMode::parse("").is_err());
    }

    #[test]
    fn mode_env_value_defaults_and_fails_loudly() {
        assert_eq!(SimdMode::parse_env_value(None).unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse_env_value(Some("")).unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse_env_value(Some("  ")).unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse_env_value(Some("off")).unwrap(), SimdMode::Off);
        assert!(SimdMode::parse_env_value(Some("fast")).is_err());
    }

    #[test]
    fn resolve_prefers_flag_over_default() {
        let args = crate::util::cli::Args::parse_from(vec!["--simd".to_string(), "off".to_string()]);
        assert_eq!(SimdMode::resolve(&args).unwrap(), SimdMode::Off);
        let bad = crate::util::cli::Args::parse_from(vec!["--simd".to_string(), "wat".to_string()]);
        assert!(SimdMode::resolve(&bad).is_err());
    }

    #[test]
    fn off_forces_scalar_and_auto_detects() {
        let _g = crate::kernels::TEST_THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let before = active_isa();
        assert_eq!(set_simd_mode(SimdMode::Off), Isa::Scalar);
        assert_eq!(active_isa(), Isa::Scalar);
        assert_eq!(isa_name(), "scalar");
        let auto = set_simd_mode(SimdMode::Auto);
        #[cfg(target_arch = "x86_64")]
        if std::is_x86_feature_detected!("avx2") {
            assert_eq!(auto, Isa::Avx2);
        }
        assert_eq!(active_isa(), auto);
        // Restore whatever the surrounding tests were running under.
        ACTIVE.store(before as u8, Ordering::Relaxed);
    }
}
