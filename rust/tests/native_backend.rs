//! Losslessness of the Block-attention serving path on the hermetic
//! [`NativeBackend`] — the paper's central claims, executable with no
//! artifacts:
//!
//! * single-block Block-attention prefill equals full-attention prefill
//!   (with one block the attention patterns coincide, and RoPE
//!   re-encoding by Δ=0 is the identity);
//! * `BlockNoReencode` (the w/o-pos ablation / PromptCache-like mode)
//!   measurably diverges once a block sits at a non-zero offset;
//! * block fine-tuning on the native backward pass actually reduces the
//!   loss.

use block_attn::config::ModelConfig;
use block_attn::coordinator::{write_ctx, AttentionMode, Coordinator, Request};
use block_attn::runtime::NativeBackend;
use block_attn::tensor::Tensor;
use block_attn::util::rng::Rng;
use block_attn::Backend;

/// Pinned to the f32 cache tier: these tests assert *bit-exact*
/// losslessness of the serving path, which the int8 tier intentionally
/// trades away (its own contract — cosine ≥ 0.999 — lives in
/// `tests/kv_quant.rs`). Pinning keeps them meaningful when the suite
/// runs under `BLOCK_ATTN_KV_QUANT=int8`.
fn coordinator() -> Coordinator<NativeBackend> {
    Coordinator::with_kv_precision(
        NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C),
        64 << 20,
        block_attn::config::KvPrecision::F32,
    )
}

fn rand_tokens(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(250) as i32).collect()
}

fn req(id: u64, blocks: Vec<Vec<i32>>, query: Vec<i32>, mode: AttentionMode) -> Request {
    Request { id, blocks, query, max_new_tokens: 6, mode }
}

/// Serving-level losslessness: with a single context block, Full and
/// Block modes must emit identical tokens (greedy decode over equal
/// logits) — through the whole pipeline including the cache and the
/// Δ=0 re-encode.
#[test]
fn single_block_block_mode_equals_full_mode() {
    let mut rng = Rng::new(41);
    let block = rand_tokens(&mut rng, 48);
    let query = rand_tokens(&mut rng, 24);

    let mut coord = coordinator();
    let full = coord
        .process(&req(1, vec![block.clone()], query.clone(), AttentionMode::Full))
        .unwrap();
    // Fresh coordinator: no cache interference between the runs.
    let mut coord = coordinator();
    let block_mode = coord
        .process(&req(2, vec![block], query, AttentionMode::Block))
        .unwrap();
    assert_eq!(
        full.tokens, block_mode.tokens,
        "single-block Block-attention must be lossless vs full attention"
    );
}

/// The w/o-pos ablation: skipping Eq.-3 re-encoding leaves the second
/// block's keys at local positions 0..L, which must measurably change
/// the logits (that is exactly the degradation Table 1's
/// `w/o-pos`/PromptCache rows quantify).
#[test]
fn no_reencode_measurably_diverges_with_two_blocks() {
    let mut rng = Rng::new(43);
    let b1 = rand_tokens(&mut rng, 40);
    let b2 = rand_tokens(&mut rng, 40);
    let query = rand_tokens(&mut rng, 20);

    // Engine-level comparison so we can look at raw logits.
    let eng = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C);
    let cfg = eng.config().clone();
    let rope = block_attn::rope::RopeTable::new(cfg.head_dim, cfg.rope_theta);

    let (k1, v1) = eng.prefill_block(&b1).unwrap();
    let (k2, v2) = eng.prefill_block(&b2).unwrap();
    let ctx_len = 80;
    let assemble = |reencode: bool| {
        let mut past_k = eng.kv_zeros(ctx_len);
        let mut past_v = eng.kv_zeros(ctx_len);
        let mut k1 = k1.clone();
        let mut k2 = k2.clone();
        if reencode {
            rope.reencode_block(k1.data_mut(), cfg.layers, 40, cfg.kv_heads, 0);
            rope.reencode_block(k2.data_mut(), cfg.layers, 40, cfg.kv_heads, 40);
        }
        write_ctx(&mut past_k, &k1, 0);
        write_ctx(&mut past_v, &v1, 0);
        write_ctx(&mut past_k, &k2, 40);
        write_ctx(&mut past_v, &v2, 40);
        eng.prefill_final(&query, &past_k, &past_v, ctx_len)
            .unwrap()
            .last_logits
    };
    let with_pos = assemble(true);
    let without_pos = assemble(false);
    let mut diff = 0.0f32;
    for (a, b) in with_pos.iter().zip(&without_pos) {
        diff = diff.max((a - b).abs());
    }
    assert!(
        diff > 1e-3,
        "w/o-pos ablation did not diverge (max logit diff {diff})"
    );

    // And the serving pipeline exposes the same contrast.
    let mut coord = coordinator();
    let a = coord
        .process(&req(
            1,
            vec![b1.clone(), b2.clone()],
            query.clone(),
            AttentionMode::Block,
        ))
        .unwrap();
    let mut coord = coordinator();
    let b = coord
        .process(&req(2, vec![b1, b2], query, AttentionMode::BlockNoReencode))
        .unwrap();
    assert_eq!(a.total_blocks, b.total_blocks);
    // Identical bookkeeping, different numerics — tokens usually differ;
    // at minimum the modes must not be the same computation, which the
    // logit check above already pinned down.
    let _ = (a.tokens, b.tokens);
}

/// Multi-block Block mode vs Full mode: different attention patterns
/// (the untrained w/o-ft gap) — the serving path must not silently fall
/// back to one or the other.
#[test]
fn two_block_modes_are_distinct_computations() {
    let mut rng = Rng::new(47);
    let b1 = rand_tokens(&mut rng, 32);
    let b2 = rand_tokens(&mut rng, 32);
    let query = rand_tokens(&mut rng, 16);
    let eng = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C);

    let mut all = b1.clone();
    all.extend_from_slice(&b2);
    all.extend_from_slice(&query);
    let full = eng.prefill_full(&all).unwrap().last_logits;

    let cfg = eng.config().clone();
    let rope = block_attn::rope::RopeTable::new(cfg.head_dim, cfg.rope_theta);
    let (mut k1, v1) = eng.prefill_block(&b1).unwrap();
    let (mut k2, v2) = eng.prefill_block(&b2).unwrap();
    rope.reencode_block(k1.data_mut(), cfg.layers, 32, cfg.kv_heads, 0);
    rope.reencode_block(k2.data_mut(), cfg.layers, 32, cfg.kv_heads, 32);
    let mut past_k = eng.kv_zeros(64);
    let mut past_v = eng.kv_zeros(64);
    write_ctx(&mut past_k, &k1, 0);
    write_ctx(&mut past_v, &v1, 0);
    write_ctx(&mut past_k, &k2, 32);
    write_ctx(&mut past_v, &v2, 32);
    let blk = eng
        .prefill_final(&query, &past_k, &past_v, 64)
        .unwrap()
        .last_logits;

    let mut diff = 0.0f32;
    for (a, b) in full.iter().zip(&blk) {
        diff = diff.max((a - b).abs());
    }
    assert!(diff > 1e-4, "block-diagonal masking had no effect on 2 blocks");
}

/// Block fine-tuning end to end on the native backward pass: the loss
/// on a low-entropy stream must drop, and it must drop in *both* halves
/// of the dual-mode schedule.
#[test]
fn native_train_step_reduces_loss() {
    let eng = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 1).with_train_shape(2, 48);
    let (b, l) = eng.train_shape().unwrap();
    // Low-entropy repeating data: loss must drop fast.
    let toks: Vec<i32> = (0..b * l).map(|i| ((i % 7) + 1) as i32).collect();
    let tokens = Tensor::from_vec(&[b, l], toks);
    let full_seg = Tensor::from_vec(&[b, l], vec![0i32; b * l]);
    // Two context segments + final segment, mirroring a packed sample.
    let seg_row: Vec<i32> = (0..l)
        .map(|t| if t < l / 3 { 0 } else if t < 2 * l / 3 { 1 } else { 2 })
        .collect();
    let block_seg = Tensor::from_vec(&[b, l], seg_row.repeat(b));
    let mask = Tensor::from_vec(&[b, l], vec![1.0f32; b * l]);

    let mut losses = Vec::new();
    for step in 0..6 {
        // Dual-mode alternation: even steps full mask, odd steps block.
        let seg = if step % 2 == 0 { &full_seg } else { &block_seg };
        let out = eng.train_step(step, 5e-3, &tokens, seg, &mask).unwrap();
        assert!(out.loss.is_finite());
        losses.push(out.loss);
    }
    assert!(
        losses[4].min(losses[5]) < losses[0] - 0.3,
        "loss did not drop: {losses:?}"
    );
}
