//! Figure 4 reproduction: RAG accuracy of the two attention modes as a
//! function of block-fine-tune steps. The series is recorded during
//! `make checkpoints` (the dual-mode run evaluates both modes every N
//! steps into `checkpoints/fig4.json`); this bench renders it and checks
//! the paper's shape: a large early gap that closes with training.
//!
//! ```sh
//! cargo bench --bench fig4_steps
//! ```

use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    let path = PathBuf::from(args.str_or("checkpoints", "checkpoints")).join("fig4.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("missing {path:?} — run `make checkpoints` first");
        std::process::exit(0);
    };
    let series = Json::parse(&text)?;
    let points = series.as_arr().unwrap_or(&[]);
    if points.is_empty() {
        eprintln!("empty fig4 series");
        std::process::exit(0);
    }

    println!("# Figure 4 — both attention modes vs block fine-tune step (dual-mode training)");
    println!("# acc = exact-match; nll = teacher-forced answer NLL (the resolvable signal");
    println!("# at tiny-model scale — see EXPERIMENTS.md §Figure 4).");
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>10} {:>9}",
        "step", "block-acc", "full-acc", "block-nll", "full-nll", "nll-gap"
    );
    let mut rows = Vec::new();
    for p in points {
        let step = p.get("step").as_f64().unwrap_or(0.0);
        let b = p.get("block_acc").as_f64().unwrap_or(f64::NAN);
        let f = p.get("full_acc").as_f64().unwrap_or(f64::NAN);
        let bn = p.get("block_nll").as_f64().unwrap_or(f64::NAN);
        let fnl = p.get("full_nll").as_f64().unwrap_or(f64::NAN);
        println!(
            "{:>6} {:>9.1}% {:>9.1}% {:>10.3} {:>10.3} {:>9.3}",
            step,
            b * 100.0,
            f * 100.0,
            bn,
            fnl,
            bn - fnl,
        );
        rows.push((step, b, f));
    }

    // ASCII plot.
    println!("\n  accuracy  (B = block mode, F = full mode)");
    for level in (0..=10).rev() {
        let y = level as f64 / 10.0;
        let mut line = format!("{:>4.0}% |", y * 100.0);
        for (_, b, f) in &rows {
            let cb = (b * 10.0).round() as i64 == level;
            let cf = (f * 10.0).round() as i64 == level;
            line.push(match (cb, cf) {
                (true, true) => '*',
                (true, false) => 'B',
                (false, true) => 'F',
                _ => ' ',
            });
            line.push(' ');
        }
        println!("{line}");
    }
    println!("      +{}", "--".repeat(rows.len()));
    let steps: Vec<String> = rows.iter().map(|(s, _, _)| format!("{s:.0}")).collect();
    println!("       {}", steps.join(" "));

    // Paper-shape checks (§3.5 conclusion 4 / Figure 4).
    let (first, last) = (rows.first().unwrap(), rows.last().unwrap());
    let early_gap = first.2 - first.1;
    let late_gap = (last.2 - last.1).abs();
    println!(
        "\n# early gap {:.1} pts → final gap {:.1} pts (paper: gap vanishes by ~800 steps)",
        early_gap * 100.0,
        late_gap * 100.0
    );
    println!(
        "# block-mode accuracy {:.1}% → {:.1}% over training",
        first.1 * 100.0,
        last.1 * 100.0
    );
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}
