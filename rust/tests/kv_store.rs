//! Persistent disk tier, end to end: spill → restart → promote must be
//! bitwise invisible to serving at every KV precision and thread count,
//! and every corrupt store file must be rejected loudly and recomputed
//! (never served). The on-disk layout under test is normative in
//! `docs/kvstore-format.md`; the corruption cases below flip bytes at
//! the offsets that document defines.
//!
//! The restart-reuse test honors `$BLOCK_ATTN_KV_STORE_DIR` so the CI
//! leg that runs the suite twice against one directory observes
//! cross-process reuse: the second run's first request must report
//! disk hits before this process has spilled anything.

use block_attn::config::{KvPrecision, KvStoreConfig, ModelConfig};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::kernels::set_threads;
use block_attn::kvcache::disk::DiskStore;
use block_attn::kvcache::store::{CHECKSUM_OFFSET, HEADER_LEN, VERSION_OFFSET};
use block_attn::kvcache::{block_key, BlockKvCache};
use block_attn::rope::RopeTable;
use block_attn::runtime::NativeBackend;
use block_attn::util::rng::Rng;
use block_attn::Backend;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Tests here flip the process-global kernel thread budget; serialize
/// so concurrent tests can't mask thread-count differences.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab: 24,
        d_model: 16,
        layers: 2,
        heads: 2,
        kv_heads: 1,
        head_dim: 8,
        d_ff: 32,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        max_len: 256,
    }
}

/// Fresh per-test scratch store directory (wiped on entry; tests also
/// clean up on success, but a panic must not poison the next run).
fn store_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("block-attn-test-kvstore-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn coordinator(precision: KvPrecision) -> Coordinator<NativeBackend> {
    let engine = NativeBackend::new(micro_config(), 0xD15C);
    Coordinator::with_kv_precision(engine, 64 << 20, precision)
}

/// Deterministic request stream with shared and fresh blocks (same
/// token content in every process, so store keys reproduce across
/// restarts).
fn request_stream() -> Vec<Request> {
    let mut rng = Rng::new(42);
    let mut block = |len: usize| -> Vec<i32> {
        (0..len).map(|_| rng.below(24) as i32).collect()
    };
    let shared = block(10);
    (0..3)
        .map(|i| Request {
            id: i as u64,
            blocks: match i {
                0 => vec![shared.clone(), block(9)],
                1 => vec![shared.clone(), block(12), block(5)],
                _ => vec![block(7)],
            },
            query: block(8),
            max_new_tokens: 5,
            mode: AttentionMode::Block,
        })
        .collect()
}

fn serve_stream(coord: &mut Coordinator<NativeBackend>) -> Vec<(Vec<i32>, usize, usize)> {
    request_stream()
        .iter()
        .map(|req| {
            let resp = coord.process(req).expect("process");
            (resp.tokens.clone(), resp.cached_blocks, resp.total_blocks)
        })
        .collect()
}

/// The tentpole parity sweep: at every KV tier and thread budget, a
/// warm pass served from **disk-promoted** blocks is byte-identical to
/// a warm pass served from never-evicted RAM-resident blocks.
#[test]
fn disk_promoted_serving_is_bitwise_identical_across_tiers_and_threads() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    for precision in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
        let mut per_thread = Vec::new();
        for &threads in &[1usize, 3, 8] {
            set_threads(threads);
            // Reference: pass 2 over a RAM-resident cache.
            let mut ram = coordinator(precision);
            serve_stream(&mut ram);
            let ram_warm = serve_stream(&mut ram);

            // Store path: populate, spill everything, drop residency,
            // then pass 2 is served entirely via disk promotion.
            let dir = store_dir(&format!("sweep-{precision:?}-{threads}"));
            let mut disk = coordinator(precision);
            disk.attach_kv_store(&KvStoreConfig { dir: dir.clone(), budget_bytes: 0 })
                .expect("attach");
            serve_stream(&mut disk);
            assert!(disk.flush_kv_store() > 0, "nothing spilled");
            assert!(disk.drop_resident_blocks() > 0, "nothing resident to drop");
            let disk_warm = serve_stream(&mut disk);

            assert_eq!(
                ram_warm, disk_warm,
                "{precision:?}/{threads}t: disk-promoted pass differs from RAM-warm pass"
            );
            let s = disk.cache_stats();
            assert!(s.disk_hits > 0, "{precision:?}/{threads}t: no disk promotions");
            assert_eq!(s.disk_errors, 0, "{precision:?}/{threads}t: disk errors");
            per_thread.push(disk_warm);
            let _ = std::fs::remove_dir_all(&dir);
        }
        assert!(
            per_thread.windows(2).all(|w| w[0] == w[1]),
            "{precision:?}: disk-warm serving depends on the thread count"
        );
    }
    set_threads(prev);
}

/// Restart reuse: a fresh coordinator (fresh process, in the CI leg
/// that points `$BLOCK_ATTN_KV_STORE_DIR` at one directory across two
/// `cargo test` invocations) serving the same stream over a populated
/// store computes **zero** block prefills — every context block is a
/// disk hit.
#[test]
fn populated_store_serves_a_fresh_process_with_disk_hits() {
    let env_cfg = KvStoreConfig::from_env().expect("valid $BLOCK_ATTN_KV_STORE_* settings");
    let (dir, scratch) = match &env_cfg {
        Some(c) => (c.dir.clone(), false),
        None => (store_dir("restart"), true),
    };
    let precision = KvPrecision::from_env();
    let cfg = KvStoreConfig { dir: dir.clone(), budget_bytes: 0 };

    // Did a previous process already encode this stream's first block?
    let mut coord_a = coordinator(precision);
    let fp = block_attn::kvcache::store::weights_fingerprint(
        coord_a.engine().config(),
        &coord_a.engine().params_host().expect("params"),
    );
    let first_key = block_key(&request_stream()[0].blocks[0]);
    let pre_populated =
        DiskStore::open(&dir, fp, 0).expect("open store").contains(first_key);

    let run_a = serve_stream(&mut coord_a);
    let stats_a = coord_a.cache_stats();
    assert_eq!(
        stats_a.disk_hits > 0,
        pre_populated,
        "run A must promote from disk iff the store was pre-populated (restart reuse)"
    );
    assert!(coord_a.flush_kv_store() > 0 || pre_populated);

    // "Restart": a brand-new coordinator over the now-populated store.
    let mut coord_b = coordinator(precision);
    coord_b.attach_kv_store(&cfg).expect("attach");
    let mut total_blocks = 0;
    for req in &request_stream() {
        let resp = coord_b.process(req).expect("process");
        assert_eq!(
            resp.cached_blocks, resp.total_blocks,
            "request {}: fresh process missed a stored block",
            req.id
        );
        assert_eq!(
            resp.block_prefill_s, 0.0,
            "request {}: fresh process recomputed block KV despite the store",
            req.id
        );
        total_blocks += resp.total_blocks;
    }
    let stats_b = coord_b.cache_stats();
    assert!(stats_b.disk_hits > 0, "fresh process reported no disk hits");
    assert_eq!(stats_b.disk_errors, 0);
    assert!(total_blocks > 0);
    // Promotion must also reproduce run A's generations exactly.
    let mut coord_c = coordinator(precision);
    coord_c.attach_kv_store(&cfg).expect("attach");
    assert_eq!(serve_stream(&mut coord_c), run_a);
    if scratch {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

fn bakv_files(dir: &Path) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("read store dir")
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().map(|e| e == "bakv").unwrap_or(false))
        .collect();
    files.sort();
    files
}

/// Crash safety: every damaged file class is rejected at promotion
/// time (loudly, with the file quarantined) and the block recomputed —
/// the served tokens never change. Offsets per `docs/kvstore-format.md`:
/// magic at 0, version u16 at 4, weights fingerprint at 24, payload
/// checksum u64 at 56, payload from 64.
#[test]
fn corrupt_store_files_are_rejected_and_recomputed() {
    let dir = store_dir("corrupt");
    let cfg = KvStoreConfig { dir: dir.clone(), budget_bytes: 0 };
    let mut coord = coordinator(KvPrecision::Int8);
    coord.attach_kv_store(&cfg).expect("attach");
    let reference = serve_stream(&mut coord);
    assert!(coord.flush_kv_store() > 0);

    let files = bakv_files(&dir);
    assert!(!files.is_empty());
    let victim = files[0].clone();
    let pristine = std::fs::read(&victim).expect("read pristine file");
    assert!(pristine.len() > HEADER_LEN);

    // (name, corrupted bytes) — each must trip a distinct decode check.
    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("truncated header", pristine[..10].to_vec()),
        ("truncated payload", pristine[..pristine.len() - 7].to_vec()),
        ("bad magic", {
            let mut b = pristine.clone();
            b[0] ^= 0xFF;
            b
        }),
        ("wrong version", {
            let mut b = pristine.clone();
            b[VERSION_OFFSET] = 0xFF;
            b
        }),
        ("fingerprint mismatch", {
            let mut b = pristine.clone();
            b[24] ^= 0xFF;
            b
        }),
        ("checksum mismatch", {
            let mut b = pristine.clone();
            b[CHECKSUM_OFFSET] ^= 0x01;
            b
        }),
        ("payload bit flip", {
            let mut b = pristine.clone();
            let n = b.len();
            b[n - 1] ^= 0x10;
            b
        }),
    ];

    let mut errors_seen = coord.cache_stats().disk_errors;
    for (name, bytes) in cases {
        std::fs::write(&victim, &bytes).expect("plant corrupt file");
        assert!(coord.drop_resident_blocks() > 0);
        let served = serve_stream(&mut coord);
        assert_eq!(served, reference, "case '{name}': corrupt file changed the output");
        let s = coord.cache_stats();
        assert!(
            s.disk_errors > errors_seen,
            "case '{name}': corruption was not counted as a disk error"
        );
        errors_seen = s.disk_errors;
        assert!(
            !victim.exists(),
            "case '{name}': corrupt file was not quarantined (deleted)"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A read-only store directory must degrade loudly (spill errors
/// counted) without affecting serving output.
#[cfg(unix)]
#[test]
fn read_only_store_dir_degrades_loudly_not_wrongly() {
    use std::os::unix::fs::PermissionsExt;
    let dir = store_dir("readonly");
    std::fs::create_dir_all(&dir).expect("create dir");
    std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o555))
        .expect("chmod store dir");
    // Privileged processes (root CI containers) ignore mode bits; the
    // test is only meaningful when writes actually fail.
    if std::fs::write(dir.join("probe"), b"x").is_ok() {
        let _ = std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o755));
        let _ = std::fs::remove_dir_all(&dir);
        eprintln!("skipping read-only-dir assertions: process can write anyway");
        return;
    }

    let mut plain = coordinator(KvPrecision::F32);
    let want = serve_stream(&mut plain);

    let mut coord = coordinator(KvPrecision::F32);
    coord
        .attach_kv_store(&KvStoreConfig { dir: dir.clone(), budget_bytes: 0 })
        .expect("attach to read-only dir");
    let got = serve_stream(&mut coord);
    assert_eq!(got, want, "read-only store dir changed the served output");
    let spilled = coord.flush_kv_store();
    assert_eq!(spilled, 0, "spill into a read-only directory reported success");
    let s = coord.cache_stats();
    assert!(s.disk_errors > 0, "failed spills were not counted as disk errors");
    let _ = std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o755));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two caches over one directory, spilling and promoting concurrently:
/// the atomic publish (tmp + rename) means a reader never observes a
/// partial file, and every promoted block is bitwise identical to the
/// single-threaded reference.
#[test]
fn concurrent_spill_and_promote_share_one_directory() {
    const FP: u64 = 0xF1;
    let cfg = micro_config();
    let dir = store_dir("concurrent");
    std::fs::create_dir_all(&dir).expect("create dir");
    let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);

    let mut rng = Rng::new(7);
    let blocks: Vec<Vec<i32>> = (0..6)
        .map(|i| (0..(4 + i)).map(|_| rng.below(24) as i32).collect())
        .collect();

    // Single-threaded reference fetch (delta 5) per block.
    let engine = NativeBackend::new(cfg.clone(), 0xBEE);
    let mut reference = Vec::new();
    {
        let mut cache = BlockKvCache::with_precision(rope.clone(), 0, KvPrecision::Int4);
        for b in &blocks {
            let (k, v) = engine.prefill_block(b).expect("prefill");
            let key = block_key(b);
            cache.insert_pinned(key, k, v);
            cache.unpin(key);
        }
        for b in &blocks {
            let r = cache.get_reencoded(block_key(b), 5).expect("reference fetch");
            reference.push((r.k.clone(), r.v.clone(), r.len));
        }
    }

    let barrier = std::sync::Barrier::new(2);
    let (dir_ref, cfg_ref, rope_ref, blocks_ref, reference_ref) =
        (&dir, &cfg, &rope, &blocks, &reference);
    let results: Vec<(u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2usize)
            .map(|who| {
                let barrier = &barrier;
                s.spawn(move || {
                    let engine = NativeBackend::new(cfg_ref.clone(), 0xBEE);
                    let mut cache =
                        BlockKvCache::with_precision(rope_ref.clone(), 0, KvPrecision::Int4);
                    cache.attach_store(
                        DiskStore::open(dir_ref, FP, 0).expect("open shared store"),
                    );
                    // Raceful phase: spill this thread's half, then
                    // immediately poll the full keyspace while the
                    // other thread is still spilling its half. The
                    // tmp+rename publish means a concurrent fetch sees
                    // either nothing (a clean miss) or a complete file
                    // — never a partial one.
                    for b in blocks_ref.iter().skip(who).step_by(2) {
                        let key = block_key(b);
                        let (k, v) = engine.prefill_block(b).expect("prefill");
                        cache.insert_pinned(key, k, v);
                        cache.unpin(key);
                    }
                    cache.spill_all();
                    cache.drop_resident();
                    for (b, (want_k, want_v, want_len)) in
                        blocks_ref.iter().zip(reference_ref)
                    {
                        let key = block_key(b);
                        if cache.lookup_pin(key) {
                            let got = cache.get_reencoded(key, 5).expect("fetch");
                            assert_eq!(&got.k, want_k, "thread {who}: K diverged (race)");
                            assert_eq!(&got.v, want_v, "thread {who}: V diverged (race)");
                            assert_eq!(got.len, *want_len);
                            cache.unpin(key);
                        }
                    }
                    barrier.wait();
                    // Deterministic phase: everything is published now;
                    // all six blocks must promote and match bitwise.
                    cache.drop_resident();
                    for (b, (want_k, want_v, want_len)) in
                        blocks_ref.iter().zip(reference_ref)
                    {
                        let key = block_key(b);
                        assert!(cache.lookup_pin(key), "thread {who}: lost block");
                        let got = cache.get_reencoded(key, 5).expect("fetch");
                        assert_eq!(&got.k, want_k, "thread {who}: K diverged");
                        assert_eq!(&got.v, want_v, "thread {who}: V diverged");
                        assert_eq!(got.len, *want_len);
                        cache.unpin(key);
                    }
                    let st = cache.stats();
                    (st.disk_hits, st.disk_errors)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    for (who, (hits, errors)) in results.iter().enumerate() {
        assert!(*hits >= blocks.len() as u64, "thread {who}: too few promotions ({hits})");
        assert_eq!(*errors, 0, "thread {who}: disk errors under concurrency");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
