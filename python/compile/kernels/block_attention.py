"""L1 Pallas kernels: the attention hot paths of Block-Attention.

Two kernels implement the paper's two prefill shapes:

* :func:`flash_block_attention` — independent (block-diagonal) prefill of
  one block: causal attention restricted to the block itself. In the
  serving stack each retrieved passage runs through this kernel once and
  its KV states are cached (paper §2.1).
* :func:`flash_context_attention` — the final block's attention: queries
  attend to the full (re-encoded) cached context plus causally to the
  block itself (paper §2.5, Figure 2).

Hardware adaptation (GPU paper → TPU kernel, DESIGN.md §Hardware-
Adaptation): instead of FlashAttention's warp-level tiling into SRAM, the
grid is (q-head, q-tile); Q/K/V tiles are staged into VMEM by `BlockSpec`
index maps, the online-softmax state lives in the `fori_loop` carry, and
the inner contraction is an MXU-shaped `(TILE_Q × d) @ (d × TILE_K)`
matmul. GQA is expressed in the K/V index maps (`h // kv_repeat`) so
grouped heads share the same VMEM tile instead of materializing repeats.
The block-diagonal mask of Figure 1 costs zero FLOPs: independence is in
the *grid*, not in a mask tensor.

Kernels are lowered with ``interpret=True`` — mandatory for the CPU PJRT
runtime (real-TPU lowering emits Mosaic custom-calls the CPU plugin
cannot execute). Correctness is pinned against ``ref.py`` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_Q = 64
DEFAULT_TILE_K = 64
NEG_INF = -1e30


def _flash_body(q, k_ref, v_ref, row0, n_kv_tiles, tile_k, mask_fn):
    """Shared online-softmax loop over KV tiles.

    q: (TQ, d) f32 tile already loaded.
    mask_fn(rows, cols) -> bool (TQ, TK) given absolute row/col indices.
    Returns the attention output tile (TQ, d) f32.
    """
    tq, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def body(i, carry):
        acc, m, l = carry
        k = k_ref[pl.dslice(i * tile_k, tile_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(i * tile_k, tile_k), :].astype(jnp.float32)
        s = (q @ k.T) * scale  # (TQ, TK) — MXU-shaped contraction
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (tq, tile_k), 0)
        cols = i * tile_k + jax.lax.broadcasted_iota(jnp.int32, (tq, tile_k), 1)
        s = jnp.where(mask_fn(rows, cols), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    acc = jnp.zeros((tq, d), jnp.float32)
    m0 = jnp.full((tq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((tq,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv_tiles, body, (acc, m0, l0))
    return acc / jnp.maximum(l, 1e-30)[:, None]


def _block_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, tile_k):
    q = q_ref[...].astype(jnp.float32)  # (TQ, d)
    tq = q.shape[0]
    L = k_ref.shape[0]
    qi = pl.program_id(1)
    n = len_ref[0]
    row0 = qi * tq

    def mask(rows, cols):
        return (cols <= rows) & (cols < n)

    o_ref[...] = _flash_body(q, k_ref, v_ref, row0, L // tile_k, tile_k, mask).astype(
        o_ref.dtype
    )


def flash_block_attention(
    q, k, v, length, *, tile_q=DEFAULT_TILE_Q, tile_k=DEFAULT_TILE_K, interpret=True
):
    """Causal attention within one block (+ valid-length mask).

    q: (Hq, L, d); k, v: (Hkv, L, d) with Hq % Hkv == 0 (GQA);
    length: (1,) i32 — number of valid tokens (the tail is padding).
    Returns (Hq, L, d), same dtype as q.
    """
    Hq, L, d = q.shape
    Hkv = k.shape[0]
    assert Hq % Hkv == 0, (Hq, Hkv)
    assert L % tile_q == 0 and L % tile_k == 0, (L, tile_q, tile_k)
    kv_repeat = Hq // Hkv
    kern = functools.partial(_block_kernel, tile_k=tile_k)
    return pl.pallas_call(
        kern,
        grid=(Hq, L // tile_q),
        in_specs=[
            pl.BlockSpec((None, tile_q, d), lambda h, i: (h, i, 0)),
            # GQA: grouped q heads share the K/V tile via the index map.
            pl.BlockSpec((None, L, d), lambda h, i, r=kv_repeat: (h // r, 0, 0)),
            pl.BlockSpec((None, L, d), lambda h, i, r=kv_repeat: (h // r, 0, 0)),
            pl.BlockSpec((1,), lambda h, i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, tile_q, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((Hq, L, d), q.dtype),
        interpret=interpret,
    )(q, k, v, length)


def _context_kernel(q_ref, k_ref, v_ref, ctxlen_ref, o_ref, *, ctx_capacity, tile_k):
    q = q_ref[...].astype(jnp.float32)  # (Lq, d) — final block is one tile
    Lq = q.shape[0]
    Lk = k_ref.shape[0]
    ctx_len = ctxlen_ref[0]

    def mask(rows, cols):
        in_ctx = cols < ctx_len
        in_self = (cols >= ctx_capacity) & (cols - ctx_capacity <= rows)
        return in_ctx | in_self

    o_ref[...] = _flash_body(q, k_ref, v_ref, 0, Lk // tile_k, tile_k, mask).astype(
        o_ref.dtype
    )


def flash_context_attention(
    q, kv_k, kv_v, ctx_len, *, ctx_capacity, tile_k=DEFAULT_TILE_K, interpret=True
):
    """Final-block attention over cached context + causal self.

    q: (Hq, Lq, d) — the user-query block.
    kv_k, kv_v: (Hkv, ctx_capacity + Lq, d) — re-encoded cached context
        (padded to the static ``ctx_capacity``) concatenated with the
        final block's own K/V.
    ctx_len: (1,) i32 — valid prefix of the context region.
    """
    Hq, Lq, d = q.shape
    Hkv = kv_k.shape[0]
    Lk = kv_k.shape[1]
    assert Lk == ctx_capacity + Lq, (Lk, ctx_capacity, Lq)
    assert Lk % tile_k == 0, (Lk, tile_k)
    kv_repeat = Hq // Hkv
    kern = functools.partial(_context_kernel, ctx_capacity=ctx_capacity, tile_k=tile_k)
    return pl.pallas_call(
        kern,
        grid=(Hq,),
        in_specs=[
            pl.BlockSpec((None, Lq, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, Lk, d), lambda h, r=kv_repeat: (h // r, 0, 0)),
            pl.BlockSpec((None, Lk, d), lambda h, r=kv_repeat: (h // r, 0, 0)),
            pl.BlockSpec((1,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((None, Lq, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Hq, Lq, d), q.dtype),
        interpret=interpret,
    )(q, kv_k, kv_v, ctx_len)


def vmem_bytes(tile_q, tile_k, d, L):
    """Static VMEM footprint estimate per program instance (f32).

    Used by the perf pass to pick tile shapes for the (hypothetical) real
    TPU lowering: q tile + whole-block K/V + accumulator + score tile.
    """
    return 4 * (tile_q * d + 2 * L * d + tile_q * d + tile_q * tile_k + 2 * tile_q)


def mxu_utilization(tile_q, tile_k, d, mxu=128):
    """Fraction of MXU lanes occupied by the inner matmul shapes."""
    occ = lambda n: min(n, mxu) / mxu
    # (TQ × d) @ (d × TK) and (TQ × TK) @ (TK × d)
    qk = occ(tile_q) * occ(d) * occ(tile_k)
    av = occ(tile_q) * occ(tile_k) * occ(d)
    return 0.5 * (qk + av)
