//! A persistent fixed-size thread pool with a shared FIFO queue (tokio
//! replacement for the offline build).
//!
//! Two consumers with very different shapes share this type:
//!
//! * The **server** spawns fire-and-forget connection handlers via
//!   [`ThreadPool::spawn`] / [`ThreadPool::submit`].
//! * The **kernel layer** runs its fork/join parallel regions through
//!   [`ThreadPool::run_scoped`] on one process-global pool, retiring the
//!   per-region `std::thread::scope` spawn/join it used to pay. Workers
//!   are spawned once; a decode-sized parallel region costs a queue
//!   push + condvar wake instead of an OS thread spawn.
//!
//! Design points the tests pin down:
//!
//! * **Panic containment.** Every job runs under `catch_unwind`; a
//!   panicking job never kills a worker, never poisons the queue, and
//!   never leaks the in-flight count — remaining jobs still run and
//!   [`ThreadPool::wait_idle`] still drains. Scoped regions capture the
//!   first panic payload and re-raise it on the submitting thread
//!   *after* the whole region has completed.
//! * **Help-while-wait.** A thread waiting for its scoped region
//!   executes that region's still-queued tasks instead of blocking
//!   (and only those — stealing an unrelated ms-scale job would wedge
//!   a µs-scale region behind it). Every region is therefore
//!   self-sufficient: even with every worker busy, the submitter
//!   drains its own tasks, so regions complete at any worker count and
//!   nested regions cannot deadlock — the bottom of every nesting
//!   chain is a budget-1 leaf that runs inline.
//! * **Loud shutdown.** [`ThreadPool::shutdown`] (also run by `Drop`)
//!   drains the queue, then joins the workers; submitting into a
//!   shut-down pool panics instead of silently dropping the job.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A borrowed task handed to [`ThreadPool::run_scoped`]; may capture
/// non-`'static` references — the region does not return until every
/// task has run.
pub type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

/// A queued job plus the scoped region it belongs to (`None` for
/// fire-and-forget `spawn` jobs). Carrying the region here — instead
/// of wrapping every task in a bookkeeping shim closure — lets a
/// waiting submitter pick out *its own* tasks from the shared FIFO by
/// pointer identity and keeps the per-task dispatch cost to the one
/// `Box` the caller already paid.
struct Queued {
    region: Option<Arc<RegionState>>,
    job: Job,
}

struct Shared {
    queue: Mutex<QueueState>,
    cond: Condvar,
}

impl Shared {
    /// Queue lock, poison-tolerant. Jobs never run under this lock and
    /// the pool's own critical sections are plain bookkeeping that
    /// cannot be left half-done by a panic, so entering a poisoned
    /// mutex is always safe here. This matters for soundness:
    /// [`ThreadPool::run_scoped`]'s completion barrier must be
    /// genuinely no-unwind (its lifetime erasure rests on it), so its
    /// wait loop must not panic on a `PoisonError` some other thread
    /// left behind.
    fn lock(&self) -> std::sync::MutexGuard<'_, QueueState> {
        self.queue.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn wait<'a>(
        &self,
        g: std::sync::MutexGuard<'a, QueueState>,
    ) -> std::sync::MutexGuard<'a, QueueState> {
        self.cond.wait(g).unwrap_or_else(|e| e.into_inner())
    }

    /// Run one dequeued job with the bookkeeping every execution site
    /// (worker loop and help-while-wait loop) must agree on. The caller
    /// has already popped the entry and incremented `in_flight` under
    /// the lock, now released. Contains panics, routes a scoped task's
    /// outcome (panic payload + completion) to its region, settles the
    /// counters, and notifies every waiter (idle workers, `wait_idle`,
    /// region joins).
    fn execute(&self, queued: Queued) {
        let result = catch_unwind(AssertUnwindSafe(queued.job));
        let panicked = result.is_err();
        if let Some(region) = queued.region {
            // Payload stored and `remaining` decremented before the
            // notify below, so a woken waiter observes completion.
            region.complete(result.err());
        }
        let mut q = self.lock();
        q.in_flight -= 1;
        q.jobs_executed += 1;
        q.jobs_panicked += panicked as u64;
        drop(q);
        self.cond.notify_all();
    }
}

struct QueueState {
    jobs: std::collections::VecDeque<Queued>,
    shutdown: bool,
    in_flight: usize,
    /// Jobs fully executed (completed or panicked), all execution sites.
    jobs_executed: u64,
    /// Queued jobs whose closure panicked — fire-and-forget `spawn`
    /// jobs and scoped-region tasks alike, counted uniformly at the
    /// execution sites ([`Shared::execute`]). Contained and counted,
    /// never fatal. A panic in `run_scoped`'s *local* closure is not a
    /// queued job and is re-raised to the caller instead.
    jobs_panicked: u64,
    /// High-water mark of the queue depth (dispatch backlog).
    queue_peak: usize,
}

/// Point-in-time pool counters (serialized into server stats and the
/// bench reports).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub workers: usize,
    pub jobs_executed: u64,
    pub jobs_panicked: u64,
    pub queue_peak: usize,
}

/// Bookkeeping for one scoped region: outstanding tasks plus the first
/// panic payload. Completion is signalled through the pool's condvar.
struct RegionState {
    remaining: AtomicUsize,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl RegionState {
    fn complete(&self, payload: Option<Box<dyn Any + Send>>) {
        if let Some(p) = payload {
            let mut slot = self.panic.lock().unwrap_or_else(|e| e.into_inner());
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        // Release pairs with the Acquire in the region wait loop: once
        // the waiter reads 0, every task's writes are visible.
        self.remaining.fetch_sub(1, Ordering::Release);
    }
}

/// Persistent thread pool. Dropping the pool joins all workers after
/// the queue drains.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                jobs: Default::default(),
                shutdown: false,
                in_flight: 0,
                jobs_executed: 0,
                jobs_panicked: 0,
                queue_peak: 0,
            }),
            cond: Condvar::new(),
        });
        let pool = ThreadPool { shared, workers: Mutex::new(Vec::new()) };
        pool.ensure_workers(threads.max(1));
        pool
    }

    /// Grow the worker set to at least `n` threads (never shrinks —
    /// idle workers just sleep on the condvar; the *budget* arithmetic
    /// in the kernel layer decides how many are actually used).
    /// No-op on a shut-down pool.
    pub fn ensure_workers(&self, n: usize) {
        let mut ws = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        if self.shared.lock().shutdown {
            return;
        }
        while ws.len() < n {
            let shared = self.shared.clone();
            let i = ws.len();
            ws.push(
                thread::Builder::new()
                    .name(format!("block-attn-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker"),
            );
        }
    }

    /// Submit a fire-and-forget job.
    ///
    /// Panics if the pool has been shut down: a job silently dropped on
    /// the floor is a bug at the call site, and the failure must be
    /// loud enough to surface it.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.lock();
        if q.shutdown {
            // Release the guard before panicking: the panic is the
            // API's loud failure, not grounds to poison the mutex for
            // every other pool user (including `Drop`).
            drop(q);
            panic!("ThreadPool::spawn on a shut-down pool");
        }
        q.jobs.push_back(Queued { region: None, job: Box::new(job) });
        q.queue_peak = q.queue_peak.max(q.jobs.len());
        drop(q);
        self.shared.cond.notify_one();
    }

    /// Submit a job and get a handle to its result.
    pub fn submit<T: Send + 'static>(
        &self,
        job: impl FnOnce() -> T + Send + 'static,
    ) -> JobHandle<T> {
        let (tx, rx) = mpsc::channel();
        self.spawn(move || {
            let _ = tx.send(job());
        });
        JobHandle { rx }
    }

    /// Map `f` over `items` on the pool, preserving order.
    pub fn map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(I) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = items
            .into_iter()
            .map(|it| {
                let f = f.clone();
                self.submit(move || f(it))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }

    /// Run a fork/join region: `tasks` are dispatched to the pool,
    /// `local` runs on the calling thread, and the call returns only
    /// when **every** task has finished. While it waits, the calling
    /// thread executes *this region's* still-queued tasks
    /// ("help-while-wait"), so the region completes even with zero
    /// free workers and nested regions cannot deadlock: every queued
    /// task is always runnable by its own submitter. Stealing is
    /// deliberately scoped to the waiter's own region — popping an
    /// unrelated job would wedge a µs-scale region behind a foreign
    /// ms-scale one and nest arbitrary work on this stack.
    ///
    /// Tasks may borrow from the caller's stack (the `'env` lifetime):
    /// the completion barrier is what makes that sound. If `local` or
    /// any task panics, the remaining tasks still run to completion and
    /// the first payload is re-raised here afterwards — a panicking
    /// region never leaves the pool wedged or the queue poisoned.
    pub fn run_scoped<'env>(&self, local: impl FnOnce(), tasks: Vec<ScopedJob<'env>>) {
        if tasks.is_empty() {
            local();
            return;
        }
        let region = Arc::new(RegionState {
            remaining: AtomicUsize::new(tasks.len()),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.lock();
            if q.shutdown {
                // Nothing queued yet; dropping `tasks` un-run is safe,
                // and releasing the guard first keeps the loud failure
                // from poisoning the mutex (see `spawn`).
                drop(q);
                panic!("ThreadPool::run_scoped on a shut-down pool");
            }
            for task in tasks {
                // SAFETY: lifetime erasure. This function does not
                // return (or unwind — the wait below runs even when
                // `local` panics, and uses only poison-tolerant locks,
                // so it cannot itself panic) until `region.remaining`
                // reaches zero, i.e. until every task has run to
                // completion ([`Shared::execute`] decrements it after
                // the task returns or panics), so the `'env` borrows
                // the tasks capture strictly outlive their last use.
                let task: ScopedJob<'static> = unsafe {
                    std::mem::transmute::<ScopedJob<'env>, ScopedJob<'static>>(task)
                };
                q.jobs.push_back(Queued { region: Some(region.clone()), job: task });
            }
            q.queue_peak = q.queue_peak.max(q.jobs.len());
        }
        self.shared.cond.notify_all();

        let local_panic = catch_unwind(AssertUnwindSafe(local)).err();

        // Help-while-wait: run this region's still-queued tasks until
        // it drains (tasks already in flight on workers finish there).
        // The completion signal rides the pool condvar: every execution
        // site notifies after finishing a job.
        let mut q = self.shared.lock();
        while region.remaining.load(Ordering::Acquire) != 0 {
            let mine = q
                .jobs
                .iter()
                .position(|j| matches!(&j.region, Some(r) if Arc::ptr_eq(r, &region)));
            if let Some(idx) = mine {
                let queued = q.jobs.remove(idx).expect("indexed job vanished");
                q.in_flight += 1;
                drop(q);
                self.shared.execute(queued);
                q = self.shared.lock();
            } else {
                q = self.shared.wait(q);
            }
        }
        drop(q);

        let payload = local_panic
            .or_else(|| region.panic.lock().unwrap_or_else(|e| e.into_inner()).take());
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }

    /// Block until the queue is empty and no job is running.
    pub fn wait_idle(&self) {
        let mut q = self.shared.lock();
        while !q.jobs.is_empty() || q.in_flight > 0 {
            q = self.shared.wait(q);
        }
    }

    /// Drain the queue, then join all workers. Idempotent; `Drop` calls
    /// it. Afterwards `spawn`/`run_scoped` panic (fail loudly) instead
    /// of silently dropping work.
    pub fn shutdown(&self) {
        self.shared.lock().shutdown = true;
        self.shared.cond.notify_all();
        // Drain the handles out of the lock before joining: a join
        // performed while holding the workers mutex would deadlock
        // against a job on the joined worker that calls
        // `threads()`/`stats()` on its own pool.
        let handles: Vec<_> = {
            let mut ws = self.workers.lock().unwrap_or_else(|e| e.into_inner());
            ws.drain(..).collect()
        };
        for w in handles {
            let _ = w.join();
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn stats(&self) -> PoolStats {
        // Take the two locks one at a time: `ensure_workers` holds
        // `workers` while touching `queue`, so holding them here in the
        // opposite order could deadlock.
        let (jobs_executed, jobs_panicked, queue_peak) = {
            let q = self.shared.lock();
            (q.jobs_executed, q.jobs_panicked, q.queue_peak)
        };
        PoolStats {
            workers: self.threads(),
            jobs_executed,
            jobs_panicked,
            queue_peak,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let queued = {
            let mut q = shared.lock();
            loop {
                if let Some(queued) = q.jobs.pop_front() {
                    q.in_flight += 1;
                    break queued;
                }
                if q.shutdown {
                    return;
                }
                q = shared.wait(q);
            }
        };
        // Panics are contained inside `execute`: the worker survives,
        // the in-flight count drains, and a scoped task's payload and
        // completion are routed to its region.
        shared.execute(queued);
    }
}

/// Handle to a submitted job's result.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Wait for the job to finish. Panics if the job panicked (its
    /// result sender is dropped without sending).
    pub fn join(self) -> T {
        self.rx.recv().expect("worker job panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_results() {
        let pool = ThreadPool::new(2);
        let h1 = pool.submit(|| 1 + 1);
        let h2 = pool.submit(|| "x".to_string() + "y");
        assert_eq!(h1.join(), 2);
        assert_eq!(h2.join(), "xy");
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |i| i * 2);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must drain queue before join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn run_scoped_borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let mut data = [0u32; 7];
        let (head, rest) = data.split_at_mut(1);
        let tasks: Vec<ScopedJob<'_>> = rest
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| {
                Box::new(move || *slot = i as u32 + 2) as ScopedJob<'_>
            })
            .collect();
        pool.run_scoped(|| head[0] = 1, tasks);
        assert_eq!(data, [1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn run_scoped_empty_tasks_runs_local_inline() {
        let pool = ThreadPool::new(1);
        let mut hit = false;
        pool.run_scoped(|| hit = true, Vec::new());
        assert!(hit);
    }

    #[test]
    fn ensure_workers_grows_never_shrinks() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        pool.ensure_workers(3);
        assert_eq!(pool.threads(), 3);
        pool.ensure_workers(2);
        assert_eq!(pool.threads(), 3);
        assert_eq!(pool.stats().workers, 3);
    }
}
