//! Serving-path equivalence for auto-segmented requests (the tentpole
//! acceptance bar): a raw request — `prompt` under the text policy,
//! `demos` under icl, `system`+`turns` under chat, `state` under
//! gamecore, and each of them under `auto` — must produce output
//! **bitwise identical** to the equivalent pre-segmented `passages`
//! request, at every thread count and KV tier. Both request shapes
//! take the same tokenize + normalize + pin → cache → re-encode →
//! decode path; these tests prove the wire-level split is invisible.

use block_attn::config::{KvPrecision, ModelConfig, SegmentPolicy};
use block_attn::coordinator::segmenter::gamecore_field_texts;
use block_attn::coordinator::{Coordinator, Request};
use block_attn::kernels::set_threads;
use block_attn::runtime::NativeBackend;
use block_attn::server::parse_request_with_policy;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::json::Json;
use block_attn::workload::gamecore::GamecoreSim;
use std::sync::Mutex;

static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn serve_config() -> ModelConfig {
    ModelConfig {
        name: "serve-micro".into(),
        vocab: 261,
        d_model: 32,
        layers: 2,
        heads: 2,
        kv_heads: 1,
        head_dim: 16,
        d_ff: 64,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        max_len: 512,
    }
}

fn coordinator(precision: KvPrecision) -> Coordinator<NativeBackend> {
    let engine = NativeBackend::new(serve_config(), 0x5E57);
    Coordinator::with_kv_precision(engine, 64 << 20, precision)
}

fn line(fields: Vec<(&str, Json)>) -> String {
    Json::obj(fields).to_string()
}

fn str_arr(items: &[&str]) -> Json {
    Json::Arr(items.iter().map(|s| Json::str(s.to_string())).collect())
}

/// (scenario name, policy, raw request line, equivalent passages line).
fn scenarios() -> Vec<(&'static str, SegmentPolicy, String, String)> {
    let demos = ["big -> small", "hot -> cold", "up -> down"];
    // gamecore: the simulator's own wire line; the passages twin uses
    // the same per-field cut the server applies.
    let mut sim = GamecoreSim::new(4, 9);
    for _ in 0..3 {
        sim.step();
    }
    let fields = gamecore_field_texts(&sim.frame());
    vec![
        // text: division labels cut the prompt; every part is a block
        // and the wire `query` field stays the final block.
        (
            "text",
            SegmentPolicy::Text,
            line(vec![
                ("id", Json::num(1.0)),
                ("prompt", Json::str("alpha passage---beta passage===gamma tail")),
                ("query", Json::str("what follows?")),
                ("max_new_tokens", Json::num(8.0)),
            ]),
            line(vec![
                ("id", Json::num(1.0)),
                ("passages", str_arr(&["alpha passage---", "beta passage===", "gamma tail"])),
                ("query", Json::str("what follows?")),
                ("max_new_tokens", Json::num(8.0)),
            ]),
        ),
        // icl: one block per frozen demonstration.
        (
            "icl",
            SegmentPolicy::Icl,
            line(vec![
                ("id", Json::num(2.0)),
                ("demos", str_arr(&demos)),
                ("query", Json::str("wet ->")),
                ("max_new_tokens", Json::num(8.0)),
            ]),
            line(vec![
                ("id", Json::num(2.0)),
                ("passages", str_arr(&demos)),
                ("query", Json::str("wet ->")),
                ("max_new_tokens", Json::num(8.0)),
            ]),
        ),
        // chat: system block + one block per completed turn.
        (
            "chat",
            SegmentPolicy::Chat,
            line(vec![
                ("id", Json::num(3.0)),
                ("system", Json::str("you are terse")),
                ("turns", str_arr(&["user: hi / you: hello", "user: go on / you: ok"])),
                ("query", Json::str("and then?")),
                ("max_new_tokens", Json::num(8.0)),
            ]),
            line(vec![
                ("id", Json::num(3.0)),
                (
                    "passages",
                    str_arr(&["you are terse", "user: hi / you: hello", "user: go on / you: ok"]),
                ),
                ("query", Json::str("and then?")),
                ("max_new_tokens", Json::num(8.0)),
            ]),
        ),
        (
            "gamecore",
            SegmentPolicy::Gamecore,
            sim.request_line(4, 8),
            line(vec![
                ("id", Json::num(4.0)),
                (
                    "passages",
                    Json::Arr(fields.iter().map(|t| Json::str(t.clone())).collect()),
                ),
                ("query", Json::str("act")),
                ("max_new_tokens", Json::num(8.0)),
            ]),
        ),
    ]
}

fn parse(linetext: &str, policy: SegmentPolicy) -> Request {
    let tok = ByteTokenizer::new();
    parse_request_with_policy(linetext, &tok, policy).expect("parse")
}

/// The wire-level guarantee behind the bitwise bar: a raw request
/// parses to the exact token blocks of its pre-segmented twin — under
/// its own policy and under `auto`.
#[test]
fn raw_requests_parse_to_their_presegmented_twins() {
    for (name, policy, raw, passages) in scenarios() {
        let twin = parse(&passages, SegmentPolicy::Passages);
        for p in [policy, SegmentPolicy::Auto] {
            let req = parse(&raw, p);
            assert_eq!(req.blocks, twin.blocks, "{name}/{p:?}: blocks differ");
            assert_eq!(req.query, twin.query, "{name}/{p:?}: query differs");
            assert_eq!(req.max_new_tokens, twin.max_new_tokens);
        }
        // A pre-segmented request is served identically under every
        // policy — `passages` never re-segments.
        for p in [
            SegmentPolicy::Passages,
            SegmentPolicy::Text,
            SegmentPolicy::Icl,
            SegmentPolicy::Chat,
            SegmentPolicy::Gamecore,
            SegmentPolicy::Auto,
        ] {
            let req = parse(&passages, p);
            assert_eq!(req.blocks, twin.blocks, "{name}: passages re-cut under {p:?}");
        }
    }
}

/// End-to-end: serve every scenario's raw and pre-segmented form on
/// fresh coordinators at each thread count and KV tier; generated
/// tokens must match bitwise, and the warm raw pass must re-serve its
/// blocks from cache.
#[test]
fn raw_and_presegmented_serving_is_bitwise_identical() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    for (name, policy, raw, passages) in scenarios() {
        let raw_req = parse(&raw, policy);
        let pre_req = parse(&passages, SegmentPolicy::Passages);
        for precision in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
            let mut per_thread = Vec::new();
            for &threads in &[1usize, 3, 8] {
                set_threads(threads);
                let mut a = coordinator(precision);
                let ra = a.process(&raw_req).expect("raw serve");
                let mut b = coordinator(precision);
                let rb = b.process(&pre_req).expect("passages serve");
                assert_eq!(
                    ra.tokens, rb.tokens,
                    "{name}/{precision:?}/{threads}t: raw serving diverged from passages"
                );
                // Warm re-serve of the same raw request: every block
                // (and no more) comes from cache, output unchanged.
                let rw = a.process(&raw_req).expect("warm raw serve");
                assert_eq!(rw.cached_blocks, rw.total_blocks, "{name}: warm pass missed");
                assert_eq!(rw.tokens, ra.tokens, "{name}: warm pass diverged");
                per_thread.push(ra.tokens.clone());
            }
            assert!(
                per_thread.windows(2).all(|w| w[0] == w[1]),
                "{name}/{precision:?}: serving depends on the thread count"
            );
        }
    }
    set_threads(prev);
}
