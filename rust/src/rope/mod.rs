//! Rotary position embedding (RoPE) re-encoding — the L3-native hot path.
//!
//! Paper §2.3: a cached block's K states were computed at *local*
//! positions `0..L`. When the block is reused at offset `Δ` inside a new
//! prompt, its keys must be rotated to absolute positions `Δ..Δ+L`
//! (Eq. 3). Because 2-D rotations compose additively, rotating every RoPE
//! pair by `Δ·θ_j` is exactly equivalent to recomputing the keys at the
//! shifted positions — that is the invariant the tests pin down (and the
//! python side cross-checks against the Pallas kernel).
//!
//! Convention: Llama-style "half-split" pairing. For head dim `d`, the
//! pair `j` is `(x[j], x[j + d/2])` and `θ_j = base^(-2j/d)`,
//! `j ∈ [0, d/2)`. This must match `python/compile/kernels/rope.py`.

/// Rotate one head span's RoPE pairs in place: `x` has length
/// `head_dim`, pair `j` is `(x[j], x[j + half])`. Splits at `half` and
/// applies the ISA-dispatched elementwise rotation
/// ([`crate::kernels::simd::rotate_pairs`]), which is bitwise identical
/// to the scalar `a·cos − b·sin` / `a·sin + b·cos` sequence on every
/// backend — the property that keeps Eq.-3 re-encoding inside the
/// determinism contract.
#[inline]
fn rotate_span(x: &mut [f32], half: usize, cos: &[f32], sin: &[f32]) {
    let (lo, hi) = x.split_at_mut(half);
    crate::kernels::simd::rotate_pairs(lo, hi, cos, sin);
}

/// A borrowed view of one stored K panel at its storage tier — the
/// parameterized input of [`RopeTable::reencode_into`], so the f32,
/// int8, and int4 fetch paths share a single materialize-then-rotate
/// implementation (one place Eq. 3 happens).
///
/// All tiers describe the same `(layers, L, kv_heads, head_dim)`
/// row-major element order; only the encoding differs.
pub enum KvView<'a> {
    /// Dense f32 keys, copied verbatim before rotation.
    F32(&'a [f32]),
    /// Int8 codes + one f32 scale per (layer, head, channel)
    /// ([`crate::kernels::quant::QuantizedKv`] layout).
    Int8 { q: &'a [i8], scales: &'a [f32] },
    /// Packed int4 codes (two per byte) + one f32 scale per (layer,
    /// token-group, head, channel)
    /// ([`crate::kernels::quant::QuantizedKv4`] layout).
    Int4 { packed: &'a [u8], scales: &'a [f32] },
}

/// Small Δ-keyed memo of [`RopeTable::angles`] results, so a fetch
/// sweep where consecutive blocks share an offset (or revisit a recent
/// one) does not recompute — and reallocate — the cos/sin vectors per
/// block. Entries are replayed verbatim, and `angles` itself is a pure
/// deterministic function of `(table, Δ)`, so caching is bitwise
/// invisible. Bounded FIFO: at most [`Self::CAPACITY`] deltas live at
/// once (a serving plan touches only a handful of distinct offsets).
#[derive(Debug, Default)]
pub struct AngleCache {
    entries: Vec<(i64, Vec<f32>, Vec<f32>)>,
}

impl AngleCache {
    /// Distinct Δ values kept; the oldest is dropped beyond this.
    pub const CAPACITY: usize = 16;

    pub fn new() -> AngleCache {
        AngleCache { entries: Vec::new() }
    }

    /// Number of memoized Δ entries (introspection for tests).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// cos/sin of `delta·θ_j`, computed through `table` on first
    /// request and replayed verbatim afterwards.
    fn get_or_compute(&mut self, table: &RopeTable, delta: i64) -> (&[f32], &[f32]) {
        let at = match self.entries.iter().position(|(d, _, _)| *d == delta) {
            Some(i) => i,
            None => {
                if self.entries.len() >= Self::CAPACITY {
                    self.entries.remove(0);
                }
                let (cos, sin) = table.angles(delta);
                self.entries.push((delta, cos, sin));
                self.entries.len() - 1
            }
        };
        let e = &self.entries[at];
        (&e.1, &e.2)
    }
}

/// Precomputed per-pair inverse frequencies for one head dim.
#[derive(Debug, Clone)]
pub struct RopeTable {
    head_dim: usize,
    inv_freq: Vec<f64>, // len = head_dim / 2
}

impl RopeTable {
    /// `base` is the RoPE theta (e.g. 10000.0 or 500000.0 for Llama-3).
    pub fn new(head_dim: usize, base: f64) -> RopeTable {
        assert!(head_dim % 2 == 0, "head_dim must be even");
        let half = head_dim / 2;
        let inv_freq = (0..half)
            .map(|j| base.powf(-2.0 * j as f64 / head_dim as f64))
            .collect();
        RopeTable { head_dim, inv_freq }
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// cos/sin of `pos·θ_j` for all pairs, f32.
    pub fn angles(&self, pos: i64) -> (Vec<f32>, Vec<f32>) {
        let mut cos = Vec::with_capacity(self.inv_freq.len());
        let mut sin = Vec::with_capacity(self.inv_freq.len());
        for &f in &self.inv_freq {
            let a = pos as f64 * f;
            cos.push(a.cos() as f32);
            sin.push(a.sin() as f32);
        }
        (cos, sin)
    }

    /// Rotate one head vector in place by angle `pos·θ_j` per pair.
    ///
    /// `x` has length `head_dim`; pairs are `(x[j], x[j+d/2])`.
    pub fn rotate_head(&self, x: &mut [f32], pos: i64) {
        debug_assert_eq!(x.len(), self.head_dim);
        let half = self.head_dim / 2;
        let (cos, sin) = self.angles(pos);
        rotate_span(x, half, &cos, &sin);
    }

    /// Apply RoPE at absolute positions to a `(L, H, head_dim)` tensor
    /// stored row-major in `x` (used by tests to emulate "compute at
    /// absolute positions").
    pub fn encode_at(&self, x: &mut [f32], seq_len: usize, heads: usize, pos0: i64) {
        let d = self.head_dim;
        assert_eq!(x.len(), seq_len * heads * d);
        for t in 0..seq_len {
            for h in 0..heads {
                let off = (t * heads + h) * d;
                self.rotate_head(&mut x[off..off + d], pos0 + t as i64);
            }
        }
    }

    /// **The re-encoding hot path** (paper Eq. 3): rotate every key of a
    /// cached block by `Δ`, converting keys encoded at local positions
    /// `0..L` into keys at absolute positions `Δ..Δ+L`.
    ///
    /// `k` is `(layers, L, kv_heads, head_dim)` row-major. The same cos/sin
    /// pair is reused for every (layer, token, head), so the per-element
    /// cost is 2 mul + 1 add (fma-friendly), and the precomputed table is
    /// `d/2` wide regardless of block length.
    pub fn reencode_block(
        &self,
        k: &mut [f32],
        layers: usize,
        seq_len: usize,
        kv_heads: usize,
        delta: i64,
    ) {
        self.rotate_panel(k, layers, seq_len, kv_heads, delta, &mut AngleCache::new());
    }

    /// Rotate a materialized f32 `(layers, L, kv_heads, head_dim)`
    /// panel in place by `delta` — **the single place Eq. 3 touches
    /// data**. Every tier's fetch funnels here via
    /// [`Self::reencode_into`], and it doubles as the delta-mode
    /// primitive: rotating a panel already at `Δ₁` by `Δ₂−Δ₁` lands it
    /// at `Δ₂` (rotations compose additively — pinned by
    /// `reencode_composes_additively`). cos/sin come from the Δ-keyed
    /// `angles` memo, which is bitwise invisible.
    pub fn rotate_panel(
        &self,
        k: &mut [f32],
        layers: usize,
        seq_len: usize,
        kv_heads: usize,
        delta: i64,
        angles: &mut AngleCache,
    ) {
        let d = self.head_dim;
        assert_eq!(k.len(), layers * seq_len * kv_heads * d);
        if delta == 0 {
            return;
        }
        let half = d / 2;
        let (cos, sin) = angles.get_or_compute(self, delta);
        let heads_total = layers * seq_len * kv_heads;
        for h in 0..heads_total {
            rotate_span(&mut k[h * d..(h + 1) * d], half, cos, sin);
        }
    }

    /// **The unified re-encode path** (paper Eq. 3) over any storage
    /// tier: materialize the `(layers, L, kv_heads, head_dim)` panel
    /// described by `view` into `out` (verbatim copy / fused int8
    /// dequant / fused int4 unpack+dequant), then rotate every head
    /// span by `delta` through [`Self::rotate_panel`].
    ///
    /// Dequantization is per-element and order-free, and the rotation
    /// applies the exact operation sequence of [`Self::reencode_block`]
    /// with identical cos/sin values, so this path is **bitwise
    /// identical** per tier to the three fused variants it replaced
    /// (`unified_path_matches_legacy_variants_bitwise` pins it, and
    /// those variants survive as thin wrappers over this one).
    pub fn reencode_into(
        &self,
        view: KvView<'_>,
        layers: usize,
        seq_len: usize,
        kv_heads: usize,
        delta: i64,
        angles: &mut AngleCache,
        out: &mut [f32],
    ) {
        use crate::kernels::quant::{dequant_i4_row, dequant_i8_row, I4_GROUP};
        let d = self.head_dim;
        assert_eq!(out.len(), layers * seq_len * kv_heads * d);
        match view {
            KvView::F32(x) => {
                assert_eq!(x.len(), out.len());
                out.copy_from_slice(x);
            }
            KvView::Int8 { q, scales } => {
                assert_eq!(q.len(), out.len());
                assert_eq!(scales.len(), layers * kv_heads * d);
                for l in 0..layers {
                    for t in 0..seq_len {
                        for h in 0..kv_heads {
                            let off = ((l * seq_len + t) * kv_heads + h) * d;
                            let srow = &scales[(l * kv_heads + h) * d..(l * kv_heads + h + 1) * d];
                            dequant_i8_row(&q[off..off + d], srow, &mut out[off..off + d]);
                        }
                    }
                }
            }
            KvView::Int4 { packed, scales } => {
                let groups = seq_len.div_ceil(I4_GROUP);
                assert!(d % 2 == 0, "int4 packing needs an even head_dim");
                assert_eq!(packed.len() * 2, out.len());
                assert_eq!(scales.len(), layers * groups * kv_heads * d);
                let half = d / 2;
                for l in 0..layers {
                    for t in 0..seq_len {
                        let g = t / I4_GROUP;
                        for h in 0..kv_heads {
                            let off = ((l * seq_len + t) * kv_heads + h) * d;
                            let srow = &scales[((l * groups + g) * kv_heads + h) * d..][..d];
                            let brow = &packed[off / 2..off / 2 + half];
                            dequant_i4_row(brow, srow, &mut out[off..off + d]);
                        }
                    }
                }
            }
        }
        self.rotate_panel(out, layers, seq_len, kv_heads, delta, angles);
    }

    /// Fused dequantize + re-encode: the int8-tier variant of
    /// [`Self::reencode_block`]. `q` holds int8 key codes in the same
    /// `(layers, L, kv_heads, head_dim)` row-major order and `scales`
    /// one f32 per (layer, head, channel) (`layers·kv_heads·head_dim`,
    /// see [`crate::kernels::quant::QuantizedKv`]); the reconstructed
    /// keys, rotated by `delta`, are written to `out`.
    ///
    /// Dequantization (`x = q·s`) is per-element and order-free, and the
    /// rotation applies the exact operation sequence of
    /// [`Self::reencode_block`], so the fused path is **bitwise
    /// identical** to dequantizing first and re-encoding second — the
    /// property that keeps the int8 tier inside the serving stack's
    /// thread-count determinism contract.
    #[allow(clippy::too_many_arguments)]
    pub fn reencode_block_dequant(
        &self,
        q: &[i8],
        scales: &[f32],
        layers: usize,
        seq_len: usize,
        kv_heads: usize,
        delta: i64,
        out: &mut [f32],
    ) {
        self.reencode_into(
            KvView::Int8 { q, scales },
            layers,
            seq_len,
            kv_heads,
            delta,
            &mut AngleCache::new(),
            out,
        );
    }

    /// Fused unpack + dequantize + re-encode for the **packed int4**
    /// layout ([`crate::kernels::quant::QuantizedKv4`]): `packed` holds
    /// two 4-bit key codes per byte in `(layers, L, kv_heads,
    /// head_dim)` row-major element order, and `scales` one f32 per
    /// (layer, token-group, kv_head, channel) with groups of
    /// [`crate::kernels::quant::I4_GROUP`] tokens. The reconstructed
    /// keys, rotated by `delta`, are written to `out`.
    ///
    /// Like [`Self::reencode_block_dequant`], the unpack and `q·s` are
    /// per-element and the rotation applies the exact operation
    /// sequence of [`Self::reencode_block`], so the fused path is
    /// **bitwise identical** to dequantizing first and re-encoding
    /// second.
    pub fn reencode_block_dequant_i4(
        &self,
        packed: &[u8],
        scales: &[f32],
        layers: usize,
        seq_len: usize,
        kv_heads: usize,
        delta: i64,
        out: &mut [f32],
    ) {
        self.reencode_into(
            KvView::Int4 { packed, scales },
            layers,
            seq_len,
            kv_heads,
            delta,
            &mut AngleCache::new(),
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_keys(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn rotation_composes_additively() {
        // rotate(rotate(x, a), b) == rotate(x, a+b)
        let table = RopeTable::new(32, 10000.0);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let base = random_keys(&mut rng, 32);
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            let mut x1 = base.clone();
            table.rotate_head(&mut x1, a);
            table.rotate_head(&mut x1, b);
            let mut x2 = base.clone();
            table.rotate_head(&mut x2, a + b);
            for (p, q) in x1.iter().zip(&x2) {
                assert!((p - q).abs() < 1e-4, "{p} vs {q}");
            }
        }
    }

    #[test]
    fn reencode_equals_recompute_at_shifted_positions() {
        // Paper Eq. 3 invariant: keys encoded at local positions then
        // re-encoded by delta == keys encoded at absolute positions.
        let (layers, seq, heads, d) = (2, 5, 3, 16);
        let table = RopeTable::new(d, 10000.0);
        let mut rng = Rng::new(2);
        let raw = random_keys(&mut rng, layers * seq * heads * d);
        let delta = 37i64;

        // Path A: encode at local pos 0.., then reencode_block by delta.
        let mut a = raw.clone();
        for l in 0..layers {
            let off = l * seq * heads * d;
            table.encode_at(&mut a[off..off + seq * heads * d], seq, heads, 0);
        }
        table.reencode_block(&mut a, layers, seq, heads, delta);

        // Path B: encode directly at absolute positions delta..
        let mut b = raw.clone();
        for l in 0..layers {
            let off = l * seq * heads * d;
            table.encode_at(&mut b[off..off + seq * heads * d], seq, heads, delta);
        }

        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    /// Eq.-3 invariant swept across head dims and offsets: rotating
    /// local-position keys by Δ must match directly encoding the same
    /// raw keys at absolute positions Δ..Δ+L — for every head dim the
    /// model zoo uses and offsets from 1 to deep-context scale.
    #[test]
    fn reencode_matches_absolute_across_dims_and_deltas() {
        for (dim_i, &d) in [8usize, 32, 64, 128].iter().enumerate() {
            // Long-context thetas for the bigger dims, Llama-style.
            let base = if d >= 64 { 500000.0 } else { 10000.0 };
            let table = RopeTable::new(d, base);
            let (layers, seq, heads) = (2, 7, 2);
            let mut rng = Rng::new(0xD1 + dim_i as u64);
            let raw = random_keys(&mut rng, layers * seq * heads * d);
            for &delta in &[1i64, 5, 64, 1000, 4096, 30000] {
                // Path A: encode at local positions, re-encode by delta.
                let mut a = raw.clone();
                for l in 0..layers {
                    let off = l * seq * heads * d;
                    table.encode_at(&mut a[off..off + seq * heads * d], seq, heads, 0);
                }
                table.reencode_block(&mut a, layers, seq, heads, delta);
                // Path B: encode directly at absolute positions delta..
                let mut b = raw.clone();
                for l in 0..layers {
                    let off = l * seq * heads * d;
                    table.encode_at(&mut b[off..off + seq * heads * d], seq, heads, delta);
                }
                for (x, y) in a.iter().zip(&b) {
                    assert!(
                        (x - y).abs() < 2e-3,
                        "d={d} delta={delta}: {x} vs {y}"
                    );
                }
            }
        }
    }

    /// Re-encoding composes: Δ₁ then Δ₂ equals Δ₁+Δ₂ in one shot.
    #[test]
    fn reencode_composes_additively() {
        let table = RopeTable::new(16, 10000.0);
        let mut rng = Rng::new(0xADD);
        let raw = random_keys(&mut rng, 2 * 4 * 2 * 16);
        let mut two_hops = raw.clone();
        table.reencode_block(&mut two_hops, 2, 4, 2, 100);
        table.reencode_block(&mut two_hops, 2, 4, 2, 23);
        let mut one_hop = raw.clone();
        table.reencode_block(&mut one_hop, 2, 4, 2, 123);
        for (x, y) in two_hops.iter().zip(&one_hop) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    /// The int8 tier's fused dequant+re-encode must be bitwise identical
    /// to dequantizing first and re-encoding second — per element the
    /// same `q·s` then the same rotation sequence.
    #[test]
    fn fused_dequant_reencode_matches_two_step_bitwise() {
        use crate::kernels::quant::QuantizedKv;
        use crate::tensor::Tensor;
        let (layers, seq, heads, d) = (2usize, 5, 2, 16);
        let table = RopeTable::new(d, 10000.0);
        let mut rng = Rng::new(0x0D9);
        let raw = random_keys(&mut rng, layers * seq * heads * d);
        let kq = QuantizedKv::quantize(&Tensor::from_vec(&[layers, seq, heads, d], raw));
        for &delta in &[0i64, 1, 37, 4096] {
            // Two-step: dequantize, then the f32 re-encode.
            let mut want = kq.dequantize();
            table.reencode_block(want.data_mut(), layers, seq, heads, delta);
            // Fused.
            let mut got = vec![0.0f32; kq.q.len()];
            table.reencode_block_dequant(&kq.q, &kq.scales, layers, seq, heads, delta, &mut got);
            assert_eq!(got, want.data(), "fused path differs at delta={delta}");
        }
    }

    /// The int4 tier's fused unpack+dequant+re-encode must be bitwise
    /// identical to dequantizing first and re-encoding second — per
    /// element the same nibble unpack and `q·s`, then the same rotation
    /// sequence. 37 tokens ⇒ a partial second scale group.
    #[test]
    fn fused_dequant_reencode_i4_matches_two_step_bitwise() {
        use crate::kernels::quant::QuantizedKv4;
        use crate::tensor::Tensor;
        let (layers, seq, heads, d) = (2usize, 37, 2, 16);
        let table = RopeTable::new(d, 10000.0);
        let mut rng = Rng::new(0x0D4);
        let raw = random_keys(&mut rng, layers * seq * heads * d);
        let kq = QuantizedKv4::quantize(&Tensor::from_vec(&[layers, seq, heads, d], raw));
        for &delta in &[0i64, 1, 37, 4096] {
            // Two-step: dequantize, then the f32 re-encode.
            let mut want = kq.dequantize();
            table.reencode_block(want.data_mut(), layers, seq, heads, delta);
            // Fused.
            let mut got = vec![0.0f32; kq.packed.len() * 2];
            table.reencode_block_dequant_i4(
                &kq.packed, &kq.scales, layers, seq, heads, delta, &mut got,
            );
            assert_eq!(got, want.data(), "fused int4 path differs at delta={delta}");
        }
    }

    /// The unified `KvView` path must be bitwise identical, per tier,
    /// to the three fused variants it replaced — including when the
    /// angle cache is warm (second call replays memoized cos/sin).
    #[test]
    fn unified_path_matches_legacy_variants_bitwise() {
        use crate::kernels::quant::{QuantizedKv, QuantizedKv4};
        use crate::tensor::Tensor;
        let (layers, seq, heads, d) = (2usize, 37, 2, 16);
        let table = RopeTable::new(d, 10000.0);
        let mut rng = Rng::new(0x07F);
        let raw = random_keys(&mut rng, layers * seq * heads * d);
        let q8 = QuantizedKv::quantize(&Tensor::from_vec(&[layers, seq, heads, d], raw.clone()));
        let q4 = QuantizedKv4::quantize(&Tensor::from_vec(&[layers, seq, heads, d], raw.clone()));
        let mut ac = AngleCache::new();
        for &delta in &[0i64, 1, 37, 37, 4096, 37] {
            // f32 tier vs clone + reencode_block.
            let mut want = raw.clone();
            table.reencode_block(&mut want, layers, seq, heads, delta);
            let mut got = vec![0.0f32; raw.len()];
            let vf = KvView::F32(&raw);
            table.reencode_into(vf, layers, seq, heads, delta, &mut ac, &mut got);
            assert_eq!(got, want, "f32 unified path differs at delta={delta}");
            // int8 tier vs the legacy fused variant.
            let mut w8 = vec![0.0f32; raw.len()];
            table.reencode_block_dequant(&q8.q, &q8.scales, layers, seq, heads, delta, &mut w8);
            let mut g8 = vec![0.0f32; raw.len()];
            let view8 = KvView::Int8 { q: &q8.q, scales: &q8.scales };
            table.reencode_into(view8, layers, seq, heads, delta, &mut ac, &mut g8);
            assert_eq!(g8, w8, "int8 unified path differs at delta={delta}");
            // int4 tier vs the legacy fused variant.
            let mut w4 = vec![0.0f32; raw.len()];
            table.reencode_block_dequant_i4(
                &q4.packed, &q4.scales, layers, seq, heads, delta, &mut w4,
            );
            let mut g4 = vec![0.0f32; raw.len()];
            let view4 = KvView::Int4 { packed: &q4.packed, scales: &q4.scales };
            table.reencode_into(view4, layers, seq, heads, delta, &mut ac, &mut g4);
            assert_eq!(g4, w4, "int4 unified path differs at delta={delta}");
        }
    }

    /// The Δ-keyed angle memo replays `angles` verbatim and stays
    /// bounded at its FIFO capacity.
    #[test]
    fn angle_cache_is_bitwise_and_bounded() {
        let table = RopeTable::new(32, 10000.0);
        let mut cache = AngleCache::new();
        assert!(cache.is_empty());
        for round in 0..2 {
            for delta in 1..=(AngleCache::CAPACITY as i64 + 9) {
                let (cos, sin) = cache.get_or_compute(&table, delta);
                let (wc, ws) = table.angles(delta);
                assert_eq!(cos, wc.as_slice(), "round {round} delta {delta}");
                assert_eq!(sin, ws.as_slice(), "round {round} delta {delta}");
            }
        }
        assert_eq!(cache.len(), AngleCache::CAPACITY);
    }

    #[test]
    fn zero_delta_is_identity() {
        let table = RopeTable::new(8, 10000.0);
        let mut rng = Rng::new(3);
        let orig = random_keys(&mut rng, 2 * 3 * 2 * 8);
        let mut x = orig.clone();
        table.reencode_block(&mut x, 2, 3, 2, 0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rotation_preserves_norm() {
        let table = RopeTable::new(64, 500000.0);
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let x = random_keys(&mut rng, 64);
            let mut y = x.clone();
            table.rotate_head(&mut y, rng.below(100_000) as i64);
            let n1: f32 = x.iter().map(|v| v * v).sum();
            let n2: f32 = y.iter().map(|v| v * v).sum();
            assert!((n1 - n2).abs() / n1.max(1e-6) < 1e-4);
        }
    }

    #[test]
    fn inv_freq_matches_formula() {
        let t = RopeTable::new(8, 10000.0);
        assert!((t.inv_freq[0] - 1.0).abs() < 1e-12);
        assert!((t.inv_freq[1] - 10000f64.powf(-0.25)).abs() < 1e-12);
        assert!((t.inv_freq[3] - 10000f64.powf(-0.75)).abs() < 1e-12);
    }
}
