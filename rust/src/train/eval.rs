//! Accuracy evaluation (the paper's metric: does a correct answer appear
//! in the generated output — §3.1 "judging whether any correct answers
//! appear in the predicted output").

use crate::coordinator::{AttentionMode, Coordinator, Request};
use crate::runtime::Backend;
use crate::tokenizer::ByteTokenizer;
use crate::util::rng::Rng;
use crate::workload::Sample;
use anyhow::Result;

/// Evaluation options.
#[derive(Debug, Clone)]
pub struct EvalOpts {
    pub mode: AttentionMode,
    pub max_new_tokens: usize,
    /// Clear the KV cache first (required whenever parameters changed).
    pub fresh_cache: bool,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            mode: AttentionMode::Block,
            // Long enough for the restatement responses ("the <rel> of
            // <subj> is <value> ." plus 2-hop chains).
            max_new_tokens: 48,
            fresh_cache: true,
        }
    }
}

/// Exact-containment accuracy of greedy decoding over `samples`.
///
/// Zero-shot samples (no context blocks) always run in full-attention
/// mode — the paper's fallback for MMLU/IFEval/HumanEval (§3.1).
pub fn accuracy<B: Backend>(
    coord: &mut Coordinator<B>,
    samples: &[Sample],
    opts: &EvalOpts,
) -> Result<f64> {
    if opts.fresh_cache {
        coord.clear_cache();
    }
    let tok = ByteTokenizer::new();
    let mut correct = 0usize;
    for (i, s) in samples.iter().enumerate() {
        let sp = s.segment(&tok);
        let mode = if sp.blocks.is_empty() {
            AttentionMode::Full
        } else {
            opts.mode
        };
        let req = Request {
            id: i as u64,
            blocks: sp.blocks,
            query: sp.query,
            max_new_tokens: opts.max_new_tokens,
            mode,
        };
        let resp = coord.process(&req)?;
        let text = tok.decode_until_eos(&resp.tokens);
        if !s.answer.is_empty() && text.contains(&s.answer) {
            correct += 1;
        }
    }
    Ok(correct as f64 / samples.len().max(1) as f64)
}

/// Generate a fixed evaluation set from a generator function.
pub fn eval_set(
    gen: impl Fn(&mut Rng) -> Sample,
    seed: u64,
    n: usize,
) -> Vec<Sample> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen(&mut rng)).collect()
}

/// Teacher-forced mean NLL (nats/token) of the gold response under a
/// serving mode.
///
/// Finer-grained than exact-match accuracy: distribution mismatch
/// between attention modes (the paper's w/o-ft and w/o-pos degradations)
/// shows up as an NLL gap long before generation-level accuracy
/// separates — essential at this compute scale, where the tiny model's
/// copy circuits are only partially formed (DESIGN.md §training notes).
/// Scored through the *serving* path (prefill → teacher-forced decode),
/// so every mode including the position-corrupting baselines is
/// measurable.
pub fn answer_nll<B: Backend>(
    coord: &mut Coordinator<B>,
    samples: &[Sample],
    opts: &EvalOpts,
) -> Result<f64> {
    if opts.fresh_cache {
        coord.clear_cache();
    }
    let tok = ByteTokenizer::new();
    let mut total = 0.0;
    let mut count = 0usize;
    for s in samples.iter() {
        let sp = s.segment(&tok);
        let mode = if sp.blocks.is_empty() {
            crate::coordinator::AttentionMode::Full
        } else {
            opts.mode
        };
        let mut target = tok.encode(&s.response);
        target.push(crate::tokenizer::EOS);
        let nll = coord.score_continuation(&sp.blocks, &sp.query, &target, mode)?;
        total += nll.iter().sum::<f64>();
        count += nll.len();
    }
    Ok(total / count.max(1) as f64)
}
