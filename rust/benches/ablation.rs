//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Block granularity** — TTFT-block at fixed context vs the number
//!    of blocks it is split into (the §2.2 segmentation question:
//!    finer blocks → more reuse, more per-block overhead).
//! 2. **Reuse skew** — cache hit rate and saved prefill tokens vs the
//!    Zipf exponent of passage reuse (the §3.7 deployment question:
//!    how hot must passages be for caching to pay?).
//!
//! ```sh
//! cargo bench --bench ablation
//! cargo bench --bench ablation -- --ctx 4096
//! ```

use block_attn::coordinator::{write_ctx, AttentionMode, Coordinator, Request};
use block_attn::kvcache::{block_key, BlockKvCache};
use block_attn::rope::RopeTable;
use block_attn::runtime::backend_from_args;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::cli::Args;
use block_attn::util::rng::Rng;
use block_attn::util::timer::{bench, BenchOpts};
use block_attn::workload::traces::RagTrace;
use block_attn::Backend;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    block_granularity(&args)?;
    reuse_skew(&args)?;
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}

/// Ablation 1: split a fixed context into n blocks of ctx/n tokens and
/// measure the cached-serving TTFT (fetch + re-encode + assemble + final
/// prefill). All variants compute the same attention; only the reuse
/// granularity changes.
fn block_granularity(args: &Args) -> anyhow::Result<()> {
    // The interpretive native backend defaults to a shorter context;
    // `--backend xla --ctx 2048` reproduces the paper-scale ablation.
    let default_ctx =
        if block_attn::runtime::backend_choice(args) == "native" { 512 } else { 2048 };
    let ctx = args.usize_or("ctx", default_ctx);
    let q_len = args.usize_or("user-input", 50);
    let engine = backend_from_args(args, "bench")?;
    let cfg = engine.config().clone();
    let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
    let mut rng = Rng::new(11);
    let tokens: Vec<i32> = (0..ctx + q_len).map(|_| rng.below(cfg.vocab) as i32).collect();
    let query = &tokens[ctx..];
    let max_block = engine.max_block_tokens()?.min(512);

    println!("# Ablation 1 — block granularity at ctx={ctx} (config '{}', all blocks cached)", cfg.name);
    println!("{:>8} {:>12} {:>16} {:>14}", "blocks", "block-toks", "ttft-cached(ms)", "reencode(ms)");
    for n_blocks in [1usize, 2, 4, 8, 16] {
        let bl = ctx / n_blocks;
        if bl > max_block {
            println!("{n_blocks:>8} {bl:>12}   (exceeds prefill_block bucket {max_block}; skipped)");
            continue;
        }
        let mut cache = BlockKvCache::new(rope.clone(), 0);
        let blocks: Vec<&[i32]> = tokens[..ctx].chunks(bl).collect();
        for b in &blocks {
            let (k, v) = engine.prefill_block(b)?;
            let key = block_key(b);
            cache.insert_pinned(key, k, v);
            cache.unpin(key);
        }
        let cap = engine.final_ctx_capacity(ctx)?;
        let opts = BenchOpts { warmup_iters: 1, iters: 5, max_seconds: 60.0 };
        // Isolate the re-encode share.
        let r_re = bench("reencode", &opts, || {
            let mut off = 0;
            for b in &blocks {
                let blk = cache.get_reencoded(block_key(b), off).unwrap();
                off += blk.len;
                std::hint::black_box(&blk.k);
            }
        });
        let r = bench("cached-ttft", &opts, || {
            let mut past_k = engine.kv_zeros(cap);
            let mut past_v = engine.kv_zeros(cap);
            let mut off = 0;
            for b in &blocks {
                let blk = cache.get_reencoded(block_key(b), off).unwrap();
                write_ctx(&mut past_k, &blk.k, off);
                write_ctx(&mut past_v, &blk.v, off);
                off += blk.len;
            }
            engine.prefill_final(query, &past_k, &past_v, ctx).expect("final");
        });
        println!(
            "{n_blocks:>8} {bl:>12} {:>16.1} {:>14.2}",
            r.p50_ms(),
            r_re.p50_ms()
        );
    }
    println!("# finer blocks cost only the extra re-encode/memcpy — reuse granularity is ~free.\n");
    Ok(())
}

/// Ablation 2: serve Zipf(s) query streams for several skews and report
/// block hit rate + saved prefill tokens (tiny config, trained ckpt not
/// required — hit accounting is model-independent).
fn reuse_skew(args: &Args) -> anyhow::Result<()> {
    let n_requests = args.usize_or("requests", 30);
    let k = args.usize_or("passages-per-query", 6);
    let engine = backend_from_args(args, "tiny")?;
    engine.warmup()?;
    let mut coord = Coordinator::new(engine, 256 << 20);
    let tok = ByteTokenizer::new();

    println!("# Ablation 2 — cache efficiency vs passage-reuse skew ({n_requests} requests, {k} passages each, cold start)");
    println!("{:>8} {:>10} {:>14} {:>12}", "zipf-s", "hit-rate", "miss-tokens", "flops-saved");
    for s in [0.6, 0.9, 1.1, 1.4] {
        coord.clear_cache();
        let mut rng = Rng::new(7);
        let trace = RagTrace::build(&mut rng, 64);
        let mut cached = 0usize;
        let mut total = 0usize;
        let mut miss_tokens = 0usize;
        let mut all_tokens = 0usize;
        for i in 0..n_requests {
            let sample = trace.request(&mut rng, k, s);
            let sp = sample.segment(&tok);
            let plan = coord.dry_plan(&sp.blocks);
            cached += plan.cached_count();
            total += plan.items.len();
            miss_tokens += plan.miss_tokens();
            all_tokens += plan.total_tokens;
            // Actually serve so the cache fills as in production.
            let req = Request {
                id: i as u64,
                blocks: sp.blocks,
                query: sp.query,
                max_new_tokens: 1,
                mode: AttentionMode::Block,
            };
            coord.process(&req)?;
        }
        println!(
            "{s:>8.1} {:>9.1}% {:>10}/{:<6} {:>11.1}%",
            cached as f64 / total as f64 * 100.0,
            miss_tokens,
            all_tokens,
            (1.0 - miss_tokens as f64 / all_tokens as f64) * 100.0,
        );
    }
    println!("# hotter reuse (larger s) → higher hit rate → more prefill eliminated (paper §3.7).");
    Ok(())
}
