//! Directory-backed persistent tier under [`super::BlockKvCache`].
//!
//! A [`DiskStore`] is one flat directory of block files, one file per
//! cached block, named `<content-key:032x>-<fingerprint:016x>.bakv` —
//! the same 128-bit content key that addresses the RAM tier
//! ([`super::block_key`]) plus the weights fingerprint
//! ([`super::store::weights_fingerprint`]) the blocks were computed
//! under. Addressing is therefore pure: a lookup is a filename probe,
//! and two processes (or two runs, days apart) that compute the same
//! passage under the same weights produce byte-identical files at the
//! same path.
//!
//! Crash-safety and concurrency come from two filesystem guarantees
//! rather than locks:
//!
//! * **Atomic publish** — `put` writes to a unique `.tmp-*` file and
//!   `rename(2)`s it into place. Readers see either no file or a
//!   complete one; a crash mid-write leaves only tmp litter that is
//!   never addressed. Concurrent spills of the same block race benignly
//!   (both rename byte-identical images).
//! * **Read stability** — `get` reads the whole file in one `fs::read`;
//!   on POSIX an unlink (budget eviction in another process) after the
//!   open does not affect the in-flight read.
//!
//! Validation failures in `get` (truncation, checksum, version —
//! see [`super::store::decode_block`]) delete the damaged file and
//! surface as an `Err` the cache converts into a loud recompute miss,
//! so one bad block can never wedge a request or survive to be hit
//! again.
//!
//! The byte budget (0 = unbounded) is enforced after each put by
//! deleting oldest-modified files first — mtime-LRU across *all*
//! processes sharing the directory. A validated read-through `get`
//! refreshes the file's mtime, so promotion-heavy blocks count as
//! recently used instead of aging toward eviction while hot. Equal
//! mtimes (coarse filesystem granularity) break ties on the content
//! key, so eviction order is deterministic regardless of directory
//! iteration order.

use super::store::{self, StoredBlock};
use super::KvData;
use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::SystemTime;

/// Extension of published block files; anything else in the directory
/// (tmp litter, user files) is ignored by scans and the budget.
pub const FILE_EXT: &str = "bakv";

/// Process-wide tmp-name uniquifier: two caches in one process
/// spilling concurrently into the same directory must never collide
/// on the staging file.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// One open store directory. Cheap handle: holds counters, no file
/// descriptors.
pub struct DiskStore {
    dir: PathBuf,
    fingerprint: u64,
    budget_bytes: u64,
    entries: usize,
    bytes: u64,
}

impl DiskStore {
    /// Open (creating if needed) a store directory for blocks computed
    /// under `fingerprint`. `budget_bytes` bounds the summed file sizes
    /// (0 = unbounded). Fails loudly when the directory cannot be
    /// created or scanned — a store that cannot enumerate itself must
    /// not be attached.
    pub fn open(dir: &Path, fingerprint: u64, budget_bytes: u64) -> Result<DiskStore> {
        fs::create_dir_all(dir)
            .with_context(|| format!("kv-store: creating {}", dir.display()))?;
        let mut s = DiskStore {
            dir: dir.to_path_buf(),
            fingerprint,
            budget_bytes,
            entries: 0,
            bytes: 0,
        };
        for (_, len, _) in s.scan()? {
            s.entries += 1;
            s.bytes += len;
        }
        Ok(s)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Published block files in the directory (all fingerprints).
    pub fn entries(&self) -> usize {
        self.entries
    }

    /// Summed size of the published block files.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    fn path_for(&self, key: u128) -> PathBuf {
        self.dir.join(format!("{key:032x}-{:016x}.{FILE_EXT}", self.fingerprint))
    }

    /// Filename probe: is this block (under this store's fingerprint)
    /// published? Says nothing about validity — `get` decides that.
    pub fn contains(&self, key: u128) -> bool {
        self.path_for(key).exists()
    }

    /// Every published block file as `(mtime, len, path)`.
    fn scan(&self) -> Result<Vec<(SystemTime, u64, PathBuf)>> {
        let mut files = Vec::new();
        let rd = fs::read_dir(&self.dir)
            .with_context(|| format!("kv-store: scanning {}", self.dir.display()))?;
        for ent in rd {
            let ent = ent.with_context(|| format!("kv-store: scanning {}", self.dir.display()))?;
            let path = ent.path();
            if path.extension().and_then(|e| e.to_str()) != Some(FILE_EXT) {
                continue;
            }
            // A file deleted between readdir and stat is not an error.
            if let Ok(md) = ent.metadata() {
                let mtime = md.modified().unwrap_or(SystemTime::UNIX_EPOCH);
                files.push((mtime, md.len(), path));
            }
        }
        Ok(files)
    }

    /// Publish one block (write-behind spill). Returns `Ok(false)`
    /// without touching the disk when the file already exists —
    /// content addressing makes re-spilling the same block a no-op.
    pub(crate) fn put(&mut self, key: u128, data: &KvData, len: usize) -> Result<bool> {
        let path = self.path_for(key);
        if path.exists() {
            return Ok(false);
        }
        let img = store::encode_block(key, self.fingerprint, data, len);
        let tmp = self.dir.join(format!(
            ".tmp-{key:032x}-{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, &img).with_context(|| format!("kv-store: writing {}", tmp.display()))?;
        if let Err(e) = fs::rename(&tmp, &path) {
            let _ = fs::remove_file(&tmp);
            return Err(e)
                .with_context(|| format!("kv-store: publishing {}", path.display()));
        }
        self.entries += 1;
        self.bytes += img.len() as u64;
        self.enforce_budget();
        Ok(true)
    }

    /// Read-through fetch. `Ok(None)` is a clean miss (no file);
    /// `Err` means the file existed but failed validation — it has
    /// been deleted so a healthy copy can be re-spilled, and the
    /// caller must treat the lookup as a recompute miss. A validated
    /// hit refreshes the file's mtime so the cross-process mtime-LRU
    /// sees promotions as recency, not just spills.
    pub(crate) fn get(&mut self, key: u128) -> Result<Option<StoredBlock>> {
        let path = self.path_for(key);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("kv-store: reading {}", path.display()))
            }
        };
        match store::decode_block(&bytes, key, self.fingerprint) {
            Ok(block) => {
                Self::touch(&path);
                Ok(Some(block))
            }
            Err(e) => {
                if fs::remove_file(&path).is_ok() {
                    self.entries = self.entries.saturating_sub(1);
                    self.bytes = self.bytes.saturating_sub(bytes.len() as u64);
                }
                Err(e.context(format!("kv-store: rejecting {}", path.display())))
            }
        }
    }

    /// Best-effort mtime refresh so a read-through hit counts as
    /// recency for the cross-process mtime-LRU. Failure (read-only
    /// directory, file raced away by another process's eviction) only
    /// costs eviction-order accuracy, never correctness, so errors
    /// are ignored.
    fn touch(path: &Path) {
        let now = SystemTime::now();
        if let Ok(f) = fs::OpenOptions::new().append(true).open(path) {
            let _ = f.set_times(fs::FileTimes::new().set_accessed(now).set_modified(now));
        }
    }

    /// Content key parsed back out of a published filename
    /// (`<key:032x>-<fingerprint:016x>.bakv`); `None` for anything
    /// else. Used only to order same-mtime evictions deterministically.
    fn key_of(path: &Path) -> Option<u128> {
        let stem = path.file_stem()?.to_str()?;
        let (key_hex, _) = stem.split_once('-')?;
        u128::from_str_radix(key_hex, 16).ok()
    }

    /// Delete oldest-modified files until the summed size fits the
    /// budget. Refreshes the counters from a scan, so drift from other
    /// processes sharing the directory self-corrects here.
    fn enforce_budget(&mut self) {
        if self.budget_bytes == 0 {
            return;
        }
        let Ok(mut files) = self.scan() else { return };
        let mut total: u64 = files.iter().map(|(_, len, _)| *len).sum();
        // Oldest first; content key (then path, for non-block litter)
        // as the tie-break so same-second writes (coarse mtime
        // granularity) evict deterministically regardless of directory
        // iteration order.
        files.sort_by(|a, b| {
            (a.0, Self::key_of(&a.2), &a.2).cmp(&(b.0, Self::key_of(&b.2), &b.2))
        });
        let mut kept = files.len();
        for (_, len, path) in &files {
            if total <= self.budget_bytes {
                break;
            }
            if fs::remove_file(path).is_ok() {
                total -= len;
                kept -= 1;
            }
        }
        self.entries = kept;
        self.bytes = total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Tensor, TensorF};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("block-attn-disk-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn f32_block(len: usize, fill: f32) -> KvData {
        let mut k: TensorF = Tensor::zeros(&[2, len, 1, 8]);
        k.data_mut().iter_mut().for_each(|x| *x = fill);
        KvData::F32 { k_local: k.clone(), v: k }
    }

    #[test]
    fn put_get_roundtrip_and_idempotence() {
        let dir = tmpdir("roundtrip");
        let mut st = DiskStore::open(&dir, 0xFEED, 0).unwrap();
        assert_eq!((st.entries(), st.bytes()), (0, 0));
        assert!(st.get(42).unwrap().is_none(), "empty store must miss cleanly");

        let data = f32_block(4, 1.5);
        assert!(st.put(42, &data, 4).unwrap());
        assert!(!st.put(42, &data, 4).unwrap(), "re-spill must be a no-op");
        assert_eq!(st.entries(), 1);
        assert!(st.contains(42) && !st.contains(43));

        let got = st.get(42).unwrap().expect("published block must be readable");
        assert_eq!(got.len, 4);
        match (&got.data, &data) {
            (KvData::F32 { k_local: a, v: av }, KvData::F32 { k_local: b, v: bv }) => {
                assert_eq!(a, b);
                assert_eq!(av, bv);
            }
            _ => panic!("tier changed"),
        }

        // A second handle on the same directory sees the same state —
        // the restart path.
        let mut st2 = DiskStore::open(&dir, 0xFEED, 0).unwrap();
        assert_eq!(st2.entries(), 1);
        assert!(st2.get(42).unwrap().is_some());
        // A handle under different weights misses by filename.
        let mut st3 = DiskStore::open(&dir, 0xBEEF, 0).unwrap();
        assert!(st3.get(42).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_is_rejected_and_quarantined() {
        let dir = tmpdir("corrupt");
        let mut st = DiskStore::open(&dir, 1, 0).unwrap();
        st.put(7, &f32_block(4, 2.0), 4).unwrap();
        let path = st.path_for(7);
        let mut img = fs::read(&path).unwrap();
        let n = img.len();
        img[n - 1] ^= 0x10;
        fs::write(&path, &img).unwrap();

        let err = format!("{:#}", st.get(7).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(!path.exists(), "damaged file must be deleted");
        assert!(st.get(7).unwrap().is_none(), "second fetch is a clean miss");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_bounds_the_directory() {
        let dir = tmpdir("budget");
        let one = {
            let mut probe = DiskStore::open(&dir, 1, 0).unwrap();
            probe.put(1, &f32_block(4, 1.0), 4).unwrap();
            probe.bytes()
        };
        let _ = fs::remove_dir_all(&dir);

        // Budget of two files: the third put must evict one.
        let mut st = DiskStore::open(&dir, 1, 2 * one).unwrap();
        for key in 1..=3u128 {
            st.put(key, &f32_block(4, key as u32 as f32), 4).unwrap();
        }
        assert_eq!(st.entries(), 2, "budget must hold two of three files");
        assert!(st.bytes() <= 2 * one);
        let served: usize =
            (1..=3u128).filter(|&k| st.get(k).unwrap().is_some()).count();
        assert_eq!(served, 2, "surviving files must still be readable");
        let _ = fs::remove_dir_all(&dir);
    }

    fn set_mtime(path: &Path, t: SystemTime) {
        let f = fs::OpenOptions::new().append(true).open(path).unwrap();
        f.set_times(fs::FileTimes::new().set_accessed(t).set_modified(t)).unwrap();
    }

    #[test]
    fn get_refreshes_mtime_lru_recency() {
        use std::time::Duration;
        let dir = tmpdir("touch");
        let one = {
            let mut probe = DiskStore::open(&dir, 1, 0).unwrap();
            probe.put(1, &f32_block(4, 1.0), 4).unwrap();
            probe.bytes()
        };
        let _ = fs::remove_dir_all(&dir);

        let mut st = DiskStore::open(&dir, 1, 2 * one).unwrap();
        st.put(1, &f32_block(4, 1.0), 4).unwrap();
        st.put(2, &f32_block(4, 2.0), 4).unwrap();
        // Backdate both, key 1 colder than key 2.
        let old = SystemTime::UNIX_EPOCH + Duration::from_secs(1_000_000);
        set_mtime(&st.path_for(1), old);
        set_mtime(&st.path_for(2), old + Duration::from_secs(60));
        // A read-through hit must promote key 1 to warmest...
        assert!(st.get(1).unwrap().is_some());
        // ...so the next over-budget put evicts key 2, not key 1.
        st.put(3, &f32_block(4, 3.0), 4).unwrap();
        assert!(st.contains(1), "read-through hit must refresh recency");
        assert!(!st.contains(2), "coldest untouched file must evict first");
        assert!(st.contains(3));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn equal_mtime_eviction_breaks_ties_on_content_key() {
        use std::time::Duration;
        let dir = tmpdir("ties");
        let mut st = DiskStore::open(&dir, 1, 0).unwrap();
        for key in [9u128, 3, 7] {
            st.put(key, &f32_block(4, key as u32 as f32), 4).unwrap();
        }
        let one = st.bytes() / 3;
        // Identical mtimes: eviction must fall back to the content key
        // (lowest first), independent of readdir order or put order.
        let t = SystemTime::UNIX_EPOCH + Duration::from_secs(2_000_000);
        for key in [9u128, 3, 7] {
            set_mtime(&st.path_for(key), t);
        }
        st.budget_bytes = 2 * one;
        st.enforce_budget();
        assert!(!st.contains(3), "lowest content key must evict first on equal mtime");
        assert!(st.contains(7) && st.contains(9));
        assert_eq!(st.entries(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_of_parses_published_filenames() {
        let p = Path::new("/x/000000000000000000000000000000ff-0000000000000001.bakv");
        assert_eq!(DiskStore::key_of(p), Some(0xff));
        assert_eq!(DiskStore::key_of(Path::new("/x/garbage.bakv")), None);
        assert_eq!(DiskStore::key_of(Path::new("/x/.tmp-12-3-4")), None);
    }
}
