//! Host [`Tensor`] ⇄ PJRT conversion.
//!
//! Inputs travel host→device via [`xla::PjRtClient::buffer_from_host_buffer`]
//! (`buf_f`/`buf_i`/scalars) and outputs device→host via
//! `to_literal_sync` + the literal readers below.
//!
//! We deliberately avoid `PjRtLoadedExecutable::execute` (the
//! literal-argument variant): its C shim releases every
//! `BufferFromHostLiteral` result without freeing it after the run,
//! leaking each call's entire input set (~22 MB per train step). The
//! `execute_b` path with rust-owned input buffers is leak-free — and
//! lets parameters stay device-resident across calls.

use crate::tensor::{Tensor, TensorF, TensorI};
use anyhow::{anyhow, Result};

/// Upload an f32 tensor to the device.
pub fn buf_f(client: &xla::PjRtClient, t: &TensorF) -> Result<xla::PjRtBuffer> {
    Ok(client.buffer_from_host_buffer::<f32>(t.data(), t.dims(), None)?)
}

/// Upload an i32 tensor to the device.
pub fn buf_i(client: &xla::PjRtClient, t: &TensorI) -> Result<xla::PjRtBuffer> {
    Ok(client.buffer_from_host_buffer::<i32>(t.data(), t.dims(), None)?)
}

/// Upload a rank-0 i32 scalar.
pub fn buf_scalar_i(client: &xla::PjRtClient, v: i32) -> Result<xla::PjRtBuffer> {
    Ok(client.buffer_from_host_buffer::<i32>(&[v], &[], None)?)
}

/// Upload a rank-0 f32 scalar.
pub fn buf_scalar_f(client: &xla::PjRtClient, v: f32) -> Result<xla::PjRtBuffer> {
    Ok(client.buffer_from_host_buffer::<f32>(&[v], &[], None)?)
}

/// f32 tensor → literal with the tensor's shape.
pub fn tensor_f(t: &TensorF) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    if t.rank() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// i32 tensor → literal with the tensor's shape.
pub fn tensor_i(t: &TensorI) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    if t.rank() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Literal → f32 tensor (shape taken from the literal).
pub fn literal_to_f32(lit: &xla::Literal) -> Result<TensorF> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    if data.len() != dims.iter().product::<usize>() {
        return Err(anyhow!("literal shape/data mismatch"));
    }
    Ok(Tensor::from_vec(&dims, data))
}

/// Literal → i32 tensor.
pub fn literal_to_i32(lit: &xla::Literal) -> Result<TensorI> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<i32>()?;
    Ok(Tensor::from_vec(&dims, data))
}
