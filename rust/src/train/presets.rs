//! Training presets: the three checkpoints behind Tables 1-2 / Figure 4.
//!
//! | checkpoint | paper analogue            | data           | mode |
//! |------------|---------------------------|----------------|------|
//! | `base`     | Llama-3.1-Tulu-3-8B-SFT   | general tasks  | Full |
//! | `rag`      | Tulu3-RAG                 | RAG + general  | Full |
//! | `block`    | Tulu3-block-ft            | RAG + general  | Dual |
//!
//! All three start from the same deterministic init; `rag` and `block`
//! warm-start from `base` (mirroring the paper: both fine-tune the same
//! SFT model on the same data, differing only in the attention mask).

use super::eval::{accuracy, answer_nll, eval_set, EvalOpts};
use super::{train, DataMix, TrainConfig, TrainMode};
use crate::coordinator::{AttentionMode, Coordinator};
use crate::runtime::Backend;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::general::{GeneralGen, GeneralTask};
use crate::workload::rag::{RagGen, RagVariant};
use anyhow::Result;
use std::path::Path;

/// Seeds: world construction is shared between train/eval generators of
/// the same task family; the *sample streams* differ, and eval worlds
/// use distinct seeds so accuracy measures the mechanism, not
/// memorization of specific passages.
pub const TRAIN_WORLD_SEED: u64 = 11;
pub const EVAL_WORLD_SEED: u64 = 22;

/// The general-task mixture (the Tulu3-SFT stand-in).
pub fn general_mix(world_seed: u64) -> DataMix {
    let mut mix = DataMix::new();
    for (i, (w, task)) in [
        // Copy/IclMap up-weighted: they drive induction-head formation,
        // the prerequisite circuit for RAG retrieval.
        (2.0f64, GeneralTask::Copy),
        (1.0, GeneralTask::Reverse),
        (2.0, GeneralTask::IclMap { shots: 4 }),
        (1.0, GeneralTask::IclArith { shots: 4 }),
        (1.0, GeneralTask::IclSort { shots: 3 }),
    ]
    .into_iter()
    .enumerate()
    {
        let mut rng = Rng::new(world_seed ^ (i as u64 + 1)); // distinct world per task
        let g = GeneralGen::new(task, &mut rng, 60);
        mix = mix.add(w, move |r| g.sample(r));
    }
    mix
}

/// RAG + general mixture (the paper's Tulu3 + TQA/2Wiki training data).
pub fn rag_mix(world_seed: u64) -> DataMix {
    let mut mix = general_mix(world_seed);
    for v in RagVariant::ALL {
        let mut rng = Rng::new(world_seed.wrapping_add(v as u64 + 100));
        let g = RagGen::new(v, &mut rng, 60);
        mix = mix.add(2.5, move |r| g.sample(r));
    }
    mix
}

/// A fixed RAG evaluation set mixing the four variants (for Figure 4).
pub fn rag_eval_samples(n: usize) -> Vec<crate::workload::Sample> {
    let mut out = Vec::new();
    for v in RagVariant::ALL {
        let mut rng = Rng::new(EVAL_WORLD_SEED.wrapping_add(v as u64 + 100));
        let g = RagGen::new(v, &mut rng, 60);
        out.extend(eval_set(move |r| g.sample(r), 777 + v as u64, n / 4));
    }
    out
}

/// Per-variant RAG evaluation sets (the four Table-1 benchmark columns).
pub fn rag_eval_by_variant(n: usize) -> Vec<(String, Vec<crate::workload::Sample>)> {
    RagVariant::ALL
        .iter()
        .map(|&v| {
            let mut rng = Rng::new(EVAL_WORLD_SEED.wrapping_add(v as u64 + 100));
            let g = RagGen::new(v, &mut rng, 60);
            (
                v.name().to_string(),
                eval_set(move |r| g.sample(r), 777 + v as u64, n),
            )
        })
        .collect()
}

/// Per-task general/ICL evaluation sets (the Table-2 columns).
pub fn general_eval_by_task(n: usize) -> Vec<(String, bool, Vec<crate::workload::Sample>)> {
    GeneralTask::table2()
        .into_iter()
        .enumerate()
        .map(|(i, task)| {
            let mut rng = Rng::new(EVAL_WORLD_SEED ^ (i as u64 + 1));
            let g = GeneralGen::new(task, &mut rng, 60);
            (
                task.name(),
                task.is_zero_shot(),
                eval_set(move |r| g.sample(r), 888 + i as u64, n),
            )
        })
        .collect()
}

/// Step counts (scaled by `scale`, default 1.0).
#[derive(Debug, Clone)]
pub struct PresetOpts {
    pub base_steps: usize,
    pub rag_steps: usize,
    pub block_steps: usize,
    pub fig4_every: usize,
    pub fig4_samples: usize,
    pub lr: f64,
    /// Reuse existing `base`/`rag` checkpoints and run only the block
    /// fine-tune + Figure-4 trace.
    pub only_block: bool,
}

impl Default for PresetOpts {
    fn default() -> Self {
        PresetOpts {
            base_steps: 800,
            rag_steps: 800,
            block_steps: 1600,
            fig4_every: 200,
            fig4_samples: 40,
            lr: 1.5e-3,
            only_block: false,
        }
    }
}

impl PresetOpts {
    pub fn scaled(scale: f64) -> PresetOpts {
        let d = PresetOpts::default();
        let s = |x: usize| ((x as f64 * scale).round() as usize).max(2);
        PresetOpts {
            base_steps: s(d.base_steps),
            rag_steps: s(d.rag_steps),
            block_steps: s(d.block_steps),
            ..d
        }
    }
}

/// Train the three Table-1 checkpoints and record the Figure-4 series.
///
/// Writes to `out_dir`: `tiny_base.bin`, `tiny_rag.bin`, `tiny_block.bin`,
/// `fig4.json` (accuracy of both modes vs fine-tune step) and
/// `losses.json`.
pub fn run_table1_training<B: Backend>(
    coord: &mut Coordinator<B>,
    out_dir: &Path,
    opts: &PresetOpts,
) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let engine_name = coord.engine().config().name.clone();
    let path = |tag: &str| out_dir.join(format!("{engine_name}_{tag}.bin"));
    let mut all_losses: Vec<(String, Vec<f32>)> = Vec::new();

    if opts.only_block {
        eprintln!("[train] --only-block: reusing existing base/rag checkpoints");
        anyhow::ensure!(path("base").exists(), "missing base checkpoint");
        return run_block_phase(coord, out_dir, opts, &mut all_losses);
    }

    // 1. Base "SFT" model: general tasks, full attention.
    eprintln!("[train] base: {} steps of general mix (full attention)", opts.base_steps);
    let cfg = TrainConfig {
        steps: opts.base_steps,
        lr: opts.lr,
        mode: TrainMode::Full,
        seed: 1,
        ..Default::default()
    };
    let losses = train(coord, &cfg, &general_mix(TRAIN_WORLD_SEED), |_, _| {})?;
    log_loss("base", &losses);
    all_losses.push(("base".into(), losses));
    coord.engine().save_params_file(&path("base"))?;

    // 2. RAG fine-tune (full attention) — the Tulu3-RAG ceiling.
    eprintln!("[train] rag: {} steps of RAG mix (full attention)", opts.rag_steps);
    coord.engine().load_params_file(&path("base"))?;
    coord.engine().reset_opt_state();
    let cfg = TrainConfig {
        steps: opts.rag_steps,
        lr: opts.lr,
        mode: TrainMode::Full,
        seed: 2,
        ..Default::default()
    };
    let losses = train(coord, &cfg, &rag_mix(TRAIN_WORLD_SEED), |_, _| {})?;
    log_loss("rag", &losses);
    all_losses.push(("rag".into(), losses));
    coord.engine().save_params_file(&path("rag"))?;

    run_block_phase(coord, out_dir, opts, &mut all_losses)
}

/// Phase 3: block fine-tune (dual mode) with the Figure-4 trace.
///
/// Records accuracy **and** teacher-forced answer NLL for both modes at
/// each eval point: at tiny-model compute scale the NLL gap closes well
/// before generation accuracy separates, so it is the Figure-4 signal.
fn run_block_phase<B: Backend>(
    coord: &mut Coordinator<B>,
    out_dir: &Path,
    opts: &PresetOpts,
    all_losses: &mut Vec<(String, Vec<f32>)>,
) -> Result<()> {
    let engine_name = coord.engine().config().name.clone();
    let path = |tag: &str| out_dir.join(format!("{engine_name}_{tag}.bin"));
    eprintln!(
        "[train] block: {} steps of RAG mix (dual mode), eval every {}",
        opts.block_steps, opts.fig4_every
    );
    coord.engine().load_params_file(&path("base"))?;
    coord.engine().reset_opt_state();
    let eval_samples = rag_eval_samples(opts.fig4_samples);
    let mut fig4: Vec<Json> = Vec::new();
    let cfg = TrainConfig {
        steps: opts.block_steps,
        lr: opts.lr,
        mode: TrainMode::Dual,
        seed: 3,
        eval_every: opts.fig4_every,
        ..Default::default()
    };
    let losses = train(coord, &cfg, &rag_mix(TRAIN_WORLD_SEED), |c, step| {
        let eval = |c: &mut Coordinator<B>, mode| {
            let o = EvalOpts { mode, max_new_tokens: 48, fresh_cache: true };
            let acc = accuracy(c, &eval_samples, &o).unwrap_or(f64::NAN);
            let nll = answer_nll(c, &eval_samples, &o).unwrap_or(f64::NAN);
            (acc, nll)
        };
        let (ba, bn) = eval(c, AttentionMode::Block);
        let (fa, fn_) = eval(c, AttentionMode::Full);
        eprintln!(
            "[fig4] step {step}: block acc={ba:.3} nll={bn:.3} | full acc={fa:.3} nll={fn_:.3}"
        );
        fig4.push(Json::obj(vec![
            ("step", Json::num(step as f64)),
            ("block_acc", Json::num(ba)),
            ("full_acc", Json::num(fa)),
            ("block_nll", Json::num(bn)),
            ("full_nll", Json::num(fn_)),
        ]));
    })?;
    log_loss("block", &losses);
    all_losses.push(("block".into(), losses));
    coord.engine().save_params_file(&path("block"))?;

    std::fs::write(out_dir.join("fig4.json"), Json::Arr(fig4).to_string())?;
    let losses_json = Json::Obj(
        all_losses
            .iter()
            .map(|(k, v)| {
                (
                    k.clone(),
                    Json::Arr(v.iter().map(|&x| Json::num(x as f64)).collect()),
                )
            })
            .collect(),
    );
    std::fs::write(out_dir.join("losses.json"), losses_json.to_string())?;
    eprintln!("[train] checkpoints written to {out_dir:?}");
    Ok(())
}

fn log_loss(tag: &str, losses: &[f32]) {
    let first = losses.first().copied().unwrap_or(f32::NAN);
    let last_k = &losses[losses.len().saturating_sub(20)..];
    let last: f32 = last_k.iter().sum::<f32>() / last_k.len().max(1) as f32;
    eprintln!("[train] {tag}: loss {first:.3} -> {last:.3} over {} steps", losses.len());
}
