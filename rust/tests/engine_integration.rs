//! Backend-level integration tests.
//!
//! They run hermetically against [`NativeBackend`] (no artifacts, no
//! XLA): the centerpiece is the **losslessness** of the Block-attention
//! serving path — per-block prefill at local positions + RoPE re-encode
//! + context assembly + final-block prefill must reproduce vanilla
//! full-attention prefill in the single-block case.
//!
//! Artifact-specific cases (bucket padding, Pallas-kernel parity, the
//! AOT train step) live in the `xla_artifacts` module behind
//! `--features xla` and additionally need `make artifacts`.

use block_attn::config::ModelConfig;
use block_attn::coordinator::write_ctx;
use block_attn::rope::RopeTable;
use block_attn::runtime::NativeBackend;
use block_attn::util::rng::Rng;
use block_attn::Backend;

fn engine() -> NativeBackend {
    NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C)
}

fn rand_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab - 5) as i32).collect()
}

fn close(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= atol, "{what}: max abs diff {worst} > {atol}");
}

#[test]
fn prefill_full_runs_and_is_deterministic() {
    let eng = engine();
    let mut rng = Rng::new(1);
    let toks = rand_tokens(&mut rng, 100, eng.config().vocab);
    let a = eng.prefill_full(&toks).unwrap();
    let b = eng.prefill_full(&toks).unwrap();
    assert_eq!(a.last_logits.len(), eng.config().vocab);
    assert!(a.last_logits.iter().all(|x| x.is_finite()));
    close(&a.last_logits, &b.last_logits, 0.0, "determinism");
    assert_eq!(a.k.dims(), &[4, 100, 2, 32]);
}

/// The headline invariant: the cached-block serving path reproduces
/// full-attention exactly in the single-block case (no fine-tune needed:
/// with one block the two attention patterns coincide).
#[test]
fn block_path_equals_full_for_single_block() {
    let eng = engine();
    let cfg = eng.config().clone();
    let mut rng = Rng::new(4);
    let block = rand_tokens(&mut rng, 64, cfg.vocab);
    let query = rand_tokens(&mut rng, 48, cfg.vocab);

    // Vanilla: one shot.
    let mut full = block.clone();
    full.extend_from_slice(&query);
    let want = eng.prefill_full(&full).unwrap();

    // Block path: block prefill at local positions → re-encode by 0 (the
    // block sits at offset 0) → assemble context → final prefill.
    let (k_local, v) = eng.prefill_block(&block).unwrap();
    let cap = eng.final_ctx_capacity(block.len()).unwrap();
    let mut past_k = eng.kv_zeros(cap);
    let mut past_v = eng.kv_zeros(cap);
    write_ctx(&mut past_k, &k_local, 0);
    write_ctx(&mut past_v, &v, 0);
    let got = eng
        .prefill_final(&query, &past_k, &past_v, block.len())
        .unwrap();

    close(&got.last_logits, &want.last_logits, 1e-4, "single-block logits");
    // The final block's own KV must equal the corresponding slice of the
    // full run (they are the same computation).
    close(
        got.k.data(),
        extract_tail(&want.k, block.len(), query.len()).data(),
        1e-4,
        "final-block keys",
    );
}

/// Two blocks with native re-encoding: the assembled context + decode
/// continuation must be finite, deterministic, and write KV at the
/// right cache slot.
#[test]
fn block_path_then_decode_is_consistent() {
    let eng = engine();
    let cfg = eng.config().clone();
    let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
    let mut rng = Rng::new(5);
    let b1 = rand_tokens(&mut rng, 64, cfg.vocab);
    let b2 = rand_tokens(&mut rng, 64, cfg.vocab);
    let query = rand_tokens(&mut rng, 40, cfg.vocab);

    // Block path.
    let (mut k1, v1) = eng.prefill_block(&b1).unwrap();
    let (mut k2, v2) = eng.prefill_block(&b2).unwrap();
    rope.reencode_block(k1.data_mut(), cfg.layers, 64, cfg.kv_heads, 0);
    rope.reencode_block(k2.data_mut(), cfg.layers, 64, cfg.kv_heads, 64);
    let ctx_len = 128;
    let cap = eng.final_ctx_capacity(ctx_len).unwrap();
    let mut past_k = eng.kv_zeros(cap);
    let mut past_v = eng.kv_zeros(cap);
    write_ctx(&mut past_k, &k1, 0);
    write_ctx(&mut past_v, &v1, 0);
    write_ctx(&mut past_k, &k2, 64);
    write_ctx(&mut past_v, &v2, 64);
    let fin = eng.prefill_final(&query, &past_k, &past_v, ctx_len).unwrap();
    assert!(fin.last_logits.iter().all(|f| f.is_finite()));

    // Assemble a dense decode cache: ctx + final block.
    let dc = eng.decode_ctx_capacity().unwrap();
    let mut kc = eng.kv_zeros(dc);
    let mut vc = eng.kv_zeros(dc);
    write_ctx(&mut kc, &k1, 0);
    write_ctx(&mut vc, &v1, 0);
    write_ctx(&mut kc, &k2, 64);
    write_ctx(&mut vc, &v2, 64);
    write_ctx(&mut kc, &fin.k, 128);
    write_ctx(&mut vc, &fin.v, 128);
    let total = 128 + query.len();

    // Decode one token; its logits must be finite and the updated cache
    // must contain the new token's KV at position `total`.
    let next = block_attn::tensor::argmax(&fin.last_logits) as i32;
    let out = eng.decode(next, &kc, &vc, total).unwrap();
    assert!(out.logits.iter().all(|f| f.is_finite()));
    let row = cfg.kv_heads * cfg.head_dim;
    let layer0 = out.k_cache.axis0(0);
    let newk = &layer0[total * row..(total + 1) * row];
    assert!(newk.iter().any(|&x| x != 0.0), "decode wrote KV at cache_len");

    // And decoding from the same cache twice is deterministic.
    let out2 = eng.decode(next, &kc, &vc, total).unwrap();
    close(&out.logits, &out2.logits, 0.0, "decode determinism");
}

#[test]
fn decode_matches_prefill_extension() {
    let eng = engine();
    let cfg = eng.config().clone();
    let mut rng = Rng::new(6);
    let toks = rand_tokens(&mut rng, 90, cfg.vocab);
    let pre = eng.prefill_full(&toks).unwrap();
    let next = block_attn::tensor::argmax(&pre.last_logits) as i32;

    // Decode path.
    let dc = eng.decode_ctx_capacity().unwrap();
    let mut kc = eng.kv_zeros(dc);
    let mut vc = eng.kv_zeros(dc);
    write_ctx(&mut kc, &pre.k, 0);
    write_ctx(&mut vc, &pre.v, 0);
    let dec = eng.decode(next, &kc, &vc, 90).unwrap();

    // Prefill-extension path.
    let mut ext = toks.clone();
    ext.push(next);
    let pre2 = eng.prefill_full(&ext).unwrap();

    close(&dec.logits, &pre2.last_logits, 1e-4, "decode vs prefill ext");
}

/// Superposition-style position origin: the query can sit at a position
/// decoupled from the context length.
#[test]
fn prefill_final_at_respects_q_pos0() {
    let eng = engine();
    let cfg = eng.config().clone();
    let mut rng = Rng::new(8);
    let block = rand_tokens(&mut rng, 32, cfg.vocab);
    let query = rand_tokens(&mut rng, 16, cfg.vocab);
    let (k, v) = eng.prefill_block(&block).unwrap();
    let mut past_k = eng.kv_zeros(32);
    let mut past_v = eng.kv_zeros(32);
    write_ctx(&mut past_k, &k, 0);
    write_ctx(&mut past_v, &v, 0);
    let at_ctx = eng
        .prefill_final_at(&query, &past_k, &past_v, 32, 32)
        .unwrap();
    let at_zero = eng
        .prefill_final_at(&query, &past_k, &past_v, 32, 0)
        .unwrap();
    let mut diff = 0.0f32;
    for (a, b) in at_ctx.last_logits.iter().zip(&at_zero.last_logits) {
        diff = diff.max((a - b).abs());
    }
    assert!(diff > 1e-4, "q_pos0 had no effect on the logits");
}

/// Slice the last `q_len` token rows from a `(layers, len, kv, hd)` KV.
fn extract_tail(
    kv: &block_attn::tensor::TensorF,
    at: usize,
    q_len: usize,
) -> block_attn::tensor::TensorF {
    let dims = kv.dims();
    let (layers, row) = (dims[0], dims[2] * dims[3]);
    let mut out = block_attn::tensor::Tensor::zeros(&[layers, q_len, dims[2], dims[3]]);
    for n in 0..layers {
        out.axis0_mut(n)
            .copy_from_slice(&kv.axis0(n)[at * row..(at + q_len) * row]);
    }
    out
}

/// Artifact-backed cases (require `--features xla`, a real xla crate and
/// `make artifacts`).
#[cfg(feature = "xla")]
mod xla_artifacts {
    use super::{close, rand_tokens};
    use block_attn::config::{default_artifacts_dir, Manifest};
    use block_attn::coordinator::write_ctx;
    use block_attn::rope::RopeTable;
    use block_attn::runtime::ModelEngine;
    use block_attn::tensor::Tensor;
    use block_attn::util::rng::Rng;
    use block_attn::Backend;

    fn engine() -> ModelEngine {
        let manifest = Manifest::load(default_artifacts_dir()).expect("run `make artifacts`");
        ModelEngine::new(&manifest, "tiny").expect("engine")
    }

    #[test]
    fn bucket_padding_is_transparent() {
        // The same prompt through two different length buckets must agree.
        let eng = engine();
        let mut rng = Rng::new(2);
        let toks = rand_tokens(&mut rng, 120, eng.config().vocab);
        let a = eng.prefill_full(&toks).unwrap(); // L=128 bucket
        let mut padded = toks.clone();
        padded.resize(200, 0); // forces the 320 bucket
        let b = eng.prefill_full(&padded[..200].to_vec()).unwrap();
        // Only compare the KV of the first 120 positions: logits differ
        // (the padded prompt has a different "last" position), but the
        // causal KV prefix must match across buckets.
        let ka = a.k.data();
        let kb = b.k.slice_axis0(0, 4);
        let row = 2 * 32;
        for layer in 0..4 {
            let sa = &ka[layer * 120 * row..(layer * 120 + 120) * row];
            let sb = &kb.data()[layer * 200 * row..(layer * 200 + 120) * row];
            close(sa, sb, 1e-4, "kv prefix across buckets");
        }
    }

    #[test]
    fn reencode_native_matches_pallas_artifact() {
        let eng = engine();
        let cfg = eng.config().clone();
        let mut rng = Rng::new(3);
        let dims = [cfg.layers, 64, cfg.kv_heads, cfg.head_dim];
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let k = Tensor::from_vec(&dims, data);

        let via_artifact = eng.reencode_k_artifact(&k, 137).unwrap();
        let mut via_native = k.clone();
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        rope.reencode_block(via_native.data_mut(), cfg.layers, 64, cfg.kv_heads, 137);
        close(
            via_artifact.data(),
            via_native.data(),
            1e-4,
            "rust rope vs pallas artifact",
        );
    }

    #[test]
    fn block_path_equals_full_for_single_block_on_artifacts() {
        let eng = engine();
        let cfg = eng.config().clone();
        let mut rng = Rng::new(4);
        let block = rand_tokens(&mut rng, 64, cfg.vocab);
        let query = rand_tokens(&mut rng, 48, cfg.vocab);

        let mut full = block.clone();
        full.extend_from_slice(&query);
        let want = eng.prefill_full(&full).unwrap();

        let (k_local, v) = eng.prefill_block(&block).unwrap();
        let cap = eng.final_ctx_capacity(block.len()).unwrap();
        let mut past_k = eng.kv_zeros(cap);
        let mut past_v = eng.kv_zeros(cap);
        write_ctx(&mut past_k, &k_local, 0);
        write_ctx(&mut past_v, &v, 0);
        let got = eng
            .prefill_final(&query, &past_k, &past_v, block.len())
            .unwrap();
        close(&got.last_logits, &want.last_logits, 5e-3, "single-block logits");
    }

    #[test]
    fn train_step_reduces_loss_on_tiny_batch() {
        let eng = engine();
        let (b, l) = eng.train_shape().unwrap();
        // Low-entropy repeating data: loss must drop fast.
        let toks: Vec<i32> = (0..b * l).map(|i| ((i % 7) + 1) as i32).collect();
        let tokens = Tensor::from_vec(&[b, l], toks);
        let seg = Tensor::from_vec(&[b, l], vec![0i32; b * l]);
        let mask = Tensor::from_vec(&[b, l], vec![1.0f32; b * l]);
        let mut losses = Vec::new();
        for step in 0..4 {
            let out = eng.train_step(step, 3e-3, &tokens, &seg, &mask).unwrap();
            assert!(out.loss.is_finite());
            losses.push(out.loss);
        }
        assert!(losses[3] < losses[0] - 0.3, "loss did not drop: {losses:?}");
    }
}
