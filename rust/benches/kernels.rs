//! Kernel-layer benchmarks: scalar vs tiled vs tiled+parallel GEMM, and
//! 1-vs-N-thread concurrent block prefill — the two wins this layer
//! exists for.
//!
//! ```sh
//! cargo bench --bench kernels                      # 256³ GEMM + prefill
//! cargo bench --bench kernels -- --size 384 --par-threads 8
//! ```
//!
//! The scalar baseline is the saxpy triple loop the kernels replaced.
//! Every variant is checked bitwise-identical before timing — the
//! speedup must come for free, not from a different reduction order.
//!
//! The nt family and the decode `dot_i4` GEMV are timed twice: once
//! with `--simd off` (the `gemm_nt_*_ms` / `dot_i4_ms` keys, comparable
//! across machines) and once at the resolved SIMD mode (the `*_simd_ms`
//! twins; the active ISA lands in the `simd_isa` JSON field).
//!
//! Results are written machine-readable to `BENCH_kernels.json`
//! (`--json-out PATH` overrides) so the perf trajectory is tracked
//! across PRs.

use block_attn::config::KvPrecision;
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::kernels::{
    dot_i4, gemm_nn_acc, gemm_nt_acc, gemm_nt_i4_acc, gemm_nt_i8_acc, isa_name, quant,
    set_simd_mode, set_threads, SimdMode,
};
use block_attn::runtime::backend_from_args;
use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use block_attn::util::rng::Rng;
use block_attn::util::timer::{bench, BenchOpts};
use block_attn::Backend;

/// The pre-kernel-layer scalar baseline: row-major saxpy accumulation.
fn scalar_matmul_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            for (o, &bv) in orow.iter_mut().zip(&b[p * n..(p + 1) * n]) {
                *o += av * bv;
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let machine_threads = block_attn::kernels::init_threads_from_args(&args);
    // Scalar legs force the reference kernels (so the historical
    // gemm_nt_*_ms keys stay comparable across machines and with the
    // pre-SIMD baselines); the *_simd_ms twins run at this resolved
    // mode (auto on CI → the detected ISA).
    let simd_mode = SimdMode::resolve(&args)?;
    // The headline comparison is pinned at 4 threads (the acceptance
    // configuration); override with --par-threads.
    let par_threads = args.usize_or("par-threads", 4);
    let size = args.usize_or("size", 256);
    let (m, k, n) = (size, size, size);
    let gflop = (2.0 * (m * k * n) as f64) / 1e9;

    let mut rng = Rng::new(3);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();

    // Bitwise parity gate before any timing.
    let mut want = vec![0.0f32; m * n];
    scalar_matmul_acc(&a, &b, m, k, n, &mut want);
    for t in [1, par_threads] {
        set_threads(t);
        let mut got = vec![0.0f32; m * n];
        gemm_nn_acc(&a, &b, m, k, n, &mut got);
        assert_eq!(got, want, "tiled GEMM (threads={t}) differs from scalar");
    }

    println!("# kernels — GEMM {m}x{k}x{n} ({gflop:.2} GFLOP), machine threads {machine_threads}");
    let opts = BenchOpts { warmup_iters: 1, iters: 7, max_seconds: 120.0 };
    let mut out = vec![0.0f32; m * n];

    let r_scalar = bench("gemm_scalar", &opts, || {
        out.fill(0.0);
        scalar_matmul_acc(&a, &b, m, k, n, &mut out);
    });
    println!("{}  ({:.2} GFLOP/s)", r_scalar.report_line(), gflop / (r_scalar.p50_ms() / 1e3));

    set_threads(1);
    let r_tiled = bench("gemm_tiled(1 thread)", &opts, || {
        out.fill(0.0);
        gemm_nn_acc(&a, &b, m, k, n, &mut out);
    });
    println!("{}  ({:.2} GFLOP/s)", r_tiled.report_line(), gflop / (r_tiled.p50_ms() / 1e3));

    set_threads(par_threads);
    let r_par = bench(&format!("gemm_tiled({par_threads} threads)"), &opts, || {
        out.fill(0.0);
        gemm_nn_acc(&a, &b, m, k, n, &mut out);
    });
    println!("{}  ({:.2} GFLOP/s)", r_par.report_line(), gflop / (r_par.p50_ms() / 1e3));

    let speed_tiled = r_scalar.p50_ms() / r_tiled.p50_ms();
    let speed_par = r_scalar.p50_ms() / r_par.p50_ms();
    println!(
        "# speedup: tiled {speed_tiled:.2}x, tiled+{par_threads}t {speed_par:.2}x (target ≥ 3x)"
    );

    // -- int8 × f32 mixed GEMM vs f32 ----------------------------------
    // The QKᵀ layout of the fused-dequant attention path: `b` plays the
    // int8-quantized K operand (per shared-dim channel scales). Parity
    // gate first: the fused dequant must match the f32 kernel over the
    // pre-dequantized operand bit for bit.
    let bscale = quant::channel_scales(&b, size, size);
    let bq: Vec<i8> = b
        .iter()
        .enumerate()
        .map(|(i, &v)| quant::quantize_one(v, bscale[i % size]))
        .collect();
    let bdeq: Vec<f32> = bq
        .iter()
        .enumerate()
        .map(|(i, &q)| q as f32 * bscale[i % size])
        .collect();
    set_threads(1);
    let mut want_nt = vec![0.0f32; m * n];
    gemm_nt_acc(&a, &bdeq, m, k, n, &mut want_nt);
    let mut got_nt = vec![0.0f32; m * n];
    gemm_nt_i8_acc(&a, &bq, &bscale, m, k, n, &mut got_nt);
    assert_eq!(got_nt, want_nt, "int8 GEMM differs from dequantized f32");

    // SIMD-off vs resolved-mode parity before any nt timing: the
    // lane-striped scalar reference and the dispatched vector body must
    // agree bitwise.
    set_simd_mode(SimdMode::Off);
    let mut got_scalar = vec![0.0f32; m * n];
    gemm_nt_acc(&a, &bdeq, m, k, n, &mut got_scalar);
    assert_eq!(got_scalar, want_nt, "scalar nt GEMM differs from SIMD nt GEMM");
    set_simd_mode(simd_mode);

    // -- int4 × f32 mixed GEMM vs f32 ----------------------------------
    // The same QKᵀ layout with a packed int4 K operand (two codes per
    // byte along the shared dim, per-channel amax/7 scales — the
    // shipped recipe from kernels::quant). Parity gate first: fused
    // unpack+dequant must match the f32 kernel over the pre-dequantized
    // operand bit for bit.
    let (bq4, bscale4) = quant::quantize_cols_i4(&b, size, size);
    let bdeq4 = quant::dequantize_cols_i4(&bq4, &bscale4, size);
    let mut want_nt4 = vec![0.0f32; m * n];
    gemm_nt_acc(&a, &bdeq4, m, k, n, &mut want_nt4);
    let mut got_nt4 = vec![0.0f32; m * n];
    gemm_nt_i4_acc(&a, &bq4, &bscale4, m, k, n, &mut got_nt4);
    assert_eq!(got_nt4, want_nt4, "int4 GEMM differs from dequantized f32");

    // -- scalar vs SIMD timing, nt family ------------------------------
    set_simd_mode(SimdMode::Off);
    let r_nt_f32 = bench("gemm_nt_f32(scalar)", &opts, || {
        out.fill(0.0);
        gemm_nt_acc(&a, &b, m, k, n, &mut out);
    });
    println!("{}  ({:.2} GFLOP/s)", r_nt_f32.report_line(), gflop / (r_nt_f32.p50_ms() / 1e3));
    let r_nt_i8 = bench("gemm_nt_i8(scalar)", &opts, || {
        out.fill(0.0);
        gemm_nt_i8_acc(&a, &bq, &bscale, m, k, n, &mut out);
    });
    println!("{}  ({:.2} GFLOP/s)", r_nt_i8.report_line(), gflop / (r_nt_i8.p50_ms() / 1e3));
    let r_nt_i4 = bench("gemm_nt_i4(scalar)", &opts, || {
        out.fill(0.0);
        gemm_nt_i4_acc(&a, &bq4, &bscale4, m, k, n, &mut out);
    });
    println!("{}  ({:.2} GFLOP/s)", r_nt_i4.report_line(), gflop / (r_nt_i4.p50_ms() / 1e3));
    println!(
        "# int8-vs-f32 nt GEMM: {:.2}x the f32 time at ¼ the operand bytes; int4 {:.2}x at ⅛",
        r_nt_i8.p50_ms() / r_nt_f32.p50_ms(),
        r_nt_i4.p50_ms() / r_nt_f32.p50_ms()
    );

    set_simd_mode(simd_mode);
    let simd_isa = isa_name();
    let r_nt_f32_simd = bench(&format!("gemm_nt_f32({simd_isa})"), &opts, || {
        out.fill(0.0);
        gemm_nt_acc(&a, &b, m, k, n, &mut out);
    });
    println!(
        "{}  ({:.2} GFLOP/s)",
        r_nt_f32_simd.report_line(),
        gflop / (r_nt_f32_simd.p50_ms() / 1e3)
    );
    let r_nt_i8_simd = bench(&format!("gemm_nt_i8({simd_isa})"), &opts, || {
        out.fill(0.0);
        gemm_nt_i8_acc(&a, &bq, &bscale, m, k, n, &mut out);
    });
    println!(
        "{}  ({:.2} GFLOP/s)",
        r_nt_i8_simd.report_line(),
        gflop / (r_nt_i8_simd.p50_ms() / 1e3)
    );
    let r_nt_i4_simd = bench(&format!("gemm_nt_i4({simd_isa})"), &opts, || {
        out.fill(0.0);
        gemm_nt_i4_acc(&a, &bq4, &bscale4, m, k, n, &mut out);
    });
    println!(
        "{}  ({:.2} GFLOP/s)",
        r_nt_i4_simd.report_line(),
        gflop / (r_nt_i4_simd.p50_ms() / 1e3)
    );
    println!(
        "# simd speedup ({simd_isa}, nt): f32 {:.2}x, int8 {:.2}x, int4 {:.2}x (int4 target ≥ 2x)",
        r_nt_f32.p50_ms() / r_nt_f32_simd.p50_ms().max(1e-9),
        r_nt_i8.p50_ms() / r_nt_i8_simd.p50_ms().max(1e-9),
        r_nt_i4.p50_ms() / r_nt_i4_simd.p50_ms().max(1e-9)
    );

    // -- decode-path dot_i4 micro (GEMV shape) -------------------------
    // One f32 query row against every packed-int4 context row — the
    // exact inner loop of quantized decode attention. Repeated so the
    // timing clears bench_guard's --min-ms noise floor.
    let dot_reps = args.usize_or("dot-reps", 64);
    let half = size / 2;
    let mut sink = 0.0f32;
    set_simd_mode(SimdMode::Off);
    let r_dot_i4 = bench(&format!("dot_i4_gemv(scalar, {dot_reps}x)"), &opts, || {
        for _ in 0..dot_reps {
            for j in 0..n {
                sink += dot_i4(&a[..k], &bq4[j * half..(j + 1) * half], &bscale4);
            }
        }
    });
    println!("{}", r_dot_i4.report_line());
    set_simd_mode(simd_mode);
    let r_dot_i4_simd = bench(&format!("dot_i4_gemv({simd_isa}, {dot_reps}x)"), &opts, || {
        for _ in 0..dot_reps {
            for j in 0..n {
                sink += dot_i4(&a[..k], &bq4[j * half..(j + 1) * half], &bscale4);
            }
        }
    });
    println!("{}", r_dot_i4_simd.report_line());
    assert!(sink.is_finite(), "dot_i4 sink diverged");
    println!(
        "# dot_i4 GEMV: scalar {:.2} ms vs {simd_isa} {:.2} ms ({:.2}x)",
        r_dot_i4.p50_ms(),
        r_dot_i4_simd.p50_ms(),
        r_dot_i4.p50_ms() / r_dot_i4_simd.p50_ms().max(1e-9)
    );

    // -- dispatch overhead: per-region scoped spawn vs persistent pool -
    // A decode-sized parallel region (a handful of head rows, ~µs of
    // math) is launched once per layer per generated token, so the
    // *launch* cost is the metric. The scoped baseline reproduces the
    // retired implementation: one std::thread::scope spawn/join per
    // region. The pool path is the live `par_rows`. Both produce
    // bitwise-identical buffers (checked below); only the dispatch
    // mechanism differs.
    const DISP_ROWS: usize = 8;
    const DISP_LEN: usize = 64;
    let disp_reps = args.usize_or("dispatch-reps", 500);
    fn disp_work(r0: usize, chunk: &mut [f32]) {
        for (i, row) in chunk.chunks_mut(DISP_LEN).enumerate() {
            let base = (r0 + i) as f32;
            for (c, v) in row.iter_mut().enumerate() {
                *v = base + (c as f32).sqrt();
            }
        }
    }
    // The retired per-region spawn/join, preserved here as the baseline.
    fn scoped_par_rows(out: &mut [f32], threads: usize) {
        let rows = out.len() / DISP_LEN;
        let chunks = threads.max(1).min(rows);
        let per = rows.div_ceil(chunks);
        std::thread::scope(|s| {
            let mut rest = out;
            let mut row0 = 0;
            while !rest.is_empty() {
                let take = per.min(rows - row0);
                let (head, tail) = rest.split_at_mut(take * DISP_LEN);
                rest = tail;
                let r0 = row0;
                row0 += take;
                s.spawn(move || disp_work(r0, head));
            }
        });
    }
    set_threads(par_threads);
    let mut buf_scoped = vec![0.0f32; DISP_ROWS * DISP_LEN];
    let mut buf_pool = vec![0.0f32; DISP_ROWS * DISP_LEN];
    scoped_par_rows(&mut buf_scoped, par_threads);
    block_attn::kernels::par_rows(&mut buf_pool, DISP_LEN, 1, disp_work);
    assert_eq!(buf_scoped, buf_pool, "dispatch mechanisms disagree on the math");
    let r_disp_scoped = bench(&format!("dispatch_scoped({disp_reps}x)"), &opts, || {
        for _ in 0..disp_reps {
            scoped_par_rows(&mut buf_scoped, par_threads);
        }
    });
    println!("{}", r_disp_scoped.report_line());
    let r_disp_pool = bench(&format!("dispatch_pool({disp_reps}x)"), &opts, || {
        for _ in 0..disp_reps {
            block_attn::kernels::par_rows(&mut buf_pool, DISP_LEN, 1, disp_work);
        }
    });
    println!("{}", r_disp_pool.report_line());
    println!(
        "# dispatch overhead, {disp_reps} decode-sized regions: scoped {:.2} ms vs pool {:.2} ms ({:.2}x)",
        r_disp_scoped.p50_ms(),
        r_disp_pool.p50_ms(),
        r_disp_scoped.p50_ms() / r_disp_pool.p50_ms().max(1e-9),
    );

    // -- concurrent block prefill --------------------------------------
    // 8 independent 64-token blocks through the real engine, then the
    // end-to-end coordinator TTFT on a cold cache (miss prefill is the
    // dominant term). Outputs are identical at every thread count; only
    // the wall clock moves.
    let engine = backend_from_args(&args, "tiny")?;
    let n_blocks = args.usize_or("blocks", 8);
    let block_len = args.usize_or("block-len", 64);
    let vocab = engine.config().vocab;
    let blocks: Vec<Vec<i32>> = (0..n_blocks)
        .map(|_| (0..block_len).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let refs: Vec<&[i32]> = blocks.iter().map(|b| b.as_slice()).collect();
    let popts = BenchOpts { warmup_iters: 1, iters: 3, max_seconds: 300.0 };

    set_threads(1);
    let kv1 = engine.prefill_blocks(&refs)?;
    let r_p1 = bench("prefill_blocks(1 thread)", &popts, || {
        engine.prefill_blocks(&refs).expect("prefill_blocks");
    });
    println!("{}", r_p1.report_line());

    set_threads(par_threads);
    let kvn = engine.prefill_blocks(&refs)?;
    for ((k1, v1), (kn, vn)) in kv1.iter().zip(&kvn) {
        assert_eq!(k1, kn, "block K differs across thread counts");
        assert_eq!(v1, vn, "block V differs across thread counts");
    }
    let r_pn = bench(&format!("prefill_blocks({par_threads} threads)"), &popts, || {
        engine.prefill_blocks(&refs).expect("prefill_blocks");
    });
    println!("{}", r_pn.report_line());
    let speed_prefill = r_p1.p50_ms() / r_pn.p50_ms();
    println!("# prefill speedup: {speed_prefill:.2}x with {par_threads} threads");

    // Cold-cache TTFT through the coordinator (clear_cache each iter so
    // every block misses and goes through the concurrent path).
    let query: Vec<i32> = (0..32).map(|_| rng.below(vocab) as i32).collect();
    let req = Request {
        id: 1,
        blocks: blocks.clone(),
        query,
        max_new_tokens: 1,
        mode: AttentionMode::Block,
    };
    let mut coord = Coordinator::new(engine, 256 << 20);
    let mut ttft = [0.0f64; 2];
    for (slot, t) in [(0usize, 1usize), (1, par_threads)] {
        set_threads(t);
        let r = bench(&format!("coordinator_ttft({t} threads)"), &popts, || {
            coord.clear_cache();
            coord.process(&req).expect("process");
        });
        ttft[slot] = r.p50_ms();
        println!("{}", r.report_line());
    }
    let ttft_speedup = ttft[0] / ttft[1];
    println!("# TTFT cold-cache: {:.1} ms → {:.1} ms ({ttft_speedup:.2}x)", ttft[0], ttft[1]);

    // Warm-cache TTFT per KV tier: every block hits, so the timed path
    // is fetch (+ fused dequant on the quantized tiers) + Eq.-3
    // re-encode + context assembly + final prefill + the tier-precision
    // decode-context build. The quantized tiers pay the dequant but
    // store each block at ~¼ (int8) / ~⅛ (int4) the bytes (reported
    // alongside).
    set_threads(par_threads);
    let mut warm_ms = [0.0f64; 3];
    let mut tier_bytes = [0usize; 3];
    for (slot, prec) in [
        (0usize, KvPrecision::F32),
        (1, KvPrecision::Int8),
        (2, KvPrecision::Int4),
    ] {
        let tier_engine = backend_from_args(&args, "tiny")?;
        let mut tier_coord = Coordinator::with_kv_precision(tier_engine, 256 << 20, prec);
        tier_coord.process(&req).expect("cache warm-up");
        let r = bench(&format!("coordinator_ttft_warm({})", prec.as_str()), &popts, || {
            tier_coord.process(&req).expect("process");
        });
        warm_ms[slot] = r.p50_ms();
        tier_bytes[slot] = tier_coord.cache_stats().bytes;
        println!("{}", r.report_line());
    }
    println!(
        "# warm TTFT: f32 {:.1} ms vs int8 {:.1} ms vs int4 {:.1} ms; cache bytes {} vs {} ({:.1}% of f32) vs {} ({:.1}% of f32)",
        warm_ms[0],
        warm_ms[1],
        warm_ms[2],
        tier_bytes[0],
        tier_bytes[1],
        100.0 * tier_bytes[1] as f64 / tier_bytes[0].max(1) as f64,
        tier_bytes[2],
        100.0 * tier_bytes[2] as f64 / tier_bytes[0].max(1) as f64
    );
    set_threads(machine_threads);
    let pool_end = block_attn::kernels::pool_stats();
    eprintln!("{}", block_attn::kernels::pool_stats_line());

    let report = Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("gemm_size", Json::num(size as f64)),
        ("par_threads", Json::num(par_threads as f64)),
        ("machine_threads", Json::num(machine_threads as f64)),
        ("gemm_scalar_ms", Json::num(r_scalar.p50_ms())),
        ("gemm_tiled_ms", Json::num(r_tiled.p50_ms())),
        ("gemm_parallel_ms", Json::num(r_par.p50_ms())),
        ("gemm_speedup_tiled", Json::num(speed_tiled)),
        ("gemm_speedup_parallel", Json::num(speed_par)),
        ("prefill_blocks", Json::num(n_blocks as f64)),
        ("prefill_block_len", Json::num(block_len as f64)),
        ("prefill_1t_ms", Json::num(r_p1.p50_ms())),
        ("prefill_nt_ms", Json::num(r_pn.p50_ms())),
        ("prefill_speedup", Json::num(speed_prefill)),
        ("ttft_1t_ms", Json::num(ttft[0])),
        ("ttft_nt_ms", Json::num(ttft[1])),
        ("gemm_nt_f32_ms", Json::num(r_nt_f32.p50_ms())),
        ("gemm_nt_i8_ms", Json::num(r_nt_i8.p50_ms())),
        ("gemm_nt_i4_ms", Json::num(r_nt_i4.p50_ms())),
        ("gemm_nt_f32_simd_ms", Json::num(r_nt_f32_simd.p50_ms())),
        ("gemm_nt_i8_simd_ms", Json::num(r_nt_i8_simd.p50_ms())),
        ("gemm_nt_i4_simd_ms", Json::num(r_nt_i4_simd.p50_ms())),
        ("dot_i4_reps", Json::num(dot_reps as f64)),
        ("dot_i4_ms", Json::num(r_dot_i4.p50_ms())),
        ("dot_i4_simd_ms", Json::num(r_dot_i4_simd.p50_ms())),
        ("simd_isa", Json::str(simd_isa)),
        ("ttft_warm_f32_ms", Json::num(warm_ms[0])),
        ("ttft_warm_int8_ms", Json::num(warm_ms[1])),
        ("ttft_warm_int4_ms", Json::num(warm_ms[2])),
        ("kv_bytes_f32", Json::num(tier_bytes[0] as f64)),
        ("kv_bytes_int8", Json::num(tier_bytes[1] as f64)),
        ("kv_bytes_int4", Json::num(tier_bytes[2] as f64)),
        ("dispatch_reps", Json::num(disp_reps as f64)),
        ("dispatch_scoped_ms", Json::num(r_disp_scoped.p50_ms())),
        ("dispatch_pool_ms", Json::num(r_disp_pool.p50_ms())),
        ("pool_workers", Json::num(pool_end.workers as f64)),
        ("pool_jobs_executed", Json::num(pool_end.jobs_executed as f64)),
        ("pool_jobs_panicked", Json::num(pool_end.jobs_panicked as f64)),
        ("pool_queue_peak", Json::num(pool_end.queue_peak as f64)),
    ]);
    let out_path = args.str_or("json-out", "BENCH_kernels.json");
    std::fs::write(&out_path, format!("{report}\n"))?;
    eprintln!("# wrote {out_path}");
    Ok(())
}
