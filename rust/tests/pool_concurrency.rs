//! Lifecycle and concurrency battery for the persistent worker pool —
//! the substrate every kernel parallel region now dispatches through.
//!
//! What must hold (and what each test pins):
//!
//! * A panicking job is **contained**: no dead worker, no poisoned
//!   queue, no leaked in-flight count — later jobs still run and
//!   `wait_idle` still drains.
//! * Scoped regions re-raise the panic on the submitting thread only
//!   *after* the whole region has completed (sibling tasks always run).
//! * `Drop`/`shutdown` join the workers only after the queue drains,
//!   and submitting into a shut-down pool fails loudly instead of
//!   silently dropping the job.
//! * 10k tiny jobs across 1/2/3/8 workers complete **exactly once** —
//!   a seen-set plus a counter catches both lost wakeups in the
//!   condvar loop (jobs that never run) and double-execution.

use block_attn::util::pool::{ScopedJob, ThreadPool};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn panicking_job_does_not_deadlock_or_poison() {
    let pool = ThreadPool::new(2);
    let counter = Arc::new(AtomicUsize::new(0));
    // Interleave panicking jobs with normal ones; every normal job must
    // still run and the pool must still drain.
    for i in 0..60 {
        let c = counter.clone();
        if i % 10 == 3 {
            pool.spawn(move || panic!("job {i} exploded"));
        } else {
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
    }
    pool.wait_idle();
    assert_eq!(counter.load(Ordering::SeqCst), 54, "a surviving job was lost");
    let stats = pool.stats();
    assert_eq!(stats.jobs_panicked, 6, "panics must be counted, not fatal");
    assert_eq!(stats.jobs_executed, 60);
    // The pool is still fully functional after the panics.
    let h = pool.submit(|| 41 + 1);
    assert_eq!(h.join(), 42);
    pool.wait_idle();
}

#[test]
fn scoped_region_panic_propagates_after_siblings_finish() {
    let pool = ThreadPool::new(3);
    let ran = Arc::new(AtomicUsize::new(0));
    let tasks: Vec<ScopedJob<'_>> = (0..8)
        .map(|i| {
            let ran = ran.clone();
            Box::new(move || {
                if i == 2 {
                    panic!("task 2 exploded");
                }
                ran.fetch_add(1, Ordering::SeqCst);
            }) as ScopedJob<'_>
        })
        .collect();
    let result = catch_unwind(AssertUnwindSafe(|| {
        pool.run_scoped(|| {}, tasks);
    }));
    assert!(result.is_err(), "region panic must reach the submitting thread");
    // Every sibling ran even though one task panicked: the region
    // drains first, then re-raises.
    assert_eq!(ran.load(Ordering::SeqCst), 7);
    // Region-task panics are counted too (the region shim fields the
    // payload before the execution site's catch_unwind can see it).
    assert_eq!(pool.stats().jobs_panicked, 1, "region panic not counted");
    // And the pool survives for the next region.
    let mut touched = [false; 4];
    let tasks: Vec<ScopedJob<'_>> = touched
        .iter_mut()
        .map(|t| Box::new(move || *t = true) as ScopedJob<'_>)
        .collect();
    pool.run_scoped(|| {}, tasks);
    assert!(touched.iter().all(|&t| t));
}

#[test]
fn scoped_local_panic_still_waits_for_tasks() {
    // The caller's own closure panicking must not let the region return
    // (or unwind) while borrowed tasks are still in flight.
    let pool = ThreadPool::new(2);
    let ran = Arc::new(AtomicUsize::new(0));
    let tasks: Vec<ScopedJob<'_>> = (0..6)
        .map(|_| {
            let ran = ran.clone();
            Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                ran.fetch_add(1, Ordering::SeqCst);
            }) as ScopedJob<'_>
        })
        .collect();
    let result = catch_unwind(AssertUnwindSafe(|| {
        pool.run_scoped(|| panic!("local exploded"), tasks);
    }));
    assert!(result.is_err());
    assert_eq!(ran.load(Ordering::SeqCst), 6, "tasks must complete before the unwind");
}

#[test]
fn drop_joins_after_drain() {
    let counter = Arc::new(AtomicUsize::new(0));
    {
        let pool = ThreadPool::new(2);
        for _ in 0..40 {
            let c = counter.clone();
            pool.spawn(move || {
                std::thread::sleep(std::time::Duration::from_micros(200));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // `drop` runs here: shutdown must drain the queue before joining.
    }
    assert_eq!(counter.load(Ordering::SeqCst), 40, "drop lost queued jobs");
}

#[test]
fn spawn_into_shut_down_pool_fails_loudly() {
    let pool = ThreadPool::new(1);
    pool.shutdown();
    let r = catch_unwind(AssertUnwindSafe(|| pool.spawn(|| {})));
    assert!(r.is_err(), "spawn on a shut-down pool must panic, not drop the job");
    let r = catch_unwind(AssertUnwindSafe(|| {
        pool.run_scoped(|| {}, vec![Box::new(|| {}) as ScopedJob<'_>]);
    }));
    assert!(r.is_err(), "run_scoped on a shut-down pool must panic");
    // The loud failures must not have poisoned the pool's mutex: every
    // later call (stats, the idempotent shutdown, Drop at scope exit)
    // still works instead of cascading PoisonError panics — a poisoned
    // Drop would double-panic and abort the whole test binary.
    assert_eq!(pool.stats().jobs_executed, 0);
    pool.shutdown();
    assert_eq!(pool.threads(), 0);
}

/// 10k tiny jobs per worker count: each must run exactly once. The
/// seen-set (per-slot AtomicBool swap) catches double execution; the
/// counter + wait_idle catches lost wakeups (a job stranded in the
/// queue would leave `wait_idle` hanging or the counter short).
#[test]
fn stress_tiny_jobs_complete_exactly_once() {
    const JOBS: usize = 10_000;
    for workers in [1usize, 2, 3, 8] {
        let pool = ThreadPool::new(workers);
        let seen: Arc<Vec<AtomicBool>> =
            Arc::new((0..JOBS).map(|_| AtomicBool::new(false)).collect());
        let done = Arc::new(AtomicUsize::new(0));
        for i in 0..JOBS {
            let seen = seen.clone();
            let done = done.clone();
            pool.spawn(move || {
                let prev = seen[i].swap(true, Ordering::SeqCst);
                assert!(!prev, "job {i} ran twice ({workers} workers)");
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(
            done.load(Ordering::SeqCst),
            JOBS,
            "lost jobs at {workers} workers"
        );
        assert!(
            seen.iter().all(|s| s.load(Ordering::SeqCst)),
            "unexecuted slot at {workers} workers"
        );
        let stats = pool.stats();
        assert!(stats.jobs_executed >= JOBS as u64);
        assert_eq!(stats.jobs_panicked, 0);
        assert!(stats.queue_peak > 0, "queue peak must track the backlog");
    }
}

/// Scoped regions from several submitting threads at once, against one
/// small pool: help-while-wait must keep every region making progress
/// (no deadlock with more regions than workers) and every region must
/// see exactly its own results.
#[test]
fn concurrent_scoped_regions_share_one_pool() {
    let pool = Arc::new(ThreadPool::new(2));
    let mut joins = Vec::new();
    for t in 0..6u64 {
        let pool = pool.clone();
        joins.push(std::thread::spawn(move || {
            for round in 0..20u64 {
                let mut out = vec![0u64; 32];
                let (head, rest) = out.split_at_mut(16);
                let tasks: Vec<ScopedJob<'_>> = vec![Box::new(move || {
                    for (i, v) in rest.iter_mut().enumerate() {
                        *v = (16 + i) as u64;
                    }
                })];
                pool.run_scoped(
                    || {
                        for (i, v) in head.iter_mut().enumerate() {
                            *v = i as u64;
                        }
                    },
                    tasks,
                );
                for (i, &v) in out.iter().enumerate() {
                    assert_eq!(v, i as u64, "thread {t} round {round} corrupted");
                }
            }
        }));
    }
    for j in joins {
        j.join().expect("submitting thread panicked");
    }
}
