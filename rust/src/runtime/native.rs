//! [`NativeBackend`]: a pure-Rust Llama-style forward pass.
//!
//! Architecture (mirrors `python/compile/model.py` exactly): token
//! embedding (tied LM head), N pre-norm blocks (RMSNorm → GQA attention
//! with RoPE → RMSNorm → SwiGLU MLP), final RMSNorm. Parameters use the
//! same 11-tensor flat layout as the AOT manifest, so checkpoints are
//! interchangeable with the `xla` backend.
//!
//! Unlike the bucketed AOT engine, shapes are dynamic: capacities are
//! exact (`final_ctx_capacity(n) == n`) and no padding/trimming happens.
//! Weights initialize from a deterministic seeded stream, which makes
//! the whole serving pipeline — segmentation, content-addressed KV
//! reuse, Eq.-3 RoPE re-encoding, decode — testable with no artifacts
//! directory and no C dependencies.
//!
//! All dense math flows through [`crate::kernels`]: tiled GEMMs for the
//! projections, fused row kernels for norm/softmax/SwiGLU, and
//! row-parallel attention (queries in prefill, heads in decode). The
//! forward pass is written row-wise so that the hidden state of a token
//! depends only on itself and the keys it attends to, in ascending key
//! order; combined with the kernels' fixed reduction order this makes
//! the block-serving path *bitwise* faithful to the monolithic
//! computation in the single-segment case — for every `--threads`
//! setting — the invariant `tests/native_backend.rs` pins down.
//!
//! Independent blocks are embarrassingly parallel (the paper's §2.1
//! independence property), so [`Backend::prefill_blocks`] fans cache-miss
//! blocks out over the persistent kernel worker pool, one block per
//! worker; with fewer blocks than budgeted threads each block inherits
//! an even share of the budget for its inner kernels.
//!
//! The quantized KV tiers intersect this backend in exactly one place:
//! [`Backend::decode_ctx`]. The *prefill* side stays precision-agnostic
//! (blocks are quantized at cache insert and reconstructed to f32,
//! fused with the Eq.-3 re-encode, before `prefill_final_at` sees
//! them), but the *decode* side attends directly over the quantized
//! assembled context ([`DecodeCtx`]): the per-head attention inner
//! loops read int8 codes / packed int4 nibbles through
//! [`crate::kernels::dot_i8`] / [`crate::kernels::dot_i4`] (and the
//! `axpy` twins for V) — the same fused-dequant kernels the mixed
//! low-bit GEMMs are built from — so no dense f32 copy of the context
//! ever exists on the decode path. Because quantize and dequantize are
//! per-element and order-free and the fused kernels keep the ascending
//! accumulation order, the bitwise thread-determinism invariant above
//! holds unchanged under `--kv-quant int8|int4` — pinned by
//! `tests/kv_quant.rs` and the fused-vs-dense parity tests below.

use super::native_train;
use super::{Backend, CtxKv, DecodeCtx, DecodeOut, PrefillFinalOut, PrefillFullOut, TrainOut};
use crate::config::{ModelConfig, ParamSpec};
use crate::kernels::quant::I4_GROUP;
use crate::kernels::{
    axpy, axpy_i4, axpy_i8, dot, dot_i4, dot_i8, gemm_nn, gemm_nn_acc, gemm_nt_acc, par_map,
    par_rows, rms_norm_rows, softmax_inplace, swiglu_rows,
};
use crate::rope::RopeTable;
use crate::tensor::{argmax, Tensor, TensorF, TensorI};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::cell::RefCell;

// Parameter layout indices (checkpoint order; must match
// `python/compile/model.py::param_specs`).
pub(crate) const P_EMBED: usize = 0;
pub(crate) const P_LN1: usize = 1;
pub(crate) const P_WQ: usize = 2;
pub(crate) const P_WK: usize = 3;
pub(crate) const P_WV: usize = 4;
pub(crate) const P_WO: usize = 5;
pub(crate) const P_LN2: usize = 6;
pub(crate) const P_WG: usize = 7;
pub(crate) const P_WU: usize = 8;
pub(crate) const P_WD: usize = 9;
pub(crate) const P_FINAL_NORM: usize = 10;
pub(crate) const N_PARAMS: usize = 11;

/// The flattened parameter layout for one config (manifest order).
pub fn native_param_specs(cfg: &ModelConfig) -> Vec<ParamSpec> {
    let (n, dm, h, kv, f, v, hd) = (
        cfg.layers,
        cfg.d_model,
        cfg.heads,
        cfg.kv_heads,
        cfg.d_ff,
        cfg.vocab,
        cfg.head_dim,
    );
    let spec = |name: &str, shape: &[usize]| ParamSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
    };
    vec![
        spec("embed", &[v, dm]),
        spec("ln1", &[n, dm]),
        spec("wq", &[n, dm, h * hd]),
        spec("wk", &[n, dm, kv * hd]),
        spec("wv", &[n, dm, kv * hd]),
        spec("wo", &[n, h * hd, dm]),
        spec("ln2", &[n, dm]),
        spec("wg", &[n, dm, f]),
        spec("wu", &[n, dm, f]),
        spec("wd", &[n, f, dm]),
        spec("final_norm", &[dm]),
    ]
}

/// Deterministic seeded initialization (same recipe as
/// `model.py::init_params`, on this crate's splitmix stream: norms are
/// ones, residual-out projections are depth-scaled, everything else is
/// N(0, 0.02)).
pub fn init_params(cfg: &ModelConfig, specs: &[ParamSpec], seed: u64) -> Vec<TensorF> {
    let mut rng = Rng::new(seed);
    let resid_scale = 1.0 / (2.0 * cfg.layers as f64).sqrt();
    specs
        .iter()
        .map(|s| match s.name.as_str() {
            "ln1" | "ln2" | "final_norm" => Tensor::from_vec(&s.shape, vec![1.0f32; s.len()]),
            name => {
                let std = if name == "wo" || name == "wd" {
                    0.02 * resid_scale
                } else {
                    0.02
                };
                let data = (0..s.len()).map(|_| (rng.normal() * std) as f32).collect();
                Tensor::from_vec(&s.shape, data)
            }
        })
        .collect()
}

// -- parameter views -------------------------------------------------------

/// Borrowed view over the 11-tensor parameter list.
pub(crate) struct Weights<'a> {
    pub embed: &'a [f32],
    pub final_norm: &'a [f32],
    tensors: &'a [TensorF],
}

/// Per-layer weight slices.
pub(crate) struct LayerWeights<'a> {
    pub ln1: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub ln2: &'a [f32],
    pub wg: &'a [f32],
    pub wu: &'a [f32],
    pub wd: &'a [f32],
}

impl<'a> Weights<'a> {
    pub fn split(params: &'a [TensorF]) -> Weights<'a> {
        assert_eq!(params.len(), N_PARAMS, "native backend expects 11 parameter tensors");
        Weights {
            embed: params[P_EMBED].data(),
            final_norm: params[P_FINAL_NORM].data(),
            tensors: params,
        }
    }

    pub fn layer(&self, n: usize) -> LayerWeights<'a> {
        LayerWeights {
            ln1: self.tensors[P_LN1].axis0(n),
            wq: self.tensors[P_WQ].axis0(n),
            wk: self.tensors[P_WK].axis0(n),
            wv: self.tensors[P_WV].axis0(n),
            wo: self.tensors[P_WO].axis0(n),
            ln2: self.tensors[P_LN2].axis0(n),
            wg: self.tensors[P_WG].axis0(n),
            wu: self.tensors[P_WU].axis0(n),
            wd: self.tensors[P_WD].axis0(n),
        }
    }
}

fn check_tokens(cfg: &ModelConfig, tokens: &[i32]) -> Result<()> {
    ensure!(!tokens.is_empty(), "empty token sequence");
    for &t in tokens {
        ensure!(
            t >= 0 && (t as usize) < cfg.vocab,
            "token id {t} out of vocab range 0..{}",
            cfg.vocab
        );
    }
    Ok(())
}

/// One head's decode attention over one session's context: QKᵀ scores
/// over the tier-precision prefix (dequantization fused into the dot
/// kernel), then the f32 tail — including the just-written row at
/// `tail_len` — softmax, and the AV accumulation through the matching
/// `axpy` kernel, all in ascending token order.
///
/// This is the single copy of the fused tier-matching inner loop:
/// [`Backend::decode_ctx`] (one session, parallel over heads) and
/// [`Backend::decode_batch`] (one row per session × head) both call it,
/// so batched decode is bitwise identical to serial decode by
/// construction, not only by test. `scores` must hold `ctx.len() + 1`
/// entries; every entry is overwritten before use.
fn attend_ctx_head(
    ctx: &DecodeCtx,
    n: usize,
    kh: usize,
    qv: &[f32],
    scale: f32,
    scores: &mut [f32],
    ov: &mut [f32],
) {
    let (_, kvh, hd) = ctx.kv_dims();
    let plen = ctx.prefix_len();
    let tlen = ctx.tail_len();
    debug_assert_eq!(scores.len(), plen + tlen + 1);
    // Token groups of the int4 prefix scale table.
    let groups = plen.div_ceil(I4_GROUP);
    let kt = ctx.k_tail.axis0(n);
    let vt = ctx.v_tail.axis0(n);
    // Prefix keys at tier precision, ascending token order.
    match &ctx.prefix {
        CtxKv::F32 { k, .. } => {
            let kl = k.axis0(n);
            for (j, s) in scores.iter_mut().take(plen).enumerate() {
                *s = dot(qv, &kl[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd]) * scale;
            }
        }
        CtxKv::Int8 { k, .. } => {
            let srow = &k.scales[(n * kvh + kh) * hd..(n * kvh + kh + 1) * hd];
            for (j, s) in scores.iter_mut().take(plen).enumerate() {
                let off = ((n * plen + j) * kvh + kh) * hd;
                *s = dot_i8(qv, &k.q[off..off + hd], srow) * scale;
            }
        }
        CtxKv::Int4 { k, .. } => {
            for (j, s) in scores.iter_mut().take(plen).enumerate() {
                let at = ((n * groups + j / I4_GROUP) * kvh + kh) * hd;
                let srow = &k.scales[at..at + hd];
                let off = ((n * plen + j) * kvh + kh) * (hd / 2);
                *s = dot_i4(qv, &k.packed[off..off + hd / 2], srow) * scale;
            }
        }
    }
    // Generated tail (f32), including the just-appended token.
    for j in 0..=tlen {
        scores[plen + j] = dot(qv, &kt[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd]) * scale;
    }
    softmax_inplace(scores);
    ov.fill(0.0);
    match &ctx.prefix {
        CtxKv::F32 { v, .. } => {
            let vl = v.axis0(n);
            for j in 0..plen {
                axpy(scores[j], &vl[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd], ov);
            }
        }
        CtxKv::Int8 { v, .. } => {
            let srow = &v.scales[(n * kvh + kh) * hd..(n * kvh + kh + 1) * hd];
            for j in 0..plen {
                let off = ((n * plen + j) * kvh + kh) * hd;
                axpy_i8(scores[j], &v.q[off..off + hd], srow, ov);
            }
        }
        CtxKv::Int4 { v, .. } => {
            for j in 0..plen {
                let at = ((n * groups + j / I4_GROUP) * kvh + kh) * hd;
                let srow = &v.scales[at..at + hd];
                let off = ((n * plen + j) * kvh + kh) * (hd / 2);
                axpy_i4(scores[j], &v.packed[off..off + hd / 2], srow, ov);
            }
        }
    }
    for j in 0..=tlen {
        axpy(scores[plen + j], &vt[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd], ov);
    }
}

// -- the forward pass ------------------------------------------------------

/// Shared prefill body, free of `&self` so concurrent block prefills can
/// share one borrowed [`Weights`] view across worker threads.
///
/// `past = (past_k, past_v, past_len)` adds a cached-context prefix
/// every query token attends to; `pos0` is the RoPE position of the
/// first token. Returns `(last_logits_or_empty, k, v)` with KV shaped
/// `(layers, L, kv_heads, head_dim)`.
fn prefill_pass(
    cfg: &ModelConfig,
    rope: &RopeTable,
    w: &Weights<'_>,
    tokens: &[i32],
    pos0: usize,
    past: Option<(&TensorF, &TensorF, usize)>,
    want_logits: bool,
) -> Result<(Vec<f32>, TensorF, TensorF)> {
    check_tokens(cfg, tokens)?;
    let (dm, nh, kvh, hd, ff) = (cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.d_ff);
    let rep = nh / kvh;
    let scale = 1.0 / (hd as f32).sqrt();
    let l = tokens.len();

    let past_len = match past {
        Some((pk, pv, n)) => {
            let want = [cfg.layers, pk.dims().get(1).copied().unwrap_or(0), kvh, hd];
            ensure!(
                pk.dims() == &want[..] && pv.dims() == &want[..],
                "past KV dims {:?}/{:?} do not match (layers={}, C, kv_heads={}, head_dim={})",
                pk.dims(),
                pv.dims(),
                cfg.layers,
                kvh
            );
            ensure!(
                n <= pk.dims()[1],
                "past_len {n} exceeds context capacity {}",
                pk.dims()[1]
            );
            n
        }
        None => 0,
    };

    // x = embed[tokens]
    let mut x = vec![0.0f32; l * dm];
    for (t, &tok) in tokens.iter().enumerate() {
        let row = &w.embed[tok as usize * dm..(tok as usize + 1) * dm];
        x[t * dm..(t + 1) * dm].copy_from_slice(row);
    }

    let mut k_all = Tensor::zeros(&[cfg.layers, l, kvh, hd]);
    let mut v_all = Tensor::zeros(&[cfg.layers, l, kvh, hd]);

    // Scratch buffers reused across layers.
    let mut h1 = vec![0.0f32; l * dm];
    let mut rstd = vec![0.0f32; l];
    let mut q = vec![0.0f32; l * nh * hd];
    let mut kb = vec![0.0f32; l * kvh * hd];
    let mut vb = vec![0.0f32; l * kvh * hd];
    let mut o = vec![0.0f32; l * nh * hd];
    let mut mg = vec![0.0f32; l * ff];
    let mut mu = vec![0.0f32; l * ff];

    // Average attention work per query row; chunks smaller than ~32K
    // mul-adds are not worth a thread.
    let attn_row_cost = nh * hd * (past_len + l / 2 + 1) * 2;
    let attn_min_rows = ((1 << 15) / attn_row_cost.max(1)).max(1);

    for n in 0..cfg.layers {
        let lw = w.layer(n);

        // Attention sublayer.
        rms_norm_rows(&x, lw.ln1, cfg.norm_eps, l, dm, &mut h1, &mut rstd);
        gemm_nn(&h1, lw.wq, l, dm, nh * hd, &mut q);
        gemm_nn(&h1, lw.wk, l, dm, kvh * hd, &mut kb);
        gemm_nn(&h1, lw.wv, l, dm, kvh * hd, &mut vb);
        for t in 0..l {
            let pos = (pos0 + t) as i64;
            for h in 0..nh {
                rope.rotate_head(&mut q[(t * nh + h) * hd..(t * nh + h + 1) * hd], pos);
            }
            for h in 0..kvh {
                rope.rotate_head(&mut kb[(t * kvh + h) * hd..(t * kvh + h + 1) * hd], pos);
            }
        }
        k_all.axis0_mut(n).copy_from_slice(&kb);
        v_all.axis0_mut(n).copy_from_slice(&vb);

        let empty: &[f32] = &[];
        let (pk_l, pv_l) = match past {
            Some((pk, pv, _)) => (pk.axis0(n), pv.axis0(n)),
            None => (empty, empty),
        };
        // GQA attention, parallel over query rows: row `t` of `o` is a
        // function of query `t` and keys `0..=t` only, so the split is
        // invisible to the results (and to the block-serving prefix
        // invariant).
        let (q_r, kb_r, vb_r) = (&q, &kb, &vb);
        par_rows(&mut o, nh * hd, attn_min_rows, |t0, chunk| {
            let mut scores = vec![0.0f32; past_len + l];
            for (ti, orow) in chunk.chunks_mut(nh * hd).enumerate() {
                let t = t0 + ti;
                orow.fill(0.0);
                for h in 0..nh {
                    let kh = h / rep;
                    let qv = &q_r[(t * nh + h) * hd..(t * nh + h + 1) * hd];
                    let n_keys = past_len + t + 1;
                    for (j, s) in scores.iter_mut().take(past_len).enumerate() {
                        *s = dot(qv, &pk_l[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd]) * scale;
                    }
                    for j in 0..=t {
                        scores[past_len + j] =
                            dot(qv, &kb_r[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd]) * scale;
                    }
                    softmax_inplace(&mut scores[..n_keys]);
                    let ov = &mut orow[h * hd..(h + 1) * hd];
                    for j in 0..past_len {
                        axpy(scores[j], &pv_l[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd], ov);
                    }
                    for j in 0..=t {
                        axpy(
                            scores[past_len + j],
                            &vb_r[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd],
                            ov,
                        );
                    }
                }
            }
        });
        gemm_nn_acc(&o, lw.wo, l, nh * hd, dm, &mut x);

        // MLP sublayer.
        rms_norm_rows(&x, lw.ln2, cfg.norm_eps, l, dm, &mut h1, &mut rstd);
        gemm_nn(&h1, lw.wg, l, dm, ff, &mut mg);
        gemm_nn(&h1, lw.wu, l, dm, ff, &mut mu);
        swiglu_rows(&mut mg, &mu);
        gemm_nn_acc(&mg, lw.wd, l, ff, dm, &mut x);
    }

    let logits = if want_logits {
        let mut hf = vec![0.0f32; dm];
        let mut r1 = [0.0f32; 1];
        rms_norm_rows(&x[(l - 1) * dm..], w.final_norm, cfg.norm_eps, 1, dm, &mut hf, &mut r1);
        let mut out = vec![0.0f32; cfg.vocab];
        gemm_nt_acc(&hf, w.embed, 1, dm, cfg.vocab, &mut out);
        out
    } else {
        Vec::new()
    };
    Ok((logits, k_all, v_all))
}

// -- the backend -----------------------------------------------------------

/// Pure-Rust inference + training backend (see module docs).
pub struct NativeBackend {
    cfg: ModelConfig,
    specs: Vec<ParamSpec>,
    rope: RopeTable,
    params: RefCell<Vec<TensorF>>,
    /// Adam state (m, v), allocated on first train step.
    opt_state: RefCell<Option<(Vec<TensorF>, Vec<TensorF>)>>,
    train_shape: (usize, usize),
}

impl NativeBackend {
    /// Create a backend with deterministic seeded weights.
    pub fn new(cfg: ModelConfig, weight_seed: u64) -> NativeBackend {
        let specs = native_param_specs(&cfg);
        let params = init_params(&cfg, &specs, weight_seed);
        // `tiny` mirrors the python AOT train bucket (B=8, L=256);
        // other configs default to a modest packed batch.
        let train_shape = if cfg.name == "tiny" {
            (8, 256)
        } else {
            (4, cfg.max_len.min(256))
        };
        NativeBackend {
            rope: RopeTable::new(cfg.head_dim, cfg.rope_theta),
            specs,
            params: RefCell::new(params),
            opt_state: RefCell::new(None),
            train_shape,
            cfg,
        }
    }

    /// Override the `(batch, seq_len)` used by the training driver.
    pub fn with_train_shape(mut self, batch: usize, seq_len: usize) -> NativeBackend {
        assert!(batch > 0 && seq_len > 1);
        self.train_shape = (batch, seq_len);
        self
    }

    fn forward_prefill(
        &self,
        tokens: &[i32],
        pos0: usize,
        past: Option<(&TensorF, &TensorF, usize)>,
        want_logits: bool,
    ) -> Result<(Vec<f32>, TensorF, TensorF)> {
        let params = self.params.borrow();
        let w = Weights::split(&params);
        prefill_pass(&self.cfg, &self.rope, &w, tokens, pos0, past, want_logits)
    }
}

impl Backend for NativeBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn param_specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    fn set_params(&self, tensors: Vec<TensorF>) -> Result<()> {
        if tensors.len() != self.specs.len() {
            bail!(
                "expected {} parameter tensors, got {}",
                self.specs.len(),
                tensors.len()
            );
        }
        for (spec, t) in self.specs.iter().zip(&tensors) {
            if spec.shape != t.dims() {
                bail!("param '{}' shape {:?} != {:?}", spec.name, t.dims(), spec.shape);
            }
        }
        *self.params.borrow_mut() = tensors;
        Ok(())
    }

    fn params_host(&self) -> Result<Vec<TensorF>> {
        Ok(self.params.borrow().clone())
    }

    fn reset_opt_state(&self) {
        *self.opt_state.borrow_mut() = None;
    }

    fn prefill_full(&self, tokens: &[i32]) -> Result<PrefillFullOut> {
        let (last_logits, k, v) = self.forward_prefill(tokens, 0, None, true)?;
        Ok(PrefillFullOut { last_logits, k, v })
    }

    fn prefill_block(&self, tokens: &[i32]) -> Result<(TensorF, TensorF)> {
        let (_, k, v) = self.forward_prefill(tokens, 0, None, false)?;
        Ok((k, v))
    }

    /// Concurrent block prefill: blocks are independent by construction
    /// (block-diagonal attention, local positions), so each one runs on
    /// its own worker; with fewer blocks than threads each worker keeps
    /// an even share of the budget for its inner kernels. Results come
    /// back in input order and are bitwise identical to the serial path.
    fn prefill_blocks(&self, blocks: &[&[i32]]) -> Result<Vec<(TensorF, TensorF)>> {
        // Validate up front so errors surface deterministically.
        for b in blocks {
            check_tokens(&self.cfg, b)?;
        }
        let params = self.params.borrow();
        let w = Weights::split(&params);
        let (cfg, rope) = (&self.cfg, &self.rope);
        par_map(blocks, |_, toks| {
            prefill_pass(cfg, rope, &w, toks, 0, None, false).map(|(_, k, v)| (k, v))
        })
        .into_iter()
        .collect()
    }

    fn prefill_final_at(
        &self,
        tokens: &[i32],
        past_k: &TensorF,
        past_v: &TensorF,
        past_len: usize,
        q_pos0: usize,
    ) -> Result<PrefillFinalOut> {
        let (last_logits, k, v) =
            self.forward_prefill(tokens, q_pos0, Some((past_k, past_v, past_len)), true)?;
        Ok(PrefillFinalOut { last_logits, k, v })
    }

    fn decode(
        &self,
        token: i32,
        k_cache: &TensorF,
        v_cache: &TensorF,
        cache_len: usize,
    ) -> Result<DecodeOut> {
        check_tokens(&self.cfg, &[token])?;
        let cfg = &self.cfg;
        let (dm, nh, kvh, hd, ff) = (cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.d_ff);
        let rep = nh / kvh;
        let scale = 1.0 / (hd as f32).sqrt();
        let c = k_cache.dims().get(1).copied().unwrap_or(0);
        let want = [cfg.layers, c, kvh, hd];
        ensure!(
            k_cache.dims() == &want[..] && v_cache.dims() == &want[..],
            "decode cache dims {:?}/{:?} do not match model",
            k_cache.dims(),
            v_cache.dims()
        );
        ensure!(cache_len < c, "cache_len {cache_len} >= capacity {c}");

        let params = self.params.borrow();
        let w = Weights::split(&params);
        let mut k_out = k_cache.clone();
        let mut v_out = v_cache.clone();

        let mut x = vec![0.0f32; dm];
        x.copy_from_slice(&w.embed[token as usize * dm..(token as usize + 1) * dm]);

        let mut h1 = vec![0.0f32; dm];
        let mut rstd = [0.0f32; 1];
        let mut q = vec![0.0f32; nh * hd];
        let mut kb = vec![0.0f32; kvh * hd];
        let mut vb = vec![0.0f32; kvh * hd];
        let mut o = vec![0.0f32; nh * hd];
        let mut mg = vec![0.0f32; ff];
        let mut mu = vec![0.0f32; ff];
        let pos = cache_len as i64;

        // Per-head attention work. Decode dispatches to the persistent
        // worker pool once per layer per *token*; a dispatch is a queue
        // push + condvar wake (µs-scale), so the per-chunk floor sits
        // at ~32K mul-adds instead of the thread-spawn scale the scoped
        // implementation needed — decode-sized contexts start forking
        // as soon as a head's work covers the dispatch cost.
        let head_cost = (cache_len + 1) * hd * 2;
        let head_min_rows = ((1 << 15) / head_cost.max(1)).max(1);

        for n in 0..cfg.layers {
            let lw = w.layer(n);
            rms_norm_rows(&x, lw.ln1, cfg.norm_eps, 1, dm, &mut h1, &mut rstd);
            gemm_nn(&h1, lw.wq, 1, dm, nh * hd, &mut q);
            gemm_nn(&h1, lw.wk, 1, dm, kvh * hd, &mut kb);
            gemm_nn(&h1, lw.wv, 1, dm, kvh * hd, &mut vb);
            for h in 0..nh {
                self.rope.rotate_head(&mut q[h * hd..(h + 1) * hd], pos);
            }
            for h in 0..kvh {
                self.rope.rotate_head(&mut kb[h * hd..(h + 1) * hd], pos);
            }
            {
                let kl = k_out.axis0_mut(n);
                kl[cache_len * kvh * hd..(cache_len + 1) * kvh * hd].copy_from_slice(&kb);
                let vl = v_out.axis0_mut(n);
                vl[cache_len * kvh * hd..(cache_len + 1) * kvh * hd].copy_from_slice(&vb);
            }
            let kl = k_out.axis0(n);
            let vl = v_out.axis0(n);
            // Decode attention, parallel over heads (head rows of `o`
            // are contiguous and independent).
            let q_r = &q;
            par_rows(&mut o, hd, head_min_rows, |h0, chunk| {
                let mut scores = vec![0.0f32; cache_len + 1];
                for (hi, ov) in chunk.chunks_mut(hd).enumerate() {
                    let h = h0 + hi;
                    let kh = h / rep;
                    let qv = &q_r[h * hd..(h + 1) * hd];
                    for (j, s) in scores.iter_mut().enumerate() {
                        *s = dot(qv, &kl[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd]) * scale;
                    }
                    softmax_inplace(&mut scores);
                    ov.fill(0.0);
                    for (j, &p) in scores.iter().enumerate() {
                        axpy(p, &vl[(j * kvh + kh) * hd..(j * kvh + kh + 1) * hd], ov);
                    }
                }
            });
            gemm_nn_acc(&o, lw.wo, 1, nh * hd, dm, &mut x);

            rms_norm_rows(&x, lw.ln2, cfg.norm_eps, 1, dm, &mut h1, &mut rstd);
            gemm_nn(&h1, lw.wg, 1, dm, ff, &mut mg);
            gemm_nn(&h1, lw.wu, 1, dm, ff, &mut mu);
            swiglu_rows(&mut mg, &mu);
            gemm_nn_acc(&mg, lw.wd, 1, ff, dm, &mut x);
        }

        let mut hf = vec![0.0f32; dm];
        rms_norm_rows(&x, w.final_norm, cfg.norm_eps, 1, dm, &mut hf, &mut rstd);
        let mut logits = vec![0.0f32; cfg.vocab];
        gemm_nt_acc(&hf, w.embed, 1, dm, cfg.vocab, &mut logits);
        Ok(DecodeOut { logits, k_cache: k_out, v_cache: v_out })
    }

    /// Fused quantized decode — the serving decode path. The context
    /// prefix is read **at its stored tier**: per head, the QKᵀ scores
    /// over the prefix run through [`dot`] / [`dot_i8`] / [`dot_i4`]
    /// and the AV accumulation through the matching `axpy` kernel, all
    /// in ascending token order (prefix first, then the f32 tail), so
    /// the step is bitwise identical to materializing the dequantized
    /// prefix and decoding over dense f32 — at every thread count. The
    /// token's new KV lands in the context's tail in place: no
    /// capacity-sized cache is allocated or cloned per step.
    fn decode_ctx(&self, token: i32, ctx: &mut DecodeCtx) -> Result<Vec<f32>> {
        check_tokens(&self.cfg, &[token])?;
        let cfg = &self.cfg;
        let (dm, nh, kvh, hd, ff) = (cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.d_ff);
        let rep = nh / kvh;
        let scale = 1.0 / (hd as f32).sqrt();
        ensure!(
            ctx.kv_dims() == (cfg.layers, kvh, hd),
            "decode context dims {:?} do not match model (layers={}, kv_heads={}, head_dim={})",
            ctx.kv_dims(),
            cfg.layers,
            kvh,
            hd
        );
        ctx.reserve_one()?;
        let len = ctx.len();

        let params = self.params.borrow();
        let w = Weights::split(&params);

        let mut x = vec![0.0f32; dm];
        x.copy_from_slice(&w.embed[token as usize * dm..(token as usize + 1) * dm]);
        let mut h1 = vec![0.0f32; dm];
        let mut rstd = [0.0f32; 1];
        let mut q = vec![0.0f32; nh * hd];
        let mut kb = vec![0.0f32; kvh * hd];
        let mut vb = vec![0.0f32; kvh * hd];
        let mut o = vec![0.0f32; nh * hd];
        let mut mg = vec![0.0f32; ff];
        let mut mu = vec![0.0f32; ff];
        let pos = len as i64;

        // Same per-head dispatch floor as the dense `decode`.
        let head_cost = (len + 1) * hd * 2;
        let head_min_rows = ((1 << 15) / head_cost.max(1)).max(1);

        for n in 0..cfg.layers {
            let lw = w.layer(n);
            rms_norm_rows(&x, lw.ln1, cfg.norm_eps, 1, dm, &mut h1, &mut rstd);
            gemm_nn(&h1, lw.wq, 1, dm, nh * hd, &mut q);
            gemm_nn(&h1, lw.wk, 1, dm, kvh * hd, &mut kb);
            gemm_nn(&h1, lw.wv, 1, dm, kvh * hd, &mut vb);
            for h in 0..nh {
                self.rope.rotate_head(&mut q[h * hd..(h + 1) * hd], pos);
            }
            for h in 0..kvh {
                self.rope.rotate_head(&mut kb[h * hd..(h + 1) * hd], pos);
            }
            ctx.write_tail_row(n, &kb, &vb);
            let ctx_r: &DecodeCtx = ctx;
            let q_r = &q;
            par_rows(&mut o, hd, head_min_rows, |h0, chunk| {
                let mut scores = vec![0.0f32; len + 1];
                for (hi, ov) in chunk.chunks_mut(hd).enumerate() {
                    let h = h0 + hi;
                    let kh = h / rep;
                    let qv = &q_r[h * hd..(h + 1) * hd];
                    attend_ctx_head(ctx_r, n, kh, qv, scale, &mut scores, ov);
                }
            });
            gemm_nn_acc(&o, lw.wo, 1, nh * hd, dm, &mut x);

            rms_norm_rows(&x, lw.ln2, cfg.norm_eps, 1, dm, &mut h1, &mut rstd);
            gemm_nn(&h1, lw.wg, 1, dm, ff, &mut mg);
            gemm_nn(&h1, lw.wu, 1, dm, ff, &mut mu);
            swiglu_rows(&mut mg, &mu);
            gemm_nn_acc(&mg, lw.wd, 1, ff, dm, &mut x);
        }

        let mut hf = vec![0.0f32; dm];
        rms_norm_rows(&x, w.final_norm, cfg.norm_eps, 1, dm, &mut hf, &mut rstd);
        let mut logits = vec![0.0f32; cfg.vocab];
        gemm_nt_acc(&hf, w.embed, 1, dm, cfg.vocab, &mut logits);
        ctx.advance_tail();
        Ok(logits)
    }

    /// Batched continuous-batching decode: one forward pass advances
    /// every in-flight session by one token. Each session's row is an
    /// independent row of every GEMM (`m = batch` instead of `m = 1`),
    /// which turns the memory-bound per-session GEMV into one
    /// compute-dense GEMM dispatch per projection per layer — the
    /// throughput lever of the serving loop. Attention still runs
    /// per (session, head) through [`attend_ctx_head`], the same inner
    /// loop as [`Self::decode_ctx`], at each session's own length and
    /// KV tier (mixed tiers in one batch are fine).
    ///
    /// Bitwise identical to decoding the sessions one at a time: GEMM
    /// rows are independent with a fixed ascending-k reduction order
    /// (`kernels::gemm`), `rms_norm_rows`/`swiglu_rows` are row-local,
    /// and the attention kernel is literally shared — at every thread
    /// count (pinned by `tests/serving_batch.rs`).
    fn decode_batch(&self, ctxs: &mut [&mut DecodeCtx], last: &[i32]) -> Result<Vec<i32>> {
        ensure!(
            ctxs.len() == last.len(),
            "decode_batch: {} contexts vs {} tokens",
            ctxs.len(),
            last.len()
        );
        let bsz = ctxs.len();
        if bsz == 0 {
            return Ok(Vec::new());
        }
        check_tokens(&self.cfg, last)?;
        let cfg = &self.cfg;
        let (dm, nh, kvh, hd, ff) = (cfg.d_model, cfg.heads, cfg.kv_heads, cfg.head_dim, cfg.d_ff);
        let rep = nh / kvh;
        let scale = 1.0 / (hd as f32).sqrt();
        for ctx in ctxs.iter() {
            ensure!(
                ctx.kv_dims() == (cfg.layers, kvh, hd),
                "decode context dims {:?} do not match model (layers={}, kv_heads={}, head_dim={})",
                ctx.kv_dims(),
                cfg.layers,
                kvh,
                hd
            );
        }
        // Reserve every tail up front: all capacity errors surface
        // before any state is touched, so a failed batch leaves every
        // session's length unchanged.
        for ctx in ctxs.iter_mut() {
            ctx.reserve_one()?;
        }
        let lens: Vec<usize> = ctxs.iter().map(|c| c.len()).collect();

        let params = self.params.borrow();
        let w = Weights::split(&params);

        let mut x = vec![0.0f32; bsz * dm];
        for (i, &t) in last.iter().enumerate() {
            x[i * dm..(i + 1) * dm]
                .copy_from_slice(&w.embed[t as usize * dm..(t as usize + 1) * dm]);
        }
        let mut h1 = vec![0.0f32; bsz * dm];
        let mut rstd = vec![0.0f32; bsz];
        let mut q = vec![0.0f32; bsz * nh * hd];
        let mut kb = vec![0.0f32; bsz * kvh * hd];
        let mut vb = vec![0.0f32; bsz * kvh * hd];
        let mut o = vec![0.0f32; bsz * nh * hd];
        let mut mg = vec![0.0f32; bsz * ff];
        let mut mu = vec![0.0f32; bsz * ff];

        // Per-head dispatch floor at the mean session length (the floor
        // only shapes the parallel split, never the values — rows are
        // whole heads either way).
        let mean_len = lens.iter().sum::<usize>() / bsz;
        let head_cost = (mean_len + 1) * hd * 2;
        let head_min_rows = ((1 << 15) / head_cost.max(1)).max(1);

        for n in 0..cfg.layers {
            let lw = w.layer(n);
            rms_norm_rows(&x, lw.ln1, cfg.norm_eps, bsz, dm, &mut h1, &mut rstd);
            gemm_nn(&h1, lw.wq, bsz, dm, nh * hd, &mut q);
            gemm_nn(&h1, lw.wk, bsz, dm, kvh * hd, &mut kb);
            gemm_nn(&h1, lw.wv, bsz, dm, kvh * hd, &mut vb);
            for (i, &len) in lens.iter().enumerate() {
                let pos = len as i64;
                for h in 0..nh {
                    let at = (i * nh + h) * hd;
                    self.rope.rotate_head(&mut q[at..at + hd], pos);
                }
                for h in 0..kvh {
                    let at = (i * kvh + h) * hd;
                    self.rope.rotate_head(&mut kb[at..at + hd], pos);
                }
            }
            for (i, ctx) in ctxs.iter_mut().enumerate() {
                ctx.write_tail_row(
                    n,
                    &kb[i * kvh * hd..(i + 1) * kvh * hd],
                    &vb[i * kvh * hd..(i + 1) * kvh * hd],
                );
            }
            // Attention over all sessions' head rows in one dispatch;
            // row r of `o` is (session r / heads, head r % heads).
            let views: Vec<&DecodeCtx> = ctxs.iter().map(|c| &**c).collect();
            let q_r = &q;
            let views_r = &views;
            par_rows(&mut o, hd, head_min_rows, |r0, chunk| {
                let mut scores: Vec<f32> = Vec::new();
                for (ri, ov) in chunk.chunks_mut(hd).enumerate() {
                    let r = r0 + ri;
                    let ctx = views_r[r / nh];
                    let kh = (r % nh) / rep;
                    let qv = &q_r[r * hd..(r + 1) * hd];
                    scores.resize(ctx.len() + 1, 0.0);
                    attend_ctx_head(ctx, n, kh, qv, scale, &mut scores, ov);
                }
            });
            drop(views);
            gemm_nn_acc(&o, lw.wo, bsz, nh * hd, dm, &mut x);

            rms_norm_rows(&x, lw.ln2, cfg.norm_eps, bsz, dm, &mut h1, &mut rstd);
            gemm_nn(&h1, lw.wg, bsz, dm, ff, &mut mg);
            gemm_nn(&h1, lw.wu, bsz, dm, ff, &mut mu);
            swiglu_rows(&mut mg, &mu);
            gemm_nn_acc(&mg, lw.wd, bsz, ff, dm, &mut x);
        }

        let mut hf = vec![0.0f32; bsz * dm];
        rms_norm_rows(&x, w.final_norm, cfg.norm_eps, bsz, dm, &mut hf, &mut rstd);
        let mut logits = vec![0.0f32; bsz * cfg.vocab];
        gemm_nt_acc(&hf, w.embed, bsz, dm, cfg.vocab, &mut logits);
        for ctx in ctxs.iter_mut() {
            ctx.advance_tail();
        }
        Ok((0..bsz)
            .map(|i| argmax(&logits[i * cfg.vocab..(i + 1) * cfg.vocab]) as i32)
            .collect())
    }

    fn train_step(
        &self,
        step: usize,
        lr: f32,
        tokens: &TensorI,
        seg: &TensorI,
        loss_mask: &TensorF,
    ) -> Result<TrainOut> {
        let (loss, grads) = {
            let params = self.params.borrow();
            native_train::loss_and_grads(&self.cfg, &self.rope, &params, tokens, seg, loss_mask)?
        };
        let mut params = self.params.borrow_mut();
        let mut opt = self.opt_state.borrow_mut();
        if opt.is_none() {
            let zeros: Vec<TensorF> =
                self.specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
            *opt = Some((zeros.clone(), zeros));
        }
        let (m_state, v_state) = opt.as_mut().unwrap();
        native_train::adam_update(&mut params, grads, m_state, v_state, step, lr);
        Ok(TrainOut { loss })
    }

    fn final_ctx_capacity(&self, ctx_len: usize) -> Result<usize> {
        Ok(ctx_len)
    }

    fn final_q_capacity(&self) -> Result<usize> {
        Ok(self.cfg.max_len)
    }

    fn decode_ctx_capacity(&self) -> Result<usize> {
        Ok(self.cfg.max_len)
    }

    fn max_block_tokens(&self) -> Result<usize> {
        Ok(self.cfg.max_len)
    }

    fn train_shape(&self) -> Result<(usize, usize)> {
        Ok(self.train_shape)
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use crate::config::ModelConfig;

    /// A deliberately tiny config for fast unit tests.
    pub fn micro_config() -> ModelConfig {
        ModelConfig {
            name: "micro".into(),
            vocab: 24,
            d_model: 16,
            layers: 2,
            heads: 2,
            kv_heads: 1,
            head_dim: 8,
            d_ff: 32,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            max_len: 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_util::micro_config;
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::new(micro_config(), 7)
    }

    #[test]
    fn specs_match_python_layout() {
        let cfg = ModelConfig::builtin("tiny").unwrap();
        let specs = native_param_specs(&cfg);
        assert_eq!(specs.len(), N_PARAMS);
        assert_eq!(specs[P_EMBED].shape, vec![261, 128]);
        assert_eq!(specs[P_WQ].shape, vec![4, 128, 128]);
        assert_eq!(specs[P_WK].shape, vec![4, 128, 64]);
        assert_eq!(specs[P_WO].shape, vec![4, 128, 128]);
        assert_eq!(specs[P_WG].shape, vec![4, 128, 344]);
        assert_eq!(specs[P_WD].shape, vec![4, 344, 128]);
        assert_eq!(specs[P_FINAL_NORM].shape, vec![128]);
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let cfg = micro_config();
        let specs = native_param_specs(&cfg);
        let a = init_params(&cfg, &specs, 1);
        let b = init_params(&cfg, &specs, 1);
        let c = init_params(&cfg, &specs, 2);
        assert_eq!(a[P_EMBED], b[P_EMBED]);
        assert!(a[P_EMBED].max_abs_diff(&c[P_EMBED]) > 1e-4);
        // Norm weights start at exactly one.
        assert!(a[P_LN1].data().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn prefill_full_shapes_and_determinism() {
        let b = backend();
        let toks = vec![1, 2, 3, 4, 5, 6, 7];
        let a = b.prefill_full(&toks).unwrap();
        let c = b.prefill_full(&toks).unwrap();
        assert_eq!(a.last_logits.len(), 24);
        assert!(a.last_logits.iter().all(|x| x.is_finite()));
        assert_eq!(a.k.dims(), &[2, 7, 1, 8]);
        assert_eq!(a.v.dims(), &[2, 7, 1, 8]);
        assert_eq!(a.last_logits, c.last_logits);
        assert_eq!(a.k, c.k);
    }

    #[test]
    fn prefill_rejects_bad_tokens() {
        let b = backend();
        assert!(b.prefill_full(&[]).is_err());
        assert!(b.prefill_full(&[0, 24]).is_err());
        assert!(b.prefill_full(&[-1]).is_err());
    }

    #[test]
    fn prefill_blocks_matches_serial_bitwise() {
        let b = backend();
        let blocks: Vec<Vec<i32>> = vec![
            vec![1, 2, 3, 4, 5],
            vec![6, 7],
            vec![8, 9, 10, 11, 12, 13, 14, 15, 16],
            vec![1, 2, 3, 4, 5], // duplicate content
        ];
        let refs: Vec<&[i32]> = blocks.iter().map(|b| b.as_slice()).collect();
        let batch = b.prefill_blocks(&refs).unwrap();
        assert_eq!(batch.len(), blocks.len());
        for (toks, (k, v)) in blocks.iter().zip(&batch) {
            let (ks, vs) = b.prefill_block(toks).unwrap();
            assert_eq!(k, &ks, "K differs from serial prefill");
            assert_eq!(v, &vs, "V differs from serial prefill");
        }
        // Errors propagate.
        assert!(b.prefill_blocks(&[&[1], &[999]]).is_err());
    }

    #[test]
    fn decode_appends_kv_at_cache_len() {
        let b = backend();
        let pre = b.prefill_full(&[1, 2, 3]).unwrap();
        let cap = 10;
        // Assemble the dense cache: copy the 3-token prefix per layer.
        let mut kc = b.kv_zeros(cap);
        let mut vc = b.kv_zeros(cap);
        let row = 8;
        for n in 0..2 {
            kc.axis0_mut(n)[..3 * row].copy_from_slice(&pre.k.axis0(n)[..3 * row]);
            vc.axis0_mut(n)[..3 * row].copy_from_slice(&pre.v.axis0(n)[..3 * row]);
        }
        let out = b.decode(4, &kc, &vc, 3).unwrap();
        assert!(out.logits.iter().all(|x| x.is_finite()));
        let l0 = out.k_cache.axis0(0);
        assert!(l0[3 * row..4 * row].iter().any(|&x| x != 0.0));
        assert!(l0[4 * row..5 * row].iter().all(|&x| x == 0.0));
        // Deterministic.
        let out2 = b.decode(4, &kc, &vc, 3).unwrap();
        assert_eq!(out.logits, out2.logits);
        // Capacity guard.
        assert!(b.decode(4, &kc, &vc, 10).is_err());
    }

    /// The fused quantized decode must be **bitwise** equal to the
    /// dense bridge (the default `Backend::decode_ctx` body:
    /// dequantize-materialize, dense `decode`, feed the row back) at
    /// every tier — the property that lets the serving stack route
    /// decode attention over codes without renegotiating any numeric
    /// contract. The quantized tiers must also actually differ from
    /// f32 (they are lossy; a pass-through would fake the parity).
    #[test]
    fn decode_ctx_fused_matches_dense_bridge_bitwise() {
        use crate::config::KvPrecision;
        let b = backend();
        let pre = b.prefill_full(&[1, 2, 3, 4, 5]).unwrap();
        let cap = b.decode_ctx_capacity().unwrap();
        let mut first_logits: Vec<Vec<f32>> = Vec::new();
        for prec in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
            let mut fused = DecodeCtx::new(pre.k.clone(), pre.v.clone(), prec, cap).unwrap();
            let mut dense = DecodeCtx::new(pre.k.clone(), pre.v.clone(), prec, cap).unwrap();
            assert_eq!(fused.precision(), prec);
            let mut tok = 6i32;
            for step in 0..6 {
                let lf = b.decode_ctx(tok, &mut fused).unwrap();
                let (kc, vc) = dense.to_dense(cap).unwrap();
                let out = b.decode(tok, &kc, &vc, dense.len()).unwrap();
                dense.push_row_from_dense(&out.k_cache, &out.v_cache).unwrap();
                assert_eq!(
                    lf, out.logits,
                    "{prec:?} fused decode differs from the dense bridge at step {step}"
                );
                if step == 0 {
                    first_logits.push(lf.clone());
                }
                tok = crate::tensor::argmax(&lf) as i32;
            }
            assert_eq!(fused.len(), dense.len());
            assert_eq!(fused.len(), 5 + 6);
        }
        assert_ne!(first_logits[0], first_logits[1], "int8 tier must be lossy vs f32");
        assert_ne!(first_logits[0], first_logits[2], "int4 tier must be lossy vs f32");
        assert_ne!(first_logits[1], first_logits[2], "int4 must differ from int8");
    }

    /// The f32-tier `decode_ctx` reproduces the legacy dense `decode`
    /// loop bit for bit — the refactor that removed the
    /// capacity-sized clone-per-step must be numerically invisible.
    #[test]
    fn decode_ctx_f32_matches_legacy_dense_decode() {
        use crate::config::KvPrecision;
        let b = backend();
        let toks = [1, 2, 3, 4, 5, 6, 7];
        let pre = b.prefill_full(&toks).unwrap();
        let cap = 24;
        // Legacy path: dense cache at fixed capacity, cloned per step.
        let mut kc = b.kv_zeros(cap);
        let mut vc = b.kv_zeros(cap);
        let row = 8;
        for n in 0..2 {
            kc.axis0_mut(n)[..toks.len() * row].copy_from_slice(pre.k.axis0(n));
            vc.axis0_mut(n)[..toks.len() * row].copy_from_slice(pre.v.axis0(n));
        }
        let mut legacy = Vec::new();
        let mut len = toks.len();
        let mut tok = 8i32;
        for _ in 0..5 {
            let out = b.decode(tok, &kc, &vc, len).unwrap();
            kc = out.k_cache;
            vc = out.v_cache;
            len += 1;
            tok = crate::tensor::argmax(&out.logits) as i32;
            legacy.push(out.logits);
        }
        // DecodeCtx path.
        let mut ctx = DecodeCtx::new(pre.k.clone(), pre.v.clone(), KvPrecision::F32, cap).unwrap();
        let mut tok = 8i32;
        for want in &legacy {
            let logits = b.decode_ctx(tok, &mut ctx).unwrap();
            assert_eq!(&logits, want, "f32 decode_ctx drifted from the legacy decode");
            tok = crate::tensor::argmax(&logits) as i32;
        }
    }

    /// `decode_batch` must be bitwise identical to advancing each
    /// session serially through `decode_ctx` — tokens and KV tails —
    /// including sessions at different lengths and mixed KV tiers in
    /// one batch. (The thread-count sweep lives in
    /// `tests/serving_batch.rs`; this pins the single-process contract.)
    #[test]
    fn decode_batch_matches_serial_decode_ctx_bitwise() {
        use crate::config::KvPrecision;
        let b = backend();
        let cap = b.decode_ctx_capacity().unwrap();
        let prompts: [&[i32]; 3] = [&[1, 2, 3, 4, 5], &[6, 7], &[8, 9, 10, 11, 12, 13, 2, 1]];
        let tiers = [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4];
        let build = |b: &NativeBackend| -> (Vec<DecodeCtx>, Vec<i32>) {
            let mut ctxs = Vec::new();
            let mut first = Vec::new();
            for (toks, prec) in prompts.iter().zip(tiers) {
                let pre = b.prefill_full(toks).unwrap();
                first.push(argmax(&pre.last_logits) as i32);
                ctxs.push(DecodeCtx::new(pre.k, pre.v, prec, cap).unwrap());
            }
            (ctxs, first)
        };
        // Serial reference: one session at a time.
        let (mut serial, mut stok) = build(&b);
        let mut serial_tokens: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        for _ in 0..6 {
            for (i, ctx) in serial.iter_mut().enumerate() {
                let logits = b.decode_ctx(stok[i], ctx).unwrap();
                stok[i] = argmax(&logits) as i32;
                serial_tokens[i].push(stok[i]);
            }
        }
        // Batched: all sessions per round through one dispatch.
        let (mut batch, mut btok) = build(&b);
        let mut batch_tokens: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        for _ in 0..6 {
            let mut refs: Vec<&mut DecodeCtx> = batch.iter_mut().collect();
            let next = b.decode_batch(&mut refs, &btok).unwrap();
            for (i, &t) in next.iter().enumerate() {
                btok[i] = t;
                batch_tokens[i].push(t);
            }
        }
        assert_eq!(serial_tokens, batch_tokens, "batched tokens differ from serial");
        for (s, bc) in serial.iter().zip(&batch) {
            let (ks, vs) = s.to_dense(cap).unwrap();
            let (kb, vb) = bc.to_dense(cap).unwrap();
            assert_eq!(ks, kb, "batched K tail differs from serial");
            assert_eq!(vs, vb, "batched V tail differs from serial");
        }

        // Validation: an empty batch is a no-op; a malformed batch
        // errors before touching any session.
        let mut none: Vec<&mut DecodeCtx> = Vec::new();
        assert!(b.decode_batch(&mut none, &[]).unwrap().is_empty());
        let len_before = batch[0].len();
        let mut one: Vec<&mut DecodeCtx> = batch.iter_mut().take(1).collect();
        assert!(b.decode_batch(&mut one, &[1, 2]).is_err(), "length mismatch must error");
        assert!(b.decode_batch(&mut one, &[999]).is_err(), "bad token must error");
        drop(one);
        assert_eq!(batch[0].len(), len_before, "failed batch must not advance sessions");
    }

    #[test]
    fn set_params_checks_layout() {
        let b = backend();
        let ps = b.params_host().unwrap();
        assert!(b.set_params(ps.clone()).is_ok());
        let mut bad = ps;
        bad.pop();
        assert!(b.set_params(bad).is_err());
    }

    #[test]
    fn checkpoint_roundtrip_via_backend() {
        let b = backend();
        let dir = std::env::temp_dir().join("block_attn_native_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("micro.bin");
        b.save_params_file(&path).unwrap();
        let b2 = NativeBackend::new(micro_config(), 999);
        let before = b2.prefill_full(&[1, 2, 3]).unwrap().last_logits;
        b2.load_params_file(&path).unwrap();
        let after = b2.prefill_full(&[1, 2, 3]).unwrap().last_logits;
        let want = b.prefill_full(&[1, 2, 3]).unwrap().last_logits;
        assert_ne!(before, after, "checkpoint load must change the weights");
        assert_eq!(after, want, "checkpoint must reproduce the source model");
    }

    #[test]
    fn capacities_are_exact() {
        let b = backend();
        assert_eq!(b.final_ctx_capacity(37).unwrap(), 37);
        assert_eq!(b.decode_ctx_capacity().unwrap(), 64);
        assert_eq!(b.max_block_tokens().unwrap(), 64);
        assert_eq!(b.final_q_capacity().unwrap(), 64);
        assert_eq!(b.kv_zeros(5).dims(), &[2, 5, 1, 8]);
    }
}
