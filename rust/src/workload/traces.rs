//! Serving traces: query streams with Zipf-skewed passage reuse.
//!
//! The paper's efficiency argument (§3.7) assumes passages recur across
//! requests ("passages in the external databases might have been
//! computed"). This module materializes that assumption: a fixed passage
//! pool, and queries whose retrieved sets are drawn Zipf-skewed from the
//! pool — hot passages appear in many requests.

use super::rag::{RagGen, RagVariant};
use super::Sample;
use crate::util::rng::Rng;

/// A pool of passages + a query stream over them.
pub struct RagTrace {
    /// All distinct passages (the "external database").
    pub pool: Vec<String>,
    /// Gold (subject-passage index, answer) metadata per pool entry.
    answers: Vec<(String, String)>, // (query, answer) answered by pool[i]
}

impl RagTrace {
    /// Build a pool of `pool_size` fact passages.
    pub fn build(rng: &mut Rng, pool_size: usize) -> RagTrace {
        let gen = RagGen::new(RagVariant::OneHopEasy, rng, pool_size * 2);
        let mut pool = Vec::with_capacity(pool_size);
        let mut answers = Vec::with_capacity(pool_size);
        let mut seen = std::collections::HashSet::new();
        while pool.len() < pool_size {
            let s = gen.sample(rng);
            // Take the gold passage of each generated sample.
            for (b, _) in s.blocks.iter().zip(0..) {
                if b.contains(&format!("is {} .", s.answer)) && seen.insert(b.clone()) {
                    pool.push(b.clone());
                    answers.push((s.query.clone(), s.answer.clone()));
                    break;
                }
            }
        }
        RagTrace { pool, answers }
    }

    /// Draw one request: `k` passages Zipf-sampled from the pool (gold
    /// passage guaranteed present), query answerable from the gold one.
    pub fn request(&self, rng: &mut Rng, k: usize, zipf_s: f64) -> Sample {
        let gold = rng.zipf(self.pool.len(), zipf_s);
        let mut idxs = vec![gold];
        while idxs.len() < k.min(self.pool.len()) {
            let i = rng.zipf(self.pool.len(), zipf_s);
            if !idxs.contains(&i) {
                idxs.push(i);
            }
        }
        rng.shuffle(&mut idxs[..]);
        let (query, answer) = self.answers[gold].clone();
        Sample {
            blocks: idxs.iter().map(|&i| self.pool[i].clone()).collect(),
            query,
            response: self.pool[gold].clone(),
            answer,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_is_distinct() {
        let mut rng = Rng::new(1);
        let tr = RagTrace::build(&mut rng, 50);
        let set: std::collections::HashSet<_> = tr.pool.iter().collect();
        assert_eq!(set.len(), 50);
    }

    #[test]
    fn requests_reuse_hot_passages() {
        let mut rng = Rng::new(2);
        let tr = RagTrace::build(&mut rng, 100);
        let mut counts = vec![0usize; 100];
        for _ in 0..200 {
            let s = tr.request(&mut rng, 5, 1.1);
            assert_eq!(s.blocks.len(), 5);
            for b in &s.blocks {
                let i = tr.pool.iter().position(|p| p == b).unwrap();
                counts[i] += 1;
            }
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // Top passage reused far more than the median one.
        assert!(sorted[0] >= 20, "head too cold: {}", sorted[0]);
        assert!(sorted[0] > sorted[50] * 3);
    }

    #[test]
    fn gold_passage_always_present() {
        let mut rng = Rng::new(3);
        let tr = RagTrace::build(&mut rng, 40);
        for _ in 0..50 {
            let s = tr.request(&mut rng, 4, 1.2);
            assert!(
                s.blocks.iter().any(|b| b.contains(&format!("is {} .", s.answer))),
                "gold missing"
            );
        }
    }
}
