//! Cache-aware prefill planning.
//!
//! Given a request's context blocks, the scheduler decides which blocks
//! must be computed (cache misses), assigns every block its offset in
//! the assembled prompt, and pins cache entries so eviction cannot race
//! an admitted request. The plan is the unit the batcher schedules.

use crate::kvcache::{block_key, BlockKvCache};

/// One block in a prefill plan.
#[derive(Debug, Clone)]
pub struct PlanItem {
    /// Content hash of the block tokens.
    pub key: u128,
    /// Token offset of this block in the assembled prompt.
    pub offset: usize,
    pub len: usize,
    /// True if the KV states were already cached (pinned by planning).
    pub cached: bool,
}

/// A full prefill plan for one request's context.
#[derive(Debug, Clone)]
pub struct PrefillPlan {
    pub items: Vec<PlanItem>,
    /// Total context tokens (== offset + len of the last block).
    pub total_tokens: usize,
}

impl PrefillPlan {
    pub fn cached_count(&self) -> usize {
        self.items.iter().filter(|i| i.cached).count()
    }

    /// Tokens whose KV must actually be computed (the paper's saved
    /// computation is `total_tokens - miss_tokens`).
    pub fn miss_tokens(&self) -> usize {
        self.items
            .iter()
            .filter(|i| !i.cached)
            .map(|i| i.len)
            .sum()
    }

    /// Invariant: blocks tile the context exactly once, in order.
    pub fn covers_exactly(&self) -> bool {
        let mut at = 0;
        for it in &self.items {
            if it.offset != at {
                return false;
            }
            at += it.len;
        }
        at == self.total_tokens
    }
}

/// The planner. (Stateless today; owns admission policy knobs as the
/// system grows — kept as a struct so the batcher can carry it.)
#[derive(Debug, Default)]
pub struct Scheduler {}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler {}
    }

    /// Build a plan for `blocks`, pinning every cached block. Duplicate
    /// blocks within one request reuse the same cache entry but still
    /// occupy distinct offsets.
    pub fn plan(&self, blocks: &[Vec<i32>], cache: &mut BlockKvCache) -> PrefillPlan {
        let mut items = Vec::with_capacity(blocks.len());
        let mut offset = 0;
        for b in blocks {
            let key = block_key(b);
            let cached = cache.lookup_pin(key);
            items.push(PlanItem { key, offset, len: b.len(), cached });
            offset += b.len();
        }
        PrefillPlan { items, total_tokens: offset }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rope::RopeTable;
    use crate::tensor::Tensor;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::prop_assert;

    fn cache() -> BlockKvCache {
        BlockKvCache::new(RopeTable::new(8, 10000.0), 0)
    }

    fn fake_kv(len: usize) -> (crate::tensor::TensorF, crate::tensor::TensorF) {
        (Tensor::zeros(&[1, len, 1, 8]), Tensor::zeros(&[1, len, 1, 8]))
    }

    #[test]
    fn plan_offsets_are_cumulative() {
        let mut c = cache();
        let blocks = vec![vec![1, 2, 3], vec![4, 5], vec![6]];
        let plan = Scheduler::new().plan(&blocks, &mut c);
        assert_eq!(plan.total_tokens, 6);
        assert_eq!(plan.items[0].offset, 0);
        assert_eq!(plan.items[1].offset, 3);
        assert_eq!(plan.items[2].offset, 5);
        assert!(plan.covers_exactly());
        assert_eq!(plan.cached_count(), 0);
        assert_eq!(plan.miss_tokens(), 6);
    }

    #[test]
    fn plan_sees_cache_hits() {
        let mut c = cache();
        let b1 = vec![1, 2, 3];
        let (k, v) = fake_kv(3);
        c.insert_pinned(block_key(&b1), k, v);
        c.unpin(block_key(&b1));
        let blocks = vec![b1.clone(), vec![9, 9]];
        let plan = Scheduler::new().plan(&blocks, &mut c);
        assert!(plan.items[0].cached);
        assert!(!plan.items[1].cached);
        assert_eq!(plan.miss_tokens(), 2);
        // Planning pinned the hit.
        c.unpin(block_key(&b1));
    }

    #[test]
    fn same_content_same_key_different_offsets() {
        let mut c = cache();
        let b = vec![7, 8];
        let blocks = vec![b.clone(), b.clone()];
        let plan = Scheduler::new().plan(&blocks, &mut c);
        assert_eq!(plan.items[0].key, plan.items[1].key);
        assert_ne!(plan.items[0].offset, plan.items[1].offset);
    }

    #[test]
    fn prop_plan_always_tiles_context() {
        prop::check("plan-tiles", 0xBEEF, 300, |rng: &mut Rng| {
            let mut c = cache();
            let nblocks = rng.range(1, 12);
            let blocks: Vec<Vec<i32>> = (0..nblocks)
                .map(|_| {
                    let len = rng.range(1, 20);
                    (0..len).map(|_| rng.below(50) as i32).collect()
                })
                .collect();
            // Pre-cache a random subset.
            for b in &blocks {
                if rng.chance(0.5) {
                    let (k, v) = fake_kv(b.len());
                    let key = block_key(b);
                    if !c.contains(key) {
                        c.insert_pinned(key, k, v);
                        c.unpin(key);
                    }
                }
            }
            let plan = Scheduler::new().plan(&blocks, &mut c);
            prop_assert!(plan.covers_exactly(), "plan does not tile");
            let total: usize = blocks.iter().map(|b| b.len()).sum();
            prop_assert!(plan.total_tokens == total, "token total mismatch");
            prop_assert!(
                plan.miss_tokens() <= total,
                "miss tokens exceed total"
            );
            Ok(())
        });
    }
}
