//! Model / artifact configuration.
//!
//! The single source of truth is `artifacts/manifest.json`, written by
//! `python/compile/aot.py` at build time. It describes every model config
//! (dimensions, parameter layout, initial-parameter file) and every AOT
//! entry point (HLO file + static shapes). The Rust side never hardcodes
//! shapes — everything is read from the manifest.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Storage precision of cached block KV states (the `BlockKvCache`
/// tier) **and** of the assembled decode-path context attended to by
/// `Backend::decode_ctx`.
///
/// * `F32` — full-precision storage; cached reuse is bit-lossless.
/// * `Int8` — symmetric int8 codes with per-(layer, head, channel) f32
///   scales (see `kernels::quant`): ~¼ the bytes, so ~4× the blocks
///   per byte budget. Accuracy contract: decode-logit cosine
///   similarity vs the f32 tier ≥ 0.999 on the workload traces
///   (`tests/kv_quant.rs`).
/// * `Int4` — packed 4-bit codes (two per byte along the channel axis)
///   with group-wise f32 scales per (layer, head, channel, 32-token
///   group): ~⅛ the bytes (≤ 16% with scales), so ~8× the blocks per
///   byte budget. Accuracy contract: decode-logit cosine ≥ 0.99 on the
///   same traces.
///
/// Every tier keeps output bitwise identical across thread counts
/// because quantization is per-element and order-free.
///
/// Resolution order: `--kv-quant f32|int8|int4` >
/// `$BLOCK_ATTN_KV_QUANT` > `F32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvPrecision {
    #[default]
    F32,
    Int8,
    Int4,
}

impl KvPrecision {
    pub fn parse(s: &str) -> Result<KvPrecision> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "full" => KvPrecision::F32,
            "int8" | "i8" | "q8" => KvPrecision::Int8,
            "int4" | "i4" | "q4" => KvPrecision::Int4,
            other => bail!("unknown KV precision '{other}' (expected 'f32', 'int8' or 'int4')"),
        })
    }

    /// `$BLOCK_ATTN_KV_QUANT`, defaulting to `F32` when unset or empty.
    /// An unparsable value **panics**: this runs inside constructors
    /// that cannot return a `Result`, and silently serving the f32 tier
    /// when the operator asked for a quantized one (or typo'd it) would
    /// hide a 4-8× capacity misconfiguration. Bins fail loudly at
    /// startup instead.
    pub fn from_env() -> KvPrecision {
        match Self::parse_env_value(std::env::var("BLOCK_ATTN_KV_QUANT").ok().as_deref()) {
            Ok(p) => p,
            Err(e) => panic!("invalid $BLOCK_ATTN_KV_QUANT: {e}"),
        }
    }

    /// The pure resolution behind [`Self::from_env`]: `None` or an
    /// empty/whitespace value defaults to `F32`, anything else must
    /// parse. Split out so both paths are unit-testable without
    /// touching the process environment.
    pub fn parse_env_value(v: Option<&str>) -> Result<KvPrecision> {
        match v {
            Some(s) if !s.trim().is_empty() => KvPrecision::parse(s),
            _ => Ok(KvPrecision::F32),
        }
    }

    /// `--kv-quant` from parsed CLI options, falling back to the
    /// environment then `F32`. Errors on an unparsable flag value.
    pub fn resolve(args: &crate::util::cli::Args) -> Result<KvPrecision> {
        match args.kv_quant() {
            Some(v) => KvPrecision::parse(v),
            None => KvPrecision::parse_env_value(
                std::env::var("BLOCK_ATTN_KV_QUANT").ok().as_deref(),
            ),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            KvPrecision::F32 => "f32",
            KvPrecision::Int8 => "int8",
            KvPrecision::Int4 => "int4",
        }
    }
}

/// How a cached block is re-encoded to its serving offset at fetch
/// time (paper Eq. 3; ROADMAP item 4 — the LazyAttention direction).
///
/// * `Eager` (default) — every memo-cold fetch derives the rotated
///   panel from the block's stored local-position codes; memo-warm
///   fetches replay a stored panel verbatim. Serving output is
///   **bitwise identical** to recomputing the rotation each fetch.
/// * `Delta` — a panel already memoized at `Δ₁` is delta-rotated by
///   `Δ₂−Δ₁` instead of re-derived from the codes. Rotations compose
///   additively in exact arithmetic but f32 rounding differs per hop,
///   so this mode is **cosine-contracted** like the quantized tiers
///   (decode-logit cosine ≥ 0.999 vs eager on the workload traces,
///   `tests/reencode_modes.rs`), not bitwise.
///
/// Resolution order: `--reencode eager|delta` > `$BLOCK_ATTN_REENCODE`
/// > `Eager`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReencodeMode {
    #[default]
    Eager,
    Delta,
}

impl ReencodeMode {
    pub fn parse(s: &str) -> Result<ReencodeMode> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "eager" => ReencodeMode::Eager,
            "delta" | "lazy" => ReencodeMode::Delta,
            other => bail!("unknown re-encode mode '{other}' (expected 'eager' or 'delta')"),
        })
    }

    /// `$BLOCK_ATTN_REENCODE`, defaulting to `Eager` when unset or
    /// empty. An unparsable value **panics**, like
    /// [`KvPrecision::from_env`]: silently serving the bitwise path
    /// when the operator asked for (or typo'd) the accelerated one
    /// would hide the misconfiguration.
    pub fn from_env() -> ReencodeMode {
        match Self::parse_env_value(std::env::var("BLOCK_ATTN_REENCODE").ok().as_deref()) {
            Ok(m) => m,
            Err(e) => panic!("invalid $BLOCK_ATTN_REENCODE: {e}"),
        }
    }

    /// The pure resolution behind [`Self::from_env`]: `None` or an
    /// empty/whitespace value defaults to `Eager`, anything else must
    /// parse. Unit-testable without touching the process environment.
    pub fn parse_env_value(v: Option<&str>) -> Result<ReencodeMode> {
        match v {
            Some(s) if !s.trim().is_empty() => ReencodeMode::parse(s),
            _ => Ok(ReencodeMode::Eager),
        }
    }

    /// `--reencode` from parsed CLI options, falling back to the
    /// environment then `Eager`. Errors on an unparsable flag value.
    pub fn resolve(args: &crate::util::cli::Args) -> Result<ReencodeMode> {
        match args.reencode() {
            Some(v) => ReencodeMode::parse(v),
            None => ReencodeMode::parse_env_value(
                std::env::var("BLOCK_ATTN_REENCODE").ok().as_deref(),
            ),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ReencodeMode::Eager => "eager",
            ReencodeMode::Delta => "delta",
        }
    }
}

/// How the serving front-end turns a raw request into context blocks
/// (the `--segment` knob; policy logic in `coordinator::segmenter`).
///
/// * `Passages` (default) — requests must arrive pre-segmented as a
///   `passages` array (the RAG shape every prior PR served); raw
///   `prompt`/`demos`/`turns`/`state` fields are rejected loudly.
/// * `Text` — a raw `prompt` string is split on the paper's §3.1
///   division labels (`segment_text`).
/// * `Icl` — a `demos` array becomes one cacheable exemplar block per
///   demonstration (`segment_icl`).
/// * `Chat` — an optional `system` string plus a `turns` array become
///   one block per completed exchange, so turn *N+1* re-serves turn
///   *N*'s blocks from cache.
/// * `Gamecore` — a `state` JSON object is split per field
///   (Appendix-A Game-AI shape, `segment_gamecore`).
/// * `Auto` — dispatch on which raw field the request carries.
///
/// Pre-segmented `passages` requests are served identically under
/// *every* policy; the policy only governs raw-field segmentation.
///
/// Resolution order: `--segment` > `$BLOCK_ATTN_SEGMENT` > `Passages`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SegmentPolicy {
    #[default]
    Passages,
    Text,
    Icl,
    Chat,
    Gamecore,
    Auto,
}

impl SegmentPolicy {
    pub fn parse(s: &str) -> Result<SegmentPolicy> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "passages" | "rag" => SegmentPolicy::Passages,
            "text" => SegmentPolicy::Text,
            "icl" | "demos" => SegmentPolicy::Icl,
            "chat" | "turns" => SegmentPolicy::Chat,
            "gamecore" | "game" => SegmentPolicy::Gamecore,
            "auto" => SegmentPolicy::Auto,
            other => bail!(
                "unknown segment policy '{other}' (expected \
                 'passages', 'text', 'icl', 'chat', 'gamecore' or 'auto')"
            ),
        })
    }

    /// `$BLOCK_ATTN_SEGMENT`, defaulting to `Passages` when unset or
    /// empty. An unparsable value **panics**, like
    /// [`KvPrecision::from_env`]: silently falling back to
    /// passages-only parsing when the operator asked for (or typo'd)
    /// automatic segmentation would hide the misconfiguration.
    pub fn from_env() -> SegmentPolicy {
        match Self::parse_env_value(std::env::var("BLOCK_ATTN_SEGMENT").ok().as_deref()) {
            Ok(p) => p,
            Err(e) => panic!("invalid $BLOCK_ATTN_SEGMENT: {e}"),
        }
    }

    /// The pure resolution behind [`Self::from_env`]: `None` or an
    /// empty/whitespace value defaults to `Passages`, anything else
    /// must parse. Unit-testable without touching the process
    /// environment.
    pub fn parse_env_value(v: Option<&str>) -> Result<SegmentPolicy> {
        match v {
            Some(s) if !s.trim().is_empty() => SegmentPolicy::parse(s),
            _ => Ok(SegmentPolicy::Passages),
        }
    }

    /// `--segment` from parsed CLI options, falling back to the
    /// environment then `Passages`. Errors on an unparsable flag value.
    pub fn resolve(args: &crate::util::cli::Args) -> Result<SegmentPolicy> {
        match args.segment() {
            Some(v) => SegmentPolicy::parse(v),
            None => {
                SegmentPolicy::parse_env_value(std::env::var("BLOCK_ATTN_SEGMENT").ok().as_deref())
            }
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SegmentPolicy::Passages => "passages",
            SegmentPolicy::Text => "text",
            SegmentPolicy::Icl => "icl",
            SegmentPolicy::Chat => "chat",
            SegmentPolicy::Gamecore => "gamecore",
            SegmentPolicy::Auto => "auto",
        }
    }
}

/// Where the persistent block KV store lives and how much disk it may
/// use (the tier under `kvcache::disk::DiskStore`; file format in
/// `docs/kvstore-format.md`).
///
/// Resolution order, matching every other knob in the stack:
/// `--kv-store-dir` / `--kv-store-budget` > `$BLOCK_ATTN_KV_STORE_DIR`
/// / `$BLOCK_ATTN_KV_STORE_BUDGET` > disabled. The budget is in **MB**
/// (like `--cache-mb`), 0 = unbounded. No directory configured means
/// no store: serving stays purely in-RAM, exactly as before this tier
/// existed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvStoreConfig {
    pub dir: PathBuf,
    pub budget_bytes: usize,
}

impl KvStoreConfig {
    /// `--kv-store-dir`/`--kv-store-budget` from parsed CLI options,
    /// falling back to the environment. `Ok(None)` = no store
    /// configured. Errors loudly on an unparsable budget or a budget
    /// without a directory — a misconfigured persistence layer must
    /// not silently degrade to RAM-only serving.
    pub fn resolve(args: &crate::util::cli::Args) -> Result<Option<KvStoreConfig>> {
        let dir = args
            .kv_store_dir()
            .map(str::to_string)
            .or_else(|| std::env::var("BLOCK_ATTN_KV_STORE_DIR").ok());
        let budget = args
            .kv_store_budget()
            .map(str::to_string)
            .or_else(|| std::env::var("BLOCK_ATTN_KV_STORE_BUDGET").ok());
        Self::parse_values(dir.as_deref(), budget.as_deref())
    }

    /// Environment-only resolution (for paths with no CLI in scope,
    /// e.g. tests honoring a CI-provided store directory).
    pub fn from_env() -> Result<Option<KvStoreConfig>> {
        let dir = std::env::var("BLOCK_ATTN_KV_STORE_DIR").ok();
        let budget = std::env::var("BLOCK_ATTN_KV_STORE_BUDGET").ok();
        Self::parse_values(dir.as_deref(), budget.as_deref())
    }

    /// The pure value-level resolver behind [`Self::resolve`] /
    /// [`Self::from_env`] (unit-testable without touching the process
    /// environment). `None` or empty directory disables the store; the
    /// budget is MB, absent/empty = 0 = unbounded.
    pub fn parse_values(dir: Option<&str>, budget_mb: Option<&str>) -> Result<Option<KvStoreConfig>> {
        let dir = match dir.map(str::trim) {
            Some(d) if !d.is_empty() => d.to_string(),
            _ => {
                if let Some(b) = budget_mb.map(str::trim) {
                    if !b.is_empty() {
                        bail!(
                            "kv-store budget '{b}' given without a store directory \
                             (--kv-store-dir or $BLOCK_ATTN_KV_STORE_DIR)"
                        );
                    }
                }
                return Ok(None);
            }
        };
        let mb: usize = match budget_mb.map(str::trim) {
            Some(b) if !b.is_empty() => b.parse().map_err(|_| {
                anyhow!("invalid kv-store budget '{b}' (expected MB as an integer, 0 = unbounded)")
            })?,
            _ => 0,
        };
        Ok(Some(KvStoreConfig { dir: PathBuf::from(dir), budget_bytes: mb << 20 }))
    }
}

/// Transformer dimensions for one named config (e.g. `tiny`).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub max_len: usize,
}

impl ModelConfig {
    /// Built-in configs for the artifact-free [`crate::runtime::NativeBackend`].
    ///
    /// Dimensions mirror `python/compile/configs.py` exactly (`tiny`,
    /// `small`, `bench`), so flat-f32 checkpoints are interchangeable
    /// between the native and AOT backends.
    pub fn builtin(name: &str) -> Option<ModelConfig> {
        let c = |vocab, d_model, layers, heads, kv_heads, d_ff, rope_theta, max_len| ModelConfig {
            name: name.to_string(),
            vocab,
            d_model,
            layers,
            heads,
            kv_heads,
            head_dim: d_model / heads,
            d_ff,
            rope_theta,
            norm_eps: 1e-5,
            max_len,
        };
        match name {
            "tiny" => Some(c(261, 128, 4, 4, 2, 344, 10000.0, 704)),
            "small" => Some(c(261, 256, 6, 8, 4, 688, 10000.0, 2176)),
            "bench" => Some(c(32000, 256, 4, 8, 4, 688, 500000.0, 32768)),
            _ => None,
        }
    }

    /// Total parameter count (tied embedding).
    pub fn param_count(&self, layout: &[ParamSpec]) -> usize {
        layout.iter().map(|p| p.len()).sum()
    }

    /// KV bytes for one token (all layers, f32 K+V).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.layers * self.kv_heads * self.head_dim * 4
    }
}

/// One tensor in the flattened parameter layout (order matters: it is the
/// argument order of `train_step` and the layout of checkpoint files).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The kind of AOT entry point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EntryKind {
    /// Vanilla full-attention prefill of the whole prompt (baseline).
    PrefillFull,
    /// Independent prefill of one block at local positions (no cross-block
    /// attention) returning its KV states.
    PrefillBlock,
    /// Prefill of the final block attending to the (re-encoded) cached
    /// context KV.
    PrefillFinal,
    /// Single-token decode step over a dense KV cache.
    DecodeStep,
    /// RoPE re-encode of a cached K block (parity checking vs native rust).
    ReencodeK,
    /// One fine-tuning step (fwd + bwd + AdamW).
    TrainStep,
}

impl EntryKind {
    pub fn parse(s: &str) -> Result<EntryKind> {
        Ok(match s {
            "prefill_full" => EntryKind::PrefillFull,
            "prefill_block" => EntryKind::PrefillBlock,
            "prefill_final" => EntryKind::PrefillFinal,
            "decode_step" => EntryKind::DecodeStep,
            "reencode_k" => EntryKind::ReencodeK,
            "train_step" => EntryKind::TrainStep,
            other => bail!("unknown entry kind '{other}'"),
        })
    }
}

/// One AOT-compiled entry point (an HLO text file with static shapes).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: EntryKind,
    pub file: PathBuf,
    /// Static size parameters, e.g. `L` (sequence bucket), `C` (context
    /// capacity), `Lq` (final-block capacity), `B` (train batch).
    pub sizes: BTreeMap<String, usize>,
}

impl ArtifactEntry {
    pub fn size(&self, key: &str) -> Result<usize> {
        self.sizes
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("entry '{}' missing size '{key}'", self.name))
    }
}

/// Everything the runtime knows about one model config.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub config: ModelConfig,
    pub params: Vec<ParamSpec>,
    /// Initial parameters file (flat f32 in `params` order), if present.
    pub init_file: Option<PathBuf>,
    pub entries: Vec<ArtifactEntry>,
}

impl ModelArtifacts {
    /// All entries of a kind, sorted by their primary bucket size.
    pub fn entries_of(&self, kind: EntryKind, bucket_key: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> =
            self.entries.iter().filter(|e| e.kind == kind).collect();
        v.sort_by_key(|e| e.sizes.get(bucket_key).copied().unwrap_or(usize::MAX));
        v
    }

    /// Smallest entry of `kind` whose `bucket_key` size is >= `need`.
    pub fn pick_bucket(
        &self,
        kind: EntryKind,
        bucket_key: &str,
        need: usize,
    ) -> Result<&ArtifactEntry> {
        self.entries_of(kind, bucket_key)
            .into_iter()
            .find(|e| e.sizes.get(bucket_key).copied().unwrap_or(0) >= need)
            .ok_or_else(|| {
                anyhow!(
                    "no {kind:?} artifact with {bucket_key} >= {need} for config '{}'",
                    self.config.name
                )
            })
    }

    pub fn param_count(&self) -> usize {
        self.config.param_count(&self.params)
    }
}

/// The parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
}

impl Manifest {
    /// Load a manifest from `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Self::from_json(dir, &root)
    }

    pub fn from_json(dir: PathBuf, root: &Json) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        let configs = root
            .get("configs")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing 'configs'"))?;
        for (name, c) in configs {
            let config = ModelConfig {
                name: name.clone(),
                vocab: c.req_usize("vocab")?,
                d_model: c.req_usize("d_model")?,
                layers: c.req_usize("layers")?,
                heads: c.req_usize("heads")?,
                kv_heads: c.req_usize("kv_heads")?,
                head_dim: c.req_usize("head_dim")?,
                d_ff: c.req_usize("d_ff")?,
                rope_theta: c.req_f64("rope_theta")?,
                norm_eps: c.req_f64("norm_eps")?,
                max_len: c.req_usize("max_len")?,
            };
            let params = c
                .req_arr("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req_str("name")?.to_string(),
                        shape: p
                            .req_arr("shape")?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape")))
                            .collect::<Result<_>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let init_file = c
                .get("init_file")
                .as_str()
                .map(|f| dir.join(f));
            let mut entries = Vec::new();
            for e in c.req_arr("entries")? {
                let mut sizes = BTreeMap::new();
                if let Some(obj) = e.get("sizes").as_obj() {
                    for (k, v) in obj {
                        sizes.insert(
                            k.clone(),
                            v.as_usize().ok_or_else(|| anyhow!("bad size {k}"))?,
                        );
                    }
                }
                entries.push(ArtifactEntry {
                    name: e.req_str("name")?.to_string(),
                    kind: EntryKind::parse(e.req_str("kind")?)?,
                    file: dir.join(e.req_str("file")?),
                    sizes,
                });
            }
            models.insert(
                name.clone(),
                ModelArtifacts { config, params, init_file, entries },
            );
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no config '{name}'"))
    }
}

/// Default artifacts directory: `$BLOCK_ATTN_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("BLOCK_ATTN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Json {
        Json::parse(
            r#"{
          "version": 1,
          "configs": {
            "tiny": {
              "vocab": 261, "d_model": 128, "layers": 4, "heads": 4,
              "kv_heads": 2, "head_dim": 32, "d_ff": 344,
              "rope_theta": 10000.0, "norm_eps": 1e-5, "max_len": 1024,
              "init_file": "tiny_init.bin",
              "params": [
                {"name": "embed", "shape": [261, 128]},
                {"name": "final_norm", "shape": [128]}
              ],
              "entries": [
                {"name": "a", "kind": "prefill_full", "file": "a.hlo.txt",
                 "sizes": {"L": 256}},
                {"name": "b", "kind": "prefill_full", "file": "b.hlo.txt",
                 "sizes": {"L": 1024}},
                {"name": "c", "kind": "decode_step", "file": "c.hlo.txt",
                 "sizes": {"C": 1088}}
              ]
            }
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(PathBuf::from("/x"), &sample_manifest()).unwrap();
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.config.d_model, 128);
        assert_eq!(tiny.config.kv_heads, 2);
        assert_eq!(tiny.params.len(), 2);
        assert_eq!(tiny.params[0].len(), 261 * 128);
        assert_eq!(tiny.entries.len(), 3);
        assert_eq!(tiny.init_file.as_deref(), Some(Path::new("/x/tiny_init.bin")));
    }

    #[test]
    fn bucket_picking() {
        let m = Manifest::from_json(PathBuf::from("/x"), &sample_manifest()).unwrap();
        let tiny = m.model("tiny").unwrap();
        let e = tiny.pick_bucket(EntryKind::PrefillFull, "L", 200).unwrap();
        assert_eq!(e.sizes["L"], 256);
        let e = tiny.pick_bucket(EntryKind::PrefillFull, "L", 257).unwrap();
        assert_eq!(e.sizes["L"], 1024);
        assert!(tiny.pick_bucket(EntryKind::PrefillFull, "L", 5000).is_err());
        assert!(tiny.pick_bucket(EntryKind::TrainStep, "B", 1).is_err());
    }

    #[test]
    fn builtin_configs_mirror_python() {
        let tiny = ModelConfig::builtin("tiny").unwrap();
        assert_eq!(tiny.d_model, 128);
        assert_eq!(tiny.layers, 4);
        assert_eq!(tiny.heads, 4);
        assert_eq!(tiny.kv_heads, 2);
        assert_eq!(tiny.head_dim, 32);
        assert_eq!(tiny.vocab, crate::tokenizer::BYTE_VOCAB);
        let small = ModelConfig::builtin("small").unwrap();
        assert_eq!(small.head_dim, 32);
        assert_eq!(small.max_len, 2176);
        let bench = ModelConfig::builtin("bench").unwrap();
        assert_eq!(bench.vocab, 32000);
        assert!((bench.rope_theta - 500000.0).abs() < 1e-9);
        assert!(ModelConfig::builtin("giant").is_none());
    }

    #[test]
    fn kv_precision_parses_and_defaults() {
        assert_eq!(KvPrecision::parse("f32").unwrap(), KvPrecision::F32);
        assert_eq!(KvPrecision::parse(" INT8 ").unwrap(), KvPrecision::Int8);
        assert_eq!(KvPrecision::parse("i8").unwrap(), KvPrecision::Int8);
        assert_eq!(KvPrecision::parse("int4").unwrap(), KvPrecision::Int4);
        assert_eq!(KvPrecision::parse("q4").unwrap(), KvPrecision::Int4);
        assert!(KvPrecision::parse("int2").is_err());
        assert_eq!(KvPrecision::default(), KvPrecision::F32);
        assert_eq!(KvPrecision::Int8.as_str(), "int8");
        assert_eq!(KvPrecision::Int4.as_str(), "int4");
        // Flag beats environment; absent flag falls through to env/F32.
        let args = crate::util::cli::Args::parse_from(vec![
            "--kv-quant".to_string(),
            "int4".to_string(),
        ]);
        assert_eq!(KvPrecision::resolve(&args).unwrap(), KvPrecision::Int4);
        let bad = crate::util::cli::Args::parse_from(vec![
            "--kv-quant".to_string(),
            "int2".to_string(),
        ]);
        assert!(KvPrecision::resolve(&bad).is_err());
    }

    /// The two `$BLOCK_ATTN_KV_QUANT` paths, on the pure resolver so
    /// the test never mutates the process environment: unset/empty
    /// stays the `F32` default, anything unparsable is an error (which
    /// [`KvPrecision::from_env`] escalates to a startup panic — a typo
    /// must not silently serve the f32 tier at 4-8× the expected cache
    /// footprint).
    #[test]
    fn kv_precision_env_value_defaults_and_fails_loudly() {
        assert_eq!(KvPrecision::parse_env_value(None).unwrap(), KvPrecision::F32);
        assert_eq!(KvPrecision::parse_env_value(Some("")).unwrap(), KvPrecision::F32);
        assert_eq!(KvPrecision::parse_env_value(Some("  ")).unwrap(), KvPrecision::F32);
        assert_eq!(KvPrecision::parse_env_value(Some("int8")).unwrap(), KvPrecision::Int8);
        assert_eq!(KvPrecision::parse_env_value(Some("int4")).unwrap(), KvPrecision::Int4);
        let err = KvPrecision::parse_env_value(Some("in8t")).unwrap_err();
        assert!(format!("{err}").contains("in8t"), "error must name the bad value");
    }

    #[test]
    fn reencode_mode_parses_and_defaults() {
        assert_eq!(ReencodeMode::parse("eager").unwrap(), ReencodeMode::Eager);
        assert_eq!(ReencodeMode::parse(" DELTA ").unwrap(), ReencodeMode::Delta);
        assert_eq!(ReencodeMode::parse("lazy").unwrap(), ReencodeMode::Delta);
        assert!(ReencodeMode::parse("sloppy").is_err());
        assert_eq!(ReencodeMode::default(), ReencodeMode::Eager);
        assert_eq!(ReencodeMode::Eager.as_str(), "eager");
        assert_eq!(ReencodeMode::Delta.as_str(), "delta");
        // Flag beats environment; absent flag falls through to env/Eager.
        let args = crate::util::cli::Args::parse_from(vec![
            "--reencode".to_string(),
            "delta".to_string(),
        ]);
        assert_eq!(ReencodeMode::resolve(&args).unwrap(), ReencodeMode::Delta);
        let bad = crate::util::cli::Args::parse_from(vec![
            "--reencode".to_string(),
            "sloppy".to_string(),
        ]);
        assert!(ReencodeMode::resolve(&bad).is_err());
    }

    /// The two `$BLOCK_ATTN_REENCODE` paths, on the pure resolver so
    /// the test never mutates the process environment: unset/empty
    /// stays the bitwise `Eager` default, anything unparsable is an
    /// error (which [`ReencodeMode::from_env`] escalates to a startup
    /// panic).
    #[test]
    fn reencode_mode_env_value_defaults_and_fails_loudly() {
        assert_eq!(ReencodeMode::parse_env_value(None).unwrap(), ReencodeMode::Eager);
        assert_eq!(ReencodeMode::parse_env_value(Some("")).unwrap(), ReencodeMode::Eager);
        assert_eq!(ReencodeMode::parse_env_value(Some("  ")).unwrap(), ReencodeMode::Eager);
        assert_eq!(ReencodeMode::parse_env_value(Some("delta")).unwrap(), ReencodeMode::Delta);
        let err = ReencodeMode::parse_env_value(Some("detla")).unwrap_err();
        assert!(format!("{err}").contains("detla"), "error must name the bad value");
    }

    #[test]
    fn segment_policy_parses_and_defaults() {
        assert_eq!(SegmentPolicy::parse("passages").unwrap(), SegmentPolicy::Passages);
        assert_eq!(SegmentPolicy::parse("rag").unwrap(), SegmentPolicy::Passages);
        assert_eq!(SegmentPolicy::parse(" TEXT ").unwrap(), SegmentPolicy::Text);
        assert_eq!(SegmentPolicy::parse("icl").unwrap(), SegmentPolicy::Icl);
        assert_eq!(SegmentPolicy::parse("demos").unwrap(), SegmentPolicy::Icl);
        assert_eq!(SegmentPolicy::parse("chat").unwrap(), SegmentPolicy::Chat);
        assert_eq!(SegmentPolicy::parse("turns").unwrap(), SegmentPolicy::Chat);
        assert_eq!(SegmentPolicy::parse("gamecore").unwrap(), SegmentPolicy::Gamecore);
        assert_eq!(SegmentPolicy::parse("game").unwrap(), SegmentPolicy::Gamecore);
        assert_eq!(SegmentPolicy::parse("auto").unwrap(), SegmentPolicy::Auto);
        assert!(SegmentPolicy::parse("sentences").is_err());
        assert_eq!(SegmentPolicy::default(), SegmentPolicy::Passages);
        assert_eq!(SegmentPolicy::Passages.as_str(), "passages");
        assert_eq!(SegmentPolicy::Auto.as_str(), "auto");
        // Flag beats environment; absent flag falls through to env/Passages.
        let args = crate::util::cli::Args::parse_from(vec![
            "--segment".to_string(),
            "gamecore".to_string(),
        ]);
        assert_eq!(SegmentPolicy::resolve(&args).unwrap(), SegmentPolicy::Gamecore);
        let bad = crate::util::cli::Args::parse_from(vec![
            "--segment".to_string(),
            "sentences".to_string(),
        ]);
        assert!(SegmentPolicy::resolve(&bad).is_err());
    }

    /// The two `$BLOCK_ATTN_SEGMENT` paths, on the pure resolver so the
    /// test never mutates the process environment: unset/empty stays
    /// the pre-segmented `Passages` default, anything unparsable is an
    /// error (which [`SegmentPolicy::from_env`] escalates to a startup
    /// panic).
    #[test]
    fn segment_policy_env_value_defaults_and_fails_loudly() {
        assert_eq!(SegmentPolicy::parse_env_value(None).unwrap(), SegmentPolicy::Passages);
        assert_eq!(SegmentPolicy::parse_env_value(Some("")).unwrap(), SegmentPolicy::Passages);
        assert_eq!(SegmentPolicy::parse_env_value(Some("  ")).unwrap(), SegmentPolicy::Passages);
        assert_eq!(SegmentPolicy::parse_env_value(Some("auto")).unwrap(), SegmentPolicy::Auto);
        let err = SegmentPolicy::parse_env_value(Some("setgment")).unwrap_err();
        assert!(format!("{err}").contains("setgment"), "error must name the bad value");
    }

    /// The persistent-store knobs, on the pure value resolver so the
    /// test never mutates the process environment: no dir = no store,
    /// budget in MB (0/absent = unbounded), loud failures on a
    /// non-integer budget or a budget without a dir.
    #[test]
    fn kv_store_config_parses_values() {
        assert_eq!(KvStoreConfig::parse_values(None, None).unwrap(), None);
        assert_eq!(KvStoreConfig::parse_values(Some(""), None).unwrap(), None);
        assert_eq!(KvStoreConfig::parse_values(Some("  "), Some("")).unwrap(), None);
        let c = KvStoreConfig::parse_values(Some("/tmp/kv"), None).unwrap().unwrap();
        assert_eq!(c.dir, PathBuf::from("/tmp/kv"));
        assert_eq!(c.budget_bytes, 0, "absent budget = unbounded");
        let c = KvStoreConfig::parse_values(Some(" /tmp/kv "), Some(" 64 ")).unwrap().unwrap();
        assert_eq!(c.dir, PathBuf::from("/tmp/kv"));
        assert_eq!(c.budget_bytes, 64 << 20, "budget is MB");
        let c = KvStoreConfig::parse_values(Some("/tmp/kv"), Some("0")).unwrap().unwrap();
        assert_eq!(c.budget_bytes, 0);
        let err = KvStoreConfig::parse_values(Some("/tmp/kv"), Some("lots")).unwrap_err();
        assert!(format!("{err}").contains("lots"), "error must name the bad value");
        let err = KvStoreConfig::parse_values(None, Some("64")).unwrap_err();
        assert!(
            format!("{err}").contains("without a store directory"),
            "budget without dir must fail loudly, got: {err}"
        );
        // Flag beats environment; flags alone resolve without env.
        let args = crate::util::cli::Args::parse_from(vec![
            "--kv-store-dir".to_string(),
            "/tmp/kv-flag".to_string(),
            "--kv-store-budget".to_string(),
            "2".to_string(),
        ]);
        let c = KvStoreConfig::resolve(&args).unwrap().unwrap();
        assert_eq!(c.dir, PathBuf::from("/tmp/kv-flag"));
        assert_eq!(c.budget_bytes, 2 << 20);
    }

    #[test]
    fn kv_bytes() {
        let m = Manifest::from_json(PathBuf::from("/x"), &sample_manifest()).unwrap();
        let cfg = &m.model("tiny").unwrap().config;
        assert_eq!(cfg.kv_bytes_per_token(), 2 * 4 * 2 * 32 * 4);
    }
}
