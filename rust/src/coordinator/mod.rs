//! L3 coordinator — the serving-side system contribution of the paper.
//!
//! Pipeline per request (paper Figure 2):
//!
//! ```text
//! raw prompt ──segmenter──► blocks ──scheduler──► plan
//!     plan: per block, cache hit (reuse KV) or miss (prefill_block)
//!   misses ──engine.prefill_block──► KV ──► cache (content-addressed)
//!   all blocks ──RoPE re-encode to prompt offsets──► context tensor
//!   final block ──engine.prefill_final──► first token  ← TTFT stops here
//!   context + final KV ──quantize at tier──► DecodeCtx prefix
//!   decode loop over DecodeCtx (continuous batching across requests)
//! ```
//!
//! On the quantized KV tiers the decode loop attends **directly over
//! the quantized assembled context**: the prompt prefix is stored once
//! as int8/int4 codes in the request's [`DecodeCtx`] and the backend's
//! `decode_ctx` reads them through the fused mixed-precision kernels —
//! the old dense f32 decode cache (full decode capacity, cloned every
//! step) no longer exists.
//!
//! Modes ([`AttentionMode`]) cover the paper's serving variants: `Full`
//! (vanilla baseline), `Block` (the contribution), `BlockNoReencode`
//! (PromptCache-like / the w/o-pos ablation) and `BlockParallel`
//! (Superposition-like position assignment).

pub mod batcher;
pub mod metrics;
pub mod scheduler;
pub mod segmenter;
pub mod session;

use crate::config::{KvPrecision, ReencodeMode, SegmentPolicy};
use crate::kvcache::{block_key, BlockKvCache};
use crate::rope::RopeTable;
use crate::runtime::{Backend, DecodeCtx};
use crate::tensor::{argmax, TensorF};
use crate::tokenizer::EOS;
use anyhow::{bail, ensure, Result};
use segmenter::{coalesce_small_blocks, split_oversized_blocks, SegmentedPrompt};
use metrics::Metrics;
use scheduler::{PrefillPlan, Scheduler};
use std::time::Instant;

/// How the prompt context is attended to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionMode {
    /// Vanilla full-attention prefill of the entire prompt (baseline).
    Full,
    /// Block-attention with position re-encoding (the paper).
    Block,
    /// Block-attention **without** re-encoding: every cached block keeps
    /// its local `0..L` positions (PromptCache-like; the paper's
    /// `w/o-pos` ablation).
    BlockNoReencode,
    /// Superposition-like: all blocks re-encoded to the *same* offset 0
    /// ("parallel paths"); the query follows the longest path.
    BlockParallel,
}

impl AttentionMode {
    pub fn parse(s: &str) -> Result<AttentionMode> {
        Ok(match s {
            "full" => AttentionMode::Full,
            "block" => AttentionMode::Block,
            "no-reencode" | "promptcache" => AttentionMode::BlockNoReencode,
            "parallel" | "superposition" => AttentionMode::BlockParallel,
            other => bail!("unknown attention mode '{other}'"),
        })
    }
}

/// Context blocks shorter than this many tokens are merged into their
/// predecessor before planning (`segmenter::coalesce_small_blocks`):
/// tiny blocks waste cache entries and bucket padding. Applied
/// uniformly to every block-mode request — pre-segmented and
/// auto-segmented prompts normalize to the same shapes, which is what
/// makes a raw-`prompt` request bitwise identical to its equivalent
/// `passages` request even when composition triggers.
pub const MIN_BLOCK_TOKENS: usize = 4;

/// A generation request: pre-segmented context blocks plus the final
/// (query) block.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub blocks: Vec<Vec<i32>>,
    pub query: Vec<i32>,
    pub max_new_tokens: usize,
    pub mode: AttentionMode,
}

impl Request {
    pub fn prompt_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum::<usize>() + self.query.len()
    }
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds from admission to the first generated token.
    pub ttft: f64,
    /// Seconds spent computing cache-miss block KV — the concurrent
    /// part of prefill, so the direct observable for `--threads` wins.
    /// Zero when every block hit the cache (or in full-attention mode).
    pub block_prefill_s: f64,
    /// Analytic FLOPs spent producing the first token (paper's
    /// FLOPs-TFT metric), including any block prefills that missed cache.
    pub flops_tft: f64,
    pub cached_blocks: usize,
    pub total_blocks: usize,
    pub prompt_tokens: usize,
}

/// The serving coordinator: engine + cache + scheduler + metrics.
///
/// Generic over the inference [`Backend`]: the same pipeline runs on
/// the hermetic pure-Rust `NativeBackend` (tests, CI) and on the
/// artifact-backed PJRT engine (`--features xla`).
pub struct Coordinator<B: Backend> {
    engine: B,
    cache: BlockKvCache,
    scheduler: Scheduler,
    pub metrics: Metrics,
    flops: crate::flops::FlopsModel,
    /// Raw logits of the most recent prefill (teacher-forced scoring).
    last_prefill_logits: Option<Vec<f32>>,
    /// How the serving front-end segments raw prompts into blocks
    /// (surfaced in server `stats`; the segmentation itself runs in
    /// `server::parse_request` before requests reach this struct).
    segment_policy: SegmentPolicy,
}

impl<B: Backend> Coordinator<B> {
    /// Default construction resolves the KV storage precision from
    /// `$BLOCK_ATTN_KV_QUANT` (so the whole stack — tests included —
    /// can be flipped to the int8 tier without touching call sites);
    /// use [`Self::with_kv_precision`] to pin it explicitly.
    pub fn new(engine: B, cache_budget_bytes: usize) -> Coordinator<B> {
        Self::with_kv_precision(engine, cache_budget_bytes, KvPrecision::from_env())
    }

    /// A coordinator whose block-KV cache stores at `precision` (the
    /// `--kv-quant` plumbing; see [`KvPrecision`]). The fetch-time
    /// re-encode mode starts from `$BLOCK_ATTN_REENCODE` (eager when
    /// unset); pin it explicitly with [`Self::set_reencode_mode`].
    pub fn with_kv_precision(
        engine: B,
        cache_budget_bytes: usize,
        precision: KvPrecision,
    ) -> Coordinator<B> {
        let cfg = engine.config().clone();
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        let flops = crate::flops::FlopsModel::from_config(&cfg);
        let mut cache = BlockKvCache::with_precision(rope, cache_budget_bytes, precision);
        cache.set_reencode_mode(ReencodeMode::from_env());
        Coordinator {
            engine,
            cache,
            scheduler: Scheduler::new(),
            metrics: Metrics::new(),
            flops,
            last_prefill_logits: None,
            segment_policy: SegmentPolicy::from_env(),
        }
    }

    /// Active request-segmentation policy (the `--segment` plumbing;
    /// see [`SegmentPolicy`]). Defaults from `$BLOCK_ATTN_SEGMENT`.
    pub fn segment_policy(&self) -> SegmentPolicy {
        self.segment_policy
    }

    /// Pin the request-segmentation policy explicitly (the `serve` CLI
    /// resolves flag > env > default via [`SegmentPolicy::resolve`]).
    pub fn set_segment_policy(&mut self, policy: SegmentPolicy) {
        self.segment_policy = policy;
    }

    pub fn engine(&self) -> &B {
        &self.engine
    }

    /// Storage precision of the block-KV cache (and of the decode
    /// contexts built for new requests).
    pub fn kv_precision(&self) -> KvPrecision {
        self.cache.precision()
    }

    /// Switch the KV tier for *future* cache inserts and decode
    /// contexts. Resident cache entries keep the tier they were stored
    /// at (mixed-tier populations are fully supported — see
    /// [`BlockKvCache::set_precision`]); in-flight requests keep their
    /// decode context's tier.
    pub fn set_kv_precision(&mut self, precision: KvPrecision) {
        self.cache.set_precision(precision);
    }

    /// Fetch-time re-encode mode of the block-KV cache (the
    /// `--reencode` plumbing; see [`ReencodeMode`]).
    pub fn reencode_mode(&self) -> ReencodeMode {
        self.cache.reencode_mode()
    }

    /// Switch the fetch-time re-encode mode. Eager stays the bitwise
    /// default; delta composes rotations from the closest memoized
    /// panel (see [`BlockKvCache::set_reencode_mode`]).
    pub fn set_reencode_mode(&mut self, mode: ReencodeMode) {
        self.cache.set_reencode_mode(mode);
    }

    pub fn cache_stats(&self) -> crate::kvcache::CacheStats {
        self.cache.stats()
    }

    /// Invalidate all cached block KV (mandatory after parameter
    /// updates — cached states are functions of the weights). Also
    /// detaches any attached disk store: its fingerprint binds it to
    /// the old weights ([`Self::attach_kv_store`] re-derives one).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
    }

    /// Attach a persistent disk tier under the block cache (spill on
    /// RAM eviction, promote on RAM miss; format spec in
    /// `docs/kvstore-format.md`). The directory is keyed by a
    /// fingerprint of the model config + **current weights**
    /// ([`crate::kvcache::store::weights_fingerprint`]), so a store
    /// populated under different weights — another seed, another
    /// checkpoint — reads as a clean miss instead of serving stale KV.
    pub fn attach_kv_store(&mut self, store_cfg: &crate::config::KvStoreConfig) -> Result<()> {
        let fp = crate::kvcache::store::weights_fingerprint(
            self.engine.config(),
            &self.engine.params_host()?,
        );
        let store =
            crate::kvcache::disk::DiskStore::open(&store_cfg.dir, fp, store_cfg.budget_bytes as u64)?;
        self.cache.attach_store(store);
        Ok(())
    }

    /// Persist every resident cached block to the attached store
    /// (no-op without one) — the explicit flush used by the offline
    /// `precompute` bin and by tests that exercise the restart path.
    /// Returns the number of blocks newly written.
    pub fn flush_kv_store(&mut self) -> usize {
        self.cache.spill_all()
    }

    /// Directory of the attached disk store, if any (surfaced in the
    /// server's `stats` line).
    pub fn kv_store_dir(&self) -> Option<std::path::PathBuf> {
        self.cache.store().map(|s| s.dir().to_path_buf())
    }

    /// Drop unpinned resident blocks **without** spilling, keeping the
    /// disk tier attached — measurement aid for disk-warm paths (the
    /// store bench and restart tests force the next lookups through
    /// promotion). Returns the number dropped.
    pub fn drop_resident_blocks(&mut self) -> usize {
        self.cache.drop_resident()
    }

    /// Serve one request to completion (prefill + full decode loop).
    /// Continuous batching across requests lives in [`batcher`].
    pub fn process(&mut self, req: &Request) -> Result<Response> {
        let t0 = Instant::now();
        let (state, resp_proto) = self.prefill(req, t0)?;
        self.decode_to_completion(req, state, resp_proto)
    }

    /// Run the prefill phase: returns the in-flight decode state and the
    /// response skeleton (TTFT/FLOPs already final — TTFT is defined by
    /// the first token, which prefill produces).
    pub(crate) fn prefill(
        &mut self,
        req: &Request,
        t0: Instant,
    ) -> Result<(DecodeState, Response)> {
        let out = match req.mode {
            AttentionMode::Full => self.prefill_vanilla(req)?,
            _ => self.prefill_block_mode(req)?,
        };
        let ttft = t0.elapsed().as_secs_f64();
        self.metrics.record_ttft(ttft, out.flops_tft);
        // Only miss-bearing requests contribute: an all-hit (or
        // full-attention) request would flood the summary with zeros
        // and mask real miss-prefill latency.
        if out.block_prefill_s > 0.0 {
            self.metrics.record_block_prefill(out.block_prefill_s);
        }
        self.metrics
            .record_cache(out.cached_blocks, out.total_blocks);
        let first = argmax(&out.last_logits) as i32;
        self.last_prefill_logits = Some(out.last_logits);
        let resp = Response {
            id: req.id,
            tokens: vec![first],
            ttft,
            block_prefill_s: out.block_prefill_s,
            flops_tft: out.flops_tft,
            cached_blocks: out.cached_blocks,
            total_blocks: out.total_blocks,
            prompt_tokens: req.prompt_tokens(),
        };
        Ok((out.state, resp))
    }

    pub(crate) fn decode_to_completion(
        &mut self,
        req: &Request,
        mut state: DecodeState,
        mut resp: Response,
    ) -> Result<Response> {
        while resp.tokens.len() < req.max_new_tokens {
            let last = *resp.tokens.last().unwrap();
            if last == EOS {
                break;
            }
            let next = self.decode_one(&mut state, last)?;
            resp.tokens.push(next);
        }
        self.metrics.record_completion(resp.tokens.len());
        Ok(resp)
    }

    /// One decode step for an in-flight request (used by the batcher for
    /// round-robin continuous batching). Runs over the request's
    /// [`DecodeCtx`] — on the quantized tiers, attention reads the
    /// assembled context's codes directly (no dense f32 cache exists).
    pub(crate) fn decode_one(&mut self, state: &mut DecodeState, last: i32) -> Result<i32> {
        let logits = self.engine.decode_ctx(last, &mut state.ctx)?;
        Ok(argmax(&logits) as i32)
    }

    /// One decode round across several in-flight requests: routes to
    /// [`Backend::decode_batch`], so every session advances one token
    /// through a single kernel dispatch per layer (the continuous
    /// batching hot path). Bitwise identical to per-session
    /// [`Self::decode_one`] calls — see the `Backend` contract.
    pub(crate) fn decode_batch(
        &mut self,
        states: &mut [&mut DecodeState],
        last: &[i32],
    ) -> Result<Vec<i32>> {
        let mut ctxs: Vec<&mut DecodeCtx> = states.iter_mut().map(|s| &mut s.ctx).collect();
        self.engine.decode_batch(&mut ctxs, last)
    }

    // -- prefill paths -----------------------------------------------------

    fn prefill_vanilla(&mut self, req: &Request) -> Result<PrefillOutcome> {
        let mut all: Vec<i32> = Vec::with_capacity(req.prompt_tokens());
        for b in &req.blocks {
            all.extend_from_slice(b);
        }
        all.extend_from_slice(&req.query);
        let n = all.len();
        let out = self.engine.prefill_full(&all)?;
        // Decode context at the serving tier: the prompt KV is the
        // static prefix (quantized on the int8/int4 tiers), generated
        // tokens land in the growing f32 tail.
        let cap = self.engine.decode_ctx_capacity()?;
        let ctx = DecodeCtx::new(out.k, out.v, self.cache.precision(), cap)?;
        Ok(PrefillOutcome {
            last_logits: out.last_logits,
            state: DecodeState { ctx },
            flops_tft: self.flops.prefill_full(n),
            block_prefill_s: 0.0,
            cached_blocks: 0,
            total_blocks: req.blocks.len(),
        })
    }

    /// Normalize a request's block shapes so they always fit the
    /// engine's prefill buckets: merge sub-[`MIN_BLOCK_TOKENS`] blocks
    /// into their predecessor, chunk blocks past
    /// [`Backend::max_block_tokens`], and reject (loudly, not at some
    /// deeper buffer write) a query block past
    /// [`Backend::final_q_capacity`] — the query attends across the
    /// whole context in one final prefill and cannot be split. Pure in
    /// the token stream: the concatenation of blocks + query is
    /// unchanged, so `prompt_tokens` stays honest.
    fn normalized_blocks(&self, req: &Request) -> Result<Vec<Vec<i32>>> {
        let max_block = self.engine.max_block_tokens()?;
        let sp = SegmentedPrompt { blocks: req.blocks.clone(), query: req.query.clone() };
        let sp = coalesce_small_blocks(sp, MIN_BLOCK_TOKENS.min(max_block));
        let sp = split_oversized_blocks(sp, max_block)?;
        let q_cap = self.engine.final_q_capacity()?;
        ensure!(
            req.query.len() <= q_cap,
            "query block of {} tokens exceeds the final-prefill capacity ({q_cap})",
            req.query.len()
        );
        Ok(sp.blocks)
    }

    fn prefill_block_mode(&mut self, req: &Request) -> Result<PrefillOutcome> {
        let blocks = self.normalized_blocks(req)?;
        let plan = self.scheduler.plan(&blocks, &mut self.cache);
        // Planning pinned every cached block; the body below pins each
        // miss as it lands. Tracking the acquired pins here and
        // releasing them on *both* exits keeps error paths (over-length
        // prompts, engine failures) from leaving entries unevictable.
        let mut pins: Vec<u128> =
            plan.items.iter().filter(|it| it.cached).map(|it| it.key).collect();
        let out = self.prefill_block_mode_pinned(req, &blocks, &plan, &mut pins);
        for key in pins {
            self.cache.unpin(key);
        }
        out
    }

    /// Body of [`Self::prefill_block_mode`]; every pin it acquires is
    /// pushed onto `pins` so the caller can release them regardless of
    /// which `?` exits first.
    fn prefill_block_mode_pinned(
        &mut self,
        req: &Request,
        blocks: &[Vec<i32>],
        plan: &PrefillPlan,
        pins: &mut Vec<u128>,
    ) -> Result<PrefillOutcome> {
        let mut flops = 0.0;

        // 1. Compute KV for missing blocks (cache misses) concurrently:
        // blocks are independent by construction (block-diagonal
        // attention at local positions), so the engine fans the batch
        // out over the persistent kernel worker pool, one block per
        // budgeted thread. Results return in input order and are
        // inserted in plan order — byte-identical serving at every
        // `--threads` setting. Duplicate blocks within one request are
        // computed once.
        let t_blocks = Instant::now();
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_toks: Vec<&[i32]> = Vec::new();
        for (i, item) in plan.items.iter().enumerate() {
            if !item.cached && !miss_idx.iter().any(|&j| plan.items[j].key == item.key) {
                miss_idx.push(i);
                miss_toks.push(&blocks[i]);
            }
        }
        let block_prefill_s = if miss_idx.is_empty() {
            0.0
        } else {
            let kvs = self.engine.prefill_blocks(&miss_toks)?;
            for (&i, (k, v)) in miss_idx.iter().zip(kvs) {
                self.cache.insert_pinned(plan.items[i].key, k, v);
                pins.push(plan.items[i].key);
                flops += self.flops.prefill_full(blocks[i].len());
            }
            t_blocks.elapsed().as_secs_f64()
        };
        // Later occurrences of a deduped miss reuse the fresh entry;
        // each needs its own pin (released by the caller). This is
        // intra-request sharing, not a cache hit, so stats are untouched.
        for (i, item) in plan.items.iter().enumerate() {
            if !item.cached && !miss_idx.contains(&i) {
                let present = self.cache.pin(item.key);
                debug_assert!(present, "deduplicated miss vanished from cache");
                pins.push(item.key);
            }
        }

        // 2. Assemble the re-encoded context at the final bucket capacity.
        let ctx_len = plan.total_tokens;
        let cap = self.engine.final_ctx_capacity(ctx_len)?;
        let mut past_k = self.engine.kv_zeros(cap);
        let mut past_v = self.engine.kv_zeros(cap);
        let mut max_block = 0usize;
        for item in &plan.items {
            let delta = match req.mode {
                AttentionMode::Block => item.offset,
                AttentionMode::BlockNoReencode => 0,
                AttentionMode::BlockParallel => 0,
                AttentionMode::Full => unreachable!(),
            };
            let blk = self
                .cache
                .get_reencoded(item.key, delta)
                .expect("planned block vanished (pinned)");
            write_ctx(&mut past_k, &blk.k, item.offset);
            write_ctx(&mut past_v, &blk.v, item.offset);
            max_block = max_block.max(blk.len);
            // Eq. 3 work only happens for a non-zero shift: offset-0
            // blocks and the no-reencode/parallel modes fetch at
            // delta == 0 and must not inflate reported re-encode FLOPs.
            if delta != 0 {
                flops += self.flops.reencode(blk.len);
            }
        }

        // 3. Final-block prefill: the query attends to everything. In
        // superposition mode the query sits right after the longest
        // parallel document path; otherwise after the whole context.
        let q_pos0 = match req.mode {
            AttentionMode::BlockParallel => max_block,
            _ => ctx_len,
        };
        let out = self
            .engine
            .prefill_final_at(&req.query, &past_k, &past_v, ctx_len, q_pos0)?;
        flops += self.flops.prefill_final(req.query.len(), ctx_len);

        // 4. Decode context = context + final block, stored at the
        // serving tier: the assembled prompt prefix is quantized once
        // here (int8/int4) and decode attention reads the codes
        // directly — no dense f32 decode cache is materialized. (Pins
        // are released by the caller once this returns — the context
        // owns the data from here.)
        let cap_d = self.engine.decode_ctx_capacity()?;
        let total = ctx_len + req.query.len();
        let mut kp = self.engine.kv_zeros(total);
        let mut vp = self.engine.kv_zeros(total);
        copy_ctx_prefix(&mut kp, &past_k, ctx_len);
        copy_ctx_prefix(&mut vp, &past_v, ctx_len);
        write_ctx(&mut kp, &out.k, ctx_len);
        write_ctx(&mut vp, &out.v, ctx_len);
        let ctx = DecodeCtx::new(kp, vp, self.cache.precision(), cap_d)?;

        Ok(PrefillOutcome {
            last_logits: out.last_logits,
            state: DecodeState { ctx },
            flops_tft: flops,
            block_prefill_s,
            cached_blocks: plan.cached_count(),
            total_blocks: plan.items.len(),
        })
    }

    /// Teacher-forced raw-logit trace: serve `blocks + query` through
    /// the real prefill path, then decode feeding `forced` tokens.
    /// Returns `forced.len() + 1` logit vectors — index 0 is the
    /// prefill's next-token logits, index `i+1` follows `forced[..=i]`.
    ///
    /// This is the quantization accuracy harness: the same forced
    /// stream through an f32-tier and an int8-tier coordinator yields
    /// directly comparable logits (`tests/kv_quant.rs` asserts cosine
    /// similarity ≥ 0.999 per step on the workload traces).
    pub fn logits_trace(
        &mut self,
        blocks: &[Vec<i32>],
        query: &[i32],
        forced: &[i32],
        mode: AttentionMode,
    ) -> Result<Vec<Vec<f32>>> {
        let req = Request {
            id: u64::MAX,
            blocks: blocks.to_vec(),
            query: query.to_vec(),
            max_new_tokens: 1,
            mode,
        };
        let t0 = Instant::now();
        let (mut state, _) = self.prefill(&req, t0)?;
        let mut out = Vec::with_capacity(forced.len() + 1);
        out.push(
            self.last_prefill_logits
                .take()
                .ok_or_else(|| anyhow::anyhow!("prefill did not record logits"))?,
        );
        for &t in forced {
            out.push(self.engine.decode_ctx(t, &mut state.ctx)?);
        }
        Ok(out)
    }

    /// Teacher-forced scoring: per-token NLL (nats) of `target` following
    /// `blocks + query` under the given attention mode. Runs the real
    /// serving path (prefill + decode) via [`Self::logits_trace`]:
    /// logits_i predict target_i.
    pub fn score_continuation(
        &mut self,
        blocks: &[Vec<i32>],
        query: &[i32],
        target: &[i32],
        mode: AttentionMode,
    ) -> Result<Vec<f64>> {
        // An empty target still runs the prefill (validation, cache
        // warming and metrics side effects) — the trace's prefill entry
        // just goes unscored.
        let forced = &target[..target.len().saturating_sub(1)];
        let trace = self.logits_trace(blocks, query, forced, mode)?;
        Ok(target
            .iter()
            .zip(&trace)
            .map(|(&t, logits)| nll_of(logits, t))
            .collect())
    }

    /// Precompute + cache the KV of a block (offline warm-up of the
    /// passage store, cf. paper §1: "passages might have been
    /// computed"). Skips blocks already resident **or already
    /// published in the attached disk store** — the offline
    /// `precompute` bin re-runs over a corpus idempotently. Returns
    /// whether the block was actually computed.
    pub fn precompute_block(&mut self, tokens: &[i32]) -> Result<bool> {
        let key = block_key(tokens);
        if self.cache.contains_anywhere(key) {
            return Ok(false);
        }
        let (k, v) = self.engine.prefill_block(tokens)?;
        self.cache.insert_pinned(key, k, v);
        self.cache.unpin(key);
        Ok(true)
    }

    /// Plan without executing (for tests / introspection).
    pub fn dry_plan(&mut self, blocks: &[Vec<i32>]) -> PrefillPlan {
        let plan = self.scheduler.plan(blocks, &mut self.cache);
        for item in &plan.items {
            if item.cached {
                self.cache.unpin(item.key);
            }
        }
        plan
    }
}

/// In-flight decode state of one request: the decode context holds the
/// prompt prefix at the serving tier plus the growing f32 tail of
/// generated tokens (see [`DecodeCtx`]).
pub struct DecodeState {
    pub ctx: DecodeCtx,
}

struct PrefillOutcome {
    last_logits: Vec<f32>,
    state: DecodeState,
    flops_tft: f64,
    /// Wall time of the concurrent cache-miss block prefill.
    block_prefill_s: f64,
    cached_blocks: usize,
    total_blocks: usize,
}

/// Write a `(layers, len, kv_heads, head_dim)` block into a context
/// tensor at token offset `at` — the context-assembly primitive shared
/// by the serving path, the benches and the integration tests (one
/// definition so the KV layout has a single owner).
pub fn write_ctx(ctx: &mut TensorF, block: &TensorF, at: usize) {
    let layers = ctx.dims()[0];
    let row: usize = ctx.dims()[2] * ctx.dims()[3];
    let blen = block.dims()[1];
    debug_assert_eq!(ctx.dims()[2..], block.dims()[2..]);
    for n in 0..layers {
        let dst = ctx.axis0_mut(n);
        let src = block.axis0(n);
        dst[at * row..(at + blen) * row].copy_from_slice(&src[..blen * row]);
    }
}

/// Negative log-likelihood (nats) of token `t` under raw `logits`.
fn nll_of(logits: &[f32], t: i32) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = logits
        .iter()
        .map(|&x| ((x - max) as f64).exp())
        .sum::<f64>()
        .ln()
        + max as f64;
    lse - logits[t as usize] as f64
}

/// Copy the first `len` token rows of each layer between context tensors
/// of (possibly) different capacities.
pub(crate) fn copy_ctx_prefix(dst: &mut TensorF, src: &TensorF, len: usize) {
    let layers = dst.dims()[0];
    let row: usize = dst.dims()[2] * dst.dims()[3];
    for n in 0..layers {
        let d = dst.axis0_mut(n);
        let s = src.axis0(n);
        d[..len * row].copy_from_slice(&s[..len * row]);
    }
}
