//! L3 runtime: load AOT HLO artifacts and execute them on the PJRT CPU
//! client.
//!
//! The [`ModelEngine`] is the only place in the crate that touches the
//! `xla` FFI; everything above it works with host [`Tensor`]s. Artifacts
//! are compiled lazily on first use and memoized per entry, so loading a
//! manifest is cheap and a serving process only pays for the buckets it
//! actually exercises.

mod engine;
mod literal;

pub use engine::{DecodeOut, ModelEngine, PrefillFinalOut, PrefillFullOut, TrainOut};
pub use literal::{literal_to_f32, literal_to_i32, tensor_f, tensor_i};
