//! Streaming statistics: counters, summaries and quantile estimation.
//!
//! Backbone of the serving metrics (TTFT percentiles, throughput) and of
//! the bench harness (criterion replacement).

/// Online mean/min/max/variance plus a bounded reservoir for quantiles.
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    /// Reservoir sample for quantiles (exact while n <= cap).
    sample: Vec<f64>,
    cap: usize,
    seen: u64,
    rng_state: u64,
}

impl Default for Summary {
    fn default() -> Self {
        Self::with_capacity(4096)
    }
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sample: Vec::with_capacity(cap.min(4096)),
            cap,
            seen: 0,
            rng_state: 0x1234_5678_9ABC_DEF0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        // Reservoir sampling (Algorithm R).
        self.seen += 1;
        if self.sample.len() < self.cap {
            self.sample.push(x);
        } else {
            self.rng_state = self
                .rng_state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = (self.rng_state >> 16) % self.seen;
            if (j as usize) < self.cap {
                self.sample[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Quantile in [0,1] from the reservoir (exact when n <= capacity).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.sample.is_empty() {
            return f64::NAN;
        }
        let mut s = self.sample.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        s[idx]
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Fixed-bucket histogram (log2 buckets) for latency distributions.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// bucket i counts values in [2^i, 2^(i+1)) microseconds.
    buckets: [u64; 48],
    count: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { buckets: [0; 48], count: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_micros(&mut self, us: u64) {
        let b = 64 - us.max(1).leading_zeros() as usize - 1;
        self.buckets[b.min(47)] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate quantile (upper bucket bound).
    pub fn quantile_micros(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (self.count as f64 * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn summary_quantiles_exact_small_n() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((50.0..=51.0).contains(&s.p50()), "p50={}", s.p50());
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn reservoir_bounded() {
        let mut s = Summary::with_capacity(128);
        for i in 0..100_000 {
            s.add(i as f64);
        }
        assert_eq!(s.count(), 100_000);
        // Median of uniform 0..100000 should be near 50000.
        let p50 = s.p50();
        assert!((p50 - 50_000.0).abs() < 15_000.0, "p50={p50}");
    }

    #[test]
    fn log_histogram() {
        let mut h = LogHistogram::new();
        for _ in 0..90 {
            h.add_micros(100);
        }
        for _ in 0..10 {
            h.add_micros(10_000);
        }
        assert!(h.quantile_micros(0.5) <= 256);
        assert!(h.quantile_micros(0.99) >= 8_192);
    }
}
