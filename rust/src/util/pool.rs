//! A small fixed-size thread pool with a shared FIFO queue (tokio
//! replacement for the offline build).
//!
//! The coordinator uses it for concurrent block prefills and for serving
//! connections; on the 1-core CI box it mainly provides *logical*
//! concurrency, but the code is written for real multi-core parallelism.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<QueueState>,
    cond: Condvar,
}

struct QueueState {
    jobs: std::collections::VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// Fixed-size thread pool. Dropping the pool joins all workers after the
/// queue drains.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                jobs: Default::default(),
                shutdown: false,
                in_flight: 0,
            }),
            cond: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = shared.clone();
                thread::Builder::new()
                    .name(format!("block-attn-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Submit a job.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.push_back(Box::new(job));
        drop(q);
        self.shared.cond.notify_one();
    }

    /// Submit a job and get a handle to its result.
    pub fn submit<T: Send + 'static>(
        &self,
        job: impl FnOnce() -> T + Send + 'static,
    ) -> JobHandle<T> {
        let (tx, rx) = mpsc::channel();
        self.spawn(move || {
            let _ = tx.send(job());
        });
        JobHandle { rx }
    }

    /// Map `f` over `items` on the pool, preserving order.
    pub fn map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(I) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = items
            .into_iter()
            .map(|it| {
                let f = f.clone();
                self.submit(move || f(it))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }

    /// Block until the queue is empty and no job is running.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.jobs.is_empty() || q.in_flight > 0 {
            q = self.shared.cond.wait(q).unwrap();
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    q.in_flight += 1;
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cond.wait(q).unwrap();
            }
        };
        job();
        let mut q = shared.queue.lock().unwrap();
        q.in_flight -= 1;
        drop(q);
        shared.cond.notify_all();
    }
}

/// Handle to a submitted job's result.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Wait for the job to finish. Panics if the job panicked.
    pub fn join(self) -> T {
        self.rx.recv().expect("worker job panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_results() {
        let pool = ThreadPool::new(2);
        let h1 = pool.submit(|| 1 + 1);
        let h2 = pool.submit(|| "x".to_string() + "y");
        assert_eq!(h1.join(), 2);
        assert_eq!(h2.join(), "xy");
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |i| i * 2);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must drain queue before join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
