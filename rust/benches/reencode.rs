//! Re-encode fetch bench: memo-cold vs memo-warm `get_reencoded` per
//! KV tier — the rotation-memo win (a warm same-offset fetch is a
//! copy, not a dequant + Eq.-3 rotation).
//!
//! ```sh
//! cargo bench --bench reencode                    # 8 blocks x 256 tokens
//! cargo bench --bench reencode -- --blocks 4 --block-len 128
//! ```
//!
//! Operates on [`BlockKvCache`] directly (fetch cost scales with KV
//! elements, not the forward pass, so no backend is needed). Writes
//! `BENCH_reencode.json` (`--json-out PATH` overrides) with
//! `fetch_cold_*_ms` / `fetch_warm_*_ms` per tier for the `bench_guard`
//! gate. The bench itself fails if a memo-warm fetch is not bitwise
//! identical to the cold fetch it replays, or if the int8 warm fetch is
//! not ≥ 3x faster than cold.

use block_attn::config::KvPrecision;
use block_attn::kvcache::{block_key, BlockKvCache};
use block_attn::rope::RopeTable;
use block_attn::tensor::{Tensor, TensorF};
use block_attn::util::cli::Args;
use block_attn::util::json::Json;
use block_attn::util::rng::Rng;
use block_attn::util::timer::{bench, BenchOpts};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let threads = block_attn::kernels::init_threads_from_args(&args);
    let n_blocks = args.usize_or("blocks", 8);
    let block_len = args.usize_or("block-len", 256);
    // Tiny-model KV shape.
    let (layers, kv_heads, head_dim) = (4usize, 2, 32);

    let mut rng = Rng::new(0xE9);
    let mut mk = || -> TensorF {
        let dims = [layers, block_len, kv_heads, head_dim];
        let n: usize = dims.iter().product();
        Tensor::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect())
    };

    let opts = BenchOpts { warmup_iters: 2, iters: 20, max_seconds: 120.0 };
    let mut rows: Vec<(&'static str, f64, f64)> = Vec::new();
    for tier in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
        let rope = RopeTable::new(head_dim, 10000.0);
        let mut cache = BlockKvCache::with_precision(rope, 0, tier);
        let keys: Vec<u128> = (0..n_blocks).map(|i| block_key(&[i as i32])).collect();
        let deltas: Vec<usize> = (0..n_blocks).map(|i| i * block_len).collect();
        for &key in &keys {
            let (k, v) = (mk(), mk());
            cache.insert_pinned(key, k, v);
            cache.unpin(key);
        }

        // Correctness first, untimed: the memo-warm fetch must replay
        // the cold fetch bitwise and be counted as a memo hit.
        for i in 0..n_blocks {
            cache.clear_memo();
            let cold = cache.get_reencoded(keys[i], deltas[i]).expect("resident block");
            let hits0 = cache.stats().memo_hits;
            let warm = cache.get_reencoded(keys[i], deltas[i]).expect("resident block");
            anyhow::ensure!(
                warm.k == cold.k && warm.v == cold.v,
                "{} block {i}: memo-warm fetch diverged from cold",
                tier.as_str()
            );
            anyhow::ensure!(
                cache.stats().memo_hits == hits0 + 1,
                "{} block {i}: repeat fetch was not a memo hit",
                tier.as_str()
            );
        }

        let name = tier.as_str();
        let r_cold = bench(&format!("{name}-cold"), &opts, || {
            cache.clear_memo();
            for i in 0..n_blocks {
                let b = cache.get_reencoded(keys[i], deltas[i]).expect("resident block");
                assert_eq!(b.len, block_len);
            }
        });
        // Populate the memo once, then time pure memo hits.
        for i in 0..n_blocks {
            cache.get_reencoded(keys[i], deltas[i]).expect("resident block");
        }
        let r_warm = bench(&format!("{name}-warm"), &opts, || {
            for i in 0..n_blocks {
                let b = cache.get_reencoded(keys[i], deltas[i]).expect("resident block");
                assert_eq!(b.len, block_len);
            }
        });
        let s = cache.stats();
        anyhow::ensure!(s.memo_bytes > 0 && s.memo_hits > 0, "{name}: memo never engaged");
        rows.push((name, r_cold.p50_ms(), r_warm.p50_ms()));
    }

    let (c8, w8) = (rows[1].1, rows[1].2);
    anyhow::ensure!(
        c8 >= 3.0 * w8,
        "int8 memo-warm fetch ({w8:.3} ms) is not >= 3x faster than cold ({c8:.3} ms)"
    );

    println!("# reencode fetch — {n_blocks} blocks x {block_len} tokens, {threads} threads");
    println!("{:>6} {:>12} {:>12} {:>9}", "tier", "cold", "memo-warm", "speedup");
    for (name, c, w) in &rows {
        println!("{name:>6} {c:>10.3}ms {w:>10.3}ms {:>8.2}x", c / w);
    }

    let report = Json::obj(vec![
        ("bench", Json::str("reencode")),
        ("threads", Json::num(threads as f64)),
        ("blocks", Json::num(n_blocks as f64)),
        ("block_len", Json::num(block_len as f64)),
        ("fetch_cold_f32_ms", Json::num(rows[0].1)),
        ("fetch_warm_f32_ms", Json::num(rows[0].2)),
        ("fetch_cold_int8_ms", Json::num(rows[1].1)),
        ("fetch_warm_int8_ms", Json::num(rows[1].2)),
        ("fetch_cold_int4_ms", Json::num(rows[2].1)),
        ("fetch_warm_int4_ms", Json::num(rows[2].2)),
        ("memo_speedup_int8", Json::num(c8 / w8)),
    ]);
    let out_path = args.str_or("json-out", "BENCH_reencode.json");
    std::fs::write(&out_path, format!("{report}\n"))?;
    eprintln!("# wrote {out_path}");
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}
