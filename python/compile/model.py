"""L2: the Llama-style transformer and its five AOT entry points.

Architecture: token embedding (tied LM head), N pre-norm blocks
(RMSNorm → GQA attention with RoPE → RMSNorm → SwiGLU MLP), final
RMSNorm. Per-layer parameters are *stacked* on a leading layer axis and
the forward pass is a ``lax.scan`` over layers, which keeps the lowered
HLO compact and the Rust-side parameter interface small (11 tensors).

Entry points (signatures mirrored in ``artifacts/manifest.json``; the
Rust runtime binds them by name):

* ``prefill_full(tokens, length, *params)`` → ``(last_logits, k, v)`` —
  vanilla causal prefill (the paper's full-attention baseline).
* ``prefill_block(tokens, length, *params)`` → ``(k, v)`` — independent
  prefill of one block at **local** positions ``0..L`` (paper §2.1); the
  returned keys are cached and later re-encoded (§2.3).
* ``prefill_final(tokens, q_len, past_k, past_v, past_len, *params)`` →
  ``(last_logits, k, v)`` — the final block attends to the re-encoded
  cached context (§2.5); queries sit at absolute positions
  ``past_len..past_len+q_len``.
* ``decode_step(token, cache_len, k_cache, v_cache, *params)`` →
  ``(logits, k_cache, v_cache)`` — one autoregressive step over a dense
  cache.
* ``train_step(step, lr, tokens, seg, loss_mask, *params, *m, *v)`` →
  ``(loss, *params, *m, *v)`` — one block-fine-tune step (§2.4): the
  attention mask is derived from per-token segment ids (Figure 1 right);
  a row whose segment ids are all equal trains in full-attention mode,
  so one artifact serves both halves of the paper's dual-mode training.

Positions are always *global*: block fine-tuning uses the block-diagonal
mask with sequential positions, matching inference where cached
local-position keys are rotated to their global offsets (the two are
equivalent because RoPE attention depends only on relative positions
within each attended span — pinned by ``tests/test_model.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .configs import ModelConfig
from .kernels import block_attention as ba
from .kernels.ref import apply_rope, rope_cos_sin

# Parameter layout (order matters — it is the checkpoint/train interface).
def param_specs(cfg: ModelConfig):
    N, Dm, H, K, F, V = (
        cfg.layers,
        cfg.d_model,
        cfg.heads,
        cfg.kv_heads,
        cfg.d_ff,
        cfg.vocab,
    )
    hd = cfg.head_dim
    return [
        ("embed", (V, Dm)),
        ("ln1", (N, Dm)),
        ("wq", (N, Dm, H * hd)),
        ("wk", (N, Dm, K * hd)),
        ("wv", (N, Dm, K * hd)),
        ("wo", (N, H * hd, Dm)),
        ("ln2", (N, Dm)),
        ("wg", (N, Dm, F)),
        ("wu", (N, Dm, F)),
        ("wd", (N, F, Dm)),
        ("final_norm", (Dm,)),
    ]


def init_params(cfg: ModelConfig, seed: int):
    """Deterministic initial parameters (numpy, written to the manifest's
    ``init_file`` so Rust-driven training starts from the same weights)."""
    import numpy as np

    rs = np.random.RandomState(seed)
    out = []
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.layers)
    for name, shape in param_specs(cfg):
        if name in ("ln1", "ln2", "final_norm"):
            a = np.ones(shape, np.float32)
        elif name == "embed":
            a = rs.normal(0.0, 0.02, shape).astype(np.float32)
        elif name in ("wo", "wd"):
            a = rs.normal(0.0, 0.02 * resid_scale, shape).astype(np.float32)
        else:
            a = rs.normal(0.0, 0.02, shape).astype(np.float32)
        out.append(a)
    return out


def rms_norm(x, w, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _attn_prefill(cfg, q, k, v, length):
    """Per-block causal attention dispatch. q: (L,H,hd), k/v: (L,K,hd)."""
    L = q.shape[0]
    qT = q.transpose(1, 0, 2)
    kT = k.transpose(1, 0, 2)
    vT = v.transpose(1, 0, 2)
    if cfg.attn_impl == "pallas":
        o = ba.flash_block_attention(qT, kT, vT, jnp.reshape(length, (1,)))
    else:
        o = _jnp_chunked_causal(qT, kT, vT, length, cfg)
    return o.transpose(1, 0, 2)


def _jnp_chunked_causal(q, k, v, length, cfg, chunk=256):
    """Flash-style chunked causal attention in plain jnp (CPU-fast path
    for the very long bench-config sequences — O(L·chunk) memory)."""
    Hq, L, d = q.shape
    Hkv = k.shape[0]
    if Hq != Hkv:
        k = jnp.repeat(k, Hq // Hkv, axis=0)
        v = jnp.repeat(v, Hq // Hkv, axis=0)
    chunk = min(chunk, L)
    assert L % chunk == 0
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qs = q.reshape(Hq, L // chunk, chunk, d).transpose(1, 0, 2, 3)

    def per_chunk(args):
        ci, qc = args
        s = jnp.einsum("hid,hjd->hij", qc, k) * scale
        rows = ci * chunk + jnp.arange(chunk)[:, None]
        cols = jnp.arange(L)[None, :]
        m = (cols <= rows) & (cols < length)
        s = jnp.where(m[None], s, ba.NEG_INF)
        return jnp.einsum("hij,hjd->hid", jax.nn.softmax(s, axis=-1), v)

    out = lax.map(per_chunk, (jnp.arange(L // chunk), qs))
    return out.transpose(1, 0, 2, 3).reshape(Hq, L, d)


def _split_layer_params(params):
    (embed, ln1, wq, wk, wv, wo, ln2, wg, wu, wd, final_norm) = params
    return embed, (ln1, wq, wk, wv, wo, ln2, wg, wu, wd), final_norm


def _layer_step(cfg, x, lp, cos, sin, attn_fn):
    """One transformer block. Returns (x', (k, v)) with k/v post-RoPE
    (keys) ready for caching."""
    L = x.shape[0]
    hd = cfg.head_dim
    l1, wq, wk, wv, wo, l2, wg, wu, wd = lp
    h = rms_norm(x, l1, cfg.norm_eps)
    q = (h @ wq).reshape(L, cfg.heads, hd)
    k = (h @ wk).reshape(L, cfg.kv_heads, hd)
    v = (h @ wv).reshape(L, cfg.kv_heads, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = attn_fn(q, k, v)
    x = x + o.reshape(L, cfg.heads * hd) @ wo
    h2 = rms_norm(x, l2, cfg.norm_eps)
    x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd
    return x, (k, v)


def _prefill(cfg, params, tokens, length, positions):
    """Shared prefill body: scan over layers, collect per-layer KV."""
    embed, layer_params, final_norm = _split_layer_params(params)
    x = embed[tokens]
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)

    def layer(x, lp):
        return _layer_step(
            cfg, x, lp, cos, sin, lambda q, k, v: _attn_prefill(cfg, q, k, v, length)
        )

    x, (ks, vs) = lax.scan(layer, x, layer_params)
    return x, final_norm, embed, ks, vs


def _last_logits(cfg, x, final_norm, embed, idx):
    h = rms_norm(x, final_norm, cfg.norm_eps)
    last = lax.dynamic_slice_in_dim(h, idx, 1, axis=0)[0]
    return last @ embed.T


def prefill_full(cfg: ModelConfig, tokens, length, *params):
    """Vanilla full-attention prefill (baseline). Positions 0..L."""
    L = tokens.shape[0]
    x, final_norm, embed, ks, vs = _prefill(
        cfg, params, tokens, length, jnp.arange(L, dtype=jnp.int32)
    )
    logits = _last_logits(cfg, x, final_norm, embed, length - 1)
    return logits, ks, vs


def prefill_block(cfg: ModelConfig, tokens, length, *params):
    """Independent prefill of one block at local positions (paper §2.1)."""
    L = tokens.shape[0]
    _, _, _, ks, vs = _prefill(
        cfg, params, tokens, length, jnp.arange(L, dtype=jnp.int32)
    )
    return ks, vs


def prefill_final(
    cfg: ModelConfig, tokens, q_len, past_k, past_v, past_len, q_pos0, *params
):
    """Final-block prefill attending to the re-encoded cached context.

    past_k/past_v: (layers, C, kv_heads, hd), valid prefix ``past_len``,
    already rotated to absolute positions by the L3 cache manager.
    ``q_pos0`` is the RoPE position of the first query token — normally
    ``past_len``, but superposition-style baselines place the query right
    after the longest parallel document path instead.
    """
    Lq = tokens.shape[0]
    C = past_k.shape[1]
    embed, layer_params, final_norm = _split_layer_params(params)
    x = embed[tokens]
    positions = q_pos0 + jnp.arange(Lq, dtype=jnp.int32)
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)

    def attn(q, k, v, pk, pv):
        kv_k = jnp.concatenate([pk, k], axis=0)  # (C+Lq, K, hd)
        kv_v = jnp.concatenate([pv, v], axis=0)
        qT = q.transpose(1, 0, 2)
        kT = kv_k.transpose(1, 0, 2)
        vT = kv_v.transpose(1, 0, 2)
        if cfg.attn_impl == "pallas":
            o = ba.flash_context_attention(
                qT, kT, vT, jnp.reshape(past_len, (1,)), ctx_capacity=C
            )
        else:
            from .kernels.ref import context_attention

            o = context_attention(
                qT, kT, vT, C, past_len, kv_repeat=cfg.heads // cfg.kv_heads
            ).astype(qT.dtype)
        return o.transpose(1, 0, 2)

    def layer(x, lp_and_past):
        lp, pk, pv = lp_and_past[:-2], lp_and_past[-2], lp_and_past[-1]
        return _layer_step(
            cfg, x, lp, cos, sin, lambda q, k, v: attn(q, k, v, pk, pv)
        )

    x, (ks, vs) = lax.scan(layer, x, layer_params + (past_k, past_v))
    logits = _last_logits(cfg, x, final_norm, embed, q_len - 1)
    return logits, ks, vs


def decode_step(cfg: ModelConfig, token, cache_len, k_cache, v_cache, *params):
    """One decode step over a dense cache (new token at ``cache_len``)."""
    embed, layer_params, final_norm = _split_layer_params(params)
    hd = cfg.head_dim
    x = embed[token]  # (Dm,)
    pos = jnp.reshape(cache_len, (1,))
    cos, sin = rope_cos_sin(pos, hd, cfg.rope_theta)  # (1, hd/2)
    rep = cfg.heads // cfg.kv_heads
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def layer(x, lp_and_cache):
        lp, kc, vc = lp_and_cache[:-2], lp_and_cache[-2], lp_and_cache[-1]
        l1, wq, wk, wv, wo, l2, wg, wu, wd = lp
        h = rms_norm(x, l1, cfg.norm_eps)
        q = (h @ wq).reshape(1, cfg.heads, hd)
        k = (h @ wk).reshape(1, cfg.kv_heads, hd)
        v = (h @ wv).reshape(1, cfg.kv_heads, hd)
        q = apply_rope(q, cos, sin)[0]  # (H, hd)
        k = apply_rope(k, cos, sin)
        kc = lax.dynamic_update_slice(kc, k, (cache_len, 0, 0))
        vc = lax.dynamic_update_slice(vc, v, (cache_len, 0, 0))
        kr = jnp.repeat(kc, rep, axis=1)  # (C, H, hd)
        vr = jnp.repeat(vc, rep, axis=1)
        s = jnp.einsum("hd,chd->hc", q.astype(jnp.float32), kr.astype(jnp.float32))
        mask = jnp.arange(kc.shape[0]) <= cache_len
        s = jnp.where(mask[None, :], s * scale, ba.NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hc,chd->hd", p, vr.astype(jnp.float32)).astype(x.dtype)
        x = x + o.reshape(cfg.heads * hd) @ wo
        h2 = rms_norm(x, l2, cfg.norm_eps)
        x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd
        return x, (kc, vc)

    x, (kcs, vcs) = lax.scan(layer, x, layer_params + (k_cache, v_cache))
    logits = rms_norm(x, final_norm, cfg.norm_eps) @ embed.T
    return logits, kcs, vcs


# ---------------------------------------------------------------------------
# Training (paper §2.4: block fine-tune)
# ---------------------------------------------------------------------------

def segment_attention_mask(seg):
    """Figure-1 mask from per-token segment ids, batched.

    seg: (B, L) i32; padding rows use a dedicated trailing segment id.
    mask[b,i,j] = causal AND (same segment OR query in final segment).
    The final segment is the row-wise max id — the "last block attends
    everything" rule of Block-attention. A row whose ids are all equal
    degenerates to plain causal (full-attention training mode).
    """
    L = seg.shape[1]
    rows = jnp.arange(L)[:, None]
    cols = jnp.arange(L)[None, :]
    causal = cols <= rows
    same = seg[:, :, None] == seg[:, None, :]
    final = seg[:, :, None] == jnp.max(seg, axis=1)[:, None, None]
    return causal[None] & (same | final)


def _train_forward(cfg, params, tokens, seg):
    embed, layer_params, final_norm = _split_layer_params(params)
    B, L = tokens.shape
    hd = cfg.head_dim
    x = embed[tokens]  # (B, L, Dm)
    cos, sin = rope_cos_sin(jnp.arange(L, dtype=jnp.int32), hd, cfg.rope_theta)
    mask = segment_attention_mask(seg)  # (B, L, L)
    rep = cfg.heads // cfg.kv_heads
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def layer(x, lp):
        l1, wq, wk, wv, wo, l2, wg, wu, wd = lp
        h = rms_norm(x, l1, cfg.norm_eps)
        q = (h @ wq).reshape(B, L, cfg.heads, hd)
        k = (h @ wk).reshape(B, L, cfg.kv_heads, hd)
        v = (h @ wv).reshape(B, L, cfg.kv_heads, hd)
        q = jax.vmap(apply_rope, in_axes=(0, None, None))(q, cos, sin)
        k = jax.vmap(apply_rope, in_axes=(0, None, None))(k, cos, sin)
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        s = jnp.einsum("bihd,bjhd->bhij", q, k) * scale
        s = jnp.where(mask[:, None, :, :], s, ba.NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhij,bjhd->bihd", p, v).reshape(B, L, cfg.heads * hd)
        x = x + o @ wo
        h2 = rms_norm(x, l2, cfg.norm_eps)
        x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd
        return x, None

    x, _ = lax.scan(layer, x, layer_params)
    return rms_norm(x, final_norm, cfg.norm_eps) @ embed.T  # (B, L, V)


def train_loss(cfg, params, tokens, seg, loss_mask):
    """Next-token CE where ``loss_mask[b, t] = 1`` marks token t as a
    prediction target (predicted from position t-1)."""
    logits = _train_forward(cfg, params, tokens, seg)
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    w = loss_mask[:, 1:]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


ADAM_B1, ADAM_B2, ADAM_EPS, CLIP_NORM = 0.9, 0.999, 1e-8, 1.0


def train_step(cfg: ModelConfig, step, lr, tokens, seg, loss_mask, *state):
    """One Adam step with global-norm clipping. ``state`` is
    ``params + m + v`` (3 × 11 tensors); returns ``(loss,) + new_state``."""
    n = len(param_specs(cfg))
    params, m, v = state[:n], state[n : 2 * n], state[2 * n :]
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(cfg, p, tokens, seg, loss_mask)
    )(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
    clip = jnp.minimum(1.0, CLIP_NORM / jnp.maximum(gnorm, 1e-12))
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        g = g * clip
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * jnp.square(g)
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_p.append(p - lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return (loss, *new_p, *new_m, *new_v)


def bind(cfg: ModelConfig, name: str):
    """Entry point by name with the config closed over (for aot/tests)."""
    fns = {
        "prefill_full": prefill_full,
        "prefill_block": prefill_block,
        "prefill_final": prefill_final,
        "decode_step": decode_step,
        "train_step": train_step,
    }
    return functools.partial(fns[name], cfg)
