//! Game-AI scenario (paper Appendix A): a gamecore JSON stream where
//! consecutive frames are nearly identical, so per-field block caching
//! removes almost all prefill work — the paper reports TTFT 2800 ms →
//! 100 ms on a 300-block game state.
//!
//! ```sh
//! cargo run --release --example game_ai -- --frames 12 --players 20
//! ```

use block_attn::coordinator::segmenter::{segment_gamecore, split_oversized_blocks};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::runtime::backend_from_args;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::cli::Args;
use block_attn::util::stats::Summary;
use block_attn::workload::gamecore::{repetition_ratio, GamecoreSim};
use block_attn::Backend;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    let frames = args.usize_or("frames", 12);
    let players = args.usize_or("players", 20);

    let engine = backend_from_args(&args, "small")?;
    engine.warmup()?;
    // Default to the backend's real per-block capacity (clamped to the
    // small-config artifact bucket so native and xla runs agree),
    // overridable with --max-block.
    let max_block = args.usize_or("max-block", engine.max_block_tokens()?.min(256));
    let mut coord = Coordinator::new(engine, 512 << 20);
    let tok = ByteTokenizer::new();
    let mut sim = GamecoreSim::new(players, args.u64_or("seed", 7));

    let mut block_ttft = Summary::new();
    let mut full_ttft = Summary::new();
    let mut rep = Summary::new();
    let mut prev_blocks: Vec<Vec<i32>> = Vec::new();

    println!("frame  blocks  repeat%  ttft-block(ms)  ttft-full(ms)  speedup");
    for f in 0..frames {
        let sp = split_oversized_blocks(
            segment_gamecore(&tok, &sim.frame(), "choose the next action ."),
            max_block,
        )?;
        let repetition = repetition_ratio(&prev_blocks, &sp.blocks);
        prev_blocks = sp.blocks.clone();

        let mk = |mode| Request {
            id: f as u64,
            blocks: sp.blocks.clone(),
            query: sp.query.clone(),
            max_new_tokens: 4,
            mode,
        };
        let rb = coord.process(&mk(AttentionMode::Block))?;
        let rf = coord.process(&mk(AttentionMode::Full))?;
        if f > 0 {
            // Frame 0 is the cold start; the steady state is what matters.
            block_ttft.add(rb.ttft * 1e3);
            full_ttft.add(rf.ttft * 1e3);
            rep.add(repetition);
        }
        println!(
            "{f:>5}  {:>6}  {:>6.1}  {:>14.2}  {:>13.2}  {:>6.1}x",
            rb.total_blocks,
            repetition * 100.0,
            rb.ttft * 1e3,
            rf.ttft * 1e3,
            rf.ttft / rb.ttft.max(1e-9),
        );
        sim.step();
    }

    println!(
        "\nsteady state: repetition {:.1}% | TTFT block p50 {:.2} ms vs full p50 {:.2} ms \
         ({:.1}x) — the Appendix-A effect",
        rep.mean() * 100.0,
        block_ttft.p50(),
        full_ttft.p50(),
        full_ttft.p50() / block_ttft.p50().max(1e-9),
    );
    println!("{}", coord.metrics.report());
    Ok(())
}
