//! The rotation-memo and delta re-encode contracts, end to end:
//!
//! 1. **Bitwise memo** — a memo-warm `get_reencoded` replays the cold
//!    fetch bitwise at every KV tier and thread budget, and survives a
//!    disk spill → drop → promote round-trip (the memo dies with the
//!    resident entry; the re-derived fetch must still match).
//! 2. **Delta accuracy** — `--reencode delta` rotates memoized panels
//!    by Δ₂−Δ₁ instead of re-deriving from the stored block; decode
//!    logits on the workload traces stay within cosine 0.999 of eager.
//! 3. **Memo budget** — `set_memo_budget` bounds `memo_bytes`, evicts
//!    LRU-whole-entry, and never changes fetch results.
//! 4. **FLOPs accounting** — Eq.-3 re-encode FLOPs are charged only
//!    for non-zero shifts: `BlockNoReencode`/`BlockParallel` (and the
//!    offset-0 block in `Block` mode) report none (the PR-9 bugfix).

use block_attn::config::{KvPrecision, ModelConfig, ReencodeMode};
use block_attn::coordinator::{AttentionMode, Coordinator, Request};
use block_attn::flops::FlopsModel;
use block_attn::kernels::set_threads;
use block_attn::kvcache::disk::DiskStore;
use block_attn::kvcache::{block_key, BlockKvCache};
use block_attn::rope::RopeTable;
use block_attn::runtime::NativeBackend;
use block_attn::tokenizer::ByteTokenizer;
use block_attn::util::rng::Rng;
use block_attn::workload::traces::RagTrace;
use block_attn::Backend;
use std::path::PathBuf;
use std::sync::Mutex;

/// The thread-sweep test flips the process-global kernel thread
/// budget; serialize against any sibling doing the same.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab: 24,
        d_model: 16,
        layers: 2,
        heads: 2,
        kv_heads: 1,
        head_dim: 8,
        d_ff: 32,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        max_len: 256,
    }
}

/// Fresh per-test scratch store directory (wiped on entry).
fn store_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("block-attn-test-reencode-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut ab, mut aa, mut bb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        ab += x as f64 * y as f64;
        aa += x as f64 * x as f64;
        bb += y as f64 * y as f64;
    }
    if aa == 0.0 || bb == 0.0 {
        return 1.0;
    }
    ab / (aa.sqrt() * bb.sqrt())
}

/// Contract 1: across every KV tier and thread budget, a memo-warm
/// fetch is bitwise identical to the cold fetch it replays; spilling to
/// disk, dropping residency (which kills the memo), and promoting back
/// re-derives the same bytes.
#[test]
fn memo_warm_fetch_is_bitwise_across_tiers_threads_and_disk() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = block_attn::kernels::num_threads();
    const FP: u64 = 0x9E;
    let cfg = micro_config();
    let mut rng = Rng::new(0x5EED);
    let blocks: Vec<Vec<i32>> = (0..4)
        .map(|i| (0..(6 + 3 * i)).map(|_| rng.below(24) as i32).collect())
        .collect();
    let engine = NativeBackend::new(cfg.clone(), 0xBEE);

    for tier in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
        let mut per_thread = Vec::new();
        for &threads in &[1usize, 3, 8] {
            set_threads(threads);
            let dir = store_dir(&format!("sweep-{tier:?}-{threads}"));
            let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
            let mut cache = BlockKvCache::with_precision(rope, 0, tier);
            assert_eq!(cache.reencode_mode(), ReencodeMode::Eager);
            cache.attach_store(DiskStore::open(&dir, FP, 0).expect("open store"));
            for b in &blocks {
                let (k, v) = engine.prefill_block(b).expect("prefill");
                let key = block_key(b);
                cache.insert_pinned(key, k, v);
                cache.unpin(key);
            }

            let mut delta = 0usize;
            let mut fetched = Vec::new();
            for b in &blocks {
                let key = block_key(b);
                let hits0 = cache.stats().memo_hits;
                let cold = cache.get_reencoded(key, delta).expect("resident block");
                let warm = cache.get_reencoded(key, delta).expect("resident block");
                assert_eq!(cache.stats().memo_hits, hits0 + 1, "repeat fetch not a hit");
                assert_eq!(warm.k, cold.k, "{tier:?}/{threads}t: memo-warm K diverged");
                assert_eq!(warm.v, cold.v, "{tier:?}/{threads}t: memo-warm V diverged");
                assert_eq!(warm.len, cold.len);
                fetched.push((cold.k, cold.v, cold.len));
                delta += b.len();
            }

            // Round-trip: the memo dies with residency; the promoted
            // block must re-derive every panel bitwise.
            assert!(cache.spill_all() > 0, "nothing spilled");
            assert!(cache.drop_resident() > 0, "nothing resident to drop");
            assert_eq!(cache.stats().memo_entries, 0, "memo outlived its entries");
            let mut delta = 0usize;
            for (b, (want_k, want_v, want_len)) in blocks.iter().zip(&fetched) {
                let key = block_key(b);
                assert!(cache.lookup_pin(key), "{tier:?}/{threads}t: lost block on disk");
                let got = cache.get_reencoded(key, delta).expect("promoted block");
                assert_eq!(&got.k, want_k, "{tier:?}/{threads}t: disk K diverged");
                assert_eq!(&got.v, want_v, "{tier:?}/{threads}t: disk V diverged");
                assert_eq!(got.len, *want_len);
                cache.unpin(key);
                delta += b.len();
            }
            let s = cache.stats();
            assert!(s.memo_hits > 0 && s.memo_misses > 0, "memo never engaged");
            assert_eq!(s.disk_errors, 0);
            per_thread.push(fetched);
            let _ = std::fs::remove_dir_all(&dir);
        }
        assert!(
            per_thread.windows(2).all(|w| w[0] == w[1]),
            "{tier:?}: re-encoded fetches depend on the thread count"
        );
    }
    set_threads(prev);
}

/// Contract 2: `--reencode delta` serves decode logits within cosine
/// 0.999 of eager on the workload traces, and actually takes the
/// delta-rotation path (eager must never).
#[test]
fn delta_mode_decode_logits_cosine_against_eager() {
    let tok = ByteTokenizer::new();
    let mut rng = Rng::new(0xACC);
    let trace = RagTrace::build(&mut rng, 24);
    let coordinator = |mode: ReencodeMode| -> Coordinator<NativeBackend> {
        let engine = NativeBackend::new(ModelConfig::builtin("tiny").unwrap(), 0xB10C);
        let mut c = Coordinator::with_kv_precision(engine, 64 << 20, KvPrecision::F32);
        // Explicit, so the test means the same thing under the
        // `BLOCK_ATTN_REENCODE=delta` CI leg.
        c.set_reencode_mode(mode);
        c
    };
    let mut eager = coordinator(ReencodeMode::Eager);
    let mut delta = coordinator(ReencodeMode::Delta);
    assert_eq!(eager.reencode_mode(), ReencodeMode::Eager);
    assert_eq!(delta.reencode_mode(), ReencodeMode::Delta);

    let mut worst = 1.0f64;
    for _ in 0..5 {
        let sample = trace.request(&mut rng, 4, 1.1);
        let sp = sample.segment(&tok);
        let mut forced = tok.encode(&sample.response);
        forced.truncate(6);
        let a = eager
            .logits_trace(&sp.blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("eager trace");
        let b = delta
            .logits_trace(&sp.blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("delta trace");
        assert_eq!(a.len(), b.len());
        for (step, (la, lb)) in a.iter().zip(&b).enumerate() {
            let c = cosine(la, lb);
            worst = worst.min(c);
            assert!(c >= 0.999, "step {step}: cosine {c} < 0.999 (delta drift too large)");
        }
    }
    // Force offset reuse deterministically: serve one more sample,
    // then the same passages in reverse order — every block refetches
    // at a new Δ, so delta mode must take the Δ₂−Δ₁ rotation path.
    let sample = trace.request(&mut rng, 4, 1.1);
    let sp = sample.segment(&tok);
    let mut rev = sp.blocks.clone();
    rev.reverse();
    let mut forced = tok.encode(&sample.response);
    forced.truncate(4);
    for blocks in [&sp.blocks, &rev] {
        let a = eager
            .logits_trace(blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("eager trace");
        let b = delta
            .logits_trace(blocks, &sp.query, &forced, AttentionMode::Block)
            .expect("delta trace");
        for (la, lb) in a.iter().zip(&b) {
            worst = worst.min(cosine(la, lb));
        }
    }
    assert!(worst >= 0.999, "worst cosine {worst} < 0.999");
    // The modes must actually differ in mechanism, not just agree.
    assert_eq!(eager.cache_stats().delta_rotations, 0, "eager took the delta path");
    assert!(
        delta.cache_stats().delta_rotations > 0,
        "delta mode never delta-rotated despite forced offset reuse"
    );
}

/// Contract 3: the memo byte budget is respected (LRU whole-entry
/// eviction, ties on content key) and budget pressure never changes
/// what a fetch returns.
#[test]
fn memo_budget_is_respected_and_bitwise_invisible() {
    let cfg = micro_config();
    let engine = NativeBackend::new(cfg.clone(), 0xBEE);
    let mut rng = Rng::new(0xB06);
    let blocks: Vec<Vec<i32>> = (0..6)
        .map(|_| (0..12).map(|_| rng.below(24) as i32).collect())
        .collect();
    let mk_cache = || -> BlockKvCache {
        let rope = RopeTable::new(cfg.head_dim, cfg.rope_theta);
        let mut cache = BlockKvCache::with_precision(rope, 0, KvPrecision::Int8);
        for b in &blocks {
            let (k, v) = engine.prefill_block(b).expect("prefill");
            let key = block_key(b);
            cache.insert_pinned(key, k, v);
            cache.unpin(key);
        }
        cache
    };
    let mut unbounded = mk_cache();
    let mut budgeted = mk_cache();
    // Room for roughly two memo entries: one dense f32 K panel + V
    // per block, 2 layers x 12 tokens x 1 head x 8 dims x 4 bytes x 2.
    let budget = 2 * (2 * 2 * 12 * 8 * 4);
    budgeted.set_memo_budget(budget);

    for round in 0..3 {
        let mut delta = 0usize;
        for b in &blocks {
            let key = block_key(b);
            let want = unbounded.get_reencoded(key, delta).expect("unbounded fetch");
            let got = budgeted.get_reencoded(key, delta).expect("budgeted fetch");
            assert_eq!(got.k, want.k, "round {round}: budget pressure changed K");
            assert_eq!(got.v, want.v, "round {round}: budget pressure changed V");
            let s = budgeted.stats();
            assert!(
                s.memo_bytes <= budget,
                "round {round}: memo_bytes {} over budget {budget}",
                s.memo_bytes
            );
            delta += b.len();
        }
    }
    let s = budgeted.stats();
    assert!(s.memo_evictions > 0, "budget never forced an eviction");
    assert!(s.memo_entries > 0 && s.memo_bytes > 0, "memo fully starved");
    let su = unbounded.stats();
    assert_eq!(su.memo_evictions, 0, "unbounded cache evicted memo entries");
    assert!(su.memo_hits > s.memo_hits, "budgeted cache should hit less often");
}

/// Contract 4 (the FLOPs bugfix): on a fully warm cache, `Block` mode
/// charges exactly one Eq.-3 re-encode per **non-zero-offset** block on
/// top of the final prefill, and the no-reencode modes charge none —
/// they fetch everything at Δ = 0.
#[test]
fn reencode_flops_charged_only_for_nonzero_shifts() {
    let cfg = micro_config();
    let fm = FlopsModel::from_config(&cfg);
    let engine = NativeBackend::new(cfg, 0xD15C);
    let mut coord = Coordinator::with_kv_precision(engine, 64 << 20, KvPrecision::F32);
    let mut rng = Rng::new(0xF10);
    let mut block = |len: usize| -> Vec<i32> {
        (0..len).map(|_| rng.below(24) as i32).collect()
    };
    let blocks = vec![block(10), block(7), block(12)];
    let query = block(6);
    let req = |mode: AttentionMode| Request {
        id: 0,
        blocks: blocks.clone(),
        query: query.clone(),
        max_new_tokens: 2,
        mode,
    };

    // Cold pass populates the cache; every later pass is fully warm.
    coord.process(&req(AttentionMode::Block)).expect("cold pass");
    let warm = |coord: &mut Coordinator<NativeBackend>, mode: AttentionMode| -> f64 {
        let resp = coord.process(&req(mode)).expect("warm pass");
        assert_eq!(resp.cached_blocks, resp.total_blocks, "{mode:?}: warm pass missed");
        assert_eq!(resp.block_prefill_s, 0.0, "{mode:?}: warm pass recomputed KV");
        resp.flops_tft
    };
    let f_block = warm(&mut coord, AttentionMode::Block);
    let f_nore = warm(&mut coord, AttentionMode::BlockNoReencode);
    let f_par = warm(&mut coord, AttentionMode::BlockParallel);

    let ctx: usize = blocks.iter().map(|b| b.len()).sum();
    let f_final = fm.prefill_final(query.len(), ctx);
    // Block 0 sits at offset 0: fetched at Δ = 0, no Eq.-3 work.
    let f_shift: f64 = blocks[1..].iter().map(|b| fm.reencode(b.len())).sum();
    let close = |got: f64, want: f64| (got - want).abs() <= 1e-9 * want.max(1.0);
    assert!(
        close(f_nore, f_final),
        "BlockNoReencode warm FLOPs {f_nore} != final-prefill-only {f_final} \
         (Δ=0 fetches are being charged for re-encode)"
    );
    assert_eq!(f_nore, f_par, "the two Δ=0 modes must report identical FLOPs");
    assert!(
        close(f_block, f_final + f_shift),
        "Block warm FLOPs {f_block} != {} (final {f_final} + shifted-block \
         re-encode {f_shift})",
        f_final + f_shift
    );
    assert!(f_block > f_nore, "re-encode work vanished from Block mode");
}
