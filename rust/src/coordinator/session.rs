//! Multi-turn dialogue sessions over Block-attention.
//!
//! Paper §2.2: "in multi-turn dialogues, each turn could be segmented
//! into an individual block". A [`Session`] accumulates turns; every
//! *completed* turn (user message + assistant reply) becomes an
//! immutable context block whose KV states are cached once and reused —
//! with RoPE re-encoding — on every subsequent turn of this session
//! *and any other session that shares a prefix block* (system prompts,
//! few-shot preambles), so the per-turn prefill cost stays constant
//! instead of growing with history length.

use super::{AttentionMode, Coordinator, Request, Response};
use crate::runtime::Backend;
use crate::tokenizer::{ByteTokenizer, EOS, SEP};
use anyhow::Result;

/// One in-progress conversation.
pub struct Session {
    id: u64,
    /// Completed history, one token block per turn (SEP-terminated).
    history: Vec<Vec<i32>>,
    tok: ByteTokenizer,
    pub max_new_tokens: usize,
    pub mode: AttentionMode,
}

impl Session {
    pub fn new(id: u64) -> Session {
        Session {
            id,
            history: Vec::new(),
            tok: ByteTokenizer::new(),
            max_new_tokens: 32,
            mode: AttentionMode::Block,
        }
    }

    /// Seed the session with a system/preamble block (shareable across
    /// sessions through the content-addressed cache).
    pub fn with_system(mut self, system: &str) -> Session {
        let mut ids = self.tok.encode(system);
        ids.push(SEP);
        self.history.push(ids);
        self
    }

    pub fn turns(&self) -> usize {
        self.history.len()
    }

    /// Tokens of prior context (what block caching saves per turn).
    pub fn history_tokens(&self) -> usize {
        self.history.iter().map(|b| b.len()).sum()
    }

    /// Run one turn: the user message is the final (query) block over
    /// the cached history; the exchange is then sealed into a new
    /// history block. Returns (reply text, serving response).
    pub fn turn<B: Backend>(
        &mut self,
        coord: &mut Coordinator<B>,
        user: &str,
    ) -> Result<(String, Response)> {
        let mut query = vec![crate::tokenizer::QRY];
        query.extend(self.tok.encode(user));
        let req = Request {
            id: self.id,
            blocks: self.history.clone(),
            query: query.clone(),
            max_new_tokens: self.max_new_tokens,
            mode: self.mode,
        };
        let resp = coord.process(&req)?;
        let reply = self.tok.decode_until_eos(&resp.tokens);

        // Seal the exchange as an immutable history block: query + reply
        // + SEP, and precompute its independent-block KV *off the
        // critical path* (the reply has already been returned) so the
        // next turn is fully cache-hot.
        let mut block = query;
        block.extend(resp.tokens.iter().take_while(|&&t| t != EOS));
        block.push(SEP);
        coord.precompute_block(&block)?;
        self.history.push(block);
        Ok((reply, resp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_grows_one_block_per_turn() {
        let s = Session::new(1).with_system("be brief");
        assert_eq!(s.turns(), 1);
        assert!(s.history_tokens() > 0);
    }

    #[test]
    fn system_blocks_are_shareable() {
        let a = Session::new(1).with_system("same system prompt");
        let b = Session::new(2).with_system("same system prompt");
        // Identical token content → identical cache key → cross-session
        // KV reuse.
        assert_eq!(a.history[0], b.history[0]);
        assert_eq!(
            crate::kvcache::block_key(&a.history[0]),
            crate::kvcache::block_key(&b.history[0])
        );
    }
}
