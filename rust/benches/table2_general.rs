//! Table 2 reproduction: accuracy on general (0-shot) and ICL (k-shot)
//! benchmarks for the three checkpoints. Zero-shot tasks run the
//! Block-attention model in full-attention mode (the paper's fallback);
//! k-shot tasks segment each demonstration into its own block.
//!
//! ```sh
//! cargo bench --bench table2_general -- --samples 50
//! ```

use block_attn::coordinator::{AttentionMode, Coordinator};
use block_attn::runtime::backend_from_args;
use block_attn::train::eval::{accuracy, EvalOpts};
use block_attn::train::presets::general_eval_by_task;
use block_attn::util::cli::Args;
use block_attn::Backend;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    block_attn::kernels::init_threads_from_args(&args);
    let samples_n = args.usize_or("samples", 25);
    let ck_dir = PathBuf::from(args.str_or("checkpoints", "checkpoints"));
    let model = args.str_or("model", "tiny");

    for tag in ["base", "rag", "block"] {
        let p = ck_dir.join(format!("{model}_{tag}.bin"));
        if !p.exists() {
            eprintln!("missing checkpoint {p:?} — run `make checkpoints` first");
            std::process::exit(0);
        }
    }

    let engine = backend_from_args(&args, &model)?;
    let mut coord = Coordinator::new(engine, 256 << 20);
    let benches = general_eval_by_task(samples_n);

    // (row label, checkpoint, ICL mode) — zero-shot tasks always run full.
    let rows: Vec<(&str, &str, AttentionMode)> = vec![
        ("SFT (base)", "base", AttentionMode::Full),
        ("RAG-ft", "rag", AttentionMode::Full),
        ("block-ft", "block", AttentionMode::Block),
    ];

    println!("# Table 2 — general (0-shot → full-attn fallback) and ICL (k-shot → blocks)");
    print!("{:<12}", "model");
    for (name, _, _) in &benches {
        print!(" {name:>18}");
    }
    println!(" {:>8}", "avg");

    let mut loaded = String::new();
    for (label, ckpt, icl_mode) in rows {
        if loaded != ckpt {
            coord
                .engine()
                .load_params_file(&ck_dir.join(format!("{model}_{ckpt}.bin")))?;
            loaded = ckpt.to_string();
        }
        print!("{label:<12}");
        let mut sum = 0.0;
        for (_, zero_shot, samples) in &benches {
            let mode = if *zero_shot { AttentionMode::Full } else { icl_mode };
            let acc = accuracy(
                &mut coord,
                samples,
                &EvalOpts { mode, max_new_tokens: 12, fresh_cache: true },
            )?;
            sum += acc;
            print!(" {:>17.1}%", acc * 100.0);
        }
        println!(" {:>7.1}%", sum / benches.len() as f64 * 100.0);
    }
    println!("\n# paper shape: block-ft ≈ the full-attention models on every column;");
    println!("# mode switching (0-shot full fallback) costs nothing.");
    eprintln!("{}", block_attn::kernels::pool_stats_line());
    Ok(())
}
